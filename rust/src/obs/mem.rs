//! Deterministic memory-footprint accounting — the capacity half of the
//! observability plane (the flight recorder in [`crate::obs`] is the
//! timing half).
//!
//! The paper's core claim is that a LEO shell can act as one giant
//! distributed KV cache, which makes *cache bytes per cached token* the
//! capacity currency of the whole system.  Every container that holds
//! cache state — the satellite [`crate::satellite::store::ChunkStore`]s,
//! the [`crate::kvc::radix`] prefix index, the managers' per-block maps —
//! implements [`MemFootprint`] and reports a [`FootprintEstimate`] split
//! three ways:
//!
//! * `payload_bytes` — the cached data itself (chunk payloads, decoded
//!   KV values).  This is what the byte budgets meter.
//! * `index_bytes` — bookkeeping that finds the payload: map entries,
//!   radix nodes, LRU tracker slots.
//! * `overhead_bytes` — modeled per-allocation cost
//!   ([`ALLOC_OVERHEAD`] per heap allocation: allocator headers plus
//!   size-class rounding).  Estimates that ignore this undercount small
//!   objects badly, so it is carried explicitly, never folded into the
//!   other two.
//!
//! Index containers additionally report an informational *layer split*:
//! `frozen_bytes` (the immutable epoch-compacted arena in
//! [`crate::kvc::frozen`]) and `delta_bytes` (the mutable layer
//! absorbing the live epoch's writes).  Both re-tag bytes already
//! counted in `index_bytes`/`overhead_bytes`, so they are *not* part of
//! [`FootprintEstimate::total`] — they say where the index bytes live,
//! not add to them.
//!
//! Everything here is an *estimate* computed from live element counts
//! and `size_of` — a pure function of cache state, so same-seed runs
//! report byte-identical numbers and `sim::diff` can gate on them.  The
//! feature-gated counting allocator in [`profile`] (`--features
//! mem-profile`) provides ground truth to validate the model against
//! (`rust/benches/mem.rs`).

use crate::util::json::{n, obj, Json};

/// Modeled cost of one heap allocation in bytes: allocator header plus
/// size-class rounding.  48 B matches the jemalloc-measured per-object
/// overhead of small-map workloads (see ROADMAP's memkv citation); the
/// exact value matters less than charging *something* per allocation so
/// many-small-objects layouts are not reported as free.
pub const ALLOC_OVERHEAD: usize = 48;

/// A structured memory estimate.  All byte counts are estimates derived
/// from live element counts (never `Vec` capacities), so they are
/// deterministic, monotone under inserts, and shrink on eviction.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FootprintEstimate {
    /// Cached data itself (chunk payloads, decoded KV values).
    pub payload_bytes: u64,
    /// Bookkeeping that finds the payload (map entries, radix nodes,
    /// LRU slots).
    pub index_bytes: u64,
    /// Modeled per-allocation overhead ([`ALLOC_OVERHEAD`] each).
    pub overhead_bytes: u64,
    /// Informational: index + overhead bytes living in an immutable
    /// epoch-compacted frozen layer ([`crate::kvc::frozen`]).  A re-tag
    /// of bytes already counted above, never added to [`Self::total`].
    pub frozen_bytes: u64,
    /// Informational: index + overhead bytes living in a mutable delta
    /// layer (the live epoch's writes).  A re-tag, like `frozen_bytes`.
    pub delta_bytes: u64,
}

impl FootprintEstimate {
    pub const ZERO: FootprintEstimate = FootprintEstimate {
        payload_bytes: 0,
        index_bytes: 0,
        overhead_bytes: 0,
        frozen_bytes: 0,
        delta_bytes: 0,
    };

    /// Sum of all three components.
    pub fn total(&self) -> u64 {
        self.payload_bytes + self.index_bytes + self.overhead_bytes
    }

    /// Accumulate another estimate into this one (rollups).
    pub fn add(&mut self, other: FootprintEstimate) {
        self.payload_bytes += other.payload_bytes;
        self.index_bytes += other.index_bytes;
        self.overhead_bytes += other.overhead_bytes;
        self.frozen_bytes += other.frozen_bytes;
        self.delta_bytes += other.delta_bytes;
    }

    /// Charge `count` heap allocations of modeled overhead.
    pub fn charge_allocs(&mut self, count: u64) {
        self.overhead_bytes += count * ALLOC_OVERHEAD as u64;
    }

    /// Byte-stable JSON rendering (sorted keys, integer bytes).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("delta_bytes", n(self.delta_bytes as f64)),
            ("frozen_bytes", n(self.frozen_bytes as f64)),
            ("index_bytes", n(self.index_bytes as f64)),
            ("overhead_bytes", n(self.overhead_bytes as f64)),
            ("payload_bytes", n(self.payload_bytes as f64)),
            ("total_bytes", n(self.total() as f64)),
        ])
    }
}

/// Implemented by every container that holds cache state.  The estimate
/// must be a pure function of the container's logical contents: two
/// containers holding the same elements report the same footprint, no
/// matter how they got there.
pub trait MemFootprint {
    fn mem_footprint(&self) -> FootprintEstimate;
}

/// The feature-gated counting global allocator (`--features
/// mem-profile`): wraps the system allocator and keeps process-wide
/// allocation count, live bytes, and peak bytes.  `rust/benches/mem.rs`
/// installs it as `#[global_allocator]` to validate the
/// [`FootprintEstimate`] model against measured reality; it is never
/// compiled into default builds.
#[cfg(feature = "mem-profile")]
pub mod profile {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::sync::atomic::{AtomicU64, Ordering};

    static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);
    static LIVE_BYTES: AtomicU64 = AtomicU64::new(0);
    static PEAK_BYTES: AtomicU64 = AtomicU64::new(0);

    /// Counting wrapper around [`System`].  `realloc` is counted as one
    /// new allocation (the old block is debited, the new size credited),
    /// so `allocations` is an upper bound on distinct live objects while
    /// `live_bytes` stays exact.
    pub struct CountingAlloc;

    fn record_alloc(size: usize) {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        let live = LIVE_BYTES.fetch_add(size as u64, Ordering::Relaxed) + size as u64;
        PEAK_BYTES.fetch_max(live, Ordering::Relaxed);
    }

    unsafe impl GlobalAlloc for CountingAlloc {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            let p = System.alloc(layout);
            if !p.is_null() {
                record_alloc(layout.size());
            }
            p
        }

        unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
            let p = System.alloc_zeroed(layout);
            if !p.is_null() {
                record_alloc(layout.size());
            }
            p
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout);
            LIVE_BYTES.fetch_sub(layout.size() as u64, Ordering::Relaxed);
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            let p = System.realloc(ptr, layout, new_size);
            if !p.is_null() {
                LIVE_BYTES.fetch_sub(layout.size() as u64, Ordering::Relaxed);
                record_alloc(new_size);
            }
            p
        }
    }

    /// A copy of the process-wide allocation counters.
    #[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
    pub struct AllocSnapshot {
        pub allocations: u64,
        pub live_bytes: u64,
        pub peak_bytes: u64,
    }

    pub fn snapshot() -> AllocSnapshot {
        AllocSnapshot {
            allocations: ALLOCATIONS.load(Ordering::Relaxed),
            live_bytes: LIVE_BYTES.load(Ordering::Relaxed),
            peak_bytes: PEAK_BYTES.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_and_rollups() {
        let mut a = FootprintEstimate {
            payload_bytes: 100,
            index_bytes: 10,
            ..FootprintEstimate::ZERO
        };
        a.charge_allocs(2);
        assert_eq!(a.overhead_bytes, 2 * ALLOC_OVERHEAD as u64);
        assert_eq!(a.total(), 100 + 10 + 2 * ALLOC_OVERHEAD as u64);
        let mut sum = FootprintEstimate::ZERO;
        sum.add(a);
        sum.add(a);
        assert_eq!(sum.total(), 2 * a.total());
        assert_eq!(FootprintEstimate::ZERO.total(), 0);
    }

    #[test]
    fn layer_split_is_informational_not_additive() {
        let mut a = FootprintEstimate {
            index_bytes: 40,
            overhead_bytes: 8,
            frozen_bytes: 30,
            delta_bytes: 18,
            ..FootprintEstimate::ZERO
        };
        // the split re-tags index + overhead; total ignores it
        assert_eq!(a.total(), 48);
        let b = a;
        a.add(b);
        assert_eq!((a.frozen_bytes, a.delta_bytes), (60, 36));
        assert_eq!(a.total(), 96);
    }

    #[test]
    fn json_is_sorted_and_integer() {
        let e = FootprintEstimate {
            payload_bytes: 5,
            index_bytes: 3,
            overhead_bytes: 2,
            frozen_bytes: 4,
            delta_bytes: 1,
        };
        let j = e.to_json().to_string();
        assert_eq!(
            j,
            r#"{"delta_bytes":1,"frozen_bytes":4,"index_bytes":3,"overhead_bytes":2,"payload_bytes":5,"total_bytes":10}"#
        );
    }
}
