//! Observability: a deterministic, virtual-time flight recorder.
//!
//! Every layer that does timed work — the [`crate::net::sched`] link
//! scheduler, the single-shell [`crate::kvc::manager::KvcManager`], the
//! [`crate::federation::manager::FederatedKvcManager`] and the scenario
//! harness — can emit structured span/instant [`TraceEvent`]s into a
//! [`TraceSink`].  Events are stamped with **virtual time** (the
//! scheduler's `virtual_ns` clock, never the wall clock) plus a logical
//! sequence number assigned at record time as the deterministic
//! tie-break, so two runs of the same seed produce byte-identical logs.
//!
//! The default sink is [`NoopSink`]: every instrumentation site first
//! asks [`TraceSink::wants`] for its [`SpanKind`] and skips all event
//! construction when the answer is `false`, so tracing is
//! pay-for-what-you-use.  [`Recorder`] collects events in memory for the
//! two exporters:
//!
//! * [`jsonl`] — compact one-object-per-line JSON, byte-stable under the
//!   same `util::json` discipline as scenario metrics (golden-testable);
//! * [`chrome`] — Chrome trace-event JSON loadable in Perfetto /
//!   `chrome://tracing`, with shells rendered as processes and links as
//!   threads.
//!
//! See `docs/TRACING.md` for the event schema and a worked example.
//!
//! The capacity half of the plane lives in [`mem`]: deterministic
//! memory-footprint estimates ([`mem::MemFootprint`]) for every
//! cache-holding container, sampled by the harness into the scenario
//! reports' `memory` object.

pub mod mem;

use crate::util::json::{obj, s, Json};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// The layer a trace event belongs to; `--spans` filters on these.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanKind {
    /// `net::sched` transfer lifecycle: enqueue, acquire, queue, serialize, xfer.
    Sched,
    /// Single-shell manager Get/Set fan-out batches.
    Kvc,
    /// Federation: race arms, promotions, evacuations, epoch rotation.
    Fed,
    /// Injected failures: satellite loss, ISL outage, correlated plans.
    Fault,
    /// Harness milestones: epoch boundaries, handovers.
    Sim,
}

impl SpanKind {
    pub const ALL: [SpanKind; 5] =
        [SpanKind::Sched, SpanKind::Kvc, SpanKind::Fed, SpanKind::Fault, SpanKind::Sim];

    pub fn as_str(self) -> &'static str {
        match self {
            SpanKind::Sched => "sched",
            SpanKind::Kvc => "kvc",
            SpanKind::Fed => "fed",
            SpanKind::Fault => "fault",
            SpanKind::Sim => "sim",
        }
    }

    fn bit(self) -> u8 {
        match self {
            SpanKind::Sched => 1 << 0,
            SpanKind::Kvc => 1 << 1,
            SpanKind::Fed => 1 << 2,
            SpanKind::Fault => 1 << 3,
            SpanKind::Sim => 1 << 4,
        }
    }
}

/// Which [`SpanKind`]s a [`Recorder`] keeps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanFilter {
    mask: u8,
}

impl SpanFilter {
    /// Keep every kind.
    pub fn all() -> SpanFilter {
        SpanFilter { mask: 0b1_1111 }
    }

    /// Parse a comma-separated kind list, e.g. `"sched,fed"`.
    pub fn parse(spec: &str) -> Result<SpanFilter, String> {
        let mut mask = 0u8;
        for part in spec.split(',') {
            let part = part.trim();
            let kind = match part {
                "sched" => SpanKind::Sched,
                "kvc" => SpanKind::Kvc,
                "fed" => SpanKind::Fed,
                "fault" => SpanKind::Fault,
                "sim" => SpanKind::Sim,
                _ => {
                    return Err(format!(
                        "unknown span kind `{part}` (expected sched|kvc|fed|fault|sim)"
                    ))
                }
            };
            mask |= kind.bit();
        }
        if mask == 0 {
            return Err("empty span filter".into());
        }
        Ok(SpanFilter { mask })
    }

    pub fn allows(self, kind: SpanKind) -> bool {
        self.mask & kind.bit() != 0
    }
}

impl Default for SpanFilter {
    fn default() -> Self {
        SpanFilter::all()
    }
}

/// A structured argument value on a trace event.
#[derive(Debug, Clone, PartialEq)]
pub enum ArgVal {
    U(u64),
    I(i64),
    S(String),
}

impl ArgVal {
    fn to_json(&self) -> Json {
        match self {
            ArgVal::U(v) => Json::Num(*v as f64),
            ArgVal::I(v) => Json::Num(*v as f64),
            ArgVal::S(v) => s(v),
        }
    }
}

/// One span (`dur_ns > 0`) or instant (`dur_ns == 0`) on the virtual
/// timeline.  `seq` is assigned by the sink at record time and is the
/// deterministic tie-break for events sharing a timestamp.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub seq: u64,
    /// Virtual-time start, nanoseconds.
    pub ts_ns: u64,
    /// Span duration in virtual nanoseconds; 0 marks an instant event.
    pub dur_ns: u64,
    pub kind: SpanKind,
    pub name: &'static str,
    /// Emitting shell, if the event is shell-scoped (federation control
    /// events carry `None`).
    pub shell: Option<u16>,
    /// Link label (`uplink:P.S` / `serve:P.S`), if link-scoped.
    pub link: Option<String>,
    pub args: Vec<(&'static str, ArgVal)>,
}

impl TraceEvent {
    /// An instant event (no duration).
    pub fn instant(kind: SpanKind, name: &'static str, ts_ns: u64) -> TraceEvent {
        TraceEvent { seq: 0, ts_ns, dur_ns: 0, kind, name, shell: None, link: None, args: vec![] }
    }

    /// A span event covering `[ts_ns, ts_ns + dur_ns)`.
    pub fn span(kind: SpanKind, name: &'static str, ts_ns: u64, dur_ns: u64) -> TraceEvent {
        TraceEvent { seq: 0, ts_ns, dur_ns, kind, name, shell: None, link: None, args: vec![] }
    }

    pub fn with_shell(mut self, shell: u16) -> TraceEvent {
        self.shell = Some(shell);
        self
    }

    pub fn with_link(mut self, link: String) -> TraceEvent {
        self.link = Some(link);
        self
    }

    pub fn arg(mut self, key: &'static str, val: ArgVal) -> TraceEvent {
        self.args.push((key, val));
        self
    }

    pub fn arg_u(self, key: &'static str, val: u64) -> TraceEvent {
        self.arg(key, ArgVal::U(val))
    }
}

/// Where instrumented code sends events.  Implementations must be cheap
/// to interrogate: call sites gate all event construction on
/// [`TraceSink::wants`].
pub trait TraceSink: Send + Sync {
    /// Does this sink want events of `kind` at all?  `false` lets the
    /// caller skip event construction entirely.
    fn wants(&self, kind: SpanKind) -> bool;
    fn record(&self, ev: TraceEvent);
}

/// The zero-cost default sink: wants nothing, records nothing.
#[derive(Debug, Default, Clone, Copy)]
pub struct NoopSink;

impl TraceSink for NoopSink {
    fn wants(&self, _kind: SpanKind) -> bool {
        false
    }
    fn record(&self, _ev: TraceEvent) {}
}

/// An in-memory sink.  Sequence numbers are assigned in record order;
/// all instrumented paths record from a single thread of control, so
/// record order — and therefore the exported byte stream — is a pure
/// function of the seed.
#[derive(Debug, Default)]
pub struct Recorder {
    events: Mutex<Vec<TraceEvent>>,
    seq: AtomicU64,
    filter: SpanFilter,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::with_filter(SpanFilter::all())
    }

    pub fn with_filter(filter: SpanFilter) -> Recorder {
        Recorder { events: Mutex::new(Vec::new()), seq: AtomicU64::new(0), filter }
    }

    /// Drain all recorded events, in sequence order.
    pub fn take(&self) -> Vec<TraceEvent> {
        std::mem::take(&mut *self.events.lock().unwrap())
    }

    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl TraceSink for Recorder {
    fn wants(&self, kind: SpanKind) -> bool {
        self.filter.allows(kind)
    }

    fn record(&self, mut ev: TraceEvent) {
        if !self.filter.allows(ev.kind) {
            return;
        }
        ev.seq = self.seq.fetch_add(1, Ordering::Relaxed);
        self.events.lock().unwrap().push(ev);
    }
}

fn event_json(ev: &TraceEvent) -> Json {
    let args = Json::Obj(ev.args.iter().map(|(k, v)| (k.to_string(), v.to_json())).collect());
    let mut pairs = vec![
        ("args", args),
        ("dur_ns", Json::Num(ev.dur_ns as f64)),
        ("kind", s(ev.kind.as_str())),
        ("name", s(ev.name)),
        ("seq", Json::Num(ev.seq as f64)),
        ("ts_ns", Json::Num(ev.ts_ns as f64)),
    ];
    if let Some(link) = &ev.link {
        pairs.push(("link", s(link)));
    }
    if let Some(shell) = ev.shell {
        pairs.push(("shell", Json::Num(shell as f64)));
    }
    obj(pairs)
}

/// Compact JSONL export: one event object per line, in sequence order,
/// keys sorted — byte-stable across same-seed runs.
pub fn jsonl(events: &[TraceEvent]) -> String {
    let mut out = String::with_capacity(events.len() * 96);
    for ev in events {
        out.push_str(&event_json(ev).to_string());
        out.push('\n');
    }
    out
}

/// Process id for the Chrome export: shells map to pid `shell + 1`,
/// shell-less (federation control / harness) events to pid 0.
fn chrome_pid(ev: &TraceEvent) -> u64 {
    match ev.shell {
        Some(sh) => sh as u64 + 1,
        None => 0,
    }
}

/// Chrome trace-event JSON (the `traceEvents` array form), loadable in
/// Perfetto or `chrome://tracing`.  Shells become processes, links
/// become named threads; events without a link land on thread 0
/// (`ops`).  Timestamps are virtual microseconds.
pub fn chrome(events: &[TraceEvent]) -> String {
    use std::collections::BTreeMap;
    // Stable thread ids: per process, links sorted by label, from 1.
    let mut pids: BTreeMap<u64, Vec<String>> = BTreeMap::new();
    for ev in events {
        let links = pids.entry(chrome_pid(ev)).or_default();
        if let Some(link) = &ev.link {
            if !links.contains(link) {
                links.push(link.clone());
            }
        }
    }
    let mut tid_of: BTreeMap<(u64, String), u64> = BTreeMap::new();
    let mut meta: Vec<Json> = Vec::new();
    for (pid, links) in &mut pids {
        links.sort();
        let pname = if *pid == 0 {
            "control".to_string()
        } else {
            format!("shell {}", pid - 1)
        };
        meta.push(obj(vec![
            ("args", obj(vec![("name", s(&pname))])),
            ("name", s("process_name")),
            ("ph", s("M")),
            ("pid", Json::Num(*pid as f64)),
            ("tid", Json::Num(0.0)),
        ]));
        for (i, link) in std::iter::once(&"ops".to_string()).chain(links.iter()).enumerate() {
            tid_of.insert((*pid, link.clone()), i as u64);
            meta.push(obj(vec![
                ("args", obj(vec![("name", s(link))])),
                ("name", s("thread_name")),
                ("ph", s("M")),
                ("pid", Json::Num(*pid as f64)),
                ("tid", Json::Num(i as f64)),
            ]));
        }
    }
    let mut out: Vec<Json> = meta;
    for ev in events {
        let pid = chrome_pid(ev);
        let tid = match &ev.link {
            Some(link) => *tid_of.get(&(pid, link.clone())).unwrap_or(&0),
            None => 0,
        };
        let mut arg_pairs: Vec<(String, Json)> =
            ev.args.iter().map(|(k, v)| (k.to_string(), v.to_json())).collect();
        arg_pairs.push(("seq".to_string(), Json::Num(ev.seq as f64)));
        let mut pairs = vec![
            ("args", Json::Obj(arg_pairs.into_iter().collect())),
            ("cat", s(ev.kind.as_str())),
            ("name", s(ev.name)),
            ("pid", Json::Num(pid as f64)),
            ("tid", Json::Num(tid as f64)),
            ("ts", Json::Num(ev.ts_ns as f64 / 1000.0)),
        ];
        if ev.dur_ns > 0 {
            pairs.push(("dur", Json::Num(ev.dur_ns as f64 / 1000.0)));
            pairs.push(("ph", s("X")));
        } else {
            pairs.push(("ph", s("i")));
            pairs.push(("s", s("t")));
        }
        out.push(obj(pairs));
    }
    obj(vec![("traceEvents", Json::Arr(out))]).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn ev(kind: SpanKind, name: &'static str, ts: u64) -> TraceEvent {
        TraceEvent::instant(kind, name, ts)
    }

    #[test]
    fn noop_sink_wants_nothing() {
        let sink = NoopSink;
        for kind in SpanKind::ALL {
            assert!(!sink.wants(kind));
        }
        sink.record(ev(SpanKind::Sched, "x", 0)); // must not panic
    }

    #[test]
    fn recorder_assigns_monotone_sequence_numbers() {
        let rec = Recorder::new();
        rec.record(ev(SpanKind::Sched, "a", 10));
        rec.record(ev(SpanKind::Kvc, "b", 5));
        rec.record(ev(SpanKind::Fed, "c", 10));
        let events = rec.take();
        assert_eq!(events.len(), 3);
        assert_eq!(events.iter().map(|e| e.seq).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert!(rec.is_empty());
    }

    #[test]
    fn span_filter_parses_and_filters() {
        let f = SpanFilter::parse("sched,fed").unwrap();
        assert!(f.allows(SpanKind::Sched));
        assert!(f.allows(SpanKind::Fed));
        assert!(!f.allows(SpanKind::Kvc));
        assert!(!f.allows(SpanKind::Sim));
        assert!(SpanFilter::parse("bogus").is_err());
        assert!(SpanFilter::parse("").is_err());

        let rec = Recorder::with_filter(f);
        assert!(!rec.wants(SpanKind::Kvc));
        rec.record(ev(SpanKind::Kvc, "dropped", 1));
        rec.record(ev(SpanKind::Sched, "kept", 2));
        let events = rec.take();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].name, "kept");
        assert_eq!(events[0].seq, 0);
    }

    #[test]
    fn jsonl_lines_are_sorted_key_objects() {
        let e = TraceEvent::span(SpanKind::Sched, "serialize", 100, 50)
            .with_shell(2)
            .with_link("uplink:1.2".to_string())
            .arg_u("tag", 7);
        let out = jsonl(&[e]);
        assert_eq!(
            out,
            "{\"args\":{\"tag\":7},\"dur_ns\":50,\"kind\":\"sched\",\"link\":\"uplink:1.2\",\
             \"name\":\"serialize\",\"seq\":0,\"shell\":2,\"ts_ns\":100}\n"
        );
    }

    #[test]
    fn chrome_export_is_valid_json_with_metadata_and_phases() {
        let events = vec![
            TraceEvent::span(SpanKind::Sched, "serialize", 1000, 500)
                .with_shell(0)
                .with_link("uplink:1.2".to_string()),
            TraceEvent::instant(SpanKind::Fed, "end_of_epoch", 2000).arg_u("epoch", 1),
        ];
        let out = chrome(&events);
        let parsed = Json::parse(&out).expect("chrome export parses");
        let Json::Obj(top) = parsed else { panic!("top level must be an object") };
        let Json::Arr(evs) = &top["traceEvents"] else { panic!("traceEvents must be an array") };
        // 2 data events + process/thread metadata for both pids.
        assert!(evs.len() >= 2 + 2);
        let phases: Vec<String> = evs
            .iter()
            .filter_map(|e| match e {
                Json::Obj(o) => match &o["ph"] {
                    Json::Str(p) => Some(p.clone()),
                    _ => None,
                },
                _ => None,
            })
            .collect();
        assert!(phases.iter().any(|p| p == "X"));
        assert!(phases.iter().any(|p| p == "i"));
        assert!(phases.iter().any(|p| p == "M"));
    }

    #[test]
    fn recorder_works_through_a_trait_object() {
        let sink: Arc<dyn TraceSink> = Arc::new(Recorder::new());
        assert!(sink.wants(SpanKind::Sim));
        sink.record(ev(SpanKind::Sim, "epoch", 0));
    }
}
