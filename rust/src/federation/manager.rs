//! The federated KVC manager: §3.8 Get/Set fan-out over shell-qualified
//! layouts.
//!
//! Every block is homed on exactly one shell, chosen by the
//! [`PlacementPolicy`] at Set time (cheapest shell first, spillover on
//! saturation or failure).  Within its home shell a block uses the
//! standard chunk-to-server striping over the shell's own
//! [`crate::mapping::Strategy`] layout — chunk `i` goes to
//! `FedSatId { shell, layout[i % n] }` — so the single-shell rotation
//! arithmetic (write-epoch shift, §3.4 migration) applies unchanged per
//! shell.
//!
//! Chunk I/O has full fan-out parity with
//! [`crate::kvc::manager::KvcManager`]: each block's Get/Set set is one
//! [`crate::net::sched`] virtual-time batch on its home shell's
//! scheduler ([`crate::federation::transport::ShellLink::sched`]), so the
//! transfers pipeline over per-link in-flight windows with deterministic
//! `(virtual_time, tag)` ordering — the old sequential special-case
//! (per-chunk round trips, kept only for determinism) is gone.
//!
//! Handover: when a shell's layout box degrades below the placement
//! threshold, [`FederatedKvcManager::evacuate_shell`] drains the box's
//! surviving satellites to the same relative cells of a healthy shell over
//! the inter-shell links and re-homes the affected blocks (proactive
//! handover; cell offsets are preserved, so the rotation arithmetic keeps
//! working on the new shell).  Blocks whose chunks were already lost heal
//! reactively: the broken fetch drops them from the index, and the next
//! Set re-places them on whichever shell placement now prefers.

use crate::constellation::topology::SatId;
use crate::federation::placement::{cheapest_index, shell_cost, PlacementPolicy, ShellCandidate};
use crate::federation::transport::FederatedTransport;
use crate::federation::{FedSatId, ShellId};
use crate::kvc::block::BlockHash;
use crate::kvc::chunk::{chunk_count, split_chunks, ChunkKey};
use crate::kvc::manager::{encode_chunk_header, KvcConfig, CHUNK_HEADER_LEN};
use crate::kvc::quantize::Quantizer;
use crate::kvc::radix::BlockMeta;
use crate::mapping::box_width;
use crate::net::sched::{ChunkOp, ChunkResult, Transfer};
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Where a block lives and how to reassemble it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FedBlockMeta {
    pub shell: ShellId,
    pub meta: BlockMeta,
}

/// Per-shell manager counters.
#[derive(Debug, Default)]
pub struct ShellCounters {
    pub blocks_stored: AtomicU64,
    pub fetch_attempts: AtomicU64,
    pub blocks_hit: AtomicU64,
    /// Encoded payload bytes of the blocks currently homed here by
    /// placement or evacuation (headers excluded; moved between shells on
    /// evacuation, not debited on LRU eviction).
    pub placed_bytes: AtomicU64,
}

/// Federation-wide manager counters.
#[derive(Debug, Default)]
pub struct FedStats {
    /// Blocks placed off the cheapest shell (saturation or failure).
    pub spillovers: AtomicU64,
    /// Blocks re-homed by proactive cross-shell evacuation.
    pub proactive_handover_blocks: AtomicU64,
    /// Blocks re-homed reactively: broken on one shell, re-stored on
    /// another.
    pub reactive_rehomed_blocks: AtomicU64,
    /// Fetches that found a chunk missing (prefix truncation).
    pub broken_blocks: AtomicU64,
}

/// Summary of one shell evacuation.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EvacSummary {
    pub chunks_moved: u32,
    pub bytes_moved: u64,
    pub blocks_rehomed: u64,
}

/// The shell-aware KVC manager.
pub struct FederatedKvcManager {
    pub config: KvcConfig,
    pub placement: PlacementPolicy,
    transport: Arc<FederatedTransport>,
    /// Block -> home shell + reassembly metadata.  Chained hashes commit
    /// to the whole prefix, so one entry per block hash suffices (no radix
    /// walk needed; prefix length is a `take_while` over the hash list).
    /// BTreeMap: deterministic iteration for evacuation order.
    index: Mutex<BTreeMap<BlockHash, FedBlockMeta>>,
    /// Last known home of blocks dropped as broken, to count reactive
    /// re-homing on their next Set.
    tombstones: Mutex<BTreeMap<BlockHash, ShellId>>,
    shell_counters: Vec<ShellCounters>,
    /// Static per-shell placement cost (pure function of geometry and the
    /// server count), computed once at construction.
    shell_costs: Vec<f64>,
    pub stats: FedStats,
}

impl FederatedKvcManager {
    pub fn new(
        config: KvcConfig,
        transport: Arc<FederatedTransport>,
        placement: PlacementPolicy,
    ) -> Self {
        assert!(config.n_servers >= 1);
        let w = box_width(config.n_servers);
        for link in transport.links() {
            let t = &link.shell.torus;
            assert!(
                w <= t.planes && w <= t.sats_per_plane,
                "{}: {w}x{w} layout box does not fit a {}x{} torus",
                link.shell.name,
                t.planes,
                t.sats_per_plane
            );
        }
        let shell_counters = (0..transport.n_shells()).map(|_| ShellCounters::default()).collect();
        let shell_costs = transport
            .links()
            .iter()
            .map(|l| shell_cost(&l.shell.geometry, config.n_servers))
            .collect();
        Self {
            config,
            placement,
            transport,
            index: Mutex::new(BTreeMap::new()),
            tombstones: Mutex::new(BTreeMap::new()),
            shell_counters,
            shell_costs,
            stats: FedStats::default(),
        }
    }

    pub fn transport(&self) -> &Arc<FederatedTransport> {
        &self.transport
    }

    pub fn shell_counters(&self) -> &[ShellCounters] {
        &self.shell_counters
    }

    /// Blocks currently indexed (federation-wide).
    pub fn indexed_blocks(&self) -> usize {
        self.index.lock().unwrap().len()
    }

    /// Current home shell of a block, if indexed.
    pub fn home_of(&self, block: &BlockHash) -> Option<ShellId> {
        self.index.lock().unwrap().get(block).map(|e| e.shell)
    }

    /// Live fraction of `shell`'s current layout box (the placement
    /// eligibility signal).
    pub fn box_live_fraction(&self, shell: ShellId) -> f64 {
        let link = self.transport.link(shell);
        let torus = link.shell.torus;
        let center = self.transport.closest(shell);
        let half = (box_width(self.config.n_servers) as i32 - 1) / 2;
        let mut live = 0usize;
        let mut total = 0usize;
        for dp in -half..=half {
            for ds in -half..=half {
                total += 1;
                if !link.faults.is_satellite_failed(torus.offset(center, dp, ds)) {
                    live += 1;
                }
            }
        }
        live as f64 / total as f64
    }

    fn candidates(&self) -> Vec<ShellCandidate> {
        (0..self.transport.n_shells())
            .map(|i| ShellCandidate {
                shell: i as ShellId,
                cost_s: self.shell_costs[i],
                live_fraction: self.box_live_fraction(i as ShellId),
                placed_bytes: self.shell_counters[i].placed_bytes.load(Ordering::Relaxed),
            })
            .collect()
    }

    /// The federation's static primary shell (cheapest by cost alone —
    /// answered from the cached costs, no torus scans).
    pub fn primary_shell(&self) -> ShellId {
        cheapest_index(&self.shell_costs).expect("federation has shells") as ShellId
    }

    /// The cheapest currently-live shell other than `exclude`, if any.
    pub fn cheapest_live_shell_excluding(&self, exclude: ShellId) -> Option<ShellId> {
        let mut best: Option<(ShellId, f64)> = None;
        for c in self.candidates() {
            if c.shell == exclude || c.live_fraction < self.placement.min_live_fraction {
                continue;
            }
            let better = match best {
                None => true,
                Some((_, cost)) => c.cost_s < cost,
            };
            if better {
                best = Some((c.shell, c.cost_s));
            }
        }
        best.map(|(s, _)| s)
    }

    // ------------------------------------------------------------ SET ---

    /// Store one block's KV values on the shell placement chooses; no-op
    /// if the block is already indexed.  Returns the home shell.
    pub fn put_block(
        &self,
        hashes: &[BlockHash],
        block_idx: usize,
        kv_values: &[f32],
        now_epoch: u64,
    ) -> Result<ShellId> {
        let block = hashes[block_idx];
        if let Some(e) = self.index.lock().unwrap().get(&block) {
            return Ok(e.shell);
        }
        let cands = self.candidates();
        let chosen = self.placement.choose(&cands).expect("federation has shells");
        let primary = self.placement.primary(&cands).expect("federation has shells");
        let shell = cands[chosen].shell;
        let payload = self.config.quantizer.encode(kv_values);
        let meta = self.store_payload(shell, block, &payload, now_epoch)?;
        if chosen != primary {
            self.stats.spillovers.fetch_add(1, Ordering::Relaxed);
        }
        if let Some(old_home) = self.tombstones.lock().unwrap().remove(&block) {
            if old_home != shell {
                self.stats.reactive_rehomed_blocks.fetch_add(1, Ordering::Relaxed);
            }
        }
        self.index.lock().unwrap().insert(block, FedBlockMeta { shell, meta });
        Ok(shell)
    }

    /// Stripe an encoded payload over `shell`'s current layout: one
    /// virtual-time batch on the shell's scheduler (fan-out parity with
    /// the single-shell manager).
    fn store_payload(
        &self,
        shell: ShellId,
        block: BlockHash,
        payload: &[u8],
        now_epoch: u64,
    ) -> Result<BlockMeta> {
        let n_chunks = chunk_count(payload.len(), self.config.chunk_size) as u32;
        let header = encode_chunk_header(
            self.config.quantizer.id(),
            n_chunks,
            payload.len() as u32,
            now_epoch,
        );
        let torus = self.transport.shell(shell).torus;
        let center = self.transport.closest(shell);
        let layout = self.config.strategy.initial_layout(&torus, center, self.config.n_servers);
        let transfers: Vec<Transfer> = split_chunks(payload, self.config.chunk_size)
            .iter()
            .enumerate()
            .map(|(i, chunk)| {
                let mut data = Vec::with_capacity(CHUNK_HEADER_LEN + chunk.len());
                data.extend_from_slice(&header);
                data.extend_from_slice(chunk);
                Transfer {
                    tag: i as u64,
                    op: ChunkOp::Set {
                        dest: layout[i % self.config.n_servers],
                        key: ChunkKey::new(block, i as u32),
                        data,
                    },
                }
            })
            .collect();
        let batch = self.transport.link(shell).sched.run_batch(transfers);
        for o in &batch.outcomes {
            if let ChunkResult::Failed(e) = &o.result {
                bail!("shell {shell}: chunk {} set failed: {e}", o.tag);
            }
        }
        let counters = &self.shell_counters[shell as usize];
        counters.blocks_stored.fetch_add(1, Ordering::Relaxed);
        counters.placed_bytes.fetch_add(payload.len() as u64, Ordering::Relaxed);
        Ok(BlockMeta {
            num_chunks: n_chunks,
            kvc_len: payload.len() as u32,
            write_epoch: now_epoch,
            quantizer_id: self.config.quantizer.id(),
        })
    }

    // ------------------------------------------------------------ GET ---

    /// Longest cached prefix (in blocks) of `hashes`: chained hashes make
    /// this a plain `take_while` over the federation index.
    pub fn lookup(&self, hashes: &[BlockHash]) -> usize {
        let index = self.index.lock().unwrap();
        hashes.iter().take_while(|h| index.contains_key(h)).count()
    }

    /// The shell-qualified layout of a block's servers at `now_epoch`.
    fn layout_for(&self, shell: ShellId, write_epoch: u64, now_epoch: u64) -> Vec<SatId> {
        let torus = self.transport.shell(shell).torus;
        let delta = (now_epoch - write_epoch) as i32;
        // the centre slides one slot west per epoch; the write-time centre
        // was `delta` slots east of the current one
        let write_center = torus.offset(self.transport.closest(shell), 0, delta);
        self.config.strategy.layout_at(
            &torus,
            write_center,
            self.config.n_servers,
            now_epoch - write_epoch,
        )
    }

    /// Fetch a block's chunks as one virtual-time batch on its home
    /// shell's scheduler and reassemble them in tag order.
    fn fetch_payload(
        &self,
        shell: ShellId,
        block: BlockHash,
        meta: &BlockMeta,
        now_epoch: u64,
    ) -> Option<Vec<u8>> {
        let layout = self.layout_for(shell, meta.write_epoch, now_epoch);
        let transfers: Vec<Transfer> = (0..meta.num_chunks as usize)
            .map(|i| Transfer {
                tag: i as u64,
                op: ChunkOp::Get {
                    dest: layout[i % self.config.n_servers],
                    key: ChunkKey::new(block, i as u32),
                },
            })
            .collect();
        let batch = self.transport.link(shell).sched.run_batch(transfers);
        let mut payload = Vec::with_capacity(meta.kvc_len as usize);
        for o in batch.outcomes {
            match o.result {
                ChunkResult::Got(Some(data)) if data.len() > CHUNK_HEADER_LEN => {
                    payload.extend_from_slice(&data[CHUNK_HEADER_LEN..])
                }
                _ => return None,
            }
        }
        if payload.len() == meta.kvc_len as usize {
            Some(payload)
        } else {
            None
        }
    }

    /// Fetch one block's KV values from its home shell; `None` if the
    /// block is unknown or broken (broken blocks are dropped and lazily
    /// evicted, and their home is remembered for re-homing stats).
    pub fn fetch_block(
        &self,
        hashes: &[BlockHash],
        block_idx: usize,
        now_epoch: u64,
    ) -> Result<Option<Vec<f32>>> {
        let block = hashes[block_idx];
        let Some(entry) = self.index.lock().unwrap().get(&block).copied() else {
            return Ok(None);
        };
        let counters = &self.shell_counters[entry.shell as usize];
        counters.fetch_attempts.fetch_add(1, Ordering::Relaxed);
        match self.fetch_payload(entry.shell, block, &entry.meta, now_epoch) {
            Some(payload) => {
                counters.blocks_hit.fetch_add(1, Ordering::Relaxed);
                let group = match self.config.quantizer {
                    Quantizer::QuantoInt8 { group } | Quantizer::HqqInt8 { group } => group,
                    Quantizer::F32 => 32,
                };
                let quantizer = Quantizer::from_id(entry.meta.quantizer_id, group).ok_or_else(
                    || anyhow::anyhow!("unknown quantizer id {}", entry.meta.quantizer_id),
                )?;
                Ok(Some(quantizer.decode(&payload)?))
            }
            None => {
                self.drop_broken(block, &entry, now_epoch);
                Ok(None)
            }
        }
    }

    /// §3.9 lazy eviction, federated: drop the broken block from the
    /// index, remember its home for re-homing stats, and tell the
    /// surviving replicas on its home shell to purge.
    fn drop_broken(&self, block: BlockHash, entry: &FedBlockMeta, now_epoch: u64) {
        self.stats.broken_blocks.fetch_add(1, Ordering::Relaxed);
        self.index.lock().unwrap().remove(&block);
        self.tombstones.lock().unwrap().insert(block, entry.shell);
        let layout = self.layout_for(entry.shell, entry.meta.write_epoch, now_epoch);
        let servers = self.config.n_servers.min(entry.meta.num_chunks as usize);
        for sat in layout.iter().take(servers) {
            let _ = self.transport.evict_block(FedSatId::new(entry.shell, *sat), block);
        }
    }

    /// Fetch blocks `0..blocks` in order; returns how many were served
    /// before the prefix truncated.
    pub fn fetch_prefix(
        &self,
        hashes: &[BlockHash],
        blocks: usize,
        now_epoch: u64,
    ) -> Result<usize> {
        let mut got = 0;
        for b in 0..blocks {
            match self.fetch_block(hashes, b, now_epoch)? {
                Some(_) => got += 1,
                None => break,
            }
        }
        Ok(got)
    }

    // ------------------------------------------------------ ROTATION ----

    /// §3.4 intra-shell rotation migration for one shell: the exiting east
    /// column hands its chunks to the entering west column, per plane
    /// (the same handoff pairs the single-shell manager issues).
    pub fn migration_requests(&self, shell: ShellId) -> Vec<(SatId, SatId)> {
        if !self.config.strategy.migrates() {
            return vec![];
        }
        let torus = self.transport.shell(shell).torus;
        crate::mapping::migration::rotation_handoff_pairs(
            &torus,
            self.transport.closest(shell),
            self.config.n_servers,
        )
    }

    // ------------------------------------------------------ HANDOVER ----

    /// Proactive inter-shell handover: drain every cell of `from`'s
    /// current layout box to the same relative cell of `to`'s box (over
    /// the inter-shell links) and re-home `from`'s blocks onto `to`.
    /// Because cell offsets relative to the (lockstep-rotating) centres
    /// are preserved, the write-epoch layout arithmetic keeps resolving
    /// every surviving chunk on the new shell.
    pub fn evacuate_shell(&self, from: ShellId, to: ShellId, _now_epoch: u64) -> EvacSummary {
        assert_ne!(from, to, "evacuation needs a distinct target shell");
        let src_torus = self.transport.shell(from).torus;
        let dst_torus = self.transport.shell(to).torus;
        let src_center = self.transport.closest(from);
        let dst_center = self.transport.closest(to);
        let half = (box_width(self.config.n_servers) as i32 - 1) / 2;
        let mut chunks_moved = 0u32;
        let mut bytes_moved = 0u64;
        for dp in -half..=half {
            for ds in -half..=half {
                let s = FedSatId::new(from, src_torus.offset(src_center, dp, ds));
                let d = FedSatId::new(to, dst_torus.offset(dst_center, dp, ds));
                let (m, b) = self.transport.migrate_cross_shell(s, d);
                chunks_moved += m;
                bytes_moved += b;
            }
        }
        let mut rehomed = 0u64;
        let mut rehomed_bytes = 0u64;
        for entry in self.index.lock().unwrap().values_mut() {
            if entry.shell == from {
                entry.shell = to;
                rehomed += 1;
                rehomed_bytes += entry.meta.kvc_len as u64;
            }
        }
        self.stats.proactive_handover_blocks.fetch_add(rehomed, Ordering::Relaxed);
        // move the placement accounting with the blocks (payload-byte
        // convention, matching store_payload; every rehomed block was
        // credited to `from` when stored, so the debit cannot underflow)
        self.shell_counters[from as usize].placed_bytes.fetch_sub(rehomed_bytes, Ordering::Relaxed);
        self.shell_counters[to as usize].placed_bytes.fetch_add(rehomed_bytes, Ordering::Relaxed);
        EvacSummary { chunks_moved, bytes_moved, blocks_rehomed: rehomed }
    }

    /// Number of chunks a block of `n_values` f32s will produce.
    pub fn chunks_for_values(&self, n_values: usize) -> usize {
        self.config.chunks_for_values(n_values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constellation::geometry::Geometry;
    use crate::constellation::los::LosGrid;
    use crate::constellation::topology::Torus;
    use crate::federation::transport::ShellLink;
    use crate::federation::Shell;
    use crate::kvc::block::block_hashes;
    use crate::kvc::eviction::EvictionPolicy;
    use crate::net::faults::FaultyTransport;
    use crate::net::transport::{GroundView, InProcTransport, Transport};
    use crate::satellite::fleet::Fleet;
    use crate::util::rng::XorShift64;

    fn shell_link(id: ShellId, name: &str, planes: usize, slots: usize, alt: f64) -> ShellLink {
        let torus = Torus::new(planes, slots);
        let geometry = Geometry::new(alt, slots, planes);
        let shell = Shell::new(id, name, torus, geometry);
        let center = SatId::new((planes / 2) as u16, (slots / 2) as u16);
        let fleet = Arc::new(Fleet::new(torus, 10 << 20, EvictionPolicy::Lazy));
        let los = LosGrid::new(center, 2, (planes / 2).min(2));
        let ground = GroundView::new(center, &los, torus.sats_per_plane);
        let inproc = Arc::new(InProcTransport::new(fleet.clone(), ground, None));
        let faults =
            Arc::new(FaultyTransport::new(inproc.clone(), torus, los.half_slots, los.half_planes));
        ShellLink::new(shell, fleet, inproc, faults, 8)
    }

    /// Two small shells; the denser second one ("b-630") is cheaper and
    /// therefore primary.
    fn manager() -> FederatedKvcManager {
        let transport = Arc::new(FederatedTransport::new(vec![
            shell_link(0, "a-550", 9, 11, 550.0),
            shell_link(1, "b-630", 15, 15, 630.0),
        ]));
        let config = KvcConfig { n_servers: 9, chunk_size: 600, ..KvcConfig::default() };
        FederatedKvcManager::new(config, transport, PlacementPolicy::default())
    }

    fn values(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = XorShift64::new(seed);
        (0..n).map(|_| (rng.next_f64() as f32 - 0.5) * 4.0).collect()
    }

    #[test]
    fn put_then_fetch_roundtrip_on_primary() {
        let m = manager();
        let primary = m.primary_shell();
        assert_eq!(primary, 1, "the denser 15x15 shell should be cheapest");
        let tokens: Vec<i32> = (0..96).collect();
        let hashes = block_hashes(&tokens, 32);
        let kv = values(2048, 1);
        let home = m.put_block(&hashes, 0, &kv, 0).unwrap();
        assert_eq!(home, primary);
        assert_eq!(m.lookup(&hashes), 1);
        let fetched = m.fetch_block(&hashes, 0, 0).unwrap().unwrap();
        assert_eq!(fetched.len(), kv.len());
        let max_err =
            kv.iter().zip(&fetched).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
        assert!(max_err < 0.05, "max_err={max_err}");
        // idempotent: a second put keeps the home and stores nothing new
        let stored = m.shell_counters()[home as usize].blocks_stored.load(Ordering::Relaxed);
        assert_eq!(m.put_block(&hashes, 0, &kv, 0).unwrap(), home);
        assert_eq!(
            m.shell_counters()[home as usize].blocks_stored.load(Ordering::Relaxed),
            stored
        );
    }

    #[test]
    fn prefix_lookup_spans_shells() {
        let m = manager();
        let tokens: Vec<i32> = (0..128).collect();
        let hashes = block_hashes(&tokens, 32);
        for b in 0..3 {
            m.put_block(&hashes, b, &values(2048, b as u64), 0).unwrap();
        }
        // force block 1 onto the other shell by re-homing its index entry
        // is not possible from outside; instead verify the walk truncates
        // at the first unknown block
        assert_eq!(m.lookup(&hashes), 3);
        assert_eq!(m.fetch_prefix(&hashes, 3, 0).unwrap(), 3);
        let mut tokens2 = tokens.clone();
        tokens2[40] = 999; // diverge inside block 1
        let hashes2 = block_hashes(&tokens2, 32);
        assert_eq!(m.lookup(&hashes2), 1);
    }

    #[test]
    fn dead_primary_box_spills_to_secondary() {
        let m = manager();
        let primary = m.primary_shell();
        let other = 1 - primary;
        // kill the primary's whole layout box
        let link = m.transport().link(primary);
        let center = link.faults.closest();
        for dp in -1..=1 {
            for ds in -1..=1 {
                link.faults.fail_satellite(link.shell.torus.offset(center, dp, ds));
            }
        }
        assert!(m.box_live_fraction(primary) < 0.2);
        let tokens: Vec<i32> = (0..32).collect();
        let hashes = block_hashes(&tokens, 32);
        let home = m.put_block(&hashes, 0, &values(2048, 3), 0).unwrap();
        assert_eq!(home, other, "placement must spill off the dead shell");
        assert_eq!(m.stats.spillovers.load(Ordering::Relaxed), 1);
        assert!(m.fetch_block(&hashes, 0, 0).unwrap().is_some());
    }

    #[test]
    fn rotation_migration_keeps_blocks_fetchable_per_shell() {
        let m = manager();
        let tokens: Vec<i32> = (0..32).collect();
        let hashes = block_hashes(&tokens, 32);
        let kv = values(2048, 9);
        let home = m.put_block(&hashes, 0, &kv, 0).unwrap();
        // run one epoch of migration on every shell, then advance
        let mut moved = 0;
        for s in 0..m.transport().n_shells() as ShellId {
            for (from, to) in m.migration_requests(s) {
                moved += m.transport().link(s).faults.migrate(from, to).unwrap();
            }
        }
        m.transport().set_epoch_all(1);
        assert!(moved > 0, "the east column should hand over chunks");
        let fetched = m.fetch_block(&hashes, 0, 1).unwrap().unwrap();
        assert_eq!(fetched.len(), kv.len());
        assert_eq!(m.home_of(&hashes[0]), Some(home));
    }

    #[test]
    fn evacuation_rehomes_and_keeps_blocks_fetchable() {
        let m = manager();
        let primary = m.primary_shell();
        let other = 1 - primary;
        let tokens: Vec<i32> = (0..96).collect();
        let hashes = block_hashes(&tokens, 32);
        for b in 0..3 {
            m.put_block(&hashes, b, &values(2048, b as u64), 0).unwrap();
        }
        let before = m.transport().link(other).fleet.total_chunks();
        let summary = m.evacuate_shell(primary, other, 0);
        assert_eq!(summary.blocks_rehomed, 3);
        assert!(summary.chunks_moved > 0);
        assert!(summary.bytes_moved > 0);
        assert!(m.transport().link(other).fleet.total_chunks() > before);
        assert_eq!(m.transport().link(primary).fleet.total_chunks(), 0);
        // now kill the evacuated shell entirely: data must still serve
        let link = m.transport().link(primary);
        for sat in link.shell.torus.all() {
            link.faults.fail_satellite(sat);
        }
        for b in 0..3 {
            assert_eq!(m.home_of(&hashes[b]), Some(other));
            assert!(m.fetch_block(&hashes, b, 0).unwrap().is_some(), "block {b}");
        }
        assert!(
            m.transport().stats.inter_shell_bytes.load(Ordering::Relaxed) >= summary.bytes_moved
        );
    }

    #[test]
    fn evacuation_survives_rotation_afterwards() {
        let m = manager();
        let primary = m.primary_shell();
        let other = 1 - primary;
        let tokens: Vec<i32> = (0..32).collect();
        let hashes = block_hashes(&tokens, 32);
        m.put_block(&hashes, 0, &values(2048, 7), 0).unwrap();
        m.evacuate_shell(primary, other, 0);
        // rotate two epochs with per-shell migration on the new home
        for e in 0..2u64 {
            for (from, to) in m.migration_requests(other) {
                m.transport().link(other).faults.migrate(from, to).unwrap();
            }
            m.transport().set_epoch_all(e + 1);
        }
        assert!(m.fetch_block(&hashes, 0, 2).unwrap().is_some());
    }

    #[test]
    fn broken_block_truncates_and_counts_reactive_rehome() {
        let m = manager();
        let tokens: Vec<i32> = (0..96).collect();
        let hashes = block_hashes(&tokens, 32);
        for b in 0..3 {
            m.put_block(&hashes, b, &values(2048, b as u64), 0).unwrap();
        }
        let home = m.home_of(&hashes[1]).unwrap();
        // sabotage block 1 everywhere on its home shell
        for node in m.transport().link(home).fleet.nodes() {
            let torus = m.transport().shell(home).torus;
            let env = crate::net::messages::Envelope::new(node.id, 0);
            node.handle(
                &torus,
                &env,
                &crate::net::messages::Request::Evict { block: hashes[1], gossip_ttl: 0 },
            );
        }
        assert_eq!(m.fetch_prefix(&hashes, 3, 0).unwrap(), 1, "prefix truncates");
        assert_eq!(m.stats.broken_blocks.load(Ordering::Relaxed), 1);
        assert_eq!(m.lookup(&hashes), 1, "broken block left the index");
        // re-store while the home shell's box is dead: reactive re-home
        let link = m.transport().link(home);
        let center = link.faults.closest();
        for dp in -1..=1 {
            for ds in -1..=1 {
                link.faults.fail_satellite(link.shell.torus.offset(center, dp, ds));
            }
        }
        let new_home = m.put_block(&hashes, 1, &values(2048, 1), 0).unwrap();
        assert_ne!(new_home, home);
        assert_eq!(m.stats.reactive_rehomed_blocks.load(Ordering::Relaxed), 1);
    }
}
