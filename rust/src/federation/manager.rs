//! The federated KVC manager: §3.8 Get/Set fan-out over shell-qualified
//! layouts, hot-block replication, and §3.7-style predictive
//! pre-placement.
//!
//! Every block's *primary* copy is homed on exactly one shell, chosen by
//! the [`PlacementPolicy`] at Set time (cheapest shell first, spillover
//! on saturation or failure).  Within a shell a block uses the shell's
//! own stripe ([`ShellLayoutConfig`]): chunk `i` goes to
//! `FedSatId { shell, layout[i % n_servers] }` of that shell's layout —
//! shells of one federation may run different strategies and stripe
//! widths — so the single-shell rotation arithmetic (write-epoch shift,
//! §3.4 migration) applies unchanged per shell.
//!
//! Chunk I/O has full fan-out parity with
//! [`crate::kvc::manager::KvcManager`]: each copy's Get/Set set is one
//! [`crate::net::sched`] virtual-time batch on its shell's scheduler
//! ([`crate::federation::transport::ShellLink::sched`]).
//!
//! Replication ([`ReplicationPolicy`]): at each epoch boundary
//! ([`FederatedKvcManager::end_of_epoch`]) the top-K hottest blocks (by
//! access count, ties by hash) gain a live replica so their copies span
//! the two cheapest live shells ([`cheapest_two`]).  Reads *race* every
//! copy via [`race_batches`] — all arms really execute, the fastest
//! complete copy serves — and a broken primary promotes its surviving
//! replica to primary instead of dropping the block.  Writes fan out
//! invalidations: dropping a block evicts every copy on every shell.
//!
//! Pre-placement: the §3.7-style predictor
//! ([`predict_preplacement_shell`]) extrapolates each shell's layout-box
//! live fraction one rotation ahead and pre-places the hot set's *next*
//! rotation layout (write epoch `e+1`, centred one slot west) on the
//! predicted-cheapest shell before the handover — instead of reacting to
//! broken fetches after the shell degrades.
//!
//! Handover: when a shell's layout box degrades below the placement
//! threshold, [`FederatedKvcManager::evacuate_shell`] drains the box's
//! surviving satellites to a healthy shell over the inter-shell links
//! and re-homes the affected blocks (proactive handover).  Between
//! shells with identical layout configs cell offsets are preserved, so
//! the rotation arithmetic keeps working on the new shell; between
//! differing configs every block is re-fetched and re-striped onto the
//! target's own layout.  Blocks whose chunks were already lost heal
//! reactively: the broken fetch drops them from the index, and the next
//! Set re-places them on whichever shell placement now prefers.

use crate::constellation::topology::SatId;
use crate::federation::placement::{
    cheapest_index, cheapest_two, predict_preplacement_shell, shell_cost, PlacementPolicy,
    ReplicationPolicy, ShellCandidate, ShellLayoutConfig,
};
use crate::federation::transport::FederatedTransport;
use crate::federation::{FedSatId, ShellId};
use crate::kvc::block::BlockHash;
use crate::kvc::chunk::{chunk_count, split_chunks, ChunkKey};
use crate::kvc::frozen::FrozenMap;
use crate::kvc::manager::{encode_chunk_header, KvcConfig, CHUNK_HEADER_LEN};
use crate::kvc::quantize::Quantizer;
use crate::kvc::radix::BlockMeta;
use crate::mapping::box_width;
use crate::net::sched::{race_batches, BatchReport, ChunkOp, ChunkResult, Transfer};
use crate::obs::mem::{FootprintEstimate, MemFootprint};
use crate::obs::{ArgVal, NoopSink, SpanKind, TraceEvent, TraceSink};
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::mem::size_of;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One copy of a block on one shell (a replica or a pre-placed copy).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockCopy {
    pub shell: ShellId,
    pub meta: BlockMeta,
}

/// Where a block lives and how to reassemble it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FedBlockMeta {
    /// Home shell of the primary copy.
    pub shell: ShellId,
    pub meta: BlockMeta,
    /// Served fetches of this block (the replication hotness signal).
    pub accesses: u64,
    /// Live replica created by [`ReplicationPolicy`], if any.
    pub replica: Option<BlockCopy>,
    /// Pre-placed next-rotation copy created by the §3.7 predictor.
    pub preplaced: Option<BlockCopy>,
}

/// Per-shell manager counters.
#[derive(Debug, Default)]
pub struct ShellCounters {
    pub blocks_stored: AtomicU64,
    /// Fetch arms raced against this shell (every copy fetch counts).
    pub fetch_attempts: AtomicU64,
    /// Fetches this shell served (fastest complete copy).
    pub blocks_hit: AtomicU64,
    /// Fetches this shell served from a replica / pre-placed copy.
    pub replica_hits: AtomicU64,
    /// Replicas created onto this shell.
    pub replicas_hosted: AtomicU64,
    /// Pre-placed copies created onto this shell.
    pub preplaced_hosted: AtomicU64,
    /// Encoded payload bytes of the copies currently on this shell
    /// (headers excluded; moved between shells on evacuation and debited
    /// when a copy is dropped, not debited on LRU eviction).
    pub placed_bytes: AtomicU64,
}

/// Federation-wide manager counters.
#[derive(Debug, Default)]
pub struct FedStats {
    /// Blocks placed off the cheapest shell (saturation or failure).
    pub spillovers: AtomicU64,
    /// Blocks re-homed by proactive cross-shell evacuation.
    pub proactive_handover_blocks: AtomicU64,
    /// Blocks re-homed reactively: broken on one shell, re-stored on
    /// another.
    pub reactive_rehomed_blocks: AtomicU64,
    /// Fetches that found every copy broken (prefix truncation).
    pub broken_blocks: AtomicU64,
    /// Replicas created (top-K hot blocks onto the second-cheapest
    /// shell).
    pub replicated_blocks: AtomicU64,
    /// Fetches that raced two or more copies.
    pub replica_races: AtomicU64,
    /// Races won (served) by a non-home copy.
    pub replica_race_wins: AtomicU64,
    /// Broken primaries healed by promoting a surviving copy.
    pub replica_promotions: AtomicU64,
    /// Next-rotation copies pre-placed by the predictor.
    pub preplaced_blocks: AtomicU64,
    /// Fetches served by a pre-placed copy.
    pub preplace_hits: AtomicU64,
}

/// Summary of one shell evacuation.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EvacSummary {
    pub chunks_moved: u32,
    pub bytes_moved: u64,
    pub blocks_rehomed: u64,
}

/// The shell-aware KVC manager.
pub struct FederatedKvcManager {
    pub config: KvcConfig,
    pub placement: PlacementPolicy,
    pub replication: ReplicationPolicy,
    /// Run the §3.7 pre-placement predictor at epoch boundaries (shares
    /// the replication hot set, so it needs `replication.top_k > 0`).
    pub preplace: bool,
    transport: Arc<FederatedTransport>,
    /// Per-shell stripe configuration (strategy + width), index-aligned
    /// with the transport's shells.
    shell_layouts: Vec<ShellLayoutConfig>,
    /// Block -> home shell + reassembly metadata + copies.  Chained
    /// hashes commit to the whole prefix, so one entry per block hash
    /// suffices (no radix walk needed; prefix length is a `take_while`
    /// over the hash list).  Two layers ([`crate::kvc::frozen`]): an
    /// immutable epoch-compacted arena plus a BTreeMap delta holding the
    /// live epoch's writes (copy-on-write on mutation, tombstones on
    /// removal); [`Self::end_of_epoch`] freezes the delta.  Merged
    /// iteration is hash-sorted, preserving the old BTreeMap's
    /// deterministic evacuation and hot-set order.
    index: Mutex<FrozenMap<FedBlockMeta>>,
    /// Last known home of blocks dropped as broken, to count reactive
    /// re-homing on their next Set.
    tombstones: Mutex<BTreeMap<BlockHash, ShellId>>,
    /// Per-shell box live fractions at the previous epoch boundary (the
    /// predictor's trend input).
    prev_live: Mutex<Vec<f64>>,
    shell_counters: Vec<ShellCounters>,
    /// Static per-shell placement cost (pure function of geometry and the
    /// shell's stripe width), computed once at construction.
    shell_costs: Vec<f64>,
    /// Flight-recorder sink for federation-level events (race arms,
    /// promotions, evacuations, epoch boundaries).
    trace: Mutex<Arc<dyn TraceSink>>,
    pub stats: FedStats,
}

impl FederatedKvcManager {
    /// A manager with every shell striping the global [`KvcConfig`]
    /// layout and replication off — the re-homing-only configuration.
    pub fn new(
        config: KvcConfig,
        transport: Arc<FederatedTransport>,
        placement: PlacementPolicy,
    ) -> Self {
        let layouts = vec![
            ShellLayoutConfig { strategy: config.strategy, n_servers: config.n_servers };
            transport.n_shells()
        ];
        Self::new_with(config, transport, placement, ReplicationPolicy::default(), false, layouts)
    }

    /// A fully-configured manager: per-shell layouts, replication policy
    /// and the pre-placement predictor switch.
    pub fn new_with(
        config: KvcConfig,
        transport: Arc<FederatedTransport>,
        placement: PlacementPolicy,
        replication: ReplicationPolicy,
        preplace: bool,
        shell_layouts: Vec<ShellLayoutConfig>,
    ) -> Self {
        assert!(config.n_servers >= 1);
        assert_eq!(
            shell_layouts.len(),
            transport.n_shells(),
            "one layout config per shell"
        );
        for (link, lc) in transport.links().iter().zip(&shell_layouts) {
            assert!(lc.n_servers >= 1, "{}: a stripe needs servers", link.shell.name);
            let w = box_width(lc.n_servers);
            let t = &link.shell.torus;
            assert!(
                w <= t.planes && w <= t.sats_per_plane,
                "{}: {w}x{w} layout box does not fit a {}x{} torus",
                link.shell.name,
                t.planes,
                t.sats_per_plane
            );
        }
        let shell_counters = (0..transport.n_shells()).map(|_| ShellCounters::default()).collect();
        let shell_costs = transport
            .links()
            .iter()
            .zip(&shell_layouts)
            .map(|(l, lc)| shell_cost(&l.shell.geometry, lc.n_servers))
            .collect();
        let prev_live = vec![1.0; transport.n_shells()];
        Self {
            config,
            placement,
            replication,
            preplace,
            transport,
            shell_layouts,
            index: Mutex::new(FrozenMap::new()),
            tombstones: Mutex::new(BTreeMap::new()),
            prev_live: Mutex::new(prev_live),
            shell_counters,
            shell_costs,
            trace: Mutex::new(Arc::new(NoopSink)),
            stats: FedStats::default(),
        }
    }

    pub fn transport(&self) -> &Arc<FederatedTransport> {
        &self.transport
    }

    /// Route federation events to `sink` and install it on every shell's
    /// scheduler (each stamps its own shell index on its events).
    pub fn set_trace_sink(&self, sink: Arc<dyn TraceSink>) {
        for (i, link) in self.transport.links().iter().enumerate() {
            link.sched.set_trace_sink(sink.clone(), i as u16);
        }
        *self.trace.lock().unwrap() = sink;
    }

    /// Install the session-layer reference table
    /// ([`crate::kvc::session::BlockRefs`]) on every shell's fleet:
    /// session-referenced blocks are pinned against LRU pressure and
    /// propagated evictions federation-wide, so invalidation decrements
    /// interest instead of deleting a prefix a live session still maps.
    pub fn set_block_refs(&self, refs: &Arc<crate::kvc::session::BlockRefs>) {
        for link in self.transport.links() {
            link.fleet.set_block_refs(refs);
        }
    }

    /// Federation-level virtual-time stamp for events that belong to no
    /// single shell: the sum of every shell scheduler's clock (monotone
    /// and deterministic).
    fn fed_now(&self) -> u64 {
        self.transport
            .links()
            .iter()
            .map(|l| l.sched.stats.virtual_ns.load(Ordering::Relaxed))
            .sum()
    }

    pub fn shell_counters(&self) -> &[ShellCounters] {
        &self.shell_counters
    }

    pub fn shell_layout(&self, shell: ShellId) -> ShellLayoutConfig {
        self.shell_layouts[shell as usize]
    }

    /// Blocks currently indexed (federation-wide; copies not counted).
    pub fn indexed_blocks(&self) -> usize {
        self.index.lock().unwrap().len()
    }

    /// Current home shell of a block, if indexed.
    pub fn home_of(&self, block: &BlockHash) -> Option<ShellId> {
        self.index.lock().unwrap().get(block).map(|e| e.shell)
    }

    /// Shell of a block's live replica, if any.
    pub fn replica_of(&self, block: &BlockHash) -> Option<ShellId> {
        self.index.lock().unwrap().get(block).and_then(|e| e.replica.map(|r| r.shell))
    }

    /// Live fraction of `shell`'s current layout box (the placement
    /// eligibility signal).
    pub fn box_live_fraction(&self, shell: ShellId) -> f64 {
        let link = self.transport.link(shell);
        let torus = link.shell.torus;
        let center = self.transport.closest(shell);
        let half = (box_width(self.shell_layouts[shell as usize].n_servers) as i32 - 1) / 2;
        let mut live = 0usize;
        let mut total = 0usize;
        for dp in -half..=half {
            for ds in -half..=half {
                total += 1;
                if !link.faults.is_satellite_failed(torus.offset(center, dp, ds)) {
                    live += 1;
                }
            }
        }
        live as f64 / total as f64
    }

    fn candidates(&self) -> Vec<ShellCandidate> {
        (0..self.transport.n_shells())
            .map(|i| ShellCandidate {
                shell: i as ShellId,
                cost_s: self.shell_costs[i],
                live_fraction: self.box_live_fraction(i as ShellId),
                placed_bytes: self.shell_counters[i].placed_bytes.load(Ordering::Relaxed),
            })
            .collect()
    }

    /// The federation's static primary shell (cheapest by cost alone —
    /// answered from the cached costs, no torus scans).
    pub fn primary_shell(&self) -> ShellId {
        cheapest_index(&self.shell_costs).expect("federation has shells") as ShellId
    }

    /// The cheapest currently-live shell other than `exclude`, if any.
    pub fn cheapest_live_shell_excluding(&self, exclude: ShellId) -> Option<ShellId> {
        let mut best: Option<(ShellId, f64)> = None;
        for c in self.candidates() {
            if c.shell == exclude || c.live_fraction < self.placement.min_live_fraction {
                continue;
            }
            let better = match best {
                None => true,
                Some((_, cost)) => c.cost_s < cost,
            };
            if better {
                best = Some((c.shell, c.cost_s));
            }
        }
        best.map(|(s, _)| s)
    }

    // ------------------------------------------------------------ SET ---

    /// Store one block's KV values on the shell placement chooses; no-op
    /// if the block is already indexed.  Returns the home shell.
    pub fn put_block(
        &self,
        hashes: &[BlockHash],
        block_idx: usize,
        kv_values: &[f32],
        now_epoch: u64,
    ) -> Result<ShellId> {
        let block = hashes[block_idx];
        if let Some(e) = self.index.lock().unwrap().get(&block) {
            return Ok(e.shell);
        }
        let cands = self.candidates();
        let chosen = self.placement.choose(&cands).expect("federation has shells");
        let primary = self.placement.primary(&cands).expect("federation has shells");
        let shell = cands[chosen].shell;
        let payload = self.config.quantizer.encode(kv_values);
        let meta = self.store_payload(shell, block, &payload, now_epoch)?;
        if chosen != primary {
            self.stats.spillovers.fetch_add(1, Ordering::Relaxed);
        }
        if let Some(old_home) = self.tombstones.lock().unwrap().remove(&block) {
            if old_home != shell {
                self.stats.reactive_rehomed_blocks.fetch_add(1, Ordering::Relaxed);
            }
        }
        self.index.lock().unwrap().insert(
            block,
            FedBlockMeta { shell, meta, accesses: 0, replica: None, preplaced: None },
        );
        Ok(shell)
    }

    /// Stripe an encoded payload over `shell`'s layout around `center`
    /// as one virtual-time batch on the shell's scheduler (fan-out parity
    /// with the single-shell manager).  No counters: callers account
    /// stores, replicas and evacuations differently.
    fn stripe_payload(
        &self,
        shell: ShellId,
        block: BlockHash,
        payload: &[u8],
        write_epoch: u64,
        center: SatId,
    ) -> Result<BlockMeta> {
        let lc = self.shell_layouts[shell as usize];
        let n_chunks = chunk_count(payload.len(), self.config.chunk_size) as u32;
        let header = encode_chunk_header(
            self.config.quantizer.id(),
            n_chunks,
            payload.len() as u32,
            write_epoch,
        );
        let torus = self.transport.shell(shell).torus;
        let layout = lc.strategy.initial_layout(&torus, center, lc.n_servers);
        let transfers: Vec<Transfer> = split_chunks(payload, self.config.chunk_size)
            .iter()
            .enumerate()
            .map(|(i, chunk)| {
                let mut data = Vec::with_capacity(CHUNK_HEADER_LEN + chunk.len());
                data.extend_from_slice(&header);
                data.extend_from_slice(chunk);
                Transfer {
                    tag: i as u64,
                    op: ChunkOp::Set {
                        dest: layout[i % lc.n_servers],
                        key: ChunkKey::new(block, i as u32),
                        data,
                    },
                }
            })
            .collect();
        let batch = self.transport.link(shell).sched.run_batch(transfers);
        for o in &batch.outcomes {
            if let ChunkResult::Failed(e) = &o.result {
                bail!("shell {shell}: chunk {} set failed: {e}", o.tag);
            }
        }
        Ok(BlockMeta {
            num_chunks: n_chunks,
            kvc_len: payload.len() as u32,
            write_epoch,
            quantizer_id: self.config.quantizer.id(),
        })
    }

    /// Store a primary copy on `shell` at the current rotation centre,
    /// with the store counters.
    fn store_payload(
        &self,
        shell: ShellId,
        block: BlockHash,
        payload: &[u8],
        now_epoch: u64,
    ) -> Result<BlockMeta> {
        let center = self.transport.closest(shell);
        let meta = self.stripe_payload(shell, block, payload, now_epoch, center)?;
        let counters = &self.shell_counters[shell as usize];
        counters.blocks_stored.fetch_add(1, Ordering::Relaxed);
        counters.placed_bytes.fetch_add(payload.len() as u64, Ordering::Relaxed);
        Ok(meta)
    }

    // ------------------------------------------------------------ GET ---

    /// Longest cached prefix (in blocks) of `hashes`: chained hashes make
    /// this a plain `take_while` over the federation index.
    pub fn lookup(&self, hashes: &[BlockHash]) -> usize {
        let index = self.index.lock().unwrap();
        hashes.iter().take_while(|h| index.contains_key(h)).count()
    }

    /// The layout of a copy written on `shell` at `write_epoch`, resolved
    /// at `now_epoch`.  Total over pre-placed copies too: a copy written
    /// for a *future* epoch sits one slot west per epoch of lead, with no
    /// rotation shift yet.
    fn layout_for(&self, shell: ShellId, write_epoch: u64, now_epoch: u64) -> Vec<SatId> {
        let lc = self.shell_layouts[shell as usize];
        let torus = self.transport.shell(shell).torus;
        let delta = now_epoch as i64 - write_epoch as i64;
        // the centre slides one slot west per epoch; the write-time
        // centre was `delta` slots east of the current one (west of it
        // for a copy pre-placed for a future epoch)
        let write_center = torus.offset(self.transport.closest(shell), 0, delta as i32);
        lc.strategy.layout_at(&torus, write_center, lc.n_servers, delta.max(0) as u64)
    }

    /// The Get transfer set of one copy.
    fn copy_transfers(
        &self,
        shell: ShellId,
        block: BlockHash,
        meta: &BlockMeta,
        now_epoch: u64,
    ) -> Vec<Transfer> {
        let lc = self.shell_layouts[shell as usize];
        let layout = self.layout_for(shell, meta.write_epoch, now_epoch);
        (0..meta.num_chunks as usize)
            .map(|i| Transfer {
                tag: i as u64,
                op: ChunkOp::Get {
                    dest: layout[i % lc.n_servers],
                    key: ChunkKey::new(block, i as u32),
                },
            })
            .collect()
    }

    /// Whether a copy's batch report carries a complete payload — the
    /// allocation-free check [`Self::assemble`] would answer with `Some`.
    fn copy_complete(report: &BatchReport, meta: &BlockMeta) -> bool {
        let mut len = 0usize;
        for o in &report.outcomes {
            match &o.result {
                ChunkResult::Got(Some(data)) if data.len() > CHUNK_HEADER_LEN => {
                    len += data.len() - CHUNK_HEADER_LEN
                }
                _ => return false,
            }
        }
        len == meta.kvc_len as usize
    }

    /// Reassemble a copy's payload from its batch report (outcomes are in
    /// tag order); `None` when any chunk is missing or short.
    fn assemble(&self, report: &BatchReport, meta: &BlockMeta) -> Option<Vec<u8>> {
        let mut payload = Vec::with_capacity(meta.kvc_len as usize);
        for o in &report.outcomes {
            match &o.result {
                ChunkResult::Got(Some(data)) if data.len() > CHUNK_HEADER_LEN => {
                    payload.extend_from_slice(&data[CHUNK_HEADER_LEN..])
                }
                _ => return None,
            }
        }
        if payload.len() == meta.kvc_len as usize {
            Some(payload)
        } else {
            None
        }
    }

    /// Fetch one copy (no counters): one virtual-time batch on its
    /// shell's scheduler.  Used by replication and re-striping
    /// evacuation, which must not perturb the fetch metrics.
    fn fetch_copy_payload(
        &self,
        shell: ShellId,
        block: BlockHash,
        meta: &BlockMeta,
        now_epoch: u64,
    ) -> Option<Vec<u8>> {
        let transfers = self.copy_transfers(shell, block, meta, now_epoch);
        let report = self.transport.link(shell).sched.run_batch(transfers);
        self.assemble(&report, meta)
    }

    /// Fetch one block's KV values, racing every live copy; `None` if the
    /// block is unknown or every copy is broken (broken blocks are
    /// dropped with invalidations fanned out to every copy, and their
    /// home is remembered for re-homing stats).
    pub fn fetch_block(
        &self,
        hashes: &[BlockHash],
        block_idx: usize,
        now_epoch: u64,
    ) -> Result<Option<Vec<f32>>> {
        let block = hashes[block_idx];
        let Some(entry) = self.index.lock().unwrap().get(&block).copied() else {
            return Ok(None);
        };
        // arm 0 is always the home copy; copies follow in slot order
        let mut arms: Vec<BlockCopy> = vec![BlockCopy { shell: entry.shell, meta: entry.meta }];
        if let Some(r) = entry.replica {
            arms.push(r);
        }
        if let Some(p) = entry.preplaced {
            arms.push(p);
        }
        for arm in &arms {
            self.shell_counters[arm.shell as usize].fetch_attempts.fetch_add(1, Ordering::Relaxed);
        }
        if arms.len() > 1 {
            self.stats.replica_races.fetch_add(1, Ordering::Relaxed);
        }
        let race_arms = arms
            .iter()
            .map(|arm| {
                (
                    self.transport.link(arm.shell).sched.as_ref(),
                    self.copy_transfers(arm.shell, block, &arm.meta, now_epoch),
                )
            })
            .collect();
        let sink = self.trace.lock().unwrap().clone();
        let tracing = sink.wants(SpanKind::Fed);
        // each arm's span starts on its own shell's clock, read before the
        // race advances it
        let arm_bases: Vec<u64> = if tracing {
            arms.iter()
                .map(|a| {
                    self.transport.link(a.shell).sched.stats.virtual_ns.load(Ordering::Relaxed)
                })
                .collect()
        } else {
            Vec::new()
        };
        let outcome = race_batches(race_arms);
        // the serving arm: fastest makespan among arms whose payload
        // reassembled completely, ties to the lowest arm index
        let mut order: Vec<usize> = (0..arms.len()).collect();
        order.sort_by_key(|&i| (outcome.reports[i].makespan_ns, i));
        let mut served: Option<(usize, Vec<u8>)> = None;
        for i in order {
            if let Some(payload) = self.assemble(&outcome.reports[i], &arms[i].meta) {
                served = Some((i, payload));
                break;
            }
        }
        if tracing {
            let win = served.as_ref().map(|(i, _)| *i);
            for (i, arm) in arms.iter().enumerate() {
                let result = match win {
                    Some(w) if w == i => "win",
                    _ if Self::copy_complete(&outcome.reports[i], &arm.meta) => "lose",
                    _ => "broken",
                };
                sink.record(
                    TraceEvent::span(
                        SpanKind::Fed,
                        "race_arm",
                        arm_bases[i],
                        outcome.reports[i].makespan_ns,
                    )
                    .with_shell(u16::from(arm.shell))
                    .arg_u("arm", i as u64)
                    .arg("outcome", ArgVal::S(result.to_string())),
                );
            }
        }
        let Some((winner, payload)) = served else {
            self.drop_broken(block, &entry, now_epoch);
            return Ok(None);
        };
        let win = arms[winner];
        let counters = &self.shell_counters[win.shell as usize];
        counters.blocks_hit.fetch_add(1, Ordering::Relaxed);
        if winner > 0 {
            counters.replica_hits.fetch_add(1, Ordering::Relaxed);
            self.stats.replica_race_wins.fetch_add(1, Ordering::Relaxed);
            // arm indices: replica (if present) sits right after home
            let is_preplaced = entry.replica.is_some() && winner == 2
                || entry.replica.is_none() && winner == 1;
            if is_preplaced {
                self.stats.preplace_hits.fetch_add(1, Ordering::Relaxed);
            }
            // a broken primary promotes the surviving copy it raced
            if !Self::copy_complete(&outcome.reports[0], &arms[0].meta) {
                self.promote_copy(block, &entry, winner, now_epoch);
            }
        }
        // copy arms that raced and failed to reassemble are dead even
        // though their shell's box may still be live (chunk loss, LRU):
        // drop their slots now so the next epoch boundary re-creates
        // them — otherwise a silently-broken replica is raced forever
        // and protects nothing
        for (i, arm) in arms.iter().enumerate().skip(1) {
            if i != winner && !Self::copy_complete(&outcome.reports[i], &arm.meta) {
                self.invalidate_copy_slot(block, arm, now_epoch);
            }
        }
        self.bump_accesses(&block);
        let group = match self.config.quantizer {
            Quantizer::QuantoInt8 { group } | Quantizer::HqqInt8 { group } => group,
            Quantizer::F32 => 32,
        };
        let quantizer = Quantizer::from_id(win.meta.quantizer_id, group)
            .ok_or_else(|| anyhow::anyhow!("unknown quantizer id {}", win.meta.quantizer_id))?;
        Ok(Some(quantizer.decode(&payload)?))
    }

    fn bump_accesses(&self, block: &BlockHash) {
        if let Some(e) = self.index.lock().unwrap().get_mut(block) {
            e.accesses += 1;
        }
    }

    /// Drop a dead copy's slot (matched by value), evict its leftover
    /// chunks and debit its bytes.  No-op when the slot no longer holds
    /// this exact copy (e.g. it was just promoted to primary, or its
    /// bytes were already settled by a collapse).
    fn invalidate_copy_slot(&self, block: BlockHash, copy: &BlockCopy, now_epoch: u64) {
        let mut index = self.index.lock().unwrap();
        let Some(e) = index.get_mut(&block) else { return };
        if e.replica == Some(*copy) {
            e.replica = None;
        } else if e.preplaced == Some(*copy) {
            e.preplaced = None;
        } else {
            return;
        }
        drop(index);
        // safe by-block eviction: copies live on pairwise-distinct
        // shells, so no other copy of this block shares these satellites
        self.evict_copy(copy, block, now_epoch);
        self.shell_counters[copy.shell as usize]
            .placed_bytes
            .fetch_sub(copy.meta.kvc_len as u64, Ordering::Relaxed);
    }

    /// Re-home a block onto the copy that won its race while the primary
    /// was broken: the copy becomes the primary, the dead primary's
    /// chunks are invalidated, and the block never leaves the index.
    fn promote_copy(&self, block: BlockHash, entry: &FedBlockMeta, winner: usize, now_epoch: u64) {
        let old = BlockCopy { shell: entry.shell, meta: entry.meta };
        let mut index = self.index.lock().unwrap();
        let Some(e) = index.get_mut(&block) else { return };
        let promoted = if entry.replica.is_some() && winner == 1 {
            e.replica.take()
        } else {
            e.preplaced.take()
        };
        let Some(copy) = promoted else { return };
        e.shell = copy.shell;
        e.meta = copy.meta;
        // a leftover copy slot on the new home shell duplicates the block
        // there: drop the slot and its byte credit.  Its chunks are left
        // to LRU — chunk keys are not copy-qualified, so evicting by
        // block hash would purge the promoted copy too.
        let mut merged_bytes = 0u64;
        if let Some(r) = e.replica {
            if r.shell == e.shell {
                merged_bytes += r.meta.kvc_len as u64;
                e.replica = None;
            }
        }
        if let Some(p) = e.preplaced {
            if p.shell == e.shell {
                merged_bytes += p.meta.kvc_len as u64;
                e.preplaced = None;
            }
        }
        let new_home = e.shell;
        drop(index);
        if merged_bytes > 0 {
            self.shell_counters[new_home as usize]
                .placed_bytes
                .fetch_sub(merged_bytes, Ordering::Relaxed);
        }
        self.stats.replica_promotions.fetch_add(1, Ordering::Relaxed);
        let sink = self.trace.lock().unwrap().clone();
        if sink.wants(SpanKind::Fed) {
            sink.record(
                TraceEvent::instant(SpanKind::Fed, "promote_copy", self.fed_now())
                    .with_shell(u16::from(new_home))
                    .arg_u("from_shell", u64::from(old.shell))
                    .arg_u("bytes", old.meta.kvc_len as u64),
            );
        }
        // fan out the invalidation of the dead primary and move the
        // placement accounting onto the promoted copy's shell
        self.evict_copy(&old, block, now_epoch);
        self.shell_counters[old.shell as usize]
            .placed_bytes
            .fetch_sub(old.meta.kvc_len as u64, Ordering::Relaxed);
    }

    /// §3.9 lazy eviction, federated: every copy is broken — drop the
    /// block from the index, remember its home for re-homing stats, and
    /// fan out evictions to the surviving satellites of *every* copy.
    fn drop_broken(&self, block: BlockHash, entry: &FedBlockMeta, now_epoch: u64) {
        self.stats.broken_blocks.fetch_add(1, Ordering::Relaxed);
        self.index.lock().unwrap().remove(&block);
        self.tombstones.lock().unwrap().insert(block, entry.shell);
        let mut copies = vec![BlockCopy { shell: entry.shell, meta: entry.meta }];
        copies.extend(entry.replica);
        copies.extend(entry.preplaced);
        for c in &copies {
            self.evict_copy(c, block, now_epoch);
            self.shell_counters[c.shell as usize]
                .placed_bytes
                .fetch_sub(c.meta.kvc_len as u64, Ordering::Relaxed);
        }
    }

    /// Tell the satellites of one copy's layout to purge the block.
    fn evict_copy(&self, copy: &BlockCopy, block: BlockHash, now_epoch: u64) {
        let lc = self.shell_layouts[copy.shell as usize];
        let layout = self.layout_for(copy.shell, copy.meta.write_epoch, now_epoch);
        let servers = lc.n_servers.min(copy.meta.num_chunks as usize);
        for sat in layout.iter().take(servers) {
            let _ = self.transport.evict_block(FedSatId::new(copy.shell, *sat), block);
        }
    }

    /// Fetch blocks `0..blocks` in order; returns how many were served
    /// before the prefix truncated.
    pub fn fetch_prefix(
        &self,
        hashes: &[BlockHash],
        blocks: usize,
        now_epoch: u64,
    ) -> Result<usize> {
        let mut got = 0;
        for b in 0..blocks {
            match self.fetch_block(hashes, b, now_epoch)? {
                Some(_) => got += 1,
                None => break,
            }
        }
        Ok(got)
    }

    // ------------------------------------------------- REPLICATION ------

    /// The deterministic hot set: top-K blocks by `(accesses desc, hash
    /// asc)` among blocks with at least
    /// [`ReplicationPolicy::min_accesses`] accesses.
    fn hot_blocks(&self, k: usize) -> Vec<BlockHash> {
        let entries = self.index.lock().unwrap().entries();
        let mut hot: Vec<(u64, BlockHash)> = entries
            .iter()
            .filter(|(_, e)| e.accesses >= self.replication.min_accesses)
            .map(|(h, e)| (e.accesses, *h))
            .collect();
        hot.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        hot.truncate(k);
        hot.into_iter().map(|(_, h)| h).collect()
    }

    /// Ensure `block` has a live replica so its copies span the cheapest
    /// live pair; returns 1 when a replica was created.
    fn ensure_replica(&self, block: BlockHash, span: &[ShellId], now_epoch: u64) -> u64 {
        let Some(entry) = self.index.lock().unwrap().get(&block).copied() else { return 0 };
        if let Some(r) = entry.replica {
            if self.box_live_fraction(r.shell) >= self.placement.min_live_fraction {
                return 0; // live replica already in place
            }
            // the replica's shell died: drop the stale copy and re-create
            self.evict_copy(&r, block, now_epoch);
            self.shell_counters[r.shell as usize]
                .placed_bytes
                .fetch_sub(r.meta.kvc_len as u64, Ordering::Relaxed);
            if let Some(e) = self.index.lock().unwrap().get_mut(&block) {
                e.replica = None;
            }
        }
        // never target the home shell, nor the shell already holding the
        // pre-placed copy: chunk keys are not copy-qualified, so two
        // copies of one block on one shell would collide and a later
        // invalidation of either would purge both
        let preplaced_shell = entry.preplaced.map(|c| c.shell);
        let target = span
            .iter()
            .copied()
            .find(|s| *s != entry.shell && Some(*s) != preplaced_shell)
            .or_else(|| {
                self.cheapest_live_shell_excluding(entry.shell)
                    .filter(|s| Some(*s) != preplaced_shell)
            });
        let Some(target) = target else { return 0 };
        let Some(payload) = self.fetch_copy_payload(entry.shell, block, &entry.meta, now_epoch)
        else {
            return 0; // broken home heals reactively on its next fetch
        };
        let center = self.transport.closest(target);
        let Ok(meta) = self.stripe_payload(target, block, &payload, now_epoch, center) else {
            return 0;
        };
        if let Some(e) = self.index.lock().unwrap().get_mut(&block) {
            e.replica = Some(BlockCopy { shell: target, meta });
        } else {
            return 0;
        }
        let counters = &self.shell_counters[target as usize];
        counters.replicas_hosted.fetch_add(1, Ordering::Relaxed);
        counters.placed_bytes.fetch_add(payload.len() as u64, Ordering::Relaxed);
        self.stats.replicated_blocks.fetch_add(1, Ordering::Relaxed);
        self.transport.account_inter_shell(
            entry.shell,
            target,
            meta.num_chunks as u64,
            payload.len() as u64,
        );
        1
    }

    /// Pre-place `block`'s next-rotation layout on the predicted shell
    /// `p`: write epoch `now + 1`, centred one slot west of `p`'s current
    /// centre (where `p`'s ground view will be after the handover).
    fn ensure_preplaced(&self, block: BlockHash, p: ShellId, now_epoch: u64) -> u64 {
        let Some(entry) = self.index.lock().unwrap().get(&block).copied() else { return 0 };
        if entry.shell == p || entry.replica.map(|r| r.shell) == Some(p) {
            return 0; // a copy already lives on the predicted shell
        }
        if let Some(old) = entry.preplaced {
            if old.shell == p {
                return 0; // already pre-placed there (keeps rotating along)
            }
            // prediction moved: invalidate the stale pre-placement
            self.evict_copy(&old, block, now_epoch);
            self.shell_counters[old.shell as usize]
                .placed_bytes
                .fetch_sub(old.meta.kvc_len as u64, Ordering::Relaxed);
            if let Some(e) = self.index.lock().unwrap().get_mut(&block) {
                e.preplaced = None;
            }
        }
        let payload = self
            .fetch_copy_payload(entry.shell, block, &entry.meta, now_epoch)
            .or_else(|| {
                let r = entry.replica?;
                self.fetch_copy_payload(r.shell, block, &r.meta, now_epoch)
            });
        let Some(payload) = payload else { return 0 };
        let torus = self.transport.shell(p).torus;
        let next_center = torus.offset(self.transport.closest(p), 0, -1);
        let Ok(meta) = self.stripe_payload(p, block, &payload, now_epoch + 1, next_center) else {
            return 0;
        };
        if let Some(e) = self.index.lock().unwrap().get_mut(&block) {
            e.preplaced = Some(BlockCopy { shell: p, meta });
        } else {
            return 0;
        }
        let counters = &self.shell_counters[p as usize];
        counters.preplaced_hosted.fetch_add(1, Ordering::Relaxed);
        counters.placed_bytes.fetch_add(payload.len() as u64, Ordering::Relaxed);
        self.stats.preplaced_blocks.fetch_add(1, Ordering::Relaxed);
        self.transport.account_inter_shell(
            entry.shell,
            p,
            meta.num_chunks as u64,
            payload.len() as u64,
        );
        1
    }

    /// Epoch-boundary policy hook: replicate the hot set across the
    /// cheapest live pair, run the §3.7 predictor and pre-place the hot
    /// set's next-rotation layout on its pick, then record this epoch's
    /// live fractions as the next trend input.  Call after serving an
    /// epoch's traffic and before advancing the ground views.  Returns
    /// `(replicas created, copies pre-placed)`.
    pub fn end_of_epoch(&self, now_epoch: u64) -> (u64, u64) {
        let cands = self.candidates();
        let mut replicated = 0u64;
        let mut preplaced = 0u64;
        if self.replication.enabled() {
            let hot = self.hot_blocks(self.replication.top_k);
            let span: Vec<ShellId> = cheapest_two(&cands, self.placement.min_live_fraction)
                .into_iter()
                .map(|i| i as ShellId)
                .collect();
            for block in &hot {
                replicated += self.ensure_replica(*block, &span, now_epoch);
            }
            if self.preplace {
                let prev = self.prev_live.lock().unwrap().clone();
                if let Some(p) = predict_preplacement_shell(
                    &cands,
                    &prev,
                    self.placement.min_live_fraction,
                ) {
                    for block in &hot {
                        preplaced += self.ensure_preplaced(*block, p as ShellId, now_epoch);
                    }
                }
            }
        }
        *self.prev_live.lock().unwrap() = cands.iter().map(|c| c.live_fraction).collect();
        // freeze the live epoch's index delta into a new generation
        // (tombstoned keys drop for real, everything else survives)
        let compacted = self.index.lock().unwrap().compact();
        let sink = self.trace.lock().unwrap().clone();
        if sink.wants(SpanKind::Fed) {
            sink.record(
                TraceEvent::instant(SpanKind::Fed, "end_of_epoch", self.fed_now())
                    .arg_u("compacted", u64::from(compacted))
                    .arg_u("epoch", now_epoch)
                    .arg_u("preplaced", preplaced)
                    .arg_u("replicated", replicated),
            );
        }
        (replicated, preplaced)
    }

    /// Frozen index generations built (one per compacting
    /// [`Self::end_of_epoch`]).
    pub fn index_compactions(&self) -> u64 {
        self.index.lock().unwrap().compactions()
    }

    // ------------------------------------------------------ ROTATION ----

    /// §3.4 intra-shell rotation migration for one shell: the exiting east
    /// column hands its chunks to the entering west column, per plane
    /// (the same handoff pairs the single-shell manager issues), using
    /// the shell's own stripe width.
    pub fn migration_requests(&self, shell: ShellId) -> Vec<(SatId, SatId)> {
        let lc = self.shell_layouts[shell as usize];
        if !lc.strategy.migrates() {
            return vec![];
        }
        let torus = self.transport.shell(shell).torus;
        crate::mapping::migration::rotation_handoff_pairs(
            &torus,
            self.transport.closest(shell),
            lc.n_servers,
        )
    }

    // ------------------------------------------------------ HANDOVER ----

    /// Proactive inter-shell handover: move every block homed on `from`
    /// onto `to` and re-home the index.
    ///
    /// When both shells share one [`ShellLayoutConfig`], the whole layout
    /// box is drained cell-by-cell to the same relative cells of `to`'s
    /// box (offsets relative to the lockstep-rotating centres are
    /// preserved, so the write-epoch arithmetic keeps resolving every
    /// surviving chunk).  When the configs differ, every block is
    /// re-fetched and re-striped onto `to`'s own layout (write epoch
    /// `now_epoch`); blocks that no longer reassemble drop to tombstones
    /// and heal reactively.  Replicas already on `to` are kept; copies
    /// stranded on `from` are invalidated or re-tagged.
    pub fn evacuate_shell(&self, from: ShellId, to: ShellId, now_epoch: u64) -> EvacSummary {
        assert_ne!(from, to, "evacuation needs a distinct target shell");
        // pre-placed copies on `from` straddle the next rotation's box and
        // cannot ride either path: invalidate them first
        let stranded: Vec<(BlockHash, BlockCopy)> = self
            .index
            .lock()
            .unwrap()
            .entries()
            .into_iter()
            .filter_map(|(h, e)| e.preplaced.filter(|c| c.shell == from).map(|c| (h, c)))
            .collect();
        for (block, copy) in &stranded {
            self.evict_copy(copy, *block, now_epoch);
            self.shell_counters[from as usize]
                .placed_bytes
                .fetch_sub(copy.meta.kvc_len as u64, Ordering::Relaxed);
            if let Some(e) = self.index.lock().unwrap().get_mut(block) {
                e.preplaced = None;
            }
        }
        let summary = if self.shell_layouts[from as usize] == self.shell_layouts[to as usize] {
            self.evacuate_same_layout(from, to)
        } else {
            self.evacuate_restripe(from, to, now_epoch)
        };
        let sink = self.trace.lock().unwrap().clone();
        if sink.wants(SpanKind::Fed) {
            sink.record(
                TraceEvent::instant(SpanKind::Fed, "evacuate_shell", self.fed_now())
                    .arg_u("from", u64::from(from))
                    .arg_u("to", u64::from(to))
                    .arg_u("chunks_moved", u64::from(summary.chunks_moved))
                    .arg_u("bytes_moved", summary.bytes_moved)
                    .arg_u("blocks_rehomed", summary.blocks_rehomed),
            );
        }
        summary
    }

    /// The offset-preserving evacuation path (identical layout configs).
    fn evacuate_same_layout(&self, from: ShellId, to: ShellId) -> EvacSummary {
        let src_torus = self.transport.shell(from).torus;
        let dst_torus = self.transport.shell(to).torus;
        let src_center = self.transport.closest(from);
        let dst_center = self.transport.closest(to);
        let half = (box_width(self.shell_layouts[from as usize].n_servers) as i32 - 1) / 2;
        let mut chunks_moved = 0u32;
        let mut bytes_moved = 0u64;
        for dp in -half..=half {
            for ds in -half..=half {
                let s = FedSatId::new(from, src_torus.offset(src_center, dp, ds));
                let d = FedSatId::new(to, dst_torus.offset(dst_center, dp, ds));
                let (m, b) = self.transport.migrate_cross_shell(s, d);
                chunks_moved += m;
                bytes_moved += b;
            }
        }
        let mut rehomed = 0u64;
        let mut rehomed_bytes = 0u64;
        let mut copy_bytes_moved = 0u64;
        let mut copy_bytes_merged = 0u64;
        let mut copy_bytes_collapsed = 0u64;
        // walk a merged snapshot and write back only the entries that
        // actually changed, so untouched frozen entries are not
        // copy-on-write'd into the delta
        let mut index = self.index.lock().unwrap();
        for (block, before) in index.entries() {
            let mut entry = before;
            if entry.shell == from {
                entry.shell = to;
                rehomed += 1;
                rehomed_bytes += entry.meta.kvc_len as u64;
            }
            // replicas physically rode the drain with everything else:
            // re-tag them, and drop the slot if it collapsed onto the
            // (possibly just re-homed) primary
            if let Some(mut r) = entry.replica {
                if r.shell == from {
                    r.shell = to;
                    if to == entry.shell {
                        copy_bytes_merged += r.meta.kvc_len as u64;
                        entry.replica = None;
                    } else {
                        copy_bytes_moved += r.meta.kvc_len as u64;
                        entry.replica = Some(r);
                    }
                }
            }
            // the target shell may already hold this block's replica or
            // pre-placed copy: a re-homed primary collapses onto it.
            // Drop the slot and its byte credit; the chunks share keys
            // with the primary's, so a by-block eviction would purge the
            // primary too — leave them to LRU.
            if entry.shell == to {
                if let Some(r) = entry.replica {
                    if r.shell == to {
                        copy_bytes_collapsed += r.meta.kvc_len as u64;
                        entry.replica = None;
                    }
                }
                if let Some(p) = entry.preplaced {
                    if p.shell == to {
                        copy_bytes_collapsed += p.meta.kvc_len as u64;
                        entry.preplaced = None;
                    }
                }
            }
            if entry != before {
                *index.get_mut(&block).expect("key came from entries()") = entry;
            }
        }
        drop(index);
        self.stats.proactive_handover_blocks.fetch_add(rehomed, Ordering::Relaxed);
        // move the placement accounting with the blocks (payload-byte
        // convention, matching store_payload; every moved copy was
        // credited to `from` — and every collapsed copy to `to` — when
        // stored, so the debits cannot underflow)
        self.shell_counters[from as usize]
            .placed_bytes
            .fetch_sub(rehomed_bytes + copy_bytes_moved + copy_bytes_merged, Ordering::Relaxed);
        self.shell_counters[to as usize]
            .placed_bytes
            .fetch_add(rehomed_bytes + copy_bytes_moved, Ordering::Relaxed);
        self.shell_counters[to as usize]
            .placed_bytes
            .fetch_sub(copy_bytes_collapsed, Ordering::Relaxed);
        EvacSummary { chunks_moved, bytes_moved, blocks_rehomed: rehomed }
    }

    /// The re-striping evacuation path (differing layout configs): fetch
    /// each block homed on `from` and stripe it onto `to`'s own layout.
    fn evacuate_restripe(&self, from: ShellId, to: ShellId, now_epoch: u64) -> EvacSummary {
        // replicas stranded on `from` (blocks homed elsewhere) cannot be
        // offset-preserved across layout configs: invalidate them — the
        // replication policy re-creates them on a live shell at the next
        // epoch boundary
        let stranded: Vec<(BlockHash, BlockCopy)> = self
            .index
            .lock()
            .unwrap()
            .entries()
            .into_iter()
            .filter(|(_, e)| e.shell != from)
            .filter_map(|(h, e)| e.replica.filter(|c| c.shell == from).map(|c| (h, c)))
            .collect();
        for (block, copy) in &stranded {
            self.evict_copy(copy, *block, now_epoch);
            self.shell_counters[from as usize]
                .placed_bytes
                .fetch_sub(copy.meta.kvc_len as u64, Ordering::Relaxed);
            if let Some(e) = self.index.lock().unwrap().get_mut(block) {
                e.replica = None;
            }
        }
        let homed: Vec<(BlockHash, FedBlockMeta)> = self
            .index
            .lock()
            .unwrap()
            .entries()
            .into_iter()
            .filter(|(_, e)| e.shell == from)
            .collect();
        let dst_center = self.transport.closest(to);
        let mut chunks_moved = 0u32;
        let mut bytes_moved = 0u64;
        let mut rehomed = 0u64;
        for (block, entry) in homed {
            // prefer the home copy; fall back to a replica if the home
            // box already lost chunks
            let payload = self
                .fetch_copy_payload(from, block, &entry.meta, now_epoch)
                .or_else(|| {
                    let r = entry.replica?;
                    self.fetch_copy_payload(r.shell, block, &r.meta, now_epoch)
                });
            let Some(payload) = payload else {
                // nothing to move: drop the block like drop_broken would —
                // every copy evicted and debited — and heal reactively
                self.index.lock().unwrap().remove(&block);
                self.tombstones.lock().unwrap().insert(block, from);
                self.shell_counters[from as usize]
                    .placed_bytes
                    .fetch_sub(entry.meta.kvc_len as u64, Ordering::Relaxed);
                for c in entry.replica.iter().chain(entry.preplaced.iter()) {
                    self.evict_copy(c, block, now_epoch);
                    self.shell_counters[c.shell as usize]
                        .placed_bytes
                        .fetch_sub(c.meta.kvc_len as u64, Ordering::Relaxed);
                }
                continue;
            };
            let Ok(meta) = self.stripe_payload(to, block, &payload, now_epoch, dst_center) else {
                continue;
            };
            // the old primary's surviving chunks stay behind otherwise,
            // squatting in `from`'s LRU stores (the same-layout path
            // physically drains them); no other copy lives on `from` by
            // now, so a by-block eviction there is safe
            self.evict_copy(&BlockCopy { shell: from, meta: entry.meta }, block, now_epoch);
            let mut index = self.index.lock().unwrap();
            let Some(e) = index.get_mut(&block) else { continue };
            e.shell = to;
            e.meta = meta;
            if e.replica.map(|r| r.shell) == Some(to) {
                // the replica slot collapsed onto the new home
                let r = e.replica.take().unwrap();
                self.shell_counters[to as usize]
                    .placed_bytes
                    .fetch_sub(r.meta.kvc_len as u64, Ordering::Relaxed);
            }
            if e.preplaced.map(|p| p.shell) == Some(to) {
                // so did the pre-placed copy
                let p = e.preplaced.take().unwrap();
                self.shell_counters[to as usize]
                    .placed_bytes
                    .fetch_sub(p.meta.kvc_len as u64, Ordering::Relaxed);
            }
            drop(index);
            rehomed += 1;
            chunks_moved += meta.num_chunks;
            bytes_moved += payload.len() as u64;
            self.shell_counters[from as usize]
                .placed_bytes
                .fetch_sub(entry.meta.kvc_len as u64, Ordering::Relaxed);
            self.shell_counters[to as usize]
                .placed_bytes
                .fetch_add(payload.len() as u64, Ordering::Relaxed);
        }
        self.stats.proactive_handover_blocks.fetch_add(rehomed, Ordering::Relaxed);
        self.transport.account_inter_shell(from, to, chunks_moved as u64, bytes_moved);
        EvacSummary { chunks_moved, bytes_moved, blocks_rehomed: rehomed }
    }

    /// Number of chunks a block of `n_values` f32s will produce.
    pub fn chunks_for_values(&self, n_values: usize) -> usize {
        self.config.chunks_for_values(n_values)
    }

    /// Tokens the federation index currently covers (`block_tokens`
    /// tokens per indexed block, copies not double-counted).
    pub fn cached_tokens(&self) -> u64 {
        self.indexed_blocks() as u64 * self.config.block_tokens as u64
    }

    /// Block copies resident per shell (primary + replica + pre-placed),
    /// in shell order — the per-shell residency signal of the memory
    /// plane.  One deterministic pass over the (sorted) index.
    pub fn shell_resident_copies(&self) -> Vec<u64> {
        let mut out = vec![0u64; self.transport.n_shells()];
        for (_, entry) in self.index.lock().unwrap().entries() {
            out[entry.shell as usize] += 1;
            if let Some(r) = entry.replica {
                out[r.shell as usize] += 1;
            }
            if let Some(p) = entry.preplaced {
                out[p.shell as usize] += 1;
            }
        }
        out
    }

    /// Store footprint of one shell: the rollup of every satellite chunk
    /// store in that shell's fleet.
    pub fn shell_store_footprint(&self, shell: ShellId) -> FootprintEstimate {
        let mut est = FootprintEstimate::ZERO;
        for node in self.transport.link(shell).fleet.nodes() {
            est.add(node.footprint());
        }
        est
    }

    /// Footprint of the federation-side bookkeeping: the two-layer block
    /// index (frozen arena + B-tree delta, reported with its
    /// frozen/delta split) plus the broken-block tombstone map.  B-tree
    /// nodes hold up to 11 entries, so the B-tree model charges one
    /// allocation per 11 plus two `usize` of node linkage per entry.
    pub fn index_footprint(&self) -> FootprintEstimate {
        fn btree_est(len: u64, entry: usize) -> FootprintEstimate {
            let slot = (entry + 2 * size_of::<usize>()) as u64;
            let mut est = FootprintEstimate {
                index_bytes: len * slot,
                ..FootprintEstimate::ZERO
            };
            est.charge_allocs(len.div_ceil(11));
            est
        }
        let mut est = self.index.lock().unwrap().mem_footprint();
        let tomb_len = self.tombstones.lock().unwrap().len() as u64;
        est.add(btree_est(tomb_len, size_of::<(BlockHash, ShellId)>()));
        est
    }
}

impl MemFootprint for FederatedKvcManager {
    /// Federation total: every shell's fleet-store rollup plus the
    /// federation-side index maps.
    fn mem_footprint(&self) -> FootprintEstimate {
        let mut est = self.index_footprint();
        for shell in 0..self.transport.n_shells() {
            est.add(self.shell_store_footprint(shell as ShellId));
        }
        est
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constellation::geometry::Geometry;
    use crate::constellation::los::LosGrid;
    use crate::constellation::topology::Torus;
    use crate::federation::transport::ShellLink;
    use crate::federation::Shell;
    use crate::kvc::block::block_hashes;
    use crate::kvc::eviction::EvictionPolicy;
    use crate::mapping::Strategy;
    use crate::net::faults::FaultyTransport;
    use crate::net::transport::{GroundView, InProcTransport, Transport};
    use crate::satellite::fleet::Fleet;
    use crate::util::rng::XorShift64;

    fn shell_link(id: ShellId, name: &str, planes: usize, slots: usize, alt: f64) -> ShellLink {
        let torus = Torus::new(planes, slots);
        let geometry = Geometry::new(alt, slots, planes);
        let shell = Shell::new(id, name, torus, geometry);
        let center = SatId::new((planes / 2) as u16, (slots / 2) as u16);
        let fleet = Arc::new(Fleet::new(torus, 10 << 20, EvictionPolicy::Lazy));
        let los = LosGrid::new(center, 2, (planes / 2).min(2));
        let ground = GroundView::new(center, &los, torus.sats_per_plane);
        let inproc = Arc::new(InProcTransport::new(fleet.clone(), ground, None));
        let faults =
            Arc::new(FaultyTransport::new(inproc.clone(), torus, los.half_slots, los.half_planes));
        ShellLink::new(shell, fleet, inproc, faults, 8)
    }

    /// Two small shells; the denser second one ("b-630") is cheaper and
    /// therefore primary.
    fn manager() -> FederatedKvcManager {
        let transport = Arc::new(FederatedTransport::new(vec![
            shell_link(0, "a-550", 9, 11, 550.0),
            shell_link(1, "b-630", 15, 15, 630.0),
        ]));
        let config = KvcConfig { n_servers: 9, chunk_size: 600, ..KvcConfig::default() };
        FederatedKvcManager::new(config, transport, PlacementPolicy::default())
    }

    /// Three shells with replication + pre-placement on: a-550 (second
    /// cheapest), b-630 (dense, primary), c-1200 (expensive polar
    /// stand-in running its *own* layout config).
    fn tri_manager(top_k: usize, preplace: bool) -> FederatedKvcManager {
        let transport = Arc::new(FederatedTransport::new(vec![
            shell_link(0, "a-550", 9, 11, 550.0),
            shell_link(1, "b-630", 15, 15, 630.0),
            shell_link(2, "c-1200", 9, 11, 1200.0),
        ]));
        let config = KvcConfig { n_servers: 9, chunk_size: 600, ..KvcConfig::default() };
        let layouts = vec![
            ShellLayoutConfig { strategy: config.strategy, n_servers: 9 },
            ShellLayoutConfig { strategy: config.strategy, n_servers: 9 },
            // the polar shell stripes differently: re-stripe paths apply
            ShellLayoutConfig { strategy: Strategy::RotationAware, n_servers: 9 },
        ];
        FederatedKvcManager::new_with(
            config,
            transport,
            PlacementPolicy::default(),
            ReplicationPolicy { top_k, min_accesses: 2 },
            preplace,
            layouts,
        )
    }

    fn values(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = XorShift64::new(seed);
        (0..n).map(|_| (rng.next_f64() as f32 - 0.5) * 4.0).collect()
    }

    fn kill_box(m: &FederatedKvcManager, shell: ShellId) {
        let link = m.transport().link(shell);
        let center = link.faults.closest();
        for dp in -1..=1 {
            for ds in -1..=1 {
                link.faults.fail_satellite(link.shell.torus.offset(center, dp, ds));
            }
        }
    }

    fn restore_box(m: &FederatedKvcManager, shell: ShellId) {
        let link = m.transport().link(shell);
        let center = link.faults.closest();
        for dp in -1..=1 {
            for ds in -1..=1 {
                link.faults.restore_satellite(link.shell.torus.offset(center, dp, ds));
            }
        }
    }

    #[test]
    fn put_then_fetch_roundtrip_on_primary() {
        let m = manager();
        let primary = m.primary_shell();
        assert_eq!(primary, 1, "the denser 15x15 shell should be cheapest");
        let tokens: Vec<i32> = (0..96).collect();
        let hashes = block_hashes(&tokens, 32);
        let kv = values(2048, 1);
        let home = m.put_block(&hashes, 0, &kv, 0).unwrap();
        assert_eq!(home, primary);
        assert_eq!(m.lookup(&hashes), 1);
        let fetched = m.fetch_block(&hashes, 0, 0).unwrap().unwrap();
        assert_eq!(fetched.len(), kv.len());
        let max_err =
            kv.iter().zip(&fetched).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
        assert!(max_err < 0.05, "max_err={max_err}");
        // idempotent: a second put keeps the home and stores nothing new
        let stored = m.shell_counters()[home as usize].blocks_stored.load(Ordering::Relaxed);
        assert_eq!(m.put_block(&hashes, 0, &kv, 0).unwrap(), home);
        assert_eq!(
            m.shell_counters()[home as usize].blocks_stored.load(Ordering::Relaxed),
            stored
        );
    }

    #[test]
    fn trace_records_race_arms_and_epoch_boundaries() {
        use crate::obs::Recorder;
        let m = tri_manager(4, false);
        let sink = Arc::new(Recorder::new());
        m.set_trace_sink(sink.clone());
        let tokens: Vec<i32> = (0..32).collect();
        let hashes = block_hashes(&tokens, 32);
        m.put_block(&hashes, 0, &values(2048, 5), 0).unwrap();
        for _ in 0..3 {
            assert!(m.fetch_block(&hashes, 0, 0).unwrap().is_some());
        }
        m.end_of_epoch(0);
        assert!(m.replica_of(&hashes[0]).is_some(), "hot block should replicate");
        assert!(m.fetch_block(&hashes, 0, 0).unwrap().is_some());
        let events = sink.take();
        let arms: Vec<_> = events.iter().filter(|e| e.name == "race_arm").collect();
        // three single-arm fetches, then one two-arm race post-replication
        assert_eq!(arms.len(), 5);
        let outcome = |e: &TraceEvent, want: &str| {
            e.args
                .iter()
                .any(|(k, v)| *k == "outcome" && matches!(v, ArgVal::S(s) if s == want))
        };
        assert_eq!(arms.iter().filter(|e| outcome(e, "win")).count(), 4);
        assert_eq!(arms.iter().filter(|e| outcome(e, "lose")).count(), 1);
        assert!(events.iter().any(|e| e.name == "end_of_epoch" && e.dur_ns == 0));
        // the shell schedulers ride the same sink, stamped per shell
        assert!(events.iter().any(|e| matches!(e.kind, SpanKind::Sched)));
    }

    #[test]
    fn prefix_lookup_spans_shells() {
        let m = manager();
        let tokens: Vec<i32> = (0..128).collect();
        let hashes = block_hashes(&tokens, 32);
        for b in 0..3 {
            m.put_block(&hashes, b, &values(2048, b as u64), 0).unwrap();
        }
        assert_eq!(m.lookup(&hashes), 3);
        assert_eq!(m.fetch_prefix(&hashes, 3, 0).unwrap(), 3);
        let mut tokens2 = tokens.clone();
        tokens2[40] = 999; // diverge inside block 1
        let hashes2 = block_hashes(&tokens2, 32);
        assert_eq!(m.lookup(&hashes2), 1);
    }

    #[test]
    fn dead_primary_box_spills_to_secondary() {
        let m = manager();
        let primary = m.primary_shell();
        let other = 1 - primary;
        kill_box(&m, primary);
        assert!(m.box_live_fraction(primary) < 0.2);
        let tokens: Vec<i32> = (0..32).collect();
        let hashes = block_hashes(&tokens, 32);
        let home = m.put_block(&hashes, 0, &values(2048, 3), 0).unwrap();
        assert_eq!(home, other, "placement must spill off the dead shell");
        assert_eq!(m.stats.spillovers.load(Ordering::Relaxed), 1);
        assert!(m.fetch_block(&hashes, 0, 0).unwrap().is_some());
    }

    #[test]
    fn rotation_migration_keeps_blocks_fetchable_per_shell() {
        let m = manager();
        let tokens: Vec<i32> = (0..32).collect();
        let hashes = block_hashes(&tokens, 32);
        let kv = values(2048, 9);
        let home = m.put_block(&hashes, 0, &kv, 0).unwrap();
        // run one epoch of migration on every shell, then advance
        let mut moved = 0;
        for s in 0..m.transport().n_shells() as ShellId {
            for (from, to) in m.migration_requests(s) {
                moved += m.transport().link(s).faults.migrate(from, to).unwrap();
            }
        }
        m.transport().set_epoch_all(1);
        assert!(moved > 0, "the east column should hand over chunks");
        let fetched = m.fetch_block(&hashes, 0, 1).unwrap().unwrap();
        assert_eq!(fetched.len(), kv.len());
        assert_eq!(m.home_of(&hashes[0]), Some(home));
    }

    #[test]
    fn evacuation_rehomes_and_keeps_blocks_fetchable() {
        let m = manager();
        let primary = m.primary_shell();
        let other = 1 - primary;
        let tokens: Vec<i32> = (0..96).collect();
        let hashes = block_hashes(&tokens, 32);
        for b in 0..3 {
            m.put_block(&hashes, b, &values(2048, b as u64), 0).unwrap();
        }
        let before = m.transport().link(other).fleet.total_chunks();
        let summary = m.evacuate_shell(primary, other, 0);
        assert_eq!(summary.blocks_rehomed, 3);
        assert!(summary.chunks_moved > 0);
        assert!(summary.bytes_moved > 0);
        assert!(m.transport().link(other).fleet.total_chunks() > before);
        assert_eq!(m.transport().link(primary).fleet.total_chunks(), 0);
        // now kill the evacuated shell entirely: data must still serve
        let link = m.transport().link(primary);
        for sat in link.shell.torus.all() {
            link.faults.fail_satellite(sat);
        }
        for b in 0..3 {
            assert_eq!(m.home_of(&hashes[b]), Some(other));
            assert!(m.fetch_block(&hashes, b, 0).unwrap().is_some(), "block {b}");
        }
        assert!(
            m.transport().stats.inter_shell_bytes.load(Ordering::Relaxed) >= summary.bytes_moved
        );
    }

    #[test]
    fn evacuation_survives_rotation_afterwards() {
        let m = manager();
        let primary = m.primary_shell();
        let other = 1 - primary;
        let tokens: Vec<i32> = (0..32).collect();
        let hashes = block_hashes(&tokens, 32);
        m.put_block(&hashes, 0, &values(2048, 7), 0).unwrap();
        m.evacuate_shell(primary, other, 0);
        // rotate two epochs with per-shell migration on the new home
        for e in 0..2u64 {
            for (from, to) in m.migration_requests(other) {
                m.transport().link(other).faults.migrate(from, to).unwrap();
            }
            m.transport().set_epoch_all(e + 1);
        }
        assert!(m.fetch_block(&hashes, 0, 2).unwrap().is_some());
    }

    #[test]
    fn broken_block_truncates_and_counts_reactive_rehome() {
        let m = manager();
        let tokens: Vec<i32> = (0..96).collect();
        let hashes = block_hashes(&tokens, 32);
        for b in 0..3 {
            m.put_block(&hashes, b, &values(2048, b as u64), 0).unwrap();
        }
        let home = m.home_of(&hashes[1]).unwrap();
        // sabotage block 1 everywhere on its home shell
        for node in m.transport().link(home).fleet.nodes() {
            let torus = m.transport().shell(home).torus;
            let env = crate::net::messages::Envelope::new(node.id, 0);
            node.handle(
                &torus,
                &env,
                &crate::net::messages::Request::Evict { block: hashes[1], gossip_ttl: 0 },
            );
        }
        assert_eq!(m.fetch_prefix(&hashes, 3, 0).unwrap(), 1, "prefix truncates");
        assert_eq!(m.stats.broken_blocks.load(Ordering::Relaxed), 1);
        assert_eq!(m.lookup(&hashes), 1, "broken block left the index");
        // re-store while the home shell's box is dead: reactive re-home
        kill_box(&m, home);
        let new_home = m.put_block(&hashes, 1, &values(2048, 1), 0).unwrap();
        assert_ne!(new_home, home);
        assert_eq!(m.stats.reactive_rehomed_blocks.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn hot_blocks_replicate_across_the_cheapest_pair() {
        let m = tri_manager(4, false);
        assert_eq!(m.primary_shell(), 1, "the dense 630 km shell is primary");
        let tokens: Vec<i32> = (0..96).collect();
        let hashes = block_hashes(&tokens, 32);
        for b in 0..3 {
            m.put_block(&hashes, b, &values(2048, b as u64), 0).unwrap();
        }
        // two served fetches per block clear min_accesses
        for _ in 0..2 {
            assert_eq!(m.fetch_prefix(&hashes, 3, 0).unwrap(), 3);
        }
        let (replicated, preplaced) = m.end_of_epoch(0);
        assert_eq!(replicated, 3, "every hot block gains a replica");
        assert_eq!(preplaced, 0, "pre-placement is off");
        assert_eq!(m.stats.replicated_blocks.load(Ordering::Relaxed), 3);
        for b in 0..3 {
            assert_eq!(m.home_of(&hashes[b]), Some(1));
            assert_eq!(m.replica_of(&hashes[b]), Some(0), "replica on the second-cheapest");
        }
        assert_eq!(m.shell_counters()[0].replicas_hosted.load(Ordering::Relaxed), 3);
        assert!(m.shell_counters()[0].placed_bytes.load(Ordering::Relaxed) > 0);
        assert!(m.transport().stats.inter_shell_bytes.load(Ordering::Relaxed) > 0);
        // replicas are idempotent across epochs
        let (again, _) = m.end_of_epoch(1);
        assert_eq!(again, 0);
        // fetches now race both copies; with a healthy home the home
        // still serves (virtual-time tie resolves to arm 0)
        assert!(m.fetch_block(&hashes, 0, 0).unwrap().is_some());
        assert!(m.stats.replica_races.load(Ordering::Relaxed) > 0);
        assert_eq!(m.stats.replica_race_wins.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn dead_home_race_serves_replica_and_promotes() {
        let m = tri_manager(4, false);
        let tokens: Vec<i32> = (0..32).collect();
        let hashes = block_hashes(&tokens, 32);
        let kv = values(2048, 5);
        let home = m.put_block(&hashes, 0, &kv, 0).unwrap();
        for _ in 0..2 {
            assert!(m.fetch_block(&hashes, 0, 0).unwrap().is_some());
        }
        m.end_of_epoch(0);
        let replica = m.replica_of(&hashes[0]).unwrap();
        assert_ne!(replica, home);
        // the home box goes dark: the race must serve the replica and
        // promote it to primary — no broken block, no truncation
        kill_box(&m, home);
        let fetched = m.fetch_block(&hashes, 0, 0).unwrap();
        assert!(fetched.is_some(), "the replica must serve");
        assert_eq!(fetched.unwrap().len(), kv.len());
        assert_eq!(m.stats.replica_race_wins.load(Ordering::Relaxed), 1);
        assert_eq!(m.stats.replica_promotions.load(Ordering::Relaxed), 1);
        assert_eq!(m.stats.broken_blocks.load(Ordering::Relaxed), 0);
        assert_eq!(m.home_of(&hashes[0]), Some(replica), "the replica is the new home");
        assert_eq!(m.replica_of(&hashes[0]), None, "the slot was consumed");
        assert!(m.shell_counters()[replica as usize].replica_hits.load(Ordering::Relaxed) >= 1);
        // and the promoted copy keeps serving
        assert!(m.fetch_block(&hashes, 0, 0).unwrap().is_some());
    }

    #[test]
    fn predictor_preplaces_next_rotation_and_serves_after_handover() {
        let m = tri_manager(4, true);
        // force the home off the primary: the primary's box is dark at
        // Set time, so placement spills to a-550
        kill_box(&m, 1);
        let tokens: Vec<i32> = (0..32).collect();
        let hashes = block_hashes(&tokens, 32);
        let home = m.put_block(&hashes, 0, &values(2048, 11), 0).unwrap();
        assert_eq!(home, 0);
        for _ in 0..2 {
            assert!(m.fetch_block(&hashes, 0, 0).unwrap().is_some());
        }
        // epoch 0 boundary: the replica goes to the cheapest live shell
        // that is not the home — the polar shell (b is dead), which runs
        // a different layout config (re-striped copy)
        let (replicated, preplaced) = m.end_of_epoch(0);
        assert_eq!(replicated, 1);
        assert_eq!(m.replica_of(&hashes[0]), Some(2));
        assert_eq!(preplaced, 0, "the predictor still picks the home shell");
        // one epoch of per-shell rotation (migration, then the views
        // move), exactly as the harness drives it
        let advance = |m: &FederatedKvcManager, to_epoch: u64| {
            for s in 0..m.transport().n_shells() as ShellId {
                for (from, to) in m.migration_requests(s) {
                    let _ = m.transport().link(s).faults.migrate(from, to);
                }
            }
            m.transport().set_epoch_all(to_epoch);
        };
        advance(&m, 1);
        // the primary heals: the predictor now forecasts b-630 eligible
        // (rising trend) and pre-places the next rotation's layout there
        restore_box(&m, 1);
        let (_, preplaced) = m.end_of_epoch(1);
        assert_eq!(preplaced, 1, "the §3.7 predictor pre-places on the healed primary");
        assert_eq!(m.stats.preplaced_blocks.load(Ordering::Relaxed), 1);
        assert_eq!(m.shell_counters()[1].preplaced_hosted.load(Ordering::Relaxed), 1);
        // advance the rotation; then lose both other copies — only the
        // pre-placed copy survives, resolves at its target epoch, serves,
        // and is promoted
        advance(&m, 2);
        kill_box(&m, 0);
        kill_box(&m, 2);
        let fetched = m.fetch_block(&hashes, 0, 2).unwrap();
        assert!(fetched.is_some(), "the pre-placed copy must serve after the handover");
        assert_eq!(m.stats.preplace_hits.load(Ordering::Relaxed), 1);
        assert_eq!(m.stats.replica_promotions.load(Ordering::Relaxed), 1);
        assert_eq!(m.home_of(&hashes[0]), Some(1));
    }

    #[test]
    fn restripe_evacuation_crosses_layout_configs() {
        let m = tri_manager(0, false);
        assert_ne!(
            m.shell_layout(0).strategy,
            m.shell_layout(2).strategy,
            "the polar shell runs its own strategy"
        );
        // home everything on a-550 (kill the primary first)
        kill_box(&m, 1);
        let tokens: Vec<i32> = (0..96).collect();
        let hashes = block_hashes(&tokens, 32);
        for b in 0..3 {
            assert_eq!(m.put_block(&hashes, b, &values(2048, b as u64), 0).unwrap(), 0);
        }
        // evacuating a -> c must re-stripe (configs differ) and keep
        // every block fetchable from the polar shell
        let summary = m.evacuate_shell(0, 2, 0);
        assert_eq!(summary.blocks_rehomed, 3);
        assert!(summary.chunks_moved > 0);
        assert!(summary.bytes_moved > 0);
        let link = m.transport().link(0);
        for sat in link.shell.torus.all() {
            link.faults.fail_satellite(sat);
        }
        for b in 0..3 {
            assert_eq!(m.home_of(&hashes[b]), Some(2));
            assert!(m.fetch_block(&hashes, b, 0).unwrap().is_some(), "block {b}");
        }
        assert_eq!(
            m.stats.proactive_handover_blocks.load(Ordering::Relaxed),
            3,
            "re-striping is still a proactive handover"
        );
        assert!(m.transport().stats.inter_shell_bytes.load(Ordering::Relaxed) > 0);
    }
}
