//! Multi-shell federation: several constellation shells at different
//! altitudes acting as one cache.
//!
//! Real deployments layer multiple Walker shells (Starlink's 550 km shell,
//! Kuiper's 630 km shell); the paper's protocol assumes one.  This module
//! federates the KVC across shells:
//!
//! * [`Shell`] — one named shell: an existing [`Torus`] + [`Geometry`] at
//!   its own altitude and shape.
//! * [`FedSatId`] — a shell-qualified satellite address
//!   (`{ShellId, SatId}`).
//! * [`FederatedConstellation`] — the shell set plus the two inter-shell
//!   link models: a ground relay (down from one shell, back up to the
//!   other) and a nearest-neighbour cross-shell hop (the closest satellite
//!   of the other shell is at most half a grid cell away horizontally and
//!   the altitude gap away vertically), both with altitude-correct
//!   latency from [`Geometry`].
//! * [`placement`] — the shell-aware policies: cost-based primary
//!   placement with spillover, per-shell layout configuration
//!   ([`placement::ShellLayoutConfig`]: each shell may run its own
//!   mapping strategy and stripe width), the hot-block
//!   [`placement::ReplicationPolicy`] (top-K blocks span the two
//!   cheapest shells, [`placement::cheapest_two`]), and the §3.7-style
//!   pre-placement predictor
//!   ([`placement::predict_preplacement_shell`]).
//! * [`transport`] — [`transport::FederatedTransport`]: routes Get/Set to
//!   the addressed shell (each shell keeps its own
//!   [`crate::net::faults::FaultyTransport`] decorator, so failure
//!   injection composes) and carries cross-shell chunk evacuations,
//!   replication and pre-placement traffic over the inter-shell links.
//! * [`manager`] — [`manager::FederatedKvcManager`]: the §3.8 Get/Set
//!   fan-out over shell-qualified layouts; reads race every copy of a
//!   replicated block via [`crate::net::sched::race_batches`] and a
//!   broken primary promotes its surviving replica; inter-shell handover
//!   (offset-preserving between identical layouts, re-striping between
//!   differing ones) moves hot chunks when a whole shell degrades.
//!
//! A federation holds any number of shells (N >= 1): single-shell runs
//! are the no-federation baseline, two shells reproduce PR 2's dual-shell
//! re-homing, and the `federated-tri-shell` scenario exercises the full
//! replicated three-shell stack under correlated failures.

pub mod manager;
pub mod placement;
pub mod transport;

use crate::constellation::geometry::{Geometry, LIGHT_SPEED_KM_S};
use crate::constellation::topology::{SatId, Torus};

/// Index of a shell within its federation (dense, assignment order).
pub type ShellId = u8;

/// One constellation shell of a federation.
#[derive(Debug, Clone)]
pub struct Shell {
    pub id: ShellId,
    pub name: String,
    pub torus: Torus,
    pub geometry: Geometry,
}

impl Shell {
    pub fn new(id: ShellId, name: &str, torus: Torus, geometry: Geometry) -> Self {
        assert_eq!(torus.planes, geometry.planes, "{name}: torus/geometry plane mismatch");
        assert_eq!(
            torus.sats_per_plane, geometry.sats_per_plane,
            "{name}: torus/geometry slot mismatch"
        );
        Self { id, name: name.to_string(), torus, geometry }
    }

    pub fn altitude_km(&self) -> f64 {
        self.geometry.altitude_km
    }
}

/// A shell-qualified satellite address.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FedSatId {
    pub shell: ShellId,
    pub sat: SatId,
}

impl FedSatId {
    pub fn new(shell: ShellId, sat: SatId) -> Self {
        Self { shell, sat }
    }
}

impl std::fmt::Display for FedSatId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "(sh{},p{},s{})", self.shell, self.sat.plane, self.sat.slot)
    }
}

/// A federation of constellation shells with its inter-shell link models.
///
/// A federation normally holds two or more shells; a single-shell
/// federation is allowed so no-federation baselines can run through the
/// same harness.
#[derive(Debug, Clone)]
pub struct FederatedConstellation {
    shells: Vec<Shell>,
    /// Serialization bandwidth of inter-shell links, bits/s.
    pub inter_shell_bandwidth_bps: f64,
}

impl FederatedConstellation {
    pub fn new(shells: Vec<Shell>) -> Self {
        assert!(!shells.is_empty(), "a federation needs at least one shell");
        for (i, s) in shells.iter().enumerate() {
            assert_eq!(s.id as usize, i, "shell ids must be dense and in order");
        }
        Self { shells, inter_shell_bandwidth_bps: 1e9 }
    }

    pub fn n_shells(&self) -> usize {
        self.shells.len()
    }

    pub fn shells(&self) -> &[Shell] {
        &self.shells
    }

    pub fn shell(&self, id: ShellId) -> &Shell {
        &self.shells[id as usize]
    }

    /// Total satellites across every shell.
    pub fn len(&self) -> usize {
        self.shells.iter().map(|s| s.torus.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    /// One-way latency of the ground-relay inter-shell link: down the
    /// slant range of shell `a`'s overhead satellite, back up to shell
    /// `b`'s (a bent pipe through the ground station).
    pub fn ground_relay_latency_s(&self, a: ShellId, b: ShellId) -> f64 {
        self.shell(a).geometry.ground_latency_s(0, 0)
            + self.shell(b).geometry.ground_latency_s(0, 0)
    }

    /// One-way latency of the nearest-neighbour cross-shell hop: the
    /// closest satellite of the other shell is at most half the coarser
    /// shell's grid spacing away horizontally and the altitude gap away
    /// vertically.
    pub fn cross_shell_hop_latency_s(&self, a: ShellId, b: ShellId) -> f64 {
        let (ga, gb) = (&self.shell(a).geometry, &self.shell(b).geometry);
        let d_alt = (ga.altitude_km - gb.altitude_km).abs();
        let spacing = ga
            .intra_plane_distance_km()
            .max(ga.inter_plane_distance_km())
            .max(gb.intra_plane_distance_km())
            .max(gb.inter_plane_distance_km());
        let horizontal = spacing / 2.0;
        (d_alt * d_alt + horizontal * horizontal).sqrt() / LIGHT_SPEED_KM_S
    }

    /// One-way inter-shell latency: the cheaper of ground relay and the
    /// direct cross-shell hop.
    pub fn inter_shell_latency_s(&self, a: ShellId, b: ShellId) -> f64 {
        self.ground_relay_latency_s(a, b).min(self.cross_shell_hop_latency_s(a, b))
    }

    /// One-way inter-shell transfer latency for `bytes` of payload.
    pub fn transfer_latency_s(&self, a: ShellId, b: ShellId, bytes: usize) -> f64 {
        self.inter_shell_latency_s(a, b) + (bytes as f64 * 8.0) / self.inter_shell_bandwidth_bps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dual() -> FederatedConstellation {
        FederatedConstellation::new(vec![
            Shell::new(0, "starlink-550", Torus::new(72, 22), Geometry::new(550.0, 22, 72)),
            Shell::new(1, "kuiper-630", Torus::new(34, 34), Geometry::new(630.0, 34, 34)),
        ])
    }

    #[test]
    fn federation_counts_every_shell() {
        let f = dual();
        assert_eq!(f.n_shells(), 2);
        assert_eq!(f.len(), 72 * 22 + 34 * 34);
        assert_eq!(f.shell(0).name, "starlink-550");
        assert_eq!(f.shell(1).altitude_km(), 630.0);
    }

    #[test]
    fn fed_sat_id_orders_by_shell_first() {
        let a = FedSatId::new(0, SatId::new(9, 9));
        let b = FedSatId::new(1, SatId::new(0, 0));
        assert!(a < b);
        assert_eq!(format!("{a}"), "(sh0,p9,s9)");
    }

    #[test]
    fn cross_shell_hop_beats_ground_relay_for_adjacent_shells() {
        // 550 vs 630 km: the 80 km vertical hop (plus half a cell of
        // horizontal offset) is shorter than going all the way down and
        // back up.
        let f = dual();
        let hop = f.cross_shell_hop_latency_s(0, 1);
        let relay = f.ground_relay_latency_s(0, 1);
        assert!(hop < relay, "hop {hop} vs relay {relay}");
        assert_eq!(f.inter_shell_latency_s(0, 1), hop);
        // both are in the LEO laser band (sub-10 ms)
        assert!(hop > 0.0 && hop < 10e-3);
        assert!(relay > 0.0 && relay < 10e-3);
    }

    #[test]
    fn inter_shell_latency_is_symmetric() {
        let f = dual();
        assert!((f.inter_shell_latency_s(0, 1) - f.inter_shell_latency_s(1, 0)).abs() < 1e-15);
        assert!(
            (f.transfer_latency_s(0, 1, 6000) - f.transfer_latency_s(1, 0, 6000)).abs() < 1e-15
        );
    }

    #[test]
    fn transfer_latency_grows_with_bytes() {
        let f = dual();
        assert!(f.transfer_latency_s(0, 1, 1 << 20) > f.transfer_latency_s(0, 1, 64));
    }

    #[test]
    fn single_shell_federation_allowed_for_baselines() {
        let f = FederatedConstellation::new(vec![Shell::new(
            0,
            "solo",
            Torus::new(5, 19),
            Geometry::new(550.0, 19, 5),
        )]);
        assert_eq!(f.n_shells(), 1);
    }
}
