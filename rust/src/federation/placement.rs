//! Shell-aware chunk placement.
//!
//! Each block's virtual servers go to one shell; the policy picks the
//! cheapest shell by uplink+hop cost and spills over when the primary
//! shell's layout box is saturated (byte budget) or failed (live fraction
//! of its box below threshold).  Costs are pure functions of a shell's
//! [`Geometry`] and the server count, so the primary shell of a federation
//! is a static property; eligibility is dynamic (failures, load).

use crate::constellation::geometry::Geometry;
use crate::federation::ShellId;
use crate::mapping::box_width;

/// Expected retrieval cost of hosting one block on a shell, seconds: the
/// round-trip slant uplink to the farthest cell of the layout box plus the
/// ISL hops a mesh entry would pay to the box edge.  Lower is better;
/// denser, lower shells win.
pub fn shell_cost(geometry: &Geometry, n_servers: usize) -> f64 {
    let half = box_width(n_servers) / 2;
    2.0 * geometry.ground_latency_s(half, half) + half as f64 * geometry.worst_hop_latency_s()
}

/// Index of the smallest cost, ties to the lowest index — the one argmin
/// every "primary shell" computation shares (spec, manager and policy
/// must all agree on which shell is primary).
pub fn cheapest_index(costs: &[f64]) -> Option<usize> {
    let mut best: Option<usize> = None;
    for (i, c) in costs.iter().enumerate() {
        let better = match best {
            None => true,
            Some(b) => *c < costs[b],
        };
        if better {
            best = Some(i);
        }
    }
    best
}

/// A shell's placement-relevant state at decision time.
#[derive(Debug, Clone, Copy)]
pub struct ShellCandidate {
    pub shell: ShellId,
    /// Static cost from [`shell_cost`].
    pub cost_s: f64,
    /// Fraction of the shell's current layout-box cells that are live.
    pub live_fraction: f64,
    /// Bytes this policy has already placed on the shell.
    pub placed_bytes: u64,
}

/// The spillover policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlacementPolicy {
    /// A shell is eligible only while at least this fraction of its layout
    /// box is live.
    pub min_live_fraction: f64,
    /// Soft per-shell byte budget; above it, placement spills to the next
    /// cheapest shell (0 = unlimited).
    pub spill_budget_bytes: u64,
}

impl Default for PlacementPolicy {
    fn default() -> Self {
        Self { min_live_fraction: 0.6, spill_budget_bytes: 0 }
    }
}

impl PlacementPolicy {
    fn alive(&self, c: &ShellCandidate) -> bool {
        c.live_fraction >= self.min_live_fraction
    }

    fn under_budget(&self, c: &ShellCandidate) -> bool {
        self.spill_budget_bytes == 0 || c.placed_bytes < self.spill_budget_bytes
    }

    /// Pick the index of the shell to place the next block on:
    /// cheapest-first among live, under-budget shells; then live shells
    /// regardless of budget; then (best effort) the most-live shell.
    /// Deterministic: ties resolve to the lowest index.
    pub fn choose(&self, candidates: &[ShellCandidate]) -> Option<usize> {
        if candidates.is_empty() {
            return None;
        }
        let argmin_cost = |keep: &dyn Fn(&ShellCandidate) -> bool| -> Option<usize> {
            let mut best: Option<usize> = None;
            for (i, c) in candidates.iter().enumerate() {
                if !keep(c) {
                    continue;
                }
                let better = match best {
                    None => true,
                    Some(b) => c.cost_s < candidates[b].cost_s,
                };
                if better {
                    best = Some(i);
                }
            }
            best
        };
        argmin_cost(&|c| self.alive(c) && self.under_budget(c))
            .or_else(|| argmin_cost(&|c| self.alive(c)))
            .or_else(|| {
                let mut best = 0;
                for (i, c) in candidates.iter().enumerate().skip(1) {
                    if c.live_fraction > candidates[best].live_fraction {
                        best = i;
                    }
                }
                Some(best)
            })
    }

    /// The index the policy would pick ignoring liveness and budget: the
    /// federation's static primary shell.
    pub fn primary(&self, candidates: &[ShellCandidate]) -> Option<usize> {
        let costs: Vec<f64> = candidates.iter().map(|c| c.cost_s).collect();
        cheapest_index(&costs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(shell: ShellId, cost_s: f64, live_fraction: f64, placed_bytes: u64) -> ShellCandidate {
        ShellCandidate { shell, cost_s, live_fraction, placed_bytes }
    }

    #[test]
    fn cheapest_live_shell_wins() {
        let p = PlacementPolicy::default();
        let c = [cand(0, 0.020, 1.0, 0), cand(1, 0.017, 1.0, 0)];
        assert_eq!(p.choose(&c), Some(1));
        assert_eq!(p.primary(&c), Some(1));
    }

    #[test]
    fn failed_primary_spills_to_secondary() {
        let p = PlacementPolicy::default();
        let c = [cand(0, 0.020, 1.0, 0), cand(1, 0.017, 0.0, 0)];
        assert_eq!(p.choose(&c), Some(0), "dead box disqualifies the cheap shell");
        assert_eq!(p.primary(&c), Some(1), "primary is a static property");
    }

    #[test]
    fn saturated_primary_spills_then_relaxes() {
        let p = PlacementPolicy { spill_budget_bytes: 1000, ..Default::default() };
        let over = [cand(0, 0.020, 1.0, 0), cand(1, 0.017, 1.0, 1000)];
        assert_eq!(p.choose(&over), Some(0), "over-budget primary spills");
        // every shell over budget: budget relaxes, liveness still binds
        let all_over = [cand(0, 0.020, 1.0, 2000), cand(1, 0.017, 1.0, 1000)];
        assert_eq!(p.choose(&all_over), Some(1));
    }

    #[test]
    fn best_effort_when_everything_is_degraded() {
        let p = PlacementPolicy::default();
        let c = [cand(0, 0.020, 0.2, 0), cand(1, 0.017, 0.4, 0)];
        assert_eq!(p.choose(&c), Some(1), "most-live shell as last resort");
        assert_eq!(p.choose(&[]), None);
    }

    #[test]
    fn cheapest_index_breaks_ties_low() {
        assert_eq!(cheapest_index(&[]), None);
        assert_eq!(cheapest_index(&[0.3]), Some(0));
        assert_eq!(cheapest_index(&[0.3, 0.1, 0.2]), Some(1));
        assert_eq!(cheapest_index(&[0.2, 0.1, 0.1]), Some(1), "ties resolve low");
    }

    #[test]
    fn denser_lower_shell_is_cheaper() {
        use crate::constellation::geometry::Geometry;
        // Kuiper's 34-sat planes have shorter chords than Starlink's
        // 22-sat planes, which dominates the 80 km altitude advantage.
        let starlink = Geometry::new(550.0, 22, 72);
        let kuiper = Geometry::new(630.0, 34, 34);
        assert!(shell_cost(&kuiper, 9) < shell_cost(&starlink, 9));
        // more servers -> a wider box -> strictly higher cost
        assert!(shell_cost(&kuiper, 25) > shell_cost(&kuiper, 9));
    }
}
