//! Shell-aware chunk placement, replication and pre-placement policy.
//!
//! Each block's *primary* copy goes to one shell; the policy picks the
//! cheapest shell by uplink+hop cost and spills over when the primary
//! shell's layout box is saturated (byte budget) or failed (live fraction
//! of its box below threshold).  Costs are pure functions of a shell's
//! [`Geometry`] and the shell's own stripe width
//! ([`ShellLayoutConfig::n_servers`]), so the primary shell of a
//! federation is a static property; eligibility is dynamic (failures,
//! load).
//!
//! On top of single-copy placement this module carries the two policies
//! the N-shell federation adds:
//!
//! * [`ReplicationPolicy`] — the top-K hottest blocks (by access count)
//!   keep a live replica so the block's copies span the **two cheapest
//!   shells** ([`cheapest_two`]); reads race the copies over
//!   [`crate::net::sched::race_batches`] and writes fan out
//!   invalidations to every copy.
//! * [`predict_preplacement_shell`] — the §3.7-style predictor: instead
//!   of reacting to broken fetches after a shell degrades, each epoch
//!   extrapolates every shell's layout-box live fraction one rotation
//!   ahead and pre-places the next rotation's layout of the hot blocks
//!   on the shell predicted to be cheapest *and still eligible*.

use crate::constellation::geometry::Geometry;
use crate::federation::ShellId;
use crate::mapping::{box_width, Strategy};

/// Expected retrieval cost of hosting one block on a shell, seconds: the
/// round-trip slant uplink to the farthest cell of the layout box plus the
/// ISL hops a mesh entry would pay to the box edge.  Lower is better;
/// denser, lower shells win.
pub fn shell_cost(geometry: &Geometry, n_servers: usize) -> f64 {
    let half = box_width(n_servers) / 2;
    2.0 * geometry.ground_latency_s(half, half) + half as f64 * geometry.worst_hop_latency_s()
}

/// Index of the smallest cost, ties to the lowest index — the one argmin
/// every "primary shell" computation shares (spec, manager and policy
/// must all agree on which shell is primary).
pub fn cheapest_index(costs: &[f64]) -> Option<usize> {
    let mut best: Option<usize> = None;
    for (i, c) in costs.iter().enumerate() {
        let better = match best {
            None => true,
            Some(b) => *c < costs[b],
        };
        if better {
            best = Some(i);
        }
    }
    best
}

/// Per-shell layout configuration: which mapping strategy a shell
/// stripes over and how many virtual servers it uses.  Shells of one
/// federation may differ (a sparse polar shell can run a narrower stripe
/// than a dense mega-shell); chunk `i` of a block homed on a shell goes
/// to `layout[i % n_servers]` of *that shell's* layout.  Cross-shell
/// evacuation between shells with identical configs preserves relative
/// box offsets (the cheap path); between differing configs the
/// federation manager re-stripes block by block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShellLayoutConfig {
    pub strategy: Strategy,
    pub n_servers: usize,
}

/// The hot-block replication policy.
///
/// Access counts accumulate per block; at each epoch boundary the
/// federation manager replicates the `top_k` hottest blocks (ties broken
/// by block hash, so the selection is deterministic) onto the cheapest
/// live shell that does not already hold a copy — after which the
/// block's copies span the two cheapest shells.  `top_k == 0` disables
/// replication (the re-homing-only baseline).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReplicationPolicy {
    /// Replicate the K hottest blocks (0 = replication off).
    pub top_k: usize,
    /// Accesses a block needs before it is replica-eligible (keeps
    /// one-shot scan traffic out of the replica set).
    pub min_accesses: u64,
}

impl Default for ReplicationPolicy {
    fn default() -> Self {
        Self { top_k: 0, min_accesses: 2 }
    }
}

impl ReplicationPolicy {
    pub fn enabled(&self) -> bool {
        self.top_k > 0
    }
}

/// A shell's placement-relevant state at decision time.
#[derive(Debug, Clone, Copy)]
pub struct ShellCandidate {
    pub shell: ShellId,
    /// Static cost from [`shell_cost`].
    pub cost_s: f64,
    /// Fraction of the shell's current layout-box cells that are live.
    pub live_fraction: f64,
    /// Bytes this policy has already placed on the shell.
    pub placed_bytes: u64,
}

/// The spillover policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlacementPolicy {
    /// A shell is eligible only while at least this fraction of its layout
    /// box is live.
    pub min_live_fraction: f64,
    /// Soft per-shell byte budget; above it, placement spills to the next
    /// cheapest shell (0 = unlimited).
    pub spill_budget_bytes: u64,
}

impl Default for PlacementPolicy {
    fn default() -> Self {
        Self { min_live_fraction: 0.6, spill_budget_bytes: 0 }
    }
}

impl PlacementPolicy {
    fn alive(&self, c: &ShellCandidate) -> bool {
        c.live_fraction >= self.min_live_fraction
    }

    fn under_budget(&self, c: &ShellCandidate) -> bool {
        self.spill_budget_bytes == 0 || c.placed_bytes < self.spill_budget_bytes
    }

    /// Pick the index of the shell to place the next block on:
    /// cheapest-first among live, under-budget shells; then live shells
    /// regardless of budget; then (best effort) the most-live shell.
    /// Deterministic: ties resolve to the lowest index.
    pub fn choose(&self, candidates: &[ShellCandidate]) -> Option<usize> {
        if candidates.is_empty() {
            return None;
        }
        let argmin_cost = |keep: &dyn Fn(&ShellCandidate) -> bool| -> Option<usize> {
            let mut best: Option<usize> = None;
            for (i, c) in candidates.iter().enumerate() {
                if !keep(c) {
                    continue;
                }
                let better = match best {
                    None => true,
                    Some(b) => c.cost_s < candidates[b].cost_s,
                };
                if better {
                    best = Some(i);
                }
            }
            best
        };
        argmin_cost(&|c| self.alive(c) && self.under_budget(c))
            .or_else(|| argmin_cost(&|c| self.alive(c)))
            .or_else(|| {
                let mut best = 0;
                for (i, c) in candidates.iter().enumerate().skip(1) {
                    if c.live_fraction > candidates[best].live_fraction {
                        best = i;
                    }
                }
                Some(best)
            })
    }

    /// The index the policy would pick ignoring liveness and budget: the
    /// federation's static primary shell.
    pub fn primary(&self, candidates: &[ShellCandidate]) -> Option<usize> {
        let costs: Vec<f64> = candidates.iter().map(|c| c.cost_s).collect();
        cheapest_index(&costs)
    }
}

/// Indices of the two cheapest *live* candidates, cheapest first.
/// Ties resolve to the lowest index; returns fewer than two when the
/// federation is smaller or degraded (a dead shell is never a replica
/// target).  This is the replica span of [`ReplicationPolicy`]: a
/// replicated block's copies live on exactly these shells when both are
/// healthy.
pub fn cheapest_two(candidates: &[ShellCandidate], min_live_fraction: f64) -> Vec<usize> {
    let mut live: Vec<usize> = (0..candidates.len())
        .filter(|&i| candidates[i].live_fraction >= min_live_fraction)
        .collect();
    // stable selection: by (cost, index), so equal costs keep index order
    live.sort_by(|&a, &b| {
        candidates[a]
            .cost_s
            .partial_cmp(&candidates[b].cost_s)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    live.truncate(2);
    live
}

/// The §3.7-style pre-placement predictor.
///
/// Extrapolates each shell's layout-box live fraction one rotation epoch
/// ahead with a linear trend (`predicted = live + (live - prev_live)`,
/// clamped to `[0, 1]`) and picks the cheapest shell whose *predicted*
/// live fraction still clears the placement threshold — so a shell that
/// is eligible today but visibly degrading is skipped before its fetches
/// start breaking.  Falls back to the shell with the best predicted live
/// fraction when no shell clears the threshold.  Deterministic: a pure
/// function of its inputs, ties to the lowest index.
pub fn predict_preplacement_shell(
    candidates: &[ShellCandidate],
    prev_live: &[f64],
    min_live_fraction: f64,
) -> Option<usize> {
    assert_eq!(candidates.len(), prev_live.len(), "one trend point per shell");
    if candidates.is_empty() {
        return None;
    }
    let predicted: Vec<f64> = candidates
        .iter()
        .zip(prev_live)
        .map(|(c, prev)| (2.0 * c.live_fraction - prev).clamp(0.0, 1.0))
        .collect();
    let mut best: Option<usize> = None;
    for (i, c) in candidates.iter().enumerate() {
        if predicted[i] < min_live_fraction {
            continue;
        }
        let better = match best {
            None => true,
            Some(b) => c.cost_s < candidates[b].cost_s,
        };
        if better {
            best = Some(i);
        }
    }
    best.or_else(|| {
        let mut b = 0;
        for i in 1..predicted.len() {
            if predicted[i] > predicted[b] {
                b = i;
            }
        }
        Some(b)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(shell: ShellId, cost_s: f64, live_fraction: f64, placed_bytes: u64) -> ShellCandidate {
        ShellCandidate { shell, cost_s, live_fraction, placed_bytes }
    }

    #[test]
    fn cheapest_live_shell_wins() {
        let p = PlacementPolicy::default();
        let c = [cand(0, 0.020, 1.0, 0), cand(1, 0.017, 1.0, 0)];
        assert_eq!(p.choose(&c), Some(1));
        assert_eq!(p.primary(&c), Some(1));
    }

    #[test]
    fn failed_primary_spills_to_secondary() {
        let p = PlacementPolicy::default();
        let c = [cand(0, 0.020, 1.0, 0), cand(1, 0.017, 0.0, 0)];
        assert_eq!(p.choose(&c), Some(0), "dead box disqualifies the cheap shell");
        assert_eq!(p.primary(&c), Some(1), "primary is a static property");
    }

    #[test]
    fn saturated_primary_spills_then_relaxes() {
        let p = PlacementPolicy { spill_budget_bytes: 1000, ..Default::default() };
        let over = [cand(0, 0.020, 1.0, 0), cand(1, 0.017, 1.0, 1000)];
        assert_eq!(p.choose(&over), Some(0), "over-budget primary spills");
        // every shell over budget: budget relaxes, liveness still binds
        let all_over = [cand(0, 0.020, 1.0, 2000), cand(1, 0.017, 1.0, 1000)];
        assert_eq!(p.choose(&all_over), Some(1));
    }

    #[test]
    fn best_effort_when_everything_is_degraded() {
        let p = PlacementPolicy::default();
        let c = [cand(0, 0.020, 0.2, 0), cand(1, 0.017, 0.4, 0)];
        assert_eq!(p.choose(&c), Some(1), "most-live shell as last resort");
        assert_eq!(p.choose(&[]), None);
    }

    #[test]
    fn cheapest_index_breaks_ties_low() {
        assert_eq!(cheapest_index(&[]), None);
        assert_eq!(cheapest_index(&[0.3]), Some(0));
        assert_eq!(cheapest_index(&[0.3, 0.1, 0.2]), Some(1));
        assert_eq!(cheapest_index(&[0.2, 0.1, 0.1]), Some(1), "ties resolve low");
    }

    #[test]
    fn cheapest_two_spans_the_two_cheapest_live_shells() {
        let c = [
            cand(0, 0.020, 1.0, 0),
            cand(1, 0.017, 1.0, 0),
            cand(2, 0.031, 1.0, 0),
        ];
        assert_eq!(cheapest_two(&c, 0.6), vec![1, 0], "cheapest first");
        // a dead shell is never a replica target: the expensive polar
        // shell steps in
        let degraded = [
            cand(0, 0.020, 0.1, 0),
            cand(1, 0.017, 1.0, 0),
            cand(2, 0.031, 1.0, 0),
        ];
        assert_eq!(cheapest_two(&degraded, 0.6), vec![1, 2]);
        // a single live shell yields a single-slot span; none yields none
        assert_eq!(cheapest_two(&[cand(0, 0.02, 1.0, 0)], 0.6), vec![0]);
        assert_eq!(cheapest_two(&[cand(0, 0.02, 0.0, 0)], 0.6), Vec::<usize>::new());
        // cost ties keep index order
        let tied = [cand(0, 0.017, 1.0, 0), cand(1, 0.017, 1.0, 0), cand(2, 0.017, 1.0, 0)];
        assert_eq!(cheapest_two(&tied, 0.6), vec![0, 1]);
    }

    #[test]
    fn saturated_cheapest_pair_still_spills_for_placement() {
        // replication span and placement spillover are independent: the
        // span ignores byte budgets (a replica is worth hosting on a
        // full shell), while placement spills off an over-budget shell
        let p = PlacementPolicy { spill_budget_bytes: 1000, ..Default::default() };
        let c = [
            cand(0, 0.020, 1.0, 0),
            cand(1, 0.017, 1.0, 2000),
            cand(2, 0.031, 1.0, 0),
        ];
        assert_eq!(p.choose(&c), Some(0), "placement spills off the saturated primary");
        assert_eq!(cheapest_two(&c, 0.6), vec![1, 0], "the span does not");
    }

    #[test]
    fn predictor_is_deterministic_and_trend_aware() {
        // stable federation: the cheapest shell is predicted to stay
        // eligible, so it is picked — repeatably
        let stable = [cand(0, 0.020, 1.0, 0), cand(1, 0.017, 1.0, 0)];
        let pick = predict_preplacement_shell(&stable, &[1.0, 1.0], 0.6);
        assert_eq!(pick, Some(1));
        assert_eq!(pick, predict_preplacement_shell(&stable, &[1.0, 1.0], 0.6));
        // the cheap shell is still eligible *today* (0.7 >= 0.6) but the
        // trend 1.0 -> 0.7 extrapolates to 0.4 next epoch: the predictor
        // moves pre-placement off it before fetches break
        let degrading = [cand(0, 0.020, 1.0, 0), cand(1, 0.017, 0.7, 0)];
        assert_eq!(predict_preplacement_shell(&degrading, &[1.0, 1.0], 0.6), Some(0));
        // everything predicted dead: best-effort falls back to the best
        // predicted live fraction, ties to the lowest index
        let grim = [cand(0, 0.020, 0.3, 0), cand(1, 0.017, 0.2, 0)];
        assert_eq!(predict_preplacement_shell(&grim, &[0.3, 0.2], 0.6), Some(0));
        assert_eq!(predict_preplacement_shell(&[], &[], 0.6), None);
    }

    #[test]
    fn replication_policy_default_is_off() {
        let r = ReplicationPolicy::default();
        assert!(!r.enabled());
        assert!(ReplicationPolicy { top_k: 4, min_accesses: 2 }.enabled());
    }

    #[test]
    fn denser_lower_shell_is_cheaper() {
        use crate::constellation::geometry::Geometry;
        // Kuiper's 34-sat planes have shorter chords than Starlink's
        // 22-sat planes, which dominates the 80 km altitude advantage.
        let starlink = Geometry::new(550.0, 22, 72);
        let kuiper = Geometry::new(630.0, 34, 34);
        assert!(shell_cost(&kuiper, 9) < shell_cost(&starlink, 9));
        // more servers -> a wider box -> strictly higher cost
        assert!(shell_cost(&kuiper, 25) > shell_cost(&kuiper, 9));
    }
}
