//! Shell-qualified transport: routes Get/Set to the addressed shell and
//! carries cross-shell chunk evacuations.
//!
//! Each shell keeps its whole single-shell stack — a
//! [`Fleet`], an [`InProcTransport`] with its own rotating
//! [`crate::net::transport::GroundView`], and a
//! [`crate::net::faults::FaultyTransport`] decorator — so failure
//! injection composes per shell: killing one shell's satellites blackholes
//! only that shell's traffic, and the federation layer above decides where
//! to re-home the affected chunks.
//!
//! Intra-shell requests pay the shell's own (accounted) link latency;
//! cross-shell transfers additionally pay the federation's inter-shell
//! link latency ([`FederatedConstellation::transfer_latency_s`]) into
//! `inter_shell_latency_ns`.

use crate::federation::{FedSatId, FederatedConstellation, Shell, ShellId};
use crate::kvc::block::BlockHash;
use crate::kvc::chunk::ChunkKey;
use crate::net::faults::FaultyTransport;
use crate::net::messages::{Request, Response};
use crate::net::sched::{ChunkOp, ChunkResult, NetScheduler, SchedConfig, Transfer};
use crate::net::transport::{InProcTransport, Transport};
use crate::satellite::fleet::Fleet;
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Counters of inter-shell activity.
#[derive(Debug, Default)]
pub struct FedTransportStats {
    /// Chunk transfers carried over inter-shell links.
    pub inter_shell_chunks: AtomicU64,
    /// Payload bytes carried over inter-shell links.
    pub inter_shell_bytes: AtomicU64,
    /// Accounted inter-shell link latency (ns), never slept.
    pub inter_shell_latency_ns: AtomicU64,
}

/// One shell's full single-shell stack, plus its timing-plane scheduler
/// (all chunk fan-out and evacuation traffic into this shell rides the
/// shell's own [`NetScheduler`] over its fault-decorated transport).
pub struct ShellLink {
    pub shell: Shell,
    pub fleet: Arc<Fleet>,
    pub inproc: Arc<InProcTransport>,
    pub faults: Arc<FaultyTransport>,
    pub sched: Arc<NetScheduler>,
}

impl ShellLink {
    /// Assemble one shell's stack; `window` is the per-link in-flight
    /// window of the shell's virtual-time scheduler.
    pub fn new(
        shell: Shell,
        fleet: Arc<Fleet>,
        inproc: Arc<InProcTransport>,
        faults: Arc<FaultyTransport>,
        window: usize,
    ) -> Self {
        let transport: Arc<dyn Transport> = faults.clone();
        let sched = Arc::new(NetScheduler::new(transport, SchedConfig { window }));
        Self { shell, fleet, inproc, faults, sched }
    }
}

/// The federation-wide transport.
pub struct FederatedTransport {
    constellation: FederatedConstellation,
    links: Vec<ShellLink>,
    pub stats: FedTransportStats,
}

impl FederatedTransport {
    pub fn new(links: Vec<ShellLink>) -> Self {
        let constellation =
            FederatedConstellation::new(links.iter().map(|l| l.shell.clone()).collect());
        Self { constellation, links, stats: FedTransportStats::default() }
    }

    pub fn constellation(&self) -> &FederatedConstellation {
        &self.constellation
    }

    pub fn n_shells(&self) -> usize {
        self.links.len()
    }

    pub fn shell(&self, id: ShellId) -> &Shell {
        &self.links[id as usize].shell
    }

    pub fn link(&self, id: ShellId) -> &ShellLink {
        &self.links[id as usize]
    }

    pub fn links(&self) -> &[ShellLink] {
        &self.links
    }

    /// The satellite of `shell` currently closest to the ground host.
    pub fn closest(&self, shell: ShellId) -> crate::constellation::topology::SatId {
        self.links[shell as usize].faults.closest()
    }

    /// Advance every shell's ground view to `epoch` (the shells rotate in
    /// lockstep: one slot-shift per epoch each).
    pub fn set_epoch_all(&self, epoch: u64) {
        for l in &self.links {
            l.faults.set_epoch(epoch);
        }
    }

    /// Total accounted network latency across the federation: every
    /// shell's serially-emulated link time, every shell scheduler's
    /// pipelined virtual time, and the inter-shell links.
    pub fn total_latency_ns(&self) -> u64 {
        let intra: u64 = self
            .links
            .iter()
            .map(|l| {
                l.inproc.stats().sim_latency_ns.load(Ordering::Relaxed)
                    + l.sched.stats.virtual_ns.load(Ordering::Relaxed)
            })
            .sum();
        intra + self.stats.inter_shell_latency_ns.load(Ordering::Relaxed)
    }

    /// Requests blackholed by fault injection, summed over every shell.
    pub fn total_blackholed(&self) -> u64 {
        self.links.iter().map(|l| l.faults.fault_stats.blackholed()).sum()
    }

    fn checked_link(&self, shell: ShellId) -> Result<&ShellLink> {
        self.links
            .get(shell as usize)
            .ok_or_else(|| anyhow::anyhow!("no such shell {shell}"))
    }

    /// Route a request to the addressed shell's (fault-decorated) stack.
    pub fn request(&self, dest: FedSatId, req: Request) -> Result<Response> {
        self.checked_link(dest.shell)?.faults.request(dest.sat, req)
    }

    // Shell-qualified conveniences, delegating to the addressed shell's
    // [`Transport`] so response handling and the per-shell stats (miss
    // counters, emulated latency) stay identical to the single-shell path.

    pub fn get_chunk(&self, dest: FedSatId, key: ChunkKey) -> Result<Option<Vec<u8>>> {
        self.checked_link(dest.shell)?.faults.get_chunk(dest.sat, key)
    }

    pub fn set_chunk(&self, dest: FedSatId, key: ChunkKey, payload: Vec<u8>) -> Result<()> {
        self.checked_link(dest.shell)?.faults.set_chunk(dest.sat, key, payload)
    }

    pub fn evict_block(&self, dest: FedSatId, block: BlockHash) -> Result<u32> {
        self.checked_link(dest.shell)?.faults.evict_block(dest.sat, block, 0)
    }

    /// Account `chunks`/`bytes` of cross-shell payload that rode the
    /// inter-shell link from `from` to `to`.  Replication, pre-placement
    /// and re-striping evacuation use this: their chunk Sets ride the
    /// target shell's scheduler like any other fan-out, and this charges
    /// the inter-shell leg on top.
    pub fn account_inter_shell(&self, from: ShellId, to: ShellId, chunks: u64, bytes: u64) {
        if chunks == 0 {
            return;
        }
        self.stats.inter_shell_chunks.fetch_add(chunks, Ordering::Relaxed);
        self.stats.inter_shell_bytes.fetch_add(bytes, Ordering::Relaxed);
        let s = self.constellation.transfer_latency_s(from, to, bytes as usize);
        self.stats.inter_shell_latency_ns.fetch_add((s * 1e9) as u64, Ordering::Relaxed);
    }

    /// Evacuate one satellite's entire chunk store across shells: drain
    /// the source node and re-Set everything (original keys and headers)
    /// on the target satellite of the other shell, over the inter-shell
    /// link.  The drain is key-sorted and submitted as one virtual-time
    /// batch on the *target* shell's scheduler, so evacuation traffic
    /// pipelines over the target's link windows like any other fan-out.
    /// Returns (chunks moved, payload bytes moved); chunks the target
    /// rejects are dropped (the block they belong to heals reactively).
    pub fn migrate_cross_shell(&self, from: FedSatId, to: FedSatId) -> (u32, u64) {
        debug_assert_ne!(from.shell, to.shell, "cross-shell migrate needs two shells");
        let chunks = self.links[from.shell as usize].fleet.node(from.sat).drain_chunks();
        let lens: Vec<usize> = chunks.iter().map(|(_, payload)| payload.len()).collect();
        let transfers: Vec<Transfer> = chunks
            .into_iter()
            .enumerate()
            .map(|(i, (key, data))| {
                Transfer { tag: i as u64, op: ChunkOp::Set { dest: to.sat, key, data } }
            })
            .collect();
        let batch = self.links[to.shell as usize].sched.run_batch(transfers);
        let mut moved = 0u32;
        let mut bytes = 0u64;
        for o in &batch.outcomes {
            if o.result == ChunkResult::Stored {
                moved += 1;
                bytes += lens[o.tag as usize] as u64;
            }
        }
        self.account_inter_shell(from.shell, to.shell, moved as u64, bytes);
        (moved, bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constellation::geometry::Geometry;
    use crate::constellation::los::LosGrid;
    use crate::constellation::topology::{SatId, Torus};
    use crate::kvc::eviction::EvictionPolicy;
    use crate::net::transport::GroundView;

    fn shell_link(id: ShellId, name: &str, planes: usize, slots: usize, alt: f64) -> ShellLink {
        let torus = Torus::new(planes, slots);
        let geometry = Geometry::new(alt, slots, planes);
        let shell = Shell::new(id, name, torus, geometry);
        let center = SatId::new((planes / 2) as u16, (slots / 2) as u16);
        let fleet = Arc::new(Fleet::new(torus, 1 << 20, EvictionPolicy::Lazy));
        let los = LosGrid::new(center, 2, (planes / 2).min(2));
        let ground = GroundView::new(center, &los, torus.sats_per_plane);
        let inproc = Arc::new(InProcTransport::new(fleet.clone(), ground, None));
        let faults =
            Arc::new(FaultyTransport::new(inproc.clone(), torus, los.half_slots, los.half_planes));
        ShellLink::new(shell, fleet, inproc, faults, 8)
    }

    fn dual() -> FederatedTransport {
        FederatedTransport::new(vec![
            shell_link(0, "a-550", 9, 11, 550.0),
            shell_link(1, "b-630", 7, 9, 630.0),
        ])
    }

    fn key(b: u8, c: u32) -> ChunkKey {
        ChunkKey::new(BlockHash([b; 32]), c)
    }

    #[test]
    fn requests_route_to_the_addressed_shell() {
        let t = dual();
        let d0 = FedSatId::new(0, SatId::new(4, 5));
        let d1 = FedSatId::new(1, SatId::new(3, 4));
        t.set_chunk(d0, key(1, 0), vec![1, 2]).unwrap();
        t.set_chunk(d1, key(1, 0), vec![9, 9, 9]).unwrap();
        // same key, different shells: independent stores
        assert_eq!(t.get_chunk(d0, key(1, 0)).unwrap(), Some(vec![1, 2]));
        assert_eq!(t.get_chunk(d1, key(1, 0)).unwrap(), Some(vec![9, 9, 9]));
        assert_eq!(t.link(0).fleet.total_chunks(), 1);
        assert_eq!(t.link(1).fleet.total_chunks(), 1);
        assert!(t.request(FedSatId::new(7, SatId::new(0, 0)), Request::Ping).is_err());
    }

    #[test]
    fn shell_faults_stay_per_shell() {
        let t = dual();
        let sat = SatId::new(4, 5);
        t.link(0).faults.fail_satellite(sat);
        assert!(t.get_chunk(FedSatId::new(0, sat), key(2, 0)).is_err());
        // the same coordinates on the other shell still answer
        assert_eq!(t.get_chunk(FedSatId::new(1, SatId::new(3, 4)), key(2, 0)).unwrap(), None);
        assert_eq!(t.total_blackholed(), 1);
    }

    #[test]
    fn cross_shell_migrate_moves_and_accounts() {
        let t = dual();
        let from = FedSatId::new(0, SatId::new(4, 5));
        let to = FedSatId::new(1, SatId::new(3, 4));
        t.set_chunk(from, key(3, 0), vec![7; 100]).unwrap();
        t.set_chunk(from, key(3, 1), vec![8; 50]).unwrap();
        let (moved, bytes) = t.migrate_cross_shell(from, to);
        assert_eq!(moved, 2);
        assert_eq!(bytes, 150);
        assert_eq!(t.get_chunk(to, key(3, 1)).unwrap(), Some(vec![8; 50]));
        assert_eq!(t.link(0).fleet.node(from.sat).chunk_count(), 0);
        assert_eq!(t.stats.inter_shell_chunks.load(Ordering::Relaxed), 2);
        assert_eq!(t.stats.inter_shell_bytes.load(Ordering::Relaxed), 150);
        assert!(t.stats.inter_shell_latency_ns.load(Ordering::Relaxed) > 0);
        assert!(t.total_latency_ns() >= t.stats.inter_shell_latency_ns.load(Ordering::Relaxed));
    }

    #[test]
    fn epochs_advance_every_shell_in_lockstep() {
        let t = dual();
        let c0 = t.closest(0);
        let c1 = t.closest(1);
        t.set_epoch_all(2);
        assert_eq!(t.closest(0), t.shell(0).torus.offset(c0, 0, -2));
        assert_eq!(t.closest(1), t.shell(1).torus.offset(c1, 0, -2));
    }
}
