//! Failure injection at the transport boundary.
//!
//! [`FaultyTransport`] wraps any [`Transport`] and blackholes requests
//! whose destination satellite has been lost, or whose greedy +GRID route
//! from the ground entry point crosses a failed ISL or a lost satellite.
//! Blackholed requests surface as transport errors, which the KVC manager
//! already treats as chunk misses (a missing chunk breaks its block, the
//! prefix truncates, and the lazy-eviction path cleans up) — exactly the
//! degradation mode §3.9 describes for real satellite loss.
//!
//! Entry modelling mirrors [`super::transport::InProcTransport`]: a
//! destination inside the reliable-LOS window is uplinked directly (only
//! its own liveness matters); anything else enters at the closest
//! satellite and rides the mesh, so every intermediate hop matters.
//!
//! The fault set is dynamic — the scenario harness injects satellite
//! losses and ISL outages per rotation epoch and heals outages on a
//! deterministic schedule.

use crate::constellation::topology::{SatId, Torus};
use crate::net::messages::{Request, Response};
use crate::net::transport::{LinkModel, RouteInfo, Transport, TransportStats};
use anyhow::{bail, Result};
use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Counters of injected-failure impact.
#[derive(Debug, Default)]
pub struct FaultStats {
    /// Requests dropped because the destination satellite is lost.
    pub dead_destination: AtomicU64,
    /// Requests dropped because the route crossed a failed link/satellite.
    pub broken_route: AtomicU64,
}

impl FaultStats {
    pub fn blackholed(&self) -> u64 {
        self.dead_destination.load(Ordering::Relaxed)
            + self.broken_route.load(Ordering::Relaxed)
    }
}

/// An undirected ISL edge in canonical (smaller-endpoint-first) order.
fn edge(a: SatId, b: SatId) -> (SatId, SatId) {
    if a <= b {
        (a, b)
    } else {
        (b, a)
    }
}

/// A transport decorator that injects satellite and link failures.
pub struct FaultyTransport {
    inner: Arc<dyn Transport>,
    torus: Torus,
    /// Reliable-LOS half extents (slots, planes) for direct-uplink entry.
    los_half_slots: usize,
    los_half_planes: usize,
    failed_sats: RwLock<HashSet<SatId>>,
    failed_links: RwLock<HashSet<(SatId, SatId)>>,
    pub fault_stats: FaultStats,
}

impl FaultyTransport {
    pub fn new(
        inner: Arc<dyn Transport>,
        torus: Torus,
        los_half_slots: usize,
        los_half_planes: usize,
    ) -> Self {
        Self {
            inner,
            torus,
            los_half_slots,
            los_half_planes,
            failed_sats: RwLock::new(HashSet::new()),
            failed_links: RwLock::new(HashSet::new()),
            fault_stats: FaultStats::default(),
        }
    }

    /// Mark a satellite as lost (all traffic to or through it fails).
    pub fn fail_satellite(&self, sat: SatId) {
        self.failed_sats.write().unwrap().insert(sat);
    }

    /// Bring a satellite back (e.g. a replacement launch).
    pub fn restore_satellite(&self, sat: SatId) {
        self.failed_sats.write().unwrap().remove(&sat);
    }

    /// Take down the ISL between two (neighbouring) satellites.
    pub fn fail_link(&self, a: SatId, b: SatId) {
        debug_assert!(self.torus.are_neighbors(a, b), "ISL outage needs a real edge");
        self.failed_links.write().unwrap().insert(edge(a, b));
    }

    /// Restore a failed ISL.
    pub fn restore_link(&self, a: SatId, b: SatId) {
        self.failed_links.write().unwrap().remove(&edge(a, b));
    }

    pub fn failed_satellites(&self) -> usize {
        self.failed_sats.read().unwrap().len()
    }

    /// Is `sat` currently marked lost?
    pub fn is_satellite_failed(&self, sat: SatId) -> bool {
        self.failed_sats.read().unwrap().contains(&sat)
    }

    pub fn failed_links(&self) -> usize {
        self.failed_links.read().unwrap().len()
    }

    pub fn clear_faults(&self) {
        self.failed_sats.write().unwrap().clear();
        self.failed_links.write().unwrap().clear();
    }

    /// Is `dest` reachable from the current ground entry point?
    fn check_reachable(&self, dest: SatId) -> Reach {
        let sats = self.failed_sats.read().unwrap();
        if sats.contains(&dest) {
            return Reach::DeadDestination;
        }
        let center = self.inner.closest();
        let (dp, ds) = self.torus.signed_offset(center, dest);
        let direct = dp.unsigned_abs() as usize <= self.los_half_planes
            && ds.unsigned_abs() as usize <= self.los_half_slots;
        if direct {
            // direct ground uplink: no mesh traversal
            return Reach::Ok;
        }
        let links = self.failed_links.read().unwrap();
        if sats.is_empty() && links.is_empty() {
            return Reach::Ok;
        }
        // a lost entry satellite cannot relay into the mesh
        if sats.contains(&center) {
            return Reach::BrokenRoute;
        }
        let mut prev = center;
        for hop in self.torus.route(center, dest) {
            if links.contains(&edge(prev, hop)) {
                return Reach::BrokenRoute;
            }
            // intermediate dead satellites cannot forward; the final hop
            // was already checked as the destination
            if hop != dest && sats.contains(&hop) {
                return Reach::BrokenRoute;
            }
            prev = hop;
        }
        Reach::Ok
    }

    /// The fault gate shared by the timed and untimed request paths:
    /// count and surface a blackhole, or let the request through.
    fn gate(&self, dest: SatId) -> Result<()> {
        match self.check_reachable(dest) {
            Reach::Ok => Ok(()),
            Reach::DeadDestination => {
                self.fault_stats.dead_destination.fetch_add(1, Ordering::Relaxed);
                bail!("injected fault: satellite {dest} is lost")
            }
            Reach::BrokenRoute => {
                self.fault_stats.broken_route.fetch_add(1, Ordering::Relaxed);
                bail!("injected fault: no route to {dest}")
            }
        }
    }
}

enum Reach {
    Ok,
    DeadDestination,
    BrokenRoute,
}

impl Transport for FaultyTransport {
    fn request(&self, dest: SatId, req: Request) -> Result<Response> {
        self.gate(dest)?;
        self.inner.request(dest, req)
    }

    fn request_untimed(&self, dest: SatId, req: Request) -> Result<Response> {
        self.gate(dest)?;
        self.inner.request_untimed(dest, req)
    }

    fn route_info(&self, dest: SatId) -> RouteInfo {
        self.inner.route_info(dest)
    }

    fn link_model(&self) -> Option<LinkModel> {
        self.inner.link_model()
    }

    fn closest(&self) -> SatId {
        self.inner.closest()
    }

    fn set_epoch(&self, epoch: u64) {
        self.inner.set_epoch(epoch);
    }

    fn epoch(&self) -> u64 {
        self.inner.epoch()
    }

    fn stats(&self) -> &TransportStats {
        self.inner.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constellation::los::LosGrid;
    use crate::kvc::block::BlockHash;
    use crate::kvc::chunk::ChunkKey;
    use crate::kvc::eviction::EvictionPolicy;
    use crate::net::transport::{GroundView, InProcTransport};
    use crate::satellite::fleet::Fleet;

    fn faulty() -> (Arc<InProcTransport>, FaultyTransport) {
        let torus = Torus::new(5, 19);
        let fleet = Arc::new(Fleet::new(torus, 1 << 20, EvictionPolicy::Gossip));
        let center = SatId::new(2, 9);
        let ground = GroundView::new(center, &LosGrid::new(center, 2, 2), torus.sats_per_plane);
        let inner = Arc::new(InProcTransport::new(fleet, ground, None));
        let faulty = FaultyTransport::new(inner.clone(), torus, 2, 2);
        (inner, faulty)
    }

    fn key(b: u8) -> ChunkKey {
        ChunkKey::new(BlockHash([b; 32]), 0)
    }

    #[test]
    fn healthy_requests_pass_through() {
        let (_inner, t) = faulty();
        let dest = SatId::new(2, 10);
        t.set_chunk(dest, key(1), vec![1, 2, 3]).unwrap();
        assert_eq!(t.get_chunk(dest, key(1)).unwrap(), Some(vec![1, 2, 3]));
        assert_eq!(t.fault_stats.blackholed(), 0);
    }

    #[test]
    fn dead_destination_blackholes() {
        let (_inner, t) = faulty();
        let dest = SatId::new(2, 10);
        t.set_chunk(dest, key(1), vec![1]).unwrap();
        t.fail_satellite(dest);
        assert!(t.get_chunk(dest, key(1)).is_err());
        assert!(t.set_chunk(dest, key(2), vec![2]).is_err());
        assert_eq!(t.fault_stats.dead_destination.load(Ordering::Relaxed), 2);
        t.restore_satellite(dest);
        assert_eq!(t.get_chunk(dest, key(1)).unwrap(), Some(vec![1]));
    }

    #[test]
    fn link_outage_blocks_mesh_routes_but_not_direct_uplink() {
        let (_inner, t) = faulty();
        // a far destination (outside the 5x5 LOS window): the route
        // leaves the centre northward first
        let center = SatId::new(2, 9);
        let far = SatId::new(0, 3);
        let first_hop = t.torus.route(center, far)[0];
        t.fail_link(center, first_hop);
        assert!(t.ping(far).is_err(), "mesh route crosses the dead link");
        // destinations inside the LOS window uplink directly
        let near = SatId::new(1, 9);
        assert!(t.ping(near).is_ok());
        t.restore_link(center, first_hop);
        assert!(t.ping(far).is_ok());
    }

    #[test]
    fn dead_intermediate_breaks_the_route() {
        let (_inner, t) = faulty();
        let far = SatId::new(2, 0); // straight west along plane 2, outside LOS
        let center = SatId::new(2, 9);
        let mid = t.torus.route(center, far)[1];
        t.fail_satellite(mid);
        assert!(t.ping(far).is_err());
        assert_eq!(t.fault_stats.broken_route.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn isl_outage_heals_on_schedule() {
        // emulate the harness's heal bookkeeping: two outages injected at
        // different epochs, each healing `heal_epochs` later
        let (_inner, t) = faulty();
        let center = SatId::new(2, 9);
        let far = SatId::new(2, 0); // westward along plane 2, outside LOS
        let route = t.torus.route(center, far);
        let heal_epochs = 2u64;
        let mut active: Vec<(u64, SatId, SatId)> = Vec::new();
        // epoch 1: first hop goes dark
        active.push((1 + heal_epochs, center, route[0]));
        t.fail_link(center, route[0]);
        assert!(t.ping(far).is_err());
        // epoch 2: a second, disjoint outage further down the route
        active.push((2 + heal_epochs, route[1], route[2]));
        t.fail_link(route[1], route[2]);
        assert_eq!(t.failed_links(), 2);
        for epoch in 3..=4u64 {
            active.retain(|(heal_at, a, b)| {
                if *heal_at <= epoch {
                    t.restore_link(*a, *b);
                    false
                } else {
                    true
                }
            });
            if epoch == 3 {
                // the first outage healed, the second still blocks
                assert_eq!(t.failed_links(), 1);
                assert!(t.ping(far).is_err(), "route still crosses the second outage");
            } else {
                assert_eq!(t.failed_links(), 0);
                assert!(t.ping(far).is_ok(), "fully healed by epoch 4");
            }
        }
    }

    #[test]
    fn blackholing_is_route_aware() {
        // a lost satellite only blackholes destinations whose greedy
        // route crosses it — traffic routed elsewhere is untouched
        let (_inner, t) = faulty();
        let center = SatId::new(2, 9);
        let west_far = SatId::new(2, 0);
        let east_far = SatId::new(2, 15);
        let mid = t.torus.route(center, west_far)[1];
        t.fail_satellite(mid);
        assert!(t.ping(west_far).is_err(), "route west crosses the lost satellite");
        assert!(t.ping(east_far).is_ok(), "route east never touches it");
        assert_eq!(t.fault_stats.broken_route.load(Ordering::Relaxed), 1);
        // the lost satellite itself is a dead destination, not a broken route
        assert!(t.ping(mid).is_err());
        assert_eq!(t.fault_stats.dead_destination.load(Ordering::Relaxed), 1);
        t.restore_satellite(mid);
        assert!(t.ping(west_far).is_ok());
    }

    #[test]
    fn los_window_bypasses_a_broken_mesh() {
        // sever every ISL out of the entry satellite: the mesh is gone,
        // but destinations inside the reliable-LOS window still uplink
        // directly (entry modelling mirrors InProcTransport)
        let (_inner, t) = faulty();
        let center = SatId::new(2, 9);
        for nb in t.torus.neighbors(center) {
            t.fail_link(center, nb);
        }
        // corner of the 5x5 LOS window: reachable without the mesh
        let in_los = SatId::new(0, 7);
        assert!(t.ping(in_los).is_ok(), "direct uplink ignores ISL state");
        // one column past the window: must ride the dead mesh
        let outside = SatId::new(0, 6);
        assert!(t.ping(outside).is_err());
        // a dead satellite inside the window is still unreachable: the
        // bypass skips the mesh, not the destination's own liveness
        t.fail_satellite(in_los);
        assert!(t.ping(in_los).is_err());
    }

    #[test]
    fn clear_faults_heals_everything() {
        let (_inner, t) = faulty();
        t.fail_satellite(SatId::new(0, 0));
        t.fail_link(SatId::new(2, 9), SatId::new(2, 10));
        assert_eq!(t.failed_satellites(), 1);
        assert_eq!(t.failed_links(), 1);
        t.clear_faults();
        assert_eq!(t.failed_satellites(), 0);
        assert_eq!(t.failed_links(), 0);
    }
}
