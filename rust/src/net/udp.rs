//! UDP constellation: every satellite is a thread with its own socket,
//! speaking CCSDS Space Packets, forwarding hop-by-hop along the +GRID
//! mesh exactly like the in-process fleet — this is the paper's "5 Intel
//! NUCs hosting a 19x5 cFS constellation ... CCSDS Space Packet Protocol
//! over UDP" testbed, with threads (groupable into OS processes via the
//! `skymemory satellite` subcommand) standing in for the NUCs.
//!
//! Request path: ground client -> entry satellite (LOS uplink datagram) ->
//! N, E, S, W greedy forwarding -> destination node.  Responses go
//! straight back to the `reply_to` address in the envelope (the downlink;
//! in LOS scenarios the serving satellite is itself ground-visible).

use crate::constellation::topology::{SatId, Torus};
use crate::kvc::eviction::EvictionPolicy;
use crate::net::messages::{
    decode_request, decode_response, encode_request, encode_response, is_request, Envelope,
    Request, Response, DEFAULT_TTL,
};
use crate::net::spp::{deframe, frame, PacketType};
use crate::net::transport::{GroundView, Transport, TransportStats};
use crate::satellite::node::Node;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::net::{SocketAddr, UdpSocket};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Address book: satellite -> socket address.
#[derive(Debug, Clone, Default)]
pub struct AddrBook {
    addrs: HashMap<SatId, SocketAddr>,
}

impl AddrBook {
    pub fn insert(&mut self, sat: SatId, addr: SocketAddr) {
        self.addrs.insert(sat, addr);
    }

    pub fn get(&self, sat: SatId) -> Option<SocketAddr> {
        self.addrs.get(&sat).copied()
    }

    pub fn len(&self) -> usize {
        self.addrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.addrs.is_empty()
    }
}

/// A running UDP satellite.
struct UdpSatellite {
    node: Arc<Node>,
    socket: UdpSocket,
    torus: Torus,
    book: Arc<AddrBook>,
    shutdown: Arc<AtomicBool>,
    /// Fleet-wide counters shared by every satellite thread: drops that
    /// used to be silent `continue`s are counted here so a debugging
    /// session can tell TTL expiry from satellite loss.
    stats: Arc<TransportStats>,
    seq: u16,
}

impl UdpSatellite {
    fn run(mut self) {
        let mut buf = vec![0u8; 70_000];
        loop {
            if self.shutdown.load(Ordering::Relaxed) {
                return;
            }
            let (len, _src) = match self.socket.recv_from(&mut buf) {
                Ok(x) => x,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    continue;
                }
                Err(_) => return,
            };
            let Ok((_hdr, body)) = deframe(&buf[..len]) else {
                self.stats.dropped_stale.fetch_add(1, Ordering::Relaxed);
                continue;
            };
            if !is_request(body) {
                // responses are not routed through satellites here
                self.stats.dropped_stale.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            let Ok((mut env, req)) = decode_request(body) else {
                self.stats.dropped_stale.fetch_add(1, Ordering::Relaxed);
                continue;
            };
            if env.dest != self.node.id {
                // forward one hop along the mesh
                if env.ttl == 0 {
                    self.stats.dropped_ttl.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                env.ttl -= 1;
                let next = self.torus.step(self.node.id, self.torus.next_step(self.node.id, env.dest));
                if let Some(addr) = self.book.get(next) {
                    let data = encode_request(&env, &req);
                    self.seq = self.seq.wrapping_add(1);
                    let pkt = frame(PacketType::Telecommand, self.apid(), self.seq, &data);
                    let _ = self.socket.send_to(&pkt, addr);
                } else {
                    self.stats.dropped_unroutable.fetch_add(1, Ordering::Relaxed);
                }
                continue;
            }
            let (resp, outgoing) = self.node.handle(&self.torus, &env, &req);
            // side-effect sends (gossip, migration transfers) ride the mesh
            for o in outgoing {
                let oenv = Envelope::new(o.dest, env.req_id);
                let first = if o.dest == self.node.id {
                    self.node.id
                } else {
                    self.torus.step(self.node.id, self.torus.next_step(self.node.id, o.dest))
                };
                if let Some(addr) = self.book.get(first) {
                    let data = encode_request(&oenv, &o.request);
                    self.seq = self.seq.wrapping_add(1);
                    let pkt = frame(PacketType::Telecommand, self.apid(), self.seq, &data);
                    let _ = self.socket.send_to(&pkt, addr);
                } else {
                    self.stats.dropped_unroutable.fetch_add(1, Ordering::Relaxed);
                }
            }
            if let Some(reply) = env.reply_to {
                let data = encode_response(&env, &resp);
                self.seq = self.seq.wrapping_add(1);
                let pkt = frame(PacketType::Telemetry, self.apid(), self.seq, &data);
                let _ = self.socket.send_to(&pkt, SocketAddr::V4(reply));
            }
        }
    }

    fn apid(&self) -> u16 {
        (self.node.id.linear(self.torus.sats_per_plane) as u16) & 0x7FF
    }
}

/// Handle to a spawned UDP constellation (drops = shutdown).
pub struct UdpFleet {
    pub torus: Torus,
    pub book: Arc<AddrBook>,
    /// Fleet-side drop counters (`dropped_ttl`, `dropped_stale`,
    /// `dropped_unroutable`), aggregated over every satellite thread.
    pub stats: Arc<TransportStats>,
    nodes: Vec<Arc<Node>>,
    shutdown: Arc<AtomicBool>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl UdpFleet {
    /// Spawn one UDP satellite thread per torus cell on loopback
    /// (ephemeral ports).  `planes` can be restricted to host a subset in
    /// this process — the paper's per-NUC partitioning.
    pub fn spawn(
        torus: Torus,
        byte_budget_per_sat: usize,
        policy: EvictionPolicy,
        planes: Option<std::ops::Range<usize>>,
    ) -> Result<Self> {
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut book = AddrBook::default();
        let mut sockets = Vec::new();
        let range = planes.unwrap_or(0..torus.planes);
        for sat in torus.all() {
            if !range.contains(&(sat.plane as usize)) {
                continue;
            }
            let socket = UdpSocket::bind("127.0.0.1:0").context("bind satellite socket")?;
            socket.set_read_timeout(Some(Duration::from_millis(50)))?;
            book.insert(sat, socket.local_addr()?);
            sockets.push((sat, socket));
        }
        let book = Arc::new(book);
        let stats = Arc::new(TransportStats::default());
        let mut nodes = Vec::new();
        let mut handles = Vec::new();
        for (sat, socket) in sockets {
            let node = Arc::new(Node::new(sat, byte_budget_per_sat, policy));
            nodes.push(node.clone());
            let s = UdpSatellite {
                node,
                socket,
                torus,
                book: book.clone(),
                shutdown: shutdown.clone(),
                stats: stats.clone(),
                seq: 0,
            };
            handles.push(std::thread::spawn(move || s.run()));
        }
        Ok(Self { torus, book, stats, nodes, shutdown, handles })
    }

    pub fn node(&self, sat: SatId) -> Option<&Arc<Node>> {
        self.nodes.iter().find(|n| n.id == sat)
    }

    pub fn total_chunks(&self) -> usize {
        self.nodes.iter().map(|n| n.chunk_count()).sum()
    }

    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for UdpFleet {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Ground-side UDP client transport.
pub struct UdpTransport {
    torus: Torus,
    book: Arc<AddrBook>,
    ground: GroundView,
    socket: Mutex<UdpSocket>,
    timeout: Duration,
    ttl: u8,
    stats: TransportStats,
    req_counter: AtomicU64,
}

impl UdpTransport {
    pub fn new(torus: Torus, book: Arc<AddrBook>, ground: GroundView, timeout: Duration) -> Result<Self> {
        let socket = UdpSocket::bind("127.0.0.1:0")?;
        socket.set_read_timeout(Some(timeout))?;
        Ok(Self {
            torus,
            book,
            ground,
            socket: Mutex::new(socket),
            timeout,
            ttl: DEFAULT_TTL,
            stats: TransportStats::default(),
            req_counter: AtomicU64::new(1),
        })
    }

    /// Override the envelope TTL of outgoing requests (default
    /// [`DEFAULT_TTL`]).  A TTL smaller than the route's hop count makes
    /// the mesh drop the forward — counted in the fleet's `dropped_ttl`
    /// — and the client surfaces a counted timeout.
    pub fn with_ttl(mut self, ttl: u8) -> Self {
        self.ttl = ttl;
        self
    }

    fn entry_for(&self, dest: SatId) -> SatId {
        if self.ground.los().contains(&self.torus, dest) {
            dest
        } else {
            self.ground.center()
        }
    }
}

impl Transport for UdpTransport {
    fn request(&self, dest: SatId, req: Request) -> Result<Response> {
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        let req_id = self.req_counter.fetch_add(1, Ordering::Relaxed);
        let socket = self.socket.lock().unwrap();
        let local = socket.local_addr()?;
        let mut env = Envelope::new(dest, req_id).with_reply_to(local);
        env.ttl = self.ttl;
        let entry = self.entry_for(dest);
        let entry_addr = self
            .book
            .get(entry)
            .with_context(|| format!("no address for entry satellite {entry}"))?;
        let data = encode_request(&env, &req);
        let pkt = frame(PacketType::Telecommand, 0, req_id as u16, &data);
        socket.send_to(&pkt, entry_addr)?;
        // await the matching response (drop strays)
        let mut buf = vec![0u8; 70_000];
        let deadline = std::time::Instant::now() + self.timeout;
        loop {
            if std::time::Instant::now() > deadline {
                self.stats.errors.fetch_add(1, Ordering::Relaxed);
                bail!("timeout waiting for response from {dest} (req {req_id})");
            }
            let (len, _src) = match socket.recv_from(&mut buf) {
                Ok(x) => x,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    continue;
                }
                Err(e) => return Err(e.into()),
            };
            let Ok((_h, body)) = deframe(&buf[..len]) else {
                self.stats.dropped_stale.fetch_add(1, Ordering::Relaxed);
                continue;
            };
            if is_request(body) {
                self.stats.dropped_stale.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            let Ok((renv, resp)) = decode_response(body) else {
                self.stats.dropped_stale.fetch_add(1, Ordering::Relaxed);
                continue;
            };
            if renv.req_id != req_id {
                // stale response from an earlier timeout
                self.stats.dropped_stale.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            if matches!(resp, Response::GetMiss) {
                self.stats.misses.fetch_add(1, Ordering::Relaxed);
            }
            return Ok(resp);
        }
    }

    fn closest(&self) -> SatId {
        self.ground.center()
    }

    fn set_epoch(&self, epoch: u64) {
        self.ground.set_epoch(epoch);
    }

    fn epoch(&self) -> u64 {
        self.ground.epoch()
    }

    fn stats(&self) -> &TransportStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constellation::los::LosGrid;
    use crate::kvc::block::BlockHash;
    use crate::kvc::chunk::ChunkKey;

    fn key(b: u8, c: u32) -> ChunkKey {
        ChunkKey::new(BlockHash([b; 32]), c)
    }

    fn setup() -> (UdpFleet, UdpTransport) {
        let torus = Torus::new(3, 7);
        let fleet = UdpFleet::spawn(torus, 1 << 20, EvictionPolicy::Gossip, None).unwrap();
        let center = SatId::new(1, 3);
        let ground = GroundView::new(center, &LosGrid::new(center, 1, 1), torus.sats_per_plane);
        let t =
            UdpTransport::new(torus, fleet.book.clone(), ground, Duration::from_secs(2)).unwrap();
        (fleet, t)
    }

    #[test]
    fn udp_set_get_direct_los() {
        let (fleet, t) = setup();
        let dest = SatId::new(1, 4); // in LOS
        t.set_chunk(dest, key(1, 0), vec![42; 6000]).unwrap();
        assert_eq!(t.get_chunk(dest, key(1, 0)).unwrap(), Some(vec![42; 6000]));
        fleet.shutdown();
    }

    #[test]
    fn udp_multi_hop_forwarding() {
        let (fleet, t) = setup();
        let far = SatId::new(0, 0); // outside the 3x3 LOS window
        t.set_chunk(far, key(2, 1), vec![7; 128]).unwrap();
        assert_eq!(t.get_chunk(far, key(2, 1)).unwrap(), Some(vec![7; 128]));
        // the chunk physically lives on the far node
        assert_eq!(fleet.node(far).unwrap().chunk_count(), 1);
        fleet.shutdown();
    }

    #[test]
    fn udp_ttl_expiry_is_a_counted_timeout() {
        let torus = Torus::new(3, 7);
        let fleet = UdpFleet::spawn(torus, 1 << 20, EvictionPolicy::Gossip, None).unwrap();
        let center = SatId::new(1, 3);
        let ground = GroundView::new(center, &LosGrid::new(center, 1, 1), torus.sats_per_plane);
        // (0, 0) is 4 mesh hops from the entry satellite; a TTL of 2
        // expires in flight, so the request must surface as a counted
        // timeout on the client and a counted TTL drop on the fleet —
        // not a mystery hang.
        let t = UdpTransport::new(torus, fleet.book.clone(), ground, Duration::from_millis(300))
            .unwrap()
            .with_ttl(2);
        let far = SatId::new(0, 0);
        let err = t.get_chunk(far, key(4, 0)).unwrap_err();
        assert!(err.to_string().contains("timeout"), "{err}");
        assert_eq!(t.stats().errors.load(Ordering::Relaxed), 1);
        assert!(fleet.stats.dropped_ttl.load(Ordering::Relaxed) >= 1, "the drop is visible");
        fleet.shutdown();
    }

    #[test]
    fn udp_miss_and_migrate() {
        let (fleet, t) = setup();
        let a = SatId::new(1, 3);
        let b = SatId::new(1, 5);
        assert_eq!(t.get_chunk(a, key(9, 9)).unwrap(), None);
        t.set_chunk(a, key(3, 0), vec![1, 2, 3]).unwrap();
        let moved = t.migrate(a, b).unwrap();
        assert_eq!(moved, 1);
        // migration rides the mesh asynchronously; poll briefly
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        loop {
            if let Some(v) = t.get_chunk(b, key(3, 0)).unwrap() {
                assert_eq!(v, vec![1, 2, 3]);
                break;
            }
            assert!(std::time::Instant::now() < deadline, "migrated chunk never arrived");
            std::thread::sleep(Duration::from_millis(20));
        }
        fleet.shutdown();
    }
}
