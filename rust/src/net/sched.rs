//! The event-driven virtual-time link scheduler — SkyMemory's *timing
//! plane*.
//!
//! The §3.8 protocol fans a whole block's chunks out "in parallel".  The
//! first implementation modelled that with scoped OS threads per block,
//! which capped concurrency at a small worker count, burned real thread
//! spawns on *simulated* round trips, and forced the federated manager
//! into fully sequential chunk I/O to stay deterministic.  This module
//! replaces all of that with a discrete-event simulation in **virtual
//! time**:
//!
//! * The [`crate::net::transport::Transport`] stays the **data plane** —
//!   every chunk still travels through the real request path (routing,
//!   fault injection, stores, byte/hop accounting) via
//!   [`Transport::request_untimed`], which skips only the transport's own
//!   latency emulation.
//! * [`NetScheduler`] is the **timing plane**: it decides *when* each
//!   transfer's bytes move.  A transfer entering the constellation holds
//!   its entry satellite's ground-uplink link for the request
//!   serialization time, propagates (fully pipelined, no resource held)
//!   over its ISL hops, holds the destination satellite's service link
//!   for the response serialization time, and propagates back.  Each link
//!   admits at most `window` concurrent transfers; excess transfers wait
//!   in a FIFO queue and their wait is accounted as queueing delay.
//!
//! Determinism contract: the event queue is keyed by
//! `(virtual_time_ns, tag)` where `tag` is a caller-assigned per-transfer
//! id, and link FIFO queues are ordered by `(arrival_ns, tag)` — so batch
//! results (completion times, data-plane execution order, queueing stats)
//! are a pure function of the transfer *set*, independent of submission
//! order and of any OS scheduling.  No threads are spawned; thousands of
//! transfers can be in flight concurrently at zero per-transfer cost.
//!
//! Serialization and propagation costs derive from the transport's
//! [`LinkModel`] ([`Transport::link_model`]) and per-destination
//! [`RouteInfo`] ([`Transport::route_info`]); without a link model every
//! delay is zero and the engine degrades to a deterministic ordering
//! harness.  When the link model asks for wall-clock emulation
//! (`sleep_scale > 0`), the scheduler sleeps once per batch for the
//! batch's *makespan* — the pipelined time — instead of the serial
//! per-request sum the transports sleep on their own.
//!
//! Caveat: the *data plane* executes synchronously inside the event
//! loop, one request at a time.  That is exactly right for in-process
//! transports (the request itself is microseconds; the modelled time is
//! virtual), but over a transport whose requests genuinely block on a
//! network — [`crate::net::udp::UdpTransport`] keeps the default
//! `request_untimed` = `request` — a batch pays its round trips
//! serially.  Real-network fan-out needs an async/io-multiplexed data
//! plane underneath this scheduler (see ROADMAP "Async data plane for
//! real transports").

use crate::constellation::topology::SatId;
use crate::kvc::chunk::ChunkKey;
use crate::net::messages::{Request, Response};
use crate::net::transport::{LinkModel, RouteInfo, Transport};
use crate::obs::{NoopSink, SpanKind, TraceEvent, TraceSink};
use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Timing-plane configuration.
#[derive(Debug, Clone, Copy)]
pub struct SchedConfig {
    /// Transfers one link serves concurrently before FIFO queueing
    /// (>= 1; 1 = strictly serial per link).
    pub window: usize,
}

impl Default for SchedConfig {
    fn default() -> Self {
        Self { window: 8 }
    }
}

/// The two contention points a transfer passes through.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LinkKind {
    /// Ground-to-entry-satellite uplink (request serialization).
    Uplink,
    /// Destination satellite's service link (response serialization).
    Serve,
}

/// One schedulable link of the constellation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct LinkKey {
    pub kind: LinkKind,
    pub sat: SatId,
}

impl LinkKey {
    /// Stable text label (`uplink:P.S` / `serve:P.S`) used by trace
    /// events and the metrics `timeline.links` rollup.
    pub fn label(&self) -> String {
        let kind = match self.kind {
            LinkKind::Uplink => "uplink",
            LinkKind::Serve => "serve",
        };
        format!("{kind}:{}.{}", self.sat.plane, self.sat.slot)
    }
}

/// One chunk operation of a batch (the data plane of a transfer).
#[derive(Debug)]
pub enum ChunkOp {
    /// Fetch a chunk from `dest`.
    Get { dest: SatId, key: ChunkKey },
    /// Store `data` (header included) on `dest`.
    Set { dest: SatId, key: ChunkKey, data: Vec<u8> },
}

impl ChunkOp {
    fn dest(&self) -> SatId {
        match self {
            ChunkOp::Get { dest, .. } | ChunkOp::Set { dest, .. } => *dest,
        }
    }

    /// Request payload bytes on the wire (mirrors the transports' own
    /// accounting: Set carries its payload, everything else ~64 B).
    fn request_bytes(&self) -> usize {
        match self {
            ChunkOp::Set { data, .. } => data.len(),
            ChunkOp::Get { .. } => 64,
        }
    }
}

/// One transfer of a batch: a caller-assigned unique `tag` (the
/// deterministic tie-break and result index) plus its chunk operation.
#[derive(Debug)]
pub struct Transfer {
    pub tag: u64,
    pub op: ChunkOp,
}

/// Data-plane result of one transfer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ChunkResult {
    /// Get response: the payload, or `None` on a miss.
    Got(Option<Vec<u8>>),
    /// Set acknowledged.
    Stored,
    /// Transport error (fault-injected blackhole, satellite error, ...).
    Failed(String),
}

/// Outcome of one transfer: its data-plane result and the virtual time
/// (ns since batch start) at which its round trip completed.
#[derive(Debug)]
pub struct ChunkOutcome {
    pub tag: u64,
    pub completion_ns: u64,
    pub result: ChunkResult,
}

/// Report of one batch run to quiescence.
#[derive(Debug)]
pub struct BatchReport {
    /// Outcomes in ascending `tag` order.
    pub outcomes: Vec<ChunkOutcome>,
    /// Virtual time at which the last transfer completed.
    pub makespan_ns: u64,
    /// Peak number of transfers simultaneously in flight (begun
    /// transmission, not yet completed).
    pub peak_in_flight: usize,
    /// Total time transfers spent holding links (serialization).
    pub busy_ns: u64,
    /// Total time transfers spent waiting for a link window slot.
    pub queued_ns: u64,
    /// Distinct links this batch touched.
    pub links_used: usize,
}

/// Cumulative per-link usage (the source of the scenario reports'
/// `timeline.links` rollup).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LinkUsage {
    pub transfers: u64,
    /// Time spent serving transfers (serialization holds).
    pub busy_ns: u64,
    /// FIFO queueing delay paid on this link.
    pub queued_ns: u64,
    /// High-water mark of the link's FIFO queue depth.
    pub queue_peak: u64,
}

/// Cumulative scheduler counters (the per-link queueing/utilization
/// figures the scenario reports export).
#[derive(Debug, Default)]
pub struct SchedStats {
    pub batches: AtomicU64,
    pub transfers: AtomicU64,
    pub failed_transfers: AtomicU64,
    /// Sum of batch makespans: the pipelined virtual network time.
    pub virtual_ns: AtomicU64,
    /// Sum over links of time spent serving transfers.
    pub busy_ns: AtomicU64,
    /// Sum over links of FIFO queueing delay.
    pub queued_ns: AtomicU64,
    /// Max in-flight concurrency seen in any batch.
    pub peak_in_flight: AtomicU64,
    /// Cumulative usage per link (BTreeMap: deterministic).
    links: Mutex<BTreeMap<LinkKey, LinkUsage>>,
}

/// Plain-value copy of [`SchedStats`] for reports and deltas.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SchedSnapshot {
    pub batches: u64,
    pub transfers: u64,
    pub failed_transfers: u64,
    pub virtual_ns: u64,
    pub busy_ns: u64,
    pub queued_ns: u64,
    pub peak_in_flight: u64,
    /// Distinct links ever used.
    pub links_used: u64,
    /// Transfer count of the busiest link.
    pub busiest_link_transfers: u64,
}

impl SchedStats {
    fn record_links(&self, batch_links: &BTreeMap<LinkKey, LinkUsage>) {
        let mut links = self.links.lock().unwrap();
        for (k, u) in batch_links {
            let e = links.entry(*k).or_default();
            e.transfers += u.transfers;
            e.busy_ns += u.busy_ns;
            e.queued_ns += u.queued_ns;
            e.queue_peak = e.queue_peak.max(u.queue_peak);
        }
    }

    pub fn links_used(&self) -> u64 {
        self.links.lock().unwrap().len() as u64
    }

    /// Cumulative per-link usage, sorted by link key.
    pub fn link_rollup(&self) -> Vec<(LinkKey, LinkUsage)> {
        self.links.lock().unwrap().iter().map(|(k, u)| (*k, *u)).collect()
    }

    pub fn snapshot(&self) -> SchedSnapshot {
        let ld = |c: &AtomicU64| c.load(Ordering::Relaxed);
        let links = self.links.lock().unwrap();
        SchedSnapshot {
            batches: ld(&self.batches),
            transfers: ld(&self.transfers),
            failed_transfers: ld(&self.failed_transfers),
            virtual_ns: ld(&self.virtual_ns),
            busy_ns: ld(&self.busy_ns),
            queued_ns: ld(&self.queued_ns),
            peak_in_flight: ld(&self.peak_in_flight),
            links_used: links.len() as u64,
            busiest_link_transfers: links.values().map(|u| u.transfers).max().unwrap_or(0),
        }
    }
}

/// Trace routing installed on a scheduler: the sink plus the shell id
/// its events are stamped with.
#[derive(Clone)]
struct TraceCtx {
    sink: Arc<dyn TraceSink>,
    shell: u16,
}

/// The virtual-time transfer engine over one transport.
pub struct NetScheduler {
    transport: Arc<dyn Transport>,
    pub config: SchedConfig,
    pub stats: SchedStats,
    trace: Mutex<TraceCtx>,
}

impl NetScheduler {
    pub fn new(transport: Arc<dyn Transport>, config: SchedConfig) -> Self {
        assert!(config.window >= 1, "a link window must admit at least one transfer");
        Self {
            transport,
            config,
            stats: SchedStats::default(),
            trace: Mutex::new(TraceCtx { sink: Arc::new(NoopSink), shell: 0 }),
        }
    }

    pub fn transport(&self) -> &Arc<dyn Transport> {
        &self.transport
    }

    /// Route this scheduler's trace events to `sink`, stamped with
    /// `shell`.  Interior mutability because schedulers are shared
    /// behind `Arc` (per-shell
    /// [`crate::federation::transport::ShellLink`]s); the default sink
    /// is [`NoopSink`], which disables all event construction.
    pub fn set_trace_sink(&self, sink: Arc<dyn TraceSink>, shell: u16) {
        *self.trace.lock().unwrap() = TraceCtx { sink, shell };
    }

    /// Run one batch of transfers to quiescence and return per-transfer
    /// outcomes, updating the cumulative stats.  Tags must be unique
    /// within the batch.
    pub fn run_batch(&self, transfers: Vec<Transfer>) -> BatchReport {
        let report = self.run_batch_untimed(transfers);
        // wall-clock emulation (serving mode): sleep the *pipelined*
        // makespan once per batch, not the serial per-request sum
        if let Some(lm) = self.transport.link_model() {
            if lm.sleep_scale > 0.0 && report.makespan_ns > 0 {
                let ns = (report.makespan_ns as f64 * lm.sleep_scale) as u64;
                std::thread::sleep(std::time::Duration::from_nanos(ns));
            }
        }
        report
    }

    /// [`NetScheduler::run_batch`] without the wall-clock emulation
    /// sleep.  Racing callers use this so concurrent arms sleep once for
    /// the *slowest* arm ([`race_batches`]) instead of summing sleeps;
    /// virtual-time accounting is identical either way.
    pub fn run_batch_untimed(&self, transfers: Vec<Transfer>) -> BatchReport {
        let trace = self.trace.lock().unwrap().clone();
        let tracing = trace.sink.wants(SpanKind::Sched);
        let link_model = self.transport.link_model();
        let mut engine = Engine {
            transport: self.transport.as_ref(),
            link_model,
            window: self.config.window,
            flights: BTreeMap::new(),
            events: BinaryHeap::new(),
            links: BTreeMap::new(),
            active: 0,
            peak_in_flight: 0,
            failed: 0,
            trace: if tracing { Some(Vec::new()) } else { None },
        };
        for t in transfers {
            engine.admit(t);
        }
        let report = engine.run();
        // Virtual-time base of this batch: trace events are stamped
        // relative to the cumulative clock before its makespan is added.
        let base = self.stats.virtual_ns.load(Ordering::Relaxed);
        self.stats.batches.fetch_add(1, Ordering::Relaxed);
        self.stats.transfers.fetch_add(report.outcomes.len() as u64, Ordering::Relaxed);
        self.stats.failed_transfers.fetch_add(engine.failed, Ordering::Relaxed);
        self.stats.virtual_ns.fetch_add(report.makespan_ns, Ordering::Relaxed);
        self.stats.busy_ns.fetch_add(report.busy_ns, Ordering::Relaxed);
        self.stats.queued_ns.fetch_add(report.queued_ns, Ordering::Relaxed);
        self.stats.peak_in_flight.fetch_max(report.peak_in_flight as u64, Ordering::Relaxed);
        let batch_links: BTreeMap<LinkKey, LinkUsage> = engine
            .links
            .iter()
            .map(|(k, l)| {
                let usage = LinkUsage {
                    transfers: l.transfers,
                    busy_ns: l.busy_ns,
                    queued_ns: l.queued_ns,
                    queue_peak: l.queue_peak as u64,
                };
                (*k, usage)
            })
            .collect();
        self.stats.record_links(&batch_links);
        if let Some(raw) = engine.trace.take() {
            for r in raw {
                let mut ev = TraceEvent::span(SpanKind::Sched, r.name, base + r.t, r.dur)
                    .with_shell(trace.shell);
                if let Some(key) = r.link {
                    ev = ev.with_link(key.label());
                }
                for (k, v) in r.args {
                    ev = ev.arg_u(k, v);
                }
                trace.sink.record(ev);
            }
            // One whole-round-trip span per transfer, in tag order.
            for o in &report.outcomes {
                trace.sink.record(
                    TraceEvent::span(SpanKind::Sched, "xfer", base, o.completion_ns)
                        .with_shell(trace.shell)
                        .arg_u("tag", o.tag),
                );
            }
        }
        report
    }
}

/// Outcome of racing one logical transfer set over several schedulers.
#[derive(Debug)]
pub struct RaceOutcome {
    /// Index of the fastest arm: the batch with the smallest makespan,
    /// ties to the lowest index.
    pub fastest: usize,
    /// Per-arm batch reports, in submission order.
    pub reports: Vec<BatchReport>,
}

/// Race the same logical chunk set across several schedulers (replica
/// arms of a federated Get: each arm addresses a different shell's copy
/// of the block, so each arm carries its own transfers).
///
/// Every arm's batch really runs — the data plane of the losing arms
/// executes too, and their traffic is paid and accounted on their own
/// links — which is exactly what issuing a replica race over the air
/// would cost.  Arms run sequentially in index order, so the outcome is
/// a pure function of the arms: each batch is itself deterministic, and
/// the winner is the smallest `makespan_ns` with ties resolved to the
/// lowest arm index.
///
/// Wall-clock emulation (`sleep_scale > 0`): the arms are concurrent,
/// so the race sleeps once for the *slowest* arm's scaled makespan
/// instead of letting each batch sleep its own (a race must never be
/// slower than its slowest arm).
///
/// The caller decides what "won" means for its payloads (e.g. the
/// fastest arm whose chunks all arrived); `fastest` is purely the
/// timing-plane verdict.
pub fn race_batches(arms: Vec<(&NetScheduler, Vec<Transfer>)>) -> RaceOutcome {
    assert!(!arms.is_empty(), "a race needs at least one arm");
    let mut reports = Vec::with_capacity(arms.len());
    let mut sleep_ns = 0u64;
    for (sched, transfers) in arms {
        let report = sched.run_batch_untimed(transfers);
        if let Some(lm) = sched.transport().link_model() {
            if lm.sleep_scale > 0.0 {
                sleep_ns = sleep_ns.max((report.makespan_ns as f64 * lm.sleep_scale) as u64);
            }
        }
        reports.push(report);
    }
    if sleep_ns > 0 {
        std::thread::sleep(std::time::Duration::from_nanos(sleep_ns));
    }
    let mut fastest = 0;
    for (i, r) in reports.iter().enumerate().skip(1) {
        if r.makespan_ns < reports[fastest].makespan_ns {
            fastest = i;
        }
    }
    RaceOutcome { fastest, reports }
}

// ======================================================================
// The single-batch event engine (single-threaded, no locks)
// ======================================================================

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Ev {
    ArriveUplink,
    UplinkDone,
    ArriveServe,
    ServeDone,
    Complete,
}

#[derive(Default)]
struct LinkState {
    in_flight: usize,
    /// Waiting transfers, FIFO by `(arrival_ns, tag)`.
    queue: BTreeSet<(u64, u64)>,
    busy_ns: u64,
    queued_ns: u64,
    transfers: u64,
    /// High-water mark of `queue`'s depth.
    queue_peak: usize,
}

/// A buffered engine trace event with batch-relative time; stamped onto
/// the cumulative virtual clock after the batch runs.
struct RawEv {
    t: u64,
    dur: u64,
    name: &'static str,
    link: Option<LinkKey>,
    args: Vec<(&'static str, u64)>,
}

struct Flight {
    op: Option<ChunkOp>,
    dest: SatId,
    route: RouteInfo,
    /// Request serialization hold on the uplink.
    req_ser_ns: u64,
    /// Response serialization hold on the destination's service link —
    /// known once the data plane has executed.
    resp_ser_ns: u64,
    /// One-way propagation (ground uplink + ISL hops), fully pipelined.
    prop_ns: u64,
    result: Option<ChunkResult>,
    completion_ns: u64,
}

struct Engine<'a> {
    transport: &'a dyn Transport,
    link_model: Option<LinkModel>,
    window: usize,
    flights: BTreeMap<u64, Flight>,
    /// Event queue: a binary min-heap popping the smallest
    /// `(virtual_time_ns, tag)` — the deterministic total order of the
    /// simulation.  A transfer's state machine is linear (every popped
    /// event schedules at most one successor for that tag, and a
    /// link-queued transfer holds no event), so at most one event per tag
    /// is ever pending and `(time, tag)` is unique in the heap; the `Ev`
    /// component never has to break a tie.  O(log n) push/pop without the
    /// BTreeMap's rebalancing and allocation overhead — this queue is the
    /// hottest structure of every scenario run.
    events: BinaryHeap<Reverse<(u64, u64, Ev)>>,
    links: BTreeMap<LinkKey, LinkState>,
    active: usize,
    peak_in_flight: usize,
    failed: u64,
    /// Event buffer, `Some` only when the installed sink wants
    /// [`SpanKind::Sched`] — the `None` path costs one branch per site.
    trace: Option<Vec<RawEv>>,
}

impl Engine<'_> {
    fn trace_ev(
        &mut self,
        t: u64,
        dur: u64,
        name: &'static str,
        link: Option<LinkKey>,
        args: &[(&'static str, u64)],
    ) {
        if let Some(buf) = &mut self.trace {
            buf.push(RawEv { t, dur, name, link, args: args.to_vec() });
        }
    }

    fn ser_ns(&self, bytes: usize) -> u64 {
        match &self.link_model {
            Some(lm) => (lm.serial_s(bytes) * 1e9) as u64,
            None => 0,
        }
    }

    fn prop_ns(&self, route: &RouteInfo) -> u64 {
        match &self.link_model {
            Some(lm) => (lm.propagation_s(route.ground_cells, route.isl_hops) * 1e9) as u64,
            None => 0,
        }
    }

    fn admit(&mut self, t: Transfer) {
        let dest = t.op.dest();
        let route = self.transport.route_info(dest);
        let flight = Flight {
            req_ser_ns: self.ser_ns(t.op.request_bytes()),
            resp_ser_ns: 0,
            prop_ns: self.prop_ns(&route),
            op: Some(t.op),
            dest,
            route,
            result: None,
            completion_ns: 0,
        };
        let prev = self.flights.insert(t.tag, flight);
        assert!(prev.is_none(), "duplicate transfer tag {}", t.tag);
        self.events.push(Reverse((0, t.tag, Ev::ArriveUplink)));
        self.trace_ev(0, 0, "enqueue", None, &[("tag", t.tag)]);
    }

    /// Execute the data plane of one transfer (deterministic point in the
    /// event order: uplink admission).
    fn execute(&mut self, tag: u64) {
        let flight = self.flights.get_mut(&tag).expect("flight exists");
        let op = flight.op.take().expect("data plane runs once");
        let dest = flight.dest;
        let (result, resp_bytes) = match op {
            ChunkOp::Get { key, .. } => {
                match self.transport.request_untimed(dest, Request::Get { key }) {
                    Ok(Response::GetOk { payload }) => {
                        let n = payload.len().max(64);
                        (ChunkResult::Got(Some(payload)), n)
                    }
                    Ok(Response::GetMiss) => {
                        self.transport.stats().misses.fetch_add(1, Ordering::Relaxed);
                        (ChunkResult::Got(None), 64)
                    }
                    Ok(r) => {
                        (ChunkResult::Failed(format!("unexpected response to Get: {r:?}")), 64)
                    }
                    Err(e) => (ChunkResult::Failed(e.to_string()), 64),
                }
            }
            ChunkOp::Set { key, data, .. } => {
                match self.transport.request_untimed(dest, Request::Set { key, payload: data }) {
                    Ok(Response::SetOk) => (ChunkResult::Stored, 64),
                    Ok(r) => {
                        (ChunkResult::Failed(format!("unexpected response to Set: {r:?}")), 64)
                    }
                    Err(e) => (ChunkResult::Failed(e.to_string()), 64),
                }
            }
        };
        if matches!(result, ChunkResult::Failed(_)) {
            self.failed += 1;
        }
        let resp_ser = self.ser_ns(resp_bytes);
        let flight = self.flights.get_mut(&tag).expect("flight exists");
        flight.result = Some(result);
        flight.resp_ser_ns = resp_ser;
    }

    fn uplink_key(&self, tag: u64) -> LinkKey {
        LinkKey { kind: LinkKind::Uplink, sat: self.flights[&tag].route.entry }
    }

    fn serve_key(&self, tag: u64) -> LinkKey {
        LinkKey { kind: LinkKind::Serve, sat: self.flights[&tag].dest }
    }

    /// Begin the uplink hold of `tag` at time `t` (the transfer is now in
    /// flight; its data plane executes here).
    fn start_uplink(&mut self, t: u64, tag: u64) {
        self.active += 1;
        self.peak_in_flight = self.peak_in_flight.max(self.active);
        self.execute(tag);
        let key = self.uplink_key(tag);
        let hold = self.flights[&tag].req_ser_ns;
        let link = self.links.entry(key).or_default();
        link.transfers += 1;
        link.busy_ns += hold;
        self.trace_ev(t, hold, "serialize_req", Some(key), &[("tag", tag)]);
        self.events.push(Reverse((t + hold, tag, Ev::UplinkDone)));
    }

    /// Begin the destination-service hold of `tag` at time `t`.
    fn start_serve(&mut self, t: u64, tag: u64) {
        let key = self.serve_key(tag);
        let hold = self.flights[&tag].resp_ser_ns;
        let link = self.links.entry(key).or_default();
        link.transfers += 1;
        link.busy_ns += hold;
        self.trace_ev(t, hold, "serialize_resp", Some(key), &[("tag", tag)]);
        self.events.push(Reverse((t + hold, tag, Ev::ServeDone)));
    }

    /// Acquire a window slot on `key` at time `t`, or join its FIFO.
    /// Returns whether the slot was acquired.
    fn acquire_or_queue(&mut self, key: LinkKey, t: u64, tag: u64) -> bool {
        let window = self.window;
        let link = self.links.entry(key).or_default();
        if link.in_flight < window {
            link.in_flight += 1;
            let in_flight = link.in_flight as u64;
            self.trace_ev(t, 0, "acquire", Some(key), &[("in_flight", in_flight), ("tag", tag)]);
            true
        } else {
            link.queue.insert((t, tag));
            link.queue_peak = link.queue_peak.max(link.queue.len());
            let depth = link.queue.len() as u64;
            self.trace_ev(t, 0, "queue", Some(key), &[("depth", depth), ("tag", tag)]);
            false
        }
    }

    /// Release a window slot on `key` at time `t`; returns the next
    /// queued transfer (FIFO by arrival, tag tie-break), now admitted.
    fn release(&mut self, key: LinkKey, t: u64) -> Option<u64> {
        let link = self.links.get_mut(&key).expect("held link exists");
        link.in_flight -= 1;
        let head = link.queue.iter().next().copied();
        if let Some((arrival, wtag)) = head {
            link.queue.remove(&(arrival, wtag));
            link.in_flight += 1;
            link.queued_ns += t - arrival;
            let waited = t - arrival;
            self.trace_ev(t, 0, "acquire", Some(key), &[("tag", wtag), ("waited_ns", waited)]);
            Some(wtag)
        } else {
            None
        }
    }

    fn run(&mut self) -> BatchReport {
        let mut makespan = 0u64;
        while let Some(Reverse((t, tag, ev))) = self.events.pop() {
            match ev {
                Ev::ArriveUplink => {
                    let key = self.uplink_key(tag);
                    if self.acquire_or_queue(key, t, tag) {
                        self.start_uplink(t, tag);
                    }
                }
                Ev::UplinkDone => {
                    let key = self.uplink_key(tag);
                    if let Some(next) = self.release(key, t) {
                        self.start_uplink(t, next);
                    }
                    let prop = self.flights[&tag].prop_ns;
                    self.events.push(Reverse((t + prop, tag, Ev::ArriveServe)));
                }
                Ev::ArriveServe => {
                    let key = self.serve_key(tag);
                    if self.acquire_or_queue(key, t, tag) {
                        self.start_serve(t, tag);
                    }
                }
                Ev::ServeDone => {
                    let key = self.serve_key(tag);
                    if let Some(next) = self.release(key, t) {
                        self.start_serve(t, next);
                    }
                    let prop = self.flights[&tag].prop_ns;
                    self.events.push(Reverse((t + prop, tag, Ev::Complete)));
                }
                Ev::Complete => {
                    self.active -= 1;
                    let flight = self.flights.get_mut(&tag).expect("flight exists");
                    flight.completion_ns = t;
                    makespan = makespan.max(t);
                }
            }
        }
        let outcomes: Vec<ChunkOutcome> = std::mem::take(&mut self.flights)
            .into_iter()
            .map(|(tag, f)| ChunkOutcome {
                tag,
                completion_ns: f.completion_ns,
                result: f.result.expect("every transfer ran its data plane"),
            })
            .collect();
        let busy_ns = self.links.values().map(|l| l.busy_ns).sum();
        let queued_ns = self.links.values().map(|l| l.queued_ns).sum();
        BatchReport {
            outcomes,
            makespan_ns: makespan,
            peak_in_flight: self.peak_in_flight,
            busy_ns,
            queued_ns,
            links_used: self.links.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constellation::geometry::Geometry;
    use crate::constellation::los::LosGrid;
    use crate::constellation::topology::Torus;
    use crate::kvc::block::BlockHash;
    use crate::kvc::eviction::EvictionPolicy;
    use crate::net::faults::FaultyTransport;
    use crate::net::transport::{GroundView, InProcTransport};
    use crate::satellite::fleet::Fleet;

    fn stack(bandwidth_bps: Option<f64>) -> (Arc<Fleet>, Arc<InProcTransport>) {
        let torus = Torus::new(7, 13);
        let fleet = Arc::new(Fleet::new(torus, 10 << 20, EvictionPolicy::Lazy));
        let center = SatId::new(3, 6);
        let los = LosGrid::new(center, 2, 2);
        let ground = GroundView::new(center, &los, torus.sats_per_plane);
        let link = bandwidth_bps.map(|b| {
            let mut lm = LinkModel::laser_defaults(Geometry::new(550.0, 13, 7));
            lm.bandwidth_bps = b;
            lm.sleep_scale = 0.0;
            lm
        });
        let inproc = Arc::new(InProcTransport::new(fleet.clone(), ground, link));
        (fleet, inproc)
    }

    fn sched(inproc: &Arc<InProcTransport>, window: usize) -> NetScheduler {
        let t: Arc<dyn Transport> = inproc.clone();
        NetScheduler::new(t, SchedConfig { window })
    }

    fn key(b: u8, c: u32) -> ChunkKey {
        ChunkKey::new(BlockHash([b; 32]), c)
    }

    fn set(tag: u64, dest: SatId, b: u8, c: u32, len: usize) -> Transfer {
        Transfer { tag, op: ChunkOp::Set { dest, key: key(b, c), data: vec![b; len] } }
    }

    fn get(tag: u64, dest: SatId, b: u8, c: u32) -> Transfer {
        Transfer { tag, op: ChunkOp::Get { dest, key: key(b, c) } }
    }

    #[test]
    fn set_then_get_roundtrip_through_the_engine() {
        let (_fleet, inproc) = stack(None);
        let s = sched(&inproc, 4);
        let dest = SatId::new(3, 7); // in LOS
        let report = s.run_batch(vec![set(0, dest, 1, 0, 100), set(1, dest, 1, 1, 50)]);
        assert_eq!(report.outcomes.len(), 2);
        assert!(report.outcomes.iter().all(|o| o.result == ChunkResult::Stored));
        // zero link model: everything completes at virtual time 0
        assert_eq!(report.makespan_ns, 0);
        let report = s.run_batch(vec![get(0, dest, 1, 0), get(1, dest, 1, 1), get(2, dest, 1, 9)]);
        assert_eq!(report.outcomes[0].result, ChunkResult::Got(Some(vec![1; 100])));
        assert_eq!(report.outcomes[1].result, ChunkResult::Got(Some(vec![1; 50])));
        assert_eq!(report.outcomes[2].result, ChunkResult::Got(None), "missing chunk is a miss");
        assert_eq!(s.stats.batches.load(Ordering::Relaxed), 2);
        assert_eq!(s.stats.transfers.load(Ordering::Relaxed), 5);
        assert_eq!(s.stats.failed_transfers.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn outcomes_are_sorted_by_tag_regardless_of_submission_order() {
        let (_fleet, inproc) = stack(Some(1e8));
        let s = sched(&inproc, 2);
        let dest = SatId::new(3, 7);
        let batch = vec![set(2, dest, 1, 2, 10), set(0, dest, 1, 0, 10), set(1, dest, 1, 1, 10)];
        let report = s.run_batch(batch);
        let tags: Vec<u64> = report.outcomes.iter().map(|o| o.tag).collect();
        assert_eq!(tags, vec![0, 1, 2]);
    }

    #[test]
    fn window_one_serializes_a_shared_link() {
        // two equal Sets to the same satellite: with window 1 the second
        // trails the first by exactly one request serialization slot;
        // with window 2 they complete together
        let dest = SatId::new(3, 6);
        let mk = || vec![set(0, dest, 2, 0, 1000), set(1, dest, 2, 1, 1000)];
        let (_f1, t1) = stack(Some(1e8));
        let serial = sched(&t1, 1).run_batch(mk());
        let (_f2, t2) = stack(Some(1e8));
        let parallel = sched(&t2, 2).run_batch(mk());
        let ser_ns = ((1000.0 * 8.0 / 1e8) * 1e9) as u64;
        let c = |r: &BatchReport, i: usize| r.outcomes[i].completion_ns;
        assert_eq!(c(&serial, 1) - c(&serial, 0), ser_ns, "FIFO trails by one slot");
        assert_eq!(c(&parallel, 0), c(&parallel, 1), "window 2 admits both at once");
        assert!(serial.queued_ns > 0, "the queued wait is accounted");
        assert_eq!(parallel.queued_ns, 0);
        assert!(serial.makespan_ns > parallel.makespan_ns);
    }

    #[test]
    fn distinct_destinations_pipeline() {
        // five transfers over four distinct LOS satellites take barely
        // longer than one transfer to the same ring, not five times as
        // long: propagation overlaps, only shared links serialize
        let (_fleet, inproc) = stack(Some(1e8));
        let s = sched(&inproc, 1);
        let one = s.run_batch(vec![set(0, SatId::new(3, 5), 3, 0, 2000)]);
        let (_fleet2, inproc2) = stack(Some(1e8));
        let s2 = sched(&inproc2, 1);
        let many = s2.run_batch(
            (0..5).map(|i| set(i, SatId::new(3, 5 + i as u16 % 4), 3, i as u32, 2000)).collect(),
        );
        assert!(
            many.makespan_ns < 2 * one.makespan_ns,
            "fan-out must not serialize: {} vs {}",
            many.makespan_ns,
            one.makespan_ns
        );
        assert!(many.peak_in_flight >= 4, "transfers overlap: {}", many.peak_in_flight);
        assert!(many.links_used > one.links_used);
    }

    #[test]
    fn failed_satellite_surfaces_as_failed_result() {
        let (_fleet, inproc) = stack(None);
        let torus = Torus::new(7, 13);
        let faults = Arc::new(FaultyTransport::new(inproc.clone(), torus, 2, 2));
        let dead = SatId::new(3, 7);
        faults.fail_satellite(dead);
        let t: Arc<dyn Transport> = faults;
        let s = NetScheduler::new(t, SchedConfig::default());
        let report = s.run_batch(vec![set(0, dead, 4, 0, 10), set(1, SatId::new(3, 6), 4, 1, 10)]);
        assert!(matches!(report.outcomes[0].result, ChunkResult::Failed(_)));
        assert_eq!(report.outcomes[1].result, ChunkResult::Stored);
        assert_eq!(s.stats.failed_transfers.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn snapshot_aggregates_links() {
        let (_fleet, inproc) = stack(Some(1e8));
        let s = sched(&inproc, 1);
        let dest = SatId::new(3, 6);
        s.run_batch(vec![set(0, dest, 5, 0, 500), set(1, dest, 5, 1, 500)]);
        s.run_batch(vec![get(0, dest, 5, 0)]);
        let snap = s.stats.snapshot();
        assert_eq!(snap.batches, 2);
        assert_eq!(snap.transfers, 3);
        // one uplink + one service link on the single destination
        assert_eq!(snap.links_used, 2);
        assert_eq!(snap.busiest_link_transfers, 3);
        assert!(snap.virtual_ns > 0);
        assert!(snap.busy_ns > 0);
        assert_eq!(snap.peak_in_flight, 2);
    }

    #[test]
    fn race_picks_the_faster_arm_and_runs_both() {
        // same transfer set over a fast and a slow stack: the fast arm
        // wins, but the slow arm's data plane ran too (both stores land)
        let dest = SatId::new(3, 6);
        let (fast_fleet, fast) = stack(Some(1e9));
        let (slow_fleet, slow) = stack(Some(1e6));
        let s_fast = sched(&fast, 4);
        let s_slow = sched(&slow, 4);
        let mk = || vec![set(0, dest, 8, 0, 1000), set(1, dest, 8, 1, 1000)];
        let out = race_batches(vec![(&s_slow, mk()), (&s_fast, mk())]);
        assert_eq!(out.fastest, 1, "the 1 Gbit/s arm must win");
        assert_eq!(out.reports.len(), 2);
        assert!(out.reports[0].makespan_ns > out.reports[1].makespan_ns);
        assert_eq!(fast_fleet.total_chunks(), 2, "the winner stored");
        assert_eq!(slow_fleet.total_chunks(), 2, "the loser's data plane ran too");
        // equal arms: ties resolve to the lowest index
        let (_f3, a) = stack(Some(1e8));
        let (_f4, b) = stack(Some(1e8));
        let (sa, sb) = (sched(&a, 4), sched(&b, 4));
        let tie = race_batches(vec![(&sa, mk()), (&sb, mk())]);
        assert_eq!(tie.fastest, 0, "ties must resolve to the first arm");
    }

    #[test]
    fn tracing_preserves_timing_and_stays_silent_by_default() {
        use crate::obs::Recorder;
        let dest = SatId::new(3, 6);
        let mk = || vec![set(0, dest, 9, 0, 1000), set(1, dest, 9, 1, 1000)];
        let (_f1, t1) = stack(Some(1e8));
        let plain = sched(&t1, 1).run_batch(mk());
        let (_f2, t2) = stack(Some(1e8));
        let s = sched(&t2, 1);
        let rec = Arc::new(Recorder::new());
        s.set_trace_sink(rec.clone(), 3);
        let traced = s.run_batch(mk());
        // instrumentation must never perturb the virtual timeline
        assert_eq!(plain.makespan_ns, traced.makespan_ns);
        assert_eq!(plain.queued_ns, traced.queued_ns);
        assert_eq!(plain.busy_ns, traced.busy_ns);
        let events = rec.take();
        assert!(!events.is_empty());
        assert!(events.iter().all(|e| e.shell == Some(3)));
        for name in ["enqueue", "acquire", "queue", "serialize_req", "serialize_resp", "xfer"] {
            assert!(events.iter().any(|e| e.name == name), "missing {name} event");
        }
        let xfer_durs: Vec<u64> =
            events.iter().filter(|e| e.name == "xfer").map(|e| e.dur_ns).collect();
        assert_eq!(xfer_durs.iter().max().copied(), Some(traced.makespan_ns));
    }

    #[test]
    fn link_rollup_reports_per_link_usage_and_queue_peaks() {
        let (_fleet, inproc) = stack(Some(1e8));
        let s = sched(&inproc, 1);
        let dest = SatId::new(3, 6);
        s.run_batch(vec![
            set(0, dest, 5, 0, 500),
            set(1, dest, 5, 1, 500),
            set(2, dest, 5, 2, 500),
        ]);
        let rollup = s.stats.link_rollup();
        let (key, usage) =
            rollup.iter().find(|(k, _)| k.kind == LinkKind::Uplink).expect("uplink present");
        assert_eq!(usage.transfers, 3);
        assert!(usage.busy_ns > 0);
        // window 1, three simultaneous arrivals: two of them queue
        assert_eq!(usage.queue_peak, 2);
        assert!(usage.queued_ns > 0);
        assert!(key.label().starts_with("uplink:"));
    }

    #[test]
    #[should_panic(expected = "duplicate transfer tag")]
    fn duplicate_tags_are_rejected() {
        let (_fleet, inproc) = stack(None);
        let s = sched(&inproc, 1);
        let dest = SatId::new(3, 6);
        s.run_batch(vec![set(7, dest, 6, 0, 10), set(7, dest, 6, 1, 10)]);
    }
}
