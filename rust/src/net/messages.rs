//! SkyMemory wire messages — the user data carried inside Space Packets.
//!
//! Every message starts with a fixed envelope so any satellite can route
//! it without understanding the body:
//!
//! ```text
//! offset  size  field
//!      0     1  message kind
//!      1     2  dest plane (LE)
//!      3     2  dest slot (LE)
//!      5     1  ttl (remaining hops; routing drops at 0)
//!      6     8  request id (LE, client correlation)
//!     14     6  reply-to: ipv4 (4) + port (2), zeros for in-proc
//!     20     .  body (kind-specific)
//! ```

use crate::constellation::topology::SatId;
use crate::kvc::block::BlockHash;
use crate::kvc::chunk::ChunkKey;
use anyhow::{bail, Result};
use std::net::{Ipv4Addr, SocketAddr, SocketAddrV4};

pub const ENVELOPE_LEN: usize = 20;
/// Default routing TTL — generous for any torus we simulate.
pub const DEFAULT_TTL: u8 = 64;

/// Requests travel ground->constellation (and between satellites).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    /// Store one chunk.
    Set { key: ChunkKey, payload: Vec<u8> },
    /// Fetch one chunk.
    Get { key: ChunkKey },
    /// Drop every chunk of a block; gossip `gossip_ttl` hops outward.
    Evict { block: BlockHash, gossip_ttl: u8 },
    /// Send all stored chunks to `to`, then drop them (rotation handoff).
    Migrate { to: SatId },
    /// Liveness/latency probe.
    Ping,
    /// Which chunks of `block` does this satellite hold?  (§3.8 step 8:
    /// the nearest satellite "will return its chunk id and based on that
    /// the shift ... is found" — the distributed, index-free lookup.)
    Query { block: BlockHash },
}

/// Responses travel back to the reply-to address.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Response {
    SetOk,
    GetOk { payload: Vec<u8> },
    GetMiss,
    EvictOk { dropped: u32 },
    MigrateOk { moved: u32 },
    Pong,
    /// Chunk ids of the queried block held locally (possibly empty).
    QueryOk { chunk_ids: Vec<u32> },
    Error { code: u8 },
}

/// A routable message envelope.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Envelope {
    pub dest: SatId,
    pub ttl: u8,
    pub req_id: u64,
    pub reply_to: Option<SocketAddrV4>,
}

impl Envelope {
    pub fn new(dest: SatId, req_id: u64) -> Self {
        Self { dest, ttl: DEFAULT_TTL, req_id, reply_to: None }
    }

    pub fn with_reply_to(mut self, addr: SocketAddr) -> Self {
        if let SocketAddr::V4(v4) = addr {
            self.reply_to = Some(v4);
        }
        self
    }
}

const K_SET: u8 = 1;
const K_GET: u8 = 2;
const K_EVICT: u8 = 3;
const K_MIGRATE: u8 = 4;
const K_PING: u8 = 5;
const K_QUERY: u8 = 6;
const K_SET_OK: u8 = 129;
const K_GET_OK: u8 = 130;
const K_GET_MISS: u8 = 131;
const K_EVICT_OK: u8 = 132;
const K_MIGRATE_OK: u8 = 133;
const K_PONG: u8 = 134;
const K_QUERY_OK: u8 = 135;
const K_ERROR: u8 = 255;

fn put_envelope(out: &mut Vec<u8>, kind: u8, env: &Envelope) {
    out.push(kind);
    out.extend_from_slice(&env.dest.plane.to_le_bytes());
    out.extend_from_slice(&env.dest.slot.to_le_bytes());
    out.push(env.ttl);
    out.extend_from_slice(&env.req_id.to_le_bytes());
    match env.reply_to {
        Some(a) => {
            out.extend_from_slice(&a.ip().octets());
            out.extend_from_slice(&a.port().to_le_bytes());
        }
        None => out.extend_from_slice(&[0u8; 6]),
    }
}

fn get_envelope(data: &[u8]) -> Result<(u8, Envelope)> {
    if data.len() < ENVELOPE_LEN {
        bail!("message shorter than envelope: {}", data.len());
    }
    let kind = data[0];
    let plane = u16::from_le_bytes([data[1], data[2]]);
    let slot = u16::from_le_bytes([data[3], data[4]]);
    let ttl = data[5];
    let req_id = u64::from_le_bytes(data[6..14].try_into().unwrap());
    let ip = Ipv4Addr::new(data[14], data[15], data[16], data[17]);
    let port = u16::from_le_bytes([data[18], data[19]]);
    let reply_to = if ip.is_unspecified() && port == 0 {
        None
    } else {
        Some(SocketAddrV4::new(ip, port))
    };
    Ok((kind, Envelope { dest: SatId::new(plane, slot), ttl, req_id, reply_to }))
}

/// Encode a request with its envelope.
pub fn encode_request(env: &Envelope, req: &Request) -> Vec<u8> {
    let mut out = Vec::with_capacity(ENVELOPE_LEN + 64);
    match req {
        Request::Set { key, payload } => {
            put_envelope(&mut out, K_SET, env);
            out.extend_from_slice(&key.encode());
            out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            out.extend_from_slice(payload);
        }
        Request::Get { key } => {
            put_envelope(&mut out, K_GET, env);
            out.extend_from_slice(&key.encode());
        }
        Request::Evict { block, gossip_ttl } => {
            put_envelope(&mut out, K_EVICT, env);
            out.extend_from_slice(block.as_bytes());
            out.push(*gossip_ttl);
        }
        Request::Migrate { to } => {
            put_envelope(&mut out, K_MIGRATE, env);
            out.extend_from_slice(&to.plane.to_le_bytes());
            out.extend_from_slice(&to.slot.to_le_bytes());
        }
        Request::Ping => put_envelope(&mut out, K_PING, env),
        Request::Query { block } => {
            put_envelope(&mut out, K_QUERY, env);
            out.extend_from_slice(block.as_bytes());
        }
    }
    out
}

/// Decode a request (returns its envelope too).
pub fn decode_request(data: &[u8]) -> Result<(Envelope, Request)> {
    let (kind, env) = get_envelope(data)?;
    let body = &data[ENVELOPE_LEN..];
    let req = match kind {
        K_SET => {
            if body.len() < 40 {
                bail!("short Set body");
            }
            let key = ChunkKey::decode(&body[..36]).ok_or_else(|| anyhow::anyhow!("bad key"))?;
            let len = u32::from_le_bytes(body[36..40].try_into().unwrap()) as usize;
            if body.len() != 40 + len {
                bail!("Set payload length mismatch");
            }
            Request::Set { key, payload: body[40..].to_vec() }
        }
        K_GET => {
            let key = ChunkKey::decode(body).ok_or_else(|| anyhow::anyhow!("bad key"))?;
            Request::Get { key }
        }
        K_EVICT => {
            if body.len() != 33 {
                bail!("bad Evict body");
            }
            let mut h = [0u8; 32];
            h.copy_from_slice(&body[..32]);
            Request::Evict { block: BlockHash(h), gossip_ttl: body[32] }
        }
        K_MIGRATE => {
            if body.len() != 4 {
                bail!("bad Migrate body");
            }
            let plane = u16::from_le_bytes([body[0], body[1]]);
            let slot = u16::from_le_bytes([body[2], body[3]]);
            Request::Migrate { to: SatId::new(plane, slot) }
        }
        K_PING => Request::Ping,
        K_QUERY => {
            if body.len() != 32 {
                bail!("bad Query body");
            }
            let mut h = [0u8; 32];
            h.copy_from_slice(body);
            Request::Query { block: BlockHash(h) }
        }
        k => bail!("unknown request kind {k}"),
    };
    Ok((env, req))
}

/// Encode a response with the request's envelope (dest = requester side).
pub fn encode_response(env: &Envelope, resp: &Response) -> Vec<u8> {
    let mut out = Vec::with_capacity(ENVELOPE_LEN + 16);
    match resp {
        Response::SetOk => put_envelope(&mut out, K_SET_OK, env),
        Response::GetOk { payload } => {
            put_envelope(&mut out, K_GET_OK, env);
            out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            out.extend_from_slice(payload);
        }
        Response::GetMiss => put_envelope(&mut out, K_GET_MISS, env),
        Response::EvictOk { dropped } => {
            put_envelope(&mut out, K_EVICT_OK, env);
            out.extend_from_slice(&dropped.to_le_bytes());
        }
        Response::MigrateOk { moved } => {
            put_envelope(&mut out, K_MIGRATE_OK, env);
            out.extend_from_slice(&moved.to_le_bytes());
        }
        Response::Pong => put_envelope(&mut out, K_PONG, env),
        Response::QueryOk { chunk_ids } => {
            put_envelope(&mut out, K_QUERY_OK, env);
            out.extend_from_slice(&(chunk_ids.len() as u16).to_le_bytes());
            for id in chunk_ids {
                out.extend_from_slice(&id.to_le_bytes());
            }
        }
        Response::Error { code } => {
            put_envelope(&mut out, K_ERROR, env);
            out.push(*code);
        }
    }
    out
}

/// Decode a response.
pub fn decode_response(data: &[u8]) -> Result<(Envelope, Response)> {
    let (kind, env) = get_envelope(data)?;
    let body = &data[ENVELOPE_LEN..];
    let resp = match kind {
        K_SET_OK => Response::SetOk,
        K_GET_OK => {
            if body.len() < 4 {
                bail!("short GetOk");
            }
            let len = u32::from_le_bytes(body[..4].try_into().unwrap()) as usize;
            if body.len() != 4 + len {
                bail!("GetOk payload length mismatch");
            }
            Response::GetOk { payload: body[4..].to_vec() }
        }
        K_GET_MISS => Response::GetMiss,
        K_EVICT_OK => {
            Response::EvictOk { dropped: u32::from_le_bytes(body.try_into()?) }
        }
        K_MIGRATE_OK => {
            Response::MigrateOk { moved: u32::from_le_bytes(body.try_into()?) }
        }
        K_PONG => Response::Pong,
        K_QUERY_OK => {
            if body.len() < 2 {
                bail!("short QueryOk");
            }
            let n = u16::from_le_bytes([body[0], body[1]]) as usize;
            if body.len() != 2 + 4 * n {
                bail!("QueryOk length mismatch");
            }
            let chunk_ids = body[2..]
                .chunks_exact(4)
                .map(|b| u32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            Response::QueryOk { chunk_ids }
        }
        K_ERROR => Response::Error { code: *body.first().unwrap_or(&0) },
        k => bail!("unknown response kind {k}"),
    };
    Ok((env, resp))
}

/// Is this user-data a request (vs a response)?  Routing uses this to know
/// whether an arriving packet needs handling or is a passing response.
pub fn is_request(data: &[u8]) -> bool {
    matches!(data.first(), Some(k) if *k < 128)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env() -> Envelope {
        Envelope::new(SatId::new(3, 14), 0xDEAD_BEEF_0123)
            .with_reply_to("10.0.0.7:9000".parse().unwrap())
    }

    #[test]
    fn request_roundtrips() {
        let key = ChunkKey::new(BlockHash([7u8; 32]), 21);
        let cases = vec![
            Request::Set { key, payload: vec![1, 2, 3, 4, 5] },
            Request::Get { key },
            Request::Evict { block: BlockHash([9u8; 32]), gossip_ttl: 3 },
            Request::Migrate { to: SatId::new(1, 2) },
            Request::Ping,
            Request::Query { block: BlockHash([3u8; 32]) },
        ];
        for req in cases {
            let e = env();
            let bytes = encode_request(&e, &req);
            assert!(is_request(&bytes));
            let (e2, r2) = decode_request(&bytes).unwrap();
            assert_eq!(e2, e);
            assert_eq!(r2, req);
        }
    }

    #[test]
    fn response_roundtrips() {
        let cases = vec![
            Response::SetOk,
            Response::GetOk { payload: vec![0xA; 6000] },
            Response::GetMiss,
            Response::EvictOk { dropped: 17 },
            Response::MigrateOk { moved: 42 },
            Response::Pong,
            Response::QueryOk { chunk_ids: vec![] },
            Response::QueryOk { chunk_ids: vec![0, 10, 20, u32::MAX] },
            Response::Error { code: 2 },
        ];
        for resp in cases {
            let e = env();
            let bytes = encode_response(&e, &resp);
            assert!(!is_request(&bytes));
            let (e2, r2) = decode_response(&bytes).unwrap();
            assert_eq!(e2, e);
            assert_eq!(r2, resp);
        }
    }

    #[test]
    fn no_reply_to_encodes_zeros() {
        let e = Envelope::new(SatId::new(0, 0), 1);
        let bytes = encode_request(&e, &Request::Ping);
        let (e2, _) = decode_request(&bytes).unwrap();
        assert_eq!(e2.reply_to, None);
    }

    #[test]
    fn corrupt_messages_rejected() {
        assert!(decode_request(&[1, 2, 3]).is_err());
        let e = env();
        let mut bytes = encode_request(
            &e,
            &Request::Set {
                key: ChunkKey::new(BlockHash([0; 32]), 0),
                payload: vec![1, 2, 3],
            },
        );
        bytes.truncate(bytes.len() - 1); // payload shorter than declared
        assert!(decode_request(&bytes).is_err());
        let mut bad = encode_request(&e, &Request::Ping);
        bad[0] = 77; // unknown kind
        assert!(decode_request(&bad).is_err());
    }

    #[test]
    fn six_kb_chunk_fits_one_spp_packet() {
        // the paper's chunk size must fit one Space Packet (<= 65536)
        let key = ChunkKey::new(BlockHash([1; 32]), 0);
        let req = Request::Set { key, payload: vec![0u8; 6000] };
        let bytes = encode_request(&env(), &req);
        assert!(bytes.len() <= 65536);
        let framed =
            crate::net::spp::frame(crate::net::spp::PacketType::Telecommand, 5, 0, &bytes);
        let (_, body) = crate::net::spp::deframe(&framed).unwrap();
        assert_eq!(decode_request(body).unwrap().1, req);
    }
}
