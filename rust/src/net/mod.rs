//! Networking substrate: CCSDS Space Packet Protocol framing ([`spp`]),
//! the SkyMemory wire messages ([`messages`]), the [`transport::Transport`]
//! abstraction the KVC manager drives, and the UDP implementation
//! ([`udp`]) used by the real multi-process fleet.
//!
//! The paper's testbed speaks "CCSDS Space Packet Protocol over UDP" [1]
//! between the LLM host and the cFS satellites; we do exactly that: every
//! datagram is a Space Packet whose user data field carries one SkyMemory
//! message.
//!
//! # Timing plane vs data plane
//!
//! Since the `net::sched` rewire the stack separates two concerns that
//! the transports used to conflate:
//!
//! * **Data plane** — *what happens*: a [`transport::Transport`] routes a
//!   request to a satellite (direct ground uplink inside the reliable-LOS
//!   window, closest-satellite relay plus ISL mesh otherwise), applies
//!   fault gating ([`faults::FaultyTransport`]) and byte/hop accounting,
//!   and returns the response.  [`transport::Transport::request_untimed`]
//!   is the pure data-plane entry point.
//! * **Timing plane** — *when it happens*: the [`sched::NetScheduler`]
//!   discrete-event engine assigns virtual-time serialization, queueing
//!   and propagation delays per link ([`sched::LinkKey`]) using the
//!   transport's [`transport::LinkModel`] and per-destination
//!   [`transport::RouteInfo`], with a configurable in-flight window per
//!   link.  All §3.8 chunk fan-out (single-shell and federated managers,
//!   cross-shell evacuation drains) flows through it — no OS threads.
//!
//! Single, non-fan-out requests (probes, evictions, migrations) still use
//! the transports' own serial latency accounting via
//! [`transport::Transport::request`].

pub mod faults;
pub mod messages;
pub mod sched;
pub mod spp;
pub mod transport;
pub mod udp;
