//! Networking substrate: CCSDS Space Packet Protocol framing ([`spp`]),
//! the SkyMemory wire messages ([`messages`]), the [`transport::Transport`]
//! abstraction the KVC manager drives, and the UDP implementation
//! ([`udp`]) used by the real multi-process fleet.
//!
//! The paper's testbed speaks "CCSDS Space Packet Protocol over UDP" [1]
//! between the LLM host and the cFS satellites; we do exactly that: every
//! datagram is a Space Packet whose user data field carries one SkyMemory
//! message.

pub mod faults;
pub mod messages;
pub mod spp;
pub mod transport;
pub mod udp;
