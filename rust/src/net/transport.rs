//! Transport abstraction the KVC manager drives (§3.8's "lookups always
//! start at the nearest satellite"), with the in-process implementation.
//!
//! A transport answers one question: deliver this request to that
//! satellite and give me the response.  The *entry* into the constellation
//! is the transport's business: a LOS satellite is contacted directly
//! (ground uplink), anything else goes up to the closest satellite and
//! rides the ISL mesh.
//!
//! [`InProcTransport`] can optionally emulate link latency in wall-clock
//! time (slant-range uplink + per-hop ISL + serialization delay) so the
//! Table 3 end-to-end run shows the same *shape* as the paper's testbed
//! without real radios.

use crate::constellation::geometry::Geometry;
use crate::constellation::los::LosGrid;
use crate::constellation::topology::SatId;
use crate::kvc::block::BlockHash;
use crate::kvc::chunk::ChunkKey;
use crate::net::messages::{Envelope, Request, Response};
use crate::satellite::fleet::Fleet;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Duration;

/// Link latency emulation for the in-proc transport.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    pub geometry: Geometry,
    /// Serialization bandwidth of a link, bits/s (ISL FSO and uplink).
    pub bandwidth_bps: f64,
    /// Multiply emulated delays by this factor; 0.0 disables sleeping
    /// (latency is still *accounted* in `sim_latency_ns`).
    pub sleep_scale: f64,
}

impl LinkModel {
    pub fn laser_defaults(geometry: Geometry) -> Self {
        Self { geometry, bandwidth_bps: 1e9, sleep_scale: 1.0 }
    }

    /// Pure propagation: slant-range ground uplink from `entry` plus
    /// `hops` worst-case ISL hops (no payload term).
    pub fn propagation_s(&self, entry_ground_cells: (usize, usize), hops: usize) -> f64 {
        let up = self
            .geometry
            .ground_latency_s(entry_ground_cells.0, entry_ground_cells.1);
        up + hops as f64 * self.geometry.worst_hop_latency_s()
    }

    /// Serialization time of `bytes` at this link's bandwidth.
    pub fn serial_s(&self, bytes: usize) -> f64 {
        (bytes as f64 * 8.0) / self.bandwidth_bps
    }

    /// One-way latency for a request entering at `entry` (ground uplink)
    /// and traversing `hops` ISL hops carrying `bytes` of payload.
    /// Exactly [`Self::propagation_s`] + [`Self::serial_s`] — the
    /// `net::sched` timing plane uses the two terms separately.
    pub fn one_way_s(&self, entry_ground_cells: (usize, usize), hops: usize, bytes: usize) -> f64 {
        self.propagation_s(entry_ground_cells, hops) + self.serial_s(bytes)
    }
}

/// Timing-plane description of the path one request takes: where it
/// enters the constellation and what it traverses.  Consumed by the
/// [`crate::net::sched`] virtual-time scheduler; the data plane never
/// looks at it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouteInfo {
    /// Satellite the request enters at (the destination itself when it is
    /// inside the reliable-LOS window, else the closest satellite).
    pub entry: SatId,
    /// ISL hops from the entry satellite to the destination.
    pub isl_hops: usize,
    /// Ground-grid cells (slots, planes) from the sub-stellar point to
    /// the entry satellite (drives the slant-range uplink latency).
    pub ground_cells: (usize, usize),
}

/// Counters every transport keeps (exported to /metrics).
#[derive(Debug, Default)]
pub struct TransportStats {
    pub requests: AtomicU64,
    pub misses: AtomicU64,
    pub errors: AtomicU64,
    pub isl_hops: AtomicU64,
    /// Payload bytes carried over ISL links, weighted by hop count
    /// (request + response bytes x hops — the mesh-capacity figure the
    /// scenario harness reports as "bytes on ISL").
    pub isl_bytes: AtomicU64,
    /// Accumulated emulated network latency (ns), whether or not slept.
    pub sim_latency_ns: AtomicU64,
    /// Forwards dropped because the envelope TTL expired in the mesh
    /// (previously a silent drop, indistinguishable from satellite loss).
    pub dropped_ttl: AtomicU64,
    /// Datagrams discarded as stale or undecodable (responses to a
    /// request that already timed out, deframe/decode failures).
    pub dropped_stale: AtomicU64,
    /// Forwards dropped because the next hop had no known address.
    pub dropped_unroutable: AtomicU64,
}

/// A synchronous satellite-cache transport.  Thread-safe: the manager
/// fans chunk operations out across threads (§3.1: "parallelism both in
/// setting and getting a single KVC").
pub trait Transport: Send + Sync {
    /// Deliver a request to a satellite and await its response.
    fn request(&self, dest: SatId, req: Request) -> Result<Response>;

    /// The satellite currently closest to the ground host (lookup entry).
    fn closest(&self) -> SatId;

    /// Advance the ground model to rotation epoch `epoch` (the transport
    /// updates its LOS window; satellites migrate separately).
    fn set_epoch(&self, epoch: u64);

    /// Current rotation epoch of the ground view.
    fn epoch(&self) -> u64;

    fn stats(&self) -> &TransportStats;

    // --- timing plane ---------------------------------------------------

    /// Data-plane-only delivery: identical routing, fault gating and
    /// byte/hop accounting to [`Transport::request`], but **no** latency
    /// accounting or sleeping — the caller (the [`crate::net::sched`]
    /// scheduler) owns timing.  Default: plain `request`.
    fn request_untimed(&self, dest: SatId, req: Request) -> Result<Response> {
        self.request(dest, req)
    }

    /// Timing-plane description of the path to `dest` (entry satellite,
    /// ISL hops, ground cells).  Default: direct zero-hop delivery.
    fn route_info(&self, dest: SatId) -> RouteInfo {
        RouteInfo { entry: dest, isl_hops: 0, ground_cells: (0, 0) }
    }

    /// The link model driving the timing plane, when this transport has
    /// one (the in-proc transport's latency emulation parameters).
    fn link_model(&self) -> Option<LinkModel> {
        None
    }

    // --- conveniences ---------------------------------------------------

    fn get_chunk(&self, dest: SatId, key: ChunkKey) -> Result<Option<Vec<u8>>> {
        match self.request(dest, Request::Get { key })? {
            Response::GetOk { payload } => Ok(Some(payload)),
            Response::GetMiss => {
                self.stats().misses.fetch_add(1, Ordering::Relaxed);
                Ok(None)
            }
            r => bail!("unexpected response to Get: {r:?}"),
        }
    }

    fn set_chunk(&self, dest: SatId, key: ChunkKey, payload: Vec<u8>) -> Result<()> {
        match self.request(dest, Request::Set { key, payload })? {
            Response::SetOk => Ok(()),
            r => bail!("unexpected response to Set: {r:?}"),
        }
    }

    fn evict_block(&self, dest: SatId, block: BlockHash, gossip_ttl: u8) -> Result<u32> {
        match self.request(dest, Request::Evict { block, gossip_ttl })? {
            Response::EvictOk { dropped } => Ok(dropped),
            r => bail!("unexpected response to Evict: {r:?}"),
        }
    }

    fn migrate(&self, from: SatId, to: SatId) -> Result<u32> {
        match self.request(from, Request::Migrate { to })? {
            Response::MigrateOk { moved } => Ok(moved),
            r => bail!("unexpected response to Migrate: {r:?}"),
        }
    }

    fn ping(&self, dest: SatId) -> Result<()> {
        match self.request(dest, Request::Ping)? {
            Response::Pong => Ok(()),
            r => bail!("unexpected response to Ping: {r:?}"),
        }
    }
}

/// Ground-station view shared by transports: the rotating LOS window.
pub struct GroundView {
    /// Centre satellite in the epoch-0 frame (rotation subtracts the
    /// epoch from its slot); a ground-station handover rebases it.
    base_center: RwLock<SatId>,
    half_slots: usize,
    half_planes: usize,
    epoch: RwLock<u64>,
    sats_per_plane: usize,
}

impl GroundView {
    pub fn new(initial_center: SatId, los: &LosGrid, sats_per_plane: usize) -> Self {
        Self {
            base_center: RwLock::new(initial_center),
            half_slots: los.half_slots,
            half_planes: los.half_planes,
            epoch: RwLock::new(0),
            sats_per_plane,
        }
    }

    pub fn epoch(&self) -> u64 {
        *self.epoch.read().unwrap()
    }

    pub fn set_epoch(&self, e: u64) {
        *self.epoch.write().unwrap() = e;
    }

    pub fn center(&self) -> SatId {
        let base = *self.base_center.read().unwrap();
        let e = self.epoch();
        let slot =
            (base.slot as i64 - e as i64).rem_euclid(self.sats_per_plane as i64) as u16;
        SatId::new(base.plane, slot)
    }

    /// Ground-station handover: re-home the view so that `new_center` is
    /// the satellite overhead *at the current epoch*.  Rotation continues
    /// from there (the centre keeps sliding one slot west per epoch).
    /// Chunk layouts written under the old ground station are not
    /// re-mapped — the failure-injection scenarios use exactly that
    /// locality loss.
    pub fn handover(&self, new_center: SatId) {
        let e = self.epoch();
        let slot = (new_center.slot as i64 + e as i64)
            .rem_euclid(self.sats_per_plane as i64) as u16;
        *self.base_center.write().unwrap() = SatId::new(new_center.plane, slot);
    }

    pub fn los(&self) -> LosGrid {
        LosGrid::new(self.center(), self.half_slots, self.half_planes)
    }
}

/// In-process transport over a [`Fleet`].
pub struct InProcTransport {
    pub fleet: Arc<Fleet>,
    pub ground: GroundView,
    pub link: Option<LinkModel>,
    stats: TransportStats,
    req_counter: AtomicU64,
}

impl InProcTransport {
    pub fn new(fleet: Arc<Fleet>, ground: GroundView, link: Option<LinkModel>) -> Self {
        Self { fleet, ground, link, stats: TransportStats::default(), req_counter: AtomicU64::new(0) }
    }

    /// Entry satellite for a destination: direct if LOS, else the closest
    /// satellite relays into the mesh.
    fn entry_for(&self, dest: SatId) -> SatId {
        let los = self.ground.los();
        if los.contains(&self.fleet.torus, dest) {
            dest
        } else {
            self.ground.center()
        }
    }

    fn emulate_latency(&self, entry: SatId, hops: usize, bytes: usize) {
        if let Some(link) = &self.link {
            let center = self.ground.center();
            let dp = self.fleet.torus.plane_distance(center, entry);
            let ds = self.fleet.torus.slot_distance(center, entry);
            // round trip: request up + response down
            let s = 2.0 * link.one_way_s((ds, dp), hops, bytes);
            self.stats
                .sim_latency_ns
                .fetch_add((s * 1e9) as u64, Ordering::Relaxed);
            if link.sleep_scale > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(s * link.sleep_scale));
            }
        }
    }

    /// Shared body of [`Transport::request`] / [`Transport::request_untimed`]:
    /// the data plane always runs; only the timing plane is optional.
    fn deliver(&self, dest: SatId, req: Request, timed: bool) -> Result<Response> {
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        let req_id = self.req_counter.fetch_add(1, Ordering::Relaxed);
        let entry = self.entry_for(dest);
        let bytes = match &req {
            Request::Set { payload, .. } => payload.len(),
            _ => 64,
        };
        let env = Envelope::new(dest, req_id);
        let (resp, hops) = self.fleet.deliver(entry, env, req);
        self.stats.isl_hops.fetch_add(hops as u64, Ordering::Relaxed);
        let resp_bytes = match &resp {
            Response::GetOk { payload } => payload.len().max(bytes),
            _ => bytes,
        };
        self.stats
            .isl_bytes
            .fetch_add(hops as u64 * (bytes + resp_bytes) as u64, Ordering::Relaxed);
        if timed {
            self.emulate_latency(entry, hops, resp_bytes);
        }
        if let Response::Error { code } = resp {
            self.stats.errors.fetch_add(1, Ordering::Relaxed);
            bail!("satellite error code {code}");
        }
        Ok(resp)
    }
}

impl Transport for InProcTransport {
    fn request(&self, dest: SatId, req: Request) -> Result<Response> {
        self.deliver(dest, req, true)
    }

    fn request_untimed(&self, dest: SatId, req: Request) -> Result<Response> {
        self.deliver(dest, req, false)
    }

    fn route_info(&self, dest: SatId) -> RouteInfo {
        let entry = self.entry_for(dest);
        let center = self.ground.center();
        let torus = &self.fleet.torus;
        RouteInfo {
            entry,
            isl_hops: torus.hops(entry, dest),
            ground_cells: (torus.slot_distance(center, entry), torus.plane_distance(center, entry)),
        }
    }

    fn link_model(&self) -> Option<LinkModel> {
        self.link
    }

    fn closest(&self) -> SatId {
        self.ground.center()
    }

    fn set_epoch(&self, epoch: u64) {
        self.ground.set_epoch(epoch);
    }

    fn epoch(&self) -> u64 {
        self.ground.epoch()
    }

    fn stats(&self) -> &TransportStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constellation::topology::Torus;
    use crate::kvc::eviction::EvictionPolicy;

    fn transport(link: Option<LinkModel>) -> InProcTransport {
        let torus = Torus::new(5, 19);
        let fleet = Arc::new(Fleet::new(torus, 1 << 20, EvictionPolicy::Gossip));
        let center = SatId::new(2, 9);
        let los = LosGrid::new(center, 2, 2);
        let ground = GroundView::new(center, &los, torus.sats_per_plane);
        InProcTransport::new(fleet, ground, link)
    }

    fn key(b: u8, c: u32) -> ChunkKey {
        ChunkKey::new(BlockHash([b; 32]), c)
    }

    #[test]
    fn chunk_roundtrip_via_trait() {
        let t = transport(None);
        let dest = SatId::new(2, 10); // in LOS
        t.set_chunk(dest, key(1, 0), vec![1, 2, 3]).unwrap();
        assert_eq!(t.get_chunk(dest, key(1, 0)).unwrap(), Some(vec![1, 2, 3]));
        assert_eq!(t.get_chunk(dest, key(1, 9)).unwrap(), None);
        assert_eq!(t.stats().misses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn los_destinations_enter_directly() {
        let t = transport(None);
        let in_los = SatId::new(1, 8);
        t.ping(in_los).unwrap();
        assert_eq!(t.stats().isl_hops.load(Ordering::Relaxed), 0, "direct uplink");
        let far = SatId::new(4, 0);
        t.ping(far).unwrap();
        let hops = t.fleet.torus.hops(SatId::new(2, 9), far) as u64;
        assert_eq!(t.stats().isl_hops.load(Ordering::Relaxed), hops);
    }

    #[test]
    fn rotation_moves_the_entry_point() {
        let t = transport(None);
        assert_eq!(t.closest(), SatId::new(2, 9));
        t.set_epoch(3);
        assert_eq!(t.closest(), SatId::new(2, 6));
        // wraps
        t.set_epoch(19);
        assert_eq!(t.closest(), SatId::new(2, 9));
    }

    #[test]
    fn ground_handover_rebases_then_keeps_rotating() {
        let t = transport(None);
        t.set_epoch(4);
        assert_eq!(t.closest(), SatId::new(2, 5));
        // handover to a station under plane 4
        t.ground.handover(SatId::new(4, 11));
        assert_eq!(t.closest(), SatId::new(4, 11), "new centre at current epoch");
        // rotation continues from the new home
        t.set_epoch(6);
        assert_eq!(t.closest(), SatId::new(4, 9));
    }

    #[test]
    fn latency_accounting_without_sleeping() {
        let g = Geometry::new(550.0, 19, 5);
        let mut link = LinkModel::laser_defaults(g);
        link.sleep_scale = 0.0;
        let t = transport(Some(link));
        let far = SatId::new(4, 0);
        t.set_chunk(far, key(1, 0), vec![0u8; 6000]).unwrap();
        let ns = t.stats().sim_latency_ns.load(Ordering::Relaxed);
        assert!(ns > 1_000_000, "multi-hop + uplink should exceed 1 ms, got {ns} ns");
    }

    #[test]
    fn route_info_mirrors_the_entry_model() {
        let t = transport(None);
        let center = SatId::new(2, 9);
        // LOS destination: direct uplink, no mesh
        let near = SatId::new(1, 8);
        let ri = t.route_info(near);
        assert_eq!(ri.entry, near);
        assert_eq!(ri.isl_hops, 0);
        assert_eq!(ri.ground_cells, (1, 1));
        // far destination: enters at the centre, rides the mesh
        let far = SatId::new(4, 0);
        let ri = t.route_info(far);
        assert_eq!(ri.entry, center);
        assert_eq!(ri.isl_hops, t.fleet.torus.hops(center, far));
        assert_eq!(ri.ground_cells, (0, 0), "the centre is the sub-stellar point");
    }

    #[test]
    fn untimed_requests_account_bytes_but_not_latency() {
        let g = Geometry::new(550.0, 19, 5);
        let mut link = LinkModel::laser_defaults(g);
        link.sleep_scale = 0.0;
        let t = transport(Some(link));
        assert_eq!(t.link_model().map(|l| l.bandwidth_bps), Some(link.bandwidth_bps));
        let far = SatId::new(4, 0);
        t.request_untimed(far, Request::Set { key: key(1, 0), payload: vec![0u8; 6000] })
            .unwrap();
        assert_eq!(t.stats().sim_latency_ns.load(Ordering::Relaxed), 0, "timing plane elsewhere");
        assert!(t.stats().isl_hops.load(Ordering::Relaxed) > 0, "data plane still accounted");
        assert!(t.stats().isl_bytes.load(Ordering::Relaxed) > 0);
        assert_eq!(t.stats().requests.load(Ordering::Relaxed), 1);
        // the timed path on the same transport does accrue latency
        t.set_chunk(far, key(1, 1), vec![0u8; 6000]).unwrap();
        assert!(t.stats().sim_latency_ns.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn eviction_via_trait() {
        let t = transport(None);
        let dest = SatId::new(2, 9);
        t.set_chunk(dest, key(7, 0), vec![1]).unwrap();
        assert_eq!(t.evict_block(dest, BlockHash([7; 32]), 0).unwrap(), 1);
        assert_eq!(t.get_chunk(dest, key(7, 0)).unwrap(), None);
    }
}
