//! CCSDS Space Packet Protocol primary header (CCSDS 133.0-B-2), from
//! scratch.  The paper's testbed frames all LLM <-> constellation traffic
//! as Space Packets over UDP; we implement the 6-byte primary header:
//!
//! ```text
//!  bits  3        1      1        11      2        14       16
//!       +--------+------+--------+-------+--------+--------+------------+
//!       |version | type | sechdr | APID  | seqflg | seqcnt | data len-1 |
//!       +--------+------+--------+-------+--------+--------+------------+
//! ```

use anyhow::{bail, Result};

/// Packet type bit: telecommand (request) or telemetry (response).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PacketType {
    /// Ground -> satellite (or satellite->satellite request): TC = 1.
    Telecommand,
    /// Satellite -> ground response: TM = 0.
    Telemetry,
}

/// A parsed Space Packet primary header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SppHeader {
    pub packet_type: PacketType,
    pub secondary_header: bool,
    /// Application process id, 11 bits (we use the satellite's linear id).
    pub apid: u16,
    /// Sequence flags, 2 bits — always 0b11 (unsegmented) here.
    pub sequence_flags: u8,
    /// Packet sequence count, 14 bits.
    pub sequence_count: u16,
    /// User-data length in bytes (the header encodes `len - 1`).
    pub data_len: usize,
}

pub const SPP_HEADER_LEN: usize = 6;
pub const APID_MAX: u16 = 0x7FF;
const SEQ_MAX: u16 = 0x3FFF;
/// CCSDS version number (3 bits) — always 0 for Space Packets.
const VERSION: u16 = 0;

impl SppHeader {
    pub fn new(packet_type: PacketType, apid: u16, sequence_count: u16, data_len: usize) -> Self {
        assert!(apid <= APID_MAX, "APID is 11 bits");
        assert!(data_len >= 1 && data_len <= 65536, "SPP user data is 1..=65536 bytes");
        Self {
            packet_type,
            secondary_header: false,
            apid,
            sequence_flags: 0b11,
            sequence_count: sequence_count & SEQ_MAX,
            data_len,
        }
    }

    /// Serialize the 6-byte primary header.
    pub fn encode(&self) -> [u8; SPP_HEADER_LEN] {
        let type_bit = match self.packet_type {
            PacketType::Telecommand => 1u16,
            PacketType::Telemetry => 0u16,
        };
        let word0: u16 = (VERSION << 13)
            | (type_bit << 12)
            | ((self.secondary_header as u16) << 11)
            | (self.apid & APID_MAX);
        let word1: u16 =
            ((self.sequence_flags as u16 & 0b11) << 14) | (self.sequence_count & SEQ_MAX);
        let word2: u16 = (self.data_len - 1) as u16;
        let mut out = [0u8; SPP_HEADER_LEN];
        out[0..2].copy_from_slice(&word0.to_be_bytes());
        out[2..4].copy_from_slice(&word1.to_be_bytes());
        out[4..6].copy_from_slice(&word2.to_be_bytes());
        out
    }

    /// Parse a 6-byte primary header.
    pub fn decode(bytes: &[u8]) -> Result<Self> {
        if bytes.len() < SPP_HEADER_LEN {
            bail!("short SPP header: {} bytes", bytes.len());
        }
        let word0 = u16::from_be_bytes([bytes[0], bytes[1]]);
        let word1 = u16::from_be_bytes([bytes[2], bytes[3]]);
        let word2 = u16::from_be_bytes([bytes[4], bytes[5]]);
        let version = word0 >> 13;
        if version != VERSION {
            bail!("unsupported SPP version {version}");
        }
        Ok(Self {
            packet_type: if word0 & (1 << 12) != 0 {
                PacketType::Telecommand
            } else {
                PacketType::Telemetry
            },
            secondary_header: word0 & (1 << 11) != 0,
            apid: word0 & APID_MAX,
            sequence_flags: (word1 >> 14) as u8,
            sequence_count: word1 & SEQ_MAX,
            data_len: word2 as usize + 1,
        })
    }
}

/// Frame user data as one Space Packet.
pub fn frame(packet_type: PacketType, apid: u16, seq: u16, user_data: &[u8]) -> Vec<u8> {
    let header = SppHeader::new(packet_type, apid, seq, user_data.len());
    let mut out = Vec::with_capacity(SPP_HEADER_LEN + user_data.len());
    out.extend_from_slice(&header.encode());
    out.extend_from_slice(user_data);
    out
}

/// Split a datagram into (header, user data), validating the length field.
pub fn deframe(datagram: &[u8]) -> Result<(SppHeader, &[u8])> {
    let header = SppHeader::decode(datagram)?;
    let body = &datagram[SPP_HEADER_LEN..];
    if body.len() != header.data_len {
        bail!("SPP length mismatch: header says {}, got {}", header.data_len, body.len());
    }
    Ok((header, body))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_roundtrip() {
        for (pt, apid, seq, len) in [
            (PacketType::Telecommand, 0u16, 0u16, 1usize),
            (PacketType::Telemetry, 0x7FF, 0x3FFF, 65536),
            (PacketType::Telecommand, 95, 1234, 6000),
        ] {
            let h = SppHeader::new(pt, apid, seq, len);
            let dec = SppHeader::decode(&h.encode()).unwrap();
            assert_eq!(h, dec);
        }
    }

    #[test]
    fn known_bit_layout() {
        // TC packet, APID 3, unsegmented, seq 1, 2 bytes of data:
        // word0 = 0b000_1_0_00000000011 = 0x1003
        // word1 = 0b11_00000000000001  = 0xC001
        // word2 = 0x0001
        let h = SppHeader::new(PacketType::Telecommand, 3, 1, 2);
        assert_eq!(h.encode(), [0x10, 0x03, 0xC0, 0x01, 0x00, 0x01]);
    }

    #[test]
    fn frame_deframe_roundtrip() {
        let data = vec![0xABu8; 6000];
        let pkt = frame(PacketType::Telecommand, 42, 7, &data);
        assert_eq!(pkt.len(), SPP_HEADER_LEN + 6000);
        let (h, body) = deframe(&pkt).unwrap();
        assert_eq!(h.apid, 42);
        assert_eq!(h.sequence_count, 7);
        assert_eq!(body, &data[..]);
    }

    #[test]
    fn length_mismatch_rejected() {
        let mut pkt = frame(PacketType::Telemetry, 1, 0, &[1, 2, 3]);
        pkt.push(0); // trailing garbage
        assert!(deframe(&pkt).is_err());
        pkt.truncate(7); // truncated body
        assert!(deframe(&pkt).is_err());
    }

    #[test]
    fn short_and_bad_version_rejected() {
        assert!(SppHeader::decode(&[0u8; 5]).is_err());
        let mut bytes = SppHeader::new(PacketType::Telemetry, 1, 0, 1).encode();
        bytes[0] |= 0b0110_0000; // version 3
        assert!(SppHeader::decode(&bytes).is_err());
    }

    #[test]
    fn sequence_count_wraps_at_14_bits() {
        let h = SppHeader::new(PacketType::Telemetry, 1, SEQ_MAX + 5, 1);
        assert_eq!(h.sequence_count, 4);
    }

    #[test]
    #[should_panic]
    fn zero_length_data_panics() {
        SppHeader::new(PacketType::Telemetry, 1, 0, 0);
    }
}
