//! Line-of-sight window: which satellites a ground host can reach directly.
//!
//! §2: "From a single point on Earth, as many as 10-20 LEO satellites may
//! be visible."  We model LOS as an axis-aligned box of grid cells around
//! the sub-stellar (closest) satellite, derived from a minimum elevation
//! angle: a satellite whose sub-satellite point is ground distance `d` away
//! is visible when `atan(h / d) >= min_elevation` (flat-earth local
//! approximation, adequate for the few-hundred-km LOS radii of LEO).

use super::geometry::Geometry;
use super::topology::{SatId, Torus};

/// A rectangular LOS window on the torus, centred on the closest satellite.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LosGrid {
    pub center: SatId,
    /// Half-extent in slots (east-west).
    pub half_slots: usize,
    /// Half-extent in planes (north-south).
    pub half_planes: usize,
}

impl LosGrid {
    pub fn new(center: SatId, half_slots: usize, half_planes: usize) -> Self {
        Self { center, half_slots, half_planes }
    }

    /// Derive the LOS window from geometry and a minimum elevation angle.
    pub fn from_geometry(geo: &Geometry, center: SatId, min_elevation_deg: f64) -> Self {
        let d_max = los_ground_radius_km(geo.altitude_km, min_elevation_deg);
        let dm = geo.intra_plane_distance_km();
        let dn = geo.inter_plane_distance_km();
        let half_slots = (d_max / dm).floor() as usize;
        let half_planes = (d_max / dn).floor() as usize;
        Self { center, half_slots, half_planes }
    }

    /// A square window big enough to hold `n_servers` cells (the §3.7
    /// bounding box: side = ceil(sqrt(n))).
    pub fn square_for_servers(center: SatId, n_servers: usize) -> Self {
        let side = (n_servers as f64).sqrt().ceil() as usize;
        // side w -> half extents (left, right) = (floor((w-1)/2), rest).
        // We keep symmetric half extents; odd sides centre exactly.
        Self::new(center, side / 2, side / 2)
    }

    pub fn width(&self) -> usize {
        2 * self.half_slots + 1
    }

    pub fn height(&self) -> usize {
        2 * self.half_planes + 1
    }

    pub fn cell_count(&self) -> usize {
        self.width() * self.height()
    }

    /// Is `sat` inside the window (torus-aware)?
    pub fn contains(&self, torus: &Torus, sat: SatId) -> bool {
        let (dp, ds) = torus.signed_offset(self.center, sat);
        dp.unsigned_abs() as usize <= self.half_planes
            && ds.unsigned_abs() as usize <= self.half_slots
    }

    /// All cells of the window, row-major (north-west to south-east), the
    /// order Figure 4's rotation-aware numbering uses.
    pub fn cells_row_major(&self, torus: &Torus) -> Vec<SatId> {
        let mut out = Vec::with_capacity(self.cell_count());
        for dp in -(self.half_planes as i32)..=(self.half_planes as i32) {
            for ds in -(self.half_slots as i32)..=(self.half_slots as i32) {
                out.push(torus.offset(self.center, dp, ds));
            }
        }
        out
    }

    /// The eastmost (exiting) column at the current position.
    pub fn east_column(&self, torus: &Torus) -> Vec<SatId> {
        self.column(torus, self.half_slots as i32)
    }

    /// The column that enters when the window shifts one slot west.
    pub fn entering_west_column(&self, torus: &Torus) -> Vec<SatId> {
        self.column(torus, -(self.half_slots as i32) - 1)
    }

    fn column(&self, torus: &Torus, ds: i32) -> Vec<SatId> {
        (-(self.half_planes as i32)..=(self.half_planes as i32))
            .map(|dp| torus.offset(self.center, dp, ds))
            .collect()
    }

    /// The same window after the constellation advanced `epochs` slot
    /// shifts (window slides west with the overhead satellite).
    pub fn shifted(&self, torus: &Torus, epochs: u64) -> Self {
        Self {
            center: torus.offset(self.center, 0, -((epochs % torus.sats_per_plane as u64) as i32)),
            ..*self
        }
    }
}

/// Ground radius of the LOS disc for a given altitude and min elevation.
pub fn los_ground_radius_km(altitude_km: f64, min_elevation_deg: f64) -> f64 {
    assert!(min_elevation_deg > 0.0 && min_elevation_deg < 90.0);
    altitude_km / min_elevation_deg.to_radians().tan()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ten_to_twenty_sats_visible_for_dense_shell() {
        // A Starlink-like dense shell (72 sats x 36 planes at 550 km)
        // puts 10-20 satellites in LOS at a ~18 deg mask — §2: "as many
        // as 10-20 LEO satellites may be visible".
        let geo = Geometry::new(550.0, 72, 36);
        let g = LosGrid::from_geometry(&geo, SatId::new(5, 5), 18.0);
        assert!(
            (10..=25).contains(&g.cell_count()),
            "visible={} ({}x{})",
            g.cell_count(),
            g.width(),
            g.height()
        );
    }

    #[test]
    fn lower_mask_sees_more() {
        let geo = Geometry::new(550.0, 40, 20);
        let lo = LosGrid::from_geometry(&geo, SatId::new(0, 0), 15.0);
        let hi = LosGrid::from_geometry(&geo, SatId::new(0, 0), 45.0);
        assert!(lo.cell_count() > hi.cell_count());
    }

    #[test]
    fn square_for_servers_matches_paper_sizes() {
        let c = SatId::new(8, 8);
        for (n, side) in [(9, 3), (25, 5), (49, 7), (81, 9)] {
            let g = LosGrid::square_for_servers(c, n);
            assert_eq!(g.width(), side, "n={n}");
            assert_eq!(g.height(), side);
            assert_eq!(g.cell_count(), n);
        }
    }

    #[test]
    fn contains_is_torus_aware() {
        let torus = Torus::new(6, 8);
        let g = LosGrid::new(SatId::new(0, 0), 1, 1);
        assert!(g.contains(&torus, SatId::new(5, 7))); // wraps both axes
        assert!(g.contains(&torus, SatId::new(0, 0)));
        assert!(!g.contains(&torus, SatId::new(3, 4)));
    }

    #[test]
    fn row_major_enumeration_is_window_shaped() {
        let torus = Torus::new(9, 9);
        let g = LosGrid::new(SatId::new(4, 4), 2, 1);
        let cells = g.cells_row_major(&torus);
        assert_eq!(cells.len(), 5 * 3);
        assert_eq!(cells[0], SatId::new(3, 2)); // NW corner
        assert_eq!(cells[7], SatId::new(4, 4)); // centre
        assert_eq!(*cells.last().unwrap(), SatId::new(5, 6)); // SE corner
        for c in &cells {
            assert!(g.contains(&torus, *c));
        }
    }

    #[test]
    fn east_and_entering_columns() {
        let torus = Torus::new(5, 9);
        let g = LosGrid::new(SatId::new(2, 4), 1, 1);
        assert_eq!(g.east_column(&torus), vec![
            SatId::new(1, 5), SatId::new(2, 5), SatId::new(3, 5)
        ]);
        assert_eq!(g.entering_west_column(&torus), vec![
            SatId::new(1, 2), SatId::new(2, 2), SatId::new(3, 2)
        ]);
    }

    #[test]
    fn shifted_window_slides_west() {
        let torus = Torus::new(5, 9);
        let g = LosGrid::new(SatId::new(2, 4), 1, 1);
        let g1 = g.shifted(&torus, 1);
        assert_eq!(g1.center, SatId::new(2, 3));
        // the old entering column is the new west edge... and the old east
        // column has left the window
        for s in g.east_column(&torus) {
            assert!(!g1.contains(&torus, s));
        }
        for s in g.entering_west_column(&torus) {
            assert!(g1.contains(&torus, s));
        }
    }

    #[test]
    fn los_radius_shrinks_with_elevation() {
        assert!(los_ground_radius_km(550.0, 10.0) > los_ground_radius_km(550.0, 30.0));
        // 45 deg -> radius == altitude
        assert!((los_ground_radius_km(550.0, 45.0) - 550.0).abs() < 1e-9);
    }
}
