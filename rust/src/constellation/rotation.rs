//! Deterministic rotation model (paper §3.4, Figures 5, 8, 9).
//!
//! The protocol never needs continuous orbital mechanics — only the
//! discrete consequences of rotation: *which* satellite is closest to the
//! ground host at time `t`, and *when* the closest satellite hands over to
//! its western (lower-slot) neighbour.  Both are exact functions of the
//! orbital period: the constellation advances one intra-plane slot every
//! `T / M` seconds, so the slot directly overhead decreases by one per
//! epoch (satellites exit LOS east, enter west — Fig. 8: satellite 4 is
//! overhead now, satellite 3 "in a few minutes").
//!
//! §3.7's closing observation — "the set of satellites in the LOS at that
//! future time is known exactly" — is `center_at(t)`: predictive placement
//! (see `kvc::manager`) just evaluates the model at a future `t`.

use super::geometry::Geometry;
use super::topology::{SatId, Torus};

/// Rotation state of one constellation shell relative to one ground host.
#[derive(Debug, Clone, Copy)]
pub struct RotationModel {
    pub geometry: Geometry,
    /// Satellite directly overhead at `t = 0`.
    pub initial_center: SatId,
}

impl RotationModel {
    pub fn new(geometry: Geometry, initial_center: SatId) -> Self {
        Self { geometry, initial_center }
    }

    pub fn torus(&self) -> Torus {
        Torus::new(self.geometry.planes, self.geometry.sats_per_plane)
    }

    /// Seconds between successive overhead handovers.
    pub fn epoch_period_s(&self) -> f64 {
        self.geometry.slot_shift_period_s()
    }

    /// Number of completed slot shifts at time `t`.
    pub fn epoch_at(&self, t_s: f64) -> u64 {
        assert!(t_s >= 0.0, "model starts at t=0");
        (t_s / self.epoch_period_s()) as u64
    }

    /// The satellite closest to the ground host at time `t`.
    pub fn center_at(&self, t_s: f64) -> SatId {
        self.center_at_epoch(self.epoch_at(t_s))
    }

    /// The satellite closest to the ground host after `epoch` slot shifts.
    pub fn center_at_epoch(&self, epoch: u64) -> SatId {
        let torus = self.torus();
        let slot = torus.wrap_slot(self.initial_center.slot as i64 - epoch as i64);
        SatId::new(self.initial_center.plane, slot)
    }

    /// Seconds until the next handover after time `t`.
    pub fn time_to_next_epoch_s(&self, t_s: f64) -> f64 {
        let p = self.epoch_period_s();
        p - (t_s % p)
    }

    /// How many columns a layout written at `t_write` has drifted east of
    /// the current center by `t_now` if it was never migrated.  This is the
    /// penalty non-rotation-aware mappings pay in the §4 simulation.
    pub fn drift_epochs(&self, t_write_s: f64, t_now_s: f64) -> u64 {
        self.epoch_at(t_now_s).saturating_sub(self.epoch_at(t_write_s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> RotationModel {
        RotationModel::new(Geometry::new(550.0, 19, 5), SatId::new(2, 9))
    }

    #[test]
    fn center_is_initial_at_t0() {
        assert_eq!(model().center_at(0.0), SatId::new(2, 9));
    }

    #[test]
    fn center_moves_one_slot_west_per_epoch() {
        let m = model();
        let p = m.epoch_period_s();
        assert_eq!(m.center_at(p * 1.01), SatId::new(2, 8));
        assert_eq!(m.center_at(p * 2.5), SatId::new(2, 7));
        // plane never changes
        for e in 0..40 {
            assert_eq!(m.center_at_epoch(e).plane, 2);
        }
    }

    #[test]
    fn center_wraps_after_full_orbit() {
        let m = model();
        assert_eq!(m.center_at_epoch(19), m.center_at_epoch(0));
        assert_eq!(m.center_at_epoch(19 + 3), m.center_at_epoch(3));
    }

    #[test]
    fn epoch_period_matches_paper_visibility_window() {
        // "a particular LEO satellite may only be visible from a point on
        // earth for 5-10 minutes" — handover cadence must be in that order.
        let p = model().epoch_period_s();
        assert!(p > 60.0 * 3.0 && p < 60.0 * 10.0, "{p}");
    }

    #[test]
    fn drift_counts_missed_migrations() {
        let m = model();
        let p = m.epoch_period_s();
        assert_eq!(m.drift_epochs(0.0, 0.5 * p), 0);
        assert_eq!(m.drift_epochs(0.0, 3.2 * p), 3);
        assert_eq!(m.drift_epochs(2.1 * p, 3.2 * p), 1);
    }

    #[test]
    fn time_to_next_epoch_counts_down() {
        let m = model();
        let p = m.epoch_period_s();
        let early = m.time_to_next_epoch_s(0.1 * p);
        let late = m.time_to_next_epoch_s(0.9 * p);
        assert!(early > late);
        assert!((early + 0.1 * p - p).abs() < 1e-6);
    }
}
