//! Orbital geometry: the paper's equations (1)–(4) and derived latencies.
//!
//! All distances are in kilometres, all times in seconds unless a name says
//! otherwise.  The speed-of-light latencies here generate Table 1's LEO
//! rows and Figures 1–2 (intra-plane ISL latency vs. `M` and `h`).

/// Mean Earth radius in km (`r_E` in the paper).
pub const EARTH_RADIUS_KM: f64 = 6371.0;

/// Speed of light in vacuum, km/s (free-space optics ISL).
pub const LIGHT_SPEED_KM_S: f64 = 299_792.458;

/// Standard gravitational parameter of Earth, km^3/s^2 (orbital period).
pub const MU_EARTH: f64 = 398_600.4418;

/// Geometry of one constellation shell.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Geometry {
    /// Constellation altitude `h` in km.
    pub altitude_km: f64,
    /// Satellites per orbital plane (`M` in eq. (1)).
    pub sats_per_plane: usize,
    /// Number of orbital planes (`N` in eq. (2)).
    pub planes: usize,
}

impl Geometry {
    pub fn new(altitude_km: f64, sats_per_plane: usize, planes: usize) -> Self {
        assert!(altitude_km > 0.0, "altitude must be positive");
        assert!(sats_per_plane >= 2 && planes >= 2, "need a real torus");
        Self { altitude_km, sats_per_plane, planes }
    }

    /// Orbit radius `r_E + h`.
    pub fn orbit_radius_km(&self) -> f64 {
        EARTH_RADIUS_KM + self.altitude_km
    }

    /// Paper eq. (1): chord distance `D_m` between adjacent satellites in
    /// the same plane: `(r_E + h) * sqrt(2 * (1 - cos(2*pi/M)))`.
    pub fn intra_plane_distance_km(&self) -> f64 {
        chord_distance_km(self.altitude_km, self.sats_per_plane)
    }

    /// Paper eq. (2): worst-case chord distance `D_n` between neighbouring
    /// satellites of adjacent planes: `(r_E + h) * sqrt(2*(1 - cos(2*pi/N)))`.
    pub fn inter_plane_distance_km(&self) -> f64 {
        chord_distance_km(self.altitude_km, self.planes)
    }

    /// One-hop ISL latency along the plane, seconds.
    pub fn intra_plane_latency_s(&self) -> f64 {
        self.intra_plane_distance_km() / LIGHT_SPEED_KM_S
    }

    /// One-hop ISL latency across planes (worst case), seconds.
    pub fn inter_plane_latency_s(&self) -> f64 {
        self.inter_plane_distance_km() / LIGHT_SPEED_KM_S
    }

    /// Worst-case single-hop ISL latency, seconds.  §2: "we can consider
    /// (1) as a worst-case scenario distance or latency for all ISL
    /// communication" — eq. (1) with the *smaller* of M, N dominates, so we
    /// take the max of the two chords.
    pub fn worst_hop_latency_s(&self) -> f64 {
        self.intra_plane_latency_s().max(self.inter_plane_latency_s())
    }

    /// Paper eq. (3): straight-line distance covered by a route step of
    /// `d_planes` plane-hops and `d_slots` slot-hops:
    /// `D = sqrt((D_m * Δo)^2 + (D_n * Δs)^2)`.
    pub fn hop_distance_km(&self, d_slots: usize, d_planes: usize) -> f64 {
        let dm = self.intra_plane_distance_km() * d_slots as f64;
        let dn = self.inter_plane_distance_km() * d_planes as f64;
        (dm * dm + dn * dn).sqrt()
    }

    /// Paper eq. (4): slant range from the ground host to a satellite whose
    /// sub-satellite point is `ground_km` away: `x = sqrt(D^2 + h^2)`.
    pub fn slant_range_km(&self, ground_km: f64) -> f64 {
        (ground_km * ground_km + self.altitude_km * self.altitude_km).sqrt()
    }

    /// Ground-to-satellite one-way latency for a satellite `slots`/`planes`
    /// grid cells away from the sub-stellar (directly overhead) satellite.
    pub fn ground_latency_s(&self, d_slots: usize, d_planes: usize) -> f64 {
        let d = self.hop_distance_km(d_slots, d_planes);
        self.slant_range_km(d) / LIGHT_SPEED_KM_S
    }

    /// Orbital period `T = 2*pi*sqrt((r_E+h)^3 / mu)`, seconds.
    pub fn orbital_period_s(&self) -> f64 {
        let r = self.orbit_radius_km();
        2.0 * std::f64::consts::PI * (r * r * r / MU_EARTH).sqrt()
    }

    /// Time between successive "column shifts": the constellation advances
    /// by one intra-plane slot every `T / M` seconds; this is the epoch at
    /// which rotation-aware mappings migrate (§3.4).
    pub fn slot_shift_period_s(&self) -> f64 {
        self.orbital_period_s() / self.sats_per_plane as f64
    }
}

/// Chord between adjacent points of `count` equidistant points on the orbit
/// circle at `altitude_km` — shared body of eqs. (1) and (2).
pub fn chord_distance_km(altitude_km: f64, count: usize) -> f64 {
    let r = EARTH_RADIUS_KM + altitude_km;
    let theta = 2.0 * std::f64::consts::PI / count as f64;
    r * (2.0 * (1.0 - theta.cos())).sqrt()
}

/// Approximate latencies of classical memory/storage tiers (paper Table 1),
/// used for the memory-hierarchy comparisons in docs and the Table 1
/// reproduction.  Values are the midpoints of the paper's ranges, seconds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MemoryTier {
    Cpu,
    Gpu,
    Rdma,
    Ssd,
    Hdd,
    Nas,
    LeoRf,
    LeoLaser,
}

impl MemoryTier {
    pub const ALL: [MemoryTier; 8] = [
        MemoryTier::Cpu,
        MemoryTier::Gpu,
        MemoryTier::Rdma,
        MemoryTier::Ssd,
        MemoryTier::Hdd,
        MemoryTier::Nas,
        MemoryTier::LeoRf,
        MemoryTier::LeoLaser,
    ];

    /// (low, high) latency band in seconds, straight from Table 1.
    pub fn latency_band_s(&self) -> (f64, f64) {
        match self {
            MemoryTier::Cpu => (10e-9, 15e-9),
            MemoryTier::Gpu => (50e-9, 100e-9),
            MemoryTier::Rdma => (2e-6, 5e-6),
            MemoryTier::Ssd => (20e-6, 200e-6),
            MemoryTier::Hdd => (2e-3, 20e-3),
            MemoryTier::Nas => (30e-3, 40e-3),
            MemoryTier::LeoRf => (20e-3, 50e-3),
            MemoryTier::LeoLaser => (2e-3, 4e-3),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            MemoryTier::Cpu => "CPU",
            MemoryTier::Gpu => "GPU",
            MemoryTier::Rdma => "RDMA",
            MemoryTier::Ssd => "SSD",
            MemoryTier::Hdd => "HDD",
            MemoryTier::Nas => "NAS",
            MemoryTier::LeoRf => "LEO (current RF)",
            MemoryTier::LeoLaser => "LEO (theoretical Laser)",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geo() -> Geometry {
        Geometry::new(550.0, 19, 5)
    }

    #[test]
    fn eq1_matches_hand_computation() {
        // D_m = (6371+550) * sqrt(2*(1-cos(2*pi/19)))
        let g = geo();
        let theta = 2.0 * std::f64::consts::PI / 19.0;
        let want = 6921.0 * (2.0 * (1.0 - theta.cos())).sqrt();
        assert!((g.intra_plane_distance_km() - want).abs() < 1e-9);
        // sanity: ~2280 km for 19 sats at 550 km
        assert!((g.intra_plane_distance_km() - 2280.0).abs() < 10.0);
    }

    #[test]
    fn eq2_uses_plane_count() {
        let g = geo();
        let theta = 2.0 * std::f64::consts::PI / 5.0;
        let want = 6921.0 * (2.0 * (1.0 - theta.cos())).sqrt();
        assert!((g.inter_plane_distance_km() - want).abs() < 1e-9);
    }

    #[test]
    fn more_sats_shrink_the_chord() {
        let mut prev = f64::INFINITY;
        for m in [5, 10, 20, 40, 80] {
            let d = chord_distance_km(550.0, m);
            assert!(d < prev, "chord must shrink with M");
            prev = d;
        }
    }

    #[test]
    fn higher_altitude_grows_the_chord() {
        assert!(chord_distance_km(2000.0, 30) > chord_distance_km(160.0, 30));
    }

    #[test]
    fn paper_claim_50_plus_sats_low_ms() {
        // §2: "roughly a latency between SSD and HDD with about 50+
        // satellites in a plane or 50+ planes (<2 milliseconds)".  The
        // claim is an extrapolation ("roughly"): at 50 sats the hop sits
        // in the low single-digit ms across the altitude sweep, and drops
        // under 2 ms as M grows ((~75+ at 550 km).
        for h in [160.0, 550.0, 1200.0, 2000.0] {
            let g = Geometry::new(h, 50, 50);
            assert!(
                g.intra_plane_latency_s() < 4.0e-3,
                "h={h}: {}",
                g.intra_plane_latency_s()
            );
        }
        assert!(Geometry::new(550.0, 80, 80).intra_plane_latency_s() < 2.0e-3);
        assert!(Geometry::new(160.0, 75, 75).intra_plane_latency_s() < 2.0e-3);
    }

    #[test]
    fn eq3_eq4_compose() {
        let g = geo();
        // zero offset -> directly overhead -> slant == altitude
        assert!((g.slant_range_km(0.0) - 550.0).abs() < 1e-12);
        assert!((g.ground_latency_s(0, 0) - 550.0 / LIGHT_SPEED_KM_S).abs() < 1e-15);
        // diagonal hop distance is the hypotenuse
        let d = g.hop_distance_km(1, 1);
        let dm = g.intra_plane_distance_km();
        let dn = g.inter_plane_distance_km();
        assert!((d - (dm * dm + dn * dn).sqrt()).abs() < 1e-9);
        assert!(g.ground_latency_s(1, 0) > g.ground_latency_s(0, 0));
    }

    #[test]
    fn orbital_period_is_leo_like() {
        // LEO periods are ~90-130 min
        let p = Geometry::new(550.0, 19, 5).orbital_period_s();
        assert!(p > 80.0 * 60.0 && p < 130.0 * 60.0, "{p}");
        let p2 = Geometry::new(2000.0, 19, 5).orbital_period_s();
        assert!(p2 > p);
    }

    #[test]
    fn table1_leo_laser_band_holds_for_isl() {
        // A 19x5 at 550 km has single-hop ISL latency in the low-ms band,
        // consistent with Table 1's laser row at constellation scale.
        let g = geo();
        assert!(g.intra_plane_latency_s() < 10e-3);
        assert!(g.worst_hop_latency_s() >= g.intra_plane_latency_s());
    }

    #[test]
    fn memory_tiers_ordered() {
        let bands: Vec<_> =
            MemoryTier::ALL.iter().map(|t| t.latency_band_s()).collect();
        for (lo, hi) in &bands {
            assert!(lo <= hi);
        }
        // LEO laser undercuts NAS and HDD midpoints (the paper's pitch)
        let mid = |t: MemoryTier| {
            let (a, b) = t.latency_band_s();
            (a + b) / 2.0
        };
        assert!(mid(MemoryTier::LeoLaser) < mid(MemoryTier::Nas));
        assert!(mid(MemoryTier::LeoLaser) < mid(MemoryTier::Hdd));
    }

    #[test]
    fn slot_shift_period_divides_orbit() {
        let g = geo();
        let want = g.orbital_period_s() / 19.0;
        assert!((g.slot_shift_period_s() - want).abs() < 1e-9);
        // 19 sats -> a new satellite overhead every ~5 minutes, matching
        // the paper's "visible for 5-10 minutes" observation.
        assert!(g.slot_shift_period_s() > 3.0 * 60.0);
        assert!(g.slot_shift_period_s() < 10.0 * 60.0);
    }
}
