//! +GRID 2D-torus topology and greedy ISL routing (paper §3.2, §4).
//!
//! Coordinate convention (matches the paper's figures): a satellite is
//! identified by `(plane, slot)` — `plane` is the orbital plane (a *row* of
//! the figures' grids), `slot` is the satellite's index within its plane (a
//! *column*).  East/West neighbours are adjacent slots of the same plane
//! (intra-plane ISL, chord `D_m`, eq. 1); North/South neighbours are the
//! same slot of adjacent planes (inter-plane ISL, chord `D_n`, eq. 2).
//! Both axes wrap around (2D torus).
//!
//! Ground motion: as the Earth rotates under the constellation, the LOS
//! window slides towards *higher* slots — the satellite about to exit LOS
//! on the east is replaced by one entering on the west (paper Fig. 5/8).



/// A satellite's coordinates in the torus.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SatId {
    /// Orbital plane index, `0..planes`.
    pub plane: u16,
    /// Index within the plane, `0..sats_per_plane`.
    pub slot: u16,
}

impl SatId {
    pub fn new(plane: u16, slot: u16) -> Self {
        Self { plane, slot }
    }

    /// Dense index for array-backed lookup tables.
    pub fn linear(&self, sats_per_plane: usize) -> usize {
        self.plane as usize * sats_per_plane + self.slot as usize
    }
}

impl std::fmt::Display for SatId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "(p{},s{})", self.plane, self.slot)
    }
}

/// A single routing step in the +GRID mesh.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Step {
    North,
    South,
    East,
    West,
    /// Already at the target.
    Arrived,
}

/// The +GRID 2D-torus mesh of a constellation shell.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Torus {
    pub planes: usize,
    pub sats_per_plane: usize,
}

impl Torus {
    pub fn new(planes: usize, sats_per_plane: usize) -> Self {
        assert!(planes >= 2 && sats_per_plane >= 2, "torus needs >=2 on each axis");
        Self { planes, sats_per_plane }
    }

    pub fn len(&self) -> usize {
        self.planes * self.sats_per_plane
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    pub fn contains(&self, s: SatId) -> bool {
        (s.plane as usize) < self.planes && (s.slot as usize) < self.sats_per_plane
    }

    pub fn all(&self) -> impl Iterator<Item = SatId> + '_ {
        (0..self.planes).flat_map(move |p| {
            (0..self.sats_per_plane).map(move |s| SatId::new(p as u16, s as u16))
        })
    }

    /// Wrap-around plane arithmetic.
    pub fn wrap_plane(&self, plane: i64) -> u16 {
        plane.rem_euclid(self.planes as i64) as u16
    }

    /// Wrap-around slot arithmetic.
    pub fn wrap_slot(&self, slot: i64) -> u16 {
        slot.rem_euclid(self.sats_per_plane as i64) as u16
    }

    pub fn north(&self, s: SatId) -> SatId {
        SatId::new(self.wrap_plane(s.plane as i64 - 1), s.slot)
    }

    pub fn south(&self, s: SatId) -> SatId {
        SatId::new(self.wrap_plane(s.plane as i64 + 1), s.slot)
    }

    pub fn west(&self, s: SatId) -> SatId {
        SatId::new(s.plane, self.wrap_slot(s.slot as i64 - 1))
    }

    pub fn east(&self, s: SatId) -> SatId {
        SatId::new(s.plane, self.wrap_slot(s.slot as i64 + 1))
    }

    /// The four +GRID ISL neighbours, in the paper's N, E, S, W order.
    pub fn neighbors(&self, s: SatId) -> [SatId; 4] {
        [self.north(s), self.east(s), self.south(s), self.west(s)]
    }

    // --- The §4 directional distances -----------------------------------

    /// Hops to reach `to`'s plane travelling north (decreasing plane).
    pub fn d_north(&self, from: SatId, to: SatId) -> usize {
        let (o, ot) = (from.plane as i64, to.plane as i64);
        (o - ot).rem_euclid(self.planes as i64) as usize
    }

    /// Hops to reach `to`'s plane travelling south (increasing plane).
    pub fn d_south(&self, from: SatId, to: SatId) -> usize {
        let (o, ot) = (from.plane as i64, to.plane as i64);
        (ot - o).rem_euclid(self.planes as i64) as usize
    }

    /// Hops to reach `to`'s slot travelling west (decreasing slot).
    pub fn d_west(&self, from: SatId, to: SatId) -> usize {
        let (s, st) = (from.slot as i64, to.slot as i64);
        (s - st).rem_euclid(self.sats_per_plane as i64) as usize
    }

    /// Hops to reach `to`'s slot travelling east (increasing slot).
    pub fn d_east(&self, from: SatId, to: SatId) -> usize {
        let (s, st) = (from.slot as i64, to.slot as i64);
        (st - s).rem_euclid(self.sats_per_plane as i64) as usize
    }

    /// Minimal wrap distance across planes.
    pub fn plane_distance(&self, from: SatId, to: SatId) -> usize {
        self.d_north(from, to).min(self.d_south(from, to))
    }

    /// Minimal wrap distance along the plane.
    pub fn slot_distance(&self, from: SatId, to: SatId) -> usize {
        self.d_west(from, to).min(self.d_east(from, to))
    }

    /// Total hop count (torus Manhattan distance) — ISL hops of the
    /// shortest +GRID route.
    pub fn hops(&self, from: SatId, to: SatId) -> usize {
        self.plane_distance(from, to) + self.slot_distance(from, to)
    }

    /// Are `a` and `b` joined by a single +GRID ISL?
    pub fn are_neighbors(&self, a: SatId, b: SatId) -> bool {
        self.hops(a, b) == 1
    }

    /// The §4 greedy next-step rule, verbatim: prefer the strictly shorter
    /// vertical direction, then the strictly shorter horizontal one.
    pub fn next_step(&self, from: SatId, to: SatId) -> Step {
        let dn = self.d_north(from, to);
        let ds = self.d_south(from, to);
        if dn != 0 || ds != 0 {
            // need to change plane
            if dn < ds {
                return Step::North;
            }
            if ds < dn {
                return Step::South;
            }
            // dn == ds != 0: either way is shortest; the paper's rule falls
            // through to the horizontal cases, so only break the tie when
            // no horizontal travel remains.
            let dw = self.d_west(from, to);
            let de = self.d_east(from, to);
            if dw < de {
                return Step::West;
            }
            if de < dw {
                return Step::East;
            }
            return Step::North; // full tie: deterministic choice
        }
        let dw = self.d_west(from, to);
        let de = self.d_east(from, to);
        if dw < de {
            Step::West
        } else if de < dw {
            Step::East
        } else if dw != 0 {
            Step::West // antipodal tie: deterministic choice
        } else {
            Step::Arrived
        }
    }

    pub fn step(&self, from: SatId, step: Step) -> SatId {
        match step {
            Step::North => self.north(from),
            Step::South => self.south(from),
            Step::East => self.east(from),
            Step::West => self.west(from),
            Step::Arrived => from,
        }
    }

    /// Full greedy route `from -> to` (excluding `from`, including `to`).
    pub fn route(&self, from: SatId, to: SatId) -> Vec<SatId> {
        let mut path = Vec::with_capacity(self.hops(from, to));
        let mut cur = from;
        loop {
            match self.next_step(cur, to) {
                Step::Arrived => break,
                s => {
                    cur = self.step(cur, s);
                    path.push(cur);
                }
            }
            assert!(path.len() <= self.len(), "routing loop {from}->{to}");
        }
        path
    }

    /// Offset (plane_delta, slot_delta) of `to` relative to `from`, each in
    /// the signed minimal-wrap range.  Ties (exactly half the axis) resolve
    /// to the positive direction.
    pub fn signed_offset(&self, from: SatId, to: SatId) -> (i32, i32) {
        let dn = self.d_north(from, to) as i32;
        let ds = self.d_south(from, to) as i32;
        let dp = if ds <= dn { ds } else { -dn };
        let dw = self.d_west(from, to) as i32;
        let de = self.d_east(from, to) as i32;
        let dsl = if de <= dw { de } else { -dw };
        (dp, dsl)
    }

    /// The satellite at a signed (plane_delta, slot_delta) from `base`.
    pub fn offset(&self, base: SatId, plane_delta: i32, slot_delta: i32) -> SatId {
        SatId::new(
            self.wrap_plane(base.plane as i64 + plane_delta as i64),
            self.wrap_slot(base.slot as i64 + slot_delta as i64),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> Torus {
        Torus::new(5, 19) // the paper's 19x5 testbed constellation
    }

    #[test]
    fn neighbors_wrap() {
        let t = t();
        let corner = SatId::new(0, 0);
        assert_eq!(t.north(corner), SatId::new(4, 0));
        assert_eq!(t.west(corner), SatId::new(0, 18));
        assert_eq!(t.south(SatId::new(4, 3)), SatId::new(0, 3));
        assert_eq!(t.east(SatId::new(2, 18)), SatId::new(2, 0));
    }

    #[test]
    fn directional_distances_match_paper_cases() {
        let t = t();
        let a = SatId::new(1, 2);
        let b = SatId::new(4, 6);
        // o_t > o: d_north wraps, d_south direct
        assert_eq!(t.d_south(a, b), 3);
        assert_eq!(t.d_north(a, b), 2);
        // s_t > s: d_east direct, d_west wraps
        assert_eq!(t.d_east(a, b), 4);
        assert_eq!(t.d_west(a, b), 15);
        assert_eq!(t.hops(a, b), 2 + 4);
    }

    #[test]
    fn hops_symmetric_and_zero_on_self() {
        let t = t();
        for a in t.all() {
            assert_eq!(t.hops(a, a), 0);
        }
        let a = SatId::new(0, 1);
        let b = SatId::new(3, 17);
        assert_eq!(t.hops(a, b), t.hops(b, a));
    }

    #[test]
    fn greedy_route_realizes_hop_count() {
        let t = t();
        let pairs = [
            (SatId::new(0, 0), SatId::new(0, 0)),
            (SatId::new(0, 0), SatId::new(4, 18)),
            (SatId::new(2, 5), SatId::new(2, 6)),
            (SatId::new(1, 18), SatId::new(3, 1)),
            (SatId::new(4, 9), SatId::new(0, 2)),
        ];
        for (a, b) in pairs {
            let route = t.route(a, b);
            assert_eq!(route.len(), t.hops(a, b), "{a} -> {b}");
            assert_eq!(*route.last().unwrap_or(&a), b);
            // each step is a +GRID neighbour of the previous
            let mut prev = a;
            for s in route {
                assert!(t.neighbors(prev).contains(&s));
                prev = s;
            }
        }
    }

    #[test]
    fn route_prefers_vertical_first() {
        // paper's rule lists north/south before west/east
        let t = t();
        let a = SatId::new(0, 0);
        let b = SatId::new(2, 2);
        let route = t.route(a, b);
        assert_eq!(route[0].plane, 1, "first step should change plane");
    }

    #[test]
    fn signed_offset_roundtrip() {
        let t = t();
        let base = SatId::new(2, 9);
        for target in t.all() {
            let (dp, ds) = t.signed_offset(base, target);
            assert_eq!(t.offset(base, dp, ds), target);
            assert_eq!(dp.unsigned_abs() as usize, t.plane_distance(base, target));
            assert_eq!(ds.unsigned_abs() as usize, t.slot_distance(base, target));
        }
    }

    #[test]
    fn antipodal_ties_terminate() {
        let t = Torus::new(4, 6);
        let a = SatId::new(0, 0);
        let b = SatId::new(2, 3); // exactly opposite on both axes
        let route = t.route(a, b);
        assert_eq!(route.len(), t.hops(a, b));
    }

    #[test]
    fn linear_index_bijective() {
        let t = t();
        let mut seen = vec![false; t.len()];
        for s in t.all() {
            let i = s.linear(t.sats_per_plane);
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.into_iter().all(|b| b));
    }
}
