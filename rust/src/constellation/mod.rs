//! Constellation substrate: geometry, topology, rotation and line-of-sight.
//!
//! The paper's system model (§2, §3.2): a Walker-style LEO constellation at
//! altitude `h` with `N` orbital planes of `M` equidistant satellites each,
//! meshed by 4 free-space-optics inter-satellite links per satellite into a
//! +GRID 2D torus (Pfandzelter & Bermbach [4]).

pub mod geometry;
pub mod los;
pub mod rotation;
pub mod topology;

pub use geometry::Geometry;
pub use los::LosGrid;
pub use rotation::RotationModel;
pub use topology::{SatId, Torus};
