//! Paper-artifact reproduction: every table and figure of the evaluation,
//! regenerated as text/CSV (DESIGN.md has the experiment index).  Shared
//! by `skymemory repro`, `examples/paper_figures.rs` and the benches.

use crate::constellation::geometry::{chord_distance_km, Geometry, MemoryTier, LIGHT_SPEED_KM_S};
use crate::constellation::topology::{SatId, Torus};
use crate::mapping::{box_side, grid_fmt, Strategy};
use crate::sim::latency::figure16_sweep;
use std::fmt::Write as _;

/// Table 1: approximate latency per memory type, with the LEO rows
/// cross-checked against the geometry model.
pub fn table1() -> String {
    let mut out = String::from("type,latency_low_s,latency_high_s\n");
    for t in MemoryTier::ALL {
        let (lo, hi) = t.latency_band_s();
        let _ = writeln!(out, "{},{lo},{hi}", t.name());
    }
    // cross-check: a 50x50 shell at low/high altitude lands in the laser band
    let lo = Geometry::new(160.0, 60, 60).intra_plane_latency_s();
    let hi = Geometry::new(2000.0, 50, 50).intra_plane_latency_s();
    let _ = writeln!(out, "# model check: ISL hop at 160km/60sats = {lo:.6}s; 2000km/50sats = {hi:.6}s");
    out
}

/// Figures 1 & 2: intra-plane ISL latency (eq. 1 / c) vs altitude for a
/// range of plane sizes M.  One CSV serves both the surface (Fig 1) and
/// the contour (Fig 2) views.
pub fn fig1_fig2() -> String {
    let mut out = String::from("m,altitude_km,latency_ms\n");
    for m in [10usize, 15, 20, 30, 40, 50, 60] {
        let mut h = 160.0;
        while h <= 2000.0 {
            let ms = chord_distance_km(h, m) / LIGHT_SPEED_KM_S * 1e3;
            let _ = writeln!(out, "{m},{h},{ms:.4}");
            h += 80.0;
        }
    }
    out
}

fn strategy_grids(strategy: Strategy) -> String {
    let mut out = String::new();
    for n in [9usize, 25, 49, 81] {
        let side = box_side(n);
        let dim = (2 * side + 3).max(15);
        let torus = Torus::new(dim, dim);
        let center = SatId::new((dim / 2) as u16, (dim / 2) as u16);
        let layout = strategy.initial_layout(&torus, center, n);
        // project over a window big enough for the unbounded diamond too
        let half = side; // diamond radius <= side for these n
        let grid = grid_fmt::project(&torus, &layout, center, half, half);
        // trim empty border rows/cols for the bounded mappings
        let _ = writeln!(out, "# {} {}x{} ({} servers)", strategy.name(), side, side, n);
        out.push_str(&grid_fmt::to_string(&grid));
        out.push('\n');
    }
    out
}

/// Figure 13: rotation-aware row-major grids.
pub fn fig13() -> String {
    strategy_grids(Strategy::RotationAware)
}

/// Figure 14: hop-aware concentric diamonds.
pub fn fig14() -> String {
    strategy_grids(Strategy::HopAware)
}

/// Figure 15: rotation-and-hop-aware bounded grids.
pub fn fig15() -> String {
    strategy_grids(Strategy::RotationHopAware)
}

/// Figure 16: the worst-case-latency sweep, as CSV.
pub fn fig16() -> String {
    let mut out = String::from(
        "strategy,altitude_km,n_servers,kvc_mb,chunk_processing_ms,total_s,network_s,processing_s,worst_hops\n",
    );
    for r in figure16_sweep() {
        let _ = writeln!(
            out,
            "{},{},{},{},{},{:.6},{:.6},{:.6},{}",
            r.strategy,
            r.altitude_km,
            r.n_servers,
            r.kvc_bytes >> 20,
            r.chunk_processing_s * 1e3,
            r.latency.total_s,
            r.latency.network_s,
            r.latency.processing_s,
            r.latency.worst_hops
        );
    }
    out
}

/// Figure 16 summary: the paper's two headline claims, computed from the
/// sweep (printed by the bench harness next to the raw CSV).
pub fn fig16_summary() -> String {
    let rows = figure16_sweep();
    let mut out = String::new();
    // claim (a): rot+hop <= others cell-wise
    let mut wins = 0usize;
    let mut cells = 0usize;
    for r in rows.iter().filter(|r| r.strategy == Strategy::RotationHopAware.name()) {
        cells += 1;
        let same_cell = |s: &str| {
            rows.iter()
                .find(|o| {
                    o.strategy == s
                        && o.altitude_km == r.altitude_km
                        && o.n_servers == r.n_servers
                        && o.kvc_bytes == r.kvc_bytes
                        && o.chunk_processing_s == r.chunk_processing_s
                })
                .unwrap()
                .latency
                .total_s
        };
        if r.latency.total_s <= same_cell(Strategy::RotationAware.name()) + 1e-12
            && r.latency.total_s <= same_cell(Strategy::HopAware.name()) + 1e-12
        {
            wins += 1;
        }
    }
    let _ = writeln!(out, "rot+hop lowest latency in {wins}/{cells} sweep cells");
    // claim (b): 9 -> 81 servers reduction at the processing-heavy corner
    let get = |n: usize| {
        rows.iter()
            .find(|r| {
                r.strategy == Strategy::RotationHopAware.name()
                    && r.altitude_km == 550.0
                    && r.n_servers == n
                    && r.kvc_bytes == 21 << 20
                    && r.chunk_processing_s == 0.02
            })
            .unwrap()
            .latency
            .total_s
    };
    let (s, l) = (get(9), get(81));
    let _ = writeln!(
        out,
        "9 -> 81 servers: {:.3}s -> {:.3}s ({:.1}% reduction; paper: ~90%)",
        s,
        l,
        100.0 * (1.0 - l / s)
    );
    out
}

/// The scenario-harness reports: every built-in scenario (the paper's
/// 19x5 testbed, the Starlink- and Kuiper-like mega shells, the
/// net::sched mega-shell stress, the fork-heavy session run, and the
/// federated dual- and tri-shell runs) at a fixed seed, one
/// metrics-JSON line each.  Deterministic: re-running produces
/// byte-identical output.
pub fn scenarios() -> String {
    let mut out = String::new();
    for spec in crate::sim::scenario::ScenarioSpec::builtin(42) {
        let report = crate::sim::harness::run_scenario(&spec);
        let _ = writeln!(out, "{}", report.to_json_string());
    }
    for fed in [
        crate::sim::scenario::FederatedScenarioSpec::federated_dual_shell(42),
        crate::sim::scenario::FederatedScenarioSpec::federated_tri_shell(42),
    ] {
        let _ =
            writeln!(out, "{}", crate::sim::harness::run_federated_scenario(&fed).to_json_string());
    }
    out
}

/// Flight-recorder snapshot of the paper's 19x5 testbed at the fixed
/// seed: the byte-stable JSONL trace `skymemory trace paper-19x5`
/// emits (docs/TRACING.md documents the schema).
pub fn trace_paper_19x5() -> String {
    let spec = crate::sim::scenario::ScenarioSpec::paper_19x5(42);
    let sink = std::sync::Arc::new(crate::obs::Recorder::new());
    crate::sim::harness::run_scenario_with_sink(&spec, sink.clone());
    crate::obs::jsonl(&sink.take())
}

/// Flight-recorder snapshot of the federated tri-shell run at the fixed
/// seed (race arms, evacuations and correlated failures included).
pub fn trace_federated_tri_shell() -> String {
    let spec = crate::sim::scenario::FederatedScenarioSpec::federated_tri_shell(42);
    let sink = std::sync::Arc::new(crate::obs::Recorder::new());
    crate::sim::harness::run_federated_scenario_with_sink(&spec, sink.clone());
    crate::obs::jsonl(&sink.take())
}

/// Render one `skymemory mem`-style line from a scenario report's JSON:
/// the `memory` object keyed by name and the fixed seed.
fn mem_line(name: &str, report: crate::util::json::Json) -> String {
    use crate::util::json::{n, obj, s};
    let memory = report.get("memory").cloned().expect("report carries a memory object");
    let mut line = obj(vec![("memory", memory), ("name", s(name)), ("seed", n(42.0))]).to_string();
    line.push('\n');
    line
}

/// Memory-footprint snapshot of the paper's 19x5 testbed at the fixed
/// seed — the byte-stable line `skymemory mem paper-19x5` emits
/// (docs/METRICS.md "The memory object" documents every key).
pub fn mem_paper_19x5() -> String {
    let spec = crate::sim::scenario::ScenarioSpec::paper_19x5(42);
    mem_line("paper-19x5", crate::sim::harness::run_scenario(&spec).to_json())
}

/// Memory-footprint snapshot of the federated tri-shell run at the
/// fixed seed, per-shell residency rows included.
pub fn mem_federated_tri_shell() -> String {
    let spec = crate::sim::scenario::FederatedScenarioSpec::federated_tri_shell(42);
    mem_line("federated-tri-shell", crate::sim::harness::run_federated_scenario(&spec).to_json())
}

/// Table 2: the simulation configuration actually used.
pub fn table2() -> String {
    let c = crate::sim::SimConfig::default();
    format!(
        "parameter,values\nKVC_BYTES,2-21 MB\nSERVERS,9-81\nCHUNK_PROCESSING_TIME,0.002-0.02 s\n\
         ALTITUDE,160-2000 km\nMAX_SATELLITES,{}\nMAX_ORBS,{}\nCENTER,({},{})\nCHUNK_BYTES,{}\nDRIFT_EPOCHS,{}\nRELIABLE_LOS_HALF,{}\n",
        c.max_satellites,
        c.max_orbs,
        c.center().plane + 1,
        c.center().slot + 1,
        c.chunk_bytes,
        c.drift_epochs,
        c.reliable_los_half,
    )
}

/// Write all static artifacts (everything except the model-driven Table 3)
/// into `outdir`; returns the file list.
pub fn write_all(outdir: &std::path::Path) -> std::io::Result<Vec<std::path::PathBuf>> {
    std::fs::create_dir_all(outdir)?;
    let items: [(&str, String); 12] = [
        ("table1.csv", table1()),
        ("fig1_fig2.csv", fig1_fig2()),
        ("fig13.txt", fig13()),
        ("fig14.txt", fig14()),
        ("fig15.txt", fig15()),
        ("fig16.csv", fig16()),
        ("table2.csv", table2()),
        ("scenarios.json", scenarios()),
        ("trace_paper_19x5.jsonl", trace_paper_19x5()),
        ("trace_federated_tri_shell.jsonl", trace_federated_tri_shell()),
        ("mem_paper_19x5.json", mem_paper_19x5()),
        ("mem_federated_tri_shell.json", mem_federated_tri_shell()),
    ];
    let mut written = Vec::new();
    for (name, content) in items {
        let path = outdir.join(name);
        std::fs::write(&path, content)?;
        written.push(path);
    }
    // Snapshot any BENCH_*.json perf-trajectory artifacts sitting in the
    // working directory (written by the bench binaries, see
    // docs/METRICS.md "Bench artifacts") next to the paper artifacts.
    let mut bench: Vec<std::path::PathBuf> = std::fs::read_dir(".")?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
        })
        .collect();
    bench.sort();
    for src in bench {
        let dst = outdir.join(src.file_name().unwrap());
        std::fs::copy(&src, &dst)?;
        written.push(dst);
    }
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_all_tiers() {
        let t = table1();
        for tier in MemoryTier::ALL {
            assert!(t.contains(tier.name()), "{}", tier.name());
        }
    }

    #[test]
    fn fig1_series_monotone_in_m() {
        let csv = fig1_fig2();
        // at h=560 (160 + 5*80), latency decreases as M grows
        let at = |m: usize| {
            csv.lines()
                .find(|l| l.starts_with(&format!("{m},560,")))
                .and_then(|l| l.split(',').nth(2))
                .unwrap()
                .parse::<f64>()
                .unwrap()
        };
        assert!(at(10) > at(20));
        assert!(at(20) > at(50));
    }

    #[test]
    fn fig15_text_contains_center_one() {
        let t = fig15();
        assert!(t.contains("rotation-and-hop-aware"));
        // 5x5 golden middle row
        assert!(t.contains("13  5  1  3  9") || t.contains("13 5 1 3 9"), "{t}");
    }

    #[test]
    fn fig16_sweep_is_full() {
        let csv = fig16();
        assert_eq!(csv.trim().lines().count(), 1 + 3 * 7 * 4 * 2 * 2);
    }

    #[test]
    fn fig16_summary_shows_full_wins() {
        let s = fig16_summary();
        assert!(s.contains("112/112"), "{s}");
    }

    #[test]
    fn write_all_creates_files() {
        let dir = std::env::temp_dir().join(format!("skymem_repro_{}", std::process::id()));
        let files = write_all(&dir).unwrap();
        // 8 paper artifacts, plus any BENCH_*.json snapshots present in
        // the working directory at test time
        assert!(files.len() >= 8, "{}", files.len());
        for f in &files {
            assert!(f.exists());
            assert!(std::fs::metadata(f).unwrap().len() > 10);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mem_snapshots_carry_the_memory_plane() {
        let single = mem_paper_19x5();
        assert_eq!(single.trim().lines().count(), 1);
        let keys =
            ["\"memory\"", "\"bytes_per_cached_token\"", "\"peak_total_bytes\"", "\"paper-19x5\""];
        for key in keys {
            assert!(single.contains(key), "missing {key} in {single}");
        }
        assert!(!single.contains("\"resident_copies\""), "single-shell has no residency rows");
        let fed = mem_federated_tri_shell();
        for key in ["\"resident_copies\"", "\"shells\"", "\"federated-tri-shell\""] {
            assert!(fed.contains(key), "missing {key} in {fed}");
        }
    }

    #[test]
    fn scenarios_artifact_has_one_line_per_builtin() {
        let text = scenarios();
        assert_eq!(text.trim().lines().count(), 7);
        for name in [
            "paper-19x5",
            "starlink-shell",
            "kuiper-shell",
            "mega-shell",
            "fork-heavy-chat",
            "federated-dual-shell",
            "federated-tri-shell",
        ] {
            assert!(text.contains(name), "{name} missing");
        }
    }
}
