//! `skymemory` — the SkyMemory launcher.
//!
//! ```text
//! skymemory serve      [--port 8080] [--workers 2] [--strategy rot-hop]
//!                      [--quantizer quanto|hqq|f32] [--no-radix]
//!                      [--link-latency] [--torus PLANESxSLOTS]
//! skymemory generate   --prompt "..." [--max-tokens 30] [--no-cache] [--twice]
//! skymemory satellite  [--torus 5x19] [--planes 0..5] [--budget-mb 64]
//! skymemory simulate   [--strategy ...] [--altitude 550] [--servers 81]
//!                      [--kvc-mb 21] [--proc-ms 2]
//! skymemory scenario   [--name NAME] [--seed 42]      (see scenario --list)
//! skymemory scenario   --list                     (names + descriptions)
//! skymemory scenario   --diff <a.json> <b.json>   (nonzero exit on regression)
//! skymemory sched      [--name mega-shell] [--seed 42] [--windows 1,8,64]
//! skymemory federate   [--shells 2|3 | --name NAME] [--seed 42]
//!                      [--replicate K] [--baseline]
//! skymemory trace      <builtin> [--seed 42] [--out PATH]
//!                      [--format jsonl|chrome] [--spans KIND,...]
//! skymemory mem        <builtin> [--seed 42] [--out PATH]
//! skymemory sessions   <builtin> [--seed 42] [--sessions N]
//!                      [--fork-frac F] [--baseline]
//! skymemory repro      [--outdir results]
//! skymemory bench      --diff <old.json> <new.json> [--tolerance PCT]
//!                      [--det-only]
//! ```
//!
//! `scenario`, `sched`, `federate` and `trace` answer `--help` with their full
//! flag/default/exit-code contract; `docs/CLI.md` is the long-form
//! reference and `docs/METRICS.md` documents every metrics-JSON key.
//! (CLI parsing is hand-rolled: the offline build has no clap.)

use anyhow::{anyhow, bail, Context, Result};
use skymemory::constellation::geometry::Geometry;
use skymemory::constellation::topology::{SatId, Torus};
use skymemory::coordinator::http::HttpServer;
use skymemory::coordinator::{GenRequest, Stack, StackConfig};
use skymemory::kvc::eviction::EvictionPolicy;
use skymemory::kvc::quantize::Quantizer;
use skymemory::mapping::Strategy;
use skymemory::net::transport::LinkModel;
use skymemory::sim::{worst_case_latency, SimConfig};

struct Args {
    flags: std::collections::HashMap<String, String>,
    /// Bare (non-flag) arguments, in order — e.g. the second file of
    /// `scenario --diff a.json b.json`.
    positionals: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut flags = std::collections::HashMap::new();
        let mut positionals = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                positionals.push(a.clone());
            }
            i += 1;
        }
        Self { flags, positionals }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            Some(v) => v.parse().map_err(|_| anyhow!("bad value for --{key}: {v}")),
            None => Ok(default),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn parse_strategy(s: &str) -> Result<Strategy> {
    Strategy::from_name(s).ok_or_else(|| anyhow!("unknown strategy {s} (rot | hop | rot-hop)"))
}

fn parse_quantizer(s: &str, group: usize) -> Result<Quantizer> {
    match s {
        "f32" => Ok(Quantizer::F32),
        "quanto" => Ok(Quantizer::QuantoInt8 { group }),
        "hqq" => Ok(Quantizer::HqqInt8 { group }),
        _ => bail!("unknown quantizer {s} (f32 | quanto | hqq)"),
    }
}

fn parse_torus(s: &str) -> Result<Torus> {
    let (p, sl) = s.split_once('x').ok_or_else(|| anyhow!("torus format PLANESxSLOTS"))?;
    Ok(Torus::new(p.parse()?, sl.parse()?))
}

fn stack_config(args: &Args) -> Result<StackConfig> {
    let mut cfg = StackConfig::default();
    if let Some(t) = args.get("torus") {
        cfg.torus = parse_torus(t)?;
        cfg.geometry = Geometry::new(550.0, cfg.torus.sats_per_plane, cfg.torus.planes);
        cfg.initial_center = SatId::new(
            (cfg.torus.planes / 2) as u16,
            (cfg.torus.sats_per_plane / 2) as u16,
        );
    }
    cfg.n_workers = args.get_or("workers", cfg.n_workers)?;
    if let Some(s) = args.get("strategy") {
        cfg.kvc.strategy = parse_strategy(s)?;
    }
    if let Some(q) = args.get("quantizer") {
        cfg.kvc.quantizer = parse_quantizer(q, 32)?;
    }
    if args.has("no-radix") {
        cfg.kvc.use_radix_index = false;
    }
    cfg.kvc.n_servers = args.get_or("servers", cfg.kvc.n_servers)?;
    if args.has("link-latency") {
        cfg.link = Some(LinkModel::laser_defaults(cfg.geometry));
    }
    Ok(cfg)
}

fn cmd_serve(args: &Args) -> Result<()> {
    let port: u16 = args.get_or("port", 8080)?;
    let stack = Stack::build(stack_config(args)?)?;
    let server = HttpServer::spawn(&format!("127.0.0.1:{port}"), stack.router.clone())?;
    println!("skymemory serving on http://{}", server.addr);
    println!("  POST /generate {{\"prompt\": \"...\", \"max_tokens\": 30}}");
    println!("  GET  /metrics");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_generate(args: &Args) -> Result<()> {
    let prompt = args
        .get("prompt")
        .ok_or_else(|| anyhow!("--prompt required"))?
        .to_string();
    let stack = Stack::build(stack_config(args)?)?;
    let req = GenRequest {
        prompt,
        max_new_tokens: args.get_or("max-tokens", 30)?,
        use_cache: !args.has("no-cache"),
        ..Default::default()
    };
    let runs = if args.has("twice") { 2 } else { 1 };
    for i in 0..runs {
        let r = stack.router.generate(req.clone())?;
        println!(
            "run {}: ttft {:.1} ms, total {:.1} ms, cached blocks {}, prefilled {}",
            i + 1,
            r.ttft_s * 1e3,
            r.total_s * 1e3,
            r.cached_blocks,
            r.prefill_blocks
        );
        println!("  output: {:?}", r.text);
    }
    Ok(())
}

fn cmd_satellite(args: &Args) -> Result<()> {
    let torus = parse_torus(args.get("torus").unwrap_or("5x19"))?;
    let planes = match args.get("planes") {
        Some(p) => {
            let (a, b) = p.split_once("..").ok_or_else(|| anyhow!("--planes A..B"))?;
            Some(a.parse::<usize>()?..b.parse::<usize>()?)
        }
        None => None,
    };
    let budget: usize = args.get_or("budget-mb", 64usize)? << 20;
    let fleet =
        skymemory::net::udp::UdpFleet::spawn(torus, budget, EvictionPolicy::Gossip, planes.clone())?;
    println!(
        "hosting {} satellites of a {}x{} constellation (planes {:?})",
        fleet.book.len(),
        torus.planes,
        torus.sats_per_plane,
        planes.unwrap_or(0..torus.planes),
    );
    for sat in torus.all() {
        if let Some(addr) = fleet.book.get(sat) {
            println!("  {sat} -> {addr}");
        }
    }
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg = SimConfig {
        strategy: parse_strategy(args.get("strategy").unwrap_or("rot-hop"))?,
        altitude_km: args.get_or("altitude", 550.0)?,
        n_servers: args.get_or("servers", 81)?,
        kvc_bytes: args.get_or("kvc-mb", 21usize)? << 20,
        chunk_processing_s: args.get_or("proc-ms", 2.0)? / 1e3,
        ..Default::default()
    };
    let b = worst_case_latency(&cfg);
    println!(
        "{} h={}km servers={} kvc={}MB proc={}ms -> total {:.4}s (network {:.4}s over {} hops, processing {:.4}s, worst server {})",
        cfg.strategy.name(),
        cfg.altitude_km,
        cfg.n_servers,
        cfg.kvc_bytes >> 20,
        cfg.chunk_processing_s * 1e3,
        b.total_s,
        b.network_s,
        b.worst_hops,
        b.processing_s,
        b.worst_server
    );
    Ok(())
}

/// `skymemory scenario --help`.
const SCENARIO_HELP: &str = "\
usage: skymemory scenario [--name NAME] [--seed N]
       skymemory scenario --list
       skymemory scenario --diff <a.json> <b.json>

Run one (or every) built-in scenario end to end and print one line of
byte-stable metrics JSON per run (docs/METRICS.md documents every key).

flags:
  --name NAME   run a single scenario, single-shell or federated; see
                --list for the registry (default: every built-in)
  --seed N      scenario seed (default 42)
  --list        print scenario names and one-line summaries, then exit
  --diff A B    compare two metrics files: per-metric deltas, '!' marks
                regressions (hit rates falling, latencies/failure
                counters rising, tracked metrics or scenarios dropped)
  --help        this text

exit codes: 0 success; 1 --diff found regressions, or an error
(unknown scenario, unreadable file); 2 usage error.
";

/// `skymemory sched --help`.
const SCHED_HELP: &str = "\
usage: skymemory sched [--name NAME] [--seed N] [--windows A,B,C]

Sweep the net::sched per-link in-flight window over one single-shell
scenario; prints one metrics-JSON line plus a '#' summary line per
window (queueing, utilization, tail latency).

flags:
  --name NAME      single-shell scenario to sweep (default mega-shell)
  --seed N         scenario seed (default 42)
  --windows LIST   comma-separated in-flight windows, each >= 1
                   (default 1,8,64)
  --help           this text

exit codes: 0 success; 1 error (unknown or federated scenario, bad
--windows entry); 2 usage error.
";

/// `skymemory federate --help`.
const FEDERATE_HELP: &str = "\
usage: skymemory federate [--shells 2|3 | --name NAME] [--seed N]
                          [--replicate K] [--baseline]

Run a federated scenario end to end and print its metrics JSON
(docs/METRICS.md documents every key, including the replication,
pre-placement and correlated-failure counters).

flags:
  --shells N     built-in federation size: 2 = federated-dual-shell
                 (default), 3 = federated-tri-shell (replication +
                 pre-placement under the correlated-failure plan)
  --name NAME    run a named federated scenario instead of --shells
  --replicate K  override the replication policy: the top-K hottest
                 blocks keep live replicas spanning the two cheapest
                 shells (0 disables replication and pre-placement)
  --seed N       scenario seed (default 42)
  --baseline     also run and print the matching baseline, then gate:
                 a replicated spec must strictly out-hit the
                 re-homing-only federation; a re-homing-only spec must
                 strictly out-hit its single primary shell
  --help         this text

exit codes: 0 success; 1 the --baseline gate failed (the federation did
not strictly beat its baseline) or an error occurred; 2 usage error.
";

fn cmd_scenario(args: &Args) -> Result<()> {
    if args.has("help") {
        print!("{SCENARIO_HELP}");
        return Ok(());
    }
    if args.has("list") {
        for (name, desc) in skymemory::sim::scenario::BUILTIN_SUMMARIES {
            println!("{name:<22} {desc}");
        }
        return Ok(());
    }
    if let Some(a_path) = args.get("diff") {
        let b_path = args
            .positionals
            .first()
            .ok_or_else(|| anyhow!("usage: skymemory scenario --diff <a.json> <b.json>"))?;
        let a = std::fs::read_to_string(a_path).with_context(|| format!("reading {a_path}"))?;
        let b = std::fs::read_to_string(b_path).with_context(|| format!("reading {b_path}"))?;
        let report = skymemory::sim::diff::diff_metrics(&a, &b)?;
        print!("{}", report.render());
        if report.has_regressions() {
            std::process::exit(1);
        }
        return Ok(());
    }
    let seed: u64 = args.get_or("seed", 42u64)?;
    match args.get("name") {
        Some(name) => {
            if let Some(spec) = skymemory::sim::scenario::ScenarioSpec::by_name(name, seed) {
                println!("{}", skymemory::sim::harness::run_scenario(&spec).to_json_string());
            } else if let Some(spec) =
                skymemory::sim::scenario::FederatedScenarioSpec::by_name(name, seed)
            {
                println!(
                    "{}",
                    skymemory::sim::harness::run_federated_scenario(&spec).to_json_string()
                );
            } else {
                bail!("unknown scenario {name} (see `skymemory scenario --list`)");
            }
        }
        None => {
            for spec in skymemory::sim::scenario::ScenarioSpec::builtin(seed) {
                println!("{}", skymemory::sim::harness::run_scenario(&spec).to_json_string());
            }
            for fed in [
                skymemory::sim::scenario::FederatedScenarioSpec::federated_dual_shell(seed),
                skymemory::sim::scenario::FederatedScenarioSpec::federated_tri_shell(seed),
            ] {
                println!(
                    "{}",
                    skymemory::sim::harness::run_federated_scenario(&fed).to_json_string()
                );
            }
        }
    }
    Ok(())
}

/// Sweep the `net::sched` per-link in-flight window over one scenario
/// and print a metrics-JSON line plus a one-line summary per window —
/// the pipelining/queueing trade the event scheduler exposes.
fn cmd_sched(args: &Args) -> Result<()> {
    if args.has("help") {
        print!("{SCHED_HELP}");
        return Ok(());
    }
    let seed: u64 = args.get_or("seed", 42u64)?;
    let name = args.get("name").unwrap_or("mega-shell");
    let windows: Vec<usize> = args
        .get("windows")
        .unwrap_or("1,8,64")
        .split(',')
        .map(|w| match w.trim().parse::<usize>() {
            Ok(n) if n >= 1 => Ok(n),
            _ => Err(anyhow!("bad --windows entry {w:?} (need integers >= 1)")),
        })
        .collect::<Result<_>>()?;
    let Some(base) = skymemory::sim::scenario::ScenarioSpec::by_name(name, seed) else {
        bail!("unknown single-shell scenario {name} (see `skymemory scenario --list`)");
    };
    println!("# net::sched window sweep: {name}, seed {seed}");
    for w in windows {
        let mut spec = base.clone();
        spec.sched_window = w;
        let t0 = std::time::Instant::now();
        let r = skymemory::sim::harness::run_scenario(&spec);
        println!("{}", r.to_json_string());
        println!(
            "# window {w}: net p50 {:.3} ms, p99 {:.3} ms, worst {:.3} ms; peak in-flight {}, \
             queued {:.3} ms, busy {:.3} ms over {} links, wall {:.2?}",
            r.net_p50_ms,
            r.net_p99_ms,
            r.net_worst_ms,
            r.sched.peak_in_flight,
            r.sched.queued_ns as f64 / 1e6,
            r.sched.busy_ns as f64 / 1e6,
            r.sched.links_used,
            t0.elapsed()
        );
    }
    Ok(())
}

fn cmd_federate(args: &Args) -> Result<()> {
    if args.has("help") {
        print!("{FEDERATE_HELP}");
        return Ok(());
    }
    use skymemory::sim::scenario::FederatedScenarioSpec;
    let seed: u64 = args.get_or("seed", 42u64)?;
    let mut spec = match (args.get("name"), args.get_or("shells", 2usize)?) {
        (Some(name), _) => FederatedScenarioSpec::by_name(name, seed).ok_or_else(|| {
            anyhow!("unknown federated scenario {name} (see `skymemory scenario --list`)")
        })?,
        (None, 2) => FederatedScenarioSpec::federated_dual_shell(seed),
        (None, 3) => FederatedScenarioSpec::federated_tri_shell(seed),
        (None, n) => bail!("no built-in {n}-shell federation (--shells 2 or 3, or use --name)"),
    };
    if let Some(k) = args.get("replicate") {
        let k: usize =
            k.parse().map_err(|_| anyhow!("bad value for --replicate: {k} (need >= 0)"))?;
        spec.replicate_top_k = k;
        if k == 0 {
            spec.preplace = false; // the predictor rides the hot set
        }
    }
    spec.validate();
    let report = skymemory::sim::harness::run_federated_scenario(&spec);
    println!("{}", report.to_json_string());
    if args.has("baseline") {
        // acceptance gates: a replicated federation must strictly
        // out-hit the same federation with re-homing only; a re-homing
        // federation must strictly out-hit its single primary shell
        let (base_spec, kind) = if spec.replicate_top_k > 0 {
            (spec.rehoming_baseline(), "re-homing-only")
        } else {
            (spec.baseline_single_shell(), "single-shell")
        };
        let base = skymemory::sim::harness::run_federated_scenario(&base_spec);
        println!("{}", base.to_json_string());
        println!(
            "# federation hit rate {:.3} vs {kind} baseline {:.3} ({} handovers, {} replicas, {} pre-placed, {} inter-shell bytes)",
            report.block_hit_rate,
            base.block_hit_rate,
            report.handovers,
            report.replicated_blocks,
            report.preplaced_blocks,
            report.inter_shell_bytes
        );
        if report.block_hit_rate <= base.block_hit_rate {
            eprintln!("# FAIL: federation does not beat the {kind} baseline");
            std::process::exit(1);
        }
    }
    Ok(())
}

/// `skymemory trace --help`.
const TRACE_HELP: &str = "\
usage: skymemory trace <builtin> [--seed N] [--out PATH]
                       [--format jsonl|chrome] [--spans KIND,...]

Run one built-in scenario (single-shell or federated) with the obs
flight recorder attached and write the trace (docs/TRACING.md documents
the event schema and span kinds).

formats:
  jsonl    one compact JSON object per event, virtual-time ordered and
           byte-stable: two runs of the same seed are byte-identical
           (default)
  chrome   Chrome trace-event JSON for Perfetto / chrome://tracing
           (shells as processes, links as threads)

flags:
  --seed N      scenario seed (default 42)
  --out PATH    write the trace to PATH instead of stdout
  --format F    jsonl (default) or chrome
  --spans LIST  comma-separated span kinds to record, from
                sched,kvc,fed,fault,sim (default: all)
  --help        this text

exit codes: 0 success; 1 error (unknown scenario, bad --spans or
--format, unwritable --out); 2 usage error.
";

fn cmd_trace(args: &Args) -> Result<()> {
    if args.has("help") {
        print!("{TRACE_HELP}");
        return Ok(());
    }
    use skymemory::obs::{chrome, jsonl, Recorder, SpanFilter};
    let Some(name) = args.positionals.first() else {
        bail!("usage: skymemory trace <builtin> [--out PATH] (see --help)");
    };
    let seed: u64 = args.get_or("seed", 42u64)?;
    let filter = match args.get("spans") {
        Some(spec) => SpanFilter::parse(spec).map_err(|e| anyhow!(e))?,
        None => SpanFilter::all(),
    };
    let sink = std::sync::Arc::new(Recorder::with_filter(filter));
    if let Some(spec) = skymemory::sim::scenario::ScenarioSpec::by_name(name, seed) {
        skymemory::sim::harness::run_scenario_with_sink(&spec, sink.clone());
    } else if let Some(spec) = skymemory::sim::scenario::FederatedScenarioSpec::by_name(name, seed)
    {
        skymemory::sim::harness::run_federated_scenario_with_sink(&spec, sink.clone());
    } else {
        bail!("unknown scenario {name} (see `skymemory scenario --list`)");
    }
    let events = sink.take();
    let out = match args.get("format").unwrap_or("jsonl") {
        "jsonl" => jsonl(&events),
        "chrome" => chrome(&events),
        f => bail!("unknown --format {f} (jsonl | chrome)"),
    };
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &out).with_context(|| format!("writing {path}"))?;
            eprintln!("# wrote {} events to {path}", events.len());
        }
        None => print!("{out}"),
    }
    Ok(())
}

/// `skymemory mem --help`.
const MEM_HELP: &str = "\
usage: skymemory mem <builtin> [--seed N] [--out PATH]

Run one built-in scenario (single-shell or federated) and print its
memory-footprint report: the deterministic `memory` object of the
scenario metrics (per-epoch payload/index/overhead series with the
index split into its frozen arena and mutable delta layers
(`frozen_bytes` / `delta_bytes`), end-of-run totals, bytes per cached
token, epoch-compaction count (`compactions`), high-water marks, and
— federated — per-shell residency), keyed by scenario name and seed.
The object is
byte-identical to the `memory` key of `skymemory scenario --name`,
and two runs of the same seed print identical bytes
(docs/METRICS.md documents every key).

flags:
  --seed N    scenario seed (default 42)
  --out PATH  write the report to PATH instead of stdout
  --help      this text

exit codes: 0 success; 1 error (unknown scenario, unwritable --out);
2 usage error.
";

fn cmd_mem(args: &Args) -> Result<()> {
    if args.has("help") {
        print!("{MEM_HELP}");
        return Ok(());
    }
    use skymemory::sim::harness::{run_federated_scenario, run_scenario};
    use skymemory::sim::scenario::{FederatedScenarioSpec, ScenarioSpec};
    use skymemory::util::json::{n, obj, s};
    let Some(name) = args.positionals.first() else {
        bail!("usage: skymemory mem <builtin> [--seed N] [--out PATH] (see --help)");
    };
    let seed: u64 = args.get_or("seed", 42u64)?;
    let report_json = if let Some(spec) = ScenarioSpec::by_name(name, seed) {
        run_scenario(&spec).to_json()
    } else if let Some(spec) = FederatedScenarioSpec::by_name(name, seed) {
        run_federated_scenario(&spec).to_json()
    } else {
        bail!("unknown scenario {name} (see `skymemory scenario --list`)");
    };
    let memory = report_json
        .get("memory")
        .cloned()
        .ok_or_else(|| anyhow!("scenario report carries no memory object"))?;
    let line =
        obj(vec![("memory", memory), ("name", s(name)), ("seed", n(seed as f64))]).to_string();
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, format!("{line}\n")).with_context(|| format!("writing {path}"))?;
            eprintln!("# wrote memory report to {path}");
        }
        None => println!("{line}"),
    }
    Ok(())
}

/// `skymemory sessions --help`.
const SESSIONS_HELP: &str = "\
usage: skymemory sessions <builtin> [--seed N] [--sessions N]
                          [--fork-frac F] [--baseline]

Run a single-shell scenario driven by the kvc::session layer (paged,
forkable sessions with refcounted prefix sharing) and print its metrics
JSON, including the deterministic `sessions` object (fork/drop
counters, blocks shared zero-copy, dedup ratio, refcount histogram,
session-metadata bytes; docs/METRICS.md documents every key).
Scenarios without a session workload (everything but fork-heavy-chat)
get the default one attached.

flags:
  --seed N       scenario seed (default 42)
  --sessions N   pre-register N logical sessions before the run — the
                 10^5..10^7 concurrency sweep knob; metadata only, the
                 served token traffic is identical at every N
  --fork-frac F  fraction of arrivals that fork a live session
                 (0..=1, default from the spec; the extend fraction
                 shrinks if needed so the mix still sums to <= 1)
  --baseline     also run the independent-sessions baseline (the same
                 trace with sharing disabled, every fork replayed as a
                 fresh session), print both, then gate: the fork run
                 must strictly beat the baseline on block hit rate,
                 ISL bytes and bytes per cached token
  --help         this text

exit codes: 0 success; 1 the --baseline gate failed or an error
(unknown or federated scenario, bad flag value); 2 usage error.
";

fn cmd_sessions(args: &Args) -> Result<()> {
    if args.has("help") {
        print!("{SESSIONS_HELP}");
        return Ok(());
    }
    use skymemory::sim::workload::SessionWorkloadConfig;
    let Some(name) = args.positionals.first() else {
        bail!("usage: skymemory sessions <builtin> [--baseline] (see --help)");
    };
    let seed: u64 = args.get_or("seed", 42u64)?;
    let Some(mut spec) = skymemory::sim::scenario::ScenarioSpec::by_name(name, seed) else {
        if skymemory::sim::scenario::FederatedScenarioSpec::by_name(name, seed).is_some() {
            bail!("{name} is federated; `skymemory sessions` drives single-shell scenarios");
        }
        bail!("unknown scenario {name} (see `skymemory scenario --list`)");
    };
    let mut sw =
        spec.sessions.unwrap_or(SessionWorkloadConfig { seed, ..SessionWorkloadConfig::default() });
    sw.presessions = args.get_or("sessions", sw.presessions)?;
    if let Some(f) = args.get("fork-frac") {
        let f: f64 = f.parse().map_err(|_| anyhow!("bad value for --fork-frac: {f}"))?;
        if !(0.0..=1.0).contains(&f) {
            bail!("bad value for --fork-frac: {f} (need 0..=1)");
        }
        sw.fork_frac = f;
        sw.extend_frac = sw.extend_frac.min(1.0 - f);
    }
    spec.sessions = Some(sw);
    spec.validate();
    let report = skymemory::sim::harness::run_scenario(&spec);
    println!("{}", report.to_json_string());
    let s = report.sessions.as_ref().expect("session-driven run reports sessions");
    if args.has("baseline") {
        // acceptance gate: refcounted prefix sharing must strictly beat
        // serving the identical trace as independent sessions — more
        // hits, less orbit traffic, cheaper bytes per cached token
        let base = skymemory::sim::harness::run_scenario(&spec.session_baseline());
        println!("{}", base.to_json_string());
        println!(
            "# fork-sharing hit rate {:.3} vs independent {:.3}; isl bytes {} vs {}; \
             bytes/cached-token {:.3} vs {:.3} ({} forks, {} blocks shared, dedup {:.2})",
            report.block_hit_rate,
            base.block_hit_rate,
            report.isl_bytes,
            base.isl_bytes,
            report.memory.bytes_per_cached_token,
            base.memory.bytes_per_cached_token,
            s.forked,
            s.blocks_shared,
            s.dedup_ratio
        );
        let mut failed = false;
        if report.block_hit_rate <= base.block_hit_rate {
            eprintln!("# FAIL: fork sharing does not out-hit independent sessions");
            failed = true;
        }
        if report.isl_bytes >= base.isl_bytes {
            eprintln!("# FAIL: fork sharing does not reduce ISL traffic");
            failed = true;
        }
        if report.memory.bytes_per_cached_token >= base.memory.bytes_per_cached_token {
            eprintln!("# FAIL: fork sharing does not reduce bytes per cached token");
            failed = true;
        }
        if failed {
            std::process::exit(1);
        }
    } else {
        println!(
            "# sessions: {} created, {} forked, {} dropped, peak {} live, {} blocks shared, \
             dedup {:.2}, {} metadata bytes",
            s.created,
            s.forked,
            s.dropped,
            s.peak_live,
            s.blocks_shared,
            s.dedup_ratio,
            s.metadata_bytes
        );
    }
    Ok(())
}

/// `skymemory bench --help`.
const BENCH_HELP: &str = "\
usage: skymemory bench --diff <old.json> <new.json> [--tolerance PCT]
                       [--det-only]

Compare two BENCH_*.json artifacts written by the bench binaries
(docs/METRICS.md \"Bench artifacts\" documents the schema).
`deterministic.*` counters must match exactly in both directions —
any drift at the same mode and seed is a logic change, not noise.
`timing.*` keys are direction-aware: only slowdowns beyond the
tolerance count as regressions, speedups never do.

flags:
  --diff A B       the two artifact files to compare (old, then new)
  --tolerance PCT  allowed timing slowdown in percent (default 15)
  --det-only       ignore timing.* entirely — compare deterministic
                   counters only (what CI does: timings are not
                   comparable across runner hardware)
  --help           this text

exit codes: 0 no regressions; 1 regressions found (counter drift,
timing beyond tolerance, tracked keys dropped) or an error reading a
file; 2 usage error.
";

fn cmd_bench(args: &Args) -> Result<()> {
    if args.has("help") {
        print!("{BENCH_HELP}");
        return Ok(());
    }
    let Some(a_path) = args.get("diff") else {
        bail!("usage: skymemory bench --diff <old.json> <new.json> (see --help)");
    };
    let b_path = args
        .positionals
        .first()
        .ok_or_else(|| anyhow!("usage: skymemory bench --diff <old.json> <new.json>"))?;
    let tolerance_pct: f64 = args.get_or("tolerance", 15.0)?;
    if !(0.0..1000.0).contains(&tolerance_pct) {
        bail!("bad value for --tolerance: {tolerance_pct} (percent, 0..1000)");
    }
    let a = std::fs::read_to_string(a_path).with_context(|| format!("reading {a_path}"))?;
    let b = std::fs::read_to_string(b_path).with_context(|| format!("reading {b_path}"))?;
    let report = skymemory::sim::diff::diff_bench_metrics(
        &a,
        &b,
        tolerance_pct / 100.0,
        args.has("det-only"),
    )?;
    print!("{}", report.render());
    if report.has_regressions() {
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let outdir = std::path::PathBuf::from(args.get("outdir").unwrap_or("results"));
    let files = skymemory::repro::write_all(&outdir).context("writing results")?;
    for f in &files {
        println!("wrote {}", f.display());
    }
    print!("{}", skymemory::repro::fig16_summary());
    println!("(table3: run `cargo run --release --example e2e_testbed`)");
    Ok(())
}

fn usage() -> ! {
    eprintln!(
        "usage: skymemory <serve|generate|satellite|simulate|scenario|sched|federate|trace|mem|sessions|repro|bench> [flags]\n\
         see rust/src/main.rs header for per-command flags"
    );
    std::process::exit(2)
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
    }
    let args = Args::parse(&argv[1..]);
    match argv[0].as_str() {
        "serve" => cmd_serve(&args),
        "generate" => cmd_generate(&args),
        "satellite" => cmd_satellite(&args),
        "simulate" => cmd_simulate(&args),
        "scenario" => cmd_scenario(&args),
        "sched" => cmd_sched(&args),
        "federate" => cmd_federate(&args),
        "trace" => cmd_trace(&args),
        "mem" => cmd_mem(&args),
        "sessions" => cmd_sessions(&args),
        "repro" => cmd_repro(&args),
        "bench" => cmd_bench(&args),
        _ => usage(),
    }
}
