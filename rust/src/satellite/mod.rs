//! The satellite node substrate — the paper's cFS deployment stand-in.
//!
//! Each satellite runs a chunk [`store`] (hashtable + LRU, §3.9), and a
//! [`node`] that handles SkyMemory requests, forwards packets along the
//! +GRID mesh, gossips evictions, and hands its chunks over on rotation
//! migration.  [`fleet`] assembles full constellations: in-process (one
//! `Node` per satellite behind an `Arc`) or over UDP (one socket + thread
//! per satellite, groupable into OS processes like the paper's 5 NUCs).

pub mod fleet;
pub mod node;
pub mod store;
