//! Per-satellite chunk store: a hashtable with an LRU byte budget (§3.9).
//!
//! "When there is memory pressure, the LRU chunk will be evicted ... As
//! soon as one chunk is gone, the block it belongs to cannot be retrieved
//! and must be purged."  Evicting one chunk therefore purges every local
//! sibling of its block and reports the block hash so the node can gossip
//! the eviction to the neighbourhood.

use crate::kvc::block::BlockHash;
use crate::kvc::chunk::ChunkKey;
use crate::kvc::eviction::LruTracker;
use crate::kvc::session::BlockRefs;
use crate::obs::mem::{FootprintEstimate, MemFootprint};
use std::collections::HashMap;
use std::mem::size_of;
use std::sync::Arc;

/// Store statistics (exported via the node's telemetry).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreStats {
    pub sets: u64,
    pub gets: u64,
    pub hits: u64,
    pub evicted_chunks: u64,
    pub evicted_blocks: u64,
    /// Evictions deflected because a live session still references the
    /// block ([`BlockRefs`]).
    pub pinned_skips: u64,
}

/// A bounded chunk store.
pub struct ChunkStore {
    map: HashMap<ChunkKey, Vec<u8>>,
    lru: LruTracker<ChunkKey>,
    bytes_used: usize,
    byte_budget: usize,
    /// Session refcounts to consult before evicting (None = no session
    /// layer, every block is fair game).
    refs: Option<Arc<BlockRefs>>,
    pub stats: StoreStats,
}

impl ChunkStore {
    /// `byte_budget` caps payload bytes held (metadata overhead ignored).
    pub fn new(byte_budget: usize) -> Self {
        Self {
            map: HashMap::new(),
            lru: LruTracker::new(),
            bytes_used: 0,
            byte_budget,
            refs: None,
            stats: StoreStats::default(),
        }
    }

    /// Install the session-layer reference table: blocks with live refs
    /// are pinned against LRU pressure and propagated evictions.
    pub fn set_block_refs(&mut self, refs: Arc<BlockRefs>) {
        self.refs = Some(refs);
    }

    fn pinned(&self, block: &BlockHash) -> bool {
        self.refs.as_ref().is_some_and(|r| r.is_pinned(block))
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn bytes_used(&self) -> usize {
        self.bytes_used
    }

    pub fn byte_budget(&self) -> usize {
        self.byte_budget
    }

    /// Store a chunk; returns the block hashes fully purged by LRU
    /// pressure (to gossip).  Storing an existing key overwrites.
    pub fn set(&mut self, key: ChunkKey, payload: Vec<u8>) -> Vec<BlockHash> {
        self.stats.sets += 1;
        if payload.len() > self.byte_budget {
            // cannot ever fit; treat as an immediate eviction of itself
            return vec![key.block];
        }
        if let Some(old) = self.map.remove(&key) {
            self.bytes_used -= old.len();
            self.lru.remove(&key);
        }
        let mut purged = Vec::new();
        while self.bytes_used + payload.len() > self.byte_budget {
            match self.evict_lru() {
                Some(block) => {
                    if !purged.contains(&block) {
                        purged.push(block);
                    }
                }
                None => break,
            }
        }
        self.bytes_used += payload.len();
        self.lru.touch(&key);
        self.map.insert(key, payload);
        purged
    }

    /// Fetch a chunk (refreshes LRU).
    pub fn get(&mut self, key: &ChunkKey) -> Option<&Vec<u8>> {
        self.stats.gets += 1;
        if self.map.contains_key(key) {
            self.stats.hits += 1;
            self.lru.touch(key);
            self.map.get(key)
        } else {
            None
        }
    }

    /// Does the store hold a chunk (no LRU side effect)?
    pub fn contains(&self, key: &ChunkKey) -> bool {
        self.map.contains_key(key)
    }

    /// Evict the LRU chunk *and* all local siblings of its block; returns
    /// the purged block hash.  Chunks of session-pinned blocks are
    /// skipped (deflected, counted) — when everything left is pinned the
    /// store runs soft-over-budget rather than reaping a live session's
    /// prefix.
    fn evict_lru(&mut self) -> Option<BlockHash> {
        let mut skipped: Vec<ChunkKey> = Vec::new();
        let mut found = None;
        while let Some(victim) = self.lru.pop_lru() {
            if self.pinned(&victim.block) {
                self.stats.pinned_skips += 1;
                if let Some(r) = &self.refs {
                    r.note_deflection();
                }
                skipped.push(victim);
                continue;
            }
            found = Some(victim);
            break;
        }
        // pinned survivors re-enter at the fresh end, in their prior
        // relative order — they are deflected wherever they sit
        for k in &skipped {
            self.lru.touch(k);
        }
        let victim = found?;
        let block = victim.block;
        if let Some(p) = self.map.remove(&victim) {
            self.bytes_used -= p.len();
            self.stats.evicted_chunks += 1;
        }
        self.purge_block_internal(block);
        self.stats.evicted_blocks += 1;
        Some(block)
    }

    fn purge_block_internal(&mut self, block: BlockHash) -> u32 {
        let siblings: Vec<ChunkKey> =
            self.map.keys().filter(|k| k.block == block).copied().collect();
        let mut dropped = 0;
        for k in siblings {
            if let Some(p) = self.map.remove(&k) {
                self.bytes_used -= p.len();
                self.lru.remove(&k);
                self.stats.evicted_chunks += 1;
                dropped += 1;
            }
        }
        dropped
    }

    /// Drop every chunk of `block` (explicit or gossiped eviction).  A
    /// session-pinned block is deflected: the eviction decrements remote
    /// interest, it must not delete a prefix another live session maps.
    pub fn evict_block(&mut self, block: BlockHash) -> u32 {
        if self.pinned(&block) {
            self.stats.pinned_skips += 1;
            if let Some(r) = &self.refs {
                r.note_deflection();
            }
            return 0;
        }
        let n = self.purge_block_internal(block);
        if n > 0 {
            self.stats.evicted_blocks += 1;
        }
        n
    }

    /// Take everything out (rotation migration handoff), in key order —
    /// `HashMap` iteration order is randomly seeded, and the handoff's
    /// downstream Set order feeds the receiver's LRU, so sorting here
    /// keeps whole simulation runs reproducible.
    pub fn drain_all(&mut self) -> Vec<(ChunkKey, Vec<u8>)> {
        self.bytes_used = 0;
        while self.lru.pop_lru().is_some() {}
        let mut out: Vec<(ChunkKey, Vec<u8>)> = self.map.drain().collect();
        out.sort_unstable_by_key(|(k, _)| *k);
        out
    }

    /// Blocks present locally with their chunk ids (scrub support).
    pub fn blocks_held(&self) -> HashMap<BlockHash, Vec<u32>> {
        let mut out: HashMap<BlockHash, Vec<u32>> = HashMap::new();
        for k in self.map.keys() {
            out.entry(k.block).or_default().push(k.chunk_id);
        }
        out
    }
}

impl MemFootprint for ChunkStore {
    /// Payload = the tracked chunk bytes (what `byte_budget` meters).
    /// Index = one map slot per chunk (key + `Vec` header + control
    /// byte) plus the LRU tracker's bookkeeping.  Overhead = one heap
    /// allocation per chunk payload buffer plus the map table itself.
    fn mem_footprint(&self) -> FootprintEstimate {
        let chunks = self.map.len() as u64;
        let slot = (size_of::<ChunkKey>() + size_of::<Vec<u8>>() + 1) as u64;
        let mut est = FootprintEstimate {
            payload_bytes: self.bytes_used as u64,
            index_bytes: chunks * slot,
            ..FootprintEstimate::ZERO
        };
        est.charge_allocs(chunks + 1);
        est.add(self.lru.footprint());
        est
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(b: u8, c: u32) -> ChunkKey {
        ChunkKey::new(BlockHash([b; 32]), c)
    }

    #[test]
    fn set_get_roundtrip() {
        let mut s = ChunkStore::new(1 << 20);
        assert!(s.set(key(1, 0), vec![1, 2, 3]).is_empty());
        assert_eq!(s.get(&key(1, 0)), Some(&vec![1, 2, 3]));
        assert_eq!(s.get(&key(1, 1)), None);
        assert_eq!(s.stats.hits, 1);
        assert_eq!(s.stats.gets, 2);
    }

    #[test]
    fn overwrite_updates_bytes() {
        let mut s = ChunkStore::new(100);
        s.set(key(1, 0), vec![0; 60]);
        s.set(key(1, 0), vec![0; 40]);
        assert_eq!(s.bytes_used(), 40);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn lru_pressure_purges_whole_block_locally() {
        let mut s = ChunkStore::new(100);
        // block 1 holds two chunks locally (40 bytes total)
        s.set(key(1, 0), vec![0; 20]);
        s.set(key(1, 5), vec![0; 20]);
        s.set(key(2, 0), vec![0; 40]);
        // 100 budget, 80 used; adding 40 more must evict LRU (block 1's
        // chunk 0) AND its sibling chunk 5
        let purged = s.set(key(3, 0), vec![0; 40]);
        assert_eq!(purged, vec![BlockHash([1; 32])]);
        assert!(!s.contains(&key(1, 0)));
        assert!(!s.contains(&key(1, 5)));
        assert!(s.contains(&key(2, 0)));
        assert!(s.contains(&key(3, 0)));
        assert_eq!(s.bytes_used(), 80);
    }

    #[test]
    fn get_refreshes_lru() {
        let mut s = ChunkStore::new(100);
        s.set(key(1, 0), vec![0; 40]);
        s.set(key(2, 0), vec![0; 40]);
        s.get(&key(1, 0)); // block 1 now MRU
        let purged = s.set(key(3, 0), vec![0; 40]);
        assert_eq!(purged, vec![BlockHash([2; 32])]);
        assert!(s.contains(&key(1, 0)));
    }

    #[test]
    fn oversized_payload_rejected_as_self_eviction() {
        let mut s = ChunkStore::new(10);
        let purged = s.set(key(1, 0), vec![0; 100]);
        assert_eq!(purged, vec![BlockHash([1; 32])]);
        assert!(s.is_empty());
    }

    #[test]
    fn explicit_evict_block() {
        let mut s = ChunkStore::new(1000);
        s.set(key(1, 0), vec![0; 10]);
        s.set(key(1, 7), vec![0; 10]);
        s.set(key(2, 0), vec![0; 10]);
        assert_eq!(s.evict_block(BlockHash([1; 32])), 2);
        assert_eq!(s.evict_block(BlockHash([1; 32])), 0);
        assert_eq!(s.len(), 1);
        assert_eq!(s.bytes_used(), 10);
    }

    #[test]
    fn drain_for_migration() {
        let mut s = ChunkStore::new(1000);
        s.set(key(1, 0), vec![1]);
        s.set(key(2, 3), vec![2, 2]);
        let mut all = s.drain_all();
        all.sort_by_key(|(k, _)| *k);
        assert_eq!(all.len(), 2);
        assert!(s.is_empty());
        assert_eq!(s.bytes_used(), 0);
        // store remains usable after drain
        s.set(key(3, 0), vec![0; 10]);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn interleaved_churn_returns_byte_total_to_zero() {
        // satellite task: after interleaved put / evict / drain_all the
        // tracked byte total must return exactly to zero — any residue
        // is leak-style drift in the LRU byte budget
        let mut s = ChunkStore::new(200);
        for round in 0u8..4 {
            for b in 0..6u8 {
                for c in 0..3u32 {
                    s.set(key(b.wrapping_add(round), c), vec![b; 10 + b as usize]);
                }
                if b % 2 == 0 {
                    s.evict_block(BlockHash([b.wrapping_add(round); 32]));
                }
            }
            // overwrite a key twice to exercise the replace path
            s.set(key(round, 0), vec![9; 17]);
            s.set(key(round, 0), vec![9; 5]);
            let drained = s.drain_all();
            assert_eq!(s.bytes_used(), 0, "round {round}: residue after drain");
            assert!(s.is_empty());
            assert!(!drained.is_empty());
            let f = s.mem_footprint();
            assert_eq!(f.payload_bytes, 0);
            // only the fixed container allocations remain
            assert_eq!(f.index_bytes, 0);
        }
    }

    #[test]
    fn footprint_tracks_contents() {
        let mut s = ChunkStore::new(1 << 20);
        let empty = s.mem_footprint();
        s.set(key(1, 0), vec![0; 100]);
        let one = s.mem_footprint();
        assert_eq!(one.payload_bytes, 100);
        assert!(one.index_bytes > empty.index_bytes);
        assert!(one.overhead_bytes > empty.overhead_bytes);
        s.set(key(1, 1), vec![0; 50]);
        let two = s.mem_footprint();
        assert_eq!(two.payload_bytes, 150);
        assert!(two.total() > one.total(), "inserts grow the estimate");
        s.evict_block(BlockHash([1; 32]));
        assert!(s.mem_footprint().total() < two.total(), "eviction shrinks it");
    }

    #[test]
    fn pinned_blocks_survive_pressure_and_gossip() {
        let refs = Arc::new(BlockRefs::new());
        let mut s = ChunkStore::new(100);
        s.set_block_refs(refs.clone());
        refs.acquire(&BlockHash([1; 32]));
        s.set(key(1, 0), vec![0; 40]);
        s.set(key(2, 0), vec![0; 40]);
        // pressure: block 1 is LRU but pinned -> block 2 goes instead
        let purged = s.set(key(3, 0), vec![0; 40]);
        assert_eq!(purged, vec![BlockHash([2; 32])]);
        assert!(s.contains(&key(1, 0)));
        assert!(s.stats.pinned_skips >= 1);
        assert!(refs.deflections() >= 1);
        // an explicit / gossiped eviction is deflected too
        assert_eq!(s.evict_block(BlockHash([1; 32])), 0);
        assert!(s.contains(&key(1, 0)));
        // releasing the last ref makes the block evictable again
        refs.release(&BlockHash([1; 32]));
        assert_eq!(s.evict_block(BlockHash([1; 32])), 1);
    }

    #[test]
    fn all_pinned_runs_soft_over_budget() {
        let refs = Arc::new(BlockRefs::new());
        let mut s = ChunkStore::new(50);
        s.set_block_refs(refs.clone());
        refs.acquire(&BlockHash([1; 32]));
        refs.acquire(&BlockHash([2; 32]));
        s.set(key(1, 0), vec![0; 40]);
        let purged = s.set(key(2, 0), vec![0; 40]);
        assert!(purged.is_empty(), "nothing is evictable: {purged:?}");
        assert!(s.bytes_used() > s.byte_budget(), "soft over budget beats data loss");
        assert!(s.contains(&key(1, 0)) && s.contains(&key(2, 0)));
    }

    #[test]
    fn blocks_held_groups_chunks() {
        let mut s = ChunkStore::new(1000);
        s.set(key(1, 0), vec![1]);
        s.set(key(1, 9), vec![1]);
        s.set(key(2, 4), vec![1]);
        let held = s.blocks_held();
        let mut b1 = held[&BlockHash([1; 32])].clone();
        b1.sort_unstable();
        assert_eq!(b1, vec![0, 9]);
        assert_eq!(held[&BlockHash([2; 32])], vec![4]);
    }
}
