//! Satellite node logic, transport-agnostic: handle a request against the
//! local store, or produce the side-effect sends (eviction gossip,
//! migration chunk transfers) the caller delivers.  Both the in-process
//! fleet and the UDP fleet drive this same handler, so protocol behaviour
//! is identical across transports (the paper's cFS app, minus cFS).

use crate::constellation::topology::{SatId, Torus};
use crate::kvc::chunk::ChunkKey;
use crate::kvc::eviction::EvictionPolicy;
use crate::net::messages::{Envelope, Request, Response};
use crate::obs::mem::{FootprintEstimate, MemFootprint};
use crate::satellite::store::{ChunkStore, StoreStats};
use std::sync::Mutex;

/// A side-effect message the node wants delivered to another satellite.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Outgoing {
    pub dest: SatId,
    pub request: Request,
}

/// One satellite.
pub struct Node {
    pub id: SatId,
    store: Mutex<ChunkStore>,
    pub policy: EvictionPolicy,
}

impl Node {
    pub fn new(id: SatId, byte_budget: usize, policy: EvictionPolicy) -> Self {
        Self { id, store: Mutex::new(ChunkStore::new(byte_budget)), policy }
    }

    pub fn stats(&self) -> StoreStats {
        self.store.lock().unwrap().stats
    }

    /// Install the session-layer reference table on this node's store
    /// ([`crate::kvc::session::BlockRefs`]): referenced blocks are pinned
    /// against LRU pressure and gossiped evictions.
    pub fn set_block_refs(&self, refs: std::sync::Arc<crate::kvc::session::BlockRefs>) {
        self.store.lock().unwrap().set_block_refs(refs);
    }

    pub fn chunk_count(&self) -> usize {
        self.store.lock().unwrap().len()
    }

    pub fn bytes_used(&self) -> usize {
        self.store.lock().unwrap().bytes_used()
    }

    /// Memory-footprint estimate of this satellite's chunk store.
    pub fn footprint(&self) -> FootprintEstimate {
        self.store.lock().unwrap().mem_footprint()
    }

    /// Drop every stored chunk — failure injection for satellite loss (a
    /// lost or rebooted satellite comes back with empty RAM, or never).
    /// Returns the number of chunks lost.
    pub fn clear(&self) -> u32 {
        let mut store = self.store.lock().unwrap();
        let n = store.len() as u32;
        store.drain_all();
        n
    }

    /// Take every stored chunk out, key-sorted (deterministic) — the
    /// evacuation drain used by cross-shell handover, where the receiving
    /// satellite lives on a *different* torus and the in-fleet
    /// [`Request::Migrate`] side-effect delivery cannot reach it.
    pub fn drain_chunks(&self) -> Vec<(ChunkKey, Vec<u8>)> {
        self.store.lock().unwrap().drain_all()
    }

    /// Handle a request addressed to this node.  Returns the response and
    /// any side-effect sends (gossip, migration transfers).
    pub fn handle(&self, torus: &Torus, env: &Envelope, req: &Request) -> (Response, Vec<Outgoing>) {
        debug_assert_eq!(env.dest, self.id);
        match req {
            Request::Set { key, payload } => {
                let purged = self.store.lock().unwrap().set(*key, payload.clone());
                let mut out = Vec::new();
                if self.policy == EvictionPolicy::Gossip {
                    for block in purged {
                        // §3.9: "a simple gossip broadcast in all
                        // directions is sufficient" — the eviction radius
                        // covers the concentric neighbourhood.
                        for nb in torus.neighbors(self.id) {
                            out.push(Outgoing {
                                dest: nb,
                                request: Request::Evict { block, gossip_ttl: 2 },
                            });
                        }
                    }
                }
                (Response::SetOk, out)
            }
            Request::Get { key } => {
                let mut store = self.store.lock().unwrap();
                match store.get(key) {
                    Some(p) => (Response::GetOk { payload: p.clone() }, vec![]),
                    None => (Response::GetMiss, vec![]),
                }
            }
            Request::Evict { block, gossip_ttl } => {
                let dropped = self.store.lock().unwrap().evict_block(*block);
                let mut out = Vec::new();
                if *gossip_ttl > 0 {
                    for nb in torus.neighbors(self.id) {
                        out.push(Outgoing {
                            dest: nb,
                            request: Request::Evict { block: *block, gossip_ttl: gossip_ttl - 1 },
                        });
                    }
                }
                (Response::EvictOk { dropped }, out)
            }
            Request::Migrate { to } => {
                let chunks = self.store.lock().unwrap().drain_all();
                let moved = chunks.len() as u32;
                let out = chunks
                    .into_iter()
                    .map(|(key, payload)| Outgoing {
                        dest: *to,
                        request: Request::Set { key, payload },
                    })
                    .collect();
                (Response::MigrateOk { moved }, out)
            }
            Request::Ping => (Response::Pong, vec![]),
            Request::Query { block } => {
                let store = self.store.lock().unwrap();
                let mut chunk_ids = store
                    .blocks_held()
                    .remove(block)
                    .unwrap_or_default();
                chunk_ids.sort_unstable();
                chunk_ids.truncate(512); // bound the response datagram
                (Response::QueryOk { chunk_ids }, vec![])
            }
        }
    }

    /// Scrub pass (EvictionPolicy::PeriodicScrub): drop blocks whose local
    /// chunk-id set looks incomplete given the striping arithmetic — a
    /// block striped over `n_servers` with `num_chunks` total must give
    /// this store either `floor` or `ceil` of `num_chunks / n_servers`
    /// chunks with ids congruent mod `n_servers`; anything inconsistent is
    /// partial garbage.  Without the block metadata we conservatively drop
    /// blocks whose ids are NOT congruent modulo `n_servers`.
    pub fn scrub(&self, n_servers: usize) -> u32 {
        let mut store = self.store.lock().unwrap();
        let mut dropped = 0;
        for (block, ids) in store.blocks_held() {
            if ids.len() > 1 {
                let r = ids[0] as usize % n_servers;
                if ids.iter().any(|i| *i as usize % n_servers != r) {
                    dropped += store.evict_block(block);
                }
            }
        }
        dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvc::block::BlockHash;
    use crate::kvc::chunk::ChunkKey;

    fn setup() -> (Torus, Node) {
        let torus = Torus::new(5, 19);
        let node = Node::new(SatId::new(2, 9), 1 << 16, EvictionPolicy::Gossip);
        (torus, node)
    }

    fn key(b: u8, c: u32) -> ChunkKey {
        ChunkKey::new(BlockHash([b; 32]), c)
    }

    fn env(node: &Node) -> Envelope {
        Envelope::new(node.id, 7)
    }

    #[test]
    fn set_then_get() {
        let (t, n) = setup();
        let (r, out) = n.handle(&t, &env(&n), &Request::Set { key: key(1, 0), payload: vec![5; 100] });
        assert_eq!(r, Response::SetOk);
        assert!(out.is_empty());
        let (r, _) = n.handle(&t, &env(&n), &Request::Get { key: key(1, 0) });
        assert_eq!(r, Response::GetOk { payload: vec![5; 100] });
        let (r, _) = n.handle(&t, &env(&n), &Request::Get { key: key(1, 1) });
        assert_eq!(r, Response::GetMiss);
    }

    #[test]
    fn eviction_pressure_gossips_to_four_neighbors() {
        let t = Torus::new(5, 19);
        let n = Node::new(SatId::new(2, 9), 150, EvictionPolicy::Gossip);
        let e = Envelope::new(n.id, 1);
        n.handle(&t, &e, &Request::Set { key: key(1, 0), payload: vec![0; 100] });
        let (_, out) = n.handle(&t, &e, &Request::Set { key: key(2, 0), payload: vec![0; 100] });
        assert_eq!(out.len(), 4, "gossip to N,E,S,W");
        for o in &out {
            assert!(matches!(
                o.request,
                Request::Evict { block, gossip_ttl: 2 } if block == BlockHash([1; 32])
            ));
            assert!(t.neighbors(n.id).contains(&o.dest));
        }
    }

    #[test]
    fn lazy_policy_does_not_gossip() {
        let t = Torus::new(5, 19);
        let n = Node::new(SatId::new(2, 9), 150, EvictionPolicy::Lazy);
        let e = Envelope::new(n.id, 1);
        n.handle(&t, &e, &Request::Set { key: key(1, 0), payload: vec![0; 100] });
        let (_, out) = n.handle(&t, &e, &Request::Set { key: key(2, 0), payload: vec![0; 100] });
        assert!(out.is_empty());
    }

    #[test]
    fn evict_decrements_ttl() {
        let (t, n) = setup();
        n.handle(&t, &env(&n), &Request::Set { key: key(1, 0), payload: vec![1] });
        let (r, out) = n.handle(
            &t,
            &env(&n),
            &Request::Evict { block: BlockHash([1; 32]), gossip_ttl: 2 },
        );
        assert_eq!(r, Response::EvictOk { dropped: 1 });
        assert_eq!(out.len(), 4);
        for o in &out {
            assert!(matches!(o.request, Request::Evict { gossip_ttl: 1, .. }));
        }
        // ttl 0 stops the flood
        let (_, out) = n.handle(
            &t,
            &env(&n),
            &Request::Evict { block: BlockHash([1; 32]), gossip_ttl: 0 },
        );
        assert!(out.is_empty());
    }

    #[test]
    fn migrate_hands_over_everything() {
        let (t, n) = setup();
        n.handle(&t, &env(&n), &Request::Set { key: key(1, 0), payload: vec![1] });
        n.handle(&t, &env(&n), &Request::Set { key: key(2, 4), payload: vec![2] });
        let target = SatId::new(2, 6);
        let (r, out) = n.handle(&t, &env(&n), &Request::Migrate { to: target });
        assert_eq!(r, Response::MigrateOk { moved: 2 });
        assert_eq!(out.len(), 2);
        for o in &out {
            assert_eq!(o.dest, target);
            assert!(matches!(o.request, Request::Set { .. }));
        }
        assert_eq!(n.chunk_count(), 0);
    }

    #[test]
    fn scrub_drops_inconsistent_stripes() {
        let (t, n) = setup();
        let e = env(&n);
        // block 1: ids 3 and 13 are congruent mod 10 — consistent
        n.handle(&t, &e, &Request::Set { key: key(1, 3), payload: vec![1] });
        n.handle(&t, &e, &Request::Set { key: key(1, 13), payload: vec![1] });
        // block 2: ids 0 and 1 cannot both live here with 10 servers
        n.handle(&t, &e, &Request::Set { key: key(2, 0), payload: vec![1] });
        n.handle(&t, &e, &Request::Set { key: key(2, 1), payload: vec![1] });
        let dropped = n.scrub(10);
        assert_eq!(dropped, 2);
        assert_eq!(n.chunk_count(), 2);
    }

    #[test]
    fn ping_pong() {
        let (t, n) = setup();
        let (r, out) = n.handle(&t, &env(&n), &Request::Ping);
        assert_eq!(r, Response::Pong);
        assert!(out.is_empty());
    }
}
