//! In-process constellation: every satellite is a [`Node`] behind an
//! `Arc`, and "packets" hop the +GRID mesh by counted routing steps.  This
//! fleet backs the in-proc transport (fast, deterministic, used by tests,
//! benches and the quickstart); the UDP fleet in [`crate::net::udp`] runs
//! the identical node logic over real sockets.

use crate::constellation::topology::{SatId, Torus};
use crate::kvc::eviction::EvictionPolicy;
use crate::net::messages::{Envelope, Request, Response};
use crate::satellite::node::{Node, Outgoing};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Delivery report for one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Delivery {
    pub response_kind_ok: bool,
    /// ISL hops the request traversed from the entry satellite.
    pub isl_hops: usize,
}

/// An in-process constellation.
pub struct Fleet {
    pub torus: Torus,
    nodes: Vec<Arc<Node>>,
    /// Total ISL hops traversed (requests + side effects), for telemetry.
    pub total_hops: AtomicU64,
    /// Total side-effect messages delivered (gossip, migration sets).
    pub side_effects: AtomicU64,
}

impl Fleet {
    pub fn new(torus: Torus, byte_budget_per_sat: usize, policy: EvictionPolicy) -> Self {
        let nodes = torus
            .all()
            .map(|id| Arc::new(Node::new(id, byte_budget_per_sat, policy)))
            .collect();
        Self { torus, nodes, total_hops: AtomicU64::new(0), side_effects: AtomicU64::new(0) }
    }

    pub fn node(&self, sat: SatId) -> &Arc<Node> {
        &self.nodes[sat.linear(self.torus.sats_per_plane)]
    }

    pub fn nodes(&self) -> &[Arc<Node>] {
        &self.nodes
    }

    /// Install the session-layer reference table on every satellite's
    /// store: session-referenced blocks are pinned against LRU pressure
    /// and propagated evictions fleet-wide.
    pub fn set_block_refs(&self, refs: &Arc<crate::kvc::session::BlockRefs>) {
        for node in &self.nodes {
            node.set_block_refs(refs.clone());
        }
    }

    /// Deliver `req` to `env.dest`, entering the constellation at `entry`
    /// (the ground uplink satellite).  Returns the response and the ISL
    /// hop count; side-effect sends (gossip, migration) are delivered
    /// breadth-first in the background of the same call.
    pub fn deliver(&self, entry: SatId, env: Envelope, req: Request) -> (Response, usize) {
        let hops = self.torus.hops(entry, env.dest);
        self.total_hops.fetch_add(hops as u64, Ordering::Relaxed);
        if hops > env.ttl as usize {
            // unreachable within TTL: routing drops the packet
            return (Response::Error { code: 1 }, hops);
        }
        let dest = env.dest;
        let (resp, outgoing) = self.node(dest).handle(&self.torus, &env, &req);
        self.run_side_effects(dest, outgoing);
        (resp, hops)
    }

    fn run_side_effects(&self, origin: SatId, outgoing: Vec<Outgoing>) {
        let mut queue: VecDeque<(SatId, Outgoing)> =
            outgoing.into_iter().map(|o| (origin, o)).collect();
        // Bounded flood: TTLs inside Evict requests bound gossip; migration
        // Sets generate no further sends; cap defensively anyway.
        let mut budget = 100_000usize;
        while let Some((from, o)) = queue.pop_front() {
            if budget == 0 {
                break;
            }
            budget -= 1;
            self.side_effects.fetch_add(1, Ordering::Relaxed);
            let hops = self.torus.hops(from, o.dest) as u64;
            self.total_hops.fetch_add(hops, Ordering::Relaxed);
            let env = Envelope::new(o.dest, 0);
            let (_, next) = self.node(o.dest).handle(&self.torus, &env, &o.request);
            for n in next {
                queue.push_back((o.dest, n));
            }
        }
    }

    /// Execute a rotation migration plan (§3.4): one Migrate per moving
    /// satellite, issued in parallel per plane in the real system — here
    /// sequentially but order-independent.
    pub fn migrate(&self, plan: &[crate::mapping::migration::MigrationMove]) -> u32 {
        let mut moved = 0;
        // Each satellite drains once even if it hosts several servers.
        let mut seen: Vec<(SatId, SatId)> = Vec::new();
        for m in plan {
            if seen.contains(&(m.from, m.to)) {
                continue;
            }
            seen.push((m.from, m.to));
            let env = Envelope::new(m.from, 0);
            let (resp, _) = self.deliver(m.from, env, Request::Migrate { to: m.to });
            if let Response::MigrateOk { moved: n } = resp {
                moved += n;
            }
        }
        moved
    }

    /// Total chunks stored across the constellation.
    pub fn total_chunks(&self) -> usize {
        self.nodes.iter().map(|n| n.chunk_count()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvc::block::BlockHash;
    use crate::kvc::chunk::ChunkKey;

    fn key(b: u8, c: u32) -> ChunkKey {
        ChunkKey::new(BlockHash([b; 32]), c)
    }

    fn fleet() -> Fleet {
        Fleet::new(Torus::new(5, 19), 1 << 20, EvictionPolicy::Gossip)
    }

    #[test]
    fn set_get_across_the_torus() {
        let f = fleet();
        let entry = SatId::new(2, 9);
        let dest = SatId::new(4, 2);
        let env = Envelope::new(dest, 1);
        let (r, hops) =
            f.deliver(entry, env.clone(), Request::Set { key: key(1, 0), payload: vec![9; 64] });
        assert_eq!(r, Response::SetOk);
        assert_eq!(hops, f.torus.hops(entry, dest));
        let (r, _) = f.deliver(entry, env, Request::Get { key: key(1, 0) });
        assert_eq!(r, Response::GetOk { payload: vec![9; 64] });
    }

    #[test]
    fn gossip_eviction_reaches_neighborhood() {
        let f = fleet();
        let center = SatId::new(2, 9);
        let block = BlockHash([5; 32]);
        // store the same block's chunks on centre and a ring-2 neighbour
        for (sat, c) in [(center, 0u32), (f.torus.north(center), 1), (f.torus.east(f.torus.east(center)), 2)] {
            let env = Envelope::new(sat, 1);
            f.deliver(sat, env, Request::Set { key: ChunkKey::new(block, c), payload: vec![1] });
        }
        assert_eq!(f.total_chunks(), 3);
        // explicit eviction at the centre gossips outward (ttl 2 covers
        // the ring-2 neighbour)
        let env = Envelope::new(center, 2);
        let (r, _) = f.deliver(center, env, Request::Evict { block, gossip_ttl: 2 });
        assert!(matches!(r, Response::EvictOk { .. }));
        assert_eq!(f.total_chunks(), 0, "gossip must purge the neighbourhood");
        assert!(f.side_effects.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn migration_moves_chunks_between_sats() {
        let f = fleet();
        let from = SatId::new(1, 18);
        let to = SatId::new(1, 15);
        for c in 0..5u32 {
            let env = Envelope::new(from, 1);
            f.deliver(from, env, Request::Set { key: key(9, c), payload: vec![c as u8; 32] });
        }
        let plan = vec![crate::mapping::migration::MigrationMove { server: 1, from, to }];
        let moved = f.migrate(&plan);
        assert_eq!(moved, 5);
        assert_eq!(f.node(from).chunk_count(), 0);
        assert_eq!(f.node(to).chunk_count(), 5);
        let env = Envelope::new(to, 2);
        let (r, _) = f.deliver(to, env, Request::Get { key: key(9, 3) });
        assert_eq!(r, Response::GetOk { payload: vec![3; 32] });
    }

    #[test]
    fn duplicate_migration_targets_drain_once() {
        let f = fleet();
        let from = SatId::new(0, 0);
        let to = SatId::new(0, 4);
        let env = Envelope::new(from, 1);
        f.deliver(from, env, Request::Set { key: key(1, 0), payload: vec![1] });
        let plan = vec![
            crate::mapping::migration::MigrationMove { server: 1, from, to },
            crate::mapping::migration::MigrationMove { server: 4, from, to },
        ];
        assert_eq!(f.migrate(&plan), 1);
    }

    #[test]
    fn hop_accounting() {
        let f = fleet();
        let entry = SatId::new(0, 0);
        let dest = SatId::new(2, 5);
        let before = f.total_hops.load(Ordering::Relaxed);
        f.deliver(entry, Envelope::new(dest, 1), Request::Ping);
        let after = f.total_hops.load(Ordering::Relaxed);
        assert_eq!(after - before, f.torus.hops(entry, dest) as u64);
    }
}
