//! Metrics-diff: compare two metrics JSON files and report per-metric
//! deltas, flagging regressions.
//!
//! A metrics file holds one JSON object per line (the format
//! `skymemory scenario`, `repro::scenarios`, the sweep example and the
//! `BENCH_*.json` bench artifacts emit); objects pair up by their
//! `"name"` field.  Nested objects (`kvc`, `shells[i]`) are flattened
//! with dotted keys.  A per-key [`Rule`] decides what counts as a
//! regression; two classifiers ship:
//!
//! * [`diff_metrics`] — scenario semantics: direction-aware keys (hit
//!   rates falling or latencies / failure counters rising regress),
//!   everything else a neutral delta.  Backs
//!   `skymemory scenario --diff a.json b.json`.
//! * [`diff_bench_metrics`] — bench-artifact semantics: every
//!   `deterministic.*` key must match exactly, every `timing.*` key is
//!   lower-better within a relative tolerance (machine noise is not a
//!   regression), and `--det-only` ignores timing keys entirely.  Backs
//!   `skymemory bench --diff old.json new.json`.
//!
//! Both exit nonzero when regressions are found, so the tools gate CI
//! runs across commits.  `docs/METRICS.md` documents the file formats,
//! every metric key and worked `--diff` examples.

use crate::util::json::Json;
use anyhow::{bail, Result};

/// How one flattened key participates in the diff.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Rule {
    /// A drop beyond [`EPS`] regresses (hit rates).
    HigherBetter,
    /// A rise beyond [`EPS`] regresses (latencies, failure counters).
    LowerBetter,
    /// Any change beyond [`EPS`] regresses (deterministic counters).
    Exact,
    /// Lower is better, but only a rise beyond `a * (1 + tol)` regresses
    /// (timing stats: machine noise inside the tolerance is neutral).
    TolerantLower(f64),
    /// Changes are reported but never regress.
    Neutral,
    /// The key does not participate at all (not even in missing-key lists).
    Ignore,
}

impl Rule {
    /// Tracked keys cannot be silently dropped from the second file.
    fn tracked(self) -> bool {
        !matches!(self, Rule::Neutral | Rule::Ignore)
    }
}

/// Metrics where *bigger* is better (suffix match on flattened keys).
const HIGHER_BETTER: &[&str] =
    &["block_hit_rate", "hit_rate", "blocks_hit", "prefix_hits", "blocks_fetched"];

/// Metrics where *smaller* is better (suffix match on flattened keys).
const LOWER_BETTER: &[&str] = &[
    "net_mean_ms",
    "net_p50_ms",
    "net_p99_ms",
    "net_worst_ms",
    "failed_writes",
    "failed_migrations",
    "blackholed_requests",
    "broken_blocks",
    "evicted_blocks",
    "evicted_chunks",
    "dropped_ttl",
    "dropped_stale",
    "dropped_unroutable",
];

/// Comparison tolerance: deltas at or below this are noise, not changes.
const EPS: f64 = 1e-9;

/// One metric's before/after pair.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricDelta {
    /// Scenario name the metric belongs to.
    pub scenario: String,
    /// Flattened metric key (e.g. `kvc.prefix_hits`, `shells.1.hit_rate`).
    pub key: String,
    pub a: f64,
    pub b: f64,
    pub regression: bool,
}

impl MetricDelta {
    pub fn delta(&self) -> f64 {
        self.b - self.a
    }
}

/// The full comparison of two metrics files.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DiffReport {
    /// Metrics whose value changed (beyond tolerance), in deterministic
    /// (scenario, key) order.
    pub deltas: Vec<MetricDelta>,
    /// Scenarios present on only one side.  A scenario that disappears
    /// from the second file is a regression for the same reason a
    /// dropped metric key is: the gate cannot be passed by deletion.
    pub only_in_a: Vec<String>,
    pub only_in_b: Vec<String>,
    /// (scenario, key) pairs present in the first file but not the second
    /// — a dropped direction-tracked metric counts as a regression (a
    /// file cannot pass the gate by deleting its bad numbers).
    pub keys_only_in_a: Vec<(String, String)>,
    /// (scenario, key) pairs present only in the second file.
    pub keys_only_in_b: Vec<(String, String)>,
    /// The subset of `keys_only_in_a` whose rule is tracked
    /// (direction-aware, exact or tolerance-compared) — each of these
    /// drops is a regression.
    pub tracked_key_drops: Vec<(String, String)>,
}

impl DiffReport {
    pub fn has_regressions(&self) -> bool {
        self.deltas.iter().any(|d| d.regression)
            || !self.tracked_key_drops.is_empty()
            || !self.only_in_a.is_empty()
    }

    pub fn regressions(&self) -> impl Iterator<Item = &MetricDelta> {
        self.deltas.iter().filter(|d| d.regression)
    }

    /// Human-readable rendering, one line per changed metric.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        for name in &self.only_in_a {
            let _ = writeln!(out, "! {name}: only in the first file");
        }
        for name in &self.only_in_b {
            let _ = writeln!(out, "+ {name}: only in the second file");
        }
        for pair in &self.keys_only_in_a {
            let marker = if self.tracked_key_drops.contains(pair) { "!" } else { "-" };
            let (scenario, key) = pair;
            let _ = writeln!(out, "{marker} {scenario}/{key}: missing in the second file");
        }
        for (scenario, key) in &self.keys_only_in_b {
            let _ = writeln!(out, "+ {scenario}/{key}: only in the second file");
        }
        for d in &self.deltas {
            let marker = if d.regression { "!" } else { " " };
            let _ = writeln!(
                out,
                "{marker} {}/{}: {} -> {} ({:+})",
                d.scenario,
                d.key,
                d.a,
                d.b,
                d.delta()
            );
        }
        let nothing = self.deltas.is_empty()
            && self.only_in_a.is_empty()
            && self.only_in_b.is_empty()
            && self.keys_only_in_a.is_empty()
            && self.keys_only_in_b.is_empty();
        if nothing {
            out.push_str("no differences\n");
        } else {
            let regressions =
                self.regressions().count() + self.tracked_key_drops.len() + self.only_in_a.len();
            let _ =
                writeln!(out, "{} metrics changed, {} regressions", self.deltas.len(), regressions);
        }
        out
    }
}

fn direction(key: &str) -> Option<bool> {
    // Some(true) = higher is better, Some(false) = lower is better
    let leaf = key.rsplit('.').next().unwrap_or(key);
    if HIGHER_BETTER.contains(&leaf) {
        Some(true)
    } else if LOWER_BETTER.contains(&leaf) {
        Some(false)
    } else {
        None
    }
}

/// Flatten a JSON value into (dotted key, number) pairs; strings and
/// booleans are skipped (the `name` key is the pairing handle, not a
/// metric).
fn flatten(prefix: &str, j: &Json, out: &mut Vec<(String, f64)>) {
    match j {
        Json::Num(v) => out.push((prefix.to_string(), *v)),
        Json::Obj(m) => {
            for (k, v) in m {
                let key = if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
                flatten(&key, v, out);
            }
        }
        Json::Arr(a) => {
            for (i, v) in a.iter().enumerate() {
                flatten(&format!("{prefix}.{i}"), v, out);
            }
        }
        _ => {}
    }
}

/// Parse one metrics file: one JSON object per nonempty line, keyed by its
/// `"name"` (falling back to the line number).  A name that repeats
/// within a file (e.g. the same scenario at several seeds) gets a `#k`
/// occurrence suffix, so pairing across files stays positional per name
/// instead of silently comparing everything against the first occurrence.
fn parse_metrics(text: &str) -> Result<Vec<(String, Vec<(String, f64)>)>> {
    let mut out: Vec<(String, Vec<(String, f64)>)> = Vec::new();
    let mut seen: std::collections::BTreeMap<String, usize> = std::collections::BTreeMap::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let j = Json::parse(line).map_err(|e| {
            anyhow::anyhow!(
                "line {}: {e} (metrics files hold one JSON object per line, as emitted by \
                 `skymemory scenario`; docs/METRICS.md documents the format and every key)",
                i + 1
            )
        })?;
        if !matches!(j, Json::Obj(_)) {
            bail!(
                "line {}: expected a JSON object (one scenario report per line; see \
                 docs/METRICS.md)",
                i + 1
            );
        }
        let base = j
            .get("name")
            .and_then(|n| n.as_str())
            .map(|s| s.to_string())
            .unwrap_or_else(|| format!("line-{}", i + 1));
        let count = seen.entry(base.clone()).or_insert(0);
        *count += 1;
        let name = if *count == 1 { base } else { format!("{base}#{count}") };
        let mut flat = Vec::new();
        flatten("", &j, &mut flat);
        out.push((name, flat));
    }
    Ok(out)
}

/// Diff two metrics files under a per-key rule classifier.
fn diff_with<F: Fn(&str) -> Rule>(a_text: &str, b_text: &str, rule: F) -> Result<DiffReport> {
    let a = parse_metrics(a_text)?;
    let b = parse_metrics(b_text)?;
    let mut report = DiffReport::default();
    for (name, _) in &a {
        if !b.iter().any(|(n, _)| n == name) {
            report.only_in_a.push(name.clone());
        }
    }
    for (name, _) in &b {
        if !a.iter().any(|(n, _)| n == name) {
            report.only_in_b.push(name.clone());
        }
    }
    for (name, a_flat) in &a {
        let Some((_, b_flat)) = b.iter().find(|(n, _)| n == name) else { continue };
        for (key, _) in b_flat {
            if rule(key) != Rule::Ignore && !a_flat.iter().any(|(k, _)| k == key) {
                report.keys_only_in_b.push((name.clone(), key.clone()));
            }
        }
        for (key, av) in a_flat {
            let key_rule = rule(key);
            if key_rule == Rule::Ignore {
                continue;
            }
            let Some((_, bv)) = b_flat.iter().find(|(k, _)| k == key) else {
                report.keys_only_in_a.push((name.clone(), key.clone()));
                if key_rule.tracked() {
                    report.tracked_key_drops.push((name.clone(), key.clone()));
                }
                continue;
            };
            let delta = bv - av;
            if delta.abs() <= EPS {
                continue;
            }
            let regression = match key_rule {
                Rule::HigherBetter => delta < -EPS,
                Rule::LowerBetter => delta > EPS,
                Rule::Exact => true,
                Rule::TolerantLower(tol) => *bv > av * (1.0 + tol) + EPS,
                Rule::Neutral | Rule::Ignore => false,
            };
            report.deltas.push(MetricDelta {
                scenario: name.clone(),
                key: key.clone(),
                a: *av,
                b: *bv,
                regression,
            });
        }
    }
    Ok(report)
}

/// Scenario classifier: the direction tables above, neutral otherwise.
/// `memory.*` keys carry their own rules so footprint regressions gate
/// CI exactly like latency ones: every byte counter (and the
/// bytes-per-cached-token efficiency figure) is lower-better,
/// `cached_tokens` is higher-better (losing cache coverage regresses
/// too), and epoch stamps / residency counts are neutral.  `sessions.*`
/// keys likewise: losing prefix reuse (`dedup_ratio`, `blocks_shared`,
/// `shared_blocks` falling) regresses, session-metadata bytes rising
/// regresses, and the raw op counters / refcount histogram are neutral
/// bookkeeping.
fn scenario_rule(key: &str) -> Rule {
    if let Some(rest) = key.strip_prefix("sessions.") {
        let leaf = rest.rsplit('.').next().unwrap_or(rest);
        return if leaf == "dedup_ratio" || leaf == "blocks_shared" || leaf == "shared_blocks" {
            Rule::HigherBetter
        } else if leaf.ends_with("_bytes") {
            Rule::LowerBetter
        } else {
            Rule::Neutral
        };
    }
    if key.starts_with("memory.") {
        let leaf = key.rsplit('.').next().unwrap_or(key);
        return if leaf == "cached_tokens" {
            Rule::HigherBetter
        } else if leaf == "bytes_per_cached_token" || leaf.ends_with("_bytes") {
            Rule::LowerBetter
        } else {
            Rule::Neutral
        };
    }
    match direction(key) {
        Some(true) => Rule::HigherBetter,
        Some(false) => Rule::LowerBetter,
        None => Rule::Neutral,
    }
}

/// Diff two scenario metrics files (the raw text of each).
pub fn diff_metrics(a_text: &str, b_text: &str) -> Result<DiffReport> {
    diff_with(a_text, b_text, scenario_rule)
}

/// Diff two `BENCH_*.json` artifacts: `deterministic.*` keys compare
/// exactly (any change regresses — those counters must be bit-identical
/// run-over-run), `timing.*` keys are lower-better within a relative
/// `timing_tolerance` (0.15 = ±15%), and `det_only` drops timing keys
/// from the comparison entirely (the CI gate runs on shared runners
/// whose wall-clock numbers are not comparable to the baselines').
pub fn diff_bench_metrics(
    a_text: &str,
    b_text: &str,
    timing_tolerance: f64,
    det_only: bool,
) -> Result<DiffReport> {
    diff_with(a_text, b_text, move |key: &str| {
        if key.starts_with("deterministic.") {
            Rule::Exact
        } else if key.starts_with("timing.") {
            if det_only {
                Rule::Ignore
            } else {
                Rule::TolerantLower(timing_tolerance)
            }
        } else {
            Rule::Neutral
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const A: &str = r#"{"name":"s1","block_hit_rate":0.8,"net_p99_ms":12.5,"requests":100,"kvc":{"prefix_hits":40}}"#;

    #[test]
    fn identical_files_have_no_differences() {
        let r = diff_metrics(A, A).unwrap();
        assert!(r.deltas.is_empty());
        assert!(!r.has_regressions());
        assert_eq!(r.render(), "no differences\n");
    }

    #[test]
    fn hit_rate_drop_is_a_regression() {
        let b = A.replace("0.8", "0.7");
        let r = diff_metrics(A, &b).unwrap();
        assert!(r.has_regressions());
        let reg: Vec<_> = r.regressions().collect();
        assert_eq!(reg.len(), 1);
        assert_eq!(reg[0].key, "block_hit_rate");
        assert!(r.render().contains("! s1/block_hit_rate: 0.8 -> 0.7"));
    }

    #[test]
    fn latency_rise_is_a_regression_and_improvement_is_not() {
        let worse = A.replace("12.5", "99.5");
        assert!(diff_metrics(A, &worse).unwrap().has_regressions());
        let better = A.replace("12.5", "2.5");
        let r = diff_metrics(A, &better).unwrap();
        assert_eq!(r.deltas.len(), 1, "the improvement is still reported");
        assert!(!r.has_regressions());
    }

    #[test]
    fn neutral_metrics_change_without_regressing() {
        let b = A.replace("\"requests\":100", "\"requests\":120");
        let r = diff_metrics(A, &b).unwrap();
        assert_eq!(r.deltas.len(), 1);
        assert_eq!(r.deltas[0].key, "requests");
        assert!(!r.has_regressions());
    }

    #[test]
    fn nested_keys_flatten_with_direction() {
        let b = A.replace("\"prefix_hits\":40", "\"prefix_hits\":10");
        let r = diff_metrics(A, &b).unwrap();
        assert!(r.has_regressions());
        assert_eq!(r.deltas[0].key, "kvc.prefix_hits");
    }

    #[test]
    fn shell_arrays_flatten_by_index() {
        let a = r#"{"name":"fed","shells":[{"hit_rate":0.9},{"hit_rate":0.5}]}"#;
        let b = r#"{"name":"fed","shells":[{"hit_rate":0.9},{"hit_rate":0.2}]}"#;
        let r = diff_metrics(a, b).unwrap();
        assert!(r.has_regressions());
        assert_eq!(r.deltas[0].key, "shells.1.hit_rate");
    }

    #[test]
    fn transport_drop_counters_regress_when_they_rise() {
        let a = r#"{"name":"s1","dropped_ttl":0,"dropped_stale":1,"dropped_unroutable":0}"#;
        let b = r#"{"name":"s1","dropped_ttl":3,"dropped_stale":1,"dropped_unroutable":0}"#;
        let r = diff_metrics(a, b).unwrap();
        assert!(r.has_regressions());
        assert_eq!(r.regressions().next().unwrap().key, "dropped_ttl");
        // drops going away is an improvement, not a regression
        let r2 = diff_metrics(b, a).unwrap();
        assert_eq!(r2.deltas.len(), 1);
        assert!(!r2.has_regressions());
    }

    #[test]
    fn timeline_epochs_flatten_by_index() {
        let a = r#"{"name":"s1","timeline":{"epochs":[{"epoch":0,"hit_rate":0.9}],"links":[{"transfers":5}],"links_elided":0}}"#;
        let b = r#"{"name":"s1","timeline":{"epochs":[{"epoch":0,"hit_rate":0.4}],"links":[{"transfers":5}],"links_elided":0}}"#;
        let r = diff_metrics(a, b).unwrap();
        assert!(r.has_regressions());
        assert_eq!(r.deltas[0].key, "timeline.epochs.0.hit_rate");
    }

    #[test]
    fn dropped_tracked_metric_is_a_regression() {
        // deleting a bad number cannot pass the gate
        let b = A.replace("\"block_hit_rate\":0.8,", "");
        let r = diff_metrics(A, &b).unwrap();
        assert_eq!(r.keys_only_in_a, vec![("s1".to_string(), "block_hit_rate".to_string())]);
        assert!(r.has_regressions());
        assert!(r.render().contains("! s1/block_hit_rate: missing in the second file"));
        // dropping an untracked metric is reported but does not regress
        let b2 = A.replace("\"requests\":100,", "");
        let r2 = diff_metrics(A, &b2).unwrap();
        assert!(!r2.has_regressions());
        assert!(r2.render().contains("- s1/requests: missing in the second file"));
        // a brand-new metric on the right side is listed too
        let r3 = diff_metrics(&b2, A).unwrap();
        assert_eq!(r3.keys_only_in_b, vec![("s1".to_string(), "requests".to_string())]);
        assert!(!r3.has_regressions());
    }

    #[test]
    fn mismatched_scenarios_are_listed_and_drops_regress() {
        let b = r#"{"name":"s2","block_hit_rate":0.8}"#;
        let r = diff_metrics(A, b).unwrap();
        assert_eq!(r.only_in_a, vec!["s1"]);
        assert_eq!(r.only_in_b, vec!["s2"]);
        assert!(r.has_regressions(), "a dropped scenario cannot pass the gate");
        assert!(r.render().contains("! s1: only in the first file"));
        // a purely-added scenario is fine
        let both = format!("{A}\n{b}\n");
        let r2 = diff_metrics(A, &both).unwrap();
        assert_eq!(r2.only_in_b, vec!["s2"]);
        assert!(!r2.has_regressions());
    }

    #[test]
    fn duplicate_names_pair_positionally() {
        // two runs of the same scenario per file: second pairs with second
        let a = format!("{A}\n{}\n", A.replace("0.8", "0.6"));
        let b = format!("{A}\n{}\n", A.replace("0.8", "0.5"));
        let r = diff_metrics(&a, &b).unwrap();
        let reg: Vec<_> = r.regressions().collect();
        assert_eq!(reg.len(), 1, "{r:?}");
        assert_eq!(reg[0].scenario, "s1#2");
        assert_eq!((reg[0].a, reg[0].b), (0.6, 0.5));
        // an extra occurrence on one side surfaces as a missing scenario
        let r2 = diff_metrics(&a, A).unwrap();
        assert_eq!(r2.only_in_a, vec!["s1#2"]);
    }

    const MEM: &str = r#"{"name":"s1","memory":{"epochs":[{"cached_tokens":32,"epoch":0,"total_bytes":100}],"summary":{"bytes_per_cached_token":3.125,"cached_tokens":32,"index_bytes":20,"overhead_bytes":16,"payload_bytes":64,"peak_epoch":0,"peak_total_bytes":100,"shells":[{"name":"a","resident_copies":2,"total_bytes":100}],"total_bytes":100}}}"#;

    #[test]
    fn memory_bytes_rise_is_a_regression() {
        let worse = MEM.replace(r#""total_bytes":100}}}"#, r#""total_bytes":150}}}"#);
        let r = diff_metrics(MEM, &worse).unwrap();
        assert!(r.has_regressions());
        assert_eq!(r.regressions().next().unwrap().key, "memory.summary.total_bytes");
        // shrinking the footprint is an improvement, not a regression
        let better = MEM.replace(r#""total_bytes":100}}}"#, r#""total_bytes":80}}}"#);
        let r2 = diff_metrics(MEM, &better).unwrap();
        assert_eq!(r2.deltas.len(), 1, "still reported");
        assert!(!r2.has_regressions());
    }

    #[test]
    fn memory_efficiency_and_coverage_have_directions() {
        let worse =
            MEM.replace(r#""bytes_per_cached_token":3.125"#, r#""bytes_per_cached_token":9.5"#);
        assert!(diff_metrics(MEM, &worse).unwrap().has_regressions());
        // losing cached tokens regresses; epoch stamps and residency
        // counts are neutral bookkeeping
        let fewer = MEM.replace(
            r#""cached_tokens":32,"index_bytes""#,
            r#""cached_tokens":16,"index_bytes""#,
        );
        let r = diff_metrics(MEM, &fewer).unwrap();
        assert!(r.has_regressions());
        assert_eq!(r.regressions().next().unwrap().key, "memory.summary.cached_tokens");
        let moved = MEM.replace(r#""resident_copies":2"#, r#""resident_copies":5"#);
        assert!(!diff_metrics(MEM, &moved).unwrap().has_regressions());
        let peak = MEM.replace(r#""peak_epoch":0"#, r#""peak_epoch":2"#);
        assert!(!diff_metrics(MEM, &peak).unwrap().has_regressions());
    }

    const SES: &str = r#"{"name":"fhc","sessions":{"blocks_shared":90,"created":20,"dedup_ratio":2.5,"deflected_evictions":3,"dropped":12,"forked":14,"live":22,"metadata_bytes":4096,"mode":"shared","peak_live":25,"presessions":0,"refcount_histogram":[4,3,2,0,0,0,0,1],"shared_blocks":9,"total_refs":60,"unique_blocks":24}}"#;

    #[test]
    fn session_sharing_losses_regress_and_bookkeeping_is_neutral() {
        // less prefix reuse regresses …
        let worse = SES.replace(r#""dedup_ratio":2.5"#, r#""dedup_ratio":1.1"#);
        let r = diff_metrics(SES, &worse).unwrap();
        assert!(r.has_regressions());
        assert_eq!(r.regressions().next().unwrap().key, "sessions.dedup_ratio");
        let fewer = SES.replace(r#""blocks_shared":90"#, r#""blocks_shared":10"#);
        assert!(diff_metrics(SES, &fewer).unwrap().has_regressions());
        // … as does session metadata growing …
        let heavier = SES.replace(r#""metadata_bytes":4096"#, r#""metadata_bytes":9999"#);
        let r2 = diff_metrics(SES, &heavier).unwrap();
        assert!(r2.has_regressions());
        assert_eq!(r2.regressions().next().unwrap().key, "sessions.metadata_bytes");
        // … while op counters and the refcount histogram are neutral.
        let churn = SES
            .replace(r#""forked":14"#, r#""forked":17"#)
            .replace(r#""refcount_histogram":[4,"#, r#""refcount_histogram":[7,"#);
        let r3 = diff_metrics(SES, &churn).unwrap();
        assert_eq!(r3.deltas.len(), 2, "{r3:?}");
        assert!(!r3.has_regressions());
        // improvements in either tracked direction never regress
        let better = SES
            .replace(r#""dedup_ratio":2.5"#, r#""dedup_ratio":4.0"#)
            .replace(r#""metadata_bytes":4096"#, r#""metadata_bytes":2048"#);
        assert!(!diff_metrics(SES, &better).unwrap().has_regressions());
    }

    #[test]
    fn per_epoch_memory_series_is_direction_tracked() {
        let worse =
            MEM.replace(r#""epoch":0,"total_bytes":100}"#, r#""epoch":0,"total_bytes":400}"#);
        let r = diff_metrics(MEM, &worse).unwrap();
        assert!(r.has_regressions());
        assert_eq!(r.regressions().next().unwrap().key, "memory.epochs.0.total_bytes");
    }

    const MEM_SPLIT: &str = r#"{"name":"s2","memory":{"epochs":[{"cached_tokens":32,"delta_bytes":0,"epoch":0,"frozen_bytes":52,"total_bytes":100}],"summary":{"bytes_per_cached_token":3.125,"cached_tokens":32,"compactions":3,"delta_bytes":12,"frozen_bytes":40,"index_bytes":20,"overhead_bytes":32,"payload_bytes":48,"total_bytes":100}}}"#;

    #[test]
    fn frozen_and_delta_split_is_direction_tracked() {
        // the frozen layer growing regresses …
        let fatter = MEM_SPLIT.replace(r#""frozen_bytes":40"#, r#""frozen_bytes":90"#);
        let r = diff_metrics(MEM_SPLIT, &fatter).unwrap();
        assert!(r.has_regressions());
        assert_eq!(r.regressions().next().unwrap().key, "memory.summary.frozen_bytes");
        // … as does an unmerged delta swelling, and the per-epoch series
        // carries the same direction as the summary
        let swollen = MEM_SPLIT.replace(r#""delta_bytes":12"#, r#""delta_bytes":500"#);
        assert!(diff_metrics(MEM_SPLIT, &swollen).unwrap().has_regressions());
        let epoch = MEM_SPLIT.replace(r#""frozen_bytes":52"#, r#""frozen_bytes":99"#);
        let re = diff_metrics(MEM_SPLIT, &epoch).unwrap();
        assert!(re.has_regressions());
        assert_eq!(re.regressions().next().unwrap().key, "memory.epochs.0.frozen_bytes");
        // shrinking a layer is an improvement, not a regression
        let thinner = MEM_SPLIT.replace(r#""frozen_bytes":40"#, r#""frozen_bytes":8"#);
        let r2 = diff_metrics(MEM_SPLIT, &thinner).unwrap();
        assert_eq!(r2.deltas.len(), 1, "still reported");
        assert!(!r2.has_regressions());
    }

    #[test]
    fn compaction_cadence_is_neutral_bookkeeping() {
        // epoch boundaries may merge the delta more or less often
        // without that being a regression in either direction
        let often = MEM_SPLIT.replace(r#""compactions":3"#, r#""compactions":7"#);
        let r = diff_metrics(MEM_SPLIT, &often).unwrap();
        assert_eq!(r.deltas.len(), 1, "still reported");
        assert!(!r.has_regressions());
        let never = MEM_SPLIT.replace(r#""compactions":3"#, r#""compactions":0"#);
        assert!(!diff_metrics(MEM_SPLIT, &never).unwrap().has_regressions());
        // the classifier itself, pinned: the split keys are lower-better
        // wherever they appear, the cadence counter is neutral
        assert_eq!(scenario_rule("memory.summary.frozen_bytes"), Rule::LowerBetter);
        assert_eq!(scenario_rule("memory.summary.delta_bytes"), Rule::LowerBetter);
        assert_eq!(scenario_rule("memory.epochs.9.delta_bytes"), Rule::LowerBetter);
        assert_eq!(scenario_rule("memory.summary.compactions"), Rule::Neutral);
    }

    const BA: &str = r#"{"deterministic":{"op":{"bytes":128,"iters":2},"sched.transfers":38},"mode":"smoke","name":"hotpath","timing":{"op":{"mean_ns":1000,"p50_ns":900}}}"#;

    #[test]
    fn bench_counter_change_regresses_in_either_direction() {
        let down = BA.replace(r#""sched.transfers":38"#, r#""sched.transfers":37"#);
        let r = diff_bench_metrics(BA, &down, 0.15, false).unwrap();
        assert!(r.has_regressions());
        assert_eq!(r.regressions().next().unwrap().key, "deterministic.sched.transfers");
        let up = BA.replace(r#""sched.transfers":38"#, r#""sched.transfers":39"#);
        assert!(diff_bench_metrics(BA, &up, 0.15, false).unwrap().has_regressions());
    }

    #[test]
    fn bench_timing_noise_inside_tolerance_is_not_a_regression() {
        let noisy = BA.replace(r#""mean_ns":1000"#, r#""mean_ns":1100"#);
        let r = diff_bench_metrics(BA, &noisy, 0.15, false).unwrap();
        assert_eq!(r.deltas.len(), 1, "still reported");
        assert!(!r.has_regressions(), "+10% is inside the ±15% tolerance");
        let worse = BA.replace(r#""mean_ns":1000"#, r#""mean_ns":1200"#);
        assert!(diff_bench_metrics(BA, &worse, 0.15, false).unwrap().has_regressions());
        let better = BA.replace(r#""mean_ns":1000"#, r#""mean_ns":500"#);
        assert!(!diff_bench_metrics(BA, &better, 0.15, false).unwrap().has_regressions());
    }

    #[test]
    fn bench_det_only_ignores_timing_keys_entirely() {
        let much_worse = BA.replace(r#""mean_ns":1000"#, r#""mean_ns":9000"#);
        let r = diff_bench_metrics(BA, &much_worse, 0.15, true).unwrap();
        assert!(r.deltas.is_empty());
        assert!(!r.has_regressions());
        let no_timing =
            BA.replace(r#","timing":{"op":{"mean_ns":1000,"p50_ns":900}}"#, r#","timing":{}"#);
        let r2 = diff_bench_metrics(BA, &no_timing, 0.15, true).unwrap();
        assert!(r2.keys_only_in_a.is_empty(), "{r2:?}");
        assert!(!r2.has_regressions());
    }

    #[test]
    fn bench_added_counters_are_neutral_but_drops_regress() {
        // bootstrap baselines carry a subset of the counters a real run
        // emits; the fresh file adding keys must pass the gate …
        let fresh = BA.replace(
            r#""sched.transfers":38"#,
            r#""sched.transfers":38,"sched.virtual_time_ns":123"#,
        );
        let r = diff_bench_metrics(BA, &fresh, 0.15, true).unwrap();
        assert_eq!(
            r.keys_only_in_b,
            vec![("hotpath".to_string(), "deterministic.sched.virtual_time_ns".to_string())]
        );
        assert!(!r.has_regressions());
        // … but dropping a baseline counter cannot.
        let r2 = diff_bench_metrics(&fresh, BA, 0.15, true).unwrap();
        assert_eq!(r2.tracked_key_drops, r2.keys_only_in_a);
        assert!(r2.has_regressions());
    }

    #[test]
    fn comments_and_blank_lines_are_skipped_and_garbage_rejected() {
        let with_comments = format!("# a sweep header\n\n{A}\n");
        assert!(diff_metrics(&with_comments, A).unwrap().deltas.is_empty());
        assert!(diff_metrics("not json", A).is_err());
        assert!(diff_metrics("[1,2]", A).is_err());
    }
}
