//! Scenario-diff: compare two scenario metrics JSON files and report
//! per-metric deltas, flagging regressions.
//!
//! A metrics file holds one JSON object per line (the format
//! `skymemory scenario`, `repro::scenarios` and the sweep example emit);
//! objects pair up by their `"name"` field.  Nested objects (`kvc`,
//! `shells[i]`) are flattened with dotted keys.  Direction-aware keys
//! decide what counts as a regression: hit rates falling or latencies /
//! failure counters rising; everything else is reported as a neutral
//! delta.  `skymemory scenario --diff a.json b.json` exits nonzero when
//! regressions are found, so the tool gates CI runs across commits.
//! `docs/METRICS.md` documents the file format, every metric key and a
//! worked `--diff` example.

use crate::util::json::Json;
use anyhow::{bail, Result};

/// Metrics where *bigger* is better (suffix match on flattened keys).
const HIGHER_BETTER: &[&str] =
    &["block_hit_rate", "hit_rate", "blocks_hit", "prefix_hits", "blocks_fetched"];

/// Metrics where *smaller* is better (suffix match on flattened keys).
const LOWER_BETTER: &[&str] = &[
    "net_mean_ms",
    "net_p50_ms",
    "net_p99_ms",
    "net_worst_ms",
    "failed_writes",
    "failed_migrations",
    "blackholed_requests",
    "broken_blocks",
    "evicted_blocks",
    "evicted_chunks",
];

/// Comparison tolerance: deltas at or below this are noise, not changes.
const EPS: f64 = 1e-9;

/// One metric's before/after pair.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricDelta {
    /// Scenario name the metric belongs to.
    pub scenario: String,
    /// Flattened metric key (e.g. `kvc.prefix_hits`, `shells.1.hit_rate`).
    pub key: String,
    pub a: f64,
    pub b: f64,
    pub regression: bool,
}

impl MetricDelta {
    pub fn delta(&self) -> f64 {
        self.b - self.a
    }
}

/// The full comparison of two metrics files.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DiffReport {
    /// Metrics whose value changed (beyond tolerance), in deterministic
    /// (scenario, key) order.
    pub deltas: Vec<MetricDelta>,
    /// Scenarios present on only one side.  A scenario that disappears
    /// from the second file is a regression for the same reason a
    /// dropped metric key is: the gate cannot be passed by deletion.
    pub only_in_a: Vec<String>,
    pub only_in_b: Vec<String>,
    /// (scenario, key) pairs present in the first file but not the second
    /// — a dropped direction-tracked metric counts as a regression (a
    /// file cannot pass the gate by deleting its bad numbers).
    pub keys_only_in_a: Vec<(String, String)>,
    /// (scenario, key) pairs present only in the second file.
    pub keys_only_in_b: Vec<(String, String)>,
}

impl DiffReport {
    pub fn has_regressions(&self) -> bool {
        self.deltas.iter().any(|d| d.regression)
            || self.keys_only_in_a.iter().any(|(_, k)| direction(k).is_some())
            || !self.only_in_a.is_empty()
    }

    pub fn regressions(&self) -> impl Iterator<Item = &MetricDelta> {
        self.deltas.iter().filter(|d| d.regression)
    }

    /// Human-readable rendering, one line per changed metric.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        for name in &self.only_in_a {
            let _ = writeln!(out, "! {name}: only in the first file");
        }
        for name in &self.only_in_b {
            let _ = writeln!(out, "+ {name}: only in the second file");
        }
        for (scenario, key) in &self.keys_only_in_a {
            let marker = if direction(key).is_some() { "!" } else { "-" };
            let _ = writeln!(out, "{marker} {scenario}/{key}: missing in the second file");
        }
        for (scenario, key) in &self.keys_only_in_b {
            let _ = writeln!(out, "+ {scenario}/{key}: only in the second file");
        }
        for d in &self.deltas {
            let marker = if d.regression { "!" } else { " " };
            let _ = writeln!(
                out,
                "{marker} {}/{}: {} -> {} ({:+})",
                d.scenario,
                d.key,
                d.a,
                d.b,
                d.delta()
            );
        }
        let nothing = self.deltas.is_empty()
            && self.only_in_a.is_empty()
            && self.only_in_b.is_empty()
            && self.keys_only_in_a.is_empty()
            && self.keys_only_in_b.is_empty();
        if nothing {
            out.push_str("no differences\n");
        } else {
            let regressions = self.regressions().count()
                + self.keys_only_in_a.iter().filter(|(_, k)| direction(k).is_some()).count()
                + self.only_in_a.len();
            let _ =
                writeln!(out, "{} metrics changed, {} regressions", self.deltas.len(), regressions);
        }
        out
    }
}

fn direction(key: &str) -> Option<bool> {
    // Some(true) = higher is better, Some(false) = lower is better
    let leaf = key.rsplit('.').next().unwrap_or(key);
    if HIGHER_BETTER.contains(&leaf) {
        Some(true)
    } else if LOWER_BETTER.contains(&leaf) {
        Some(false)
    } else {
        None
    }
}

/// Flatten a JSON value into (dotted key, number) pairs; strings and
/// booleans are skipped (the `name` key is the pairing handle, not a
/// metric).
fn flatten(prefix: &str, j: &Json, out: &mut Vec<(String, f64)>) {
    match j {
        Json::Num(v) => out.push((prefix.to_string(), *v)),
        Json::Obj(m) => {
            for (k, v) in m {
                let key = if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
                flatten(&key, v, out);
            }
        }
        Json::Arr(a) => {
            for (i, v) in a.iter().enumerate() {
                flatten(&format!("{prefix}.{i}"), v, out);
            }
        }
        _ => {}
    }
}

/// Parse one metrics file: one JSON object per nonempty line, keyed by its
/// `"name"` (falling back to the line number).  A name that repeats
/// within a file (e.g. the same scenario at several seeds) gets a `#k`
/// occurrence suffix, so pairing across files stays positional per name
/// instead of silently comparing everything against the first occurrence.
fn parse_metrics(text: &str) -> Result<Vec<(String, Vec<(String, f64)>)>> {
    let mut out: Vec<(String, Vec<(String, f64)>)> = Vec::new();
    let mut seen: std::collections::BTreeMap<String, usize> = std::collections::BTreeMap::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let j = Json::parse(line).map_err(|e| {
            anyhow::anyhow!(
                "line {}: {e} (metrics files hold one JSON object per line, as emitted by \
                 `skymemory scenario`; docs/METRICS.md documents the format and every key)",
                i + 1
            )
        })?;
        if !matches!(j, Json::Obj(_)) {
            bail!(
                "line {}: expected a JSON object (one scenario report per line; see \
                 docs/METRICS.md)",
                i + 1
            );
        }
        let base = j
            .get("name")
            .and_then(|n| n.as_str())
            .map(|s| s.to_string())
            .unwrap_or_else(|| format!("line-{}", i + 1));
        let count = seen.entry(base.clone()).or_insert(0);
        *count += 1;
        let name = if *count == 1 { base } else { format!("{base}#{count}") };
        let mut flat = Vec::new();
        flatten("", &j, &mut flat);
        out.push((name, flat));
    }
    Ok(out)
}

/// Diff two metrics files (the raw text of each).
pub fn diff_metrics(a_text: &str, b_text: &str) -> Result<DiffReport> {
    let a = parse_metrics(a_text)?;
    let b = parse_metrics(b_text)?;
    let mut report = DiffReport::default();
    for (name, _) in &a {
        if !b.iter().any(|(n, _)| n == name) {
            report.only_in_a.push(name.clone());
        }
    }
    for (name, _) in &b {
        if !a.iter().any(|(n, _)| n == name) {
            report.only_in_b.push(name.clone());
        }
    }
    for (name, a_flat) in &a {
        let Some((_, b_flat)) = b.iter().find(|(n, _)| n == name) else { continue };
        for (key, _) in b_flat {
            if !a_flat.iter().any(|(k, _)| k == key) {
                report.keys_only_in_b.push((name.clone(), key.clone()));
            }
        }
        for (key, av) in a_flat {
            let Some((_, bv)) = b_flat.iter().find(|(k, _)| k == key) else {
                report.keys_only_in_a.push((name.clone(), key.clone()));
                continue;
            };
            let delta = bv - av;
            if delta.abs() <= EPS {
                continue;
            }
            let regression = match direction(key) {
                Some(true) => delta < -EPS,
                Some(false) => delta > EPS,
                None => false,
            };
            report.deltas.push(MetricDelta {
                scenario: name.clone(),
                key: key.clone(),
                a: *av,
                b: *bv,
                regression,
            });
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    const A: &str = r#"{"name":"s1","block_hit_rate":0.8,"net_p99_ms":12.5,"requests":100,"kvc":{"prefix_hits":40}}"#;

    #[test]
    fn identical_files_have_no_differences() {
        let r = diff_metrics(A, A).unwrap();
        assert!(r.deltas.is_empty());
        assert!(!r.has_regressions());
        assert_eq!(r.render(), "no differences\n");
    }

    #[test]
    fn hit_rate_drop_is_a_regression() {
        let b = A.replace("0.8", "0.7");
        let r = diff_metrics(A, &b).unwrap();
        assert!(r.has_regressions());
        let reg: Vec<_> = r.regressions().collect();
        assert_eq!(reg.len(), 1);
        assert_eq!(reg[0].key, "block_hit_rate");
        assert!(r.render().contains("! s1/block_hit_rate: 0.8 -> 0.7"));
    }

    #[test]
    fn latency_rise_is_a_regression_and_improvement_is_not() {
        let worse = A.replace("12.5", "99.5");
        assert!(diff_metrics(A, &worse).unwrap().has_regressions());
        let better = A.replace("12.5", "2.5");
        let r = diff_metrics(A, &better).unwrap();
        assert_eq!(r.deltas.len(), 1, "the improvement is still reported");
        assert!(!r.has_regressions());
    }

    #[test]
    fn neutral_metrics_change_without_regressing() {
        let b = A.replace("\"requests\":100", "\"requests\":120");
        let r = diff_metrics(A, &b).unwrap();
        assert_eq!(r.deltas.len(), 1);
        assert_eq!(r.deltas[0].key, "requests");
        assert!(!r.has_regressions());
    }

    #[test]
    fn nested_keys_flatten_with_direction() {
        let b = A.replace("\"prefix_hits\":40", "\"prefix_hits\":10");
        let r = diff_metrics(A, &b).unwrap();
        assert!(r.has_regressions());
        assert_eq!(r.deltas[0].key, "kvc.prefix_hits");
    }

    #[test]
    fn shell_arrays_flatten_by_index() {
        let a = r#"{"name":"fed","shells":[{"hit_rate":0.9},{"hit_rate":0.5}]}"#;
        let b = r#"{"name":"fed","shells":[{"hit_rate":0.9},{"hit_rate":0.2}]}"#;
        let r = diff_metrics(a, b).unwrap();
        assert!(r.has_regressions());
        assert_eq!(r.deltas[0].key, "shells.1.hit_rate");
    }

    #[test]
    fn dropped_tracked_metric_is_a_regression() {
        // deleting a bad number cannot pass the gate
        let b = A.replace("\"block_hit_rate\":0.8,", "");
        let r = diff_metrics(A, &b).unwrap();
        assert_eq!(r.keys_only_in_a, vec![("s1".to_string(), "block_hit_rate".to_string())]);
        assert!(r.has_regressions());
        assert!(r.render().contains("! s1/block_hit_rate: missing in the second file"));
        // dropping an untracked metric is reported but does not regress
        let b2 = A.replace("\"requests\":100,", "");
        let r2 = diff_metrics(A, &b2).unwrap();
        assert!(!r2.has_regressions());
        assert!(r2.render().contains("- s1/requests: missing in the second file"));
        // a brand-new metric on the right side is listed too
        let r3 = diff_metrics(&b2, A).unwrap();
        assert_eq!(r3.keys_only_in_b, vec![("s1".to_string(), "requests".to_string())]);
        assert!(!r3.has_regressions());
    }

    #[test]
    fn mismatched_scenarios_are_listed_and_drops_regress() {
        let b = r#"{"name":"s2","block_hit_rate":0.8}"#;
        let r = diff_metrics(A, b).unwrap();
        assert_eq!(r.only_in_a, vec!["s1"]);
        assert_eq!(r.only_in_b, vec!["s2"]);
        assert!(r.has_regressions(), "a dropped scenario cannot pass the gate");
        assert!(r.render().contains("! s1: only in the first file"));
        // a purely-added scenario is fine
        let both = format!("{A}\n{b}\n");
        let r2 = diff_metrics(A, &both).unwrap();
        assert_eq!(r2.only_in_b, vec!["s2"]);
        assert!(!r2.has_regressions());
    }

    #[test]
    fn duplicate_names_pair_positionally() {
        // two runs of the same scenario per file: second pairs with second
        let a = format!("{A}\n{}\n", A.replace("0.8", "0.6"));
        let b = format!("{A}\n{}\n", A.replace("0.8", "0.5"));
        let r = diff_metrics(&a, &b).unwrap();
        let reg: Vec<_> = r.regressions().collect();
        assert_eq!(reg.len(), 1, "{r:?}");
        assert_eq!(reg[0].scenario, "s1#2");
        assert_eq!((reg[0].a, reg[0].b), (0.6, 0.5));
        // an extra occurrence on one side surfaces as a missing scenario
        let r2 = diff_metrics(&a, A).unwrap();
        assert_eq!(r2.only_in_a, vec!["s1#2"]);
    }

    #[test]
    fn comments_and_blank_lines_are_skipped_and_garbage_rejected() {
        let with_comments = format!("# a sweep header\n\n{A}\n");
        assert!(diff_metrics(&with_comments, A).unwrap().deltas.is_empty());
        assert!(diff_metrics("not json", A).is_err());
        assert!(diff_metrics("[1,2]", A).is_err());
    }
}
