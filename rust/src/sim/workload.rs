//! Serving workload generator: prompts with shared prefixes, modelled on
//! the vLLM prefix-caching benchmark the paper's §5 validation uses (a
//! fixed document context + varying questions).

use crate::util::rng::XorShift64;

/// Workload configuration.
#[derive(Debug, Clone, Copy)]
pub struct WorkloadConfig {
    /// Number of distinct shared contexts ("documents").
    pub n_contexts: usize,
    /// Characters per context (>= a few blocks to make caching matter).
    pub context_chars: usize,
    /// Distinct question suffixes per context.
    pub n_questions: usize,
    /// Every `k`-th request uses a fresh one-shot context instead of a
    /// shared one (0 = never): cold "scan" traffic that pollutes the
    /// cache and keeps LRU eviction pressure realistic without thrashing
    /// the hot set.
    pub scan_every: usize,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        Self { n_contexts: 4, context_chars: 128, n_questions: 8, scan_every: 0, seed: 7 }
    }
}

const WORDS: &[&str] = &[
    "satellite", "orbit", "cache", "chunk", "block", "hash", "token", "laser", "link", "ground",
    "plane", "mesh", "torus", "hop", "memory", "model", "prompt", "prefix", "inference", "sky",
];

/// Deterministic prose of at least `chars` characters.
pub fn synth_text(rng: &mut XorShift64, chars: usize) -> String {
    let mut s = String::with_capacity(chars + 16);
    while s.len() < chars {
        if !s.is_empty() {
            s.push(' ');
        }
        s.push_str(WORDS[rng.next_range(WORDS.len())]);
    }
    s.truncate(chars);
    s
}

/// A generated request.
#[derive(Debug, Clone)]
pub struct WorkloadItem {
    pub prompt: String,
    pub context_id: usize,
}

/// Generate `n` requests: each picks a context (round-robin) and appends
/// one of its question suffixes, so requests sharing a context share a
/// multi-block prefix — the paper's repeated-context regime.  With
/// `scan_every > 0`, every `k`-th request instead carries a fresh
/// one-shot context (cold traffic that is never revisited).
pub fn generate(cfg: &WorkloadConfig, n: usize) -> Vec<WorkloadItem> {
    let mut rng = XorShift64::new(cfg.seed);
    let contexts: Vec<String> =
        (0..cfg.n_contexts).map(|_| synth_text(&mut rng, cfg.context_chars)).collect();
    let questions: Vec<String> = (0..cfg.n_questions)
        .map(|i| format!(" q{i}: {}?", synth_text(&mut rng, 12)))
        .collect();
    (0..n)
        .map(|i| {
            let q = &questions[rng.next_range(questions.len())];
            if cfg.scan_every > 0 && (i + 1) % cfg.scan_every == 0 {
                // one-shot scan: unique context id, never repeated
                let text = synth_text(&mut rng, cfg.context_chars);
                return WorkloadItem {
                    prompt: format!("{text}{q}"),
                    context_id: cfg.n_contexts + i,
                };
            }
            let context_id = i % cfg.n_contexts;
            WorkloadItem { prompt: format!("{}{}", contexts[context_id], q), context_id }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let cfg = WorkloadConfig::default();
        let a = generate(&cfg, 10);
        let b = generate(&cfg, 10);
        assert_eq!(
            a.iter().map(|x| &x.prompt).collect::<Vec<_>>(),
            b.iter().map(|x| &x.prompt).collect::<Vec<_>>()
        );
    }

    #[test]
    fn shared_prefixes_within_context() {
        let cfg = WorkloadConfig { n_contexts: 2, ..Default::default() };
        let items = generate(&cfg, 8);
        let same: Vec<_> = items.iter().filter(|i| i.context_id == 0).collect();
        assert!(same.len() >= 2);
        let prefix_len = cfg.context_chars;
        let p0 = &same[0].prompt[..prefix_len];
        for it in &same {
            assert_eq!(&it.prompt[..prefix_len], p0);
        }
    }

    #[test]
    fn contexts_differ() {
        let cfg = WorkloadConfig::default();
        let items = generate(&cfg, cfg.n_contexts);
        let p0 = &items[0].prompt[..cfg.context_chars];
        let p1 = &items[1].prompt[..cfg.context_chars];
        assert_ne!(p0, p1);
    }

    #[test]
    fn lengths_respected() {
        let mut rng = XorShift64::new(1);
        let t = synth_text(&mut rng, 100);
        assert_eq!(t.len(), 100);
    }

    #[test]
    fn scan_requests_are_one_shot() {
        let cfg = WorkloadConfig { scan_every: 3, ..Default::default() };
        let items = generate(&cfg, 12);
        let scans: Vec<_> = items.iter().filter(|i| i.context_id >= cfg.n_contexts).collect();
        assert_eq!(scans.len(), 4, "every 3rd request is a scan");
        // scan contexts are unique (no shared prefixes between scans)
        let prefix = cfg.context_chars;
        for (a, i) in scans.iter().enumerate() {
            for j in &scans[a + 1..] {
                assert_ne!(&i.prompt[..prefix], &j.prompt[..prefix]);
            }
        }
        // hot requests still share their context prefixes
        let hot: Vec<_> = items.iter().filter(|i| i.context_id == 0).collect();
        assert!(hot.len() >= 2);
        for h in &hot {
            assert_eq!(&h.prompt[..prefix], &hot[0].prompt[..prefix]);
        }
        // scans are deterministic per seed too
        let again = generate(&cfg, 12);
        assert_eq!(
            items.iter().map(|x| &x.prompt).collect::<Vec<_>>(),
            again.iter().map(|x| &x.prompt).collect::<Vec<_>>()
        );
    }
}
