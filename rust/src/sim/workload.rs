//! Serving workload generator: prompts with shared prefixes, modelled on
//! the vLLM prefix-caching benchmark the paper's §5 validation uses (a
//! fixed document context + varying questions).

use crate::util::rng::XorShift64;

/// Workload configuration.
#[derive(Debug, Clone, Copy)]
pub struct WorkloadConfig {
    /// Number of distinct shared contexts ("documents").
    pub n_contexts: usize,
    /// Characters per context (>= a few blocks to make caching matter).
    pub context_chars: usize,
    /// Distinct question suffixes per context.
    pub n_questions: usize,
    /// Every `k`-th request uses a fresh one-shot context instead of a
    /// shared one (0 = never): cold "scan" traffic that pollutes the
    /// cache and keeps LRU eviction pressure realistic without thrashing
    /// the hot set.
    pub scan_every: usize,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        Self { n_contexts: 4, context_chars: 128, n_questions: 8, scan_every: 0, seed: 7 }
    }
}

const WORDS: &[&str] = &[
    "satellite", "orbit", "cache", "chunk", "block", "hash", "token", "laser", "link", "ground",
    "plane", "mesh", "torus", "hop", "memory", "model", "prompt", "prefix", "inference", "sky",
];

/// Deterministic prose of at least `chars` characters.
pub fn synth_text(rng: &mut XorShift64, chars: usize) -> String {
    let mut s = String::with_capacity(chars + 16);
    while s.len() < chars {
        if !s.is_empty() {
            s.push(' ');
        }
        s.push_str(WORDS[rng.next_range(WORDS.len())]);
    }
    s.truncate(chars);
    s
}

/// A generated request.
#[derive(Debug, Clone)]
pub struct WorkloadItem {
    pub prompt: String,
    pub context_id: usize,
}

/// Generate `n` requests: each picks a context (round-robin) and appends
/// one of its question suffixes, so requests sharing a context share a
/// multi-block prefix — the paper's repeated-context regime.  With
/// `scan_every > 0`, every `k`-th request instead carries a fresh
/// one-shot context (cold traffic that is never revisited).
pub fn generate(cfg: &WorkloadConfig, n: usize) -> Vec<WorkloadItem> {
    let mut rng = XorShift64::new(cfg.seed);
    let contexts: Vec<String> =
        (0..cfg.n_contexts).map(|_| synth_text(&mut rng, cfg.context_chars)).collect();
    let questions: Vec<String> = (0..cfg.n_questions)
        .map(|i| format!(" q{i}: {}?", synth_text(&mut rng, 12)))
        .collect();
    (0..n)
        .map(|i| {
            let q = &questions[rng.next_range(questions.len())];
            if cfg.scan_every > 0 && (i + 1) % cfg.scan_every == 0 {
                // one-shot scan: unique context id, never repeated
                let text = synth_text(&mut rng, cfg.context_chars);
                return WorkloadItem {
                    prompt: format!("{text}{q}"),
                    context_id: cfg.n_contexts + i,
                };
            }
            let context_id = i % cfg.n_contexts;
            WorkloadItem { prompt: format!("{}{}", contexts[context_id], q), context_id }
        })
        .collect()
}

/// Session-workload configuration: multi-tenant chat traffic over
/// Zipfian-popular prefix templates (shared system prompts / documents),
/// a fork-vs-fresh arrival mix, and a per-session lifetime.  Drives the
/// [`crate::kvc::session::SessionManager`] layer in the harness.
#[derive(Debug, Clone, Copy)]
pub struct SessionWorkloadConfig {
    /// Distinct prefix templates (system prompts).
    pub n_templates: usize,
    /// Zipf exponent of template popularity (0 = uniform).
    pub zipf_s: f64,
    /// Characters per template prefix (tokens are bytes; keep this a
    /// multiple of the scenario's `block_tokens` so chains align).
    pub template_chars: usize,
    /// Characters appended per conversation turn (same alignment rule).
    pub turn_chars: usize,
    /// Fraction of arrivals that fork the youngest live session of their
    /// template instead of starting fresh.
    pub fork_frac: f64,
    /// Fraction of arrivals that extend the youngest live session of
    /// their template by one turn.
    pub extend_frac: f64,
    /// Turns after which a session drops (its refs release).
    pub lifetime_turns: usize,
    /// Logical sessions pre-registered before the run — metadata-only
    /// forks of per-template roots, the 10⁵–10⁷ sweep knob
    /// (`skymemory sessions --sessions N`).
    pub presessions: usize,
    /// When true the harness forks for real (refcounted zero-copy prefix
    /// sharing, stores pinned); when false the identical trace replays
    /// every fork as an independent fresh session — the baseline.
    pub share: bool,
    pub seed: u64,
}

impl Default for SessionWorkloadConfig {
    fn default() -> Self {
        Self {
            n_templates: 4,
            zipf_s: 1.1,
            template_chars: 192,
            turn_chars: 32,
            fork_frac: 0.5,
            extend_frac: 0.25,
            lifetime_turns: 4,
            presessions: 0,
            share: true,
            seed: 7,
        }
    }
}

/// One session-layer operation.  `slot` numbers are dense logical ids
/// assigned by the generator; the harness maps them to live
/// [`crate::kvc::session::SessionId`]s.
#[derive(Debug, Clone, PartialEq)]
pub enum SessionOp {
    /// Start a fresh session: template prefix plus one turn.
    Create { slot: usize, template: usize, turn: String },
    /// Fork `from_slot` and append one divergent turn.
    Fork { slot: usize, from_slot: usize, turn: String },
    /// Append one turn to a live session.
    Extend { slot: usize, turn: String },
    /// End of life: the session's references release.
    Drop { slot: usize },
}

/// A generated session trace: the template texts plus the op stream.
#[derive(Debug, Clone)]
pub struct SessionTrace {
    pub templates: Vec<String>,
    pub ops: Vec<SessionOp>,
    /// Arrivals generated (ops minus the interleaved drops).
    pub arrivals: usize,
}

/// Sample an index from Zipfian cumulative weights.
fn zipf_pick(cum: &[f64], r: f64) -> usize {
    let total = *cum.last().unwrap();
    let x = r * total;
    cum.iter().position(|&c| x < c).unwrap_or(cum.len() - 1)
}

/// Generate `arrivals` session-layer arrivals.  Each arrival picks a
/// template by Zipf popularity, then forks / extends / creates per the
/// configured mix; a touched session reaching `lifetime_turns` drops
/// immediately (the drop rides the op stream).  Deterministic per seed;
/// turn texts embed the arrival index so turns never collide across
/// sessions.
pub fn generate_sessions(cfg: &SessionWorkloadConfig, arrivals: usize) -> SessionTrace {
    assert!(cfg.n_templates >= 1, "sessions need a template");
    assert!(cfg.lifetime_turns >= 1, "sessions must live at least one turn");
    assert!(
        cfg.fork_frac >= 0.0 && cfg.extend_frac >= 0.0 && cfg.fork_frac + cfg.extend_frac <= 1.0,
        "fork/extend fractions must partition the arrival mix"
    );
    let mut rng = XorShift64::new(cfg.seed ^ 0x5E55_10F0_0000_0001);
    let templates: Vec<String> =
        (0..cfg.n_templates).map(|_| synth_text(&mut rng, cfg.template_chars)).collect();
    let cum: Vec<f64> = (0..cfg.n_templates)
        .scan(0.0, |acc, i| {
            *acc += 1.0 / ((i + 1) as f64).powf(cfg.zipf_s);
            Some(*acc)
        })
        .collect();

    let mut ops = Vec::with_capacity(arrivals + arrivals / cfg.lifetime_turns + 1);
    // youngest-last live slots per template, and per-slot turn counts
    let mut live: Vec<Vec<usize>> = vec![Vec::new(); cfg.n_templates];
    let mut slot_turns: Vec<usize> = Vec::new();
    let mut slot_template: Vec<usize> = Vec::new();
    let mut turn_text = |rng: &mut XorShift64, i: usize| {
        let mut t = format!(" a{i} {}", synth_text(rng, cfg.turn_chars));
        t.truncate(cfg.turn_chars.max(1));
        t
    };
    for i in 0..arrivals {
        let t = zipf_pick(&cum, rng.next_f64());
        let r = rng.next_f64();
        let turn = turn_text(&mut rng, i);
        let touched = if r < cfg.fork_frac && !live[t].is_empty() {
            let from_slot = *live[t].last().unwrap();
            let slot = slot_turns.len();
            slot_turns.push(slot_turns[from_slot]);
            slot_template.push(t);
            live[t].push(slot);
            ops.push(SessionOp::Fork { slot, from_slot, turn });
            slot
        } else if r < cfg.fork_frac + cfg.extend_frac && !live[t].is_empty() {
            let slot = *live[t].last().unwrap();
            ops.push(SessionOp::Extend { slot, turn });
            slot
        } else {
            let slot = slot_turns.len();
            slot_turns.push(0);
            slot_template.push(t);
            live[t].push(slot);
            ops.push(SessionOp::Create { slot, template: t, turn });
            slot
        };
        slot_turns[touched] += 1;
        if slot_turns[touched] >= cfg.lifetime_turns {
            let tpl = slot_template[touched];
            live[tpl].retain(|&s| s != touched);
            ops.push(SessionOp::Drop { slot: touched });
        }
    }
    SessionTrace { templates, ops, arrivals }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let cfg = WorkloadConfig::default();
        let a = generate(&cfg, 10);
        let b = generate(&cfg, 10);
        assert_eq!(
            a.iter().map(|x| &x.prompt).collect::<Vec<_>>(),
            b.iter().map(|x| &x.prompt).collect::<Vec<_>>()
        );
    }

    #[test]
    fn shared_prefixes_within_context() {
        let cfg = WorkloadConfig { n_contexts: 2, ..Default::default() };
        let items = generate(&cfg, 8);
        let same: Vec<_> = items.iter().filter(|i| i.context_id == 0).collect();
        assert!(same.len() >= 2);
        let prefix_len = cfg.context_chars;
        let p0 = &same[0].prompt[..prefix_len];
        for it in &same {
            assert_eq!(&it.prompt[..prefix_len], p0);
        }
    }

    #[test]
    fn contexts_differ() {
        let cfg = WorkloadConfig::default();
        let items = generate(&cfg, cfg.n_contexts);
        let p0 = &items[0].prompt[..cfg.context_chars];
        let p1 = &items[1].prompt[..cfg.context_chars];
        assert_ne!(p0, p1);
    }

    #[test]
    fn lengths_respected() {
        let mut rng = XorShift64::new(1);
        let t = synth_text(&mut rng, 100);
        assert_eq!(t.len(), 100);
    }

    #[test]
    fn scan_requests_are_one_shot() {
        let cfg = WorkloadConfig { scan_every: 3, ..Default::default() };
        let items = generate(&cfg, 12);
        let scans: Vec<_> = items.iter().filter(|i| i.context_id >= cfg.n_contexts).collect();
        assert_eq!(scans.len(), 4, "every 3rd request is a scan");
        // scan contexts are unique (no shared prefixes between scans)
        let prefix = cfg.context_chars;
        for (a, i) in scans.iter().enumerate() {
            for j in &scans[a + 1..] {
                assert_ne!(&i.prompt[..prefix], &j.prompt[..prefix]);
            }
        }
        // hot requests still share their context prefixes
        let hot: Vec<_> = items.iter().filter(|i| i.context_id == 0).collect();
        assert!(hot.len() >= 2);
        for h in &hot {
            assert_eq!(&h.prompt[..prefix], &hot[0].prompt[..prefix]);
        }
        // scans are deterministic per seed too
        let again = generate(&cfg, 12);
        assert_eq!(
            items.iter().map(|x| &x.prompt).collect::<Vec<_>>(),
            again.iter().map(|x| &x.prompt).collect::<Vec<_>>()
        );
    }

    #[test]
    fn session_trace_is_deterministic() {
        let cfg = SessionWorkloadConfig::default();
        let a = generate_sessions(&cfg, 64);
        let b = generate_sessions(&cfg, 64);
        assert_eq!(a.templates, b.templates);
        assert_eq!(a.ops, b.ops);
        assert_eq!(a.arrivals, 64);
    }

    #[test]
    fn session_trace_mixes_forks_extends_and_drops() {
        let cfg = SessionWorkloadConfig::default();
        let trace = generate_sessions(&cfg, 96);
        let mut forks = 0;
        let mut extends = 0;
        let mut creates = 0;
        let mut drops = 0;
        for op in &trace.ops {
            match op {
                SessionOp::Create { .. } => creates += 1,
                SessionOp::Fork { .. } => forks += 1,
                SessionOp::Extend { .. } => extends += 1,
                SessionOp::Drop { .. } => drops += 1,
            }
        }
        assert!(creates >= 1 && forks >= 1 && extends >= 1 && drops >= 1);
        assert_eq!(creates + forks + extends, trace.arrivals);
    }

    #[test]
    fn session_ops_reference_earlier_live_slots() {
        let cfg = SessionWorkloadConfig { lifetime_turns: 3, ..Default::default() };
        let trace = generate_sessions(&cfg, 80);
        let mut live: Vec<bool> = Vec::new();
        for op in &trace.ops {
            match op {
                SessionOp::Create { slot, template, .. } => {
                    assert_eq!(*slot, live.len(), "slots are dense");
                    assert!(*template < cfg.n_templates);
                    live.push(true);
                }
                SessionOp::Fork { slot, from_slot, .. } => {
                    assert_eq!(*slot, live.len());
                    assert!(live[*from_slot], "forks only target live sessions");
                    live.push(true);
                }
                SessionOp::Extend { slot, .. } => assert!(live[*slot]),
                SessionOp::Drop { slot } => {
                    assert!(live[*slot], "double drop");
                    live[*slot] = false;
                }
            }
        }
    }

    #[test]
    fn session_turns_are_block_aligned_and_unique() {
        let cfg = SessionWorkloadConfig::default();
        let trace = generate_sessions(&cfg, 48);
        assert!(trace.templates.iter().all(|t| t.len() == cfg.template_chars));
        let mut turns: Vec<&String> = Vec::new();
        for op in &trace.ops {
            let turn = match op {
                SessionOp::Create { turn, .. }
                | SessionOp::Fork { turn, .. }
                | SessionOp::Extend { turn, .. } => turn,
                SessionOp::Drop { .. } => continue,
            };
            assert_eq!(turn.len(), cfg.turn_chars);
            assert!(!turns.contains(&turn), "turn text collides across arrivals");
            turns.push(turn);
        }
    }

    #[test]
    fn zipf_skews_template_popularity() {
        let cfg = SessionWorkloadConfig {
            fork_frac: 0.0,
            extend_frac: 0.0,
            zipf_s: 1.4,
            ..Default::default()
        };
        let trace = generate_sessions(&cfg, 200);
        let mut counts = vec![0usize; cfg.n_templates];
        for op in &trace.ops {
            if let SessionOp::Create { template, .. } = op {
                counts[*template] += 1;
            }
        }
        assert!(
            counts[0] > counts[cfg.n_templates - 1],
            "template 0 must dominate the tail: {counts:?}"
        );
    }
}
