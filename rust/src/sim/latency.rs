//! Worst-case KVC-retrieval latency (the Figure 16 model).
//!
//! All servers are contacted in parallel (§3.1), so the end-to-end time is
//! the *max* over servers of:
//!
//! ```text
//!   RTT(server)                    — direct slant-range uplink (eq. 4) if
//!                                    the satellite is inside the reliable
//!                                    LOS box; otherwise up to the closest
//!                                    satellite and greedy +GRID hops at
//!                                    the eq. (1) worst-case hop latency
//! + chunks_on(server) * t_proc     — chunks are striped `id mod n`, so a
//!                                    server serializes its own chunks
//! ```
//!
//! Migrating strategies are evaluated at their migrated layout; hop-aware
//! keeps its write-time layout, so after `drift_epochs` the ground centre
//! has moved east and every access pays the extra distance — exactly the
//! §3.6 trade the paper's Figure 16 penalizes.

use super::config::SimConfig;
use crate::constellation::topology::SatId;

/// Per-point result with the component split (for the figure and tests).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyBreakdown {
    /// Max total across servers — the headline number.
    pub total_s: f64,
    /// Network RTT of the worst server.
    pub network_s: f64,
    /// Serialized chunk processing of the worst server.
    pub processing_s: f64,
    /// ISL hops of the worst server's route.
    pub worst_hops: usize,
    pub worst_server: usize,
}

/// Compute the worst-case retrieval latency for one configuration.
pub fn worst_case_latency(cfg: &SimConfig) -> LatencyBreakdown {
    let torus = cfg.torus();
    let geo = cfg.geometry();
    let n = cfg.n_servers;
    let n_chunks = cfg.n_chunks();
    // Current ground centre.  The KVC was written `drift_epochs` ago when
    // the centre was `drift_epochs` slots east.
    let current_center = cfg.center();
    let write_center = torus.offset(current_center, 0, cfg.drift_epochs as i32);
    let layout =
        cfg.strategy
            .layout_at(&torus, write_center, n, cfg.drift_epochs);

    let t_hop = geo.worst_hop_latency_s();
    let mut worst = LatencyBreakdown {
        total_s: 0.0,
        network_s: 0.0,
        processing_s: 0.0,
        worst_hops: 0,
        worst_server: 0,
    };
    for (idx, sat) in layout.iter().enumerate() {
        // chunks on this server: ceil/floor of n_chunks / n
        let chunks_here = n_chunks / n + usize::from(idx < n_chunks % n);
        if chunks_here == 0 {
            continue;
        }
        let (rtt, hops) = access_rtt(cfg, &torus, &geo, current_center, *sat, t_hop);
        let processing = chunks_here as f64 * cfg.chunk_processing_s;
        let total = rtt + processing;
        if total > worst.total_s {
            worst = LatencyBreakdown {
                total_s: total,
                network_s: rtt,
                processing_s: processing,
                worst_hops: hops,
                worst_server: idx + 1,
            };
        }
    }
    worst
}

/// Ground round-trip to a satellite: direct slant if inside the reliable
/// LOS box, else up to the closest satellite plus greedy ISL hops.
fn access_rtt(
    cfg: &SimConfig,
    torus: &crate::constellation::topology::Torus,
    geo: &crate::constellation::geometry::Geometry,
    center: SatId,
    sat: SatId,
    t_hop: f64,
) -> (f64, usize) {
    let (dp, ds) = torus.signed_offset(center, sat);
    let within_los = dp.unsigned_abs() as usize <= cfg.reliable_los_half
        && ds.unsigned_abs() as usize <= cfg.reliable_los_half;
    if within_los {
        let one_way = geo.ground_latency_s(ds.unsigned_abs() as usize, dp.unsigned_abs() as usize);
        (2.0 * one_way, 0)
    } else {
        let hops = torus.hops(center, sat);
        let one_way = geo.ground_latency_s(0, 0) + hops as f64 * t_hop;
        (2.0 * one_way, hops)
    }
}

/// One Figure 16 sweep row.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub strategy: &'static str,
    pub altitude_km: f64,
    pub n_servers: usize,
    pub kvc_bytes: usize,
    pub chunk_processing_s: f64,
    pub latency: LatencyBreakdown,
}

/// The full Figure 16 sweep: strategies x altitudes x servers x processing
/// x KVC sizes.
pub fn figure16_sweep() -> Vec<SweepRow> {
    let mut rows = Vec::new();
    for strategy in crate::mapping::Strategy::ALL {
        for &altitude_km in &SimConfig::altitude_sweep() {
            for &n_servers in &SimConfig::server_sweep() {
                for &chunk_processing_s in &SimConfig::processing_sweep() {
                    for &kvc_bytes in &SimConfig::kvc_sweep() {
                        let cfg = SimConfig {
                            strategy,
                            altitude_km,
                            n_servers,
                            kvc_bytes,
                            chunk_processing_s,
                            ..Default::default()
                        };
                        rows.push(SweepRow {
                            strategy: strategy.name(),
                            altitude_km,
                            n_servers,
                            kvc_bytes,
                            chunk_processing_s,
                            latency: worst_case_latency(&cfg),
                        });
                    }
                }
            }
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::Strategy;

    fn cfg(strategy: Strategy) -> SimConfig {
        SimConfig { strategy, ..Default::default() }
    }

    #[test]
    fn headline_shape_rot_hop_wins() {
        // Fig 16: "the hop- and rotation-aware approach results in lower
        // latency than the hop-aware and the rotation-aware approaches
        // across different altitudes".
        for alt in SimConfig::altitude_sweep() {
            let mut c = cfg(Strategy::RotationHopAware);
            c.altitude_km = alt;
            let rh = worst_case_latency(&c).total_s;
            c.strategy = Strategy::RotationAware;
            let ra = worst_case_latency(&c).total_s;
            c.strategy = Strategy::HopAware;
            let ha = worst_case_latency(&c).total_s;
            assert!(rh <= ra + 1e-12, "alt {alt}: rot+hop {rh} vs rot {ra}");
            assert!(rh <= ha + 1e-12, "alt {alt}: rot+hop {rh} vs hop {ha}");
        }
    }

    #[test]
    fn headline_shape_8x_servers_90pct_reduction() {
        // Fig 16: "An 8x increase in servers results in about 90%
        // reduction in latency" (processing-dominated regime: the larger
        // chunk processing time of the Table 2 range).
        let mut c = cfg(Strategy::RotationHopAware);
        c.chunk_processing_s = 0.02;
        c.n_servers = 9;
        let small = worst_case_latency(&c).total_s;
        c.n_servers = 81;
        let large = worst_case_latency(&c).total_s;
        let reduction = 1.0 - large / small;
        assert!(
            (0.80..=0.95).contains(&reduction),
            "9 -> 81 servers reduced latency by {:.1}% (small {small:.3}s, large {large:.3}s)",
            100.0 * reduction
        );
    }

    #[test]
    fn more_servers_reduce_latency_for_all_strategies() {
        for st in Strategy::ALL {
            let mut prev = f64::INFINITY;
            for n in SimConfig::server_sweep() {
                let mut c = cfg(st);
                c.n_servers = n;
                let l = worst_case_latency(&c).total_s;
                assert!(l < prev, "{}: {n} servers: {l} !< {prev}", st.name());
                prev = l;
            }
        }
    }

    #[test]
    fn altitude_raises_latency() {
        for st in Strategy::ALL {
            let mut lo = cfg(st);
            lo.altitude_km = 160.0;
            let mut hi = cfg(st);
            hi.altitude_km = 2000.0;
            assert!(
                worst_case_latency(&hi).total_s > worst_case_latency(&lo).total_s,
                "{}",
                st.name()
            );
        }
    }

    #[test]
    fn hop_aware_degrades_with_drift() {
        let mut c = cfg(Strategy::HopAware);
        c.drift_epochs = 0;
        let fresh = worst_case_latency(&c).total_s;
        c.drift_epochs = 4;
        let stale = worst_case_latency(&c).total_s;
        assert!(stale > fresh, "drift must cost hop-aware: {fresh} vs {stale}");
        // migrating strategies are (near-)drift-invariant: the box stays
        // centred; only the chunk-count alignment cycles inside it.
        let mut m = cfg(Strategy::RotationHopAware);
        m.drift_epochs = 0;
        let a = worst_case_latency(&m).total_s;
        m.drift_epochs = 4;
        let b = worst_case_latency(&m).total_s;
        assert!((a - b).abs() / a < 0.05, "{a} vs {b}");
    }

    #[test]
    fn processing_dominates_at_paper_scale() {
        // 21 MB / 6 kB = 3670 chunks over 81 servers at 20 ms each ≈ 0.9 s
        // of serialized processing — far above the network terms.
        let mut c = cfg(Strategy::RotationHopAware);
        c.chunk_processing_s = 0.02;
        let b = worst_case_latency(&c);
        assert!(b.processing_s > 5.0 * b.network_s, "{b:?}");
    }

    #[test]
    fn breakdown_components_sum() {
        let c = cfg(Strategy::RotationAware);
        let b = worst_case_latency(&c);
        assert!((b.total_s - b.network_s - b.processing_s).abs() < 1e-12);
        assert!(b.worst_server >= 1 && b.worst_server <= c.n_servers);
    }

    #[test]
    fn sweep_covers_all_cells() {
        let rows = figure16_sweep();
        // 3 strategies x 7 altitudes x 4 server counts x 2 procs x 2 sizes
        assert_eq!(rows.len(), 3 * 7 * 4 * 2 * 2);
        assert!(rows.iter().all(|r| r.latency.total_s > 0.0));
    }
}
