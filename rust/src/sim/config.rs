//! Simulation configuration — paper Table 2, verbatim defaults:
//!
//! | Parameter             | Values     |
//! |-----------------------|------------|
//! | KVC_BYTES             | 2–21 MB    |
//! | SERVERS               | 9–81       |
//! | CHUNK_PROCESSING_TIME | 0.002–0.02 s |
//! | ALTITUDE              | 160–2000 km |
//! | MAX_SATELLITES        | 15         |
//! | MAX_ORBS              | 15         |
//! | CENTER_SATELLITE      | 8          |
//! | CENTER_ORB            | 8          |

use crate::constellation::geometry::Geometry;
use crate::constellation::topology::{SatId, Torus};
use crate::mapping::Strategy;

/// One simulation point.
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    pub strategy: Strategy,
    /// Constellation altitude `h` (Table 2: 160–2000 km).
    pub altitude_km: f64,
    /// Virtual servers (Table 2: 9–81, the 3x3…9x9 grids of Figs 13–15).
    pub n_servers: usize,
    /// Total KVC bytes to place (Table 2: 2–21 MB).
    pub kvc_bytes: usize,
    /// Fixed chunk payload size (§3.1 / §5: 6 kB).
    pub chunk_bytes: usize,
    /// Per-chunk processing time at a satellite (Table 2: 2–20 ms).
    pub chunk_processing_s: f64,
    /// Torus dimensions (Table 2: 15x15).
    pub max_satellites: usize,
    pub max_orbs: usize,
    /// Rotation epochs elapsed since the KVC was written.  Migrating
    /// strategies re-centre; hop-aware pays this as extra distance.
    pub drift_epochs: u64,
    /// Half-extent (cells) of the *reliably* direct-uplink LOS box; cells
    /// outside ride the ISL mesh from the closest satellite (§3.7).
    pub reliable_los_half: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            strategy: Strategy::RotationHopAware,
            altitude_km: 550.0,
            n_servers: 81,
            kvc_bytes: 21 << 20,
            chunk_bytes: 6000,
            chunk_processing_s: 0.002,
            max_satellites: 15,
            max_orbs: 15,
            drift_epochs: 2,
            reliable_los_half: 2, // ~a 5x5 direct window = the §2 "10-20 visible"
        }
    }
}

impl SimConfig {
    pub fn torus(&self) -> Torus {
        Torus::new(self.max_orbs, self.max_satellites)
    }

    /// Table 2: CENTER_SATELLITE 8, CENTER_ORB 8 (1-based) -> (7, 7).
    pub fn center(&self) -> SatId {
        SatId::new((self.max_orbs / 2) as u16, (self.max_satellites / 2) as u16)
    }

    pub fn geometry(&self) -> Geometry {
        Geometry::new(self.altitude_km, self.max_satellites, self.max_orbs)
    }

    pub fn n_chunks(&self) -> usize {
        self.kvc_bytes.div_ceil(self.chunk_bytes)
    }

    /// Paper sweep axes (Figure 16).
    pub fn altitude_sweep() -> Vec<f64> {
        vec![160.0, 400.0, 550.0, 800.0, 1200.0, 1600.0, 2000.0]
    }

    pub fn server_sweep() -> Vec<usize> {
        vec![9, 25, 49, 81]
    }

    pub fn processing_sweep() -> Vec<f64> {
        vec![0.002, 0.02]
    }

    pub fn kvc_sweep() -> Vec<usize> {
        vec![2 << 20, 21 << 20]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_table2() {
        let c = SimConfig::default();
        assert_eq!(c.max_satellites, 15);
        assert_eq!(c.max_orbs, 15);
        assert_eq!(c.center(), SatId::new(7, 7));
        assert_eq!(c.chunk_bytes, 6000);
        assert_eq!(c.torus().len(), 225);
    }

    #[test]
    fn chunk_count_for_paper_sizes() {
        let c = SimConfig { kvc_bytes: 2 << 20, ..Default::default() };
        assert_eq!(c.n_chunks(), (2 * 1024 * 1024 + 5999) / 6000);
        assert!(c.n_chunks() > c.n_servers, "paper regime: chunks >> servers");
    }

    #[test]
    fn sweeps_cover_table2_ranges() {
        let alts = SimConfig::altitude_sweep();
        assert_eq!(*alts.first().unwrap(), 160.0);
        assert_eq!(*alts.last().unwrap(), 2000.0);
        assert_eq!(SimConfig::server_sweep(), vec![9, 25, 49, 81]);
        assert_eq!(SimConfig::processing_sweep(), vec![0.002, 0.02]);
    }
}
