//! The deterministic scenario harness: composes the real layers —
//! [`crate::constellation`] geometry and torus, a full in-process
//! [`crate::satellite::fleet::Fleet`], the [`crate::mapping`] strategies
//! with §3.4 migration, and the [`crate::kvc::manager::KvcManager`]
//! running the complete §3.8 Get/Set protocol over a latency-accounting
//! [`crate::net::transport::InProcTransport`] wrapped in a
//! [`crate::net::faults::FaultyTransport`] — and sweeps a
//! [`ScenarioSpec`]'s rotation epochs, serving its workload, migrating
//! the exiting column every epoch, and injecting the planned failures.
//!
//! Determinism contract: `run_scenario` with the same spec (same seed)
//! produces a byte-identical metrics JSON.  Everything that could vary
//! between runs is pinned: the RNG is seeded, link latency is *accounted*
//! (never slept), per-satellite migration handoffs drain in sorted key
//! order, and the chunk fan-out runs on the [`crate::net::sched`]
//! virtual-time event engine — single-threaded, `(virtual_time, tag)`
//! ordered, with zero OS-scheduling influence.  Network time per request
//! is the serial accounting of the non-batched requests plus the
//! *pipelined* batch makespans of the scheduler.

use crate::constellation::los::LosGrid;
use crate::constellation::topology::{SatId, Torus};
use crate::federation::manager::{EvacSummary, FederatedKvcManager};
use crate::federation::placement::ShellLayoutConfig;
use crate::federation::transport::{FederatedTransport, ShellLink};
use crate::federation::{Shell, ShellId};
use crate::kvc::block::{block_hashes, BlockHash};
use crate::kvc::manager::{KvcManager, KvcStatsSnapshot};
use crate::kvc::session::{SessionId, SessionManager, REFCOUNT_BUCKETS};
use crate::mapping::box_width;
use crate::net::faults::FaultyTransport;
use crate::net::sched::{LinkUsage, SchedSnapshot};
use crate::net::transport::{GroundView, InProcTransport, LinkModel, Transport};
use crate::obs::mem::{FootprintEstimate, MemFootprint};
use crate::obs::{NoopSink, SpanKind, TraceEvent, TraceSink};
use crate::satellite::fleet::Fleet;
use crate::sim::config::SimConfig;
use crate::sim::latency::worst_case_latency;
use crate::sim::scenario::{
    CorrelatedFailure, FailurePlan, FederatedScenarioSpec, ScenarioSpec, ShellSpec,
};
use crate::sim::workload::{self, SessionOp, SessionTrace, SessionWorkloadConfig};
use crate::util::json::{n, obj, s, Json};
use crate::util::rng::XorShift64;
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// Reliable direct-uplink LOS half extents used by every scenario (the
/// §2 "10-20 visible" window, matching `SimConfig::reliable_los_half`).
const LOS_HALF: usize = 2;

/// Metrics of one scenario run.  `to_json` renders with sorted keys, so
/// equal reports render to byte-identical JSON.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioReport {
    pub name: String,
    pub seed: u64,
    pub planes: usize,
    pub sats_per_plane: usize,
    pub n_servers: usize,
    pub epochs: u64,
    /// Requests served.
    pub requests: u64,
    /// Full hash blocks across all requests.
    pub blocks_requested: u64,
    /// Blocks served from the constellation cache.
    pub blocks_hit: u64,
    pub block_hit_rate: f64,
    /// `put_block` calls that failed outright (faults on the write path).
    pub failed_writes: u64,
    /// Chunks handed over by §3.4 rotation migration.
    pub migrated_chunks: u64,
    /// Migration requests lost to injected faults.
    pub failed_migrations: u64,
    /// Injected failures.
    pub sat_losses: u64,
    pub isl_outages: u64,
    pub handovers: u64,
    /// Requests blackholed by the fault injector.
    pub blackholed_requests: u64,
    /// LRU eviction activity summed over every satellite store.
    pub evicted_chunks: u64,
    pub evicted_blocks: u64,
    /// Total ISL hops and hop-weighted payload bytes on the mesh.
    pub isl_hops: u64,
    pub isl_bytes: u64,
    /// Transport drop counters (TTL exhaustion, stale-epoch writes,
    /// unroutable destinations) — silent drops are regressions.
    pub dropped_ttl: u64,
    pub dropped_stale: u64,
    pub dropped_unroutable: u64,
    /// Per-epoch deltas of the headline counters (`timeline.epochs`).
    pub epoch_series: Vec<EpochSample>,
    /// Busiest links with utilization aggregates (`timeline.links`).
    pub link_rollup: Vec<LinkRollup>,
    /// Links beyond the [`LINK_ROLLUP_CAP`] busiest.
    pub links_elided: u64,
    /// Per-request accounted network time (emulated link model, ms).
    pub net_mean_ms: f64,
    pub net_p50_ms: f64,
    pub net_p99_ms: f64,
    pub net_worst_ms: f64,
    /// The §4 closed-form worst-case retrieval latency for this shape.
    pub analytic_worst_case_s: f64,
    /// KVC manager counters at the end of the run.
    pub kvc: KvcStatsSnapshot,
    /// Virtual-time scheduler counters: batches, in-flight peak, and the
    /// per-link queueing/utilization aggregates.
    pub sched: SchedSnapshot,
    /// Deterministic memory-footprint plane (`memory` in the JSON).
    pub memory: MemoryPlane,
    /// Session-layer state (`sessions` in the JSON; only for specs with
    /// a [`SessionWorkloadConfig`]).
    pub sessions: Option<SessionsReport>,
}

/// One epoch's slice of a run: deltas of the headline counters between
/// consecutive epoch boundaries.
#[derive(Debug, Clone, PartialEq)]
pub struct EpochSample {
    pub epoch: u64,
    pub requests: u64,
    pub blocks_requested: u64,
    pub blocks_hit: u64,
    pub hit_rate: f64,
    pub isl_bytes: u64,
}

/// Whole-run busy/queued utilization and queue high-water mark of one
/// scheduler link (federated keys are prefixed `s{shell}:`).
#[derive(Debug, Clone, PartialEq)]
pub struct LinkRollup {
    pub key: String,
    pub transfers: u64,
    pub busy_ns: u64,
    pub queued_ns: u64,
    pub queue_peak: u64,
}

/// Links reported in `timeline.links`; the rest are counted in
/// `timeline.links_elided` so mega-shell reports stay bounded.
const LINK_ROLLUP_CAP: usize = 16;

/// One epoch-boundary sample of the memory plane: the footprint estimate
/// of the whole cache stack at that instant plus the tokens it covers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemorySample {
    pub epoch: u64,
    pub payload_bytes: u64,
    pub index_bytes: u64,
    pub overhead_bytes: u64,
    /// Index + overhead bytes in the immutable frozen index layer
    /// ([`crate::kvc::frozen`]); informational split of the above.
    pub frozen_bytes: u64,
    /// Index + overhead bytes in the mutable delta layer.
    pub delta_bytes: u64,
    pub total_bytes: u64,
    pub cached_tokens: u64,
}

/// Per-shell residency row of the federated `memory.summary` (store
/// footprint rollup of the shell's fleet plus the block copies homed
/// there — primary, replica, or pre-placed).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShellResidency {
    pub name: String,
    pub payload_bytes: u64,
    pub index_bytes: u64,
    pub overhead_bytes: u64,
    pub total_bytes: u64,
    pub resident_copies: u64,
}

/// The memory plane of one run (the `memory` object of both report
/// flavours): per-epoch footprint series, end-of-run totals, the
/// bytes-per-cached-token efficiency figure, and high-water marks.
/// Deterministic: estimates are pure functions of cache contents.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MemoryPlane {
    pub epochs: Vec<MemorySample>,
    /// End-of-run footprint (the last epoch sample's split).
    pub payload_bytes: u64,
    pub index_bytes: u64,
    pub overhead_bytes: u64,
    /// End-of-run frozen/delta split of the index layers
    /// ([`crate::kvc::frozen`]); informational, already counted above.
    pub frozen_bytes: u64,
    pub delta_bytes: u64,
    /// Frozen index generations built across the run (one per
    /// compacting epoch boundary).
    pub compactions: u64,
    pub total_bytes: u64,
    /// Tokens the index covers at end of run (blocks x block_tokens).
    pub cached_tokens: u64,
    /// `total_bytes / cached_tokens` — the paper-facing cache-efficiency
    /// figure (0 when nothing is cached).
    pub bytes_per_cached_token: f64,
    /// High-water mark of `total_bytes` across epoch samples, and the
    /// first epoch that reached it.
    pub peak_total_bytes: u64,
    pub peak_epoch: u64,
    /// Per-shell residency (federated runs only; empty single-shell).
    pub shells: Vec<ShellResidency>,
}

impl MemoryPlane {
    /// Record one epoch-boundary sample and roll the summary forward.
    fn sample(&mut self, epoch: u64, est: FootprintEstimate, cached_tokens: u64) {
        let total = est.total();
        self.epochs.push(MemorySample {
            epoch,
            payload_bytes: est.payload_bytes,
            index_bytes: est.index_bytes,
            overhead_bytes: est.overhead_bytes,
            frozen_bytes: est.frozen_bytes,
            delta_bytes: est.delta_bytes,
            total_bytes: total,
            cached_tokens,
        });
        if total > self.peak_total_bytes {
            self.peak_total_bytes = total;
            self.peak_epoch = epoch;
        }
        self.payload_bytes = est.payload_bytes;
        self.index_bytes = est.index_bytes;
        self.overhead_bytes = est.overhead_bytes;
        self.frozen_bytes = est.frozen_bytes;
        self.delta_bytes = est.delta_bytes;
        self.total_bytes = total;
        self.cached_tokens = cached_tokens;
    }

    /// Close the plane: derive the efficiency figure and attach the
    /// per-shell residency rows (empty for single-shell runs).
    fn finish(&mut self, shells: Vec<ShellResidency>) {
        self.bytes_per_cached_token = if self.cached_tokens == 0 {
            0.0
        } else {
            self.total_bytes as f64 / self.cached_tokens as f64
        };
        self.shells = shells;
    }
}

/// Render the `memory` object (shared by both report flavours).
fn memory_json(m: &MemoryPlane) -> Json {
    let mut summary = vec![
        ("bytes_per_cached_token", n(m.bytes_per_cached_token)),
        ("cached_tokens", n(m.cached_tokens as f64)),
        ("compactions", n(m.compactions as f64)),
        ("delta_bytes", n(m.delta_bytes as f64)),
        ("frozen_bytes", n(m.frozen_bytes as f64)),
        ("index_bytes", n(m.index_bytes as f64)),
        ("overhead_bytes", n(m.overhead_bytes as f64)),
        ("payload_bytes", n(m.payload_bytes as f64)),
        ("peak_epoch", n(m.peak_epoch as f64)),
        ("peak_total_bytes", n(m.peak_total_bytes as f64)),
        ("total_bytes", n(m.total_bytes as f64)),
    ];
    if !m.shells.is_empty() {
        summary.push((
            "shells",
            Json::Arr(
                m.shells
                    .iter()
                    .map(|sh| {
                        obj(vec![
                            ("name", s(&sh.name)),
                            ("payload_bytes", n(sh.payload_bytes as f64)),
                            ("index_bytes", n(sh.index_bytes as f64)),
                            ("overhead_bytes", n(sh.overhead_bytes as f64)),
                            ("total_bytes", n(sh.total_bytes as f64)),
                            ("resident_copies", n(sh.resident_copies as f64)),
                        ])
                    })
                    .collect(),
            ),
        ));
    }
    obj(vec![
        (
            "epochs",
            Json::Arr(
                m.epochs
                    .iter()
                    .map(|e| {
                        obj(vec![
                            ("epoch", n(e.epoch as f64)),
                            ("payload_bytes", n(e.payload_bytes as f64)),
                            ("index_bytes", n(e.index_bytes as f64)),
                            ("frozen_bytes", n(e.frozen_bytes as f64)),
                            ("delta_bytes", n(e.delta_bytes as f64)),
                            ("overhead_bytes", n(e.overhead_bytes as f64)),
                            ("total_bytes", n(e.total_bytes as f64)),
                            ("cached_tokens", n(e.cached_tokens as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("summary", obj(summary)),
    ])
}

/// End-of-run state of the session layer (the `sessions` object of both
/// report flavours, present only when the spec carries a
/// [`SessionWorkloadConfig`]).  Deterministic: every field is a pure
/// function of the op trace and the refcount table.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionsReport {
    /// True for the fork-sharing run, false for the independent-sessions
    /// baseline replay of the identical trace.
    pub mode_shared: bool,
    pub created: u64,
    pub forked: u64,
    pub dropped: u64,
    pub live: u64,
    pub peak_live: u64,
    /// Logical sessions pre-registered before the run (metadata only).
    pub presessions: u64,
    /// Prefix blocks served by zero-copy sharing on the fork path —
    /// blocks the baseline must refetch from orbit instead.
    pub blocks_shared: u64,
    pub unique_blocks: u64,
    pub total_refs: u64,
    /// Blocks referenced by two or more live sessions at end of run.
    pub shared_blocks: u64,
    /// `total_refs / unique_blocks` — how many sessions each stored
    /// block serves on average (1.0 = no sharing).
    pub dedup_ratio: f64,
    /// Eviction attempts deflected off session-pinned blocks.
    pub deflected_evictions: u64,
    /// Bucket `i` counts blocks with `i + 1` refs (last bucket: more).
    pub refcount_histogram: [u64; REFCOUNT_BUCKETS],
    /// Session-table + refcount-table footprint estimate.
    pub metadata_bytes: u64,
}

/// Render the `sessions` object.
fn sessions_json(r: &SessionsReport) -> Json {
    obj(vec![
        ("mode", s(if r.mode_shared { "shared" } else { "independent" })),
        ("created", n(r.created as f64)),
        ("forked", n(r.forked as f64)),
        ("dropped", n(r.dropped as f64)),
        ("live", n(r.live as f64)),
        ("peak_live", n(r.peak_live as f64)),
        ("presessions", n(r.presessions as f64)),
        ("blocks_shared", n(r.blocks_shared as f64)),
        ("unique_blocks", n(r.unique_blocks as f64)),
        ("total_refs", n(r.total_refs as f64)),
        ("shared_blocks", n(r.shared_blocks as f64)),
        ("dedup_ratio", n(r.dedup_ratio)),
        ("deflected_evictions", n(r.deflected_evictions as f64)),
        (
            "refcount_histogram",
            Json::Arr(r.refcount_histogram.iter().map(|&c| n(c as f64)).collect()),
        ),
        ("metadata_bytes", n(r.metadata_bytes as f64)),
    ])
}

/// How one session arrival is served against the KVC — produced by the
/// [`SessionEngine`], executed by the harness serve loops so the
/// single-shell and federated semantics cannot diverge.
enum ServePlan {
    /// Cold path: look the whole chain up, fetch the cached prefix from
    /// orbit, store the rest (creates, and baseline fork replays).
    Full { hashes: Vec<BlockHash> },
    /// Fork path: the first `shared` blocks are inherited zero-copy from
    /// the parent's KV mapping (no lookup, no fetch, no ISL traffic);
    /// only the divergent turn blocks are stored.
    Forked { hashes: Vec<BlockHash>, shared: usize },
    /// Extend path: store the turn's new blocks (no prefix traffic in
    /// either mode — the session already maps its own history).
    Appended { hashes: Vec<BlockHash>, new_from: usize },
}

/// Drives a [`SessionTrace`] through a [`SessionManager`], mapping the
/// generator's logical slots to live sessions and translating each op
/// into a [`ServePlan`].  In baseline mode (`share == false`) the same
/// trace replays every fork as a fresh session carrying its parent's
/// full token history — identical token traffic, no sharing.
struct SessionEngine {
    mgr: SessionManager,
    trace: SessionTrace,
    share: bool,
    cursor: usize,
    slot_ids: Vec<Option<SessionId>>,
    slot_tokens: Vec<Vec<i32>>,
    presessions: u64,
    blocks_shared: u64,
}

impl SessionEngine {
    fn new(sw: &SessionWorkloadConfig, block_tokens: usize, arrivals: usize) -> Self {
        let trace = workload::generate_sessions(sw, arrivals);
        let mgr = SessionManager::new(block_tokens);
        let mut engine = Self {
            mgr,
            trace,
            share: sw.share,
            cursor: 0,
            slot_ids: Vec::new(),
            slot_tokens: Vec::new(),
            presessions: 0,
            blocks_shared: 0,
        };
        // Pre-register the logical session population (the 10^5..10^7
        // sweep knob): metadata-only — nothing is stored or fetched, so
        // token traffic stays identical across sweep points.  Shared
        // mode forks per-template roots (a ref increment per prefix
        // block); the baseline re-registers the full prefix every time.
        if sw.presessions > 0 {
            let template_tokens: Vec<Vec<i32>> =
                engine.trace.templates.iter().map(|t| Self::tokens(t)).collect();
            if sw.share {
                let roots: Vec<SessionId> = template_tokens
                    .iter()
                    .map(|toks| engine.mgr.create(toks).0)
                    .collect();
                engine.presessions += roots.len() as u64;
                for k in 0..sw.presessions {
                    engine.mgr.fork(roots[k % roots.len()]);
                    engine.presessions += 1;
                }
            } else {
                for k in 0..sw.presessions {
                    engine.mgr.create(&template_tokens[k % template_tokens.len()]);
                    engine.presessions += 1;
                }
            }
        }
        engine
    }

    fn tokens(text: &str) -> Vec<i32> {
        text.bytes().map(|b| b as i32).collect()
    }

    fn slot_mut(&mut self, slot: usize) -> (&mut Vec<Option<SessionId>>, &mut Vec<Vec<i32>>) {
        if slot >= self.slot_ids.len() {
            self.slot_ids.resize(slot + 1, None);
            self.slot_tokens.resize(slot + 1, Vec::new());
        }
        (&mut self.slot_ids, &mut self.slot_tokens)
    }

    /// Register a fresh session for `slot` and return its cold-path plan.
    fn create_slot(&mut self, slot: usize, tokens: Vec<i32>) -> ServePlan {
        let (id, _) = self.mgr.create(&tokens);
        let chain = self.mgr.chain(id);
        let (ids, toks) = self.slot_mut(slot);
        ids[slot] = Some(id);
        toks[slot] = tokens;
        ServePlan::Full { hashes: chain }
    }

    /// Advance the trace by one epoch's quota of arrivals (plus the drop
    /// ops riding between them) and return the serve plans, in order.
    fn next_epoch_plans(&mut self, arrivals: usize) -> Vec<ServePlan> {
        let mut plans = Vec::with_capacity(arrivals);
        let mut served = 0usize;
        while self.cursor < self.trace.ops.len() {
            if served == arrivals
                && !matches!(self.trace.ops[self.cursor], SessionOp::Drop { .. })
            {
                break;
            }
            let op = self.trace.ops[self.cursor].clone();
            self.cursor += 1;
            match op {
                SessionOp::Create { slot, template, turn } => {
                    served += 1;
                    let mut tokens = Self::tokens(&self.trace.templates[template]);
                    tokens.extend(Self::tokens(&turn));
                    plans.push(self.create_slot(slot, tokens));
                }
                SessionOp::Fork { slot, from_slot, turn } => {
                    served += 1;
                    let turn_tokens = Self::tokens(&turn);
                    if self.share {
                        let parent = self.slot_ids[from_slot].expect("fork of a live slot");
                        let child = self.mgr.fork(parent);
                        let new = self.mgr.extend(child, &turn_tokens);
                        let chain = self.mgr.chain(child);
                        let shared = chain.len() - new.len();
                        self.blocks_shared += shared as u64;
                        let mut tokens = self.slot_tokens[from_slot].clone();
                        tokens.extend(&turn_tokens);
                        let (ids, toks) = self.slot_mut(slot);
                        ids[slot] = Some(child);
                        toks[slot] = tokens;
                        plans.push(ServePlan::Forked { hashes: chain, shared });
                    } else {
                        // baseline: the fork is an independent session
                        // carrying the parent's full history — the whole
                        // prefix goes back through the cold path
                        let mut tokens = self.slot_tokens[from_slot].clone();
                        tokens.extend(&turn_tokens);
                        plans.push(self.create_slot(slot, tokens));
                    }
                }
                SessionOp::Extend { slot, turn } => {
                    served += 1;
                    let turn_tokens = Self::tokens(&turn);
                    let id = self.slot_ids[slot].expect("extend of a live slot");
                    let new = self.mgr.extend(id, &turn_tokens);
                    let chain = self.mgr.chain(id);
                    let new_from = chain.len() - new.len();
                    self.slot_tokens[slot].extend(&turn_tokens);
                    plans.push(ServePlan::Appended { hashes: chain, new_from });
                }
                SessionOp::Drop { slot } => {
                    let id = self.slot_ids[slot].take().expect("drop of a live slot");
                    self.mgr.drop_session(id);
                    self.slot_tokens[slot] = Vec::new();
                }
            }
        }
        plans
    }

    fn report(&self) -> SessionsReport {
        let snap = self.mgr.snapshot();
        SessionsReport {
            mode_shared: self.share,
            created: snap.created,
            forked: snap.forked,
            dropped: snap.dropped,
            live: snap.live,
            peak_live: snap.peak_live,
            presessions: self.presessions,
            blocks_shared: self.blocks_shared,
            unique_blocks: snap.unique_blocks,
            total_refs: snap.total_refs,
            shared_blocks: snap.shared_blocks,
            dedup_ratio: snap.dedup_ratio,
            deflected_evictions: snap.deflected_evictions,
            refcount_histogram: snap.refcount_histogram,
            metadata_bytes: snap.metadata_bytes,
        }
    }
}

/// Fold cumulative per-epoch marks `(requests, blocks_requested,
/// blocks_hit, isl_bytes)` into per-epoch deltas.
fn epoch_samples(marks: &[(u64, u64, u64, u64)]) -> Vec<EpochSample> {
    let mut prev = (0u64, 0u64, 0u64, 0u64);
    let mut out = Vec::with_capacity(marks.len());
    for (i, m) in marks.iter().enumerate() {
        let (requests, blocks_requested, blocks_hit, isl_bytes) =
            (m.0 - prev.0, m.1 - prev.1, m.2 - prev.2, m.3 - prev.3);
        out.push(EpochSample {
            epoch: i as u64,
            requests,
            blocks_requested,
            blocks_hit,
            hit_rate: if blocks_requested == 0 {
                0.0
            } else {
                blocks_hit as f64 / blocks_requested as f64
            },
            isl_bytes,
        });
        prev = *m;
    }
    out
}

/// Sort links by traffic (transfers, then busy time, ties by key) and
/// keep the [`LINK_ROLLUP_CAP`] busiest; returns the rows kept and the
/// count elided.
fn link_rollups(raw: Vec<(String, LinkUsage)>) -> (Vec<LinkRollup>, u64) {
    let mut rows: Vec<LinkRollup> = raw
        .into_iter()
        .map(|(key, u)| LinkRollup {
            key,
            transfers: u.transfers,
            busy_ns: u.busy_ns,
            queued_ns: u.queued_ns,
            queue_peak: u.queue_peak,
        })
        .collect();
    rows.sort_by(|a, b| {
        b.transfers.cmp(&a.transfers).then(b.busy_ns.cmp(&a.busy_ns)).then(a.key.cmp(&b.key))
    });
    let elided = rows.len().saturating_sub(LINK_ROLLUP_CAP) as u64;
    rows.truncate(LINK_ROLLUP_CAP);
    (rows, elided)
}

/// Render the `timeline` object (shared by both report flavours).
fn timeline_json(epochs: &[EpochSample], links: &[LinkRollup], elided: u64) -> Json {
    obj(vec![
        (
            "epochs",
            Json::Arr(
                epochs
                    .iter()
                    .map(|e| {
                        obj(vec![
                            ("epoch", n(e.epoch as f64)),
                            ("requests", n(e.requests as f64)),
                            ("blocks_requested", n(e.blocks_requested as f64)),
                            ("blocks_hit", n(e.blocks_hit as f64)),
                            ("hit_rate", n(e.hit_rate)),
                            ("isl_bytes", n(e.isl_bytes as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "links",
            Json::Arr(
                links
                    .iter()
                    .map(|l| {
                        obj(vec![
                            ("key", s(&l.key)),
                            ("transfers", n(l.transfers as f64)),
                            ("busy_ns", n(l.busy_ns as f64)),
                            ("queued_ns", n(l.queued_ns as f64)),
                            ("queue_peak", n(l.queue_peak as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("links_elided", n(elided as f64)),
    ])
}

/// Render a scheduler snapshot (shared by the single-shell and federated
/// reports; integer ns keep the JSON byte-stable).
fn sched_json(s: &SchedSnapshot) -> Json {
    obj(vec![
        ("batches", n(s.batches as f64)),
        ("transfers", n(s.transfers as f64)),
        ("failed_transfers", n(s.failed_transfers as f64)),
        ("virtual_time_ns", n(s.virtual_ns as f64)),
        ("link_busy_ns", n(s.busy_ns as f64)),
        ("link_queued_ns", n(s.queued_ns as f64)),
        ("peak_in_flight", n(s.peak_in_flight as f64)),
        ("links_used", n(s.links_used as f64)),
        ("busiest_link_transfers", n(s.busiest_link_transfers as f64)),
    ])
}

impl ScenarioReport {
    pub fn to_json(&self) -> Json {
        let k = &self.kvc;
        let mut fields = vec![
            ("name", s(&self.name)),
            ("seed", n(self.seed as f64)),
            ("planes", n(self.planes as f64)),
            ("sats_per_plane", n(self.sats_per_plane as f64)),
            ("n_servers", n(self.n_servers as f64)),
            ("epochs", n(self.epochs as f64)),
            ("requests", n(self.requests as f64)),
            ("blocks_requested", n(self.blocks_requested as f64)),
            ("blocks_hit", n(self.blocks_hit as f64)),
            ("block_hit_rate", n(self.block_hit_rate)),
            ("failed_writes", n(self.failed_writes as f64)),
            ("migrated_chunks", n(self.migrated_chunks as f64)),
            ("failed_migrations", n(self.failed_migrations as f64)),
            ("sat_losses", n(self.sat_losses as f64)),
            ("isl_outages", n(self.isl_outages as f64)),
            ("handovers", n(self.handovers as f64)),
            ("blackholed_requests", n(self.blackholed_requests as f64)),
            ("evicted_chunks", n(self.evicted_chunks as f64)),
            ("evicted_blocks", n(self.evicted_blocks as f64)),
            ("isl_hops", n(self.isl_hops as f64)),
            ("isl_bytes", n(self.isl_bytes as f64)),
            ("dropped_ttl", n(self.dropped_ttl as f64)),
            ("dropped_stale", n(self.dropped_stale as f64)),
            ("dropped_unroutable", n(self.dropped_unroutable as f64)),
            ("net_mean_ms", n(self.net_mean_ms)),
            ("net_p50_ms", n(self.net_p50_ms)),
            ("net_p99_ms", n(self.net_p99_ms)),
            ("net_worst_ms", n(self.net_worst_ms)),
            ("analytic_worst_case_s", n(self.analytic_worst_case_s)),
            (
                "kvc",
                obj(vec![
                    ("lookups", n(k.lookups as f64)),
                    ("prefix_hits", n(k.prefix_hits as f64)),
                    ("blocks_fetched", n(k.blocks_fetched as f64)),
                    ("blocks_stored", n(k.blocks_stored as f64)),
                    ("chunks_fetched", n(k.chunks_fetched as f64)),
                    ("chunks_stored", n(k.chunks_stored as f64)),
                    ("bytes_fetched", n(k.bytes_fetched as f64)),
                    ("bytes_stored", n(k.bytes_stored as f64)),
                    ("broken_blocks", n(k.broken_blocks as f64)),
                ]),
            ),
            ("sched", sched_json(&self.sched)),
            ("memory", memory_json(&self.memory)),
            (
                "timeline",
                timeline_json(&self.epoch_series, &self.link_rollup, self.links_elided),
            ),
        ];
        if let Some(sr) = &self.sessions {
            fields.push(("sessions", sessions_json(sr)));
        }
        obj(fields)
    }

    /// The canonical byte-stable rendering of this report.
    pub fn to_json_string(&self) -> String {
        self.to_json().to_string()
    }
}

/// Deterministic per-block KV payload, derived from the block hash so a
/// block's values never depend on when (or how often) it is re-stored.
fn block_values(hash: &BlockHash, count: usize) -> Vec<f32> {
    let mut seed = [0u8; 8];
    seed.copy_from_slice(&hash.as_bytes()[..8]);
    let mut rng = XorShift64::new(u64::from_le_bytes(seed));
    (0..count).map(|_| (rng.next_f64() as f32 - 0.5) * 4.0).collect()
}

fn sat_at(torus: &Torus, idx: usize) -> SatId {
    SatId::new((idx / torus.sats_per_plane) as u16, (idx % torus.sats_per_plane) as u16)
}

/// Sample a live satellite that is not the current ground entry point.
fn pick_live_satellite(
    rng: &mut XorShift64,
    torus: &Torus,
    faults: &FaultyTransport,
    exclude: SatId,
) -> Option<SatId> {
    for _ in 0..32 {
        let sat = sat_at(torus, rng.next_range(torus.len()));
        if sat != exclude && !faults.is_satellite_failed(sat) {
            return Some(sat);
        }
    }
    None
}

/// One epoch of a random [`FailurePlan`] against one shell's stack: heal
/// expired ISL outages, inject satellite losses and new outages, and
/// (per plan) re-home the ground station.  Shared by the single-shell
/// and federated harnesses so the injection semantics cannot diverge.
/// Returns the (satellite losses, ISL outages, ground handovers)
/// injected this epoch.
#[allow(clippy::too_many_arguments)]
fn inject_failures_epoch(
    rng: &mut XorShift64,
    torus: &Torus,
    fleet: &Fleet,
    faults: &FaultyTransport,
    ground: &GroundView,
    plan: &FailurePlan,
    active_outages: &mut Vec<(u64, SatId, SatId)>,
    epoch: u64,
) -> (u64, u64, u64) {
    let (mut losses, mut outages, mut handovers) = (0u64, 0u64, 0u64);
    active_outages.retain(|(heal_at, a, b)| {
        if *heal_at <= epoch {
            faults.restore_link(*a, *b);
            false
        } else {
            true
        }
    });
    for _ in 0..plan.sat_losses_per_epoch {
        if let Some(sat) = pick_live_satellite(rng, torus, faults, ground.center()) {
            fleet.node(sat).clear();
            faults.fail_satellite(sat);
            losses += 1;
        }
    }
    for _ in 0..plan.isl_outages_per_epoch {
        // draw an edge that is not already dark, so overlapping outages
        // never share a heal entry
        for _ in 0..8 {
            let a = sat_at(torus, rng.next_range(torus.len()));
            let b = torus.neighbors(a)[rng.next_range(4)];
            if active_outages.iter().any(|(_, x, y)| (*x == a && *y == b) || (*x == b && *y == a))
            {
                continue;
            }
            faults.fail_link(a, b);
            active_outages.push((epoch + plan.isl_outage_heal_epochs, a, b));
            outages += 1;
            break;
        }
    }
    if plan.handover_every_epochs > 0 && epoch % plan.handover_every_epochs == 0 {
        let cur = ground.center();
        for _ in 0..32 {
            let dp = rng.next_range(5) as i32 - 2;
            let ds = rng.next_range(7) as i32 - 3;
            let target = torus.offset(cur, dp, ds);
            if !faults.is_satellite_failed(target) {
                ground.handover(target);
                handovers += 1;
                break;
            }
        }
    }
    (losses, outages, handovers)
}

/// Apply every correlated failure scheduled for `epoch` against the
/// federation: the affected satellites' stores are wiped and their
/// traffic blackholed (permanent, like random satellite losses).
/// Coordinates resolve against the target shell's *current* ground-view
/// centre.  Returns `(plane_losses, solar_storms, box_kills,
/// satellites_killed)` for this epoch.
fn inject_correlated_epoch(
    transport: &FederatedTransport,
    layouts: &[ShellLayoutConfig],
    events: &[CorrelatedFailure],
    epoch: u64,
) -> (u64, u64, u64, u64) {
    fn kill(link: &ShellLink, sat: SatId) -> u64 {
        if link.faults.is_satellite_failed(sat) {
            return 0;
        }
        link.fleet.node(sat).clear();
        link.faults.fail_satellite(sat);
        1
    }
    let (mut planes, mut storms, mut boxes, mut killed) = (0u64, 0u64, 0u64, 0u64);
    for ev in events.iter().filter(|e| e.epoch() == epoch) {
        let shell = ev.shell() as ShellId;
        let link = transport.link(shell);
        let torus = link.shell.torus;
        let center = transport.closest(shell);
        match ev {
            CorrelatedFailure::PlaneLoss { plane_offset, .. } => {
                planes += 1;
                let plane = torus.offset(center, *plane_offset, 0).plane;
                for slot in 0..torus.sats_per_plane {
                    killed += kill(link, SatId::new(plane, slot as u16));
                }
            }
            CorrelatedFailure::SolarStorm { half_width, .. } => {
                storms += 1;
                let hw = *half_width as i32;
                for p in 0..torus.planes {
                    let band_center = SatId::new(p as u16, center.slot);
                    for ds in -hw..=hw {
                        killed += kill(link, torus.offset(band_center, 0, ds));
                    }
                }
            }
            CorrelatedFailure::BoxKill { fraction, .. } => {
                boxes += 1;
                let half =
                    (crate::mapping::box_width(layouts[ev.shell()].n_servers) as i32 - 1) / 2;
                let total = ((2 * half + 1) * (2 * half + 1)) as f64;
                let to_kill = (fraction * total).ceil() as usize;
                let mut cells = Vec::new();
                for dp in -half..=half {
                    for ds in -half..=half {
                        cells.push(torus.offset(center, dp, ds));
                    }
                }
                for sat in cells.into_iter().take(to_kill) {
                    killed += kill(link, sat);
                }
            }
        }
    }
    (planes, storms, boxes, killed)
}

fn percentile_ns(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() as f64 * p) as usize).min(sorted.len() - 1);
    sorted[idx]
}

/// The §4 closed-form worst case for one constellation shape (reported
/// next to the measured numbers so scale-out claims stay anchored to
/// Fig. 16).  Shared by the single-shell and per-federated-shell reports.
#[allow(clippy::too_many_arguments)]
fn analytic_shape_worst_case_s(
    strategy: crate::mapping::Strategy,
    altitude_km: f64,
    planes: usize,
    sats_per_plane: usize,
    n_servers: usize,
    kvc_bytes: usize,
    chunk_bytes: usize,
) -> f64 {
    let cfg = SimConfig {
        strategy,
        altitude_km,
        n_servers,
        kvc_bytes,
        chunk_bytes,
        chunk_processing_s: 0.002,
        max_satellites: sats_per_plane,
        max_orbs: planes,
        drift_epochs: 1,
        reliable_los_half: LOS_HALF,
    };
    worst_case_latency(&cfg).total_s
}

fn analytic_worst_case_s(spec: &ScenarioSpec) -> f64 {
    // session prompts are template + one turn; plain workload prompts
    // are the shared context (tokens are bytes either way)
    let prompt_chars = spec
        .sessions
        .map(|sw| sw.template_chars + sw.turn_chars)
        .unwrap_or(spec.workload.context_chars);
    let blocks_per_prompt = (prompt_chars / spec.block_tokens).max(1);
    analytic_shape_worst_case_s(
        spec.strategy,
        spec.altitude_km,
        spec.planes,
        spec.sats_per_plane,
        spec.n_servers,
        spec.quantizer.encoded_len(spec.kv_values_per_block) * blocks_per_prompt,
        spec.chunk_size,
    )
}

/// Run one scenario end to end and return its metrics report.
pub fn run_scenario(spec: &ScenarioSpec) -> ScenarioReport {
    run_scenario_with_sink(spec, Arc::new(NoopSink))
}

/// [`run_scenario`] with a flight recorder installed on every layer
/// (`skymemory trace`): the sink sees scheduler transfer spans, KVC
/// Get/Set spans, and harness epoch/fault instants, all stamped with
/// [`crate::net::sched`] virtual time.
pub fn run_scenario_with_sink(spec: &ScenarioSpec, sink: Arc<dyn TraceSink>) -> ScenarioReport {
    spec.validate();
    let torus = spec.torus();
    let geometry = spec.geometry();
    let center0 = spec.initial_center();

    let fleet = Arc::new(Fleet::new(torus, spec.sat_budget_bytes, spec.eviction));
    let los = LosGrid::new(center0, LOS_HALF, LOS_HALF.min(spec.planes / 2));
    let ground = GroundView::new(center0, &los, torus.sats_per_plane);
    let mut link = LinkModel::laser_defaults(geometry);
    link.bandwidth_bps = spec.link_bandwidth_bps;
    link.sleep_scale = 0.0; // account latency, never sleep: runs stay fast
    let inproc = Arc::new(InProcTransport::new(fleet.clone(), ground, Some(link)));
    let faults = Arc::new(FaultyTransport::new(
        inproc.clone(),
        torus,
        los.half_slots,
        los.half_planes,
    ));
    let manager = KvcManager::new(spec.kvc_config(), torus, faults.clone());
    manager.set_trace_sink(sink.clone());

    let mut rng = XorShift64::new(spec.seed ^ 0x5EED_5CEA_0A11_0F01);
    let mut session_engine = spec
        .sessions
        .as_ref()
        .map(|sw| SessionEngine::new(sw, spec.block_tokens, spec.total_requests()));
    if let Some(engine) = &session_engine {
        if engine.share {
            // pin session-referenced blocks fleet-wide (and on the local
            // tier): eviction deflects off live prefixes
            fleet.set_block_refs(&engine.mgr.refs());
            manager.set_block_refs(&engine.mgr.refs());
        }
    }
    let items = if session_engine.is_some() {
        Vec::new()
    } else {
        workload::generate(&spec.workload, spec.total_requests())
    };

    let mut blocks_requested = 0u64;
    let mut blocks_hit = 0u64;
    let mut failed_writes = 0u64;
    let mut migrated_chunks = 0u64;
    let mut failed_migrations = 0u64;
    let mut sat_losses = 0u64;
    let mut isl_outages = 0u64;
    let mut handovers = 0u64;
    let mut request_net_ns: Vec<u64> = Vec::with_capacity(items.len());
    // (heal_at_epoch, a, b) for active ISL outages
    let mut active_outages: Vec<(u64, SatId, SatId)> = Vec::new();
    // cumulative (requests, blocks_requested, blocks_hit, isl_bytes) at
    // each epoch boundary, folded into `timeline.epochs` deltas
    let mut epoch_marks: Vec<(u64, u64, u64, u64)> = Vec::with_capacity(spec.epochs as usize);
    let mut memory = MemoryPlane::default();

    for epoch in 0..spec.epochs {
        if sink.wants(SpanKind::Sim) {
            let ts = manager.sched().stats.virtual_ns.load(Ordering::Relaxed);
            sink.record(TraceEvent::instant(SpanKind::Sim, "epoch", ts).arg_u("epoch", epoch));
        }
        // --- failure injection (epoch 0 populates the cache cleanly) ----
        if epoch > 0 && !spec.failures.is_none() {
            let (l, o, h) = inject_failures_epoch(
                &mut rng,
                &torus,
                &fleet,
                &faults,
                &inproc.ground,
                &spec.failures,
                &mut active_outages,
                epoch,
            );
            sat_losses += l;
            isl_outages += o;
            handovers += h;
            if sink.wants(SpanKind::Fault) {
                let ts = manager.sched().stats.virtual_ns.load(Ordering::Relaxed);
                for (name, count) in [("sat_loss", l), ("isl_outage", o), ("handover", h)] {
                    if count > 0 {
                        sink.record(
                            TraceEvent::instant(SpanKind::Fault, name, ts)
                                .arg_u("count", count)
                                .arg_u("epoch", epoch),
                        );
                    }
                }
            }
        }

        // --- serve this epoch's slice of the workload -------------------
        // request network time = serial accounting of the non-batched
        // requests + pipelined makespans of the scheduler's batches
        let net_now = || {
            inproc.stats().sim_latency_ns.load(Ordering::Relaxed)
                + manager.sched().stats.virtual_ns.load(Ordering::Relaxed)
        };
        if let Some(engine) = &mut session_engine {
            for plan in engine.next_epoch_plans(spec.requests_per_epoch) {
                let before_ns = net_now();
                let (hashes, hit, store_from) = match plan {
                    ServePlan::Full { hashes } => {
                        blocks_requested += hashes.len() as u64;
                        let cached =
                            manager.lookup(&hashes, epoch).map(|(b, _)| b).unwrap_or(0);
                        let fetched = if cached > 0 {
                            manager
                                .fetch_prefix(&hashes, cached, epoch)
                                .map(|f| f.blocks)
                                .unwrap_or(0)
                        } else {
                            0
                        };
                        (hashes, fetched, fetched)
                    }
                    // the forked prefix is inherited zero-copy: counted
                    // as hits without any orbit traffic
                    ServePlan::Forked { hashes, shared } => {
                        blocks_requested += hashes.len() as u64;
                        (hashes, shared, shared)
                    }
                    ServePlan::Appended { hashes, new_from } => {
                        blocks_requested += (hashes.len() - new_from) as u64;
                        (hashes, 0, new_from)
                    }
                };
                blocks_hit += hit as u64;
                for b in store_from..hashes.len() {
                    let kv = block_values(&hashes[b], spec.kv_values_per_block);
                    if manager.put_block(&hashes, b, &kv, epoch).is_err() {
                        failed_writes += 1;
                    }
                }
                let after_ns = net_now();
                request_net_ns.push(after_ns.saturating_sub(before_ns));
            }
        } else {
            let lo = epoch as usize * spec.requests_per_epoch;
            let hi = lo + spec.requests_per_epoch;
            for item in &items[lo..hi] {
                let tokens: Vec<i32> = item.prompt.bytes().map(|b| b as i32).collect();
                let hashes = block_hashes(&tokens, spec.block_tokens);
                if hashes.is_empty() {
                    continue;
                }
                blocks_requested += hashes.len() as u64;
                let before_ns = net_now();
                let cached = manager.lookup(&hashes, epoch).map(|(b, _)| b).unwrap_or(0);
                let fetched = if cached > 0 {
                    manager
                        .fetch_prefix(&hashes, cached, epoch)
                        .map(|f| f.blocks)
                        .unwrap_or(0)
                } else {
                    0
                };
                blocks_hit += fetched as u64;
                // blocks not served from orbit get (re-)stored — the engine
                // would prefill them and §3.8-Set the fresh KV
                for b in fetched..hashes.len() {
                    let kv = block_values(&hashes[b], spec.kv_values_per_block);
                    if manager.put_block(&hashes, b, &kv, epoch).is_err() {
                        failed_writes += 1;
                    }
                }
                let after_ns = net_now();
                request_net_ns.push(after_ns.saturating_sub(before_ns));
            }
        }

        // --- rotate: §3.4 column migration, then the ground view moves --
        for (from, to) in manager.migration_requests(epoch) {
            // a migration controller would never hand chunks to a lost
            // satellite; count it as a failed handoff instead
            if faults.is_satellite_failed(to) {
                failed_migrations += 1;
                continue;
            }
            match manager.transport().migrate(from, to) {
                Ok(moved) => migrated_chunks += moved as u64,
                Err(_) => failed_migrations += 1,
            }
        }
        epoch_marks.push((
            request_net_ns.len() as u64,
            blocks_requested,
            blocks_hit,
            inproc.stats().isl_bytes.load(Ordering::Relaxed),
        ));
        // epoch boundary: freeze the index delta into a new generation
        // before sampling, so the memory plane sees the compacted layout
        manager.end_of_epoch(epoch);
        // memory plane: the whole stack's footprint at this boundary —
        // two-layer index + local tier (manager) plus every satellite
        // store, and the session/refcount tables when the session layer
        // drives
        let mut est = manager.mem_footprint();
        for node in fleet.nodes() {
            est.add(node.footprint());
        }
        if let Some(engine) = &session_engine {
            est.add(engine.mgr.mem_footprint());
        }
        memory.sample(epoch, est, manager.cached_tokens());
        manager.transport().set_epoch(epoch + 1);
    }

    let requests = request_net_ns.len() as u64;
    let total_ns: u64 = request_net_ns.iter().sum();
    let mut sorted_ns = request_net_ns;
    sorted_ns.sort_unstable();
    let to_ms = |ns: u64| ns as f64 / 1e6;
    let (mut evicted_chunks, mut evicted_blocks) = (0u64, 0u64);
    for node in fleet.nodes() {
        let st = node.stats();
        evicted_chunks += st.evicted_chunks;
        evicted_blocks += st.evicted_blocks;
    }
    let epoch_series = epoch_samples(&epoch_marks);
    let (link_rollup, links_elided) = link_rollups(
        manager.sched().link_rollup().into_iter().map(|(k, u)| (k.label(), u)).collect(),
    );
    memory.compactions = manager.index_compactions();
    memory.finish(Vec::new());

    ScenarioReport {
        name: spec.name.clone(),
        seed: spec.seed,
        planes: spec.planes,
        sats_per_plane: spec.sats_per_plane,
        n_servers: spec.n_servers,
        epochs: spec.epochs,
        requests,
        blocks_requested,
        blocks_hit,
        block_hit_rate: if blocks_requested == 0 {
            0.0
        } else {
            blocks_hit as f64 / blocks_requested as f64
        },
        failed_writes,
        migrated_chunks,
        failed_migrations,
        sat_losses,
        isl_outages,
        handovers,
        blackholed_requests: faults.fault_stats.blackholed(),
        evicted_chunks,
        evicted_blocks,
        isl_hops: inproc.stats().isl_hops.load(Ordering::Relaxed),
        isl_bytes: inproc.stats().isl_bytes.load(Ordering::Relaxed),
        dropped_ttl: inproc.stats().dropped_ttl.load(Ordering::Relaxed),
        dropped_stale: inproc.stats().dropped_stale.load(Ordering::Relaxed),
        dropped_unroutable: inproc.stats().dropped_unroutable.load(Ordering::Relaxed),
        epoch_series,
        link_rollup,
        links_elided,
        net_mean_ms: if requests == 0 { 0.0 } else { to_ms(total_ns / requests) },
        net_p50_ms: to_ms(percentile_ns(&sorted_ns, 0.50)),
        net_p99_ms: to_ms(percentile_ns(&sorted_ns, 0.99)),
        net_worst_ms: to_ms(sorted_ns.last().copied().unwrap_or(0)),
        analytic_worst_case_s: analytic_worst_case_s(spec),
        kvc: manager.stats.snapshot(),
        sched: manager.sched().stats.snapshot(),
        memory,
        sessions: session_engine.as_ref().map(|e| e.report()),
    }
}

// ======================================================================
// Federated scenarios
// ======================================================================

/// Per-shell slice of a federated report.
#[derive(Debug, Clone, PartialEq)]
pub struct FederatedShellReport {
    pub name: String,
    pub planes: usize,
    pub sats_per_plane: usize,
    pub altitude_km: f64,
    /// Blocks homed on this shell by placement (stores only; handover
    /// re-homing is reported federation-wide).
    pub blocks_stored: u64,
    /// Fetch arms raced against this shell (every copy fetch counts).
    pub fetch_attempts: u64,
    /// Fetches this shell served (fastest complete copy).
    pub blocks_hit: u64,
    pub hit_rate: f64,
    /// Fetches this shell served from a replica / pre-placed copy.
    pub replica_hits: u64,
    /// Replicas created onto this shell by the replication policy.
    pub replicas_hosted: u64,
    /// Next-rotation copies pre-placed onto this shell by the predictor.
    pub preplaced_hosted: u64,
    pub placed_bytes: u64,
    pub isl_hops: u64,
    pub isl_bytes: u64,
    pub evicted_chunks: u64,
    pub evicted_blocks: u64,
    pub failed_satellites: u64,
    pub analytic_worst_case_s: f64,
    /// The shell scheduler's counters (per-link queueing/utilization).
    pub sched: SchedSnapshot,
}

impl FederatedShellReport {
    fn to_json(&self) -> Json {
        obj(vec![
            ("name", s(&self.name)),
            ("planes", n(self.planes as f64)),
            ("sats_per_plane", n(self.sats_per_plane as f64)),
            ("altitude_km", n(self.altitude_km)),
            ("blocks_stored", n(self.blocks_stored as f64)),
            ("fetch_attempts", n(self.fetch_attempts as f64)),
            ("blocks_hit", n(self.blocks_hit as f64)),
            ("hit_rate", n(self.hit_rate)),
            ("replica_hits", n(self.replica_hits as f64)),
            ("replicas_hosted", n(self.replicas_hosted as f64)),
            ("preplaced_hosted", n(self.preplaced_hosted as f64)),
            ("placed_bytes", n(self.placed_bytes as f64)),
            ("isl_hops", n(self.isl_hops as f64)),
            ("isl_bytes", n(self.isl_bytes as f64)),
            ("evicted_chunks", n(self.evicted_chunks as f64)),
            ("evicted_blocks", n(self.evicted_blocks as f64)),
            ("failed_satellites", n(self.failed_satellites as f64)),
            ("analytic_worst_case_s", n(self.analytic_worst_case_s)),
            ("sched", sched_json(&self.sched)),
        ])
    }
}

/// Metrics of one federated scenario run; renders to byte-stable JSON
/// exactly like [`ScenarioReport`].
#[derive(Debug, Clone, PartialEq)]
pub struct FederatedScenarioReport {
    pub name: String,
    pub seed: u64,
    pub epochs: u64,
    pub n_servers: usize,
    /// Name of the static primary shell (cheapest by placement cost).
    pub primary_shell: String,
    pub primary_kill_epoch: u64,
    pub requests: u64,
    pub blocks_requested: u64,
    pub blocks_hit: u64,
    pub block_hit_rate: f64,
    pub failed_writes: u64,
    /// Blocks placed off the cheapest shell (saturation/failure spill).
    pub spillovers: u64,
    /// Proactive + reactive inter-shell re-homings (promotions
    /// included).
    pub handovers: u64,
    pub proactive_handover_blocks: u64,
    pub reactive_rehomed_blocks: u64,
    /// Replicas created (top-K hot blocks onto the second-cheapest
    /// shell).
    pub replicated_blocks: u64,
    /// Fetches that raced two or more copies.
    pub replica_races: u64,
    /// Races won (served) by a non-home copy.
    pub replica_race_wins: u64,
    /// Broken primaries healed by promoting a surviving copy.
    pub replica_promotions: u64,
    /// Next-rotation copies pre-placed by the §3.7 predictor.
    pub preplaced_blocks: u64,
    /// Fetches served by a pre-placed copy.
    pub preplace_hits: u64,
    /// Chunks / payload bytes carried over the inter-shell links.
    pub inter_shell_chunks: u64,
    pub inter_shell_bytes: u64,
    pub broken_blocks: u64,
    pub migrated_chunks: u64,
    pub failed_migrations: u64,
    pub sat_losses: u64,
    pub isl_outages: u64,
    /// Ground-station handovers on the primary shell
    /// ([`crate::sim::scenario::FailurePlan::handover_every_epochs`]).
    pub ground_handovers: u64,
    /// Satellites of the primary's layout-box kill band.
    pub box_killed_sats: u64,
    /// Correlated-failure events applied
    /// ([`crate::sim::scenario::CorrelatedFailure`]).
    pub plane_losses: u64,
    pub solar_storms: u64,
    pub box_kills: u64,
    /// Satellites killed by correlated failures.
    pub correlated_killed_sats: u64,
    pub blackholed_requests: u64,
    pub net_mean_ms: f64,
    pub net_p50_ms: f64,
    pub net_p99_ms: f64,
    pub net_worst_ms: f64,
    /// Transport drop counters summed across every shell.
    pub dropped_ttl: u64,
    pub dropped_stale: u64,
    pub dropped_unroutable: u64,
    /// Per-epoch deltas of the headline counters (federation-wide).
    pub epoch_series: Vec<EpochSample>,
    /// Busiest links federation-wide (keys prefixed `s{shell}:`).
    pub link_rollup: Vec<LinkRollup>,
    pub links_elided: u64,
    pub shells: Vec<FederatedShellReport>,
    /// Deterministic memory-footprint plane, federation-wide, with
    /// per-shell residency rows in the summary.
    pub memory: MemoryPlane,
    /// Session-layer state (`sessions` in the JSON; only for specs with
    /// a [`SessionWorkloadConfig`]).
    pub sessions: Option<SessionsReport>,
}

impl FederatedScenarioReport {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", s(&self.name)),
            ("seed", n(self.seed as f64)),
            ("epochs", n(self.epochs as f64)),
            ("n_servers", n(self.n_servers as f64)),
            ("primary_shell", s(&self.primary_shell)),
            ("primary_kill_epoch", n(self.primary_kill_epoch as f64)),
            ("requests", n(self.requests as f64)),
            ("blocks_requested", n(self.blocks_requested as f64)),
            ("blocks_hit", n(self.blocks_hit as f64)),
            ("block_hit_rate", n(self.block_hit_rate)),
            ("failed_writes", n(self.failed_writes as f64)),
            ("spillovers", n(self.spillovers as f64)),
            ("handovers", n(self.handovers as f64)),
            ("proactive_handover_blocks", n(self.proactive_handover_blocks as f64)),
            ("reactive_rehomed_blocks", n(self.reactive_rehomed_blocks as f64)),
            ("replicated_blocks", n(self.replicated_blocks as f64)),
            ("replica_races", n(self.replica_races as f64)),
            ("replica_race_wins", n(self.replica_race_wins as f64)),
            ("replica_promotions", n(self.replica_promotions as f64)),
            ("preplaced_blocks", n(self.preplaced_blocks as f64)),
            ("preplace_hits", n(self.preplace_hits as f64)),
            ("inter_shell_chunks", n(self.inter_shell_chunks as f64)),
            ("inter_shell_bytes", n(self.inter_shell_bytes as f64)),
            ("broken_blocks", n(self.broken_blocks as f64)),
            ("migrated_chunks", n(self.migrated_chunks as f64)),
            ("failed_migrations", n(self.failed_migrations as f64)),
            ("sat_losses", n(self.sat_losses as f64)),
            ("isl_outages", n(self.isl_outages as f64)),
            ("ground_handovers", n(self.ground_handovers as f64)),
            ("box_killed_sats", n(self.box_killed_sats as f64)),
            ("plane_losses", n(self.plane_losses as f64)),
            ("solar_storms", n(self.solar_storms as f64)),
            ("box_kills", n(self.box_kills as f64)),
            ("correlated_killed_sats", n(self.correlated_killed_sats as f64)),
            ("blackholed_requests", n(self.blackholed_requests as f64)),
            ("net_mean_ms", n(self.net_mean_ms)),
            ("net_p50_ms", n(self.net_p50_ms)),
            ("net_p99_ms", n(self.net_p99_ms)),
            ("net_worst_ms", n(self.net_worst_ms)),
            ("dropped_ttl", n(self.dropped_ttl as f64)),
            ("dropped_stale", n(self.dropped_stale as f64)),
            ("dropped_unroutable", n(self.dropped_unroutable as f64)),
            ("memory", memory_json(&self.memory)),
            (
                "timeline",
                timeline_json(&self.epoch_series, &self.link_rollup, self.links_elided),
            ),
            ("shells", Json::Arr(self.shells.iter().map(|sh| sh.to_json()).collect())),
        ];
        if let Some(sr) = &self.sessions {
            fields.push(("sessions", sessions_json(sr)));
        }
        obj(fields)
    }

    /// The canonical byte-stable rendering of this report.
    pub fn to_json_string(&self) -> String {
        self.to_json().to_string()
    }
}

/// The §4 closed-form worst case for one shell of a federated scenario,
/// using the shell's *own* strategy and stripe width.
fn fed_shell_analytic(spec: &FederatedScenarioSpec, ss: &ShellSpec) -> f64 {
    let blocks_per_prompt = (spec.workload.context_chars / spec.block_tokens).max(1);
    analytic_shape_worst_case_s(
        ss.strategy.unwrap_or(spec.strategy),
        ss.altitude_km,
        ss.planes,
        ss.sats_per_plane,
        ss.n_servers.unwrap_or(spec.n_servers),
        spec.quantizer.encoded_len(spec.kv_values_per_block) * blocks_per_prompt,
        spec.chunk_size,
    )
}

/// Build one shell's full single-shell stack for a federated run.
fn build_shell_link(id: ShellId, ss: &ShellSpec, spec: &FederatedScenarioSpec) -> ShellLink {
    let torus = ss.torus();
    let geometry = ss.geometry();
    let shell = Shell::new(id, &ss.name, torus, geometry);
    let center0 = ss.initial_center();
    let fleet = Arc::new(Fleet::new(torus, spec.sat_budget_bytes, spec.eviction));
    let los = LosGrid::new(center0, LOS_HALF, LOS_HALF.min(ss.planes / 2));
    let ground = GroundView::new(center0, &los, torus.sats_per_plane);
    let mut link = LinkModel::laser_defaults(geometry);
    link.sleep_scale = 0.0; // account latency, never sleep
    let inproc = Arc::new(InProcTransport::new(fleet.clone(), ground, Some(link)));
    let faults =
        Arc::new(FaultyTransport::new(inproc.clone(), torus, los.half_slots, los.half_planes));
    ShellLink::new(shell, fleet, inproc, faults, spec.sched_window)
}

/// Run one federated scenario end to end: multi-shell placement with
/// spillover, random failures on the primary shell, the mid-run
/// layout-box kill with proactive inter-shell evacuation, per-shell §3.4
/// rotation migration, and per-shell metrics.  Deterministic: the same
/// spec (same seed) produces byte-identical metrics JSON.
pub fn run_federated_scenario(spec: &FederatedScenarioSpec) -> FederatedScenarioReport {
    run_federated_scenario_with_sink(spec, Arc::new(NoopSink))
}

/// [`run_federated_scenario`] with a flight recorder installed on the
/// federation manager and every shell's scheduler (`skymemory trace`).
/// Federation control events carry no shell; shell-stamped events use
/// the shell's index as the Chrome-trace process.
pub fn run_federated_scenario_with_sink(
    spec: &FederatedScenarioSpec,
    sink: Arc<dyn TraceSink>,
) -> FederatedScenarioReport {
    spec.validate();
    let links: Vec<ShellLink> = spec
        .shells
        .iter()
        .enumerate()
        .map(|(i, ss)| build_shell_link(i as ShellId, ss, spec))
        .collect();
    let transport = Arc::new(FederatedTransport::new(links));
    let shell_layouts = spec.shell_layouts();
    let manager = FederatedKvcManager::new_with(
        spec.kvc_config(),
        transport.clone(),
        spec.placement(),
        spec.replication(),
        spec.preplace,
        shell_layouts.clone(),
    );
    manager.set_trace_sink(sink.clone());
    let primary = manager.primary_shell();
    debug_assert_eq!(primary as usize, spec.primary_shell_index());
    // federation-level stamp: the sum of every shell scheduler's clock
    let fed_ns = || {
        transport
            .links()
            .iter()
            .map(|l| l.sched.stats.virtual_ns.load(Ordering::Relaxed))
            .sum::<u64>()
    };

    let mut rng = XorShift64::new(spec.seed ^ 0x5EED_FEDE_0A11_0F02);
    let mut session_engine = spec
        .sessions
        .as_ref()
        .map(|sw| SessionEngine::new(sw, spec.block_tokens, spec.total_requests()));
    if let Some(engine) = &session_engine {
        if engine.share {
            // pin session-referenced blocks on every shell's fleet
            manager.set_block_refs(&engine.mgr.refs());
        }
    }
    let items = if session_engine.is_some() {
        Vec::new()
    } else {
        workload::generate(&spec.workload, spec.total_requests())
    };

    let mut blocks_requested = 0u64;
    let mut blocks_hit = 0u64;
    let mut failed_writes = 0u64;
    let mut migrated_chunks = 0u64;
    let mut failed_migrations = 0u64;
    let mut sat_losses = 0u64;
    let mut isl_outages = 0u64;
    let mut ground_handovers = 0u64;
    let mut box_killed_sats = 0u64;
    let mut plane_losses = 0u64;
    let mut solar_storms = 0u64;
    let mut box_kills = 0u64;
    let mut correlated_killed_sats = 0u64;
    let mut request_net_ns: Vec<u64> = Vec::with_capacity(items.len());
    // (heal_at_epoch, a, b) for active ISL outages on the primary shell
    let mut active_outages: Vec<(u64, SatId, SatId)> = Vec::new();
    // cumulative (requests, blocks_requested, blocks_hit, isl_bytes) at
    // each epoch boundary, folded into `timeline.epochs` deltas
    let mut epoch_marks: Vec<(u64, u64, u64, u64)> = Vec::with_capacity(spec.epochs as usize);
    let mut memory = MemoryPlane::default();
    let half = (box_width(shell_layouts[primary as usize].n_servers) as i32 - 1) / 2;

    for epoch in 0..spec.epochs {
        if sink.wants(SpanKind::Sim) {
            let ev = TraceEvent::instant(SpanKind::Sim, "epoch", fed_ns()).arg_u("epoch", epoch);
            sink.record(ev);
        }
        // --- random failures on the primary shell (epoch 0 stays clean) -
        if epoch > 0 && !spec.failures.is_none() {
            let link = transport.link(primary);
            let (l, o, h) = inject_failures_epoch(
                &mut rng,
                &link.shell.torus,
                &link.fleet,
                &link.faults,
                &link.inproc.ground,
                &spec.failures,
                &mut active_outages,
                epoch,
            );
            sat_losses += l;
            isl_outages += o;
            ground_handovers += h;
            if sink.wants(SpanKind::Fault) {
                let ts = fed_ns();
                for (name, count) in [("sat_loss", l), ("isl_outage", o), ("handover", h)] {
                    if count > 0 {
                        sink.record(
                            TraceEvent::instant(SpanKind::Fault, name, ts)
                                .with_shell(u16::from(primary))
                                .arg_u("count", count)
                                .arg_u("epoch", epoch),
                        );
                    }
                }
            }
        }

        // --- scheduled correlated failures: no pre-announced evacuation -
        if !spec.correlated.is_empty() {
            let (p, s, b, k) =
                inject_correlated_epoch(&transport, &shell_layouts, &spec.correlated, epoch);
            plane_losses += p;
            solar_storms += s;
            box_kills += b;
            correlated_killed_sats += k;
            if p + s + b > 0 && sink.wants(SpanKind::Fault) {
                sink.record(
                    TraceEvent::instant(SpanKind::Fault, "correlated_failure", fed_ns())
                        .arg_u("box_kills", b)
                        .arg_u("epoch", epoch)
                        .arg_u("killed", k)
                        .arg_u("plane_losses", p)
                        .arg_u("solar_storms", s),
                );
            }
        }

        // --- scheduled whole-box kill: evacuate first, then go dark -----
        if spec.primary_kill_epoch > 0 && epoch == spec.primary_kill_epoch {
            if let Some(target) = manager.cheapest_live_shell_excluding(primary) {
                // proactive handover: counted in the manager/transport
                // stats (proactive_handover_blocks, inter_shell_*)
                let _: EvacSummary = manager.evacuate_shell(primary, target, epoch);
            }
            let link = transport.link(primary);
            let torus = link.shell.torus;
            let center = transport.closest(primary);
            // the box slides one slot west per epoch: kill the whole band
            // it will sweep so the primary stays dark until the run ends
            let remaining = (spec.epochs - epoch) as i32;
            let killed_before = box_killed_sats;
            for dp in -half..=half {
                for ds in (-half - remaining)..=half {
                    let sat = torus.offset(center, dp, ds);
                    if !link.faults.is_satellite_failed(sat) {
                        link.fleet.node(sat).clear();
                        link.faults.fail_satellite(sat);
                        box_killed_sats += 1;
                    }
                }
            }
            if sink.wants(SpanKind::Fault) {
                sink.record(
                    TraceEvent::instant(SpanKind::Fault, "primary_kill", fed_ns())
                        .with_shell(u16::from(primary))
                        .arg_u("epoch", epoch)
                        .arg_u("killed", box_killed_sats - killed_before),
                );
            }
        }

        // --- serve this epoch's slice of the workload -------------------
        if let Some(engine) = &mut session_engine {
            for plan in engine.next_epoch_plans(spec.requests_per_epoch) {
                let before_ns = transport.total_latency_ns();
                let (hashes, hit, store_from) = match plan {
                    ServePlan::Full { hashes } => {
                        blocks_requested += hashes.len() as u64;
                        let cached = manager.lookup(&hashes);
                        let fetched = if cached > 0 {
                            manager.fetch_prefix(&hashes, cached, epoch).unwrap_or(0)
                        } else {
                            0
                        };
                        (hashes, fetched, fetched)
                    }
                    // the forked prefix is inherited zero-copy: counted
                    // as hits without any orbit traffic
                    ServePlan::Forked { hashes, shared } => {
                        blocks_requested += hashes.len() as u64;
                        (hashes, shared, shared)
                    }
                    ServePlan::Appended { hashes, new_from } => {
                        blocks_requested += (hashes.len() - new_from) as u64;
                        (hashes, 0, new_from)
                    }
                };
                blocks_hit += hit as u64;
                for b in store_from..hashes.len() {
                    let kv = block_values(&hashes[b], spec.kv_values_per_block);
                    if manager.put_block(&hashes, b, &kv, epoch).is_err() {
                        failed_writes += 1;
                    }
                }
                let after_ns = transport.total_latency_ns();
                request_net_ns.push(after_ns.saturating_sub(before_ns));
            }
        } else {
            let lo = epoch as usize * spec.requests_per_epoch;
            let hi = lo + spec.requests_per_epoch;
            for item in &items[lo..hi] {
                let tokens: Vec<i32> = item.prompt.bytes().map(|b| b as i32).collect();
                let hashes = block_hashes(&tokens, spec.block_tokens);
                if hashes.is_empty() {
                    continue;
                }
                blocks_requested += hashes.len() as u64;
                let before_ns = transport.total_latency_ns();
                let cached = manager.lookup(&hashes);
                let fetched = if cached > 0 {
                    manager.fetch_prefix(&hashes, cached, epoch).unwrap_or(0)
                } else {
                    0
                };
                blocks_hit += fetched as u64;
                for b in fetched..hashes.len() {
                    let kv = block_values(&hashes[b], spec.kv_values_per_block);
                    if manager.put_block(&hashes, b, &kv, epoch).is_err() {
                        failed_writes += 1;
                    }
                }
                let after_ns = transport.total_latency_ns();
                request_net_ns.push(after_ns.saturating_sub(before_ns));
            }
        }

        // --- epoch boundary: replicate the hot set across the cheapest
        // pair and run the §3.7 pre-placement predictor (no-ops for
        // re-homing-only specs), before the rotation handover ----------
        manager.end_of_epoch(epoch);

        // --- rotate every shell: §3.4 migration, then the views move ----
        for sid in 0..spec.shells.len() {
            let sid = sid as ShellId;
            let link = transport.link(sid);
            for (from, to) in manager.migration_requests(sid) {
                if link.faults.is_satellite_failed(to) {
                    failed_migrations += 1;
                    continue;
                }
                match link.faults.migrate(from, to) {
                    Ok(moved) => migrated_chunks += moved as u64,
                    Err(_) => failed_migrations += 1,
                }
            }
        }
        let isl = transport
            .links()
            .iter()
            .map(|l| l.inproc.stats().isl_bytes.load(Ordering::Relaxed))
            .sum::<u64>();
        epoch_marks.push((request_net_ns.len() as u64, blocks_requested, blocks_hit, isl));
        // memory plane: federation total (index maps + every shell's
        // fleet stores, plus the session/refcount tables when the
        // session layer drives) at this epoch boundary
        let mut est = manager.mem_footprint();
        if let Some(engine) = &session_engine {
            est.add(engine.mgr.mem_footprint());
        }
        memory.sample(epoch, est, manager.cached_tokens());
        transport.set_epoch_all(epoch + 1);
    }

    let requests = request_net_ns.len() as u64;
    let total_ns: u64 = request_net_ns.iter().sum();
    let mut sorted_ns = request_net_ns;
    sorted_ns.sort_unstable();
    let to_ms = |ns: u64| ns as f64 / 1e6;

    let shells = spec
        .shells
        .iter()
        .enumerate()
        .map(|(i, ss)| {
            let link = transport.link(i as ShellId);
            let counters = &manager.shell_counters()[i];
            let (mut evicted_chunks, mut evicted_blocks) = (0u64, 0u64);
            for node in link.fleet.nodes() {
                let st = node.stats();
                evicted_chunks += st.evicted_chunks;
                evicted_blocks += st.evicted_blocks;
            }
            let fetch_attempts = counters.fetch_attempts.load(Ordering::Relaxed);
            let hits = counters.blocks_hit.load(Ordering::Relaxed);
            FederatedShellReport {
                name: ss.name.clone(),
                planes: ss.planes,
                sats_per_plane: ss.sats_per_plane,
                altitude_km: ss.altitude_km,
                blocks_stored: counters.blocks_stored.load(Ordering::Relaxed),
                fetch_attempts,
                blocks_hit: hits,
                hit_rate: if fetch_attempts == 0 {
                    0.0
                } else {
                    hits as f64 / fetch_attempts as f64
                },
                replica_hits: counters.replica_hits.load(Ordering::Relaxed),
                replicas_hosted: counters.replicas_hosted.load(Ordering::Relaxed),
                preplaced_hosted: counters.preplaced_hosted.load(Ordering::Relaxed),
                placed_bytes: counters.placed_bytes.load(Ordering::Relaxed),
                isl_hops: link.inproc.stats().isl_hops.load(Ordering::Relaxed),
                isl_bytes: link.inproc.stats().isl_bytes.load(Ordering::Relaxed),
                evicted_chunks,
                evicted_blocks,
                failed_satellites: link.faults.failed_satellites() as u64,
                analytic_worst_case_s: fed_shell_analytic(spec, ss),
                sched: link.sched.stats.snapshot(),
            }
        })
        .collect();

    let epoch_series = epoch_samples(&epoch_marks);
    let mut raw_links: Vec<(String, LinkUsage)> = Vec::new();
    let (mut dropped_ttl, mut dropped_stale, mut dropped_unroutable) = (0u64, 0u64, 0u64);
    for (i, link) in transport.links().iter().enumerate() {
        for (key, u) in link.sched.link_rollup() {
            raw_links.push((format!("s{i}:{}", key.label()), u));
        }
        let st = link.inproc.stats();
        dropped_ttl += st.dropped_ttl.load(Ordering::Relaxed);
        dropped_stale += st.dropped_stale.load(Ordering::Relaxed);
        dropped_unroutable += st.dropped_unroutable.load(Ordering::Relaxed);
    }
    let (link_rollup, links_elided) = link_rollups(raw_links);

    let resident_copies = manager.shell_resident_copies();
    memory.compactions = manager.index_compactions();
    memory.finish(
        spec.shells
            .iter()
            .enumerate()
            .map(|(i, ss)| {
                let est = manager.shell_store_footprint(i as ShellId);
                ShellResidency {
                    name: ss.name.clone(),
                    payload_bytes: est.payload_bytes,
                    index_bytes: est.index_bytes,
                    overhead_bytes: est.overhead_bytes,
                    total_bytes: est.total(),
                    resident_copies: resident_copies[i],
                }
            })
            .collect(),
    );

    let proactive = manager.stats.proactive_handover_blocks.load(Ordering::Relaxed);
    let reactive = manager.stats.reactive_rehomed_blocks.load(Ordering::Relaxed);
    let promotions = manager.stats.replica_promotions.load(Ordering::Relaxed);
    FederatedScenarioReport {
        name: spec.name.clone(),
        seed: spec.seed,
        epochs: spec.epochs,
        n_servers: spec.n_servers,
        primary_shell: spec.shells[primary as usize].name.clone(),
        primary_kill_epoch: spec.primary_kill_epoch,
        requests,
        blocks_requested,
        blocks_hit,
        block_hit_rate: if blocks_requested == 0 {
            0.0
        } else {
            blocks_hit as f64 / blocks_requested as f64
        },
        failed_writes,
        spillovers: manager.stats.spillovers.load(Ordering::Relaxed),
        handovers: proactive + reactive + promotions,
        proactive_handover_blocks: proactive,
        reactive_rehomed_blocks: reactive,
        replicated_blocks: manager.stats.replicated_blocks.load(Ordering::Relaxed),
        replica_races: manager.stats.replica_races.load(Ordering::Relaxed),
        replica_race_wins: manager.stats.replica_race_wins.load(Ordering::Relaxed),
        replica_promotions: promotions,
        preplaced_blocks: manager.stats.preplaced_blocks.load(Ordering::Relaxed),
        preplace_hits: manager.stats.preplace_hits.load(Ordering::Relaxed),
        inter_shell_chunks: transport.stats.inter_shell_chunks.load(Ordering::Relaxed),
        inter_shell_bytes: transport.stats.inter_shell_bytes.load(Ordering::Relaxed),
        broken_blocks: manager.stats.broken_blocks.load(Ordering::Relaxed),
        migrated_chunks,
        failed_migrations,
        sat_losses,
        isl_outages,
        ground_handovers,
        box_killed_sats,
        plane_losses,
        solar_storms,
        box_kills,
        correlated_killed_sats,
        blackholed_requests: transport.total_blackholed(),
        net_mean_ms: if requests == 0 { 0.0 } else { to_ms(total_ns / requests) },
        net_p50_ms: to_ms(percentile_ns(&sorted_ns, 0.50)),
        net_p99_ms: to_ms(percentile_ns(&sorted_ns, 0.99)),
        net_worst_ms: to_ms(sorted_ns.last().copied().unwrap_or(0)),
        dropped_ttl,
        dropped_stale,
        dropped_unroutable,
        epoch_series,
        link_rollup,
        links_elided,
        shells,
        memory,
        sessions: session_engine.as_ref().map(|e| e.report()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::scenario::FailurePlan;

    fn tiny_spec(seed: u64) -> ScenarioSpec {
        // a scaled-down paper shape that runs in milliseconds
        let mut spec = ScenarioSpec::paper_19x5(seed);
        spec.epochs = 3;
        spec.requests_per_epoch = 8;
        spec
    }

    #[test]
    fn same_seed_same_report() {
        let spec = tiny_spec(11);
        let a = run_scenario(&spec);
        let b = run_scenario(&spec);
        assert_eq!(a, b);
        assert_eq!(a.to_json_string(), b.to_json_string());
    }

    #[test]
    fn different_seeds_differ() {
        let a = run_scenario(&tiny_spec(1));
        let b = run_scenario(&tiny_spec(2));
        // workload text and failure placement both change with the seed
        assert_ne!(a.to_json_string(), b.to_json_string());
    }

    #[test]
    fn repeated_contexts_hit_the_cache() {
        let mut spec = tiny_spec(5);
        spec.failures = FailurePlan::NONE;
        let r = run_scenario(&spec);
        assert!(r.requests > 0);
        assert!(r.blocks_hit > 0, "{r:?}");
        assert!(r.block_hit_rate > 0.3, "shared prefixes must hit: {r:?}");
        assert_eq!(r.sat_losses + r.isl_outages + r.handovers, 0);
    }

    #[test]
    fn failures_are_injected_and_survivable() {
        let r = run_scenario(&tiny_spec(9));
        assert!(r.sat_losses > 0);
        assert!(r.isl_outages > 0);
        assert!(r.block_hit_rate > 0.0, "cache must survive failures: {r:?}");
    }

    #[test]
    fn migration_happens_every_epoch() {
        let mut spec = tiny_spec(3);
        spec.failures = FailurePlan::NONE;
        let r = run_scenario(&spec);
        assert!(r.migrated_chunks > 0, "{r:?}");
        assert_eq!(r.failed_migrations, 0);
    }

    fn shell_spec(name: &str, planes: usize, sats_per_plane: usize, alt: f64) -> ShellSpec {
        ShellSpec {
            name: name.into(),
            planes,
            sats_per_plane,
            altitude_km: alt,
            strategy: None,
            n_servers: None,
        }
    }

    /// A scaled-down federation that runs in milliseconds: two small
    /// shells, 4 epochs, kill at epoch 2.
    fn tiny_fed(seed: u64) -> FederatedScenarioSpec {
        let mut spec = FederatedScenarioSpec::federated_dual_shell(seed);
        spec.shells[0] = shell_spec("a-550", 9, 19, 550.0);
        spec.shells[1] = shell_spec("b-630", 7, 17, 630.0);
        spec.epochs = 4;
        spec.requests_per_epoch = 8;
        spec.primary_kill_epoch = 2;
        spec
    }

    /// A scaled-down replicated tri-shell under the correlated plan: the
    /// dense b-630 shell is primary, a-550 is the replica span partner,
    /// and the polar shell runs its own (rotation-aware) layout config.
    fn tiny_tri(seed: u64) -> FederatedScenarioSpec {
        let mut spec = FederatedScenarioSpec::federated_tri_shell(seed);
        spec.shells[0] = shell_spec("a-550", 9, 11, 550.0);
        spec.shells[1] = shell_spec("b-630", 15, 15, 630.0);
        spec.shells[2] = shell_spec("c-1200", 9, 11, 1200.0);
        spec.shells[2].strategy = Some(crate::mapping::Strategy::RotationAware);
        spec.epochs = 4;
        spec.requests_per_epoch = 8;
        spec.correlated = vec![
            CorrelatedFailure::PlaneLoss { epoch: 1, shell: 0, plane_offset: 3 },
            CorrelatedFailure::SolarStorm { epoch: 2, shell: 1, half_width: 2 },
            CorrelatedFailure::BoxKill { epoch: 3, shell: 0, fraction: 0.33 },
        ];
        spec
    }

    #[test]
    fn federated_same_seed_same_report() {
        let spec = tiny_fed(11);
        let a = run_federated_scenario(&spec);
        let b = run_federated_scenario(&spec);
        assert_eq!(a, b);
        assert_eq!(a.to_json_string(), b.to_json_string());
    }

    #[test]
    fn federated_kill_hands_over_to_the_secondary() {
        let spec = tiny_fed(5);
        let r = run_federated_scenario(&spec);
        assert!(r.requests > 0);
        assert!(r.box_killed_sats > 0, "the primary box must go dark: {r:?}");
        assert!(r.handovers > 0, "hot blocks must re-home: {r:?}");
        assert!(r.proactive_handover_blocks > 0, "{r:?}");
        assert!(r.inter_shell_bytes > 0, "evacuation rides the inter-shell links: {r:?}");
        assert!(r.block_hit_rate > 0.0, "{r:?}");
        // both shells served fetches by the end of the run
        assert_eq!(r.shells.len(), 2);
        let primary = r.shells.iter().find(|sh| sh.name == r.primary_shell).unwrap();
        let secondary = r.shells.iter().find(|sh| sh.name != r.primary_shell).unwrap();
        assert!(primary.blocks_stored > 0);
        assert!(secondary.blocks_hit > 0, "post-kill hits come from the secondary: {r:?}");
    }

    #[test]
    fn federated_beats_the_single_shell_baseline() {
        let spec = tiny_fed(9);
        let fed = run_federated_scenario(&spec);
        let base = run_federated_scenario(&spec.baseline_single_shell());
        assert_eq!(fed.requests, base.requests, "same workload either way");
        assert!(
            fed.block_hit_rate > base.block_hit_rate,
            "federation must out-hit the dead single shell: {} vs {}",
            fed.block_hit_rate,
            base.block_hit_rate
        );
        assert_eq!(base.handovers, 0, "nowhere to hand over to");
        assert_eq!(base.inter_shell_bytes, 0);
    }

    #[test]
    fn tri_shell_correlated_plan_is_deterministic_and_counted() {
        let spec = tiny_tri(11);
        let a = run_federated_scenario(&spec);
        let b = run_federated_scenario(&spec);
        assert_eq!(a, b);
        assert_eq!(a.to_json_string(), b.to_json_string());
        assert_eq!(a.shells.len(), 3);
        assert_eq!(a.plane_losses, 1, "{a:?}");
        assert_eq!(a.solar_storms, 1, "{a:?}");
        assert_eq!(a.box_kills, 1, "{a:?}");
        assert!(a.correlated_killed_sats > 0);
        assert!(a.replicated_blocks > 0, "the hot set must replicate: {a:?}");
        assert!(a.replica_races > 0, "replicated fetches race their copies: {a:?}");
        assert!(a.replica_race_wins > 0, "the storm forces replica serves: {a:?}");
        assert!(a.replica_promotions > 0, "broken primaries promote: {a:?}");
        assert!(a.block_hit_rate > 0.0);
    }

    #[test]
    fn replicated_tri_shell_beats_the_rehoming_baseline() {
        let spec = tiny_tri(9);
        let fed = run_federated_scenario(&spec);
        let base = run_federated_scenario(&spec.rehoming_baseline());
        assert_eq!(fed.requests, base.requests, "same workload either way");
        assert_eq!(
            fed.correlated_killed_sats, base.correlated_killed_sats,
            "the correlated plan hits both runs identically"
        );
        assert!(
            fed.block_hit_rate > base.block_hit_rate,
            "replication must out-hit re-homing under correlated failures: {} vs {}",
            fed.block_hit_rate,
            base.block_hit_rate
        );
        assert_eq!(base.replicated_blocks, 0);
        assert_eq!(base.replica_race_wins, 0);
        assert_eq!(base.preplaced_blocks, 0);
    }

    #[test]
    fn federated_report_json_has_per_shell_metrics() {
        let r = run_federated_scenario(&tiny_fed(2));
        let j = r.to_json_string();
        for key in [
            "\"primary_shell\"",
            "\"handovers\"",
            "\"inter_shell_bytes\"",
            "\"spillovers\"",
            "\"shells\"",
            "\"hit_rate\"",
            "\"placed_bytes\"",
            "\"analytic_worst_case_s\"",
            "\"replicated_blocks\"",
            "\"replica_races\"",
            "\"replica_race_wins\"",
            "\"replica_promotions\"",
            "\"preplaced_blocks\"",
            "\"preplace_hits\"",
            "\"plane_losses\"",
            "\"solar_storms\"",
            "\"box_kills\"",
            "\"correlated_killed_sats\"",
            "\"replica_hits\"",
            "\"replicas_hosted\"",
            "\"preplaced_hosted\"",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
    }

    #[test]
    fn report_json_has_the_headline_keys() {
        let r = run_scenario(&tiny_spec(2));
        let j = r.to_json_string();
        for key in [
            "\"name\"",
            "\"block_hit_rate\"",
            "\"migrated_chunks\"",
            "\"isl_bytes\"",
            "\"net_p99_ms\"",
            "\"analytic_worst_case_s\"",
            "\"kvc\"",
            "\"sched\"",
            "\"peak_in_flight\"",
            "\"link_queued_ns\"",
            "\"links_used\"",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
    }

    #[test]
    fn same_seed_traces_are_byte_identical_jsonl() {
        use crate::obs::{jsonl, Recorder};
        let spec = tiny_spec(7);
        let a = Arc::new(Recorder::new());
        run_scenario_with_sink(&spec, a.clone());
        let b = Arc::new(Recorder::new());
        run_scenario_with_sink(&spec, b.clone());
        let ja = jsonl(&a.take());
        let jb = jsonl(&b.take());
        assert!(!ja.is_empty(), "a traced run must record events");
        assert_eq!(ja, jb, "same seed must produce a byte-identical trace");
    }

    #[test]
    fn federated_trace_carries_all_span_kinds() {
        use crate::obs::Recorder;
        let sink = Arc::new(Recorder::new());
        run_federated_scenario_with_sink(&tiny_tri(11), sink.clone());
        let events = sink.take();
        for kind in crate::obs::SpanKind::ALL {
            assert!(
                events.iter().any(|e| e.kind == kind),
                "no {} events in the tri-shell trace",
                kind.as_str()
            );
        }
        assert!(events.iter().any(|e| e.name == "race_arm"));
        assert!(events.iter().any(|e| e.name == "correlated_failure"));
        assert!(events.iter().any(|e| e.name == "epoch"));
    }

    #[test]
    fn timeline_rollups_are_consistent_with_totals() {
        let mut spec = tiny_spec(6);
        spec.failures = FailurePlan::NONE;
        let r = run_scenario(&spec);
        assert_eq!(r.epoch_series.len(), spec.epochs as usize);
        assert_eq!(r.epoch_series.iter().map(|e| e.requests).sum::<u64>(), r.requests);
        assert_eq!(r.epoch_series.iter().map(|e| e.blocks_hit).sum::<u64>(), r.blocks_hit);
        assert_eq!(r.epoch_series.iter().map(|e| e.isl_bytes).sum::<u64>(), r.isl_bytes);
        assert!(!r.link_rollup.is_empty());
        assert!(r.link_rollup.len() <= 16);
        assert!(
            r.link_rollup.windows(2).all(|w| w[0].transfers >= w[1].transfers),
            "rollup must be sorted busiest-first"
        );
        let j = r.to_json_string();
        for key in [
            "\"timeline\"",
            "\"epochs\"",
            "\"links\"",
            "\"links_elided\"",
            "\"queue_peak\"",
            "\"dropped_ttl\"",
            "\"dropped_stale\"",
            "\"dropped_unroutable\"",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
    }

    #[test]
    fn federated_timeline_spans_shell_links() {
        let spec = tiny_fed(3);
        let r = run_federated_scenario(&spec);
        assert_eq!(r.epoch_series.len(), spec.epochs as usize);
        assert!(!r.link_rollup.is_empty());
        // both shells carried traffic, under shell-prefixed keys
        assert!(r.link_rollup.iter().any(|l| l.key.starts_with("s0:")));
        assert!(r.link_rollup.iter().any(|l| l.key.starts_with("s1:")));
        assert!(r.to_json_string().contains("\"timeline\""));
    }

    #[test]
    fn memory_plane_tracks_the_cache() {
        let mut spec = tiny_spec(8);
        spec.failures = FailurePlan::NONE;
        let r = run_scenario(&spec);
        let m = &r.memory;
        assert_eq!(m.epochs.len(), spec.epochs as usize);
        assert!(m.cached_tokens > 0, "the cache must hold blocks: {m:?}");
        assert!(m.payload_bytes > 0);
        assert_eq!(m.total_bytes, m.payload_bytes + m.index_bytes + m.overhead_bytes);
        assert!(m.bytes_per_cached_token > 0.0);
        assert_eq!(
            m.peak_total_bytes,
            m.epochs.iter().map(|e| e.total_bytes).max().unwrap(),
            "peak must be the high-water mark of the series"
        );
        assert!(m.shells.is_empty(), "single-shell runs carry no residency rows");
        let j = r.to_json_string();
        for key in [
            "\"memory\"",
            "\"bytes_per_cached_token\"",
            "\"cached_tokens\"",
            "\"peak_total_bytes\"",
            "\"peak_epoch\"",
            "\"summary\"",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
    }

    #[test]
    fn federated_memory_plane_has_per_shell_residency() {
        let spec = tiny_fed(7);
        let r = run_federated_scenario(&spec);
        let m = &r.memory;
        assert_eq!(m.epochs.len(), spec.epochs as usize);
        assert_eq!(m.shells.len(), 2, "one residency row per shell");
        assert!(m.cached_tokens > 0);
        assert!(m.bytes_per_cached_token > 0.0);
        assert!(m.shells.iter().any(|sh| sh.total_bytes > 0));
        assert!(
            m.shells.iter().map(|sh| sh.resident_copies).sum::<u64>() > 0,
            "blocks must be resident somewhere: {m:?}"
        );
        let j = r.to_json_string();
        assert!(j.contains("\"resident_copies\""), "missing residency in {j}");
        assert!(j.contains("\"bytes_per_cached_token\""));
    }

    #[test]
    fn scheduler_counters_reflect_the_fan_out() {
        let mut spec = tiny_spec(4);
        spec.failures = FailurePlan::NONE;
        let r = run_scenario(&spec);
        assert!(r.sched.batches > 0, "{r:?}");
        // every fetched/stored chunk rode the scheduler (broken-block
        // fetch attempts make the transfer count strictly larger)
        assert!(r.sched.transfers >= r.kvc.chunks_fetched + r.kvc.chunks_stored, "{r:?}");
        assert_eq!(r.sched.failed_transfers, 0, "no faults injected: {r:?}");
        assert!(r.sched.virtual_ns > 0, "link model must cost virtual time");
        assert!(r.sched.peak_in_flight > 1, "chunks must overlap in flight");
        assert!(r.sched.links_used > 1);
    }

    /// The fork-heavy session scenario scaled down to milliseconds.
    fn fork_heavy_tiny(seed: u64) -> ScenarioSpec {
        let mut spec = ScenarioSpec::fork_heavy_chat(seed);
        spec.epochs = 4;
        spec.requests_per_epoch = 16;
        spec
    }

    #[test]
    fn fork_heavy_sessions_are_deterministic() {
        let spec = fork_heavy_tiny(11);
        let a = run_scenario(&spec);
        let b = run_scenario(&spec);
        assert_eq!(a, b);
        assert_eq!(a.to_json_string(), b.to_json_string());
        assert!(a.sessions.is_some(), "session-driven runs must report sessions");
        let j = a.to_json_string();
        for key in [
            "\"sessions\"",
            "\"mode\"",
            "\"dedup_ratio\"",
            "\"blocks_shared\"",
            "\"refcount_histogram\"",
            "\"deflected_evictions\"",
            "\"metadata_bytes\"",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
    }

    #[test]
    fn session_counters_are_consistent() {
        let spec = fork_heavy_tiny(7);
        let r = run_scenario(&spec);
        // every arrival (create / fork / extend) is served as one request
        assert_eq!(r.requests, spec.total_requests() as u64);
        let s = r.sessions.as_ref().unwrap();
        assert!(s.mode_shared);
        assert!(s.created > 0 && s.forked > 0 && s.dropped > 0, "{s:?}");
        assert!(s.blocks_shared > 0, "forks must inherit prefix blocks: {s:?}");
        assert!(s.live <= s.peak_live);
        assert_eq!(s.created + s.forked, s.dropped + s.live, "{s:?}");
        assert_eq!(s.refcount_histogram.iter().sum::<u64>(), s.unique_blocks);
        assert!(s.dedup_ratio >= 1.0);
        assert!(s.total_refs >= s.unique_blocks);
        assert!(s.metadata_bytes > 0);
    }

    #[test]
    fn fork_sharing_beats_independent_sessions() {
        let spec = fork_heavy_tiny(9);
        let fork = run_scenario(&spec);
        let base = run_scenario(&spec.session_baseline());
        // the baseline replays the identical trace with sharing disabled:
        // same arrivals, same chains, same hit-rate denominator
        assert_eq!(fork.requests, base.requests);
        assert_eq!(fork.blocks_requested, base.blocks_requested, "identical token traffic");
        let fs = fork.sessions.as_ref().unwrap();
        let bs = base.sessions.as_ref().unwrap();
        assert!(fs.mode_shared && !bs.mode_shared);
        assert!(fs.forked > 0 && fs.blocks_shared > 0);
        assert_eq!(bs.forked, 0, "the baseline replays forks as fresh sessions");
        assert_eq!(bs.blocks_shared, 0);
        assert!(
            fork.block_hit_rate > base.block_hit_rate,
            "zero-copy forks must out-hit independent replays: {} vs {}",
            fork.block_hit_rate,
            base.block_hit_rate
        );
        assert!(
            fork.isl_bytes < base.isl_bytes,
            "shared prefixes must skip orbit refetches: {} vs {}",
            fork.isl_bytes,
            base.isl_bytes
        );
        assert!(
            fork.memory.bytes_per_cached_token < base.memory.bytes_per_cached_token,
            "sharing must cost fewer bytes per cached token: {} vs {}",
            fork.memory.bytes_per_cached_token,
            base.memory.bytes_per_cached_token
        );
    }

    #[test]
    fn presessions_are_metadata_cheap_and_traffic_neutral() {
        let small = fork_heavy_tiny(5);
        let mut big = fork_heavy_tiny(5);
        big.sessions.as_mut().unwrap().presessions = 10_000;
        let rs = run_scenario(&small);
        let rb = run_scenario(&big);
        // pre-registered sessions are metadata only: the served trace and
        // its token traffic are identical across sweep points
        assert_eq!(rb.requests, rs.requests);
        assert_eq!(rb.blocks_requested, rs.blocks_requested);
        let ss = rs.sessions.as_ref().unwrap();
        let sb = rb.sessions.as_ref().unwrap();
        assert!(sb.presessions >= 10_000, "{sb:?}");
        assert!(sb.live >= 10_000, "presessions stay live for the whole run");
        let per_session = (sb.metadata_bytes - ss.metadata_bytes) / 10_000;
        assert!(
            per_session < 256,
            "a pre-registered fork must cost well under 256 B, got {per_session}"
        );
    }

    #[test]
    fn federated_runs_carry_the_session_layer() {
        let mut spec = tiny_fed(11);
        spec.sessions = Some(crate::sim::workload::SessionWorkloadConfig {
            seed: 11,
            ..Default::default()
        });
        let a = run_federated_scenario(&spec);
        let b = run_federated_scenario(&spec);
        assert_eq!(a, b);
        assert_eq!(a.to_json_string(), b.to_json_string());
        let s = a.sessions.as_ref().unwrap();
        assert!(s.mode_shared && s.created > 0, "{s:?}");
        assert!(a.block_hit_rate > 0.0);
        assert!(a.to_json_string().contains("\"sessions\""));
    }

    #[test]
    fn non_session_reports_omit_the_sessions_object() {
        let r = run_scenario(&tiny_spec(3));
        assert!(r.sessions.is_none());
        assert!(!r.to_json_string().contains("\"sessions\""));
        let f = run_federated_scenario(&tiny_fed(3));
        assert!(f.sessions.is_none());
        assert!(!f.to_json_string().contains("\"sessions\""));
    }
}
