//! The §4 simulator: "we implemented a simulator that computes the
//! worst-case latency based on the distance equation 1, and the chunk
//! farthest away" — plus the workload generator used by the serving
//! benches, and the deterministic end-to-end scenario subsystem:
//!
//! * [`config`]/[`latency`] — the closed-form Figure 16 model.
//! * [`workload`] — shared-prefix prompt generation.
//! * [`scenario`] — named, seed-driven scenario specs (the paper's 19x5
//!   testbed, a Starlink-like 72x22 mega-shell, a Kuiper-like 34x34
//!   shell, the `mega-shell` [`crate::net::sched`] stress shape, and the
//!   federated dual- and tri-shell scenarios; `skymemory scenario
//!   --list`) with failure-injection plans — random per-epoch draws
//!   ([`scenario::FailurePlan`]) and scheduled correlated events
//!   ([`scenario::CorrelatedFailure`]: whole-plane loss, solar-storm
//!   bands, fractional box kills).
//! * [`harness`] — runs a scenario end to end over the real protocol
//!   stack (fleet + mapping + migration + KVC manager; for federated
//!   scenarios, the [`crate::federation`] stack) and emits a byte-stable
//!   metrics JSON report.
//! * [`diff`] — the scenario-diff tool: per-metric deltas between two
//!   metrics JSON files with regression detection.

pub mod config;
pub mod diff;
pub mod harness;
pub mod latency;
pub mod scenario;
pub mod workload;

pub use config::SimConfig;
pub use harness::{run_federated_scenario, run_scenario, FederatedScenarioReport, ScenarioReport};
pub use latency::{worst_case_latency, LatencyBreakdown};
pub use scenario::{FailureKind, FailurePlan, FederatedScenarioSpec, ScenarioSpec};
