//! The §4 simulator: "we implemented a simulator that computes the
//! worst-case latency based on the distance equation 1, and the chunk
//! farthest away" — plus the workload generator used by the serving
//! benches.

pub mod config;
pub mod latency;
pub mod workload;

pub use config::SimConfig;
pub use latency::{worst_case_latency, LatencyBreakdown};
