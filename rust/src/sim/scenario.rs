//! Named, reproducible end-to-end scenarios.
//!
//! A [`ScenarioSpec`] fully determines one simulation run: constellation
//! shape and altitude, mapping strategy, KVC configuration, workload, the
//! rotation-epoch schedule, and a deterministic failure-injection plan
//! (satellite loss, ISL outage, ground-station handover).  The same spec
//! and seed always produce byte-identical metrics JSON — the harness
//! ([`super::harness`]) is careful to avoid every source of run-to-run
//! nondeterminism (hash-map iteration order, wall-clock time, thread
//! scheduling observable at block granularity).
//!
//! Seven scenarios ship built in (`skymemory scenario --list`):
//!
//! * `paper-19x5` — the paper's NUC-testbed shape (§5): 5 planes x 19
//!   satellites at 550 km, 9 virtual servers, heavy per-satellite memory
//!   pressure so LRU eviction and gossip stay exercised.
//! * `starlink-shell` — a mega-constellation shell of 72 planes x 22
//!   satellites (Starlink's 550 km shell), 25 servers, with recurring
//!   satellite losses, ISL outages and a ground-station handover.
//! * `kuiper-shell` — 34 planes x 34 satellites at 630 km (Kuiper's
//!   first shell), 49 servers, moderate failure pressure.
//! * `mega-shell` — the [`crate::net::sched`] stress shape: the 72x22
//!   shell with >1000 in-flight chunks per block over throttled links,
//!   for sweeping the per-link transfer window (`skymemory sched`).
//! * `fork-heavy-chat` — the session-layer scenario: the paper's 5x19
//!   shape driven by a Zipfian multi-tenant chat trace through
//!   [`crate::kvc::session::SessionManager`] — forked sessions share
//!   their prefix blocks by refcount instead of refetching them, and the
//!   refs pin shared blocks against eviction.  `skymemory sessions
//!   fork-heavy-chat --baseline` gates it against the independent-
//!   sessions replay of the identical trace
//!   ([`ScenarioSpec::session_baseline`]).
//! * `federated-dual-shell` — a two-shell federation (the Starlink-like
//!   72x22 shell at 550 km plus the Kuiper-like 34x34 shell at 630 km)
//!   run through [`crate::federation`]: shell-aware placement with
//!   spillover, random failures on the primary shell, and a mid-run kill
//!   of the primary shell's layout box that forces an inter-shell
//!   handover of the hot chunks (see
//!   [`FederatedScenarioSpec::federated_dual_shell`] and
//!   [`super::harness::run_federated_scenario`]).
//! * `federated-tri-shell` — the N-shell flagship: Starlink 550 km +
//!   Kuiper 630 km + a polar 1200 km shell with its own layout config,
//!   hot-block replication across the two cheapest shells, §3.7
//!   predictive pre-placement, and a scheduled *correlated-failure* plan
//!   ([`CorrelatedFailure`]: whole-plane loss, a solar-storm band over
//!   the primary, a fractional box kill on the fallback) that the
//!   replicated federation must survive strictly better than the
//!   re-homing-only baseline.

use crate::constellation::geometry::Geometry;
use crate::constellation::topology::{SatId, Torus};
use crate::federation::placement::{
    cheapest_index, shell_cost, PlacementPolicy, ReplicationPolicy, ShellLayoutConfig,
};
use crate::kvc::eviction::EvictionPolicy;
use crate::kvc::manager::KvcConfig;
use crate::kvc::quantize::Quantizer;
use crate::mapping::{box_width, Strategy};
use crate::sim::workload::{SessionWorkloadConfig, WorkloadConfig};

/// The failure classes the harness can inject.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailureKind {
    /// A satellite disappears: its store is wiped and all traffic to or
    /// through it fails for the rest of the run.
    SatelliteLoss,
    /// One ISL goes dark for a bounded number of epochs.
    IslOutage,
    /// The ground host switches to a different ground station; the LOS
    /// window re-homes and pre-handover chunk locality is lost.
    GroundHandover,
}

/// Deterministic, seed-driven failure schedule.  Failures start after the
/// first epoch (epoch 0 populates the cache cleanly), and are sampled
/// from the scenario RNG so the same seed yields the same plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FailurePlan {
    /// Satellites lost at the start of each epoch (permanent).
    pub sat_losses_per_epoch: usize,
    /// ISL outages injected at the start of each epoch.
    pub isl_outages_per_epoch: usize,
    /// Epochs after which an injected ISL outage heals.
    pub isl_outage_heal_epochs: u64,
    /// Ground-station handover every `k` epochs (0 = never).
    pub handover_every_epochs: u64,
}

impl FailurePlan {
    /// No failures at all.
    pub const NONE: FailurePlan = FailurePlan {
        sat_losses_per_epoch: 0,
        isl_outages_per_epoch: 0,
        isl_outage_heal_epochs: 1,
        handover_every_epochs: 0,
    };

    pub fn is_none(&self) -> bool {
        self.sat_losses_per_epoch == 0
            && self.isl_outages_per_epoch == 0
            && self.handover_every_epochs == 0
    }
}

/// A correlated (multi-satellite) failure event of a federated scenario
/// plan.  Unlike the random per-epoch [`FailurePlan`] draws, these are
/// scheduled, spatially-correlated losses; satellite coordinates are
/// relative to the target shell's *current* ground-view centre, so plans
/// stay meaningful as the shells rotate.  All three kinds are permanent
/// (stores wiped, traffic blackholed): a lost plane never redeploys
/// mid-run and a storm-latched satellite stays dark.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CorrelatedFailure {
    /// A whole orbital plane is lost (launch-vehicle or deployment
    /// failure): every satellite of the plane `plane_offset` planes from
    /// the current centre goes dark.
    PlaneLoss { epoch: u64, shell: usize, plane_offset: i32 },
    /// Fractional layout-box kill — partial-shell degradation: the given
    /// fraction of the shell's current layout-box cells (row-major from
    /// the north-west corner, `ceil`) goes dark.
    BoxKill { epoch: u64, shell: usize, fraction: f64 },
    /// A solar-storm regional outage: every satellite within
    /// `half_width` slots of the centre's slot band, across *all* planes
    /// of the shell, goes dark.
    SolarStorm { epoch: u64, shell: usize, half_width: usize },
}

impl CorrelatedFailure {
    pub fn epoch(&self) -> u64 {
        match self {
            CorrelatedFailure::PlaneLoss { epoch, .. }
            | CorrelatedFailure::BoxKill { epoch, .. }
            | CorrelatedFailure::SolarStorm { epoch, .. } => *epoch,
        }
    }

    pub fn shell(&self) -> usize {
        match self {
            CorrelatedFailure::PlaneLoss { shell, .. }
            | CorrelatedFailure::BoxKill { shell, .. }
            | CorrelatedFailure::SolarStorm { shell, .. } => *shell,
        }
    }
}

/// A fully-specified simulation scenario.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    pub name: String,
    /// Orbital planes (`N`).
    pub planes: usize,
    /// Satellites per plane (`M`).
    pub sats_per_plane: usize,
    pub altitude_km: f64,
    pub strategy: Strategy,
    /// Virtual servers the KVC stripes over.
    pub n_servers: usize,
    /// Tokens per hash block.
    pub block_tokens: usize,
    /// Chunk payload bytes.
    pub chunk_size: usize,
    pub quantizer: Quantizer,
    pub eviction: EvictionPolicy,
    /// Per-satellite store budget, bytes (small values create eviction
    /// pressure).
    pub sat_budget_bytes: usize,
    /// f32 values of one block's KV payload (must be a multiple of the
    /// quantizer group; sized so a block spans >= `n_servers` chunks and
    /// the stripe really fans out).
    pub kv_values_per_block: usize,
    /// Rotation epochs to sweep (with migration between epochs).
    pub epochs: u64,
    pub requests_per_epoch: usize,
    pub workload: WorkloadConfig,
    /// When set, the run is driven by the session layer instead of the
    /// plain prefix workload: `requests_per_epoch` arrivals per epoch are
    /// drawn from the Zipfian session trace and served through
    /// [`crate::kvc::session::SessionManager`] (`workload` is ignored).
    pub sessions: Option<SessionWorkloadConfig>,
    pub failures: FailurePlan,
    /// Per-link in-flight window of the [`crate::net::sched`] scheduler
    /// driving the chunk fan-out.
    pub sched_window: usize,
    /// Link serialization bandwidth, bits/s (uplink and ISL).
    pub link_bandwidth_bps: f64,
    pub seed: u64,
}

impl ScenarioSpec {
    pub fn torus(&self) -> Torus {
        Torus::new(self.planes, self.sats_per_plane)
    }

    pub fn geometry(&self) -> Geometry {
        Geometry::new(self.altitude_km, self.sats_per_plane, self.planes)
    }

    /// The ground host starts under the middle of the grid.
    pub fn initial_center(&self) -> SatId {
        SatId::new((self.planes / 2) as u16, (self.sats_per_plane / 2) as u16)
    }

    pub fn kvc_config(&self) -> KvcConfig {
        KvcConfig {
            block_tokens: self.block_tokens,
            chunk_size: self.chunk_size,
            n_servers: self.n_servers,
            strategy: self.strategy,
            quantizer: self.quantizer,
            eviction: self.eviction,
            use_radix_index: true,
            gossip_ttl: 2,
            sched_window: self.sched_window,
        }
    }

    pub fn total_requests(&self) -> usize {
        self.epochs as usize * self.requests_per_epoch
    }

    /// Sanity-check the spec's internal consistency (box fits the torus,
    /// quantizer grouping divides the block payload, ...).  Panics with a
    /// descriptive message on misuse; the built-in specs always pass.
    pub fn validate(&self) {
        let w = box_width(self.n_servers);
        assert!(
            w <= self.planes && w <= self.sats_per_plane,
            "{}: {}x{} LOS box does not fit a {}x{} torus",
            self.name,
            w,
            w,
            self.planes,
            self.sats_per_plane
        );
        if let Quantizer::QuantoInt8 { group } | Quantizer::HqqInt8 { group } = self.quantizer {
            assert!(
                self.kv_values_per_block % group == 0,
                "{}: kv_values_per_block must be a multiple of the group",
                self.name
            );
        }
        assert!(self.epochs >= 1 && self.requests_per_epoch >= 1, "{}: empty run", self.name);
        assert!(self.sched_window >= 1, "{}: a link window must admit a transfer", self.name);
        assert!(self.link_bandwidth_bps > 0.0, "{}: links need bandwidth", self.name);
        if let Some(sw) = &self.sessions {
            // tokens are prompt bytes, so char counts are token counts:
            // block-aligned templates and turns keep session chains free of
            // partial-block tails and make the traffic hand-predictable
            assert!(
                sw.template_chars % self.block_tokens == 0
                    && sw.turn_chars % self.block_tokens == 0,
                "{}: session template/turn chars must be block_tokens-aligned",
                self.name
            );
            assert!(sw.n_templates >= 1, "{}: sessions need a template", self.name);
            assert!(
                sw.fork_frac >= 0.0
                    && sw.extend_frac >= 0.0
                    && sw.fork_frac + sw.extend_frac <= 1.0,
                "{}: fork/extend fractions must partition the arrival mix",
                self.name
            );
            assert!(sw.lifetime_turns >= 1, "{}: sessions must live a turn", self.name);
        }
    }

    // --- built-in scenarios ---------------------------------------------

    /// The paper's 19x5 NUC-testbed shape (§5) with tight per-satellite
    /// budgets: exercises migration, LRU eviction pressure and gossip,
    /// plus light failure injection.
    pub fn paper_19x5(seed: u64) -> ScenarioSpec {
        ScenarioSpec {
            name: "paper-19x5".into(),
            planes: 5,
            sats_per_plane: 19,
            altitude_km: 550.0,
            strategy: Strategy::RotationHopAware,
            n_servers: 9,
            block_tokens: 32,
            chunk_size: 600,
            quantizer: Quantizer::QuantoInt8 { group: 32 },
            eviction: EvictionPolicy::Gossip,
            // each block encodes to ~9.2 kB over 16 chunks; the busiest
            // satellites carry ~30 kB of hot-set chunks, and the one-shot
            // scan traffic (every 5th request) pushes them over budget so
            // LRU eviction (and its gossip) stays continuously exercised
            // while the hot contexts keep hitting
            sat_budget_bytes: 48 << 10,
            kv_values_per_block: 8192,
            epochs: 6,
            requests_per_epoch: 24,
            workload: WorkloadConfig {
                n_contexts: 4,
                context_chars: 192,
                n_questions: 6,
                scan_every: 5,
                seed,
            },
            sessions: None,
            failures: FailurePlan {
                sat_losses_per_epoch: 1,
                isl_outages_per_epoch: 1,
                isl_outage_heal_epochs: 2,
                handover_every_epochs: 0,
            },
            sched_window: 8,
            link_bandwidth_bps: 1e9,
            seed,
        }
    }

    /// A Starlink-like mega-constellation shell: 72 planes x 22 sats at
    /// 550 km (1584 satellites), 25 servers, with satellite losses, ISL
    /// outages and a mid-run ground-station handover.
    pub fn starlink_shell(seed: u64) -> ScenarioSpec {
        ScenarioSpec {
            name: "starlink-shell".into(),
            planes: 72,
            sats_per_plane: 22,
            altitude_km: 550.0,
            strategy: Strategy::RotationHopAware,
            n_servers: 25,
            block_tokens: 32,
            chunk_size: 600,
            quantizer: Quantizer::QuantoInt8 { group: 32 },
            eviction: EvictionPolicy::Lazy,
            // busiest satellites hold ~43 kB of hot chunks; scan traffic
            // (every 6th request) overflows the 64 kB budget -> eviction
            sat_budget_bytes: 64 << 10,
            // 16384 f32 -> ~18.4 kB quantized -> 31 chunks > 25 servers
            kv_values_per_block: 16384,
            epochs: 5,
            requests_per_epoch: 30,
            workload: WorkloadConfig {
                n_contexts: 5,
                context_chars: 224,
                n_questions: 8,
                scan_every: 6,
                seed,
            },
            sessions: None,
            failures: FailurePlan {
                sat_losses_per_epoch: 2,
                isl_outages_per_epoch: 2,
                isl_outage_heal_epochs: 2,
                handover_every_epochs: 3,
            },
            sched_window: 8,
            link_bandwidth_bps: 1e9,
            seed,
        }
    }

    /// A Kuiper-like shell: 34 planes x 34 sats at 630 km (1156
    /// satellites), 49 servers, moderate failure pressure.
    pub fn kuiper_shell(seed: u64) -> ScenarioSpec {
        ScenarioSpec {
            name: "kuiper-shell".into(),
            planes: 34,
            sats_per_plane: 34,
            altitude_km: 630.0,
            strategy: Strategy::RotationHopAware,
            n_servers: 49,
            block_tokens: 32,
            chunk_size: 360,
            quantizer: Quantizer::QuantoInt8 { group: 32 },
            eviction: EvictionPolicy::Lazy,
            // busiest satellites hold ~21 kB of hot chunks; scan traffic
            // (every 6th request) overflows the 32 kB budget -> eviction
            sat_budget_bytes: 32 << 10,
            // 16384 f32 -> ~18.4 kB quantized -> 52 chunks over the
            // 49-way stripe
            kv_values_per_block: 16384,
            epochs: 4,
            requests_per_epoch: 24,
            workload: WorkloadConfig {
                n_contexts: 4,
                context_chars: 224,
                n_questions: 6,
                scan_every: 6,
                seed,
            },
            sessions: None,
            failures: FailurePlan {
                sat_losses_per_epoch: 1,
                isl_outages_per_epoch: 2,
                isl_outage_heal_epochs: 2,
                handover_every_epochs: 0,
            },
            sched_window: 8,
            link_bandwidth_bps: 1e9,
            seed,
        }
    }

    /// The `net::sched` stress shape: the Starlink-like 72x22 shell with
    /// *huge* blocks over tiny chunks, so a single block fans out into
    /// >1000 concurrent in-flight transfers — the regime the
    /// discrete-event scheduler exists for (thread-per-chunk would melt).
    /// Bandwidth is throttled to 20 Mbit/s so the per-link in-flight
    /// window ([`ScenarioSpec::sched_window`], sweep it with
    /// `skymemory sched`) visibly shapes queueing and tail latency.
    pub fn mega_shell(seed: u64) -> ScenarioSpec {
        ScenarioSpec {
            name: "mega-shell".into(),
            planes: 72,
            sats_per_plane: 22,
            altitude_km: 550.0,
            strategy: Strategy::RotationHopAware,
            n_servers: 25,
            block_tokens: 32,
            // 32768 f32 -> 36864 B quantized over 32 B chunks -> 1152
            // chunks per block, striped 25 ways (~46 per box satellite)
            chunk_size: 32,
            quantizer: Quantizer::QuantoInt8 { group: 32 },
            eviction: EvictionPolicy::Lazy,
            // hot set ~27 blocks x ~2.3 kB per box satellite: fits, so
            // the run measures scheduling, not eviction churn
            sat_budget_bytes: 192 << 10,
            kv_values_per_block: 32768,
            epochs: 3,
            requests_per_epoch: 10,
            workload: WorkloadConfig {
                n_contexts: 3,
                context_chars: 96,
                n_questions: 4,
                scan_every: 6,
                seed,
            },
            sessions: None,
            failures: FailurePlan {
                sat_losses_per_epoch: 1,
                isl_outages_per_epoch: 1,
                isl_outage_heal_epochs: 2,
                handover_every_epochs: 0,
            },
            sched_window: 8,
            link_bandwidth_bps: 2e7,
            seed,
        }
    }

    /// The session-layer scenario: the paper's 5x19 shape under a
    /// Zipfian multi-tenant chat trace where half the arrivals *fork* a
    /// live conversation (shared system prompt + history) instead of
    /// starting cold.  Forks share their prefix blocks through
    /// [`crate::kvc::session::SessionManager`] refcounts — no refetch, no
    /// re-store — and the refs pin shared blocks against the same LRU /
    /// gossip eviction pressure `paper-19x5` runs under.  The
    /// independent-sessions baseline ([`ScenarioSpec::session_baseline`])
    /// replays the identical token traffic with every fork served as a
    /// fresh session.
    pub fn fork_heavy_chat(seed: u64) -> ScenarioSpec {
        ScenarioSpec {
            name: "fork-heavy-chat".into(),
            planes: 5,
            sats_per_plane: 19,
            altitude_km: 550.0,
            strategy: Strategy::RotationHopAware,
            n_servers: 9,
            block_tokens: 32,
            chunk_size: 600,
            quantizer: Quantizer::QuantoInt8 { group: 32 },
            eviction: EvictionPolicy::Gossip,
            // the same tight budget as paper-19x5: session turns keep
            // minting fresh blocks, so the stores overflow and eviction
            // must steer around the pinned shared prefixes
            sat_budget_bytes: 48 << 10,
            kv_values_per_block: 8192,
            epochs: 6,
            requests_per_epoch: 24,
            // unused when `sessions` is set; kept spec-complete
            workload: WorkloadConfig {
                n_contexts: 4,
                context_chars: 192,
                n_questions: 6,
                scan_every: 5,
                seed,
            },
            sessions: Some(SessionWorkloadConfig {
                n_templates: 4,
                zipf_s: 1.1,
                // 6 blocks of shared template, 1 block per turn
                template_chars: 192,
                turn_chars: 32,
                fork_frac: 0.5,
                extend_frac: 0.25,
                lifetime_turns: 4,
                presessions: 0,
                share: true,
                seed,
            }),
            failures: FailurePlan {
                sat_losses_per_epoch: 1,
                isl_outages_per_epoch: 1,
                isl_outage_heal_epochs: 2,
                handover_every_epochs: 0,
            },
            sched_window: 8,
            link_bandwidth_bps: 1e9,
            seed,
        }
    }

    /// The independent-sessions baseline of a session scenario: the
    /// *identical* op trace (same seed, same templates, same turns) with
    /// prefix sharing switched off — every fork is served as a fresh
    /// session carrying its parent's full token history, refs are not
    /// installed, nothing is pinned.  `skymemory sessions --baseline`
    /// gates the fork-heavy run against this.
    pub fn session_baseline(&self) -> ScenarioSpec {
        let mut spec = self.clone();
        spec.name = format!("{}-baseline", self.name);
        if let Some(sw) = &mut spec.sessions {
            sw.share = false;
        }
        spec
    }

    /// All built-in scenarios, paper shape first.
    pub fn builtin(seed: u64) -> Vec<ScenarioSpec> {
        vec![
            ScenarioSpec::paper_19x5(seed),
            ScenarioSpec::starlink_shell(seed),
            ScenarioSpec::kuiper_shell(seed),
            ScenarioSpec::mega_shell(seed),
            ScenarioSpec::fork_heavy_chat(seed),
        ]
    }

    /// Look up a built-in scenario by name.
    pub fn by_name(name: &str, seed: u64) -> Option<ScenarioSpec> {
        match name {
            "paper-19x5" => Some(ScenarioSpec::paper_19x5(seed)),
            "starlink-shell" => Some(ScenarioSpec::starlink_shell(seed)),
            "kuiper-shell" => Some(ScenarioSpec::kuiper_shell(seed)),
            "mega-shell" => Some(ScenarioSpec::mega_shell(seed)),
            "fork-heavy-chat" => Some(ScenarioSpec::fork_heavy_chat(seed)),
            _ => None,
        }
    }
}

/// One-line summaries of every built-in scenario (single-shell and
/// federated), for `skymemory scenario --list`.
pub const BUILTIN_SUMMARIES: &[(&str, &str)] = &[
    (
        "paper-19x5",
        "the paper's 5x19 NUC-testbed shape at 550 km: 9 servers, heavy eviction pressure, light failures",
    ),
    (
        "starlink-shell",
        "Starlink-like 72x22 mega-shell at 550 km: 25 servers, satellite/ISL failures and a ground handover",
    ),
    (
        "kuiper-shell",
        "Kuiper-like 34x34 shell at 630 km: 49 servers, moderate failure pressure",
    ),
    (
        "mega-shell",
        "net::sched stress: 72x22 shell, >1000 in-flight chunks per block, 20 Mbit/s links (sweep windows via `skymemory sched`)",
    ),
    (
        "fork-heavy-chat",
        "session layer on the 5x19 shape: Zipfian chat trace, half the arrivals fork a live session and share its prefix by refcount (gate vs the no-sharing baseline via `skymemory sessions`)",
    ),
    (
        "federated-dual-shell",
        "two-shell federation (Starlink 550 km + Kuiper 630 km): placement spillover and a mid-run primary-box kill with inter-shell handover",
    ),
    (
        "federated-tri-shell",
        "three-shell federation (Starlink 550 km + Kuiper 630 km + polar 1200 km): hot-block replication, §3.7 pre-placement, and a correlated-failure plan (plane loss, solar storm, fractional box kill)",
    ),
];

/// One shell of a federated scenario.  `strategy` / `n_servers` override
/// the federation-wide defaults for this shell
/// ([`crate::federation::placement::ShellLayoutConfig`]): a sparse polar
/// shell can stripe differently from a dense mega-shell.
#[derive(Debug, Clone)]
pub struct ShellSpec {
    pub name: String,
    pub planes: usize,
    pub sats_per_plane: usize,
    pub altitude_km: f64,
    /// Per-shell mapping-strategy override (`None` = the spec's).
    pub strategy: Option<Strategy>,
    /// Per-shell stripe-width override (`None` = the spec's).
    pub n_servers: Option<usize>,
}

impl ShellSpec {
    pub fn torus(&self) -> Torus {
        Torus::new(self.planes, self.sats_per_plane)
    }

    pub fn geometry(&self) -> Geometry {
        Geometry::new(self.altitude_km, self.sats_per_plane, self.planes)
    }

    /// The ground host starts under the middle of the shell's grid.
    pub fn initial_center(&self) -> SatId {
        SatId::new((self.planes / 2) as u16, (self.sats_per_plane / 2) as u16)
    }
}

/// A fully-specified multi-shell federation scenario.  KVC parameters are
/// shared across shells (one stripe width, one quantizer); each shell
/// keeps its own geometry, fleet and failure state.
#[derive(Debug, Clone)]
pub struct FederatedScenarioSpec {
    pub name: String,
    /// The federated shells (normally >= 2; a single shell runs the same
    /// harness as a no-federation baseline).
    pub shells: Vec<ShellSpec>,
    pub strategy: Strategy,
    pub n_servers: usize,
    pub block_tokens: usize,
    pub chunk_size: usize,
    pub quantizer: Quantizer,
    pub eviction: EvictionPolicy,
    pub sat_budget_bytes: usize,
    pub kv_values_per_block: usize,
    pub epochs: u64,
    pub requests_per_epoch: usize,
    pub workload: WorkloadConfig,
    /// When set, the federated run is driven by the session layer instead
    /// of the plain prefix workload (see [`ScenarioSpec::sessions`]).
    pub sessions: Option<SessionWorkloadConfig>,
    /// Random failures, injected into the primary shell only.
    pub failures: FailurePlan,
    /// Scheduled correlated failures (whole-plane loss, fractional box
    /// kills, solar-storm bands), applied at the start of their epoch —
    /// *without* any pre-announced evacuation: surviving them is what
    /// replication and pre-placement are for.
    pub correlated: Vec<CorrelatedFailure>,
    /// Epoch at which the primary shell's layout box is killed for the
    /// rest of the run (0 = never).  The manager evacuates the box over
    /// the inter-shell links first — the proactive handover — and the
    /// kill band covers the box's westward slide, so the primary stays
    /// ineligible until the run ends.
    pub primary_kill_epoch: u64,
    /// Replicate the K hottest blocks across the two cheapest shells
    /// (0 = re-homing only; see
    /// [`crate::federation::placement::ReplicationPolicy`]).
    pub replicate_top_k: usize,
    /// Accesses a block needs before it is replica-eligible.
    pub replicate_min_accesses: u64,
    /// Run the §3.7 pre-placement predictor at epoch boundaries.
    pub preplace: bool,
    /// Placement eligibility threshold (live fraction of the layout box).
    pub min_live_fraction: f64,
    /// Per-shell byte budget before placement spills over (0 = none).
    pub spill_budget_bytes: u64,
    /// Per-link in-flight window of every shell's [`crate::net::sched`]
    /// scheduler.
    pub sched_window: usize,
    pub seed: u64,
}

impl FederatedScenarioSpec {
    pub fn kvc_config(&self) -> KvcConfig {
        KvcConfig {
            block_tokens: self.block_tokens,
            chunk_size: self.chunk_size,
            n_servers: self.n_servers,
            strategy: self.strategy,
            quantizer: self.quantizer,
            eviction: self.eviction,
            use_radix_index: true,
            gossip_ttl: 2,
            sched_window: self.sched_window,
        }
    }

    pub fn placement(&self) -> PlacementPolicy {
        PlacementPolicy {
            min_live_fraction: self.min_live_fraction,
            spill_budget_bytes: self.spill_budget_bytes,
        }
    }

    pub fn replication(&self) -> ReplicationPolicy {
        ReplicationPolicy {
            top_k: self.replicate_top_k,
            min_accesses: self.replicate_min_accesses,
        }
    }

    /// Effective per-shell layout configs (shell overrides applied over
    /// the federation-wide defaults), index-aligned with `shells`.
    pub fn shell_layouts(&self) -> Vec<ShellLayoutConfig> {
        self.shells
            .iter()
            .map(|s| ShellLayoutConfig {
                strategy: s.strategy.unwrap_or(self.strategy),
                n_servers: s.n_servers.unwrap_or(self.n_servers),
            })
            .collect()
    }

    pub fn total_requests(&self) -> usize {
        self.epochs as usize * self.requests_per_epoch
    }

    /// Index of the static primary shell: cheapest by [`shell_cost`]
    /// over each shell's *own* stripe width, ties to the lowest index
    /// (the same [`cheapest_index`] argmin the manager and placement
    /// policy use).
    pub fn primary_shell_index(&self) -> usize {
        let costs: Vec<f64> = self
            .shells
            .iter()
            .zip(self.shell_layouts())
            .map(|(s, lc)| shell_cost(&s.geometry(), lc.n_servers))
            .collect();
        cheapest_index(&costs).expect("a federation has shells")
    }

    /// The no-federation baseline: the same scenario reduced to the
    /// primary shell alone (same workload, failures and kill schedule,
    /// nowhere to hand over to, nothing to replicate onto).  Correlated
    /// events aimed at the dropped shells are dropped with them.
    pub fn baseline_single_shell(&self) -> FederatedScenarioSpec {
        let primary = self.primary_shell_index();
        let mut spec = self.clone();
        spec.name = format!("{}-baseline", self.name);
        spec.shells = vec![self.shells[primary].clone()];
        spec.correlated = self
            .correlated
            .iter()
            .filter(|c| c.shell() == primary)
            .map(|c| {
                let mut c = *c;
                match &mut c {
                    CorrelatedFailure::PlaneLoss { shell, .. }
                    | CorrelatedFailure::BoxKill { shell, .. }
                    | CorrelatedFailure::SolarStorm { shell, .. } => *shell = 0,
                }
                c
            })
            .collect();
        spec.replicate_top_k = 0;
        spec.preplace = false;
        spec
    }

    /// The re-homing-only baseline: the identical federation (same
    /// shells, workload, failure and correlated plans) with replication
    /// and pre-placement switched off — what PR 2 shipped.  The
    /// replicated run must strictly out-hit this under the correlated
    /// plan; `skymemory federate --baseline` gates on it.
    pub fn rehoming_baseline(&self) -> FederatedScenarioSpec {
        let mut spec = self.clone();
        spec.name = format!("{}-rehoming", self.name);
        spec.replicate_top_k = 0;
        spec.preplace = false;
        spec
    }

    /// Sanity-check internal consistency; panics with a descriptive
    /// message on misuse.  The built-in specs always pass.
    pub fn validate(&self) {
        assert!(!self.shells.is_empty(), "{}: a federation needs shells", self.name);
        for (s, lc) in self.shells.iter().zip(self.shell_layouts()) {
            let w = box_width(lc.n_servers);
            assert!(
                w <= s.planes && w <= s.sats_per_plane,
                "{}: {w}x{w} layout box does not fit shell {} ({}x{})",
                self.name,
                s.name,
                s.planes,
                s.sats_per_plane
            );
        }
        for c in &self.correlated {
            assert!(
                c.shell() < self.shells.len(),
                "{}: correlated failure aims at shell {} of {}",
                self.name,
                c.shell(),
                self.shells.len()
            );
            assert!(
                c.epoch() > 0 && c.epoch() < self.epochs,
                "{}: correlated failure epoch {} outside (0, {})",
                self.name,
                c.epoch(),
                self.epochs
            );
            if let CorrelatedFailure::BoxKill { fraction, .. } = c {
                assert!(
                    *fraction > 0.0 && *fraction <= 1.0,
                    "{}: box-kill fraction must be in (0, 1]",
                    self.name
                );
            }
        }
        assert!(
            !self.preplace || self.replicate_top_k > 0,
            "{}: the predictor pre-places the replication hot set (top_k > 0)",
            self.name
        );
        if let Quantizer::QuantoInt8 { group } | Quantizer::HqqInt8 { group } = self.quantizer {
            assert!(
                self.kv_values_per_block % group == 0,
                "{}: kv_values_per_block must be a multiple of the group",
                self.name
            );
        }
        assert!(self.epochs >= 1 && self.requests_per_epoch >= 1, "{}: empty run", self.name);
        assert!(
            self.primary_kill_epoch < self.epochs,
            "{}: the kill epoch must fall inside the run",
            self.name
        );
        assert!(
            (0.0..=1.0).contains(&self.min_live_fraction),
            "{}: min_live_fraction must be a fraction",
            self.name
        );
        assert!(self.sched_window >= 1, "{}: a link window must admit a transfer", self.name);
    }

    /// The built-in dual-shell federation: the Starlink-like 550 km shell
    /// plus the Kuiper-like 630 km shell, 9 virtual servers, random
    /// failures on the primary shell and a kill of the primary's layout
    /// box at epoch 3 of 6 — the inter-shell handover acceptance case.
    /// (Kuiper's denser 34-sat planes make it the cost-primary despite
    /// the higher altitude; Starlink is the spillover/handover target.)
    pub fn federated_dual_shell(seed: u64) -> FederatedScenarioSpec {
        FederatedScenarioSpec {
            name: "federated-dual-shell".into(),
            shells: vec![
                ShellSpec {
                    name: "starlink-550".into(),
                    planes: 72,
                    sats_per_plane: 22,
                    altitude_km: 550.0,
                    strategy: None,
                    n_servers: None,
                },
                ShellSpec {
                    name: "kuiper-630".into(),
                    planes: 34,
                    sats_per_plane: 34,
                    altitude_km: 630.0,
                    strategy: None,
                    n_servers: None,
                },
            ],
            strategy: Strategy::RotationHopAware,
            n_servers: 9,
            block_tokens: 32,
            chunk_size: 600,
            quantizer: Quantizer::QuantoInt8 { group: 32 },
            eviction: EvictionPolicy::Lazy,
            // same per-satellite pressure as paper-19x5: the one-shot scan
            // traffic overflows the budget so LRU eviction stays live
            sat_budget_bytes: 48 << 10,
            kv_values_per_block: 8192,
            epochs: 6,
            requests_per_epoch: 24,
            workload: WorkloadConfig {
                n_contexts: 4,
                context_chars: 192,
                n_questions: 6,
                scan_every: 5,
                seed,
            },
            sessions: None,
            failures: FailurePlan {
                sat_losses_per_epoch: 1,
                isl_outages_per_epoch: 1,
                isl_outage_heal_epochs: 2,
                handover_every_epochs: 0,
            },
            correlated: vec![],
            primary_kill_epoch: 3,
            replicate_top_k: 0,
            replicate_min_accesses: 2,
            preplace: false,
            min_live_fraction: 0.6,
            // generous soft budget: the scan traffic can push the primary
            // over it late in the run, but the dominant spillover driver
            // is the scheduled box kill
            spill_budget_bytes: 1 << 20,
            sched_window: 8,
            seed,
        }
    }

    /// The built-in three-shell federation under a *correlated-failure*
    /// plan: the Starlink-like 550 km shell, the Kuiper-like 630 km shell
    /// (cost-primary), and a sparse polar 1200 km shell running its own
    /// layout config (rotation-aware stripe — the per-shell override).
    /// The hot set is replicated across the two cheapest shells and the
    /// §3.7 predictor pre-places ahead of handovers.  The plan: a whole
    /// Starlink plane is lost at epoch 2, a solar storm takes out
    /// Kuiper's ±2-slot band (every plane) at epoch 3 with *no*
    /// pre-announced evacuation, and a fractional box kill degrades
    /// Starlink at epoch 4.  Surviving this strictly better than the
    /// re-homing-only baseline ([`FederatedScenarioSpec::rehoming_baseline`])
    /// is the acceptance gate (`skymemory federate --shells 3 --baseline`).
    pub fn federated_tri_shell(seed: u64) -> FederatedScenarioSpec {
        FederatedScenarioSpec {
            name: "federated-tri-shell".into(),
            shells: vec![
                ShellSpec {
                    name: "starlink-550".into(),
                    planes: 72,
                    sats_per_plane: 22,
                    altitude_km: 550.0,
                    strategy: None,
                    n_servers: None,
                },
                ShellSpec {
                    name: "kuiper-630".into(),
                    planes: 34,
                    sats_per_plane: 34,
                    altitude_km: 630.0,
                    strategy: None,
                    n_servers: None,
                },
                ShellSpec {
                    name: "polar-1200".into(),
                    planes: 12,
                    sats_per_plane: 24,
                    altitude_km: 1200.0,
                    // the per-shell override: the polar shell stripes
                    // rotation-aware, so every copy moved onto it is
                    // re-striped rather than offset-preserved
                    strategy: Some(Strategy::RotationAware),
                    n_servers: None,
                },
            ],
            strategy: Strategy::RotationHopAware,
            n_servers: 9,
            block_tokens: 32,
            chunk_size: 600,
            quantizer: Quantizer::QuantoInt8 { group: 32 },
            eviction: EvictionPolicy::Lazy,
            // roomy budgets: replication adds copies, and this scenario
            // measures correlated-failure survival, not eviction churn
            sat_budget_bytes: 256 << 10,
            kv_values_per_block: 8192,
            epochs: 6,
            requests_per_epoch: 24,
            workload: WorkloadConfig {
                n_contexts: 4,
                context_chars: 192,
                n_questions: 6,
                scan_every: 5,
                seed,
            },
            sessions: None,
            failures: FailurePlan {
                sat_losses_per_epoch: 1,
                isl_outages_per_epoch: 1,
                isl_outage_heal_epochs: 2,
                handover_every_epochs: 0,
            },
            correlated: vec![
                // a launch-vehicle loss three planes east of Starlink's
                // centre: outside the layout box, so replicas survive
                CorrelatedFailure::PlaneLoss { epoch: 2, shell: 0, plane_offset: 3 },
                // the sudden solar storm over the primary: the whole
                // ±2-slot band across all 34 Kuiper planes goes dark —
                // only racing the pre-made replicas keeps the hot set hot
                CorrelatedFailure::SolarStorm { epoch: 3, shell: 1, half_width: 2 },
                // partial-shell degradation of the fallback shell: a
                // third of Starlink's box (north row) goes dark, breaking
                // the promoted primaries and forcing a second promotion
                // onto the polar shell's replicas
                CorrelatedFailure::BoxKill { epoch: 4, shell: 0, fraction: 0.33 },
            ],
            primary_kill_epoch: 0,
            // covers the whole shared-context hot set (~24 blocks at this
            // workload): chained-hash prefix walks stop at the first
            // broken block, so replicating the full hot prefix is what
            // keeps the walks alive through the storm
            replicate_top_k: 32,
            replicate_min_accesses: 2,
            preplace: true,
            min_live_fraction: 0.6,
            spill_budget_bytes: 1 << 20,
            sched_window: 8,
            seed,
        }
    }

    /// Look up a built-in federated scenario by name.
    pub fn by_name(name: &str, seed: u64) -> Option<FederatedScenarioSpec> {
        match name {
            "federated-dual-shell" => Some(FederatedScenarioSpec::federated_dual_shell(seed)),
            "federated-tri-shell" => Some(FederatedScenarioSpec::federated_tri_shell(seed)),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_specs_validate() {
        let specs = ScenarioSpec::builtin(7);
        assert_eq!(specs.len(), 5);
        for s in &specs {
            s.validate();
            assert!(s.torus().len() >= s.n_servers);
            assert!(s.total_requests() > 0);
        }
    }

    #[test]
    fn mega_shell_fans_out_over_a_thousand_chunks() {
        let s = ScenarioSpec::mega_shell(1);
        s.validate();
        // a single block must split into >= 1000 chunks: the in-flight
        // concurrency regime the event scheduler exists for
        let payload = s.quantizer.encoded_len(s.kv_values_per_block);
        assert!(payload.div_ceil(s.chunk_size) >= 1000, "{}", payload.div_ceil(s.chunk_size));
        assert!(s.link_bandwidth_bps < 1e9, "throttled links make windows matter");
        assert_eq!(s.sched_window, 8);
    }

    #[test]
    fn builtin_summaries_cover_every_scenario() {
        let names: Vec<&str> = BUILTIN_SUMMARIES.iter().map(|(n, _)| *n).collect();
        for s in ScenarioSpec::builtin(1) {
            assert!(names.contains(&s.name.as_str()), "{} missing a summary", s.name);
        }
        assert!(names.contains(&"federated-dual-shell"));
        // every summarized name resolves through one of the registries
        for (name, desc) in BUILTIN_SUMMARIES {
            assert!(!desc.is_empty());
            assert!(
                ScenarioSpec::by_name(name, 1).is_some()
                    || FederatedScenarioSpec::by_name(name, 1).is_some(),
                "{name} is summarized but not registered"
            );
        }
    }

    #[test]
    fn fork_heavy_chat_spec_is_sound() {
        let s = ScenarioSpec::fork_heavy_chat(7);
        s.validate();
        let sw = s.sessions.expect("session scenario carries a session workload");
        assert!(sw.share, "the builtin runs with sharing on");
        assert!(sw.fork_frac >= 0.5, "fork-heavy means fork-heavy");
        assert_eq!(sw.template_chars % s.block_tokens, 0);
        assert_eq!(sw.turn_chars % s.block_tokens, 0);
        // the other builtins stay session-free
        assert!(ScenarioSpec::paper_19x5(7).sessions.is_none());
    }

    #[test]
    fn session_baseline_disables_sharing_only() {
        let s = ScenarioSpec::fork_heavy_chat(9);
        let b = s.session_baseline();
        b.validate();
        assert_eq!(b.name, "fork-heavy-chat-baseline");
        let (sw, bw) = (s.sessions.unwrap(), b.sessions.unwrap());
        assert!(!bw.share);
        // identical trace parameters -> identical token traffic
        assert_eq!(bw.seed, sw.seed);
        assert_eq!(bw.fork_frac, sw.fork_frac);
        assert_eq!(bw.n_templates, sw.n_templates);
        assert_eq!(b.sat_budget_bytes, s.sat_budget_bytes);
    }

    #[test]
    fn misaligned_session_chars_fail_validation() {
        let mut s = ScenarioSpec::fork_heavy_chat(1);
        if let Some(sw) = &mut s.sessions {
            sw.turn_chars = 33; // not a multiple of block_tokens = 32
        }
        let r = std::panic::catch_unwind(move || s.validate());
        assert!(r.is_err());
    }

    #[test]
    fn starlink_is_a_mega_constellation() {
        let s = ScenarioSpec::starlink_shell(1);
        assert!(s.planes >= 70, "acceptance: >= 70-plane shell");
        assert!(s.torus().len() > 1500);
        assert!(!s.failures.is_none(), "mega scenario must inject failures");
    }

    #[test]
    fn by_name_roundtrips() {
        for s in ScenarioSpec::builtin(3) {
            let again = ScenarioSpec::by_name(&s.name, 3).unwrap();
            assert_eq!(again.name, s.name);
            assert_eq!(again.planes, s.planes);
        }
        assert!(ScenarioSpec::by_name("no-such-shell", 3).is_none());
    }

    #[test]
    fn paper_spec_matches_testbed_shape() {
        let s = ScenarioSpec::paper_19x5(1);
        assert_eq!((s.planes, s.sats_per_plane), (5, 19));
        assert_eq!(s.initial_center(), SatId::new(2, 9));
        assert_eq!(s.geometry().planes, 5);
    }

    #[test]
    fn federated_dual_shell_spec_is_sound() {
        let f = FederatedScenarioSpec::federated_dual_shell(7);
        f.validate();
        assert_eq!(f.shells.len(), 2);
        assert_eq!(f.shells[0].torus().len(), 72 * 22);
        assert_eq!(f.shells[1].torus().len(), 34 * 34);
        // Kuiper's denser planes make it the cost-primary
        assert_eq!(f.primary_shell_index(), 1);
        assert!(f.primary_kill_epoch > 0 && f.primary_kill_epoch < f.epochs);
        // a block must fan out over the whole stripe
        let payload = f.quantizer.encoded_len(f.kv_values_per_block);
        assert!(payload.div_ceil(f.chunk_size) >= f.n_servers);
        let again = FederatedScenarioSpec::by_name("federated-dual-shell", 7).unwrap();
        assert_eq!(again.shells[0].name, f.shells[0].name);
        assert!(FederatedScenarioSpec::by_name("no-such-federation", 7).is_none());
    }

    #[test]
    fn federated_tri_shell_spec_is_sound() {
        let f = FederatedScenarioSpec::federated_tri_shell(7);
        f.validate();
        assert_eq!(f.shells.len(), 3);
        // Kuiper's denser planes keep it cost-primary; the polar shell is
        // the most expensive (highest altitude at equal stripe width)
        assert_eq!(f.primary_shell_index(), 1);
        let layouts = f.shell_layouts();
        assert_eq!(layouts[0].strategy, Strategy::RotationHopAware);
        assert_eq!(layouts[2].strategy, Strategy::RotationAware, "per-shell override");
        assert_eq!(layouts[2].n_servers, 9);
        // replication + pre-placement are on; the correlated plan covers
        // all three failure kinds, storm aimed at the primary
        assert!(f.replicate_top_k > 0);
        assert!(f.preplace);
        assert_eq!(f.correlated.len(), 3);
        assert!(f
            .correlated
            .iter()
            .any(|c| matches!(c, CorrelatedFailure::SolarStorm { shell: 1, .. })));
        assert!(f.correlated.iter().all(|c| c.epoch() > 0 && c.epoch() < f.epochs));
        let again = FederatedScenarioSpec::by_name("federated-tri-shell", 7).unwrap();
        assert_eq!(again.shells[2].name, "polar-1200");
    }

    #[test]
    fn rehoming_baseline_disables_replication_only() {
        let f = FederatedScenarioSpec::federated_tri_shell(5);
        let b = f.rehoming_baseline();
        b.validate();
        assert_eq!(b.name, "federated-tri-shell-rehoming");
        assert_eq!(b.shells.len(), 3, "same shells");
        assert_eq!(b.correlated.len(), f.correlated.len(), "same correlated plan");
        assert_eq!(b.replicate_top_k, 0);
        assert!(!b.preplace);
        assert_eq!(b.seed, f.seed);
    }

    #[test]
    fn single_shell_baseline_remaps_correlated_events() {
        let f = FederatedScenarioSpec::federated_tri_shell(5);
        let b = f.baseline_single_shell();
        b.validate();
        assert_eq!(b.shells.len(), 1);
        assert_eq!(b.shells[0].name, "kuiper-630");
        // only the storm aimed at the primary survives, re-aimed at 0
        assert_eq!(b.correlated.len(), 1);
        assert!(matches!(
            b.correlated[0],
            CorrelatedFailure::SolarStorm { shell: 0, epoch: 3, half_width: 2 }
        ));
        assert_eq!(b.replicate_top_k, 0, "one shell has nothing to replicate onto");
    }

    #[test]
    fn federated_baseline_keeps_only_the_primary() {
        let f = FederatedScenarioSpec::federated_dual_shell(3);
        let b = f.baseline_single_shell();
        b.validate();
        assert_eq!(b.shells.len(), 1);
        assert_eq!(b.shells[0].name, "kuiper-630");
        assert_eq!(b.primary_shell_index(), 0);
        assert_eq!(b.primary_kill_epoch, f.primary_kill_epoch);
        assert_eq!(b.name, "federated-dual-shell-baseline");
    }

    #[test]
    fn stripes_fan_out_across_all_servers() {
        // each built-in spec must produce at least n_servers chunks per
        // block, so a single block exercises the whole stripe
        for s in ScenarioSpec::builtin(1) {
            let payload = s.quantizer.encoded_len(s.kv_values_per_block);
            let chunks = payload.div_ceil(s.chunk_size);
            assert!(
                chunks >= s.n_servers,
                "{}: {} chunks < {} servers",
                s.name,
                chunks,
                s.n_servers
            );
        }
    }
}
