//! Rotation-aware mapping (§3.5, Figures 4/5/13): servers are numbered
//! left-to-right, top-to-bottom across the LOS grid.  Best when the ground
//! host has reliable direct links to every LOS satellite; migration moves
//! the exiting east column to the entering west column each epoch.

use super::box_side;
use crate::constellation::los::LosGrid;
use crate::constellation::topology::{SatId, Torus};

/// Row-major layout over the square `ceil(sqrt(n))` LOS box.
pub fn layout(torus: &Torus, center: SatId, n_servers: usize) -> Vec<SatId> {
    let grid = LosGrid::square_for_servers(center, n_servers);
    layout_in_box(torus, &grid, n_servers)
}

/// Row-major layout over an arbitrary LOS window (e.g. the real,
/// non-square visibility footprint of Fig. 4's 5x3 grid).
pub fn layout_in_box(torus: &Torus, grid: &LosGrid, n_servers: usize) -> Vec<SatId> {
    assert!(
        n_servers <= grid.cell_count(),
        "{n_servers} servers do not fit a {}x{} LOS grid",
        grid.width(),
        grid.height()
    );
    let mut cells = grid.cells_row_major(torus);
    // Server 1 must be the closest satellite (§3.8 step 6). Row-major
    // numbering puts the NW corner first; the paper's figures number the
    // grid row-major and the protocol locates the rest from whichever
    // server answers first, so we rotate the ordering so the centre cell
    // is server 1 while preserving row-major succession — then truncate
    // to the requested server count.
    let centre_idx = cells.iter().position(|s| *s == grid.center);
    if let Some(i) = centre_idx {
        cells.rotate_left(i);
    }
    cells.truncate(n_servers);
    cells
}

/// Row-major numbering exactly as printed in Figure 13 (NW corner = 1),
/// used by the figure reproduction and the golden tests.
pub fn figure13_grid(n_servers: usize) -> Vec<Vec<u32>> {
    let side = box_side(n_servers);
    let mut out = vec![vec![0u32; side]; side];
    let mut id = 1u32;
    for row in out.iter_mut() {
        for cell in row.iter_mut() {
            *cell = id;
            id += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure13_golden_3x3() {
        assert_eq!(
            figure13_grid(9),
            vec![vec![1, 2, 3], vec![4, 5, 6], vec![7, 8, 9]]
        );
    }

    #[test]
    fn figure13_golden_5x5() {
        assert_eq!(
            figure13_grid(25),
            vec![
                vec![1, 2, 3, 4, 5],
                vec![6, 7, 8, 9, 10],
                vec![11, 12, 13, 14, 15],
                vec![16, 17, 18, 19, 20],
                vec![21, 22, 23, 24, 25],
            ]
        );
    }

    #[test]
    fn figure13_golden_7x7_and_9x9_corners() {
        let g7 = figure13_grid(49);
        assert_eq!(g7[0][0], 1);
        assert_eq!(g7[0][6], 7);
        assert_eq!(g7[6][0], 43);
        assert_eq!(g7[6][6], 49);
        let g9 = figure13_grid(81);
        assert_eq!(g9[0], vec![1, 2, 3, 4, 5, 6, 7, 8, 9]);
        assert_eq!(g9[8][8], 81);
    }

    #[test]
    fn layout_covers_los_box_row_major() {
        let torus = Torus::new(15, 15);
        let center = SatId::new(8, 8);
        let l = layout(&torus, center, 9);
        assert_eq!(l.len(), 9);
        assert_eq!(l[0], center);
        // all cells within the 3x3 box around centre
        for s in &l {
            assert!(torus.plane_distance(center, *s) <= 1);
            assert!(torus.slot_distance(center, *s) <= 1);
        }
    }

    #[test]
    fn non_square_box_supported() {
        let torus = Torus::new(15, 15);
        let grid = LosGrid::new(SatId::new(8, 8), 2, 1); // 5 wide, 3 tall — Fig 4
        let l = layout_in_box(&torus, &grid, 15);
        assert_eq!(l.len(), 15);
        let uniq: std::collections::HashSet<_> = l.iter().collect();
        assert_eq!(uniq.len(), 15);
        assert_eq!(l[0], SatId::new(8, 8));
    }

    #[test]
    #[should_panic(expected = "do not fit")]
    fn overflow_panics() {
        let torus = Torus::new(15, 15);
        let grid = LosGrid::new(SatId::new(8, 8), 1, 1);
        layout_in_box(&torus, &grid, 10);
    }
}
