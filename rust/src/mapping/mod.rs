//! Chunk-to-server mappings (paper §3.4–§3.7) and rotation migration.
//!
//! "Servers" are *virtual* chunk destinations: chunk `i` of a block is
//! stored on server `i mod n` (§3.1), and a mapping assigns server ids
//! (1-based, server 1 = fewest hops) to physical satellites.  The paper
//! gives three mappings:
//!
//! * [`rotation_aware`] — row-major over the LOS grid (Fig. 4/13); best
//!   when the ground host reaches every LOS satellite directly.
//! * [`hop_aware`] — concentric rings (BFS) around a fixed satellite on
//!   the torus (Fig. 6/14); best for an LLM hosted *on* that satellite.
//! * [`rot_hop_aware`] — BFS rings bounded by the √n-sided LOS box
//!   (Fig. 7/8/15); the paper's recommended ground-host mapping.
//!
//! The BFS rule (breadth-first from the centre, pushing unvisited
//! neighbours in N, E, S, W order) reproduces the published Figures 14/15
//! grids *exactly*; the golden tests below pin all of them.
//!
//! Rotation handling: rotation-aware layouts migrate their exiting east
//! column to the entering west column each epoch (Fig. 5/8), which is a
//! cyclic shift of the layout pattern *within* its box — a chunk on a
//! satellite that stays in the box never moves.  Hop-aware layouts never
//! migrate and instead pay a growing hop distance as the centre drifts.

pub mod grid_fmt;
pub mod hop_aware;
pub mod migration;
pub mod rot_hop_aware;
pub mod rotation_aware;

use crate::constellation::topology::{SatId, Torus};


/// The three §3.4 mapping strategies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    RotationAware,
    HopAware,
    RotationHopAware,
}

impl Strategy {
    pub const ALL: [Strategy; 3] =
        [Strategy::RotationAware, Strategy::HopAware, Strategy::RotationHopAware];

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::RotationAware => "rotation-aware",
            Strategy::HopAware => "hop-aware",
            Strategy::RotationHopAware => "rotation-and-hop-aware",
        }
    }

    /// Parse a strategy name (canonical or the CLI short forms).
    pub fn from_name(name: &str) -> Option<Strategy> {
        match name {
            "rot" | "rotation" | "rotation-aware" => Some(Strategy::RotationAware),
            "hop" | "hop-aware" => Some(Strategy::HopAware),
            "rot-hop" | "rotation-hop" | "rotation-and-hop-aware" => {
                Some(Strategy::RotationHopAware)
            }
            _ => None,
        }
    }

    /// Does this mapping migrate chunks to follow the ground host?
    pub fn migrates(&self) -> bool {
        !matches!(self, Strategy::HopAware)
    }

    /// Server-id -> satellite at write time, centred on `center`.
    pub fn initial_layout(&self, torus: &Torus, center: SatId, n_servers: usize) -> Vec<SatId> {
        match self {
            Strategy::RotationAware => rotation_aware::layout(torus, center, n_servers),
            Strategy::HopAware => hop_aware::layout(torus, center, n_servers),
            Strategy::RotationHopAware => rot_hop_aware::layout(torus, center, n_servers),
        }
    }

    /// Layout after `epochs` rotation epochs (§3.8 step 8: "based on that
    /// the shift ... is found, and the server for all other chunks can be
    /// computed"): entirely client-side, no satellite is queried.
    pub fn layout_at(
        &self,
        torus: &Torus,
        write_center: SatId,
        n_servers: usize,
        epochs: u64,
    ) -> Vec<SatId> {
        let initial = self.initial_layout(torus, write_center, n_servers);
        if !self.migrates() || epochs == 0 {
            return initial;
        }
        migration::shift_layout(torus, &initial, write_center, box_width(n_servers), epochs)
    }
}

/// Side of the square bounding box for `n` servers (§3.7: ceil(sqrt(n))).
pub fn box_side(n_servers: usize) -> usize {
    (n_servers as f64).sqrt().ceil() as usize
}

/// Effective (odd) width of the centred LOS box actually used: a box is
/// centred on the closest satellite, so even `ceil(sqrt(n))` rounds up to
/// the next odd width (matches [`LosGrid::square_for_servers`]).
pub fn box_width(n_servers: usize) -> usize {
    let side = box_side(n_servers);
    2 * (side / 2) + 1
}

/// Breadth-first enumeration of torus cells from `center`, pushing
/// neighbours in the paper's N, E, S, W order.  `admit` filters cells
/// (e.g. the LOS bounding box); the centre is always admitted.
pub fn bfs_order<F>(torus: &Torus, center: SatId, limit: usize, mut admit: F) -> Vec<SatId>
where
    F: FnMut(SatId) -> bool,
{
    let mut order = Vec::with_capacity(limit);
    let mut visited = vec![false; torus.len()];
    let mut queue = std::collections::VecDeque::new();
    visited[center.linear(torus.sats_per_plane)] = true;
    queue.push_back(center);
    while let Some(cur) = queue.pop_front() {
        order.push(cur);
        if order.len() == limit {
            break;
        }
        for nb in torus.neighbors(cur) {
            let idx = nb.linear(torus.sats_per_plane);
            if !visited[idx] && admit(nb) {
                visited[idx] = true;
                queue.push_back(nb);
            }
        }
    }
    order
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn box_side_matches_paper_grids() {
        for (n, side) in [(9, 3), (25, 5), (49, 7), (81, 9), (10, 4), (2, 2)] {
            assert_eq!(box_side(n), side, "n={n}");
        }
    }

    #[test]
    fn strategies_have_names_and_migration_flags() {
        assert!(Strategy::RotationAware.migrates());
        assert!(!Strategy::HopAware.migrates());
        assert!(Strategy::RotationHopAware.migrates());
        let names: Vec<_> = Strategy::ALL.iter().map(|s| s.name()).collect();
        assert_eq!(names.len(), 3);
    }

    #[test]
    fn every_strategy_layout_has_unique_sats() {
        let torus = Torus::new(15, 15);
        let center = SatId::new(8, 8);
        for st in Strategy::ALL {
            for n in [1, 9, 25, 49, 81] {
                let l = st.initial_layout(&torus, center, n);
                assert_eq!(l.len(), n, "{:?} n={n}", st);
                let set: std::collections::HashSet<_> = l.iter().collect();
                assert_eq!(set.len(), n, "{:?} n={n}: duplicate satellites", st);
                assert_eq!(l[0], center, "server 1 must be the closest satellite");
            }
        }
    }

    #[test]
    fn bfs_order_is_distance_monotone() {
        let torus = Torus::new(15, 15);
        let center = SatId::new(7, 7);
        let order = bfs_order(&torus, center, 60, |_| true);
        // BFS visits by non-decreasing ring distance: each cell is at
        // least as far from the centre as every cell before it
        let mut prev = 0;
        for s in &order {
            let d = torus.hops(center, *s);
            assert!(d >= prev, "BFS must be ring-ordered: {s} at {d} after ring {prev}");
            prev = d;
        }
        // ring populations on an open grid: 1, 4, 8, 12...
        assert_eq!(torus.hops(center, order[0]), 0);
        for i in 1..=4 {
            assert_eq!(torus.hops(center, order[i]), 1);
        }
        for i in 5..=12 {
            assert_eq!(torus.hops(center, order[i]), 2);
        }
    }
}
