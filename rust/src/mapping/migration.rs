//! Rotation migration (§3.4, Figures 5/8/9): when the east column of a
//! rotation-aware layout is about to leave LOS, its chunks are copied to
//! the column entering on the west — per plane, in parallel.  A chunk on a
//! satellite that remains inside the (moving) box never moves, so each
//! epoch the layout *pattern* cyclically shifts one column within the box.
//!
//! Because rotation is deterministic, the layout after `k` epochs is a
//! closed-form function of the write-time layout (paper Fig. 10: "rotations
//! are predictable based on knowing the time of block creation") — no
//! satellite needs to be asked where a chunk lives now.

use crate::constellation::topology::{SatId, Torus};


/// One chunk-column relocation of a migration epoch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MigrationMove {
    /// 1-based server id whose chunks move.
    pub server: u32,
    pub from: SatId,
    pub to: SatId,
}

/// Closed-form layout after `epochs` west-shifts of a box of width
/// `box_width` whose centre started at `write_center`.
///
/// For a server at write-time offset `(dp, ds)` from the write centre, the
/// satellite it occupies after `k` epochs sits at offset
/// `(dp, ((ds + half + k) mod w) - half)` from the *current* centre.
pub fn shift_layout(
    torus: &Torus,
    initial: &[SatId],
    write_center: SatId,
    box_width: usize,
    epochs: u64,
) -> Vec<SatId> {
    let w = box_width as i64;
    let half = (box_width as i64 - 1) / 2;
    // the centre wraps with the orbit; the pattern cycles with the box
    let k_center = (epochs % torus.sats_per_plane as u64) as i32;
    let k_box = (epochs % box_width as u64) as i64;
    let current_center = torus.offset(write_center, 0, -k_center);
    initial
        .iter()
        .map(|sat| {
            let (dp, ds) = torus.signed_offset(write_center, *sat);
            let eff = (ds as i64 + half + k_box).rem_euclid(w) - half;
            torus.offset(current_center, dp, eff as i32)
        })
        .collect()
}

/// The §3.4 per-epoch rotation handoff pairs for a layout box of
/// `n_servers` centred on `center`: each satellite of the exiting east
/// column hands its chunks to the matching satellite of the entering
/// west column, per plane.  Shared by the single-shell and federated KVC
/// managers so their rotation semantics cannot diverge.
pub fn rotation_handoff_pairs(
    torus: &Torus,
    center: SatId,
    n_servers: usize,
) -> Vec<(SatId, SatId)> {
    let half = (super::box_width(n_servers) as i32 - 1) / 2;
    let new_center = torus.offset(center, 0, -1);
    let mut out = Vec::with_capacity(2 * half as usize + 1);
    for dp in -half..=half {
        out.push((torus.offset(center, dp, half), torus.offset(new_center, dp, -half)));
    }
    out
}

/// The chunk relocations needed to go from epoch `k` to `k + 1` for a
/// migrating strategy: exactly the servers whose satellite leaves the box.
pub fn migration_plan(
    torus: &Torus,
    strategy: super::Strategy,
    write_center: SatId,
    n_servers: usize,
    from_epoch: u64,
) -> Vec<MigrationMove> {
    let before = strategy.layout_at(torus, write_center, n_servers, from_epoch);
    let after = strategy.layout_at(torus, write_center, n_servers, from_epoch + 1);
    before
        .iter()
        .zip(after.iter())
        .enumerate()
        .filter(|(_, (b, a))| b != a)
        .map(|(i, (b, a))| MigrationMove { server: (i + 1) as u32, from: *b, to: *a })
        .collect()
}

/// Group a migration plan by orbital plane — §3.4: "This can be done in
/// parallel in each orbital plane."
pub fn by_plane(plan: &[MigrationMove]) -> std::collections::BTreeMap<u16, Vec<MigrationMove>> {
    let mut map: std::collections::BTreeMap<u16, Vec<MigrationMove>> = Default::default();
    for m in plan {
        map.entry(m.from.plane).or_default().push(*m);
    }
    map
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::Strategy;

    fn setup() -> (Torus, SatId) {
        (Torus::new(5, 5), SatId::new(2, 3)) // Fig 7/8: 5 planes x 5 slots
    }

    #[test]
    fn figure8_migration_case() {
        // Fig 8 (1-based figure coords -> our 0-based): centre is satellite
        // 4 in plane 3 = (plane 2, slot 3).  Chunks 6, 3, 8 sit on slot 5
        // (= slot index 4) in planes 2, 3, 4 (= 1, 2, 3) and migrate to
        // slot 2 (= index 1), same planes.
        let (torus, c) = setup();
        let plan = migration_plan(&torus, Strategy::RotationHopAware, c, 9, 0);
        assert_eq!(plan.len(), 3, "only the exiting column moves");
        for m in &plan {
            assert_eq!(m.from.slot, 4, "from the east column");
            assert_eq!(m.to.slot, 1, "to the entering west column");
            assert_eq!(m.from.plane, m.to.plane, "within the same plane");
        }
        // Exactly the paper's three servers: 6 at (5,2), 3 at (5,3), 8 at (5,4)
        let mut servers: Vec<u32> = plan.iter().map(|m| m.server).collect();
        servers.sort_unstable();
        assert_eq!(servers, vec![3, 6, 8]);
        let by = by_plane(&plan);
        assert_eq!(by.len(), 3, "one parallel migration per plane");
    }

    #[test]
    fn stayers_do_not_move() {
        let (torus, c) = setup();
        let before = Strategy::RotationHopAware.layout_at(&torus, c, 9, 0);
        let after = Strategy::RotationHopAware.layout_at(&torus, c, 9, 1);
        // servers whose satellite is NOT on the exiting column keep it
        for (i, (b, a)) in before.iter().zip(after.iter()).enumerate() {
            if b.slot != 4 {
                assert_eq!(b, a, "server {} should not move", i + 1);
            }
        }
    }

    #[test]
    fn layouts_remain_duplicate_free_over_time() {
        let (torus, c) = setup();
        for st in [Strategy::RotationAware, Strategy::RotationHopAware] {
            for k in 0..12 {
                let l = st.layout_at(&torus, c, 9, k);
                let uniq: std::collections::HashSet<_> = l.iter().collect();
                assert_eq!(uniq.len(), l.len(), "{:?} epoch {k}", st);
            }
        }
    }

    #[test]
    fn full_wrap_restores_pattern() {
        // The pattern restores when both the torus (5 slots) and the box
        // (3 columns) complete whole cycles: lcm(5, 3) = 15 epochs.
        let (torus, c) = setup();
        let l0 = Strategy::RotationHopAware.layout_at(&torus, c, 9, 0);
        let l15 = Strategy::RotationHopAware.layout_at(&torus, c, 9, 15);
        assert_eq!(l0, l15);
        // ... and a plain orbit wrap alone restores the *satellite set*
        // but cycles the pattern inside the box.
        let l5 = Strategy::RotationHopAware.layout_at(&torus, c, 9, 5);
        let set0: std::collections::HashSet<_> = l0.iter().collect();
        let set5: std::collections::HashSet<_> = l5.iter().collect();
        assert_eq!(set0, set5);
        assert_ne!(l0, l5);
    }

    #[test]
    fn hop_aware_never_migrates() {
        let (torus, c) = setup();
        let plan = migration_plan(&torus, Strategy::HopAware, c, 9, 0);
        assert!(plan.is_empty());
        assert_eq!(
            Strategy::HopAware.layout_at(&torus, c, 9, 0),
            Strategy::HopAware.layout_at(&torus, c, 9, 7),
        );
    }

    #[test]
    fn rotation_aware_migrates_full_column_every_epoch() {
        // a torus wider than the box, so a column really exits LOS
        let torus = Torus::new(7, 9);
        let c = SatId::new(3, 4);
        let plan = migration_plan(&torus, Strategy::RotationAware, c, 25, 0);
        // 5x5 box: the exiting column holds 5 servers
        assert_eq!(plan.len(), 5);
        for m in &plan {
            assert_eq!(m.from.plane, m.to.plane);
        }
    }

    #[test]
    fn box_as_wide_as_torus_never_migrates() {
        // 5x5 box on a 5-slot torus: nothing ever leaves LOS, so the
        // migration plan is empty and the layout is epoch-invariant.
        let (torus, c) = setup();
        assert!(migration_plan(&torus, Strategy::RotationAware, c, 25, 0).is_empty());
        assert_eq!(
            Strategy::RotationAware.layout_at(&torus, c, 25, 0),
            Strategy::RotationAware.layout_at(&torus, c, 25, 3),
        );
    }

    #[test]
    fn layout_at_is_consistent_with_chained_migrations() {
        let (torus, c) = setup();
        let st = Strategy::RotationHopAware;
        let mut layout = st.layout_at(&torus, c, 25, 0);
        for k in 0..7 {
            let plan = migration_plan(&torus, st, c, 25, k);
            for m in &plan {
                layout[(m.server - 1) as usize] = m.to;
            }
            assert_eq!(layout, st.layout_at(&torus, c, 25, k + 1), "epoch {k}");
        }
    }
}
