//! Rotation-and-hop-aware mapping (§3.7, Figures 7/8/15): concentric BFS
//! rings like [`super::hop_aware`], but bounded by the square LOS box of
//! side `ceil(sqrt(n_servers))` centred on the closest satellite.  The box
//! migrates with the rotation (entering-west-column handoff), so chunks
//! stay reachable in few hops from the ground host — the paper's best
//! strategy in Figure 16.

use super::{bfs_order, box_side};
use crate::constellation::los::LosGrid;
use crate::constellation::topology::{SatId, Torus};

/// Bounded concentric-ring layout.
pub fn layout(torus: &Torus, center: SatId, n_servers: usize) -> Vec<SatId> {
    let grid = LosGrid::square_for_servers(center, n_servers);
    layout_in_box(torus, &grid, n_servers)
}

/// Bounded BFS within an arbitrary LOS window.
pub fn layout_in_box(torus: &Torus, grid: &LosGrid, n_servers: usize) -> Vec<SatId> {
    assert!(
        n_servers <= grid.cell_count().min(torus.len()),
        "{n_servers} servers do not fit a {}x{} LOS box",
        grid.width(),
        grid.height()
    );
    bfs_order(torus, grid.center, n_servers, |s| grid.contains(torus, s))
}

/// The grid exactly as printed in Figure 15: `side x side` rows of 1-based
/// server ids (row-major, north-west first).
pub fn figure15_grid(n_servers: usize) -> Vec<Vec<u32>> {
    let side = box_side(n_servers);
    // A torus comfortably larger than the box so no wrap interferes.
    let dim = (2 * side + 3).max(8);
    let torus = Torus::new(dim, dim);
    let center = SatId::new((dim / 2) as u16, (dim / 2) as u16);
    let l = layout(&torus, center, n_servers);
    let half = (side as i32 - 1) / 2;
    let mut out = vec![vec![0u32; side]; side];
    for (i, sat) in l.iter().enumerate() {
        let (dp, ds) = torus.signed_offset(center, *sat);
        out[(dp + half) as usize][(ds + half) as usize] = (i + 1) as u32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure15_golden_5x5() {
        // Verbatim from the paper's Figure 15 (5x5 panel).
        assert_eq!(
            figure15_grid(25),
            vec![
                vec![23, 15, 6, 14, 22],
                vec![17, 8, 2, 7, 16],
                vec![13, 5, 1, 3, 9],
                vec![21, 12, 4, 10, 18],
                vec![25, 20, 11, 19, 24],
            ]
        );
    }

    #[test]
    fn figure15_golden_7x7() {
        // Verbatim from the paper's Figure 15 (7x7 panel).
        assert_eq!(
            figure15_grid(49),
            vec![
                vec![47, 39, 27, 14, 26, 38, 46],
                vec![41, 29, 16, 6, 15, 28, 40],
                vec![31, 18, 8, 2, 7, 17, 30],
                vec![25, 13, 5, 1, 3, 9, 19],
                vec![37, 24, 12, 4, 10, 20, 32],
                vec![45, 36, 23, 11, 21, 33, 42],
                vec![49, 44, 35, 22, 34, 43, 48],
            ]
        );
    }

    #[test]
    fn figure15_golden_9x9() {
        // Verbatim from the paper's Figure 15 (9x9 panel).
        assert_eq!(
            figure15_grid(81),
            vec![
                vec![79, 71, 59, 43, 26, 42, 58, 70, 78],
                vec![73, 61, 45, 28, 14, 27, 44, 60, 72],
                vec![63, 47, 30, 16, 6, 15, 29, 46, 62],
                vec![49, 32, 18, 8, 2, 7, 17, 31, 48],
                vec![41, 25, 13, 5, 1, 3, 9, 19, 33],
                vec![57, 40, 24, 12, 4, 10, 20, 34, 50],
                vec![69, 56, 39, 23, 11, 21, 35, 51, 64],
                vec![77, 68, 55, 38, 22, 36, 52, 65, 74],
                vec![81, 76, 67, 54, 37, 53, 66, 75, 80],
            ]
        );
    }

    #[test]
    fn figure15_golden_3x3() {
        // Verbatim from the paper's Figure 15 (3x3 panel).
        assert_eq!(
            figure15_grid(9),
            vec![vec![7, 2, 6], vec![5, 1, 3], vec![9, 4, 8]],
        );
    }

    #[test]
    fn bounded_layout_stays_in_box() {
        let torus = Torus::new(15, 15);
        let c = SatId::new(8, 8);
        for n in [9, 25, 49, 81] {
            let side = box_side(n) as usize;
            let half = side / 2;
            for s in layout(&torus, c, n) {
                assert!(torus.plane_distance(c, s) <= half);
                assert!(torus.slot_distance(c, s) <= half);
            }
        }
    }

    #[test]
    fn max_hops_lower_than_rotation_aware_tail() {
        // The whole point of concentric numbering: low server ids sit close
        // to the centre, so a partially-used layout (few chunks) stays
        // near.  With 81 servers but only 20 used, the rot-hop max distance
        // must be < the row-major max distance.
        let torus = Torus::new(15, 15);
        let c = SatId::new(8, 8);
        let rh = layout(&torus, c, 81);
        let ra = super::super::rotation_aware::layout(&torus, c, 81);
        let max_d = |l: &[SatId]| l.iter().take(20).map(|s| torus.hops(c, *s)).max().unwrap();
        assert!(max_d(&rh) < max_d(&ra), "{} vs {}", max_d(&rh), max_d(&ra));
    }
}
