//! Render layouts as the paper's figure grids (Figures 4, 6, 13–15) for
//! the `paper_figures` example and human inspection.

use crate::constellation::topology::{SatId, Torus};

/// Project a layout onto a `(2*half_planes+1) x (2*half_slots+1)` window
/// around `center`; `None` marks cells without a server.
pub fn project(
    torus: &Torus,
    layout: &[SatId],
    center: SatId,
    half_slots: usize,
    half_planes: usize,
) -> Vec<Vec<Option<u32>>> {
    let w = 2 * half_slots + 1;
    let h = 2 * half_planes + 1;
    let mut out = vec![vec![None; w]; h];
    for (i, sat) in layout.iter().enumerate() {
        let (dp, ds) = torus.signed_offset(center, *sat);
        if dp.unsigned_abs() as usize <= half_planes && ds.unsigned_abs() as usize <= half_slots {
            let r = (dp + half_planes as i32) as usize;
            let c = (ds + half_slots as i32) as usize;
            // first server wins if several land in one cell (can only
            // happen for drifted hop-aware views)
            if out[r][c].is_none() {
                out[r][c] = Some((i + 1) as u32);
            }
        }
    }
    out
}

/// Pretty-print a projected grid in the figures' style.
pub fn to_string(grid: &[Vec<Option<u32>>]) -> String {
    let width = grid
        .iter()
        .flatten()
        .flatten()
        .map(|v| v.to_string().len())
        .max()
        .unwrap_or(1);
    let mut s = String::new();
    for row in grid {
        for (i, cell) in row.iter().enumerate() {
            if i > 0 {
                s.push(' ');
            }
            match cell {
                Some(v) => s.push_str(&format!("{v:>width$}")),
                None => s.push_str(&" ".repeat(width).replace(' ', ".").to_string()),
            }
        }
        s.push('\n');
    }
    s
}

/// CSV form (row per grid row, empty cells blank) for results/ files.
pub fn to_csv(grid: &[Vec<Option<u32>>]) -> String {
    grid.iter()
        .map(|row| {
            row.iter()
                .map(|c| c.map(|v| v.to_string()).unwrap_or_default())
                .collect::<Vec<_>>()
                .join(",")
        })
        .collect::<Vec<_>>()
        .join("\n")
        + "\n"
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::Strategy;

    #[test]
    fn project_rot_hop_5x5_matches_golden() {
        let torus = Torus::new(15, 15);
        let c = SatId::new(8, 8);
        let l = Strategy::RotationHopAware.initial_layout(&torus, c, 25);
        let grid = project(&torus, &l, c, 2, 2);
        let want = crate::mapping::rot_hop_aware::figure15_grid(25);
        for (r, row) in want.iter().enumerate() {
            for (cidx, v) in row.iter().enumerate() {
                assert_eq!(grid[r][cidx], Some(*v));
            }
        }
    }

    #[test]
    fn hop_aware_projection_has_empty_corners() {
        let torus = Torus::new(15, 15);
        let c = SatId::new(8, 8);
        let l = Strategy::HopAware.initial_layout(&torus, c, 13); // rings 0-2
        let grid = project(&torus, &l, c, 2, 2);
        assert_eq!(grid[0][0], None, "diamond leaves corners empty");
        assert_eq!(grid[2][2], Some(1));
    }

    #[test]
    fn to_string_and_csv_render() {
        let torus = Torus::new(15, 15);
        let c = SatId::new(8, 8);
        let l = Strategy::RotationHopAware.initial_layout(&torus, c, 9);
        let grid = project(&torus, &l, c, 1, 1);
        let s = to_string(&grid);
        assert_eq!(s.lines().count(), 3);
        assert!(s.contains('1'));
        let csv = to_csv(&grid);
        assert_eq!(csv.trim().lines().count(), 3);
        assert_eq!(csv.lines().next().unwrap().split(',').count(), 3);
    }
}
