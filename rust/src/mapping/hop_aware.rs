//! Hop-aware mapping (§3.6, Figures 6/14): servers spiral out from a fixed
//! satellite in concentric rings — breadth-first over the +GRID torus with
//! neighbours pushed in N, E, S, W order.  Best when the LLM is hosted *on*
//! that satellite (no migration: the host and the cache co-rotate).

use super::bfs_order;
use crate::constellation::topology::{SatId, Torus};

/// Concentric-ring layout on the torus, unbounded (Fig. 6's "diamond").
pub fn layout(torus: &Torus, center: SatId, n_servers: usize) -> Vec<SatId> {
    assert!(
        n_servers <= torus.len(),
        "{n_servers} servers exceed the {}-satellite constellation",
        torus.len()
    );
    bfs_order(torus, center, n_servers, |_| true)
}

/// The diamond as printed in Figure 14: a map from (slot_offset,
/// plane_offset) relative to the centre to the 1-based server id.
pub fn figure14_diamond(
    torus: &Torus,
    center: SatId,
    n_servers: usize,
) -> std::collections::HashMap<(i32, i32), u32> {
    layout(torus, center, n_servers)
        .into_iter()
        .enumerate()
        .map(|(i, sat)| {
            let (dp, ds) = torus.signed_offset(center, sat);
            ((ds, dp), (i + 1) as u32)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Torus, SatId) {
        (Torus::new(15, 15), SatId::new(8, 8))
    }

    #[test]
    fn figure14_golden_rings_1_and_2() {
        // Derived in DESIGN.md from the published 5x5 grids:
        // ring 1: N=2, E=3, S=4, W=5; ring 2: NN=6, NE=7, NW=8, EE=9,
        // SE=10, SS=11, SW=12, WW=13.
        let (torus, c) = setup();
        let d = figure14_diamond(&torus, c, 25);
        let expect = [
            ((0, 0), 1),
            ((0, -1), 2),
            ((1, 0), 3),
            ((0, 1), 4),
            ((-1, 0), 5),
            ((0, -2), 6),
            ((1, -1), 7),
            ((-1, -1), 8),
            ((2, 0), 9),
            ((1, 1), 10),
            ((0, 2), 11),
            ((-1, 1), 12),
            ((-2, 0), 13),
        ];
        for ((ds, dp), id) in expect {
            assert_eq!(d.get(&(ds, dp)), Some(&id), "offset ({ds},{dp})");
        }
    }

    #[test]
    fn figure14_golden_25_server_diamond() {
        // The full Figure 14 5x5 diamond (paper page 20), rows top-down:
        //             14
        //         16   6  15
        //     18   8   2   7  17
        // 25  13   5   1   3   9  19
        //     24  12   4  10  20
        //         23  11  21
        //             22
        let (torus, c) = setup();
        let d = figure14_diamond(&torus, c, 25);
        let rows: [(&[u32], i32); 7] = [
            (&[14], -3),
            (&[16, 6, 15], -2),
            (&[18, 8, 2, 7, 17], -1),
            (&[25, 13, 5, 1, 3, 9, 19], 0),
            (&[24, 12, 4, 10, 20], 1),
            (&[23, 11, 21], 2),
            (&[22], 3),
        ];
        for (row, dp) in rows {
            let half = (row.len() as i32 - 1) / 2;
            for (j, want) in row.iter().enumerate() {
                let ds = j as i32 - half;
                assert_eq!(d.get(&(ds, dp)), Some(want), "row dp={dp} ds={ds}");
            }
        }
    }

    #[test]
    fn ring_sizes_follow_manhattan_counts() {
        let (torus, c) = setup();
        let l = layout(&torus, c, 41); // rings 0..4 on an open grid: 1+4+8+12+16
        let ring_of = |i: usize| torus.hops(c, l[i]);
        assert_eq!(ring_of(0), 0);
        assert!((1..5).all(|i| ring_of(i) == 1));
        assert!((5..13).all(|i| ring_of(i) == 2));
        assert!((13..25).all(|i| ring_of(i) == 3));
        assert!((25..41).all(|i| ring_of(i) == 4));
    }

    #[test]
    fn wraps_on_small_torus() {
        let torus = Torus::new(3, 3);
        let l = layout(&torus, SatId::new(1, 1), 9);
        assert_eq!(l.len(), 9);
        let uniq: std::collections::HashSet<_> = l.iter().collect();
        assert_eq!(uniq.len(), 9, "must cover the whole 3x3 torus");
    }

    #[test]
    #[should_panic(expected = "exceed")]
    fn too_many_servers_panics() {
        let torus = Torus::new(3, 3);
        layout(&torus, SatId::new(0, 0), 10);
    }
}
