//! # SkyMemory
//!
//! A LEO-constellation-hosted key-value cache (KVC) for transformer
//! inference, reproducing Sandholm et al., *"SkyMemory: A LEO Edge Cache for
//! Transformer Inference Optimization and Scale Out"* (2025).
//!
//! `ARCHITECTURE.md` (repository root) is the orientation document: the
//! layer map (kvc → net → federation → sim/repro), the timing-plane vs
//! data-plane split around [`net::sched`], and how a scenario run
//! composes the stack.  `docs/METRICS.md` documents every metrics-JSON
//! key and `docs/CLI.md` the `skymemory` command surface.
//!
//! The crate is organized bottom-up:
//!
//! * [`constellation`] — orbital geometry (paper eqs. 1–4), the +GRID
//!   2D-torus ISL topology with greedy routing, rotation/LOS models.
//! * [`mapping`] — the paper's three chunk-to-server mappings
//!   (rotation-aware, hop-aware, rotation-and-hop-aware) and migration.
//! * [`kvc`] — the KVC protocol: chained block hashing, chunking,
//!   quantization codecs, the local radix block index, eviction policies,
//!   and the [`kvc::manager::KvcManager`] implementing §3.8 Get/Set.
//! * [`net`] — CCSDS Space Packet Protocol framing, binary message codecs,
//!   the [`net::transport::Transport`] abstraction (in-proc, UDP,
//!   simulated-latency), the failure-injecting
//!   [`net::faults::FaultyTransport`] decorator, and the
//!   [`net::sched::NetScheduler`] — the discrete-event *virtual-time*
//!   link scheduler (timing plane) every chunk fan-out rides: per-link
//!   in-flight windows, FIFO queueing, deterministic
//!   `(virtual_time, tag)` event ordering, zero OS threads.
//! * [`federation`] — N-shell federation: named [`federation::Shell`]s
//!   at their own altitudes, shell-qualified addresses
//!   ([`federation::FedSatId`]), inter-shell links (ground relay and
//!   nearest-neighbour cross-shell hop), per-shell layout configs and
//!   cost-based placement with spillover ([`federation::placement`]),
//!   hot-block replication across the two cheapest shells with
//!   replica-racing reads, the §3.7-style pre-placement predictor, the
//!   shell-routing [`federation::transport::FederatedTransport`], and
//!   the [`federation::manager::FederatedKvcManager`] with inter-shell
//!   handover (offset-preserving or re-striping) under whole- and
//!   partial-shell degradation.
//! * [`obs`] — the deterministic flight recorder: [`obs::TraceSink`]
//!   span/instant events stamped with `net::sched` virtual time (a
//!   zero-cost [`obs::NoopSink`] is the default), exported as byte-stable
//!   JSONL or Chrome trace-event JSON (Perfetto; shells as processes,
//!   links as threads) via `skymemory trace` — see `docs/TRACING.md`;
//!   and the memory-footprint plane ([`obs::mem`]): deterministic
//!   [`obs::mem::MemFootprint`] estimates over every cache container,
//!   sampled per epoch into each report's `memory` object
//!   (bytes per cached token, per-shell residency) and validated by the
//!   `mem-profile` counting allocator in `rust/benches/mem.rs`.
//! * [`satellite`] — the satellite node substrate (the paper's cFS stand-in):
//!   chunk store with LRU, ISL forwarding, migration, eviction gossip.
//! * [`sim`] — the §4 worst-case-latency simulator (Figure 16), workload
//!   generation, and the deterministic scenario subsystem
//!   ([`sim::scenario`] + [`sim::harness`]): named, seed-driven
//!   end-to-end runs — the paper's 19x5 testbed, a Starlink-like 72x22
//!   mega-shell, a Kuiper-like 34x34 shell, the `mega-shell`
//!   [`net::sched`] stress shape (>1000 in-flight chunks per block), and
//!   the federated `federated-dual-shell` and `federated-tri-shell`
//!   scenarios — sweeping rotation epochs with migration, eviction
//!   pressure and injected failures (satellite loss, ISL outage,
//!   ground-station handover, whole-shell degradation, and correlated
//!   plans: whole-plane loss, solar-storm bands, fractional box kills
//!   via [`net::faults::FaultyTransport`]), emitting
//!   byte-stable metrics JSON with per-link scheduler stats; plus the
//!   [`sim::diff`] scenario-diff tool.
//! * [`runtime`] — PJRT execution of the AOT artifacts (L2/L1 outputs):
//!   HLO loading, weight upload, prefill/decode steps, tokenizer, sampler.
//! * [`coordinator`] — the serving engine: prefix-cache-aware generation
//!   loop, continuous scheduler, prefix-affinity router, HTTP API, metrics.
//!
//! Python (JAX + Pallas) is build-time only; the request path is pure rust.

pub mod constellation;
pub mod coordinator;
pub mod federation;
pub mod kvc;
pub mod mapping;
pub mod net;
pub mod obs;
pub mod repro;
pub mod runtime;
pub mod satellite;
pub mod sim;
pub mod util;

pub use constellation::geometry::{Geometry, EARTH_RADIUS_KM, LIGHT_SPEED_KM_S};
pub use constellation::topology::{SatId, Torus};
pub use federation::{FedSatId, FederatedConstellation, Shell, ShellId};
pub use kvc::manager::KvcManager;
