//! Serving metrics: lock-free counters + fixed-bucket latency histograms,
//! exported in Prometheus text exposition format at `GET /metrics`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Histogram buckets (seconds) tuned for token-level latencies.
const BUCKETS_S: [f64; 12] =
    [0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5];

/// A fixed-bucket histogram.
#[derive(Debug, Default)]
pub struct Histogram {
    buckets: [AtomicU64; 12],
    count: AtomicU64,
    sum_ns: AtomicU64,
}

impl Histogram {
    pub fn observe(&self, d: Duration) {
        let s = d.as_secs_f64();
        for (i, b) in BUCKETS_S.iter().enumerate() {
            if s <= *b {
                self.buckets[i].fetch_add(1, Ordering::Relaxed);
                break;
            }
        }
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.sum_ns.load(Ordering::Relaxed) / c)
    }

    fn render(&self, name: &str, out: &mut String) {
        let mut cumulative = 0;
        for (i, b) in BUCKETS_S.iter().enumerate() {
            cumulative += self.buckets[i].load(Ordering::Relaxed);
            out.push_str(&format!("{name}_bucket{{le=\"{b}\"}} {cumulative}\n"));
        }
        out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", self.count()));
        out.push_str(&format!(
            "{name}_sum {}\n{name}_count {}\n",
            self.sum_ns.load(Ordering::Relaxed) as f64 / 1e9,
            self.count()
        ));
    }
}

/// All serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests_total: AtomicU64,
    pub requests_failed: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub prompt_tokens: AtomicU64,
    pub cache_blocks_hit: AtomicU64,
    pub cache_blocks_missed: AtomicU64,
    pub blocks_stored: AtomicU64,
    pub prefill_steps: AtomicU64,
    pub decode_steps: AtomicU64,
    pub ttft: Histogram,
    pub e2e: Histogram,
    pub decode_step: Histogram,
}

impl Metrics {
    pub fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    /// Cache hit rate over blocks.
    pub fn block_hit_rate(&self) -> f64 {
        let h = self.cache_blocks_hit.load(Ordering::Relaxed) as f64;
        let m = self.cache_blocks_missed.load(Ordering::Relaxed) as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }

    /// Prometheus text exposition.
    pub fn render(&self) -> String {
        let mut out = String::with_capacity(2048);
        let c = |name: &str, v: &AtomicU64, out: &mut String| {
            out.push_str(&format!(
                "# TYPE skymemory_{name} counter\nskymemory_{name} {}\n",
                v.load(Ordering::Relaxed)
            ));
        };
        c("requests_total", &self.requests_total, &mut out);
        c("requests_failed", &self.requests_failed, &mut out);
        c("tokens_generated", &self.tokens_generated, &mut out);
        c("prompt_tokens", &self.prompt_tokens, &mut out);
        c("cache_blocks_hit", &self.cache_blocks_hit, &mut out);
        c("cache_blocks_missed", &self.cache_blocks_missed, &mut out);
        c("blocks_stored", &self.blocks_stored, &mut out);
        c("prefill_steps", &self.prefill_steps, &mut out);
        c("decode_steps", &self.decode_steps, &mut out);
        out.push_str(&format!(
            "# TYPE skymemory_block_hit_rate gauge\nskymemory_block_hit_rate {}\n",
            self.block_hit_rate()
        ));
        self.ttft.render("skymemory_ttft_seconds", &mut out);
        self.e2e.render("skymemory_e2e_seconds", &mut out);
        self.decode_step.render("skymemory_decode_step_seconds", &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_mean() {
        let h = Histogram::default();
        h.observe(Duration::from_millis(1));
        h.observe(Duration::from_millis(3));
        h.observe(Duration::from_millis(300));
        assert_eq!(h.count(), 3);
        let mean = h.mean();
        assert!(mean > Duration::from_millis(90) && mean < Duration::from_millis(120));
        let mut s = String::new();
        h.render("x", &mut s);
        assert!(s.contains("x_bucket{le=\"0.001\"} 1"));
        assert!(s.contains("x_count 3"));
    }

    /// Pull the `{le}` bucket counts out of a rendered exposition, in
    /// declaration order, finite buckets first and `+Inf` last.
    fn bucket_counts(rendered: &str, name: &str) -> Vec<u64> {
        rendered
            .lines()
            .filter(|l| l.starts_with(&format!("{name}_bucket{{")))
            .map(|l| l.rsplit(' ').next().unwrap().parse().unwrap())
            .collect()
    }

    #[test]
    fn rendered_buckets_are_cumulative_and_monotone() {
        let h = Histogram::default();
        // One sample per finite bucket, from below each upper bound.
        for b in BUCKETS_S {
            h.observe(Duration::from_secs_f64(b * 0.9));
        }
        let mut s = String::new();
        h.render("x", &mut s);
        let counts = bucket_counts(&s, "x");
        assert_eq!(counts.len(), BUCKETS_S.len() + 1);
        // Cumulative exposition: each le bucket includes everything below it.
        let expect: Vec<u64> = (1..=BUCKETS_S.len() as u64).collect();
        assert_eq!(&counts[..BUCKETS_S.len()], &expect[..]);
        assert!(counts.windows(2).all(|w| w[0] <= w[1]), "buckets must be monotone: {counts:?}");
    }

    #[test]
    fn inf_bucket_equals_count() {
        let h = Histogram::default();
        h.observe(Duration::from_micros(100));
        h.observe(Duration::from_millis(40));
        h.observe(Duration::from_secs(10)); // beyond the largest finite bucket
        let mut s = String::new();
        h.render("x", &mut s);
        let counts = bucket_counts(&s, "x");
        assert_eq!(*counts.last().unwrap(), h.count());
        assert!(s.contains("x_bucket{le=\"+Inf\"} 3"));
        assert!(s.contains("x_count 3"));
    }

    #[test]
    fn over_largest_bucket_sample_lands_only_in_inf() {
        let h = Histogram::default();
        let largest = BUCKETS_S[BUCKETS_S.len() - 1];
        h.observe(Duration::from_secs_f64(largest * 2.0));
        let mut s = String::new();
        h.render("x", &mut s);
        let counts = bucket_counts(&s, "x");
        // Every finite bucket stays at zero; only +Inf (== count) sees it.
        assert!(counts[..BUCKETS_S.len()].iter().all(|&c| c == 0), "finite buckets: {counts:?}");
        assert_eq!(*counts.last().unwrap(), 1);
    }

    #[test]
    fn hit_rate() {
        let m = Metrics::default();
        assert_eq!(m.block_hit_rate(), 0.0);
        Metrics::add(&m.cache_blocks_hit, 3);
        Metrics::add(&m.cache_blocks_missed, 1);
        assert!((m.block_hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn render_is_prometheus_shaped() {
        let m = Metrics::default();
        Metrics::inc(&m.requests_total);
        let text = m.render();
        assert!(text.contains("skymemory_requests_total 1"));
        assert!(text.contains("# TYPE skymemory_requests_total counter"));
        assert!(text.contains("skymemory_ttft_seconds_bucket"));
    }
}
