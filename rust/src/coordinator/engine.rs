//! The generation engine: prompt in, tokens out, with the SkyMemory KVC
//! as the prefix-cache tier (the paper's §5 validation flow, generalized).
//!
//! Per request:
//! 1. tokenize, chain-hash the full blocks (model-fingerprinted root),
//! 2. look up the longest cached prefix (radix index or distributed),
//! 3. fetch those blocks' chunks from the constellation, dequantize,
//!    install into the sequence slot's KV cache,
//! 4. prefill the remaining full blocks (storing each new block's KV back
//!    into the constellation),
//! 5. decode the trailing partial block token-by-token,
//! 6. sample and decode `max_new_tokens`.

use super::executor::Executor;
use super::metrics::Metrics;
use crate::kvc::block::{block_hashes_for_model, full_blocks, BlockHash};
use crate::kvc::manager::KvcManager;
use crate::runtime::kv::payload_from_new;
use crate::runtime::sampler::{Sampler, SamplerConfig};
use crate::runtime::tokenizer::ByteTokenizer;
use anyhow::{bail, Result};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

/// A generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: String,
    pub max_new_tokens: usize,
    pub use_cache: bool,
    pub sampler: SamplerConfig,
}

impl Default for GenRequest {
    fn default() -> Self {
        Self {
            prompt: String::new(),
            max_new_tokens: 30,
            use_cache: true,
            sampler: SamplerConfig::default(),
        }
    }
}

/// A generation result with serving telemetry.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub text: String,
    pub tokens: Vec<i32>,
    pub prompt_tokens: usize,
    /// Blocks restored from the constellation cache.
    pub cached_blocks: usize,
    /// Blocks prefilled on the accelerator.
    pub prefill_blocks: usize,
    /// Seconds to first generated token (the paper's TTFT target).
    pub ttft_s: f64,
    /// Total generation wall time.
    pub total_s: f64,
    /// Time spent talking to the constellation (fetch + store).
    pub kvc_fetch_s: f64,
    pub kvc_store_s: f64,
}

/// The engine: executor handle + optional cache manager.
pub struct Engine {
    pub executor: Executor,
    pub kvc: Option<Arc<KvcManager>>,
    pub metrics: Arc<Metrics>,
    tokenizer: ByteTokenizer,
    fingerprint: BlockHash,
    /// Store freshly-computed blocks back to the constellation.
    pub write_through: bool,
    /// Optional §3.7 hit predictor (records block traffic; the rotation
    /// driver calls its `preplace` ahead of each epoch).
    pub prefetcher: Option<Arc<super::prefetch::Prefetcher>>,
}

impl Engine {
    pub fn new(
        executor: Executor,
        kvc: Option<Arc<KvcManager>>,
        fingerprint: BlockHash,
        metrics: Arc<Metrics>,
    ) -> Self {
        Self {
            executor,
            kvc,
            metrics,
            tokenizer: ByteTokenizer,
            fingerprint,
            write_through: true,
            prefetcher: None,
        }
    }

    pub fn tokenizer(&self) -> &ByteTokenizer {
        &self.tokenizer
    }

    /// Chained block hashes for a prompt (§3.8 steps 1-2).
    pub fn hashes_for(&self, tokens: &[i32]) -> Vec<BlockHash> {
        block_hashes_for_model(tokens, self.executor.dims.block_tokens, &self.fingerprint)
    }

    /// Run one generation request to completion.
    pub fn generate(&self, req: &GenRequest) -> Result<GenResult> {
        let t_start = Instant::now();
        let dims = self.executor.dims;
        let b = dims.block_tokens;
        let tokens = self.tokenizer.encode(&req.prompt);
        if tokens.is_empty() {
            bail!("empty prompt");
        }
        if tokens.len() + req.max_new_tokens > dims.max_seq {
            bail!(
                "prompt ({}) + max_new_tokens ({}) exceeds context {}",
                tokens.len(),
                req.max_new_tokens,
                dims.max_seq
            );
        }
        Metrics::inc(&self.metrics.requests_total);
        Metrics::add(&self.metrics.prompt_tokens, tokens.len() as u64);

        let hashes = self.hashes_for(&tokens);
        let n_full = full_blocks(tokens.len(), b);
        let slot = self.executor.alloc_slot()?;
        let result = self.generate_inner(req, &tokens, &hashes, n_full, slot, t_start);
        self.executor.free_slot(slot);
        match &result {
            Ok(r) => {
                Metrics::add(&self.metrics.tokens_generated, r.tokens.len() as u64);
                Metrics::add(&self.metrics.cache_blocks_hit, r.cached_blocks as u64);
                Metrics::add(&self.metrics.cache_blocks_missed, r.prefill_blocks as u64);
                self.metrics.ttft.observe(std::time::Duration::from_secs_f64(r.ttft_s));
                self.metrics.e2e.observe(std::time::Duration::from_secs_f64(r.total_s));
            }
            Err(_) => Metrics::inc(&self.metrics.requests_failed),
        }
        result
    }

    fn generate_inner(
        &self,
        req: &GenRequest,
        tokens: &[i32],
        hashes: &[BlockHash],
        n_full: usize,
        slot: usize,
        t_start: Instant,
    ) -> Result<GenResult> {
        let dims = self.executor.dims;
        let b = dims.block_tokens;
        let mut kvc_fetch_s = 0.0;
        let mut kvc_store_s = 0.0;

        // --- 2+3: restore the longest cached prefix -----------------------
        let mut cached_blocks = 0usize;
        if req.use_cache {
            if let Some(m) = &self.kvc {
                let epoch = epoch_of(m);
                let t0 = Instant::now();
                if let Some((blocks, _meta)) = m.lookup(hashes, epoch) {
                    if let Some(p) = &self.prefetcher {
                        p.record(hashes, blocks);
                    }
                    let fetch = m.fetch_prefix(hashes, blocks, epoch)?;
                    for (i, payload) in fetch.kv_blocks.iter().enumerate() {
                        self.executor.write_block(slot, i, payload.clone())?;
                    }
                    cached_blocks = fetch.blocks;
                }
                kvc_fetch_s = t0.elapsed().as_secs_f64();
            }
        }
        let mut pos = cached_blocks * b;

        // --- 4: prefill remaining full blocks -----------------------------
        let mut last_logits: Option<Vec<f32>> = None;
        let mut prefill_blocks = 0usize;
        for blk in cached_blocks..n_full {
            let block_tokens = tokens[blk * b..(blk + 1) * b].to_vec();
            let out = self.executor.prefill(slot, block_tokens, pos)?;
            Metrics::inc(&self.metrics.prefill_steps);
            prefill_blocks += 1;
            pos += b;
            if req.use_cache && self.write_through {
                if let Some(m) = &self.kvc {
                    let t0 = Instant::now();
                    let payload = payload_from_new(&out.k_new, &out.v_new);
                    m.put_block(hashes, blk, &payload, epoch_of(m))?;
                    Metrics::inc(&self.metrics.blocks_stored);
                    kvc_store_s += t0.elapsed().as_secs_f64();
                }
            }
            last_logits = Some(last_row(&out.logits, dims.vocab));
        }

        // --- 5: trailing partial block, token by token --------------------
        for &t in &tokens[n_full * b..] {
            let out = self.executor.decode(slot, t, pos)?;
            Metrics::inc(&self.metrics.decode_steps);
            pos += 1;
            last_logits = Some(out.logits);
        }

        // cached prefix covered the *whole* prompt: we still need logits
        // for the last prompt token — recompute it as a decode step at
        // pos-1 (its KV gets overwritten with identical values).
        if last_logits.is_none() {
            let out = self.executor.decode(slot, tokens[tokens.len() - 1], pos - 1)?;
            Metrics::inc(&self.metrics.decode_steps);
            last_logits = Some(out.logits);
        }

        // --- 6: sample + decode loop --------------------------------------
        let mut sampler = Sampler::new(req.sampler);
        let mut generated = Vec::with_capacity(req.max_new_tokens);
        let mut logits = last_logits.unwrap();
        let mut ttft_s = 0.0;
        for i in 0..req.max_new_tokens {
            let next = sampler.sample(&logits[logits.len() - dims.vocab..]);
            if i == 0 {
                ttft_s = t_start.elapsed().as_secs_f64();
            }
            generated.push(next);
            if pos >= dims.max_seq {
                break;
            }
            let t_step = Instant::now();
            let out = self.executor.decode(slot, next, pos)?;
            self.metrics.decode_step.observe(t_step.elapsed());
            Metrics::inc(&self.metrics.decode_steps);
            pos += 1;
            logits = out.logits;
        }

        Ok(GenResult {
            text: self.tokenizer.decode(&generated),
            tokens: generated,
            prompt_tokens: tokens.len(),
            cached_blocks,
            prefill_blocks,
            ttft_s,
            total_s: t_start.elapsed().as_secs_f64(),
            kvc_fetch_s,
            kvc_store_s,
        })
    }
}

/// Current epoch as seen by the manager's transport ground view.
fn epoch_of(m: &KvcManager) -> u64 {
    // GroundView tracks the epoch; Transport exposes it via closest()
    // movement.  We keep an explicit counter on the transport stats-free
    // path: ask the transport.
    m.transport_epoch()
}

fn last_row(logits: &[f32], vocab: usize) -> Vec<f32> {
    logits[logits.len() - vocab..].to_vec()
}

impl std::ops::Deref for Engine {
    type Target = Executor;

    fn deref(&self) -> &Executor {
        &self.executor
    }
}

#[allow(unused)]
fn _ordering_probe() {
    let _ = Ordering::Relaxed;
}
