//! Predictive placement (§3.7): "if we predict a cache hit on a certain
//! set of chunks at some future time ... the set of satellites in the LOS
//! at that future time is known exactly and [we can] arrange to make those
//! chunks available on those LOS satellites at that time."
//!
//! The [`Prefetcher`] tracks per-block access frequency (EWMA-decayed hit
//! counts) and, ahead of each rotation epoch, re-places the hottest blocks
//! for the *next* epoch's LOS window using the manager's
//! `put_block_at(.., target_epoch)` — sourcing KV values from the local
//! RAM tier, so prediction costs no recompute and no extra downlink.

use crate::kvc::block::BlockHash;
use crate::kvc::manager::KvcManager;
use std::collections::HashMap;
use std::sync::Mutex;

/// A tracked prefix (the hash list up to and including a block).
#[derive(Clone)]
struct Tracked {
    hashes: Vec<BlockHash>,
    block_idx: usize,
    score: f64,
}

/// Frequency-based hit predictor + pre-placer.
pub struct Prefetcher {
    state: Mutex<HashMap<BlockHash, Tracked>>,
    /// Exponential decay applied at each epoch boundary.
    pub decay: f64,
    /// Blocks re-placed per epoch.
    pub budget: usize,
}

impl Default for Prefetcher {
    fn default() -> Self {
        Self::new(0.5, 16)
    }
}

impl Prefetcher {
    pub fn new(decay: f64, budget: usize) -> Self {
        assert!((0.0..=1.0).contains(&decay));
        Self { state: Mutex::new(HashMap::new()), decay, budget }
    }

    /// Record that a request touched the first `blocks` blocks of
    /// `hashes` (call on every lookup, hit or miss).
    pub fn record(&self, hashes: &[BlockHash], blocks: usize) {
        let mut state = self.state.lock().unwrap();
        for (i, h) in hashes.iter().take(blocks).enumerate() {
            let e = state.entry(*h).or_insert_with(|| Tracked {
                hashes: hashes[..=i].to_vec(),
                block_idx: i,
                score: 0.0,
            });
            e.score += 1.0;
        }
    }

    /// The hottest blocks, hottest first.
    pub fn hottest(&self, k: usize) -> Vec<(Vec<BlockHash>, usize, f64)> {
        let state = self.state.lock().unwrap();
        let mut all: Vec<_> = state.values().cloned().collect();
        all.sort_by(|a, b| b.score.total_cmp(&a.score));
        all.truncate(k);
        all.into_iter().map(|t| (t.hashes, t.block_idx, t.score)).collect()
    }

    pub fn tracked(&self) -> usize {
        self.state.lock().unwrap().len()
    }

    /// Epoch boundary: decay scores and pre-place the hottest blocks for
    /// `target_epoch` (normally `now_epoch + 1`).  Values come from the
    /// manager's local tier; blocks not resident there are skipped (they
    /// would need recompute, which prediction must not trigger).
    /// Returns the number of blocks pre-placed.
    pub fn preplace(
        &self,
        manager: &KvcManager,
        now_epoch: u64,
        target_epoch: u64,
    ) -> anyhow::Result<usize> {
        let Some(local) = manager.local_tier() else { return Ok(0) };
        let mut placed = 0;
        for (hashes, block_idx, _score) in self.hottest(self.budget) {
            if let Some(values) = local.get(&hashes[block_idx]) {
                // force a store even if the radix index knows the block:
                // the *placement epoch* is what changes
                manager.put_block_at_forced(&hashes, block_idx, &values, now_epoch, target_epoch)?;
                placed += 1;
            }
        }
        // decay after acting so fresh traffic dominates next epoch
        let mut state = self.state.lock().unwrap();
        state.retain(|_, t| {
            t.score *= self.decay;
            t.score > 0.05
        });
        Ok(placed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvc::block::block_hashes;

    #[test]
    fn record_and_rank() {
        let p = Prefetcher::new(0.5, 4);
        let a = block_hashes(&(0..64).collect::<Vec<i32>>(), 32);
        let b = block_hashes(&(100..164).collect::<Vec<i32>>(), 32);
        for _ in 0..3 {
            p.record(&a, 2);
        }
        p.record(&b, 1);
        let hot = p.hottest(10);
        assert_eq!(hot.len(), 3); // a[0], a[1], b[0]
        assert_eq!(hot[0].2, 3.0);
        assert!(hot.iter().any(|(h, i, _)| h.last() == Some(&b[0]) && *i == 0));
    }

    #[test]
    fn decay_forgets_cold_blocks() {
        let p = Prefetcher::new(0.1, 4);
        let a = block_hashes(&(0..32).collect::<Vec<i32>>(), 32);
        p.record(&a, 1);
        assert_eq!(p.tracked(), 1);
        // two decay rounds at 0.1: 1.0 -> 0.1 -> 0.01 < 0.05 threshold
        let mut state = p.state.lock().unwrap();
        state.retain(|_, t| {
            t.score *= p.decay;
            t.score > 0.05
        });
        state.retain(|_, t| {
            t.score *= p.decay;
            t.score > 0.05
        });
        assert_eq!(state.len(), 0);
    }
}
