//! Minimal HTTP/1.1 server (from scratch — no web framework offline) for
//! the serving API:
//!
//! * `POST /generate` — body `{"prompt": "...", "max_tokens": 30,
//!   "use_cache": true, "temperature": 0.0}` → generation result JSON.
//! * `GET /metrics` — Prometheus text exposition.
//! * `GET /healthz` — liveness.
//!
//! One thread per connection (keep-alive not supported; every response
//! closes the connection — fine for the demo scale this serves).

use super::scheduler::Router;
use crate::runtime::sampler::SamplerConfig;
use crate::util::json::{n, obj, s, Json};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// A running HTTP server.
pub struct HttpServer {
    pub addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind and serve on a background thread.
    pub fn spawn(bind: &str, router: Arc<Router>) -> Result<Self> {
        let listener = TcpListener::bind(bind).with_context(|| format!("binding {bind}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let sd = shutdown.clone();
        let handle = std::thread::spawn(move || {
            loop {
                if sd.load(Ordering::Relaxed) {
                    return;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        let router = router.clone();
                        std::thread::spawn(move || {
                            let _ = handle_connection(stream, &router);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(10));
                    }
                    Err(_) => return,
                }
            }
        });
        Ok(Self { addr, shutdown, handle: Some(handle) })
    }

    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_connection(mut stream: TcpStream, router: &Router) -> Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_secs(300)))?;
    let (method, path, body) = read_request(&mut stream)?;
    let (status, content_type, payload) = match (method.as_str(), path.as_str()) {
        ("GET", "/healthz") => (200, "text/plain", "ok\n".to_string()),
        ("GET", "/metrics") => (200, "text/plain", router.metrics.render()),
        ("POST", "/generate") => match handle_generate(router, &body) {
            Ok(j) => (200, "application/json", j.to_string()),
            Err(e) => (
                400,
                "application/json",
                obj(vec![("error", s(&e.to_string()))]).to_string(),
            ),
        },
        _ => (404, "text/plain", "not found\n".to_string()),
    };
    write_response(&mut stream, status, content_type, &payload)
}

fn handle_generate(router: &Router, body: &str) -> Result<Json> {
    let j = Json::parse(body).context("request body must be JSON")?;
    let prompt = j
        .get("prompt")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("missing 'prompt'"))?
        .to_string();
    let max_new_tokens = j.get("max_tokens").and_then(Json::as_usize).unwrap_or(30);
    let use_cache = j.get("use_cache").and_then(Json::as_bool).unwrap_or(true);
    let temperature = j.get("temperature").and_then(Json::as_f64).unwrap_or(0.0) as f32;
    let top_k = j.get("top_k").and_then(Json::as_usize).unwrap_or(0);
    let seed = j.get("seed").and_then(Json::as_u64).unwrap_or(0x5eed);
    let result = router.generate(super::engine::GenRequest {
        prompt,
        max_new_tokens,
        use_cache,
        sampler: SamplerConfig { temperature, top_k, seed },
    })?;
    Ok(obj(vec![
        ("text", s(&result.text)),
        ("prompt_tokens", n(result.prompt_tokens as f64)),
        ("generated_tokens", n(result.tokens.len() as f64)),
        ("cached_blocks", n(result.cached_blocks as f64)),
        ("prefill_blocks", n(result.prefill_blocks as f64)),
        ("ttft_s", n(result.ttft_s)),
        ("total_s", n(result.total_s)),
        ("kvc_fetch_s", n(result.kvc_fetch_s)),
        ("kvc_store_s", n(result.kvc_store_s)),
    ]))
}

fn read_request(stream: &mut TcpStream) -> Result<(String, String, String)> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut request_line = String::new();
    reader.read_line(&mut request_line)?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or_default().to_string();
    let path = parts.next().unwrap_or_default().to_string();
    if method.is_empty() || path.is_empty() {
        bail!("malformed request line");
    }
    let mut content_length = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some((k, v)) = line.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    if content_length > 10 << 20 {
        bail!("body too large");
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok((method, path, String::from_utf8_lossy(&body).into_owned()))
}

fn write_response(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    payload: &str,
) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        _ => "Error",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        payload.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(payload.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// Tiny blocking HTTP client for tests, examples and the load generator.
pub mod client {
    use super::*;

    /// `POST path` with a JSON body; returns (status, body).
    pub fn post(addr: std::net::SocketAddr, path: &str, body: &str) -> Result<(u16, String)> {
        let mut stream = TcpStream::connect(addr)?;
        let req = format!(
            "POST {path} HTTP/1.1\r\nHost: localhost\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        );
        stream.write_all(req.as_bytes())?;
        read_response(stream)
    }

    /// `GET path`; returns (status, body).
    pub fn get(addr: std::net::SocketAddr, path: &str) -> Result<(u16, String)> {
        let mut stream = TcpStream::connect(addr)?;
        let req = format!("GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n");
        stream.write_all(req.as_bytes())?;
        read_response(stream)
    }

    fn read_response(stream: TcpStream) -> Result<(u16, String)> {
        let mut reader = BufReader::new(stream);
        let mut status_line = String::new();
        reader.read_line(&mut status_line)?;
        let status: u16 = status_line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("bad status line {status_line:?}"))?;
        let mut content_length = 0usize;
        loop {
            let mut line = String::new();
            reader.read_line(&mut line)?;
            if line.trim_end().is_empty() {
                break;
            }
            if let Some((k, v)) = line.split_once(':') {
                if k.eq_ignore_ascii_case("content-length") {
                    content_length = v.trim().parse().unwrap_or(0);
                }
            }
        }
        let mut body = vec![0u8; content_length];
        reader.read_exact(&mut body)?;
        Ok((status, String::from_utf8_lossy(&body).into_owned()))
    }
}
