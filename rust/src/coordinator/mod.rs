//! The serving coordinator (L3): executor thread, generation engine,
//! prefix-affinity router + worker pool, HTTP API and metrics — the
//! vLLM-router-shaped stack the paper's testbed runs on its Jetson host,
//! with the SkyMemory constellation as the prefix-cache tier.

pub mod engine;
pub mod executor;
pub mod http;
pub mod metrics;
pub mod prefetch;
pub mod scheduler;

pub use engine::{Engine, GenRequest, GenResult};
pub use executor::Executor;
pub use metrics::Metrics;
pub use scheduler::Router;

use crate::constellation::geometry::Geometry;
use crate::constellation::los::LosGrid;
use crate::constellation::topology::{SatId, Torus};
use crate::kvc::block::{model_fingerprint, BlockHash};
use crate::kvc::manager::{KvcConfig, KvcManager};
use crate::net::transport::{GroundView, InProcTransport, LinkModel, Transport};
use crate::runtime::model_config::Artifacts;
use crate::satellite::fleet::Fleet;
use anyhow::Result;
use std::sync::Arc;

/// Everything needed to stand up a serving stack in one call (used by the
/// CLI, examples, benches and integration tests).
pub struct StackConfig {
    pub artifacts_dir: std::path::PathBuf,
    pub torus: Torus,
    pub geometry: Geometry,
    pub initial_center: SatId,
    pub los_half_slots: usize,
    pub los_half_planes: usize,
    pub kvc: KvcConfig,
    pub n_workers: usize,
    pub max_slots: usize,
    /// Emulate link latency (sleeping) in the in-proc transport.
    pub link: Option<LinkModel>,
    /// Per-satellite store budget in bytes.
    pub sat_budget: usize,
}

impl Default for StackConfig {
    fn default() -> Self {
        let geometry = Geometry::new(550.0, 19, 5); // the paper's 19x5 testbed
        Self {
            artifacts_dir: crate::runtime::model_config::default_artifacts_dir(),
            torus: Torus::new(5, 19),
            geometry,
            initial_center: SatId::new(2, 9),
            los_half_slots: 2,
            los_half_planes: 2,
            kvc: KvcConfig::default(),
            n_workers: 2,
            max_slots: 8,
            link: None,
            sat_budget: 64 << 20,
        }
    }
}

/// A fully-assembled in-process serving stack.
pub struct Stack {
    pub fleet: Arc<Fleet>,
    pub manager: Arc<KvcManager>,
    pub router: Arc<Router>,
    pub metrics: Arc<Metrics>,
    pub fingerprint: BlockHash,
}

impl Stack {
    /// Spawn the rotation driver: a background thread that, every
    /// `period`, (1) §3.7-pre-places the hottest blocks for the next
    /// epoch, (2) issues the §3.4 column migrations, (3) advances the
    /// ground view.  `period` is the (possibly time-scaled) epoch period;
    /// the real cadence is `geometry.slot_shift_period_s()` (~5 min).
    pub fn spawn_rotation_driver(
        &self,
        period: std::time::Duration,
    ) -> std::sync::mpsc::Sender<()> {
        let (stop_tx, stop_rx) = std::sync::mpsc::channel::<()>();
        let manager = self.manager.clone();
        let prefetcher = self.router.prefetcher.clone();
        std::thread::spawn(move || {
            let mut epoch = manager.transport_epoch();
            loop {
                match stop_rx.recv_timeout(period) {
                    Ok(()) | Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return,
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                }
                let _ = prefetcher.preplace(&manager, epoch, epoch + 1);
                let _ = manager.advance_epoch(epoch);
                epoch += 1;
            }
        });
        stop_tx
    }

    /// Build the whole serving stack over an in-process fleet.
    pub fn build(cfg: StackConfig) -> Result<Self> {
        let artifacts = Artifacts::load(&cfg.artifacts_dir)?;
        let fingerprint =
            model_fingerprint("skymemory-bytelm", "byte-v1", &artifacts.weights_digest()?);
        let executor = Executor::spawn(artifacts, cfg.max_slots)?;

        let fleet = Arc::new(Fleet::new(cfg.torus, cfg.sat_budget, cfg.kvc.eviction));
        let los = LosGrid::new(cfg.initial_center, cfg.los_half_slots, cfg.los_half_planes);
        let ground = GroundView::new(cfg.initial_center, &los, cfg.torus.sats_per_plane);
        let transport: Arc<dyn Transport> =
            Arc::new(InProcTransport::new(fleet.clone(), ground, cfg.link));
        let manager = Arc::new(KvcManager::new(cfg.kvc, cfg.torus, transport));

        let metrics = Arc::new(Metrics::default());
        let router = Arc::new(Router::spawn(
            executor,
            Some(manager.clone()),
            fingerprint,
            cfg.n_workers,
            metrics.clone(),
        ));
        Ok(Self { fleet, manager, router, metrics, fingerprint })
    }
}
