//! Request scheduling: a prefix-affinity router in front of a pool of
//! engine workers sharing one executor (vLLM-router-style).
//!
//! * [`Router`] — hashes the first token block of each prompt and pins the
//!   request to a worker queue, so prompts sharing a prefix land on the
//!   same worker (warm radix index, fewer duplicate constellation sets).
//!   Queue-depth-aware spill: if the pinned queue is much deeper than the
//!   shallowest, the request spills to the shallowest (work conservation).
//! * [`WorkQueue`] — a Mutex+Condvar MPMC queue (no crossbeam offline).
//! * Workers run [`Engine::generate`] and fulfil one-shot reply channels.

use super::engine::{Engine, GenRequest, GenResult};
use super::executor::Executor;
use super::metrics::Metrics;
use crate::kvc::block::BlockHash;
use crate::kvc::hash::sha256;
use crate::kvc::manager::KvcManager;
use anyhow::{anyhow, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};

/// A queued unit of work.
struct Job {
    request: GenRequest,
    reply: mpsc::Sender<Result<GenResult>>,
}

/// Blocking MPMC queue.
pub struct WorkQueue {
    inner: Mutex<VecDeque<Job>>,
    cv: Condvar,
    closed: AtomicBool,
    depth: AtomicUsize,
}

impl Default for WorkQueue {
    fn default() -> Self {
        Self {
            inner: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            closed: AtomicBool::new(false),
            depth: AtomicUsize::new(0),
        }
    }
}

impl WorkQueue {
    fn push(&self, job: Job) {
        let mut q = self.inner.lock().unwrap();
        q.push_back(job);
        self.depth.store(q.len(), Ordering::Relaxed);
        self.cv.notify_one();
    }

    fn pop(&self) -> Option<Job> {
        let mut q = self.inner.lock().unwrap();
        loop {
            if let Some(j) = q.pop_front() {
                self.depth.store(q.len(), Ordering::Relaxed);
                return Some(j);
            }
            if self.closed.load(Ordering::Relaxed) {
                return None;
            }
            q = self.cv.wait(q).unwrap();
        }
    }

    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    fn close(&self) {
        self.closed.store(true, Ordering::Relaxed);
        self.cv.notify_all();
    }
}

/// The router + worker pool.
pub struct Router {
    queues: Vec<Arc<WorkQueue>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    /// Shared §3.7 hit predictor across workers.
    pub prefetcher: Arc<super::prefetch::Prefetcher>,
    block_tokens: usize,
    /// Spill when pinned queue depth exceeds shallowest + this.
    pub spill_threshold: usize,
}

impl Router {
    /// Spawn `n_workers` engine workers over a shared executor.
    pub fn spawn(
        executor: Executor,
        kvc: Option<Arc<KvcManager>>,
        fingerprint: BlockHash,
        n_workers: usize,
        metrics: Arc<Metrics>,
    ) -> Self {
        assert!(n_workers >= 1);
        let queues: Vec<Arc<WorkQueue>> =
            (0..n_workers).map(|_| Arc::new(WorkQueue::default())).collect();
        let prefetcher = std::sync::Arc::new(super::prefetch::Prefetcher::default());
        let mut workers = Vec::with_capacity(n_workers);
        for q in &queues {
            let mut engine =
                Engine::new(executor.clone(), kvc.clone(), fingerprint, metrics.clone());
            engine.prefetcher = Some(prefetcher.clone());
            let q = q.clone();
            workers.push(std::thread::spawn(move || {
                while let Some(job) = q.pop() {
                    let result = engine.generate(&job.request);
                    let _ = job.reply.send(result);
                }
            }));
        }
        Self {
            queues,
            workers,
            metrics,
            prefetcher,
            block_tokens: executor.dims.block_tokens,
            spill_threshold: 4,
        }
    }

    /// Prefix-affinity worker choice with depth-aware spill.
    pub fn pick_worker(&self, prompt: &str) -> usize {
        let n = self.queues.len();
        if n == 1 {
            return 0;
        }
        let prefix_len = prompt.len().min(self.block_tokens);
        let digest = sha256(prompt[..prefix_len].as_bytes());
        let pinned = (u64::from_le_bytes(digest[..8].try_into().unwrap()) % n as u64) as usize;
        let (shallowest, depth) = self
            .queues
            .iter()
            .enumerate()
            .map(|(i, q)| (i, q.depth()))
            .min_by_key(|(_, d)| *d)
            .unwrap();
        if self.queues[pinned].depth() > depth + self.spill_threshold {
            shallowest
        } else {
            pinned
        }
    }

    /// Enqueue a request; returns a receiver for the result.
    pub fn submit(&self, request: GenRequest) -> mpsc::Receiver<Result<GenResult>> {
        let (tx, rx) = mpsc::channel();
        let worker = self.pick_worker(&request.prompt);
        self.queues[worker].push(Job { request, reply: tx });
        rx
    }

    /// Convenience: submit and wait.
    pub fn generate(&self, request: GenRequest) -> Result<GenResult> {
        self.submit(request)
            .recv()
            .map_err(|_| anyhow!("worker dropped the request"))?
    }

    /// Total queued jobs across workers.
    pub fn queued(&self) -> usize {
        self.queues.iter().map(|q| q.depth()).sum()
    }

    pub fn shutdown(mut self) {
        for q in &self.queues {
            q.close();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn work_queue_fifo_and_close() {
        let q = Arc::new(WorkQueue::default());
        let (tx, _rx) = mpsc::channel();
        q.push(Job { request: GenRequest { prompt: "a".into(), ..Default::default() }, reply: tx.clone() });
        q.push(Job { request: GenRequest { prompt: "b".into(), ..Default::default() }, reply: tx });
        assert_eq!(q.depth(), 2);
        assert_eq!(q.pop().unwrap().request.prompt, "a");
        assert_eq!(q.pop().unwrap().request.prompt, "b");
        q.close();
        assert!(q.pop().is_none());
    }

    #[test]
    fn queue_unblocks_waiters_on_close() {
        let q = Arc::new(WorkQueue::default());
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop().is_none());
        std::thread::sleep(std::time::Duration::from_millis(50));
        q.close();
        assert!(h.join().unwrap());
    }
}
