//! The model executor: one dedicated thread owns the PJRT model (and the
//! per-sequence KV cache slots) and serializes all accelerator work — the
//! standard single-execution-stream design of serving engines.  Engine
//! workers talk to it through channels, so `PjRtModel`'s !Send types never
//! cross threads.
//!
//! Keeping the KV caches *inside* the executor means scheduler messages
//! carry tokens and block payloads, never multi-MB cache tensors.

use crate::runtime::kv::KvCache;
use crate::runtime::model_config::ModelDims;
use crate::runtime::pjrt::{PjRtModel, StepOutput};
use anyhow::{anyhow, bail, Result};
use std::sync::mpsc;

/// A sequence slot id.
pub type SlotId = usize;

enum Msg {
    Alloc(mpsc::Sender<Result<SlotId>>),
    Free(SlotId),
    Prefill { slot: SlotId, tokens: Vec<i32>, pos: usize, reply: mpsc::Sender<Result<StepOutput>> },
    Decode { slot: SlotId, token: i32, pos: usize, reply: mpsc::Sender<Result<StepOutput>> },
    WriteBlock { slot: SlotId, block_idx: usize, payload: Vec<f32>, reply: mpsc::Sender<Result<()>> },
    Shutdown,
}

/// Cloneable handle to the executor thread.
#[derive(Clone)]
pub struct Executor {
    tx: mpsc::Sender<Msg>,
    pub dims: ModelDims,
}

impl Executor {
    /// Spawn the executor thread with `max_slots` sequence slots.  The
    /// PJRT model is *built inside* the thread (its handles are !Send);
    /// compile/load errors are reported back synchronously.
    pub fn spawn(artifacts: crate::runtime::model_config::Artifacts, max_slots: usize) -> Result<Self> {
        let dims = artifacts.dims;
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        std::thread::Builder::new()
            .name("skymemory-executor".into())
            .spawn(move || {
                let model = match PjRtModel::load(artifacts) {
                    Ok(m) => {
                        let _ = ready_tx.send(Ok(()));
                        m
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                run(model, rx, max_slots)
            })
            .expect("spawn executor");
        ready_rx.recv().map_err(|_| anyhow!("executor thread died during load"))??;
        Ok(Self { tx, dims })
    }

    /// Spawn from the default artifacts directory.
    pub fn spawn_default(max_slots: usize) -> Result<Self> {
        let dir = crate::runtime::model_config::default_artifacts_dir();
        Self::spawn(crate::runtime::model_config::Artifacts::load(dir)?, max_slots)
    }

    pub fn alloc_slot(&self) -> Result<SlotId> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Msg::Alloc(tx)).map_err(|_| anyhow!("executor gone"))?;
        rx.recv().map_err(|_| anyhow!("executor gone"))?
    }

    pub fn free_slot(&self, slot: SlotId) {
        let _ = self.tx.send(Msg::Free(slot));
    }

    /// Prefill one token block at `pos`; the slot cache is updated and the
    /// step output (logits + new block KV) returned.
    pub fn prefill(&self, slot: SlotId, tokens: Vec<i32>, pos: usize) -> Result<StepOutput> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Prefill { slot, tokens, pos, reply: tx })
            .map_err(|_| anyhow!("executor gone"))?;
        rx.recv().map_err(|_| anyhow!("executor gone"))?
    }

    /// Decode a single token at `pos`.
    pub fn decode(&self, slot: SlotId, token: i32, pos: usize) -> Result<StepOutput> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Decode { slot, token, pos, reply: tx })
            .map_err(|_| anyhow!("executor gone"))?;
        rx.recv().map_err(|_| anyhow!("executor gone"))?
    }

    /// Install a fetched KVC block payload into a slot's cache.
    pub fn write_block(&self, slot: SlotId, block_idx: usize, payload: Vec<f32>) -> Result<()> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::WriteBlock { slot, block_idx, payload, reply: tx })
            .map_err(|_| anyhow!("executor gone"))?;
        rx.recv().map_err(|_| anyhow!("executor gone"))?
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
    }
}

struct Slot {
    cache: KvCache,
    in_use: bool,
}

fn run(model: PjRtModel, rx: mpsc::Receiver<Msg>, max_slots: usize) {
    let dims = model.artifacts.dims;
    let mut slots: Vec<Slot> = (0..max_slots)
        .map(|_| Slot { cache: KvCache::new(dims), in_use: false })
        .collect();
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Alloc(reply) => {
                let r = match slots.iter_mut().enumerate().find(|(_, s)| !s.in_use) {
                    Some((i, s)) => {
                        s.in_use = true;
                        s.cache.reset();
                        Ok(i)
                    }
                    None => Err(anyhow!("no free sequence slots (max {max_slots})")),
                };
                let _ = reply.send(r);
            }
            Msg::Free(slot) => {
                if let Some(s) = slots.get_mut(slot) {
                    s.in_use = false;
                }
            }
            Msg::Prefill { slot, tokens, pos, reply } => {
                let _ = reply.send(step(&model, &mut slots, slot, &tokens, pos, true));
            }
            Msg::Decode { slot, token, pos, reply } => {
                let _ = reply.send(step(&model, &mut slots, slot, &[token], pos, false));
            }
            Msg::WriteBlock { slot, block_idx, payload, reply } => {
                let r = match slots.get_mut(slot) {
                    Some(s) if payload.len() == dims.block_payload_elems() => {
                        s.cache.write_block_payload(block_idx, &payload);
                        Ok(())
                    }
                    Some(_) => Err(anyhow!("bad payload length")),
                    None => Err(anyhow!("bad slot")),
                };
                let _ = reply.send(r);
            }
            Msg::Shutdown => return,
        }
    }
}

fn step(
    model: &PjRtModel,
    slots: &mut [Slot],
    slot: SlotId,
    tokens: &[i32],
    pos: usize,
    prefill: bool,
) -> Result<StepOutput> {
    let Some(s) = slots.get_mut(slot) else { bail!("bad slot {slot}") };
    let out = if prefill {
        model.prefill(tokens, &s.cache.k, &s.cache.v, pos)?
    } else {
        model.decode(tokens[0], &s.cache.k, &s.cache.v, pos)?
    };
    s.cache.write_new(pos, &out.k_new, &out.v_new, tokens.len());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::model_config::default_artifacts_dir;
    use crate::runtime::pjrt::PjRtModel;

    fn executor() -> Option<Executor> {
        if !default_artifacts_dir().join("model_config.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Executor::spawn_default(4).unwrap())
    }

    #[test]
    fn slot_lifecycle() {
        let Some(ex) = executor() else { return };
        let a = ex.alloc_slot().unwrap();
        let b = ex.alloc_slot().unwrap();
        assert_ne!(a, b);
        ex.free_slot(a);
        let c = ex.alloc_slot().unwrap();
        assert_eq!(c, a, "freed slot is reused");
        ex.shutdown();
    }

    #[test]
    fn slots_exhaust() {
        let Some(ex) = executor() else { return };
        let slots: Vec<_> = (0..4).map(|_| ex.alloc_slot().unwrap()).collect();
        assert!(ex.alloc_slot().is_err());
        for s in slots {
            ex.free_slot(s);
        }
        ex.shutdown();
    }

    #[test]
    fn prefill_decode_via_executor_threads() {
        let Some(ex) = executor() else { return };
        let b = ex.dims.block_tokens;
        // run two sequences from two threads concurrently
        let handles: Vec<_> = (0..2)
            .map(|i| {
                let ex = ex.clone();
                std::thread::spawn(move || {
                    let slot = ex.alloc_slot().unwrap();
                    let tokens: Vec<i32> = (0..b as i32).map(|t| (t + i) % 256).collect();
                    let out = ex.prefill(slot, tokens, 0).unwrap();
                    assert_eq!(out.logits.len(), b * ex.dims.vocab);
                    let out2 = ex.decode(slot, 65, b).unwrap();
                    assert_eq!(out2.logits.len(), ex.dims.vocab);
                    ex.free_slot(slot);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        ex.shutdown();
    }

    #[test]
    fn write_block_validates() {
        let Some(ex) = executor() else { return };
        let slot = ex.alloc_slot().unwrap();
        assert!(ex.write_block(slot, 0, vec![0.0; 3]).is_err());
        assert!(ex
            .write_block(slot, 0, vec![0.0; ex.dims.block_payload_elems()])
            .is_ok());
        assert!(ex.write_block(99, 0, vec![0.0; ex.dims.block_payload_elems()]).is_err());
        ex.shutdown();
    }
}
