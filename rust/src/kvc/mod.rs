//! The SkyMemory KVC protocol (paper §3): chained block hashing, chunking,
//! quantization codecs, the local radix block index, eviction policies and
//! the Get/Set manager.
//!
//! Layering: [`hash`]/[`block`]/[`chunk`]/[`quantize`] are pure codecs,
//! [`radix`] is the §3.10 local index and [`frozen`] its two-layer
//! epoch-compacted form (immutable arena + mutable delta), [`eviction`]
//! the §3.9 policies, [`manager::KvcManager`] drives the §3.8 protocol
//! over a [`crate::net::transport::Transport`], and [`session`] layers
//! paged, forkable per-user sessions with refcounted prefix sharing on
//! top.

pub mod block;
pub mod chunk;
pub mod eviction;
pub mod frozen;
pub mod hash;
pub mod manager;
pub mod quantize;
pub mod radix;
pub mod session;
pub mod tiered;

pub use block::{block_hashes, BlockHash};
pub use chunk::{split_chunks, ChunkKey};
pub use manager::KvcManager;
pub use quantize::Quantizer;
pub use session::{BlockRefs, SessionId, SessionManager};
