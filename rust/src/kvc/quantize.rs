//! KVC quantization codecs (§3.3: "The KVC can be implemented to be memory
//! efficient by trading off accuracy using various quantization
//! techniques"; §5 / Table 3 contrast an Optimum-Quanto 8-bit and an HQQ
//! quantizer).
//!
//! We implement the two same-shaped codecs from scratch:
//!
//! * [`Quantizer::QuantoInt8`] — symmetric per-group int8 (scale only),
//!   like optimum-quanto's weight/activation int8 path: fast, 4x smaller.
//! * [`Quantizer::HqqInt8`] — asymmetric per-group int8 (scale +
//!   zero-point, chosen by a few half-quadratic-style refinement sweeps),
//!   like HQQ: slightly better reconstruction, more encode compute —
//!   reproducing Table 3's "HQQ is slower end-to-end" behaviour.
//!
//! Groups are `group` consecutive f32s (the serving engine uses the head
//! dimension), each stored as little-endian metadata followed by the
//! quantized payload.

use anyhow::{bail, Result};

/// KVC value codec.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Quantizer {
    /// Raw little-endian f32 (no compression).
    F32,
    /// Symmetric per-group int8: `group` f32s -> 4-byte scale + `group` i8.
    QuantoInt8 { group: usize },
    /// Asymmetric per-group int8: scale + zero-point + `group` u8.
    HqqInt8 { group: usize },
}

impl Quantizer {
    /// Wire id (used by net::messages and the HTTP API).
    pub fn id(&self) -> u8 {
        match self {
            Quantizer::F32 => 0,
            Quantizer::QuantoInt8 { .. } => 1,
            Quantizer::HqqInt8 { .. } => 2,
        }
    }

    pub fn from_id(id: u8, group: usize) -> Option<Self> {
        match id {
            0 => Some(Quantizer::F32),
            1 => Some(Quantizer::QuantoInt8 { group }),
            2 => Some(Quantizer::HqqInt8 { group }),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Quantizer::F32 => "f32",
            Quantizer::QuantoInt8 { .. } => "quanto-int8",
            Quantizer::HqqInt8 { .. } => "hqq-int8",
        }
    }

    /// Encoded size for `n` f32 values.
    pub fn encoded_len(&self, n: usize) -> usize {
        match self {
            Quantizer::F32 => 4 * n,
            Quantizer::QuantoInt8 { group } => {
                assert_eq!(n % group, 0);
                (n / group) * (4 + group)
            }
            Quantizer::HqqInt8 { group } => {
                assert_eq!(n % group, 0);
                (n / group) * (8 + group)
            }
        }
    }

    pub fn encode(&self, values: &[f32]) -> Vec<u8> {
        match self {
            Quantizer::F32 => {
                let mut out = Vec::with_capacity(4 * values.len());
                for v in values {
                    out.extend_from_slice(&v.to_le_bytes());
                }
                out
            }
            Quantizer::QuantoInt8 { group } => {
                assert!(*group > 0 && values.len() % group == 0, "len % group != 0");
                let mut out = Vec::with_capacity(self.encoded_len(values.len()));
                for g in values.chunks_exact(*group) {
                    let amax = g.iter().fold(0f32, |m, v| m.max(v.abs()));
                    let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
                    // multiply by the inverse instead of dividing per
                    // element (§Perf: ~1.6x on the encode hot path); the
                    // amax/127 bound keeps |v * inv| <= 127 so the clamp
                    // only guards the rounding edge
                    let inv = 1.0 / scale;
                    out.extend_from_slice(&scale.to_le_bytes());
                    out.extend(g.iter().map(|v| {
                        (v * inv).round().clamp(-127.0, 127.0) as i8 as u8
                    }));
                }
                out
            }
            Quantizer::HqqInt8 { group } => {
                assert!(*group > 0 && values.len() % group == 0, "len % group != 0");
                let mut out = Vec::with_capacity(self.encoded_len(values.len()));
                for g in values.chunks_exact(*group) {
                    let (scale, zero) = hqq_fit(g);
                    out.extend_from_slice(&scale.to_le_bytes());
                    out.extend_from_slice(&zero.to_le_bytes());
                    for v in g {
                        out.push((v / scale + zero).round().clamp(0.0, 255.0) as u8);
                    }
                }
                out
            }
        }
    }

    pub fn decode(&self, bytes: &[u8]) -> Result<Vec<f32>> {
        match self {
            Quantizer::F32 => {
                if bytes.len() % 4 != 0 {
                    bail!("f32 payload length {} not a multiple of 4", bytes.len());
                }
                Ok(bytes
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                    .collect())
            }
            Quantizer::QuantoInt8 { group } => {
                let rec = 4 + group;
                if bytes.len() % rec != 0 {
                    bail!("quanto payload length {} not a multiple of {rec}", bytes.len());
                }
                let mut out = Vec::with_capacity((bytes.len() / rec) * group);
                for r in bytes.chunks_exact(rec) {
                    let scale = f32::from_le_bytes(r[..4].try_into().unwrap());
                    for b in &r[4..] {
                        out.push((*b as i8) as f32 * scale);
                    }
                }
                Ok(out)
            }
            Quantizer::HqqInt8 { group } => {
                let rec = 8 + group;
                if bytes.len() % rec != 0 {
                    bail!("hqq payload length {} not a multiple of {rec}", bytes.len());
                }
                let mut out = Vec::with_capacity((bytes.len() / rec) * group);
                for r in bytes.chunks_exact(rec) {
                    let scale = f32::from_le_bytes(r[..4].try_into().unwrap());
                    let zero = f32::from_le_bytes(r[4..8].try_into().unwrap());
                    for b in &r[8..] {
                        out.push((*b as f32 - zero) * scale);
                    }
                }
                Ok(out)
            }
        }
    }
}

/// Fit (scale, zero_point) for asymmetric u8 quantization with a few
/// half-quadratic refinement sweeps (a scalar-prox flavour of HQQ: after
/// the min/max init, alternate between re-quantizing and re-fitting scale
/// and zero to minimize the l2 reconstruction error).
fn hqq_fit(g: &[f32]) -> (f32, f32) {
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for v in g {
        lo = lo.min(*v);
        hi = hi.max(*v);
    }
    if !lo.is_finite() || !hi.is_finite() {
        return (1.0, 0.0);
    }
    if hi - lo < 1e-12 {
        // constant group: encode exactly via the zero point
        return (1.0, 128.0 - lo);
    }
    let mut scale = (hi - lo) / 255.0;
    let mut zero = -lo / scale;
    // refinement sweeps (this extra work is HQQ's encode-time cost)
    for _ in 0..3 {
        // quantize with current params
        let q: Vec<f32> = g
            .iter()
            .map(|v| (v / scale + zero).round().clamp(0.0, 255.0))
            .collect();
        // re-fit scale, zero by least squares of v ~ scale*(q - zero)
        let n = g.len() as f32;
        let mean_q = q.iter().sum::<f32>() / n;
        let mean_v = g.iter().sum::<f32>() / n;
        let mut cov = 0f32;
        let mut var = 0f32;
        for (v, qq) in g.iter().zip(q.iter()) {
            cov += (qq - mean_q) * (v - mean_v);
            var += (qq - mean_q) * (qq - mean_q);
        }
        if var > 1e-12 && cov.abs() > 1e-12 {
            scale = cov / var;
            zero = mean_q - mean_v / scale;
        }
    }
    (scale.max(1e-12), zero)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift64;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = XorShift64::new(seed);
        (0..n)
            .map(|_| {
                // Box-Muller-ish via sum of uniforms (Irwin–Hall), plenty
                // Gaussian for codec testing
                let s: f64 = (0..12).map(|_| rng.next_f64()).sum::<f64>() - 6.0;
                s as f32
            })
            .collect()
    }

    #[test]
    fn f32_roundtrip_exact() {
        let v = randn(256, 1);
        let q = Quantizer::F32;
        assert_eq!(q.decode(&q.encode(&v)).unwrap(), v);
        assert_eq!(q.encode(&v).len(), q.encoded_len(v.len()));
    }

    #[test]
    fn quanto_roundtrip_accurate() {
        let v = randn(32 * 64, 2);
        let q = Quantizer::QuantoInt8 { group: 32 };
        let enc = q.encode(&v);
        assert_eq!(enc.len(), q.encoded_len(v.len()));
        let dec = q.decode(&enc).unwrap();
        let max_err = v
            .iter()
            .zip(&dec)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        let amax = v.iter().fold(0f32, |m, x| m.max(x.abs()));
        assert!(max_err <= amax / 127.0 + 1e-6, "max_err={max_err}");
    }

    #[test]
    fn hqq_roundtrip_accurate_and_beats_or_matches_quanto_on_shifted_data() {
        // asymmetric data is where zero-points pay off
        let v: Vec<f32> = randn(32 * 64, 3).iter().map(|x| x + 5.0).collect();
        let hqq = Quantizer::HqqInt8 { group: 32 };
        let quanto = Quantizer::QuantoInt8 { group: 32 };
        let mse = |q: &Quantizer| {
            let dec = q.decode(&q.encode(&v)).unwrap();
            v.iter().zip(&dec).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / v.len() as f32
        };
        let (eh, eq) = (mse(&hqq), mse(&quanto));
        assert!(eh <= eq, "hqq {eh} should beat quanto {eq} on shifted data");
        assert!(eh < 1e-2);
    }

    #[test]
    fn compression_ratios() {
        let n = 1024;
        assert_eq!(Quantizer::F32.encoded_len(n), 4096);
        // quanto: ~3.56x smaller at group 32
        assert_eq!(Quantizer::QuantoInt8 { group: 32 }.encoded_len(n), 32 * 36);
        // hqq: slightly larger metadata
        assert_eq!(Quantizer::HqqInt8 { group: 32 }.encoded_len(n), 32 * 40);
    }

    #[test]
    fn constant_and_zero_groups() {
        for q in [
            Quantizer::QuantoInt8 { group: 8 },
            Quantizer::HqqInt8 { group: 8 },
        ] {
            let zeros = vec![0f32; 16];
            let dec = q.decode(&q.encode(&zeros)).unwrap();
            assert!(dec.iter().all(|v| v.abs() < 1e-6), "{:?}", q);
            let consts = vec![3.5f32; 16];
            let dec = q.decode(&q.encode(&consts)).unwrap();
            for v in dec {
                assert!((v - 3.5).abs() < 0.05, "{:?}: {v}", q);
            }
        }
    }

    #[test]
    fn wire_id_roundtrip() {
        for q in [
            Quantizer::F32,
            Quantizer::QuantoInt8 { group: 32 },
            Quantizer::HqqInt8 { group: 32 },
        ] {
            assert_eq!(Quantizer::from_id(q.id(), 32), Some(q));
        }
        assert_eq!(Quantizer::from_id(9, 32), None);
    }

    #[test]
    fn corrupt_lengths_error() {
        let q = Quantizer::QuantoInt8 { group: 32 };
        assert!(q.decode(&[0u8; 35]).is_err());
        assert!(Quantizer::F32.decode(&[0u8; 3]).is_err());
        assert!(Quantizer::HqqInt8 { group: 32 }.decode(&[0u8; 41]).is_err());
    }

    #[test]
    fn hqq_encode_slower_than_quanto() {
        // Table 3's behaviour: the fancier quantizer costs more encode
        // time.  Compare instruction-proxy: we just assert both complete
        // and hqq does >= the work (3 refinement sweeps); timing is
        // covered by the hotpath bench.
        let v = randn(32 * 256, 4);
        let t0 = std::time::Instant::now();
        for _ in 0..20 {
            Quantizer::QuantoInt8 { group: 32 }.encode(&v);
        }
        let tq = t0.elapsed();
        let t0 = std::time::Instant::now();
        for _ in 0..20 {
            Quantizer::HqqInt8 { group: 32 }.encode(&v);
        }
        let th = t0.elapsed();
        assert!(th >= tq / 2, "hqq {th:?} vs quanto {tq:?}");
    }
}
