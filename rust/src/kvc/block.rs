//! Token blocks and chained block hashing (§3.1, §3.8 steps 1–2).
//!
//! A prompt's token sequence is split into fixed-size blocks; block `i`'s
//! key is `SHA256(key_{i-1} || le_bytes(tokens_i))` with a null (all-zero)
//! key before block 0.  A block key therefore commits to the *entire*
//! prefix, so "find the matching hash furthest toward the end" (the
//! longest cached prefix) needs no further comparison of earlier blocks.
//! Only full blocks are keyed — a trailing partial block is recomputed,
//! exactly like vLLM's prefix-caching blocks the paper's baseline follows.

use super::hash::{sha256, Sha256, DIGEST_LEN};

/// A chained block key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockHash(pub [u8; DIGEST_LEN]);

impl BlockHash {
    /// The null hash preceding block 0.
    pub const NULL: BlockHash = BlockHash([0u8; DIGEST_LEN]);

    pub fn as_bytes(&self) -> &[u8; DIGEST_LEN] {
        &self.0
    }

    pub fn to_hex(&self) -> String {
        super::hash::to_hex(&self.0)
    }

    /// Short prefix for logs.
    pub fn short(&self) -> String {
        self.to_hex()[..12].to_string()
    }
}

impl From<[u8; DIGEST_LEN]> for BlockHash {
    fn from(d: [u8; DIGEST_LEN]) -> Self {
        BlockHash(d)
    }
}

/// Chain one step: `H_i = SHA256(H_{i-1} || tokens)`.
pub fn chain_hash(prev: &BlockHash, tokens: &[i32]) -> BlockHash {
    let mut h = Sha256::new();
    h.update(prev.as_bytes());
    for t in tokens {
        h.update(&t.to_le_bytes());
    }
    BlockHash(h.finalize())
}

/// Chained hashes for every *full* block of `tokens` (§3.8 steps 1–2).
pub fn block_hashes(tokens: &[i32], block_size: usize) -> Vec<BlockHash> {
    assert!(block_size > 0);
    let mut out = Vec::with_capacity(tokens.len() / block_size);
    let mut prev = BlockHash::NULL;
    for block in tokens.chunks_exact(block_size) {
        prev = chain_hash(&prev, block);
        out.push(prev);
    }
    out
}

/// Number of full blocks (the cacheable prefix length in blocks).
pub fn full_blocks(n_tokens: usize, block_size: usize) -> usize {
    n_tokens / block_size
}

/// A convenience digest of arbitrary bytes used as a cache-namespace key:
/// the cache is only valid for one (model, tokenizer) pair (§3.3), so the
/// manager mixes this fingerprint into the chain root.
pub fn model_fingerprint(model_id: &str, tokenizer_id: &str, weights_digest: &[u8]) -> BlockHash {
    let mut h = Sha256::new();
    h.update(model_id.as_bytes());
    h.update(&[0]);
    h.update(tokenizer_id.as_bytes());
    h.update(&[0]);
    h.update(weights_digest);
    BlockHash(h.finalize())
}

/// Chained hashes with a model fingerprint as the chain root.
pub fn block_hashes_for_model(
    tokens: &[i32],
    block_size: usize,
    fingerprint: &BlockHash,
) -> Vec<BlockHash> {
    assert!(block_size > 0);
    let mut out = Vec::with_capacity(tokens.len() / block_size);
    let mut prev = *fingerprint;
    for block in tokens.chunks_exact(block_size) {
        prev = chain_hash(&prev, block);
        out.push(prev);
    }
    out
}

#[allow(unused)]
fn _assert_digest_is_32() {
    let _ = sha256(b"");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_commits_to_prefix() {
        let a = vec![1i32, 2, 3, 4, 5, 6, 7, 8];
        let b = vec![9i32, 2, 3, 4, 5, 6, 7, 8]; // first token differs
        let ha = block_hashes(&a, 4);
        let hb = block_hashes(&b, 4);
        assert_eq!(ha.len(), 2);
        assert_ne!(ha[0], hb[0]);
        // second block tokens identical, but hash differs because the
        // chain differs
        assert_ne!(ha[1], hb[1]);
    }

    #[test]
    fn shared_prefix_shares_hashes() {
        let a = vec![1i32, 2, 3, 4, 5, 6, 7, 8];
        let b = vec![1i32, 2, 3, 4, 9, 9, 9, 9];
        let ha = block_hashes(&a, 4);
        let hb = block_hashes(&b, 4);
        assert_eq!(ha[0], hb[0], "same first block, same hash");
        assert_ne!(ha[1], hb[1]);
    }

    #[test]
    fn partial_blocks_not_keyed() {
        let tokens = vec![1i32; 10];
        assert_eq!(block_hashes(&tokens, 4).len(), 2);
        assert_eq!(full_blocks(10, 4), 2);
        assert_eq!(block_hashes(&tokens[..8], 4), block_hashes(&tokens, 4));
    }

    #[test]
    fn token_value_boundaries() {
        // token serialization must distinguish sign/width cleanly
        let a = block_hashes(&[i32::MAX, i32::MIN, -1, 0], 4);
        let b = block_hashes(&[i32::MAX, i32::MIN, -1, 1], 4);
        assert_ne!(a[0], b[0]);
    }

    #[test]
    fn block_size_one() {
        let h = block_hashes(&[5, 6], 1);
        assert_eq!(h.len(), 2);
        assert_eq!(h[0], chain_hash(&BlockHash::NULL, &[5]));
        assert_eq!(h[1], chain_hash(&h[0], &[6]));
    }

    #[test]
    fn model_fingerprint_separates_caches() {
        let t = vec![1i32; 8];
        let f1 = model_fingerprint("m1", "bytes", b"w1");
        let f2 = model_fingerprint("m1", "bytes", b"w2"); // different weights
        let f3 = model_fingerprint("m1", "bpe", b"w1"); // different tokenizer
        let h1 = block_hashes_for_model(&t, 4, &f1);
        let h2 = block_hashes_for_model(&t, 4, &f2);
        let h3 = block_hashes_for_model(&t, 4, &f3);
        assert_ne!(h1[0], h2[0]);
        assert_ne!(h1[0], h3[0]);
    }

    #[test]
    fn null_root_matches_plain_chain() {
        let t = vec![7i32; 8];
        assert_eq!(
            block_hashes(&t, 4),
            block_hashes_for_model(&t, 4, &BlockHash::NULL)
        );
    }
}
