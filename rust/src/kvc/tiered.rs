//! Local memory tier (§2: "A key-value cache can be stored in memory
//! hierarchies and our solution can be integrated into a stack of both
//! faster and slower memory").
//!
//! [`LocalTier`] is the fast-RAM level in front of the LEO level: the
//! manager consults it before touching the constellation and refills it on
//! every fetch/store, with its own LRU byte budget.  It stores *decoded*
//! KV values (the form the engine consumes), trading host memory for the
//! dequantize + network round-trip.

use crate::kvc::block::BlockHash;
use crate::kvc::eviction::LruTracker;
use crate::kvc::session::BlockRefs;
use crate::obs::mem::{FootprintEstimate, MemFootprint};
use std::collections::HashMap;
use std::mem::size_of;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Tier statistics.
#[derive(Debug, Default)]
pub struct TierStats {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub inserts: AtomicU64,
    pub evictions: AtomicU64,
    /// Evictions deflected by a live session reference.
    pub pinned_skips: AtomicU64,
}

struct Inner {
    map: HashMap<BlockHash, Vec<f32>>,
    lru: LruTracker<BlockHash>,
    bytes_used: usize,
    /// Session refcounts to consult before evicting (None = none).
    refs: Option<Arc<BlockRefs>>,
}

/// A bounded local block cache (thread-safe).
pub struct LocalTier {
    inner: Mutex<Inner>,
    byte_budget: usize,
    pub stats: TierStats,
}

impl LocalTier {
    pub fn new(byte_budget: usize) -> Self {
        Self {
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                lru: LruTracker::new(),
                bytes_used: 0,
                refs: None,
            }),
            byte_budget,
            stats: TierStats::default(),
        }
    }

    /// Install the session-layer reference table: referenced blocks are
    /// pinned against LRU pressure (invalidation still applies — a
    /// propagated eviction means the constellation copy is gone, and the
    /// local tier is a cache of it, not the owner).
    pub fn set_block_refs(&self, refs: Arc<BlockRefs>) {
        self.inner.lock().unwrap().refs = Some(refs);
    }

    pub fn byte_budget(&self) -> usize {
        self.byte_budget
    }

    pub fn bytes_used(&self) -> usize {
        self.inner.lock().unwrap().bytes_used
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fetch a block's KV values (refreshes LRU).
    pub fn get(&self, block: &BlockHash) -> Option<Vec<f32>> {
        let mut inner = self.inner.lock().unwrap();
        if let Some(v) = inner.map.get(block).cloned() {
            inner.lru.touch(block);
            self.stats.hits.fetch_add(1, Ordering::Relaxed);
            Some(v)
        } else {
            self.stats.misses.fetch_add(1, Ordering::Relaxed);
            None
        }
    }

    /// Insert (or refresh) a block, evicting LRU entries over budget.
    pub fn put(&self, block: BlockHash, values: Vec<f32>) {
        let bytes = values.len() * 4;
        if bytes > self.byte_budget {
            return; // cannot ever fit
        }
        let mut inner = self.inner.lock().unwrap();
        if let Some(old) = inner.map.remove(&block) {
            inner.bytes_used -= old.len() * 4;
            inner.lru.remove(&block);
        }
        let mut skipped: Vec<BlockHash> = Vec::new();
        while inner.bytes_used + bytes > self.byte_budget {
            let Some(victim) = inner.lru.pop_lru() else { break };
            if inner.refs.as_ref().is_some_and(|r| r.is_pinned(&victim)) {
                if let Some(r) = &inner.refs {
                    r.note_deflection();
                }
                self.stats.pinned_skips.fetch_add(1, Ordering::Relaxed);
                skipped.push(victim);
                continue;
            }
            if let Some(old) = inner.map.remove(&victim) {
                inner.bytes_used -= old.len() * 4;
                self.stats.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        // pinned survivors re-enter at the fresh end; when everything
        // was pinned the tier runs soft-over-budget for this insert
        for k in &skipped {
            inner.lru.touch(k);
        }
        inner.bytes_used += bytes;
        inner.lru.touch(&block);
        inner.map.insert(block, values);
        self.stats.inserts.fetch_add(1, Ordering::Relaxed);
    }

    /// Drop a block (propagated eviction).
    pub fn invalidate(&self, block: &BlockHash) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(old) = inner.map.remove(block) {
            inner.bytes_used -= old.len() * 4;
            inner.lru.remove(block);
        }
    }

    /// Hit rate so far.
    pub fn hit_rate(&self) -> f64 {
        let h = self.stats.hits.load(Ordering::Relaxed) as f64;
        let m = self.stats.misses.load(Ordering::Relaxed) as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }
}

impl MemFootprint for LocalTier {
    /// Payload = the tracked decoded-KV bytes (what `byte_budget`
    /// meters).  Index = one map slot per block plus the LRU tracker.
    /// Overhead = one heap allocation per value buffer plus the map
    /// table.
    fn mem_footprint(&self) -> FootprintEstimate {
        let inner = self.inner.lock().unwrap();
        let blocks = inner.map.len() as u64;
        let slot = (size_of::<BlockHash>() + size_of::<Vec<f32>>() + 1) as u64;
        let mut est = FootprintEstimate {
            payload_bytes: inner.bytes_used as u64,
            index_bytes: blocks * slot,
            ..FootprintEstimate::ZERO
        };
        est.charge_allocs(blocks + 1);
        est.add(inner.lru.footprint());
        est
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bh(b: u8) -> BlockHash {
        BlockHash([b; 32])
    }

    #[test]
    fn get_put_roundtrip() {
        let t = LocalTier::new(1 << 20);
        assert_eq!(t.get(&bh(1)), None);
        t.put(bh(1), vec![1.0, 2.0]);
        assert_eq!(t.get(&bh(1)), Some(vec![1.0, 2.0]));
        assert_eq!(t.stats.hits.load(Ordering::Relaxed), 1);
        assert_eq!(t.stats.misses.load(Ordering::Relaxed), 1);
        assert!((t.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn budget_evicts_lru() {
        let t = LocalTier::new(100); // 25 f32s
        t.put(bh(1), vec![0.0; 10]);
        t.put(bh(2), vec![0.0; 10]);
        t.get(&bh(1)); // refresh 1
        t.put(bh(3), vec![0.0; 10]); // evicts 2
        assert!(t.get(&bh(1)).is_some());
        assert!(t.get(&bh(2)).is_none());
        assert!(t.get(&bh(3)).is_some());
        assert_eq!(t.stats.evictions.load(Ordering::Relaxed), 1);
        assert!(t.bytes_used() <= 100);
    }

    #[test]
    fn oversized_rejected() {
        let t = LocalTier::new(8);
        t.put(bh(1), vec![0.0; 100]);
        assert!(t.is_empty());
    }

    #[test]
    fn overwrite_updates_bytes() {
        let t = LocalTier::new(1000);
        t.put(bh(1), vec![0.0; 100]);
        t.put(bh(1), vec![0.0; 50]);
        assert_eq!(t.bytes_used(), 200);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn footprint_follows_contents() {
        let t = LocalTier::new(1 << 20);
        let empty = t.mem_footprint().total();
        t.put(bh(1), vec![0.0; 100]);
        let one = t.mem_footprint();
        assert_eq!(one.payload_bytes, 400);
        assert!(one.total() > empty);
        t.invalidate(&bh(1));
        let back = t.mem_footprint();
        assert_eq!(back.payload_bytes, 0);
        assert_eq!(back.total(), empty);
    }

    #[test]
    fn pinned_blocks_survive_tier_pressure() {
        let refs = Arc::new(BlockRefs::new());
        let t = LocalTier::new(100); // 25 f32s
        t.set_block_refs(refs.clone());
        refs.acquire(&bh(1));
        t.put(bh(1), vec![0.0; 10]);
        t.put(bh(2), vec![0.0; 10]);
        // pressure: block 1 is LRU but pinned -> block 2 goes instead
        t.put(bh(3), vec![0.0; 10]);
        assert!(t.get(&bh(1)).is_some());
        assert!(t.get(&bh(2)).is_none());
        assert!(t.get(&bh(3)).is_some());
        assert_eq!(t.stats.pinned_skips.load(Ordering::Relaxed), 1);
        // invalidation still applies: the constellation copy is gone
        t.invalidate(&bh(1));
        assert!(t.get(&bh(1)).is_none());
    }

    #[test]
    fn invalidate_propagates_evictions() {
        let t = LocalTier::new(1000);
        t.put(bh(1), vec![1.0]);
        t.invalidate(&bh(1));
        assert_eq!(t.get(&bh(1)), None);
        assert_eq!(t.bytes_used(), 0);
    }
}
