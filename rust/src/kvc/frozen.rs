//! Epoch-frozen two-layer chunk index: an immutable, compacted *frozen*
//! layer plus a small mutable *delta* absorbing the live epoch's writes.
//!
//! The Box-heavy radix tree ([`crate::kvc::radix`]) and the managers'
//! per-block BTreeMaps pay ~200 modeled bytes per indexed prefix once
//! per-allocation overhead is charged.  At "billions of cached prefixes"
//! scale (ROADMAP) the index itself becomes the capacity bottleneck, so
//! this module stores the cold majority of keys in a handful of large
//! flat allocations instead of one heap object per node/entry:
//!
//! * [`FrozenArena`] — the frozen layer.  A sorted key arena with front
//!   coding (FST-style prefix compression) over the 32-byte chained
//!   block hashes: each key stores only its suffix after the longest
//!   common prefix with its predecessor, with a full restart key every
//!   [`RESTART_INTERVAL`] entries so lookups binary-search the restarts
//!   and decode at most one bucket.  Three `Vec`s total — suffix bytes,
//!   a `u32` offset table, and the values — so the whole layer costs
//!   `suffix + 4 + size_of::<V>()` bytes per key and three allocations.
//! * [`FrozenBlockIndex`] — the [`crate::kvc::manager::KvcManager`]
//!   index: a [`RadixTree`] delta over concatenated chain keys (the
//!   §3.10 structure, unchanged) in front of a [`FrozenArena`] keyed by
//!   each prefix's *terminal* hash — valid because chained hashes commit
//!   to their whole prefix, so the last hash alone identifies the chain.
//! * [`FrozenMap`] — the [`crate::federation::manager::FederatedKvcManager`]
//!   index: a `BTreeMap` delta with copy-on-write `get_mut` in front of
//!   the same arena.
//!
//! Lookups consult delta-then-frozen; removals of frozen keys leave a
//! *tombstone* in the delta that shadows the frozen entry.  At each
//! epoch boundary (`end_of_epoch` in both managers) [`FrozenBlockIndex::compact`]
//! / [`FrozenMap::compact`] merge the delta into a new frozen
//! generation, dropping tombstoned keys and preserving everything else —
//! so blocks pinned by [`crate::kvc::session::BlockRefs`] always
//! survive.  The differential oracle in `rust/tests/frozen_index_oracle.rs`
//! proves the two-layer index observationally identical to the plain
//! structures it replaces.

use crate::kvc::block::BlockHash;
use crate::kvc::radix::{BlockMeta, RadixTree};
use crate::obs::mem::{FootprintEstimate, MemFootprint};
use std::collections::BTreeMap;
use std::mem::size_of;

/// Every `RESTART_INTERVAL`-th arena entry stores its full 32-byte key
/// (front coding resets), bounding a lookup's linear decode to one
/// bucket of this size.
pub const RESTART_INTERVAL: usize = 16;

/// Key length of the frozen layer: one chained block hash.
const KEY_LEN: usize = 32;

fn common_prefix(a: &[u8; KEY_LEN], b: &[u8; KEY_LEN]) -> usize {
    a.iter().zip(b.iter()).take_while(|(x, y)| x == y).count()
}

/// The immutable frozen layer: front-coded sorted 32-byte keys in one
/// flat byte arena, a `u32` offset table, and a parallel value array.
///
/// Entry `i` stores `key[lcp..]` where `lcp` is the common prefix with
/// entry `i-1` (forced to 0 at restarts), so `lcp = KEY_LEN - suffix_len`
/// is derivable from the offset table alone.  Built only by
/// [`FrozenArena::from_sorted`]; never mutated in place.
pub struct FrozenArena<V> {
    /// Concatenated key suffixes.
    arena: Vec<u8>,
    /// `offsets[i]..offsets[i+1]` is entry `i`'s suffix (`len + 1`
    /// entries when non-empty, exactly sized).
    offsets: Vec<u32>,
    vals: Vec<V>,
}

impl<V> Default for FrozenArena<V> {
    fn default() -> Self {
        Self::new()
    }
}

impl<V: Copy> FrozenArena<V> {
    pub fn new() -> Self {
        Self { arena: Vec::new(), offsets: Vec::new(), vals: Vec::new() }
    }

    fn lcp_at(entries: &[([u8; KEY_LEN], V)], i: usize) -> usize {
        if i % RESTART_INTERVAL == 0 {
            0
        } else {
            common_prefix(&entries[i - 1].0, &entries[i].0)
        }
    }

    /// Build a frozen generation from entries sorted by key, strictly
    /// ascending.  Allocations are exact-capacity so the modeled
    /// footprint matches the measured one under `--features mem-profile`.
    pub fn from_sorted(entries: &[([u8; KEY_LEN], V)]) -> Self {
        if entries.is_empty() {
            return Self::new();
        }
        let mut arena_len = 0usize;
        for i in 0..entries.len() {
            debug_assert!(i == 0 || entries[i - 1].0 < entries[i].0, "keys strictly ascending");
            arena_len += KEY_LEN - Self::lcp_at(entries, i);
        }
        let mut arena = Vec::with_capacity(arena_len);
        let mut offsets = Vec::with_capacity(entries.len() + 1);
        let mut vals = Vec::with_capacity(entries.len());
        offsets.push(0u32);
        for (i, (key, v)) in entries.iter().enumerate() {
            let lcp = Self::lcp_at(entries, i);
            arena.extend_from_slice(&key[lcp..]);
            offsets.push(arena.len() as u32);
            vals.push(*v);
        }
        debug_assert_eq!(arena.len(), arena_len);
        Self { arena, offsets, vals }
    }

    pub fn len(&self) -> usize {
        self.vals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }

    fn suffix(&self, i: usize) -> &[u8] {
        &self.arena[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }

    /// Exact lookup: binary search the restart keys (stored in full),
    /// then decode at most one bucket front-to-back.
    pub fn get(&self, key: &[u8; KEY_LEN]) -> Option<&V> {
        let n = self.vals.len();
        if n == 0 {
            return None;
        }
        let n_restarts = n.div_ceil(RESTART_INTERVAL);
        // count restarts whose (full) key is <= the target
        let mut lo = 0usize;
        let mut hi = n_restarts;
        while lo < hi {
            let mid = (lo + hi) / 2;
            if self.suffix(mid * RESTART_INTERVAL) <= &key[..] {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        if lo == 0 {
            return None; // target sorts before the first key
        }
        let start = (lo - 1) * RESTART_INTERVAL;
        let end = (start + RESTART_INTERVAL).min(n);
        let mut scratch = [0u8; KEY_LEN];
        for i in start..end {
            let suffix = self.suffix(i);
            // lcp is relative to the immediate predecessor, whose key is
            // what the scratch currently holds
            scratch[KEY_LEN - suffix.len()..].copy_from_slice(suffix);
            match scratch.cmp(key) {
                std::cmp::Ordering::Equal => return Some(&self.vals[i]),
                std::cmp::Ordering::Greater => return None,
                std::cmp::Ordering::Less => {}
            }
        }
        None
    }

    pub fn contains(&self, key: &[u8; KEY_LEN]) -> bool {
        self.get(key).is_some()
    }

    /// Visit every entry in key order, decoding keys incrementally.
    pub fn for_each(&self, mut f: impl FnMut(&[u8; KEY_LEN], &V)) {
        let mut scratch = [0u8; KEY_LEN];
        for (i, v) in self.vals.iter().enumerate() {
            let suffix = self.suffix(i);
            scratch[KEY_LEN - suffix.len()..].copy_from_slice(suffix);
            f(&scratch, v);
        }
    }

    /// Frozen-layer footprint: the three flat arrays, three modeled
    /// allocations total, tagged as `frozen_bytes`.
    pub fn footprint(&self) -> FootprintEstimate {
        let mut est = FootprintEstimate::ZERO;
        if self.vals.is_empty() {
            return est;
        }
        est.index_bytes = self.arena.len() as u64
            + (self.offsets.len() * size_of::<u32>()) as u64
            + (self.vals.len() * size_of::<V>()) as u64;
        est.charge_allocs(3);
        est.frozen_bytes = est.index_bytes + est.overhead_bytes;
        est
    }
}

/// The two-layer §3.10 block index replacing [`crate::kvc::radix::BlockIndex`]
/// inside [`crate::kvc::manager::KvcManager`].
///
/// The delta keeps the radix tree over concatenated chain keys (`None`
/// values are tombstones shadowing frozen entries); the frozen layer is
/// keyed by each prefix's terminal hash.  `len` counts live keys across
/// both layers and is maintained incrementally.
pub struct FrozenBlockIndex {
    delta: RadixTree<Option<BlockMeta>>,
    frozen: FrozenArena<BlockMeta>,
    live: usize,
    compactions: u64,
}

impl Default for FrozenBlockIndex {
    fn default() -> Self {
        Self::new()
    }
}

impl FrozenBlockIndex {
    pub fn new() -> Self {
        Self { delta: RadixTree::new(), frozen: FrozenArena::new(), live: 0, compactions: 0 }
    }

    fn key_for(hashes: &[BlockHash]) -> Vec<u8> {
        let mut key = Vec::with_capacity(KEY_LEN * hashes.len());
        for h in hashes {
            key.extend_from_slice(h.as_bytes());
        }
        key
    }

    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Live keys in the frozen layer (tombstoned entries still count
    /// until the next compaction rewrites the generation).
    pub fn frozen_len(&self) -> usize {
        self.frozen.len()
    }

    /// Entries (writes + tombstones) in the mutable delta.
    pub fn delta_len(&self) -> usize {
        self.delta.len()
    }

    /// Frozen generations built so far.
    pub fn compactions(&self) -> u64 {
        self.compactions
    }

    /// Record that the prefix ending at `hashes.last()` is cached.
    pub fn insert(&mut self, hashes: &[BlockHash], meta: BlockMeta) {
        assert!(!hashes.is_empty());
        let prev = self.delta.insert(&Self::key_for(hashes), Some(meta));
        let was_live = match prev {
            Some(Some(_)) => true,
            Some(None) => false, // resurrecting a tombstoned key
            None => self.frozen.contains(hashes.last().unwrap().as_bytes()),
        };
        if !was_live {
            self.live += 1;
        }
    }

    /// Exact metadata for a prefix: delta first (a tombstone shadows the
    /// frozen layer), then the frozen arena by terminal hash.
    pub fn get(&self, hashes: &[BlockHash]) -> Option<BlockMeta> {
        match self.delta.get(&Self::key_for(hashes)) {
            Some(Some(m)) => Some(*m),
            Some(None) => None,
            None => self.frozen.get(hashes.last()?.as_bytes()).copied(),
        }
    }

    /// Drop the entry for a prefix (lazy eviction propagation): a key
    /// only in the delta is removed outright; a frozen key gains a delta
    /// tombstone that the next compaction turns into a real drop.
    pub fn remove(&mut self, hashes: &[BlockHash]) -> Option<BlockMeta> {
        assert!(!hashes.is_empty());
        let key = Self::key_for(hashes);
        let terminal = hashes.last().unwrap().as_bytes();
        let out = match self.delta.get(&key).copied() {
            Some(Some(m)) => {
                if self.frozen.contains(terminal) {
                    self.delta.insert(&key, None);
                } else {
                    self.delta.remove(&key);
                }
                Some(m)
            }
            Some(None) => None, // already tombstoned
            None => match self.frozen.get(terminal).copied() {
                Some(m) => {
                    self.delta.insert(&key, None);
                    Some(m)
                }
                None => None,
            },
        };
        if out.is_some() {
            self.live -= 1;
        }
        out
    }

    /// Longest cached prefix of the prompt's block-hash list: deepest
    /// live prefix across both layers (holes are jumped, matching the
    /// radix tree's deepest-match semantics).
    pub fn longest_cached_prefix(&self, hashes: &[BlockHash]) -> Option<(usize, BlockMeta)> {
        for k in (1..=hashes.len()).rev() {
            if let Some(m) = self.get(&hashes[..k]) {
                return Some((k, m));
            }
        }
        None
    }

    /// Every live entry as `(terminal hash, meta)`, sorted by terminal
    /// hash — the merged view compaction freezes and the oracle compares.
    pub fn entries(&self) -> Vec<([u8; KEY_LEN], BlockMeta)> {
        let mut ops: Vec<([u8; KEY_LEN], Option<BlockMeta>)> = self
            .delta
            .iter_collect()
            .into_iter()
            .map(|(key, v)| {
                debug_assert!(key.len() >= KEY_LEN && key.len() % KEY_LEN == 0);
                let mut t = [0u8; KEY_LEN];
                t.copy_from_slice(&key[key.len() - KEY_LEN..]);
                (t, *v)
            })
            .collect();
        ops.sort_unstable_by(|a, b| a.0.cmp(&b.0));
        let mut merged: Vec<([u8; KEY_LEN], BlockMeta)> = Vec::with_capacity(self.live);
        let mut di = 0usize;
        self.frozen.for_each(|key, v| {
            while di < ops.len() && ops[di].0 < *key {
                if let Some(m) = ops[di].1 {
                    merged.push((ops[di].0, m));
                }
                di += 1;
            }
            if di < ops.len() && ops[di].0 == *key {
                // delta overrides the frozen entry (tombstones drop it)
                if let Some(m) = ops[di].1 {
                    merged.push((ops[di].0, m));
                }
                di += 1;
            } else {
                merged.push((*key, *v));
            }
        });
        while di < ops.len() {
            if let Some(m) = ops[di].1 {
                merged.push((ops[di].0, m));
            }
            di += 1;
        }
        debug_assert_eq!(merged.len(), self.live);
        merged
    }

    /// Epoch-boundary compaction: merge the delta into a new frozen
    /// generation (delta wins, tombstoned keys drop, everything else —
    /// pinned or not — survives) and reset the delta.  No-op (and no
    /// generation bump) when the delta is empty, so repeated boundaries
    /// without writes never rebuild the arena.
    pub fn compact(&mut self) -> bool {
        if self.delta.is_empty() {
            return false;
        }
        let merged = self.entries();
        self.frozen = FrozenArena::from_sorted(&merged);
        self.delta = RadixTree::new();
        self.compactions += 1;
        true
    }

    /// The frozen layer's own footprint (tagged `frozen_bytes`).
    pub fn frozen_footprint(&self) -> FootprintEstimate {
        self.frozen.footprint()
    }

    /// The delta layer's own footprint (the radix model, tagged
    /// `delta_bytes`).
    pub fn delta_footprint(&self) -> FootprintEstimate {
        let mut est = self.delta.mem_footprint();
        est.delta_bytes = est.index_bytes + est.overhead_bytes;
        est
    }
}

impl MemFootprint for FrozenBlockIndex {
    fn mem_footprint(&self) -> FootprintEstimate {
        let mut est = self.frozen_footprint();
        est.add(self.delta_footprint());
        est
    }
}

/// The federated two-layer index: a `BTreeMap<BlockHash, Option<V>>`
/// delta (`None` = tombstone) in front of a [`FrozenArena`].
///
/// `get_mut` copies a frozen entry into the delta on first mutation
/// (copy-on-write); the stale frozen copy stays shadowed until the next
/// compaction rewrites the generation.  Iteration ([`FrozenMap::entries`])
/// merges both layers in key order, reproducing the BTreeMap's
/// deterministic order exactly.
pub struct FrozenMap<V> {
    frozen: FrozenArena<V>,
    delta: BTreeMap<BlockHash, Option<V>>,
    live: usize,
    compactions: u64,
}

impl<V> Default for FrozenMap<V> {
    fn default() -> Self {
        Self::new()
    }
}

impl<V> FrozenMap<V> {
    pub fn new() -> Self {
        Self { frozen: FrozenArena::new(), delta: BTreeMap::new(), live: 0, compactions: 0 }
    }
}

impl<V: Copy> FrozenMap<V> {
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    pub fn frozen_len(&self) -> usize {
        self.frozen.len()
    }

    pub fn delta_len(&self) -> usize {
        self.delta.len()
    }

    pub fn compactions(&self) -> u64 {
        self.compactions
    }

    pub fn get(&self, h: &BlockHash) -> Option<&V> {
        match self.delta.get(h) {
            Some(slot) => slot.as_ref(),
            None => self.frozen.get(h.as_bytes()),
        }
    }

    pub fn contains_key(&self, h: &BlockHash) -> bool {
        self.get(h).is_some()
    }

    /// Mutable access with copy-on-write: a frozen entry is copied into
    /// the delta first, shadowing the frozen copy until compaction.
    pub fn get_mut(&mut self, h: &BlockHash) -> Option<&mut V> {
        use std::collections::btree_map::Entry;
        match self.delta.entry(*h) {
            Entry::Occupied(e) => e.into_mut().as_mut(),
            Entry::Vacant(slot) => {
                let v = *self.frozen.get(h.as_bytes())?;
                slot.insert(Some(v)).as_mut()
            }
        }
    }

    pub fn insert(&mut self, h: BlockHash, v: V) -> Option<V> {
        let prev = match self.delta.insert(h, Some(v)) {
            Some(slot) => slot,
            None => self.frozen.get(h.as_bytes()).copied(),
        };
        if prev.is_none() {
            self.live += 1;
        }
        prev
    }

    pub fn remove(&mut self, h: &BlockHash) -> Option<V> {
        let out = match self.delta.get(h).copied() {
            Some(Some(v)) => {
                if self.frozen.contains(h.as_bytes()) {
                    self.delta.insert(*h, None);
                } else {
                    self.delta.remove(h);
                }
                Some(v)
            }
            Some(None) => None,
            None => match self.frozen.get(h.as_bytes()).copied() {
                Some(v) => {
                    self.delta.insert(*h, None);
                    Some(v)
                }
                None => None,
            },
        };
        if out.is_some() {
            self.live -= 1;
        }
        out
    }

    /// Every live entry in key order: a two-pointer merge of the sorted
    /// delta and the sorted frozen arena (delta wins, tombstones drop) —
    /// byte-identical to the iteration order of the plain BTreeMap it
    /// replaces.
    pub fn entries(&self) -> Vec<(BlockHash, V)> {
        let mut merged: Vec<(BlockHash, V)> = Vec::with_capacity(self.live);
        let mut di = self.delta.iter().peekable();
        self.frozen.for_each(|key, v| {
            while let Some((dh, slot)) = di.peek() {
                if dh.as_bytes() < key {
                    if let Some(dv) = slot {
                        merged.push((**dh, *dv));
                    }
                    di.next();
                } else {
                    break;
                }
            }
            if let Some((dh, slot)) = di.peek() {
                if dh.as_bytes() == key {
                    if let Some(dv) = slot {
                        merged.push((**dh, *dv));
                    }
                    di.next();
                    return;
                }
            }
            merged.push((BlockHash(*key), *v));
        });
        for (dh, slot) in di {
            if let Some(dv) = slot {
                merged.push((*dh, *dv));
            }
        }
        debug_assert_eq!(merged.len(), self.live);
        merged
    }

    /// Epoch-boundary compaction (see [`FrozenBlockIndex::compact`]).
    pub fn compact(&mut self) -> bool {
        if self.delta.is_empty() {
            return false;
        }
        let merged: Vec<([u8; KEY_LEN], V)> =
            self.entries().into_iter().map(|(h, v)| (h.0, v)).collect();
        self.frozen = FrozenArena::from_sorted(&merged);
        self.delta.clear();
        self.compactions += 1;
        true
    }

    /// The frozen layer's own footprint (tagged `frozen_bytes`).
    pub fn frozen_footprint(&self) -> FootprintEstimate {
        self.frozen.footprint()
    }

    /// The delta layer's own footprint: the B-tree model (nodes hold up
    /// to 11 entries; one allocation per 11 plus two `usize` of node
    /// linkage per entry), tagged `delta_bytes`.
    pub fn delta_footprint(&self) -> FootprintEstimate {
        let len = self.delta.len() as u64;
        let slot = (size_of::<(BlockHash, Option<V>)>() + 2 * size_of::<usize>()) as u64;
        let mut est = FootprintEstimate { index_bytes: len * slot, ..FootprintEstimate::ZERO };
        est.charge_allocs(len.div_ceil(11));
        est.delta_bytes = est.index_bytes + est.overhead_bytes;
        est
    }
}

impl<V: Copy> MemFootprint for FrozenMap<V> {
    fn mem_footprint(&self) -> FootprintEstimate {
        let mut est = self.frozen_footprint();
        est.add(self.delta_footprint());
        est
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvc::block::block_hashes;

    fn meta(n: u32) -> BlockMeta {
        BlockMeta { num_chunks: n, kvc_len: n * 6000, write_epoch: 0, quantizer_id: 1 }
    }

    fn key(i: u64) -> [u8; 32] {
        let mut k = [0u8; 32];
        k[..8].copy_from_slice(&i.to_be_bytes());
        k
    }

    #[test]
    fn arena_roundtrip_and_order() {
        let entries: Vec<([u8; 32], u64)> = (0..100u64).map(|i| (key(i * 3), i)).collect();
        let arena = FrozenArena::from_sorted(&entries);
        assert_eq!(arena.len(), 100);
        for (k, v) in &entries {
            assert_eq!(arena.get(k), Some(v));
        }
        // misses on both sides of every bucket
        assert_eq!(arena.get(&key(1)), None);
        assert_eq!(arena.get(&key(1000)), None);
        let mut seen = Vec::new();
        arena.for_each(|k, v| seen.push((*k, *v)));
        assert_eq!(seen, entries, "iteration is key order");
    }

    #[test]
    fn arena_front_coding_compresses_shared_prefixes() {
        // consecutive big-endian keys share 7 leading bytes, so
        // non-restart suffixes are far shorter than full keys
        let entries: Vec<([u8; 32], u64)> = (0..64u64).map(|i| (key(i), i)).collect();
        let arena = FrozenArena::from_sorted(&entries);
        let est = arena.footprint();
        let uncompressed = (64 * 32 + 65 * 4 + 64 * 8) as u64;
        assert!(
            est.index_bytes < uncompressed,
            "front coding must beat full keys: {} vs {uncompressed}",
            est.index_bytes
        );
        assert_eq!(est.frozen_bytes, est.index_bytes + est.overhead_bytes);
        assert_eq!(est.delta_bytes, 0);
        for (k, v) in &entries {
            assert_eq!(arena.get(k), Some(v));
        }
    }

    #[test]
    fn empty_arena_weighs_nothing() {
        let arena = FrozenArena::<u64>::new();
        assert_eq!(arena.footprint(), FootprintEstimate::ZERO);
        assert_eq!(arena.get(&key(0)), None);
    }

    #[test]
    fn block_index_insert_get_remove_across_layers() {
        let tokens: Vec<i32> = (0..160).collect();
        let hashes = block_hashes(&tokens, 32); // 5 blocks
        let mut idx = FrozenBlockIndex::new();
        idx.insert(&hashes[..2], meta(22));
        idx.insert(&hashes[..4], meta(44));
        assert_eq!(idx.len(), 2);
        assert!(idx.compact());
        assert_eq!(idx.frozen_len(), 2);
        assert_eq!(idx.delta_len(), 0);
        // frozen entries answer lookups
        assert_eq!(idx.get(&hashes[..2]).unwrap().num_chunks, 22);
        let (blocks, m) = idx.longest_cached_prefix(&hashes).unwrap();
        assert_eq!((blocks, m.num_chunks), (4, 44));
        // a tombstone shadows the frozen entry
        assert_eq!(idx.remove(&hashes[..4]).unwrap().num_chunks, 44);
        assert_eq!(idx.get(&hashes[..4]), None);
        assert_eq!(idx.len(), 1);
        assert_eq!(idx.longest_cached_prefix(&hashes).unwrap().0, 2);
        // double remove is a no-op
        assert_eq!(idx.remove(&hashes[..4]), None);
        assert_eq!(idx.len(), 1);
        // compaction drops the tombstoned key for real
        assert!(idx.compact());
        assert_eq!(idx.frozen_len(), 1);
        assert_eq!(idx.compactions(), 2);
        // resurrect it with fresh metadata
        idx.insert(&hashes[..4], meta(99));
        assert_eq!(idx.len(), 2);
        assert_eq!(idx.get(&hashes[..4]).unwrap().num_chunks, 99);
    }

    #[test]
    fn block_index_longest_prefix_jumps_holes() {
        let tokens: Vec<i32> = (0..160).collect();
        let hashes = block_hashes(&tokens, 32);
        let mut idx = FrozenBlockIndex::new();
        idx.insert(&hashes[..1], meta(1));
        idx.insert(&hashes[..2], meta(2));
        idx.insert(&hashes[..4], meta(4)); // depth 3 is a hole
        idx.compact();
        assert_eq!(idx.longest_cached_prefix(&hashes).unwrap().0, 4);
        idx.remove(&hashes[..4]);
        assert_eq!(idx.longest_cached_prefix(&hashes).unwrap().0, 2);
    }

    #[test]
    fn block_index_compaction_is_noop_without_writes() {
        let tokens: Vec<i32> = (0..64).collect();
        let hashes = block_hashes(&tokens, 32);
        let mut idx = FrozenBlockIndex::new();
        idx.insert(&hashes[..1], meta(1));
        assert!(idx.compact());
        let before = idx.mem_footprint();
        assert!(!idx.compact(), "empty delta must not rebuild the generation");
        assert_eq!(idx.compactions(), 1);
        assert_eq!(idx.mem_footprint(), before);
    }

    #[test]
    fn block_index_footprint_splits_frozen_and_delta() {
        let tokens: Vec<i32> = (0..160).collect();
        let hashes = block_hashes(&tokens, 32);
        let mut idx = FrozenBlockIndex::new();
        for k in 1..=5 {
            idx.insert(&hashes[..k], meta(k as u32));
        }
        let pre = idx.mem_footprint();
        assert_eq!(pre.frozen_bytes, 0);
        assert!(pre.delta_bytes > 0);
        assert_eq!(pre.delta_bytes + pre.frozen_bytes, pre.index_bytes + pre.overhead_bytes);
        idx.compact();
        let post = idx.mem_footprint();
        assert!(post.frozen_bytes > 0);
        assert_eq!(post.delta_bytes, 0);
        assert!(
            post.total() <= pre.total(),
            "compaction must not grow the footprint: {} -> {}",
            pre.total(),
            post.total()
        );
    }

    #[test]
    fn frozen_map_cow_and_tombstones() {
        let tokens: Vec<i32> = (0..320).collect();
        let hashes = block_hashes(&tokens, 32); // 10 blocks
        let mut map = FrozenMap::new();
        for (i, h) in hashes.iter().enumerate() {
            assert_eq!(map.insert(*h, i as u64), None);
        }
        assert_eq!(map.len(), 10);
        assert!(map.compact());
        assert_eq!((map.frozen_len(), map.delta_len()), (10, 0));
        // copy-on-write mutation shadows the frozen copy
        *map.get_mut(&hashes[3]).unwrap() = 999;
        assert_eq!(map.delta_len(), 1);
        assert_eq!(map.get(&hashes[3]), Some(&999));
        assert_eq!(map.len(), 10);
        // remove a frozen key -> tombstone until compaction
        assert_eq!(map.remove(&hashes[5]), Some(5));
        assert_eq!(map.get(&hashes[5]), None);
        assert!(!map.contains_key(&hashes[5]));
        assert_eq!(map.len(), 9);
        assert_eq!(map.remove(&hashes[5]), None);
        // merged iteration matches a plain BTreeMap of the same content
        let mut oracle: BTreeMap<BlockHash, u64> = BTreeMap::new();
        for (i, h) in hashes.iter().enumerate() {
            oracle.insert(*h, i as u64);
        }
        oracle.insert(hashes[3], 999);
        oracle.remove(&hashes[5]);
        let want: Vec<(BlockHash, u64)> = oracle.iter().map(|(h, v)| (*h, *v)).collect();
        assert_eq!(map.entries(), want);
        map.compact();
        assert_eq!((map.frozen_len(), map.delta_len()), (9, 0));
        assert_eq!(map.entries(), want);
        assert_eq!(map.get(&hashes[3]), Some(&999));
    }

    #[test]
    fn frozen_map_compaction_shrinks_a_real_delta() {
        let tokens: Vec<i32> = (0..(64 * 32)).collect();
        let hashes = block_hashes(&tokens, 32); // 64 blocks
        let mut map = FrozenMap::new();
        for (i, h) in hashes.iter().enumerate() {
            map.insert(*h, i as u64);
        }
        let pre = map.mem_footprint();
        assert!(pre.delta_bytes > 0);
        map.compact();
        let post = map.mem_footprint();
        assert!(post.frozen_bytes > 0);
        assert!(
            post.total() < pre.total(),
            "freezing 64 B-tree entries must shrink the footprint: {} -> {}",
            pre.total(),
            post.total()
        );
    }
}
