//! The SkyMemory KVC manager — the §3.3 interface and the §3.8 protocol.
//!
//! ```text
//! class KVCManager:
//!   init(model, tokenizer)
//!   add_blocks(prompt)
//!   get_cache(prompt) -> KVC
//! ```
//!
//! Set path (§3.8): tokenize -> chained block hashes -> (for each block not
//! yet cached) quantize the block's KV tensor -> split into fixed-size
//! chunks -> map chunk `i` to server `i mod n` -> store on the strategy's
//! satellite layout, in parallel.
//!
//! Get path: longest cached prefix via the local radix index (§3.10) or
//! the distributed binary search (§3.8 steps 3-6), then fetch every cached
//! block's chunks in parallel, reassemble and dequantize.  A missing chunk
//! anywhere truncates the usable prefix and (lazy policy) triggers
//! eviction of the broken block.
//!
//! Parallelism (§3.1: "parallelism both in setting and getting a single
//! KVC") is modelled by the [`crate::net::sched`] virtual-time scheduler:
//! each block's chunk Get/Set set is submitted as one
//! [`crate::net::sched::NetScheduler::run_batch`] and the event engine
//! pipelines the transfers over per-link in-flight windows — no OS
//! threads, unbounded fan-out, deterministic completion order.
//!
//! Every stored chunk is prefixed with an 18-byte self-describing header
//! (quantizer, chunk count, byte length, write epoch) so the distributed
//! lookup path needs no local state at all.

use crate::constellation::topology::{SatId, Torus};
use crate::kvc::block::BlockHash;
use crate::kvc::chunk::{chunk_count, split_chunks, ChunkKey};
use crate::kvc::eviction::EvictionPolicy;
use crate::kvc::quantize::Quantizer;
use crate::kvc::frozen::FrozenBlockIndex;
use crate::kvc::radix::BlockMeta;
use crate::mapping::{box_width, Strategy};
use crate::net::messages::{Request, Response};
use crate::net::sched::{ChunkOp, ChunkResult, NetScheduler, SchedConfig, Transfer};
use crate::net::transport::Transport;
use crate::obs::mem::{FootprintEstimate, MemFootprint};
use crate::obs::{ArgVal, NoopSink, SpanKind, TraceEvent, TraceSink};
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Chunk payload header (see module docs).
pub const CHUNK_HEADER_LEN: usize = 18;
const CHUNK_VERSION: u8 = 1;

/// Encode the self-describing chunk header (shared with the federated
/// manager, which stores the same wire format across shells).
pub fn encode_chunk_header(
    quantizer_id: u8,
    num_chunks: u32,
    kvc_len: u32,
    write_epoch: u64,
) -> [u8; CHUNK_HEADER_LEN] {
    let mut h = [0u8; CHUNK_HEADER_LEN];
    h[0] = CHUNK_VERSION;
    h[1] = quantizer_id;
    h[2..6].copy_from_slice(&num_chunks.to_le_bytes());
    h[6..10].copy_from_slice(&kvc_len.to_le_bytes());
    h[10..18].copy_from_slice(&write_epoch.to_le_bytes());
    h
}

/// Decode a chunk header: (quantizer id, num chunks, kvc len, write epoch).
pub fn decode_chunk_header(data: &[u8]) -> Result<(u8, u32, u32, u64)> {
    if data.len() < CHUNK_HEADER_LEN || data[0] != CHUNK_VERSION {
        bail!("bad chunk header");
    }
    Ok((
        data[1],
        u32::from_le_bytes(data[2..6].try_into().unwrap()),
        u32::from_le_bytes(data[6..10].try_into().unwrap()),
        u64::from_le_bytes(data[10..18].try_into().unwrap()),
    ))
}

/// Manager configuration.
#[derive(Debug, Clone, Copy)]
pub struct KvcConfig {
    /// Tokens per block (paper: 128; our scaled model: 32).
    pub block_tokens: usize,
    /// Chunk payload size in bytes (paper: 6 kB).
    pub chunk_size: usize,
    /// Virtual servers to stripe over (paper testbed: 10 LOS satellites).
    pub n_servers: usize,
    pub strategy: Strategy,
    pub quantizer: Quantizer,
    pub eviction: EvictionPolicy,
    /// Use the local radix index (§3.10) instead of the distributed
    /// binary search for prefix lookup.
    pub use_radix_index: bool,
    /// Gossip radius for explicit evictions.
    pub gossip_ttl: u8,
    /// Per-link in-flight window of the chunk fan-out's virtual-time
    /// scheduler ([`crate::net::sched::SchedConfig::window`]).
    pub sched_window: usize,
}

impl Default for KvcConfig {
    fn default() -> Self {
        Self {
            block_tokens: 32,
            chunk_size: 6000,
            n_servers: 10,
            strategy: Strategy::RotationHopAware,
            quantizer: Quantizer::QuantoInt8 { group: 32 },
            eviction: EvictionPolicy::Gossip,
            use_radix_index: true,
            gossip_ttl: 2,
            sched_window: 8,
        }
    }
}

impl KvcConfig {
    /// Number of chunks a block of `n_values` f32s will produce under
    /// this configuration's quantizer and chunk size.
    pub fn chunks_for_values(&self, n_values: usize) -> usize {
        chunk_count(self.quantizer.encoded_len(n_values), self.chunk_size)
    }
}

/// Manager counters (exported via /metrics).
#[derive(Debug, Default)]
pub struct KvcStats {
    pub lookups: AtomicU64,
    pub prefix_hits: AtomicU64,
    pub blocks_fetched: AtomicU64,
    pub blocks_stored: AtomicU64,
    pub chunks_fetched: AtomicU64,
    pub chunks_stored: AtomicU64,
    pub bytes_fetched: AtomicU64,
    pub bytes_stored: AtomicU64,
    pub broken_blocks: AtomicU64,
}

/// A plain-value copy of [`KvcStats`] (for reports and deltas).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KvcStatsSnapshot {
    pub lookups: u64,
    pub prefix_hits: u64,
    pub blocks_fetched: u64,
    pub blocks_stored: u64,
    pub chunks_fetched: u64,
    pub chunks_stored: u64,
    pub bytes_fetched: u64,
    pub bytes_stored: u64,
    pub broken_blocks: u64,
}

impl KvcStats {
    pub fn snapshot(&self) -> KvcStatsSnapshot {
        let ld = |c: &AtomicU64| c.load(Ordering::Relaxed);
        KvcStatsSnapshot {
            lookups: ld(&self.lookups),
            prefix_hits: ld(&self.prefix_hits),
            blocks_fetched: ld(&self.blocks_fetched),
            blocks_stored: ld(&self.blocks_stored),
            chunks_fetched: ld(&self.chunks_fetched),
            chunks_stored: ld(&self.chunks_stored),
            bytes_fetched: ld(&self.bytes_fetched),
            bytes_stored: ld(&self.bytes_stored),
            broken_blocks: ld(&self.broken_blocks),
        }
    }
}

/// Result of a prefix fetch.
#[derive(Debug)]
pub struct PrefixFetch {
    /// Number of leading blocks whose KV was retrieved.
    pub blocks: usize,
    /// Dequantized KV values per block, in block order.
    pub kv_blocks: Vec<Vec<f32>>,
}

/// The SkyMemory cache manager.
pub struct KvcManager {
    pub config: KvcConfig,
    transport: Arc<dyn Transport>,
    /// The virtual-time scheduler every chunk fan-out rides (timing
    /// plane; `transport` stays the data plane).
    sched: NetScheduler,
    torus: Torus,
    /// Two-layer §3.10 index: an immutable epoch-compacted frozen arena
    /// plus a mutable radix delta ([`crate::kvc::frozen`]);
    /// [`Self::end_of_epoch`] freezes the live epoch's writes.
    index: Mutex<FrozenBlockIndex>,
    /// Optional fast-RAM tier in front of the constellation (§2's memory
    /// hierarchy: GPU/CPU RAM above the LEO level).
    local: Option<crate::kvc::tiered::LocalTier>,
    /// Flight-recorder sink for block-level Get/Set spans ([`NoopSink`]
    /// by default: the gated sites cost one `wants` call per block op).
    trace: Mutex<Arc<dyn TraceSink>>,
    pub stats: KvcStats,
}

impl KvcManager {
    pub fn new(config: KvcConfig, torus: Torus, transport: Arc<dyn Transport>) -> Self {
        assert!(config.n_servers >= 1);
        let sched =
            NetScheduler::new(transport.clone(), SchedConfig { window: config.sched_window });
        Self {
            config,
            transport,
            sched,
            torus,
            index: Mutex::new(FrozenBlockIndex::new()),
            local: None,
            trace: Mutex::new(Arc::new(NoopSink)),
            stats: KvcStats::default(),
        }
    }

    /// The chunk fan-out's virtual-time scheduler (for its stats).
    pub fn sched(&self) -> &NetScheduler {
        &self.sched
    }

    /// Route trace events from this manager and its scheduler to `sink`.
    /// Single-shell managers stamp every event with shell 0.
    pub fn set_trace_sink(&self, sink: Arc<dyn TraceSink>) {
        self.sched.set_trace_sink(sink.clone(), 0);
        *self.trace.lock().unwrap() = sink;
    }

    /// Add a local RAM tier of `byte_budget` decoded-KV bytes.
    pub fn with_local_tier(mut self, byte_budget: usize) -> Self {
        self.local = Some(crate::kvc::tiered::LocalTier::new(byte_budget));
        self
    }

    pub fn local_tier(&self) -> Option<&crate::kvc::tiered::LocalTier> {
        self.local.as_ref()
    }

    /// Install the session-layer reference table
    /// ([`crate::kvc::session::BlockRefs`]) on the local tier:
    /// session-referenced blocks are pinned against its LRU pressure.
    /// (The per-satellite stores are pinned via
    /// [`crate::satellite::fleet::Fleet::set_block_refs`].)
    pub fn set_block_refs(&self, refs: &Arc<crate::kvc::session::BlockRefs>) {
        if let Some(tier) = &self.local {
            tier.set_block_refs(refs.clone());
        }
    }

    pub fn transport(&self) -> &Arc<dyn Transport> {
        &self.transport
    }

    /// Current rotation epoch of the transport's ground view.
    pub fn transport_epoch(&self) -> u64 {
        self.transport.epoch()
    }

    /// Current rotation epoch as the transport's ground view sees it.
    fn write_center_for_epoch(&self, epoch: u64, now_epoch: u64) -> SatId {
        // the centre moves one slot west per epoch; a block written
        // `now_epoch - epoch` epochs ago had its centre that many slots east
        let delta = (now_epoch - epoch) as i32;
        self.torus.offset(self.transport.closest(), 0, delta)
    }

    /// Satellite currently hosting `server_idx` (0-based) for a block
    /// written at `write_epoch`, given `now_epoch`.
    pub fn server_satellite(&self, server_idx: usize, write_epoch: u64, now_epoch: u64) -> SatId {
        let write_center = self.write_center_for_epoch(write_epoch, now_epoch);
        let layout = self.config.strategy.layout_at(
            &self.torus,
            write_center,
            self.config.n_servers,
            now_epoch - write_epoch,
        );
        layout[server_idx % self.config.n_servers]
    }

    fn chunk_satellite(&self, chunk_id: u32, write_epoch: u64, now_epoch: u64) -> SatId {
        self.server_satellite(chunk_id as usize % self.config.n_servers, write_epoch, now_epoch)
    }

    // ------------------------------------------------------------ SET ---

    /// Store one block's KV values (already extracted from the model) under
    /// `hashes[..=block_idx]`; no-op if the index says it's cached.
    pub fn put_block(
        &self,
        hashes: &[BlockHash],
        block_idx: usize,
        kv_values: &[f32],
        now_epoch: u64,
    ) -> Result<bool> {
        self.put_block_at(hashes, block_idx, kv_values, now_epoch, now_epoch)
    }

    /// §3.7 predictive placement: store for the LOS window of
    /// `target_epoch` (>= now) so the chunks are already in place when the
    /// hit is predicted to happen.
    pub fn put_block_at(
        &self,
        hashes: &[BlockHash],
        block_idx: usize,
        kv_values: &[f32],
        now_epoch: u64,
        target_epoch: u64,
    ) -> Result<bool> {
        if self.config.use_radix_index
            && self.index.lock().unwrap().get(&hashes[..=block_idx]).is_some()
        {
            return Ok(false);
        }
        self.put_block_at_forced(hashes, block_idx, kv_values, now_epoch, target_epoch)
    }

    /// Like [`Self::put_block_at`] but stores even when the index already
    /// knows the block — the prefetcher uses this to *re-place* a block
    /// for a different epoch's LOS window.
    pub fn put_block_at_forced(
        &self,
        hashes: &[BlockHash],
        block_idx: usize,
        kv_values: &[f32],
        now_epoch: u64,
        target_epoch: u64,
    ) -> Result<bool> {
        let block = hashes[block_idx];
        let payload = self.config.quantizer.encode(kv_values);
        let n_chunks = chunk_count(payload.len(), self.config.chunk_size) as u32;
        let header = encode_chunk_header(
            self.config.quantizer.id(),
            n_chunks,
            payload.len() as u32,
            target_epoch,
        );
        let chunks = split_chunks(&payload, self.config.chunk_size);
        // map each chunk to its satellite under the *target* epoch layout
        let write_center = if target_epoch >= now_epoch {
            // future (or present) centre is west of the current one
            let delta = (target_epoch - now_epoch) as i32;
            self.torus.offset(self.transport.closest(), 0, -delta)
        } else {
            self.write_center_for_epoch(target_epoch, now_epoch)
        };
        let layout = self.config.strategy.initial_layout(&self.torus, write_center, self.config.n_servers);
        // §3.1: "this allows for parallelism both in setting and getting".
        // The whole block is one virtual-time batch: the event engine
        // pipelines every chunk over the per-link windows, so a thousand
        // chunks cost no more ordering machinery than eight.
        let transfers: Vec<Transfer> = chunks
            .iter()
            .enumerate()
            .map(|(i, chunk)| {
                let mut data = Vec::with_capacity(CHUNK_HEADER_LEN + chunk.len());
                data.extend_from_slice(&header);
                data.extend_from_slice(chunk);
                Transfer {
                    tag: i as u64,
                    op: ChunkOp::Set {
                        dest: layout[i % self.config.n_servers],
                        key: ChunkKey::new(block, i as u32),
                        data,
                    },
                }
            })
            .collect();
        let sink = self.trace.lock().unwrap().clone();
        let tracing = sink.wants(SpanKind::Kvc);
        let base = if tracing {
            self.sched.stats.virtual_ns.load(Ordering::Relaxed)
        } else {
            0
        };
        let batch = self.sched.run_batch(transfers);
        if tracing {
            let dur = self.sched.stats.virtual_ns.load(Ordering::Relaxed) - base;
            sink.record(
                TraceEvent::span(SpanKind::Kvc, "set_block", base, dur)
                    .with_shell(0)
                    .arg_u("bytes", payload.len() as u64)
                    .arg_u("chunks", n_chunks as u64),
            );
        }
        for o in &batch.outcomes {
            if let ChunkResult::Failed(e) = &o.result {
                bail!("chunk {} set failed: {e}", o.tag);
            }
        }
        self.stats.blocks_stored.fetch_add(1, Ordering::Relaxed);
        if let Some(local) = &self.local {
            // write-through into the fast tier (values are what the
            // engine will ask for on the next hit)
            local.put(block, kv_values.to_vec());
        }
        self.stats.chunks_stored.fetch_add(n_chunks as u64, Ordering::Relaxed);
        self.stats.bytes_stored.fetch_add(payload.len() as u64, Ordering::Relaxed);
        if self.config.use_radix_index {
            self.index.lock().unwrap().insert(
                &hashes[..=block_idx],
                BlockMeta {
                    num_chunks: n_chunks,
                    kvc_len: payload.len() as u32,
                    write_epoch: target_epoch,
                    quantizer_id: self.config.quantizer.id(),
                },
            );
        }
        Ok(true)
    }

    // ------------------------------------------------------------ GET ---

    /// Longest cached prefix (in blocks) of `hashes`.
    pub fn lookup(&self, hashes: &[BlockHash], now_epoch: u64) -> Option<(usize, BlockMeta)> {
        self.stats.lookups.fetch_add(1, Ordering::Relaxed);
        let hit = if self.config.use_radix_index {
            self.index.lock().unwrap().longest_cached_prefix(hashes)
        } else {
            self.distributed_lookup(hashes, now_epoch)
        };
        if hit.is_some() {
            self.stats.prefix_hits.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// §3.8 steps 3-6: binary search the hash list for the deepest cached
    /// block, probing the constellation (no local state).
    fn distributed_lookup(&self, hashes: &[BlockHash], now_epoch: u64) -> Option<(usize, BlockMeta)> {
        let mut lo = 0usize; // count of blocks known cached
        let mut hi = hashes.len(); // first count known NOT (exclusive)
        let mut best: Option<(usize, BlockMeta)> = None;
        while lo < hi {
            let mid = (lo + hi + 1) / 2; // probe prefix of `mid` blocks
            match self.probe_block(hashes[mid - 1], now_epoch) {
                Some(meta) => {
                    best = Some((mid, meta));
                    lo = mid;
                }
                None => hi = mid - 1,
            }
        }
        best
    }

    /// Probe for a block without local state (§3.8 step 8): ask the
    /// nearest satellite which chunks it holds; "based on that the shift
    /// from left to right in the chunk-to-server mapping is found".
    ///
    /// Because migration cycles the layout pattern *horizontally* within
    /// its box, server 1 (and with it chunk 0) always sits somewhere on
    /// the centre row — so when the nearest satellite holds nothing (fewer
    /// chunks than servers), the probe walks the centre row outward, at
    /// most `box_width` cheap Query round-trips.
    fn probe_block(&self, block: BlockHash, now_epoch: u64) -> Option<BlockMeta> {
        let center = self.transport.closest();
        let half = (box_width(self.config.n_servers) as i32 - 1) / 2;
        // centre first, then alternating east/west along the centre row
        let mut offsets = vec![0i32];
        for d in 1..=half {
            offsets.push(d);
            offsets.push(-d);
        }
        let _ = now_epoch;
        for ds in offsets {
            let sat = self.torus.offset(center, 0, ds);
            let Ok(resp) = self.transport.request(sat, Request::Query { block }) else {
                continue;
            };
            let Response::QueryOk { chunk_ids } = resp else { continue };
            let Some(first) = chunk_ids.first().copied() else { continue };
            // fetch that chunk to read the self-describing header
            let data = self.transport.get_chunk(sat, ChunkKey::new(block, first)).ok()??;
            let (qid, num_chunks, kvc_len, write_epoch) = decode_chunk_header(&data).ok()?;
            return Some(BlockMeta { num_chunks, kvc_len, write_epoch, quantizer_id: qid });
        }
        None
    }

    /// Fetch the KV bytes of blocks `0..blocks` (all previously reported
    /// cached) in parallel; returns the dequantized values per block.
    /// Blocks that come back broken truncate the prefix (and are evicted
    /// per policy).
    pub fn fetch_prefix(
        &self,
        hashes: &[BlockHash],
        blocks: usize,
        now_epoch: u64,
    ) -> Result<PrefixFetch> {
        let mut kv_blocks = Vec::with_capacity(blocks);
        for b in 0..blocks {
            match self.fetch_block(hashes, b, now_epoch)? {
                Some(kv) => kv_blocks.push(kv),
                None => break, // truncated prefix
            }
        }
        let got = kv_blocks.len();
        Ok(PrefixFetch { blocks: got, kv_blocks })
    }

    /// Fetch one block's KV values; `None` if any chunk is missing.
    pub fn fetch_block(
        &self,
        hashes: &[BlockHash],
        block_idx: usize,
        now_epoch: u64,
    ) -> Result<Option<Vec<f32>>> {
        let block = hashes[block_idx];
        // fast-RAM tier first (§2 memory hierarchy)
        if let Some(local) = &self.local {
            if let Some(values) = local.get(&block) {
                return Ok(Some(values));
            }
        }
        let meta = if self.config.use_radix_index {
            match self.index.lock().unwrap().get(&hashes[..=block_idx]) {
                Some(m) => m,
                None => return Ok(None),
            }
        } else {
            match self.probe_block(block, now_epoch) {
                Some(m) => m,
                None => return Ok(None),
            }
        };
        let quantizer = Quantizer::from_id(
            meta.quantizer_id,
            match self.config.quantizer {
                Quantizer::QuantoInt8 { group } | Quantizer::HqqInt8 { group } => group,
                Quantizer::F32 => 32,
            },
        )
        .ok_or_else(|| anyhow::anyhow!("unknown quantizer id {}", meta.quantizer_id))?;
        // parallel chunk fan-out (§3.8 step 8: "all chunks can be queried
        // in parallel"): one virtual-time batch over the per-link
        // windows; the current layout is computed once, not per chunk
        let n_chunks = meta.num_chunks as usize;
        let write_center = self.write_center_for_epoch(meta.write_epoch, now_epoch);
        let layout = self.config.strategy.layout_at(
            &self.torus,
            write_center,
            self.config.n_servers,
            now_epoch - meta.write_epoch,
        );
        let transfers: Vec<Transfer> = (0..n_chunks)
            .map(|i| Transfer {
                tag: i as u64,
                op: ChunkOp::Get {
                    dest: layout[i % self.config.n_servers],
                    key: ChunkKey::new(block, i as u32),
                },
            })
            .collect();
        let sink = self.trace.lock().unwrap().clone();
        let tracing = sink.wants(SpanKind::Kvc);
        let base = if tracing {
            self.sched.stats.virtual_ns.load(Ordering::Relaxed)
        } else {
            0
        };
        let batch = self.sched.run_batch(transfers);
        let batch_dur = if tracing {
            self.sched.stats.virtual_ns.load(Ordering::Relaxed) - base
        } else {
            0
        };
        let trace_get = |outcome: &'static str| {
            if tracing {
                sink.record(
                    TraceEvent::span(SpanKind::Kvc, "get_block", base, batch_dur)
                        .with_shell(0)
                        .arg_u("chunks", n_chunks as u64)
                        .arg("outcome", ArgVal::S(outcome.to_string())),
                );
            }
        };
        let mut fetched: Vec<Option<Vec<u8>>> = vec![None; n_chunks];
        for o in batch.outcomes {
            if let ChunkResult::Got(Some(data)) = o.result {
                fetched[o.tag as usize] = Some(data);
            }
        }
        // strip headers, verify, reassemble
        let mut payload = Vec::with_capacity(meta.kvc_len as usize);
        let mut broken = false;
        for part in &fetched {
            match part {
                Some(data) if data.len() > CHUNK_HEADER_LEN => {
                    payload.extend_from_slice(&data[CHUNK_HEADER_LEN..])
                }
                _ => {
                    broken = true;
                    break;
                }
            }
        }
        if broken || payload.len() != meta.kvc_len as usize {
            self.stats.broken_blocks.fetch_add(1, Ordering::Relaxed);
            trace_get("broken");
            self.handle_broken_block(hashes, block_idx, &meta, now_epoch);
            return Ok(None);
        }
        trace_get("ok");
        self.stats.blocks_fetched.fetch_add(1, Ordering::Relaxed);
        self.stats.chunks_fetched.fetch_add(meta.num_chunks as u64, Ordering::Relaxed);
        self.stats.bytes_fetched.fetch_add(payload.len() as u64, Ordering::Relaxed);
        let values = quantizer.decode(&payload)?;
        if let Some(local) = &self.local {
            local.put(block, values.clone());
        }
        Ok(Some(values))
    }

    /// §3.9 lazy eviction: "the lookup client will issue evictions when
    /// chunks in a block are discovered to be missing."
    fn handle_broken_block(&self, hashes: &[BlockHash], block_idx: usize, meta: &BlockMeta, now_epoch: u64) {
        if let Some(local) = &self.local {
            for h in &hashes[block_idx..] {
                local.invalidate(h);
            }
        }
        if self.config.use_radix_index {
            // drop this prefix and every deeper one we know about
            let mut index = self.index.lock().unwrap();
            for end in block_idx..hashes.len() {
                index.remove(&hashes[..=end]);
            }
        }
        if self.config.eviction != EvictionPolicy::PeriodicScrub {
            // tell the surviving replicas to drop their chunks
            let block = hashes[block_idx];
            for server in 0..self.config.n_servers.min(meta.num_chunks as usize) {
                let sat = self.server_satellite(server, meta.write_epoch, now_epoch);
                let _ = self.transport.request(
                    sat,
                    Request::Evict { block, gossip_ttl: 0 },
                );
            }
        }
    }

    // ------------------------------------------------------ ROTATION ----

    /// The Migrate requests for one rotation epoch of this manager's
    /// layout box (§3.4): each satellite of the exiting east column hands
    /// its chunks to the entering west column, per plane.
    pub fn migration_requests(&self, now_epoch: u64) -> Vec<(SatId, SatId)> {
        if !self.config.strategy.migrates() {
            return vec![];
        }
        let _ = now_epoch;
        crate::mapping::migration::rotation_handoff_pairs(
            &self.torus,
            self.transport.closest(),
            self.config.n_servers,
        )
    }

    /// Advance one epoch: issue the migrations, then move the ground view.
    pub fn advance_epoch(&self, now_epoch: u64) -> Result<u32> {
        let reqs = self.migration_requests(now_epoch);
        let mut moved = 0;
        for (from, to) in reqs {
            moved += self.transport.migrate(from, to)?;
        }
        self.transport.set_epoch(now_epoch + 1);
        Ok(moved)
    }

    /// Number of chunks a block of `n_values` f32s will produce.
    pub fn chunks_for_values(&self, n_values: usize) -> usize {
        self.config.chunks_for_values(n_values)
    }

    /// Blocks currently present in the local radix index.
    pub fn indexed_blocks(&self) -> usize {
        self.index.lock().unwrap().len()
    }

    /// Tokens the indexed blocks cover (`block_tokens` tokens each) —
    /// the denominator of the `bytes_per_cached_token` capacity metric.
    pub fn cached_tokens(&self) -> u64 {
        self.indexed_blocks() as u64 * self.config.block_tokens as u64
    }

    /// Epoch-boundary housekeeping: compact the live epoch's index delta
    /// into a new frozen generation (tombstoned keys drop for real, every
    /// other entry — pinned or not — survives).  Returns whether a new
    /// generation was built; repeated boundaries without writes are
    /// no-ops.
    pub fn end_of_epoch(&self, now_epoch: u64) -> bool {
        let compacted = self.index.lock().unwrap().compact();
        if compacted {
            let sink = self.trace.lock().unwrap().clone();
            if sink.wants(SpanKind::Kvc) {
                let at = self.sched.stats.virtual_ns.load(Ordering::Relaxed);
                sink.record(
                    TraceEvent::instant(SpanKind::Kvc, "index_compact", at)
                        .with_shell(0)
                        .arg_u("epoch", now_epoch),
                );
            }
        }
        compacted
    }

    /// Frozen generations the index has built (one per compacting
    /// [`Self::end_of_epoch`]).
    pub fn index_compactions(&self) -> u64 {
        self.index.lock().unwrap().compactions()
    }
}

impl MemFootprint for KvcManager {
    /// The manager-side footprint: the §3.10 radix prefix index plus the
    /// optional local RAM tier.  The constellation's chunk stores belong
    /// to the fleet, not the manager — the harness rolls those up per
    /// satellite and adds this on top.
    fn mem_footprint(&self) -> FootprintEstimate {
        let mut est = self.index.lock().unwrap().mem_footprint();
        if let Some(local) = &self.local {
            est.add(local.mem_footprint());
        }
        est
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constellation::los::LosGrid;
    use crate::kvc::block::block_hashes;
    use crate::net::transport::{GroundView, InProcTransport};
    use crate::satellite::fleet::Fleet;
    use crate::util::rng::XorShift64;

    fn setup(config: KvcConfig) -> (Arc<Fleet>, KvcManager) {
        let torus = Torus::new(15, 15);
        let fleet = Arc::new(Fleet::new(torus, 10 << 20, config.eviction));
        let center = SatId::new(7, 7);
        let ground = GroundView::new(center, &LosGrid::new(center, 2, 2), torus.sats_per_plane);
        let transport = Arc::new(InProcTransport::new(fleet.clone(), ground, None));
        let manager = KvcManager::new(config, torus, transport);
        (fleet, manager)
    }

    fn values(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = XorShift64::new(seed);
        (0..n).map(|_| (rng.next_f64() as f32 - 0.5) * 4.0).collect()
    }

    fn default_config() -> KvcConfig {
        KvcConfig { n_servers: 9, chunk_size: 600, ..KvcConfig::default() }
    }

    #[test]
    fn put_then_fetch_roundtrip() {
        let (_fleet, m) = setup(default_config());
        let tokens: Vec<i32> = (0..96).collect();
        let hashes = block_hashes(&tokens, 32);
        let kv = values(2048, 1);
        assert!(m.put_block(&hashes, 0, &kv, 0).unwrap());
        // idempotent: second put is a no-op
        assert!(!m.put_block(&hashes, 0, &kv, 0).unwrap());
        let (blocks, meta) = m.lookup(&hashes, 0).unwrap();
        assert_eq!(blocks, 1);
        assert_eq!(meta.num_chunks as usize, m.chunks_for_values(2048));
        let fetched = m.fetch_block(&hashes, 0, 0).unwrap().unwrap();
        assert_eq!(fetched.len(), kv.len());
        // int8 quantization error bound
        let max_err = kv.iter().zip(&fetched).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
        assert!(max_err < 0.05, "max_err={max_err}");
    }

    #[test]
    fn prefix_fetch_multiple_blocks() {
        let (_fleet, m) = setup(default_config());
        let tokens: Vec<i32> = (0..128).collect();
        let hashes = block_hashes(&tokens, 32);
        for b in 0..3 {
            m.put_block(&hashes, b, &values(2048, b as u64), 0).unwrap();
        }
        let (blocks, _) = m.lookup(&hashes, 0).unwrap();
        assert_eq!(blocks, 3);
        let fetch = m.fetch_prefix(&hashes, blocks, 0).unwrap();
        assert_eq!(fetch.blocks, 3);
        assert_eq!(fetch.kv_blocks.len(), 3);
    }

    #[test]
    fn distributed_lookup_matches_radix() {
        let mut cfg = default_config();
        let (_fleet, m) = setup(cfg);
        let tokens: Vec<i32> = (0..160).collect();
        let hashes = block_hashes(&tokens, 32);
        for b in 0..3 {
            m.put_block(&hashes, b, &values(2048, b as u64), 0).unwrap();
        }
        // same manager, index disabled -> distributed binary search
        cfg.use_radix_index = false;
        let m2 = KvcManager::new(cfg, m.torus, m.transport.clone());
        let (blocks, meta) = m2.lookup(&hashes, 0).unwrap();
        assert_eq!(blocks, 3);
        assert_eq!(meta.num_chunks as usize, m.chunks_for_values(2048));
        // and it can fetch without any local state
        let fetch = m2.fetch_prefix(&hashes, blocks, 0).unwrap();
        assert_eq!(fetch.blocks, 3);
    }

    #[test]
    fn diverging_prompt_hits_common_prefix_only() {
        let (_fleet, m) = setup(default_config());
        let tokens: Vec<i32> = (0..96).collect();
        let hashes = block_hashes(&tokens, 32);
        for b in 0..3 {
            m.put_block(&hashes, b, &values(2048, b as u64), 0).unwrap();
        }
        let mut tokens2 = tokens.clone();
        tokens2[40] = 999; // diverge inside block 1
        let hashes2 = block_hashes(&tokens2, 32);
        let (blocks, _) = m.lookup(&hashes2, 0).unwrap();
        assert_eq!(blocks, 1);
    }

    #[test]
    fn migration_preserves_fetchability() {
        let (fleet, m) = setup(default_config());
        let tokens: Vec<i32> = (0..32).collect();
        let hashes = block_hashes(&tokens, 32);
        let kv = values(2048, 9);
        m.put_block(&hashes, 0, &kv, 0).unwrap();
        // rotate one epoch: migrate, then the ground view moves
        let moved = m.advance_epoch(0).unwrap();
        assert!(moved > 0, "east column should hand over chunks");
        assert_eq!(fleet.total_chunks() as u32, m.lookup(&hashes, 1).unwrap().1.num_chunks);
        let fetched = m.fetch_block(&hashes, 0, 1).unwrap().unwrap();
        assert_eq!(fetched.len(), kv.len());
        // two more epochs
        m.advance_epoch(1).unwrap();
        m.advance_epoch(2).unwrap();
        assert!(m.fetch_block(&hashes, 0, 3).unwrap().is_some());
    }

    #[test]
    fn broken_block_truncates_prefix_and_lazy_evicts() {
        let (fleet, m) = setup(KvcConfig { eviction: EvictionPolicy::Lazy, ..default_config() });
        let tokens: Vec<i32> = (0..96).collect();
        let hashes = block_hashes(&tokens, 32);
        for b in 0..3 {
            m.put_block(&hashes, b, &values(2048, b as u64), 0).unwrap();
        }
        // sabotage: evict block 1's chunks directly on the satellites
        for node in fleet.nodes() {
            node_evict(node, hashes[1]);
        }
        let fetch = m.fetch_prefix(&hashes, 3, 0).unwrap();
        assert_eq!(fetch.blocks, 1, "prefix truncates at the broken block");
        assert_eq!(m.stats.broken_blocks.load(Ordering::Relaxed), 1);
        // lazy eviction purged the index for blocks 1 and 2
        let (blocks, _) = m.lookup(&hashes, 0).unwrap();
        assert_eq!(blocks, 1);
    }

    fn node_evict(node: &Arc<crate::satellite::node::Node>, block: BlockHash) {
        use crate::net::messages::Envelope;
        let torus = Torus::new(15, 15);
        let env = Envelope::new(node.id, 0);
        node.handle(&torus, &env, &Request::Evict { block, gossip_ttl: 0 });
    }

    #[test]
    fn predictive_placement_hits_at_future_epoch() {
        let (_fleet, m) = setup(default_config());
        let tokens: Vec<i32> = (0..32).collect();
        let hashes = block_hashes(&tokens, 32);
        let kv = values(2048, 5);
        // place for epoch 3 while we are at epoch 0
        m.put_block_at(&hashes, 0, &kv, 0, 3).unwrap();
        // jump the ground view to epoch 3 (satellites did not migrate
        // because the block was pre-placed for that epoch)
        m.transport.set_epoch(3);
        let fetched = m.fetch_block(&hashes, 0, 3).unwrap().unwrap();
        assert_eq!(fetched.len(), kv.len());
        // every chunk was a direct-LOS access (entry == dest): hop count 0
        // for the fetches of this block is not directly observable here,
        // but fetch success at the future epoch is the §3.7 property.
    }

    #[test]
    fn local_tier_short_circuits_the_constellation() {
        let (_fleet, base) = setup(default_config());
        let m = KvcManager::new(base.config, base.torus, base.transport.clone())
            .with_local_tier(1 << 20);
        let tokens: Vec<i32> = (0..32).collect();
        let hashes = block_hashes(&tokens, 32);
        let kv = values(2048, 3);
        m.put_block(&hashes, 0, &kv, 0).unwrap();
        let before = m.transport().stats().requests.load(Ordering::Relaxed);
        // served from RAM: no new transport requests
        let fetched = m.fetch_block(&hashes, 0, 0).unwrap().unwrap();
        assert_eq!(fetched, kv, "local tier stores decoded values exactly");
        assert_eq!(m.transport().stats().requests.load(Ordering::Relaxed), before);
        assert_eq!(m.local_tier().unwrap().stats.hits.load(Ordering::Relaxed), 1);
        // invalidate -> falls back to the constellation (quantized copy)
        m.local_tier().unwrap().invalidate(&hashes[0]);
        let fetched2 = m.fetch_block(&hashes, 0, 0).unwrap().unwrap();
        assert!(m.transport().stats().requests.load(Ordering::Relaxed) > before);
        let max_err = kv.iter().zip(&fetched2).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
        assert!(max_err < 0.05);
        // ... and the miss refilled the tier
        assert_eq!(m.local_tier().unwrap().len(), 1);
    }

    #[test]
    fn f32_and_hqq_quantizers_roundtrip() {
        for q in [Quantizer::F32, Quantizer::HqqInt8 { group: 32 }] {
            let (_fleet, m) = setup(KvcConfig { quantizer: q, ..default_config() });
            let tokens: Vec<i32> = (0..32).collect();
            let hashes = block_hashes(&tokens, 32);
            let kv = values(1024, 11);
            m.put_block(&hashes, 0, &kv, 0).unwrap();
            let fetched = m.fetch_block(&hashes, 0, 0).unwrap().unwrap();
            let max_err = kv.iter().zip(&fetched).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
            let bound = if q == Quantizer::F32 { 1e-9 } else { 0.05 };
            assert!(max_err < bound, "{}: {max_err}", q.name());
        }
    }
}
