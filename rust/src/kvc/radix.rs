//! Local radix block index (§3.10).
//!
//! A compressed radix tree (patricia trie) over byte strings, built from
//! scratch.  The KVC manager keys it with the concatenation of a prompt's
//! chained block hashes, so a single longest-prefix walk answers "which is
//! the deepest block already cached?" without touching the constellation
//! (replacing the §3.8 distributed binary search), and the stored metadata
//! (chunk count, write epoch, write centre) lets the client *compute*
//! every chunk's current satellite (Fig. 10/11).

use crate::obs::mem::{FootprintEstimate, MemFootprint};
use std::mem::size_of;

/// Metadata stored per indexed block (§3.10: "total number of chunks and
/// the time of setting the value").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockMeta {
    /// Number of chunks the block's KVC was split into.
    pub num_chunks: u32,
    /// Total KVC byte length (for reassembly checks).
    pub kvc_len: u32,
    /// Rotation epoch at write time.
    pub write_epoch: u64,
    /// Quantizer wire id the payload was encoded with.
    pub quantizer_id: u8,
}

struct Node<V> {
    /// Compressed edge label from the parent.
    label: Vec<u8>,
    value: Option<V>,
    children: Vec<Node<V>>,
}

impl<V> Node<V> {
    fn new(label: Vec<u8>) -> Self {
        Self { label, value: None, children: Vec::new() }
    }

    fn child_starting_with(&self, b: u8) -> Option<usize> {
        self.children.iter().position(|c| c.label.first() == Some(&b))
    }
}

/// A compressed radix tree mapping byte strings to values.
pub struct RadixTree<V> {
    root: Node<V>,
    len: usize,
}

impl<V> Default for RadixTree<V> {
    fn default() -> Self {
        Self::new()
    }
}

fn common_prefix(a: &[u8], b: &[u8]) -> usize {
    a.iter().zip(b.iter()).take_while(|(x, y)| x == y).count()
}

impl<V> RadixTree<V> {
    pub fn new() -> Self {
        Self { root: Node::new(Vec::new()), len: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Insert `key -> value`; returns the previous value if any.
    pub fn insert(&mut self, key: &[u8], value: V) -> Option<V> {
        let (node, inserted) = Self::insert_at(&mut self.root, key, value);
        if inserted {
            self.len += 1;
        }
        node
    }

    fn insert_at(node: &mut Node<V>, key: &[u8], value: V) -> (Option<V>, bool) {
        if key.is_empty() {
            let prev = node.value.replace(value);
            let inserted = prev.is_none();
            return (prev, inserted);
        }
        if let Some(i) = node.child_starting_with(key[0]) {
            let child = &mut node.children[i];
            let cp = common_prefix(&child.label, key);
            if cp == child.label.len() {
                // descend
                return Self::insert_at(child, &key[cp..], value);
            }
            // split the edge
            let new_child = Node::new(child.label[..cp].to_vec());
            let mut old = std::mem::replace(child, new_child);
            old.label = old.label[cp..].to_vec();
            child.children.push(old);
            if cp == key.len() {
                child.value = Some(value);
                return (None, true);
            }
            let mut leaf = Node::new(key[cp..].to_vec());
            leaf.value = Some(value);
            child.children.push(leaf);
            (None, true)
        } else {
            let mut leaf = Node::new(key.to_vec());
            leaf.value = Some(value);
            node.children.push(leaf);
            (None, true)
        }
    }

    /// Exact lookup.
    pub fn get(&self, key: &[u8]) -> Option<&V> {
        let mut node = &self.root;
        let mut rest = key;
        loop {
            if rest.is_empty() {
                return node.value.as_ref();
            }
            let i = node.child_starting_with(rest[0])?;
            let child = &node.children[i];
            if rest.len() < child.label.len() || !rest.starts_with(&child.label) {
                return None;
            }
            rest = &rest[child.label.len()..];
            node = child;
        }
    }

    /// Remove a key; returns its value.
    pub fn remove(&mut self, key: &[u8]) -> Option<V> {
        let removed = Self::remove_at(&mut self.root, key);
        if removed.is_some() {
            self.len -= 1;
        }
        removed
    }

    fn remove_at(node: &mut Node<V>, key: &[u8]) -> Option<V> {
        if key.is_empty() {
            return node.value.take();
        }
        let i = node.child_starting_with(key[0])?;
        let child = &mut node.children[i];
        if key.len() < child.label.len() || !key.starts_with(&child.label) {
            return None;
        }
        let suffix = &key[child.label.len()..];
        let out = Self::remove_at(child, suffix)?;
        // prune / merge
        if child.value.is_none() && child.children.is_empty() {
            node.children.swap_remove(i);
        } else if child.value.is_none() && child.children.len() == 1 {
            let mut grand = child.children.pop().unwrap();
            let mut label = std::mem::take(&mut child.label);
            label.extend_from_slice(&grand.label);
            grand.label = label;
            node.children[i] = grand;
        }
        Some(out)
    }

    /// Longest prefix of `key` (at any byte boundary) that holds a value;
    /// returns (prefix_len_bytes, value).
    pub fn longest_prefix(&self, key: &[u8]) -> Option<(usize, &V)> {
        let mut node = &self.root;
        let mut consumed = 0;
        let mut best: Option<(usize, &V)> = node.value.as_ref().map(|v| (0, v));
        let mut rest = key;
        while !rest.is_empty() {
            let Some(i) = node.child_starting_with(rest[0]) else { break };
            let child = &node.children[i];
            if rest.len() < child.label.len() || !rest.starts_with(&child.label) {
                break;
            }
            consumed += child.label.len();
            rest = &rest[child.label.len()..];
            node = child;
            if let Some(v) = node.value.as_ref() {
                best = Some((consumed, v));
            }
        }
        best
    }

}

impl<V> MemFootprint for RadixTree<V> {
    /// The whole tree is bookkeeping, so everything lands in
    /// `index_bytes`: edge labels plus inline node structs, counted from
    /// live nodes (never `Vec` capacities), with one modeled allocation
    /// per label buffer and per children array.
    ///
    /// The walk keeps its own worklist instead of recursing: degenerate
    /// prefix chains (one block per edge over a very long prompt) can
    /// nest 10^5 nodes deep, far past any thread's call stack.
    fn mem_footprint(&self) -> FootprintEstimate {
        let mut est = FootprintEstimate::ZERO;
        let mut stack = vec![&self.root];
        while let Some(node) = stack.pop() {
            // label bytes live on the heap when non-empty (one
            // allocation); each child Node is inline in the parent's
            // children Vec (one allocation per non-empty Vec)
            est.index_bytes += node.label.len() as u64;
            if !node.label.is_empty() {
                est.charge_allocs(1);
            }
            if !node.children.is_empty() {
                est.index_bytes += (node.children.len() * size_of::<Node<V>>()) as u64;
                est.charge_allocs(1);
            }
            stack.extend(node.children.iter());
        }
        est
    }
}

impl<V> RadixTree<V> {
    /// Visit every (key, value) pair (keys materialized; test/debug aid).
    pub fn iter_collect(&self) -> Vec<(Vec<u8>, &V)> {
        let mut out = Vec::with_capacity(self.len);
        let mut stack = vec![(&self.root, Vec::new())];
        while let Some((node, prefix)) = stack.pop() {
            let mut key = prefix.clone();
            key.extend_from_slice(&node.label);
            if let Some(v) = node.value.as_ref() {
                out.push((key.clone(), v));
            }
            for c in &node.children {
                stack.push((c, key.clone()));
            }
        }
        out
    }
}

/// The §3.10 block index: a radix tree keyed by concatenated chained block
/// hashes (32 bytes per block).  Because the hashes are chained, depth `k`
/// in hash-key space equals "first k blocks cached".
pub struct BlockIndex {
    tree: RadixTree<BlockMeta>,
}

impl Default for BlockIndex {
    fn default() -> Self {
        Self::new()
    }
}

impl BlockIndex {
    pub fn new() -> Self {
        Self { tree: RadixTree::new() }
    }

    pub fn len(&self) -> usize {
        self.tree.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tree.is_empty()
    }

    fn key_for(hashes: &[super::block::BlockHash]) -> Vec<u8> {
        let mut key = Vec::with_capacity(32 * hashes.len());
        for h in hashes {
            key.extend_from_slice(h.as_bytes());
        }
        key
    }

    /// Record that the prefix ending at `hashes.last()` is cached.
    pub fn insert(&mut self, hashes: &[super::block::BlockHash], meta: BlockMeta) {
        assert!(!hashes.is_empty());
        self.tree.insert(&Self::key_for(hashes), meta);
    }

    /// Longest cached prefix of the prompt's block-hash list: returns
    /// (number_of_blocks, meta of the deepest cached block).
    pub fn longest_cached_prefix(
        &self,
        hashes: &[super::block::BlockHash],
    ) -> Option<(usize, BlockMeta)> {
        let (bytes, meta) = self.tree.longest_prefix(&Self::key_for(hashes))?;
        if bytes == 0 {
            return None;
        }
        debug_assert_eq!(bytes % 32, 0, "index keys are whole hashes");
        Some((bytes / 32, *meta))
    }

    /// Exact metadata for a prefix.
    pub fn get(&self, hashes: &[super::block::BlockHash]) -> Option<&BlockMeta> {
        self.tree.get(&Self::key_for(hashes))
    }

    /// Drop the entry for a prefix (lazy eviction propagation, §3.9/§3.10).
    pub fn remove(&mut self, hashes: &[super::block::BlockHash]) -> Option<BlockMeta> {
        self.tree.remove(&Self::key_for(hashes))
    }
}

impl MemFootprint for BlockIndex {
    fn mem_footprint(&self) -> FootprintEstimate {
        self.tree.mem_footprint()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvc::block::{block_hashes, BlockHash};

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut t = RadixTree::new();
        assert_eq!(t.insert(b"romane", 1), None);
        assert_eq!(t.insert(b"romanus", 2), None);
        assert_eq!(t.insert(b"romulus", 3), None);
        assert_eq!(t.insert(b"rubens", 4), None);
        assert_eq!(t.insert(b"ruber", 5), None);
        assert_eq!(t.len(), 5);
        assert_eq!(t.get(b"romane"), Some(&1));
        assert_eq!(t.get(b"romanus"), Some(&2));
        assert_eq!(t.get(b"roman"), None);
        assert_eq!(t.remove(b"romanus"), Some(2));
        assert_eq!(t.get(b"romanus"), None);
        assert_eq!(t.get(b"romane"), Some(&1));
        assert_eq!(t.len(), 4);
    }

    #[test]
    fn overwrite_returns_previous() {
        let mut t = RadixTree::new();
        assert_eq!(t.insert(b"abc", 1), None);
        assert_eq!(t.insert(b"abc", 2), Some(1));
        assert_eq!(t.len(), 1);
        assert_eq!(t.get(b"abc"), Some(&2));
    }

    #[test]
    fn prefix_of_existing_key() {
        let mut t = RadixTree::new();
        t.insert(b"abcdef", 1);
        t.insert(b"abc", 2); // splits the edge
        assert_eq!(t.get(b"abc"), Some(&2));
        assert_eq!(t.get(b"abcdef"), Some(&1));
        assert_eq!(t.get(b"abcd"), None);
    }

    #[test]
    fn longest_prefix_walk() {
        let mut t = RadixTree::new();
        t.insert(b"a", 1);
        t.insert(b"abc", 2);
        t.insert(b"abcde", 3);
        assert_eq!(t.longest_prefix(b"abcdefgh"), Some((5, &3)));
        assert_eq!(t.longest_prefix(b"abcd"), Some((3, &2)));
        assert_eq!(t.longest_prefix(b"ab"), Some((1, &1)));
        assert_eq!(t.longest_prefix(b"zz"), None);
        t.remove(b"abcde");
        assert_eq!(t.longest_prefix(b"abcdefgh"), Some((3, &2)));
    }

    #[test]
    fn merge_after_remove_keeps_tree_consistent() {
        let mut t = RadixTree::new();
        t.insert(b"team", 1);
        t.insert(b"test", 2);
        t.insert(b"toast", 3);
        t.remove(b"test");
        assert_eq!(t.get(b"team"), Some(&1));
        assert_eq!(t.get(b"toast"), Some(&3));
        assert_eq!(t.len(), 2);
        let mut keys: Vec<_> = t.iter_collect().into_iter().map(|(k, _)| k).collect();
        keys.sort();
        assert_eq!(keys, vec![b"team".to_vec(), b"toast".to_vec()]);
    }

    #[test]
    fn many_random_keys() {
        use crate::util::rng::XorShift64;
        let mut rng = XorShift64::new(99);
        let mut keys = std::collections::HashMap::new();
        let mut t = RadixTree::new();
        for i in 0..2000u32 {
            let len = 1 + rng.next_range(12);
            let key: Vec<u8> = (0..len).map(|_| (rng.next_range(4)) as u8).collect();
            t.insert(&key, i);
            keys.insert(key, i);
        }
        assert_eq!(t.len(), keys.len());
        for (k, v) in &keys {
            assert_eq!(t.get(k), Some(v));
        }
        // remove half, verify the rest
        let all: Vec<_> = keys.keys().cloned().collect();
        for k in all.iter().take(all.len() / 2) {
            assert_eq!(t.remove(k), keys.remove(k));
        }
        for (k, v) in &keys {
            assert_eq!(t.get(k), Some(v), "key {:?}", k);
        }
    }

    fn meta(n: u32) -> BlockMeta {
        BlockMeta { num_chunks: n, kvc_len: n * 6000, write_epoch: 0, quantizer_id: 1 }
    }

    #[test]
    fn block_index_longest_cached_prefix() {
        let tokens: Vec<i32> = (0..160).collect();
        let hashes = block_hashes(&tokens, 32); // 5 blocks
        let mut idx = BlockIndex::new();
        idx.insert(&hashes[..2], meta(22));
        idx.insert(&hashes[..4], meta(44));
        let (blocks, m) = idx.longest_cached_prefix(&hashes).unwrap();
        assert_eq!(blocks, 4);
        assert_eq!(m.num_chunks, 44);
        // a diverging prompt only matches the common prefix
        let mut tokens2 = tokens.clone();
        tokens2[100] = -1; // inside block 3
        let hashes2 = block_hashes(&tokens2, 32);
        let (blocks2, m2) = idx.longest_cached_prefix(&hashes2).unwrap();
        assert_eq!(blocks2, 2);
        assert_eq!(m2.num_chunks, 22);
    }

    #[test]
    fn block_index_remove_for_lazy_eviction() {
        let tokens: Vec<i32> = (0..64).collect();
        let hashes = block_hashes(&tokens, 32);
        let mut idx = BlockIndex::new();
        idx.insert(&hashes[..1], meta(1));
        idx.insert(&hashes[..2], meta(2));
        assert_eq!(idx.longest_cached_prefix(&hashes).unwrap().0, 2);
        idx.remove(&hashes[..2]);
        assert_eq!(idx.longest_cached_prefix(&hashes).unwrap().0, 1);
        assert_eq!(idx.len(), 1);
    }

    #[test]
    fn footprint_grows_on_insert_and_shrinks_on_remove() {
        let mut t = RadixTree::new();
        assert_eq!(t.mem_footprint().total(), 0, "an empty tree weighs nothing");
        let mut prev = 0u64;
        for key in [&b"romane"[..], b"romanus", b"romulus", b"rubens", b"ruber"] {
            t.insert(key, 1u32);
            let now = t.mem_footprint().total();
            assert!(now > prev, "insert of {key:?} must grow the estimate");
            prev = now;
        }
        t.remove(b"romanus");
        let after = t.mem_footprint().total();
        assert!(after < prev, "remove must shrink the estimate");
        // estimates are a pure function of contents: rebuilding the same
        // tree directly reports the identical footprint
        let mut fresh = RadixTree::new();
        for key in [&b"romane"[..], b"romulus", b"rubens", b"ruber"] {
            fresh.insert(key, 1u32);
        }
        assert_eq!(fresh.mem_footprint(), t.mem_footprint());
    }

    #[test]
    fn footprint_survives_a_degenerate_deep_chain() {
        use crate::obs::mem::ALLOC_OVERHEAD;
        // 10^5 nested one-byte edges: a chain this deep used to blow the
        // stack in the recursive footprint walk.  The chain is built
        // node-by-node (an insert-per-prefix build touches O(depth^2)
        // key bytes) and dismantled iteratively at the end (drop glue
        // recurses per nesting level too), on a deliberately small 1 MiB
        // stack so a recursive walk cannot hide behind a big main-thread
        // stack.
        const DEPTH: usize = 100_000;
        std::thread::Builder::new()
            .name("deep-chain".into())
            .stack_size(1 << 20)
            .spawn(|| {
                let mut node = Node::new(vec![7u8]);
                node.value = Some(1u32);
                for _ in 1..DEPTH {
                    let mut parent = Node::new(vec![7u8]);
                    parent.children.push(node);
                    node = parent;
                }
                let mut t = RadixTree::new();
                t.root.children.push(node);
                t.len = 1;
                let est = t.mem_footprint();
                // DEPTH label bytes + DEPTH single-child arrays (the
                // root's plus every internal node's), two modeled
                // allocations per level
                let node_sz = size_of::<Node<u32>>() as u64;
                assert_eq!(est.index_bytes, DEPTH as u64 + DEPTH as u64 * node_sz);
                assert_eq!(est.overhead_bytes, 2 * DEPTH as u64 * ALLOC_OVERHEAD as u64);
                let key = vec![7u8; DEPTH];
                assert_eq!(t.get(&key), Some(&1));
                assert_eq!(t.longest_prefix(&key), Some((DEPTH, &1)));
                let mut teardown = vec![std::mem::replace(&mut t.root, Node::new(Vec::new()))];
                while let Some(mut n) = teardown.pop() {
                    teardown.append(&mut n.children);
                }
            })
            .unwrap()
            .join()
            .unwrap();
    }

    #[test]
    fn block_index_no_match() {
        let hashes = block_hashes(&[1, 2, 3, 4], 2);
        let idx = BlockIndex::new();
        assert!(idx.longest_cached_prefix(&hashes).is_none());
    }
}
