//! Chunking (§3.1): a block's (possibly multi-MB) KVC byte string is split
//! into fixed-size chunks; chunk `i` goes to virtual server `i mod n`
//! (§3.8 step 5).  Every cache entry is identified by `(block_hash,
//! chunk_id)`, and a single missing chunk invalidates the whole block.

use super::block::BlockHash;

/// Identifier of one stored chunk.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ChunkKey {
    pub block: BlockHash,
    pub chunk_id: u32,
}

impl ChunkKey {
    pub fn new(block: BlockHash, chunk_id: u32) -> Self {
        Self { block, chunk_id }
    }

    /// Wire encoding: 32-byte block hash || 4-byte LE chunk id.
    pub fn encode(&self) -> [u8; 36] {
        let mut out = [0u8; 36];
        out[..32].copy_from_slice(self.block.as_bytes());
        out[32..].copy_from_slice(&self.chunk_id.to_le_bytes());
        out
    }

    pub fn decode(bytes: &[u8]) -> Option<Self> {
        if bytes.len() != 36 {
            return None;
        }
        let mut h = [0u8; 32];
        h.copy_from_slice(&bytes[..32]);
        let chunk_id = u32::from_le_bytes(bytes[32..36].try_into().ok()?);
        Some(Self { block: BlockHash(h), chunk_id })
    }
}

/// Number of chunks a payload of `len` bytes produces.
pub fn chunk_count(len: usize, chunk_size: usize) -> usize {
    assert!(chunk_size > 0);
    len.div_ceil(chunk_size)
}

/// Split a block's KVC bytes into `chunk_size`-byte chunks (last one may
/// be short).  Zero-copy: returns sub-slices.
pub fn split_chunks(data: &[u8], chunk_size: usize) -> Vec<&[u8]> {
    assert!(chunk_size > 0);
    if data.is_empty() {
        return vec![];
    }
    data.chunks(chunk_size).collect()
}

/// Reassemble chunks into the block's KVC bytes.  Returns `None` when a
/// chunk is missing (`None` entry) — §3.1: "a failed lookup of a single
/// chunk is enough to determine that the KVC does not exist".
pub fn join_chunks(chunks: &[Option<Vec<u8>>], expected_len: usize) -> Option<Vec<u8>> {
    let mut out = Vec::with_capacity(expected_len);
    for c in chunks {
        out.extend_from_slice(c.as_deref()?);
    }
    if out.len() == expected_len {
        Some(out)
    } else {
        None
    }
}

/// The virtual server (0-based) a chunk maps to (§3.1 baseline protocol).
pub fn server_for_chunk(chunk_id: u32, n_servers: usize) -> usize {
    assert!(n_servers > 0);
    (chunk_id as usize) % n_servers
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bh(b: u8) -> BlockHash {
        BlockHash([b; 32])
    }

    #[test]
    fn split_join_roundtrip() {
        let data: Vec<u8> = (0..100u8).collect();
        for cs in [1, 3, 7, 33, 100, 1000] {
            let chunks = split_chunks(&data, cs);
            assert_eq!(chunks.len(), chunk_count(data.len(), cs));
            let owned: Vec<Option<Vec<u8>>> =
                chunks.iter().map(|c| Some(c.to_vec())).collect();
            assert_eq!(join_chunks(&owned, data.len()).unwrap(), data, "cs={cs}");
        }
    }

    #[test]
    fn missing_chunk_fails_join() {
        let data = vec![7u8; 50];
        let chunks = split_chunks(&data, 16);
        let mut owned: Vec<Option<Vec<u8>>> =
            chunks.iter().map(|c| Some(c.to_vec())).collect();
        owned[2] = None;
        assert!(join_chunks(&owned, 50).is_none());
    }

    #[test]
    fn truncated_payload_fails_join() {
        let data = vec![7u8; 50];
        let mut owned: Vec<Option<Vec<u8>>> =
            split_chunks(&data, 16).iter().map(|c| Some(c.to_vec())).collect();
        owned.pop();
        assert!(join_chunks(&owned, 50).is_none());
    }

    #[test]
    fn empty_payload() {
        assert_eq!(chunk_count(0, 6000), 0);
        assert!(split_chunks(&[], 6000).is_empty());
        assert_eq!(join_chunks(&[], 0).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn paper_example_sizes() {
        // paper §5: ~2.9 MB block split into 6 kB chunks
        let n = chunk_count(2_900_000, 6000);
        assert_eq!(n, 484);
        // our scaled model: 128 KiB f32 block KVC, 6 kB chunks
        assert_eq!(chunk_count(131_072, 6000), 22);
    }

    #[test]
    fn chunk_key_codec_roundtrip() {
        let k = ChunkKey::new(bh(0xab), 1234);
        let enc = k.encode();
        assert_eq!(ChunkKey::decode(&enc), Some(k));
        assert_eq!(ChunkKey::decode(&enc[..35]), None);
    }

    #[test]
    fn server_mapping_is_mod_n() {
        assert_eq!(server_for_chunk(0, 10), 0);
        assert_eq!(server_for_chunk(9, 10), 9);
        assert_eq!(server_for_chunk(10, 10), 0);
        assert_eq!(server_for_chunk(25, 7), 4);
    }

    #[test]
    fn parallelism_claim_holds() {
        // §3.1: chunk->server mod n allows parallel get/set of one KVC —
        // i.e. the first n chunks land on n distinct servers.
        let n = 10;
        let servers: std::collections::HashSet<_> =
            (0..n as u32).map(|c| server_for_chunk(c, n)).collect();
        assert_eq!(servers.len(), n);
    }
}
