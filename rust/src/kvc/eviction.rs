//! Eviction (§3.9): LRU under memory pressure, with three propagation
//! policies — gossip broadcast to the chunk neighbourhood, lazy client
//! eviction on discovered-missing chunks, and periodic scrub of incomplete
//! blocks.  Migration-time eviction ("natural eviction as part of the
//! rotation synchronization") falls out of the satellite store dropping
//! migrated-away chunks.

use crate::obs::mem::FootprintEstimate;
use std::collections::HashMap;
use std::hash::Hash;
use std::mem::size_of;

/// How satellites and clients propagate an eviction (§3.9).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum EvictionPolicy {
    /// Evicting satellite gossips the block eviction to its neighbours so
    /// sibling chunks die together.
    #[default]
    Gossip,
    /// Nothing is propagated; the *client* purges its index and issues
    /// evictions when a lookup discovers missing chunks.
    Lazy,
    /// Satellites periodically scrub blocks whose chunk set is incomplete.
    PeriodicScrub,
}

impl EvictionPolicy {
    pub const ALL: [EvictionPolicy; 3] =
        [EvictionPolicy::Gossip, EvictionPolicy::Lazy, EvictionPolicy::PeriodicScrub];

    pub fn name(&self) -> &'static str {
        match self {
            EvictionPolicy::Gossip => "gossip",
            EvictionPolicy::Lazy => "lazy",
            EvictionPolicy::PeriodicScrub => "periodic-scrub",
        }
    }
}

/// An O(1) LRU tracker over arbitrary keys (intrusive doubly-linked list
/// over a slab, no external crates).  Used by the satellite chunk store
/// and the manager's local budget.
pub struct LruTracker<K: Eq + Hash + Clone> {
    map: HashMap<K, usize>,
    // slab of (key, prev, next); usize::MAX = none
    slab: Vec<(K, usize, usize)>,
    free: Vec<usize>,
    head: usize, // most recent
    tail: usize, // least recent
}

const NONE: usize = usize::MAX;

impl<K: Eq + Hash + Clone> Default for LruTracker<K> {
    fn default() -> Self {
        Self::new()
    }
}

impl<K: Eq + Hash + Clone> LruTracker<K> {
    pub fn new() -> Self {
        Self { map: HashMap::new(), slab: Vec::new(), free: Vec::new(), head: NONE, tail: NONE }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn contains(&self, key: &K) -> bool {
        self.map.contains_key(key)
    }

    /// Mark `key` as most-recently used (inserting it if new).
    pub fn touch(&mut self, key: &K) {
        if let Some(&idx) = self.map.get(key) {
            self.unlink(idx);
            self.push_front(idx);
        } else {
            let idx = if let Some(i) = self.free.pop() {
                self.slab[i] = (key.clone(), NONE, NONE);
                i
            } else {
                self.slab.push((key.clone(), NONE, NONE));
                self.slab.len() - 1
            };
            self.map.insert(key.clone(), idx);
            self.push_front(idx);
        }
    }

    /// Remove and return the least-recently-used key.
    pub fn pop_lru(&mut self) -> Option<K> {
        if self.tail == NONE {
            return None;
        }
        let idx = self.tail;
        self.unlink(idx);
        let key = self.slab[idx].0.clone();
        self.map.remove(&key);
        self.free.push(idx);
        Some(key)
    }

    /// Remove a specific key.
    pub fn remove(&mut self, key: &K) -> bool {
        if let Some(idx) = self.map.remove(key) {
            self.unlink(idx);
            self.free.push(idx);
            true
        } else {
            false
        }
    }

    /// Peek at the LRU key without removing it.
    pub fn peek_lru(&self) -> Option<&K> {
        if self.tail == NONE {
            None
        } else {
            Some(&self.slab[self.tail].0)
        }
    }

    /// Estimated footprint of the tracker's bookkeeping: per live entry
    /// one map slot (key + slab index + control byte) and one slab slot
    /// (key + two links), plus the three container allocations.  Counted
    /// from live entries — never slab/free capacities — so the estimate
    /// shrinks when entries are removed.
    pub fn footprint(&self) -> FootprintEstimate {
        let live = self.map.len() as u64;
        let map_slot = (size_of::<K>() + size_of::<usize>() + 1) as u64;
        let slab_slot = size_of::<(K, usize, usize)>() as u64;
        let mut est = FootprintEstimate {
            index_bytes: live * (map_slot + slab_slot),
            ..FootprintEstimate::ZERO
        };
        est.charge_allocs(3); // map table + slab + free list
        est
    }

    fn push_front(&mut self, idx: usize) {
        self.slab[idx].1 = NONE;
        self.slab[idx].2 = self.head;
        if self.head != NONE {
            self.slab[self.head].1 = idx;
        }
        self.head = idx;
        if self.tail == NONE {
            self.tail = idx;
        }
    }

    fn unlink(&mut self, idx: usize) {
        let (_, prev, next) = self.slab[idx];
        if prev != NONE {
            self.slab[prev].2 = next;
        } else if self.head == idx {
            self.head = next;
        }
        if next != NONE {
            self.slab[next].1 = prev;
        } else if self.tail == idx {
            self.tail = prev;
        }
        self.slab[idx].1 = NONE;
        self.slab[idx].2 = NONE;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lru_order_basic() {
        let mut lru = LruTracker::new();
        lru.touch(&"a");
        lru.touch(&"b");
        lru.touch(&"c");
        assert_eq!(lru.pop_lru(), Some("a"));
        lru.touch(&"b"); // refresh b
        assert_eq!(lru.pop_lru(), Some("c"));
        assert_eq!(lru.pop_lru(), Some("b"));
        assert_eq!(lru.pop_lru(), None);
    }

    #[test]
    fn touch_refreshes() {
        let mut lru = LruTracker::new();
        for k in 0..5 {
            lru.touch(&k);
        }
        lru.touch(&0);
        assert_eq!(lru.pop_lru(), Some(1));
        assert_eq!(lru.peek_lru(), Some(&2));
    }

    #[test]
    fn remove_specific() {
        let mut lru = LruTracker::new();
        for k in 0..4 {
            lru.touch(&k);
        }
        assert!(lru.remove(&2));
        assert!(!lru.remove(&2));
        assert_eq!(lru.len(), 3);
        assert_eq!(lru.pop_lru(), Some(0));
        assert_eq!(lru.pop_lru(), Some(1));
        assert_eq!(lru.pop_lru(), Some(3));
    }

    #[test]
    fn slab_reuse_after_churn() {
        let mut lru = LruTracker::new();
        for round in 0..10 {
            for k in 0..100 {
                lru.touch(&(round * 100 + k));
            }
            for _ in 0..100 {
                assert!(lru.pop_lru().is_some());
            }
        }
        assert!(lru.is_empty());
        // slab should not have grown unboundedly (free-list reuse)
        assert!(lru.slab.len() <= 200, "slab len {}", lru.slab.len());
    }

    #[test]
    fn single_element_edge_cases() {
        let mut lru = LruTracker::new();
        lru.touch(&42);
        lru.touch(&42);
        assert_eq!(lru.len(), 1);
        assert_eq!(lru.pop_lru(), Some(42));
        assert!(lru.is_empty());
        lru.touch(&7);
        assert!(lru.remove(&7));
        assert_eq!(lru.pop_lru(), None);
    }

    #[test]
    fn policies_enumerate() {
        let names: std::collections::HashSet<_> =
            EvictionPolicy::ALL.iter().map(|p| p.name()).collect();
        assert_eq!(names.len(), 3);
        assert_eq!(EvictionPolicy::default(), EvictionPolicy::Gossip);
    }
}
