//! Paged, forkable sessions with refcounted prefix sharing.
//!
//! The chained-hash scheme ([`crate::kvc::block::chain_hash`]) already
//! dedups identical prefixes implicitly: two sessions whose token streams
//! share a prefix produce the same block hashes, and `put_block` no-ops on
//! an index hit.  This module makes that sharing *explicit*: a
//! [`SessionManager`] keys paged per-user state by [`SessionId`] with
//! `create / extend / fork / drop`, and a shared [`BlockRefs`] table counts
//! how many live sessions reference each block.  `fork` shares the common
//! prefix **without copying chunks** — the child acquires one reference on
//! every block of the parent's chain and starts its own suffix; `drop`
//! releases exactly the dropping session's chain.  The per-satellite
//! stores and the manager's local tier consult the table before evicting:
//! a block still referenced by a live session is *deflected* (skipped,
//! counted), not deleted — eviction decrements interest, it never reaps a
//! block another session still maps (§3.9 eviction made session-aware).
//!
//! Sessions are metadata-cheap: a record holds the parent id, the shared
//! chain length, the session's own suffix hashes and the unaligned token
//! tail — no KV payload, no copied prefix.  `skymemory sessions` and
//! `benches/sessions.rs` sweep 10⁵–10⁷ logical sessions and report the
//! per-session footprint through [`crate::obs::mem`].

use crate::kvc::block::{chain_hash, BlockHash};
use crate::obs::mem::{FootprintEstimate, MemFootprint};
use std::collections::BTreeMap;
use std::mem::size_of;
use std::sync::Mutex;

/// Opaque session handle (dense, allocation order).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SessionId(pub u64);

/// Histogram buckets: blocks with refcount 1..=7, last bucket = 8+.
pub const REFCOUNT_BUCKETS: usize = 8;

#[derive(Default)]
struct RefsInner {
    counts: BTreeMap<BlockHash, u32>,
    total_refs: u64,
    deflected: u64,
}

/// The shared per-block reference table.  One count per block hash, the
/// sum of live sessions whose chain includes the block.  Stores treat
/// `refs > 0` as a pin: LRU victims and gossiped evictions against a
/// pinned block are deflected and counted, never honored.
#[derive(Default)]
pub struct BlockRefs {
    inner: Mutex<RefsInner>,
}

impl BlockRefs {
    pub fn new() -> Self {
        Self::default()
    }

    /// Take one reference on `block`.
    pub fn acquire(&self, block: &BlockHash) {
        let mut inner = self.inner.lock().unwrap();
        *inner.counts.entry(*block).or_insert(0) += 1;
        inner.total_refs += 1;
    }

    /// Release one reference; the entry disappears at zero.
    pub fn release(&self, block: &BlockHash) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(c) = inner.counts.get_mut(block) {
            *c -= 1;
            inner.total_refs -= 1;
            if *c == 0 {
                inner.counts.remove(block);
            }
        }
    }

    /// Current reference count of `block` (0 if untracked).
    pub fn refs(&self, block: &BlockHash) -> u32 {
        self.inner.lock().unwrap().counts.get(block).copied().unwrap_or(0)
    }

    /// Is the block pinned against eviction?
    pub fn is_pinned(&self, block: &BlockHash) -> bool {
        self.refs(block) > 0
    }

    /// Record an eviction deflected by a pin (called by the stores).
    pub fn note_deflection(&self) {
        self.inner.lock().unwrap().deflected += 1;
    }

    /// Evictions deflected so far.
    pub fn deflections(&self) -> u64 {
        self.inner.lock().unwrap().deflected
    }

    /// Blocks with at least one reference.
    pub fn unique_blocks(&self) -> u64 {
        self.inner.lock().unwrap().counts.len() as u64
    }

    /// Sum of all reference counts.
    pub fn total_refs(&self) -> u64 {
        self.inner.lock().unwrap().total_refs
    }

    /// Blocks referenced by two or more sessions (the shared set).
    pub fn shared_blocks(&self) -> u64 {
        self.inner.lock().unwrap().counts.values().filter(|&&c| c >= 2).count() as u64
    }

    /// `total_refs / unique_blocks` — 1.0 means no sharing at all; every
    /// fork of an `n`-block prefix adds `n` refs but zero new blocks, so
    /// higher is strictly more prefix reuse.
    pub fn dedup_ratio(&self) -> f64 {
        let inner = self.inner.lock().unwrap();
        if inner.counts.is_empty() {
            1.0
        } else {
            inner.total_refs as f64 / inner.counts.len() as f64
        }
    }

    /// Blocks per refcount: bucket `i` counts blocks with `i + 1`
    /// references, the last bucket everything at `REFCOUNT_BUCKETS`+.
    pub fn histogram(&self) -> [u64; REFCOUNT_BUCKETS] {
        let inner = self.inner.lock().unwrap();
        let mut h = [0u64; REFCOUNT_BUCKETS];
        for &c in inner.counts.values() {
            let bucket = (c as usize).min(REFCOUNT_BUCKETS) - 1;
            h[bucket] += 1;
        }
        h
    }
}

impl MemFootprint for BlockRefs {
    /// One BTreeMap slot (hash + count) per tracked block; B-tree nodes
    /// amortize to roughly one allocation per 11 entries.
    fn mem_footprint(&self) -> FootprintEstimate {
        let entries = self.inner.lock().unwrap().counts.len() as u64;
        let slot = (size_of::<BlockHash>() + size_of::<u32>()) as u64;
        let mut est = FootprintEstimate {
            index_bytes: entries * slot,
            ..FootprintEstimate::ZERO
        };
        est.charge_allocs(entries / 11 + 1);
        est
    }
}

/// Per-session metadata: the parent link, how much of the parent's chain
/// is shared, the session's own suffix of block hashes, and the unaligned
/// token tail.  No KV payload and no copied prefix — this is what makes
/// 10⁷ sessions cheap.
struct SessionRecord {
    parent: Option<SessionId>,
    /// Blocks of the parent's chain shared at fork time.
    shared_blocks: usize,
    /// Block hashes appended by this session itself.
    suffix: Vec<BlockHash>,
    /// Tokens not yet forming a full block.
    tail: Vec<i32>,
    /// Hash of the last full block of the chain ([`BlockHash::NULL`] for
    /// an empty chain) — extension never re-reads token history.
    last_hash: BlockHash,
    /// Live forked children (a dropped parent stays as a tombstone while
    /// any child still needs its chain).
    children: u32,
    live: bool,
}

#[derive(Default)]
struct SessionsInner {
    sessions: BTreeMap<SessionId, SessionRecord>,
    next_id: u64,
    live: u64,
    peak_live: u64,
    created: u64,
    forked: u64,
    dropped: u64,
}

/// Deterministic point-in-time counters for the `sessions` report object.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SessionsSnapshot {
    pub created: u64,
    pub forked: u64,
    pub dropped: u64,
    pub live: u64,
    pub peak_live: u64,
    pub unique_blocks: u64,
    pub total_refs: u64,
    pub shared_blocks: u64,
    pub dedup_ratio: f64,
    pub deflected_evictions: u64,
    pub refcount_histogram: [u64; REFCOUNT_BUCKETS],
    /// Estimated session + refs metadata bytes (rolls into the memory
    /// plane's index bytes).
    pub metadata_bytes: u64,
}

/// The session layer above the KVC managers.  Thread-safe; all state
/// behind one mutex, the [`BlockRefs`] table shared out by `Arc` so the
/// satellite stores can consult it.
pub struct SessionManager {
    block_tokens: usize,
    refs: std::sync::Arc<BlockRefs>,
    inner: Mutex<SessionsInner>,
}

impl SessionManager {
    pub fn new(block_tokens: usize) -> Self {
        assert!(block_tokens >= 1, "blocks need at least one token");
        Self {
            block_tokens,
            refs: std::sync::Arc::new(BlockRefs::new()),
            inner: Mutex::new(SessionsInner::default()),
        }
    }

    /// The shared reference table (install it on stores / fleets).
    pub fn refs(&self) -> std::sync::Arc<BlockRefs> {
        self.refs.clone()
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn live_sessions(&self) -> u64 {
        self.inner.lock().unwrap().live
    }

    /// Create a fresh session from `tokens`; returns the id and the full
    /// blocks the caller must store.
    pub fn create(&self, tokens: &[i32]) -> (SessionId, Vec<BlockHash>) {
        let mut inner = self.inner.lock().unwrap();
        let id = SessionId(inner.next_id);
        inner.next_id += 1;
        inner.created += 1;
        inner.live += 1;
        inner.peak_live = inner.peak_live.max(inner.live);
        let mut rec = SessionRecord {
            parent: None,
            shared_blocks: 0,
            suffix: Vec::new(),
            tail: Vec::new(),
            last_hash: BlockHash::NULL,
            children: 0,
            live: true,
        };
        let new = self.append(&mut rec, tokens);
        inner.sessions.insert(id, rec);
        (id, new)
    }

    /// Append `tokens` to a live session; returns the newly completed
    /// blocks (the caller stores exactly these — the shared prefix is
    /// untouched).
    pub fn extend(&self, id: SessionId, tokens: &[i32]) -> Vec<BlockHash> {
        let mut inner = self.inner.lock().unwrap();
        let rec = inner.sessions.get_mut(&id).expect("extend of unknown session");
        assert!(rec.live, "extend of a dropped session");
        self.append(rec, tokens)
    }

    /// Fork a live session: the child shares the parent's whole chain
    /// (one new reference per block, zero chunk copies) and diverges from
    /// the parent's current tail.
    pub fn fork(&self, id: SessionId) -> SessionId {
        let mut inner = self.inner.lock().unwrap();
        let chain = self.chain_locked(&inner, id);
        let parent = inner.sessions.get_mut(&id).expect("fork of unknown session");
        assert!(parent.live, "fork of a dropped session");
        parent.children += 1;
        let tail = parent.tail.clone();
        let last_hash = parent.last_hash;
        for h in &chain {
            self.refs.acquire(h);
        }
        let child = SessionId(inner.next_id);
        inner.next_id += 1;
        inner.forked += 1;
        inner.live += 1;
        inner.peak_live = inner.peak_live.max(inner.live);
        inner.sessions.insert(
            child,
            SessionRecord {
                parent: Some(id),
                shared_blocks: chain.len(),
                suffix: Vec::new(),
                tail,
                last_hash,
                children: 0,
                live: true,
            },
        );
        child
    }

    /// Drop a session: releases exactly its chain's references.  The
    /// record tombstones while forked children still need the chain and
    /// is freed (recursively up the parent links) once the last child
    /// goes.
    pub fn drop_session(&self, id: SessionId) {
        let mut inner = self.inner.lock().unwrap();
        let chain = self.chain_locked(&inner, id);
        for h in &chain {
            self.refs.release(h);
        }
        let rec = inner.sessions.get_mut(&id).expect("drop of unknown session");
        assert!(rec.live, "double drop");
        rec.live = false;
        inner.live -= 1;
        inner.dropped += 1;
        Self::reap(&mut inner.sessions, id);
    }

    /// Free tombstoned records with no remaining children, walking up the
    /// parent links.
    fn reap(sessions: &mut BTreeMap<SessionId, SessionRecord>, mut id: SessionId) {
        loop {
            let removable =
                sessions.get(&id).map(|r| !r.live && r.children == 0).unwrap_or(false);
            if !removable {
                return;
            }
            let rec = sessions.remove(&id).unwrap();
            let Some(parent) = rec.parent else { return };
            let p = sessions.get_mut(&parent).expect("parent outlives child");
            p.children -= 1;
            id = parent;
        }
    }

    /// The session's full block chain (shared prefix + own suffix).
    pub fn chain(&self, id: SessionId) -> Vec<BlockHash> {
        let inner = self.inner.lock().unwrap();
        self.chain_locked(&inner, id)
    }

    fn chain_locked(&self, inner: &SessionsInner, id: SessionId) -> Vec<BlockHash> {
        let rec = inner.sessions.get(&id).expect("chain of unknown session");
        let mut out = match rec.parent {
            Some(p) => {
                let mut prefix = self.chain_locked(inner, p);
                prefix.truncate(rec.shared_blocks);
                prefix
            }
            None => Vec::new(),
        };
        out.extend_from_slice(&rec.suffix);
        out
    }

    /// Hash-chain `tokens` onto `rec`, completing blocks of
    /// `block_tokens`; returns the completed hashes and holds the rest in
    /// the tail.  One reference is acquired per completed block.
    fn append(&self, rec: &mut SessionRecord, tokens: &[i32]) -> Vec<BlockHash> {
        let mut new = Vec::new();
        rec.tail.extend_from_slice(tokens);
        let mut consumed = 0;
        while rec.tail.len() - consumed >= self.block_tokens {
            let block = &rec.tail[consumed..consumed + self.block_tokens];
            let h = chain_hash(&rec.last_hash, block);
            self.refs.acquire(&h);
            rec.last_hash = h;
            rec.suffix.push(h);
            new.push(h);
            consumed += self.block_tokens;
        }
        rec.tail.drain(..consumed);
        new
    }

    /// Point-in-time counters for the report `sessions` object.
    pub fn snapshot(&self) -> SessionsSnapshot {
        let metadata_bytes = self.mem_footprint().total();
        let inner = self.inner.lock().unwrap();
        SessionsSnapshot {
            created: inner.created,
            forked: inner.forked,
            dropped: inner.dropped,
            live: inner.live,
            peak_live: inner.peak_live,
            unique_blocks: self.refs.unique_blocks(),
            total_refs: self.refs.total_refs(),
            shared_blocks: self.refs.shared_blocks(),
            dedup_ratio: self.refs.dedup_ratio(),
            deflected_evictions: self.refs.deflections(),
            refcount_histogram: self.refs.histogram(),
            metadata_bytes,
        }
    }
}

impl MemFootprint for SessionManager {
    /// One BTreeMap slot per record plus each record's suffix / tail
    /// buffers, and the shared refs table.  B-tree nodes amortize to one
    /// allocation per 11 entries; each non-empty Vec is one allocation.
    fn mem_footprint(&self) -> FootprintEstimate {
        let inner = self.inner.lock().unwrap();
        let slot = (size_of::<SessionId>() + size_of::<SessionRecord>()) as u64;
        let mut index_bytes = inner.sessions.len() as u64 * slot;
        let mut allocs = inner.sessions.len() as u64 / 11 + 1;
        for rec in inner.sessions.values() {
            index_bytes += (rec.suffix.len() * size_of::<BlockHash>()) as u64;
            index_bytes += (rec.tail.len() * size_of::<i32>()) as u64;
            allocs += u64::from(!rec.suffix.is_empty()) + u64::from(!rec.tail.is_empty());
        }
        let mut est = FootprintEstimate { index_bytes, ..FootprintEstimate::ZERO };
        est.charge_allocs(allocs);
        est.add(self.refs.mem_footprint());
        est
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvc::block::block_hashes;

    fn toks(n: usize, salt: i32) -> Vec<i32> {
        (0..n as i32).map(|i| i * 31 + salt).collect()
    }

    #[test]
    fn create_matches_block_hashes() {
        let m = SessionManager::new(4);
        let tokens = toks(12, 1);
        let (id, new) = m.create(&tokens);
        assert_eq!(new, block_hashes(&tokens, 4));
        assert_eq!(m.chain(id), new);
        assert_eq!(m.refs().total_refs(), 3);
        assert_eq!(m.refs().unique_blocks(), 3);
    }

    #[test]
    fn extend_chains_incrementally_across_tails() {
        let m = SessionManager::new(4);
        let all = toks(11, 2);
        // feed in ragged pieces: 3 + 5 + 3 tokens = 11 -> 2 full blocks
        let (id, a) = m.create(&all[..3]);
        assert!(a.is_empty(), "3 tokens complete no block");
        let b = m.extend(id, &all[3..8]);
        let c = m.extend(id, &all[8..]);
        let mut got = b;
        got.extend(c);
        assert_eq!(got, block_hashes(&all, 4));
        assert_eq!(m.chain(id), block_hashes(&all, 4));
    }

    #[test]
    fn fork_shares_the_prefix_without_new_blocks() {
        let m = SessionManager::new(4);
        let (parent, _) = m.create(&toks(8, 3));
        let before_blocks = m.refs().unique_blocks();
        let child = m.fork(parent);
        assert_eq!(m.refs().unique_blocks(), before_blocks, "fork copies nothing");
        assert_eq!(m.refs().total_refs(), 4, "2 blocks x 2 sessions");
        assert_eq!(m.refs().shared_blocks(), 2);
        assert!((m.refs().dedup_ratio() - 2.0).abs() < 1e-12);
        assert_eq!(m.chain(child), m.chain(parent));
        // divergent extends chain off the same last hash differently
        let p = m.extend(parent, &toks(4, 10));
        let c = m.extend(child, &toks(4, 20));
        assert_ne!(p, c);
        assert_eq!(m.chain(parent)[..2], m.chain(child)[..2]);
    }

    #[test]
    fn forked_replay_is_byte_identical_to_fresh() {
        let m = SessionManager::new(4);
        let prefix = toks(12, 4);
        let turn = toks(8, 5);
        let (parent, _) = m.create(&prefix);
        let child = m.fork(parent);
        let forked_new = m.extend(child, &turn);
        // a fresh session replaying prefix+turn yields the same chain...
        let mut full = prefix.clone();
        full.extend_from_slice(&turn);
        let (fresh, fresh_new) = m.create(&full);
        assert_eq!(m.chain(fresh), m.chain(child));
        // ...but must store strictly more new blocks than the fork path
        assert!(forked_new.len() < fresh_new.len());
        assert_eq!(forked_new[..], fresh_new[fresh_new.len() - forked_new.len()..]);
    }

    #[test]
    fn drop_releases_exactly_the_suffix_refs() {
        let m = SessionManager::new(4);
        let (parent, _) = m.create(&toks(8, 6)); // 2 blocks
        let child = m.fork(parent);
        m.extend(child, &toks(4, 7)); // child adds 1 block
        assert_eq!(m.refs().total_refs(), 5);
        m.drop_session(child);
        // the child's 3 refs (2 shared + 1 own) are gone; parent's remain
        assert_eq!(m.refs().total_refs(), 2);
        assert_eq!(m.refs().unique_blocks(), 2);
        m.drop_session(parent);
        assert_eq!(m.refs().total_refs(), 0);
        assert_eq!(m.refs().unique_blocks(), 0);
    }

    #[test]
    fn dropped_parent_tombstones_until_children_drop() {
        let m = SessionManager::new(4);
        let (parent, _) = m.create(&toks(8, 8));
        let child = m.fork(parent);
        m.drop_session(parent);
        assert_eq!(m.live_sessions(), 1);
        // the child's chain (through the tombstoned parent) stays whole
        assert_eq!(m.chain(child).len(), 2);
        assert_eq!(m.refs().total_refs(), 2, "the child still pins the prefix");
        m.drop_session(child);
        assert_eq!(m.live_sessions(), 0);
        assert_eq!(m.refs().total_refs(), 0);
        assert_eq!(m.inner.lock().unwrap().sessions.len(), 0, "tombstones reaped");
    }

    #[test]
    fn grandchildren_keep_the_whole_ancestry_alive() {
        let m = SessionManager::new(4);
        let (a, _) = m.create(&toks(4, 9));
        let b = m.fork(a);
        m.extend(b, &toks(4, 10));
        let c = m.fork(b);
        m.drop_session(a);
        m.drop_session(b);
        assert_eq!(m.chain(c).len(), 2, "c sees a's block and b's block");
        assert_eq!(m.refs().total_refs(), 2);
        m.drop_session(c);
        assert_eq!(m.refs().total_refs(), 0);
        assert_eq!(m.inner.lock().unwrap().sessions.len(), 0);
    }

    #[test]
    fn histogram_and_snapshot_counters() {
        let m = SessionManager::new(4);
        let (a, _) = m.create(&toks(8, 11)); // 2 blocks at refcount 1
        m.fork(a); // -> refcount 2
        m.fork(a); // -> refcount 3
        let h = m.refs().histogram();
        assert_eq!(h[2], 2, "both blocks sit in the refcount-3 bucket");
        assert_eq!(h.iter().sum::<u64>(), m.refs().unique_blocks());
        let snap = m.snapshot();
        assert_eq!(snap.created, 1);
        assert_eq!(snap.forked, 2);
        assert_eq!(snap.live, 3);
        assert_eq!(snap.peak_live, 3);
        assert!((snap.dedup_ratio - 3.0).abs() < 1e-12);
        assert!(snap.metadata_bytes > 0);
    }

    #[test]
    fn sessions_are_metadata_cheap() {
        let m = SessionManager::new(4);
        let (root, _) = m.create(&toks(16, 12));
        for _ in 0..1000 {
            m.fork(root);
        }
        let per_session = m.mem_footprint().total() / 1001;
        assert!(
            per_session < 256,
            "a forked session must cost well under 256 B, got {per_session}"
        );
    }

    #[test]
    fn deflections_count() {
        let r = BlockRefs::new();
        assert_eq!(r.deflections(), 0);
        r.note_deflection();
        r.note_deflection();
        assert_eq!(r.deflections(), 2);
    }
}
