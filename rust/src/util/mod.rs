//! Shared utilities built from scratch for the offline environment:
//! deterministic RNG, JSON codec, and a micro-benchmark harness (the
//! crates a networked build would use — rand, serde_json, criterion — are
//! not available offline; see DESIGN.md).

pub mod bench;
pub mod json;
pub mod rng;
