/// Deterministic xorshift64* RNG (no external rand dependency).
#[derive(Debug, Clone)]
pub struct XorShift64 { state: u64 }
impl XorShift64 {
    pub fn new(seed: u64) -> Self { Self { state: seed.max(1) } }
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12; x ^= x << 25; x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }
    pub fn next_f64(&mut self) -> f64 { (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64 }
    pub fn next_range(&mut self, n: usize) -> usize { (self.next_u64() % n.max(1) as u64) as usize }
}
