//! Criterion-style micro-benchmark harness (criterion is unavailable in
//! the offline build): warmup, timed iterations, mean / p50 / p95 / p99,
//! and a stable one-line report format the bench binaries print.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} iters={:<6} mean={:>12?} p50={:>12?} p95={:>12?} p99={:>12?} min={:>12?} max={:>12?}",
            self.name, self.iters, self.mean, self.p50, self.p95, self.p99, self.min, self.max
        )
    }

    /// Throughput line for a known per-iteration workload size.
    pub fn throughput(&self, bytes_per_iter: usize) -> String {
        let bps = bytes_per_iter as f64 / self.mean.as_secs_f64();
        format!("{:<44} {:>10.1} MiB/s", self.name, bps / (1024.0 * 1024.0))
    }
}

/// A tiny harness: `Bencher::new("name").run(|| work())`.
pub struct Bencher {
    name: String,
    warmup: Duration,
    measure: Duration,
    max_iters: usize,
}

impl Bencher {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            max_iters: 1_000_000,
        }
    }

    pub fn warmup(mut self, d: Duration) -> Self {
        self.warmup = d;
        self
    }

    pub fn measure(mut self, d: Duration) -> Self {
        self.measure = d;
        self
    }

    pub fn max_iters(mut self, n: usize) -> Self {
        self.max_iters = n;
        self
    }

    /// Run the closure repeatedly and collect statistics.
    pub fn run<F: FnMut()>(self, mut f: F) -> BenchResult {
        // warmup
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            f();
        }
        // measure
        let mut samples = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure && samples.len() < self.max_iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        Self::summarize(self.name, samples)
    }

    fn summarize(name: String, mut samples: Vec<Duration>) -> BenchResult {
        assert!(!samples.is_empty(), "no samples collected");
        samples.sort_unstable();
        let iters = samples.len();
        let total: Duration = samples.iter().sum();
        let pct = |p: f64| samples[((iters as f64 * p) as usize).min(iters - 1)];
        BenchResult {
            name,
            iters,
            mean: total / iters as u32,
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            min: samples[0],
            max: samples[iters - 1],
        }
    }
}

/// Record externally-collected samples (e.g. end-to-end request latencies).
pub fn summarize(name: impl Into<String>, samples: Vec<Duration>) -> BenchResult {
    Bencher::summarize(name.into(), samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_stats() {
        let r = Bencher::new("noop")
            .warmup(Duration::from_millis(5))
            .measure(Duration::from_millis(20))
            .run(|| {
                std::hint::black_box(1 + 1);
            });
        assert!(r.iters > 100);
        assert!(r.min <= r.p50 && r.p50 <= r.p95 && r.p95 <= r.p99 && r.p99 <= r.max);
        assert!(r.report().contains("noop"));
    }

    #[test]
    fn summarize_external_samples() {
        let samples = vec![
            Duration::from_millis(1),
            Duration::from_millis(2),
            Duration::from_millis(3),
            Duration::from_millis(10),
        ];
        let r = summarize("ext", samples);
        assert_eq!(r.iters, 4);
        assert_eq!(r.min, Duration::from_millis(1));
        assert_eq!(r.max, Duration::from_millis(10));
        assert_eq!(r.p50, Duration::from_millis(3));
    }

    #[test]
    fn throughput_format() {
        let r = summarize("x", vec![Duration::from_secs(1)]);
        let line = r.throughput(1024 * 1024);
        assert!(line.contains("1.0 MiB/s"), "{line}");
    }
}
