//! Criterion-style micro-benchmark harness (criterion is unavailable in
//! the offline build): warmup, timed iterations, mean / p50 / p95 / p99,
//! and a stable one-line report format the bench binaries print.
//!
//! Percentiles use the nearest-rank definition: the p-th percentile of N
//! sorted samples is the sample at rank `ceil(p * N)` (1-based), i.e.
//! index `ceil(p * N) - 1`.  For ultra-cheap operations the harness can
//! batch several iterations per `Instant::now()` pair ([`Bencher::batch`])
//! so the clock overhead does not dominate the samples.
//!
//! Besides the human-readable report, each bench binary serialises its
//! results into a `BENCH_<name>.json` artifact via [`BenchArtifact`]:
//! a byte-stable (sorted-key, compact) JSON object with two namespaces,
//! `deterministic` (iteration/byte/transfer counters that must be
//! bit-identical run-over-run) and `timing` (wall-clock stats that are
//! only comparable within a tolerance).  `skymemory bench --diff`
//! compares two artifacts with exactly those rules.

use crate::util::json::{n, obj, s, Json};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Total closure invocations measured (batched iterations all count).
    pub iters: usize,
    /// Timing samples collected (== `iters` unless batching was used).
    pub samples: usize,
    /// Bytes processed per iteration (0 when not byte-oriented).
    pub bytes_per_iter: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} iters={:<6} mean={:>12?} p50={:>12?} p95={:>12?} p99={:>12?} min={:>12?} max={:>12?}",
            self.name, self.iters, self.mean, self.p50, self.p95, self.p99, self.min, self.max
        )
    }

    /// Throughput line derived from the recorded per-iteration byte count.
    pub fn throughput(&self) -> String {
        let bps = self.bytes_per_iter as f64 / self.mean.as_secs_f64().max(1e-12);
        format!("{:<44} {:>10.1} MiB/s", self.name, bps / (1024.0 * 1024.0))
    }

    /// Deterministic counters for the artifact: iteration count and, when
    /// the bench is byte-oriented, total bytes processed.
    pub fn deterministic_json(&self) -> Json {
        let mut pairs = vec![("iters", n(self.iters as f64))];
        if self.bytes_per_iter > 0 {
            pairs.push(("bytes", n((self.bytes_per_iter * self.iters) as f64)));
        }
        obj(pairs)
    }

    /// Timing stats (nanoseconds) for the artifact's `timing` namespace.
    pub fn timing_json(&self) -> Json {
        obj(vec![
            ("max_ns", n(self.max.as_nanos() as f64)),
            ("mean_ns", n(self.mean.as_nanos() as f64)),
            ("min_ns", n(self.min.as_nanos() as f64)),
            ("p50_ns", n(self.p50.as_nanos() as f64)),
            ("p95_ns", n(self.p95.as_nanos() as f64)),
            ("p99_ns", n(self.p99.as_nanos() as f64)),
        ])
    }
}

/// A tiny harness: `Bencher::new("name").run(|| work())`.
pub struct Bencher {
    name: String,
    warmup: Duration,
    measure: Duration,
    max_iters: usize,
    fixed_iters: Option<usize>,
    batch: usize,
    bytes_per_iter: usize,
}

impl Bencher {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            max_iters: 1_000_000,
            fixed_iters: None,
            batch: 1,
            bytes_per_iter: 0,
        }
    }

    pub fn warmup(mut self, d: Duration) -> Self {
        self.warmup = d;
        self
    }

    pub fn measure(mut self, d: Duration) -> Self {
        self.measure = d;
        self
    }

    pub fn max_iters(mut self, n: usize) -> Self {
        self.max_iters = n;
        self
    }

    /// Run exactly `n` measured iterations (plus `max(1, n/8)` warmup
    /// iterations) instead of a wall-clock budget.  This makes the
    /// iteration count — and every counter derived from it — identical on
    /// every machine, which is what the `BENCH_*.json` deterministic
    /// namespace requires.
    pub fn fixed_iters(mut self, n: usize) -> Self {
        self.fixed_iters = Some(n.max(1));
        self
    }

    /// Time `k` closure calls per sample (one `Instant::now()` pair per
    /// batch) and record the per-iteration average.  Use for operations
    /// so cheap that the clock read would otherwise dominate.
    pub fn batch(mut self, k: usize) -> Self {
        self.batch = k.max(1);
        self
    }

    /// Record the per-iteration workload size for throughput reporting
    /// and the artifact's `bytes` counter.
    pub fn bytes_per_iter(mut self, bytes: usize) -> Self {
        self.bytes_per_iter = bytes;
        self
    }

    /// Run the closure repeatedly and collect statistics.
    pub fn run<F: FnMut()>(self, mut f: F) -> BenchResult {
        let mut samples = Vec::new();
        let mut total = Duration::ZERO;
        let mut iters = 0usize;
        if let Some(target) = self.fixed_iters {
            for _ in 0..(target / 8).max(1) {
                f();
            }
            while iters < target {
                let take = self.batch.min(target - iters);
                let t0 = Instant::now();
                for _ in 0..take {
                    f();
                }
                let elapsed = t0.elapsed();
                total += elapsed;
                samples.push(elapsed / take as u32);
                iters += take;
            }
        } else {
            let start = Instant::now();
            while start.elapsed() < self.warmup {
                f();
            }
            let start = Instant::now();
            while start.elapsed() < self.measure && iters < self.max_iters {
                let take = self.batch.min(self.max_iters - iters);
                let t0 = Instant::now();
                for _ in 0..take {
                    f();
                }
                let elapsed = t0.elapsed();
                total += elapsed;
                samples.push(elapsed / take as u32);
                iters += take;
            }
        }
        summarize_samples(self.name, samples, iters, total, self.bytes_per_iter)
    }
}

fn summarize_samples(
    name: String,
    mut samples: Vec<Duration>,
    iters: usize,
    total: Duration,
    bytes_per_iter: usize,
) -> BenchResult {
    assert!(!samples.is_empty(), "no samples collected");
    samples.sort_unstable();
    let count = samples.len();
    // Nearest-rank percentile: 1-based rank ceil(p * N), so index
    // ceil(p * N) - 1 (clamped for p == 1.0 rounding).
    let pct = |p: f64| {
        let rank = (count as f64 * p).ceil() as usize;
        samples[rank.clamp(1, count) - 1]
    };
    BenchResult {
        name,
        iters,
        samples: count,
        bytes_per_iter,
        mean: total / iters.max(1) as u32,
        p50: pct(0.50),
        p95: pct(0.95),
        p99: pct(0.99),
        min: samples[0],
        max: samples[count - 1],
    }
}

/// Record externally-collected samples (e.g. end-to-end request latencies).
pub fn summarize(name: impl Into<String>, samples: Vec<Duration>) -> BenchResult {
    let total: Duration = samples.iter().sum();
    let iters = samples.len();
    summarize_samples(name.into(), samples, iters, total, 0)
}

/// True when the binary was invoked with `--smoke` (CI-sized workloads).
pub fn smoke_mode() -> bool {
    std::env::args().any(|a| a == "--smoke")
}

/// Normalise a bench name into a stable artifact key: lowercase
/// alphanumerics with single underscores (`"SHA-256 64 KiB"` →
/// `"sha_256_64_kib"`).
pub fn slug(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    for c in name.chars() {
        if c.is_ascii_alphanumeric() {
            out.push(c.to_ascii_lowercase());
        } else if !out.ends_with('_') && !out.is_empty() {
            out.push('_');
        }
    }
    while out.ends_with('_') {
        out.pop();
    }
    out
}

/// Accumulates one bench binary's results into the `BENCH_<name>.json`
/// artifact: `{"deterministic":{...},"mode":"smoke|full","name":...,
/// "timing":{...}}` rendered as a single compact line with sorted keys,
/// so equal content is byte-identical and `sim::diff` can parse it as a
/// metrics line.
pub struct BenchArtifact {
    name: String,
    mode: String,
    deterministic: BTreeMap<String, Json>,
    timing: BTreeMap<String, Json>,
}

impl BenchArtifact {
    pub fn new(name: impl Into<String>, smoke: bool) -> Self {
        Self {
            name: name.into(),
            mode: if smoke { "smoke" } else { "full" }.to_string(),
            deterministic: BTreeMap::new(),
            timing: BTreeMap::new(),
        }
    }

    /// Record a [`BenchResult`] under its slugified name in both
    /// namespaces.
    pub fn push(&mut self, r: &BenchResult) {
        let key = slug(&r.name);
        self.deterministic.insert(key.clone(), r.deterministic_json());
        self.timing.insert(key, r.timing_json());
    }

    /// Add an extra deterministic counter (dotted keys group in the diff:
    /// `"sched.transfers"` flattens to `deterministic.sched.transfers`).
    pub fn counter(&mut self, key: &str, v: u64) {
        self.deterministic.insert(key.to_string(), n(v as f64));
    }

    /// Add a string annotation to the deterministic namespace.  Strings
    /// are skipped by the metric flattener, so labels never participate
    /// in the numeric diff.
    pub fn label(&mut self, key: &str, v: &str) {
        self.deterministic.insert(key.to_string(), s(v));
    }

    /// Add an extra timing value in nanoseconds (tolerance-compared).
    pub fn timing_ns(&mut self, key: &str, ns: u64) {
        self.timing.insert(key.to_string(), n(ns as f64));
    }

    /// Byte-stable rendering: compact single-line JSON with sorted keys.
    pub fn to_json_string(&self) -> String {
        obj(vec![
            ("deterministic", Json::Obj(self.deterministic.clone())),
            ("mode", s(&self.mode)),
            ("name", s(&self.name)),
            ("timing", Json::Obj(self.timing.clone())),
        ])
        .to_string()
    }

    /// Write `BENCH_<name>.json` into `$SKYMEMORY_BENCH_DIR` (or the
    /// current directory — the repo root under `cargo bench`).
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let dir = std::env::var("SKYMEMORY_BENCH_DIR").unwrap_or_else(|_| ".".to_string());
        let path = PathBuf::from(dir).join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, format!("{}\n", self.to_json_string()))?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(v: u64) -> Duration {
        Duration::from_millis(v)
    }

    #[test]
    fn collects_stats() {
        let r = Bencher::new("noop")
            .warmup(Duration::from_millis(5))
            .measure(Duration::from_millis(20))
            .run(|| {
                std::hint::black_box(1 + 1);
            });
        assert!(r.iters > 100);
        assert!(r.min <= r.p50 && r.p50 <= r.p95 && r.p95 <= r.p99 && r.p99 <= r.max);
        assert!(r.report().contains("noop"));
    }

    #[test]
    fn fixed_iters_is_exact_and_batched() {
        let r = Bencher::new("fixed").fixed_iters(100).batch(8).run(|| {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.iters, 100);
        // 12 full batches of 8 plus one remainder batch of 4.
        assert_eq!(r.samples, 13);
        assert!(r.min <= r.p50 && r.p50 <= r.p95 && r.p95 <= r.p99 && r.p99 <= r.max);
    }

    #[test]
    fn summarize_external_samples() {
        let samples = vec![ms(1), ms(2), ms(3), ms(10)];
        let r = summarize("ext", samples);
        assert_eq!(r.iters, 4);
        assert_eq!(r.min, ms(1));
        assert_eq!(r.max, ms(10));
        // Nearest-rank: p50 of 4 samples is rank ceil(0.5*4)=2 → 2ms
        // (the old truncating index was one rank high).
        assert_eq!(r.p50, ms(2));
        assert_eq!(r.p95, ms(10));
        assert_eq!(r.p99, ms(10));
    }

    #[test]
    fn nearest_rank_on_1_to_100() {
        let samples: Vec<Duration> = (1..=100).map(ms).collect();
        let r = summarize("ranks", samples);
        assert_eq!(r.p50, ms(50));
        assert_eq!(r.p95, ms(95));
        assert_eq!(r.p99, ms(99));
    }

    #[test]
    fn throughput_format() {
        let mut r = summarize("x", vec![Duration::from_secs(1)]);
        r.bytes_per_iter = 1024 * 1024;
        let line = r.throughput();
        assert!(line.contains("1.0 MiB/s"), "{line}");
    }

    #[test]
    fn slug_normalises() {
        assert_eq!(slug("SHA-256 64 KiB"), "sha_256_64_kib");
        assert_eq!(slug("put_block (13 chunks)"), "put_block_13_chunks");
        assert_eq!(slug("  odd--name  "), "odd_name");
    }

    #[test]
    fn artifact_json_is_byte_stable() {
        let build = |flip: bool| {
            let mut a = BenchArtifact::new("demo", true);
            let mut r = summarize("op one", vec![ms(1), ms(2)]);
            r.bytes_per_iter = 64;
            if flip {
                a.counter("z.count", 3);
                a.push(&r);
            } else {
                a.push(&r);
                a.counter("z.count", 3);
            }
            a.label("host", "ci");
            a.timing_ns("wall_ns", 1234);
            a.to_json_string()
        };
        let one = build(false);
        let two = build(true);
        assert_eq!(one, two);
        assert!(one.starts_with(r#"{"deterministic":"#), "{one}");
        assert!(one.contains(r#""op_one":{"bytes":128,"iters":2}"#), "{one}");
        assert!(one.contains(r#""mode":"smoke","name":"demo""#), "{one}");
        let parsed = Json::parse(&one).unwrap();
        assert_eq!(parsed.get("name").unwrap().as_str(), Some("demo"));
    }
}
