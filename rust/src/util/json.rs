//! Minimal JSON parser + writer (offline environment: no serde), used for
//! artifacts/model_config.json and the HTTP API.  Supports the full JSON
//! value grammar with the usual escape sequences; numbers parse as f64.

use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at offset {}", p.pos);
        }
        Ok(v)
    }

    // --- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|n| n as u64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Render compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for building responses.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn n(v: f64) -> Json {
    Json::Num(v)
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at offset {}", b as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => bail!("unexpected '{}' at offset {}", c as char, self.pos),
            None => bail!("unexpected end of input"),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at offset {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => bail!("expected ',' or '}}' at offset {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => bail!("expected ',' or ']' at offset {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                bail!("short unicode escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => bail!("bad escape at offset {}", self.pos),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.bytes[start..])?;
                    let c = text.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""hi\nthere""#).unwrap(), Json::Str("hi\nthere".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {"e": null}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(j.get("d").unwrap().get("e"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let cases = [
            r#"{"a":1,"b":[true,false,null],"c":"x\"y"}"#,
            r#"[1.5,-2,3e10]"#,
            r#""unicode é""#,
        ];
        for c in cases {
            let j = Json::parse(c).unwrap();
            let j2 = Json::parse(&j.to_string()).unwrap();
            assert_eq!(j, j2, "{c}");
        }
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", r#"{"a"}"#, "tru", "1 2", r#""unterminated"#] {
            assert!(Json::parse(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn parses_model_config_like_structure() {
        let text = r#"{
          "model": {"vocab": 256, "d_model": 128, "block_tokens": 32},
          "weights": [{"name": "wte", "shape": [256, 128], "offset_bytes": 0}]
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("model").unwrap().get("vocab").unwrap().as_usize(), Some(256));
        let w = &j.get("weights").unwrap().as_arr().unwrap()[0];
        assert_eq!(w.get("name").unwrap().as_str(), Some("wte"));
        assert_eq!(w.get("shape").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn utf8_passthrough() {
        let j = Json::parse(r#""héllo ☂""#).unwrap();
        assert_eq!(j.as_str(), Some("héllo ☂"));
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }
}
