//! PJRT runtime: loads the AOT artifacts the Python build path emitted
//! (HLO text + weights.bin + model_config.json) and executes prefill /
//! decode steps on the request path.  Python never runs here.
//!
//! * [`model_config`] — parses artifacts/model_config.json (the contract
//!   with python/compile/aot.py).
//! * [`pjrt`] — the PJRT CPU client wrapper: compile HLO text once, upload
//!   weights once as device buffers, execute steps with per-call buffers.
//! * [`kv`] — host-side KV-cache layout helpers ([L,H,S,D] flattening,
//!   block read/write) shared by the engine and the KVC manager.
//! * [`tokenizer`] / [`sampler`] — byte-level tokenizer and token sampling.

pub mod kv;
pub mod model_config;
pub mod pjrt;
pub mod sampler;
pub mod tokenizer;

pub use model_config::{Artifacts, ModelDims};
pub use pjrt::PjRtModel;
