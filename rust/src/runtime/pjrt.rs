//! PJRT execution of the AOT artifacts.
//!
//! HLO *text* (not serialized protos — see python/compile/aot.py) is
//! parsed and compiled once per process; the weights are uploaded once as
//! device buffers and reused by every call (the single biggest runtime
//! optimization: ~3.3 MB of weights never cross the host/device boundary
//! again).  Per step, only the tokens, the KV cache views and the position
//! scalar are transferred.
//!
//! Positional argument contract (aot.py): `[weights..., tokens, k_cache,
//! v_cache, pos]`; output is the tuple `(logits, k_new, v_new)`.

use super::model_config::Artifacts;
use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// One forward step's outputs.
#[derive(Debug, Clone)]
pub struct StepOutput {
    /// `[block, vocab]` flattened.
    pub logits: Vec<f32>,
    /// `[L, H, block, D]` flattened — the new block's keys.
    pub k_new: Vec<f32>,
    /// `[L, H, block, D]` flattened — the new block's values.
    pub v_new: Vec<f32>,
}

/// The compiled model: prefill (one token block) + decode (one token).
///
/// NOT `Send`/`Sync` — the coordinator runs it on a dedicated executor
/// thread (see `coordinator::executor`), which also matches how a real
/// deployment pins one execution stream per accelerator.
pub struct PjRtModel {
    pub artifacts: Artifacts,
    client: PjRtClient,
    prefill: PjRtLoadedExecutable,
    decode: PjRtLoadedExecutable,
    weight_buffers: Vec<PjRtBuffer>,
}

impl PjRtModel {
    /// Load artifacts, compile both executables, upload the weights.
    pub fn load(artifacts: Artifacts) -> Result<Self> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        let prefill = compile(&client, &artifacts.prefill_hlo)?;
        let decode = compile(&client, &artifacts.decode_hlo)?;
        let mut weight_buffers = Vec::with_capacity(artifacts.weights.len());
        for (shape, values) in artifacts.read_weights()? {
            weight_buffers.push(
                client
                    .buffer_from_host_buffer(&values, &shape, None)
                    .context("uploading weight buffer")?,
            );
        }
        Ok(Self { artifacts, client, prefill, decode, weight_buffers })
    }

    /// Load from the default artifacts directory.
    pub fn load_default() -> Result<Self> {
        Self::load(Artifacts::load(super::model_config::default_artifacts_dir())?)
    }

    /// Run one block of `block_tokens` tokens through the model at cache
    /// position `pos` (the cache holds `pos` valid tokens).
    pub fn prefill(&self, tokens: &[i32], k: &[f32], v: &[f32], pos: usize) -> Result<StepOutput> {
        let b = self.artifacts.dims.block_tokens;
        if tokens.len() != b {
            bail!("prefill expects exactly {b} tokens, got {}", tokens.len());
        }
        self.step(&self.prefill, tokens, k, v, pos)
    }

    /// Run a single token at cache position `pos`.
    pub fn decode(&self, token: i32, k: &[f32], v: &[f32], pos: usize) -> Result<StepOutput> {
        self.step(&self.decode, &[token], k, v, pos)
    }

    fn step(
        &self,
        exe: &PjRtLoadedExecutable,
        tokens: &[i32],
        k: &[f32],
        v: &[f32],
        pos: usize,
    ) -> Result<StepOutput> {
        let d = &self.artifacts.dims;
        if k.len() != d.cache_elems() || v.len() != d.cache_elems() {
            bail!("cache size mismatch");
        }
        if pos + tokens.len() > d.max_seq {
            bail!("pos {pos} + block {} exceeds max_seq {}", tokens.len(), d.max_seq);
        }
        let cache_dims = [d.n_layers, d.n_heads, d.max_seq, d.head_dim];
        let tok_buf = self
            .client
            .buffer_from_host_buffer(tokens, &[tokens.len()], None)?;
        let k_buf = self.client.buffer_from_host_buffer(k, &cache_dims, None)?;
        let v_buf = self.client.buffer_from_host_buffer(v, &cache_dims, None)?;
        let pos_buf = self
            .client
            .buffer_from_host_buffer(&[pos as i32], &[], None)?;
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(self.weight_buffers.len() + 4);
        args.extend(self.weight_buffers.iter());
        args.push(&tok_buf);
        args.push(&k_buf);
        args.push(&v_buf);
        args.push(&pos_buf);
        let result = exe.execute_b(&args)?;
        let tuple = result[0][0].to_literal_sync()?;
        let (logits, k_new, v_new) = tuple.to_tuple3()?;
        Ok(StepOutput {
            logits: logits.to_vec::<f32>()?,
            k_new: k_new.to_vec::<f32>()?,
            v_new: v_new.to_vec::<f32>()?,
        })
    }

    /// Logits for the *last* token of a step output.
    pub fn last_logits<'a>(&self, out: &'a StepOutput) -> &'a [f32] {
        let v = self.artifacts.dims.vocab;
        &out.logits[out.logits.len() - v..]
    }
}

fn compile(client: &PjRtClient, path: &std::path::Path) -> Result<PjRtLoadedExecutable> {
    let proto = HloModuleProto::from_text_file(path)
        .with_context(|| format!("parsing HLO text {path:?}"))?;
    let comp = XlaComputation::from_proto(&proto);
    client.compile(&comp).with_context(|| format!("compiling {path:?}"))
}

/// Keep Literal import used (Literal is part of the public xla API surface
/// we exercise in tests).
#[allow(unused)]
fn _literal_probe() -> Literal {
    Literal::scalar(0f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::kv::KvCache;
    use crate::runtime::model_config::default_artifacts_dir;
    use crate::runtime::sampler::argmax;
    use crate::runtime::tokenizer::ByteTokenizer;

    fn model() -> Option<PjRtModel> {
        if !default_artifacts_dir().join("model_config.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(PjRtModel::load_default().expect("model loads"))
    }

    #[test]
    fn prefill_shapes_and_determinism() {
        let Some(m) = model() else { return };
        let d = m.artifacts.dims;
        let cache = KvCache::new(d);
        let tokens: Vec<i32> = (0..d.block_tokens as i32).collect();
        let out1 = m.prefill(&tokens, &cache.k, &cache.v, 0).unwrap();
        let out2 = m.prefill(&tokens, &cache.k, &cache.v, 0).unwrap();
        assert_eq!(out1.logits.len(), d.block_tokens * d.vocab);
        assert_eq!(out1.k_new.len(), d.block_kv_elems());
        assert_eq!(out1.v_new.len(), d.block_kv_elems());
        assert_eq!(out1.logits, out2.logits, "deterministic");
        assert!(out1.logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn decode_continues_prefill() {
        let Some(m) = model() else { return };
        let d = m.artifacts.dims;
        let mut cache = KvCache::new(d);
        let tok = ByteTokenizer;
        let text = "the cache moves with the satellite ";
        let tokens = tok.encode(text);
        let block = &tokens[..d.block_tokens.min(tokens.len())];
        let out = m.prefill(block, &cache.k, &cache.v, 0).unwrap();
        cache.write_new(0, &out.k_new, &out.v_new, d.block_tokens);
        // decode one token; logits must be finite and shaped [1, vocab]
        let next = argmax(m.last_logits(&out));
        let out2 = m.decode(next, &cache.k, &cache.v, d.block_tokens).unwrap();
        assert_eq!(out2.logits.len(), d.vocab);
        assert!(out2.logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn prefill_block_then_decode_matches_all_decode() {
        // Cross-check the two executables against each other: feeding a
        // block via prefill then decoding token t must equal feeding all
        // tokens one-by-one via decode (same final logits).
        let Some(m) = model() else { return };
        let d = m.artifacts.dims;
        let tok = ByteTokenizer;
        let text = "a cache in the sky serves keys and values to the ground";
        let tokens: Vec<i32> = tok.encode(text)[..d.block_tokens].to_vec();

        // path A: prefill the whole block
        let cache_a = KvCache::new(d);
        let out_a = m.prefill(&tokens, &cache_a.k, &cache_a.v, 0).unwrap();
        let last_a = m.last_logits(&out_a).to_vec();

        // path B: decode token by token
        let mut cache_b = KvCache::new(d);
        let mut last_b = Vec::new();
        for (i, t) in tokens.iter().enumerate() {
            let out = m.decode(*t, &cache_b.k, &cache_b.v, i).unwrap();
            cache_b.write_new(i, &out.k_new, &out.v_new, 1);
            last_b = out.logits;
        }
        let max_err = last_a
            .iter()
            .zip(&last_b)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_err < 1e-3, "prefill vs decode divergence: {max_err}");
    }

    #[test]
    fn rejects_bad_shapes() {
        let Some(m) = model() else { return };
        let d = m.artifacts.dims;
        let cache = KvCache::new(d);
        assert!(m.prefill(&[1, 2, 3], &cache.k, &cache.v, 0).is_err());
        assert!(m.decode(1, &cache.k[..10], &cache.v, 0).is_err());
        assert!(m
            .decode(1, &cache.k, &cache.v, d.max_seq)
            .is_err());
    }

    #[test]
    fn trained_model_prefers_text_like_bytes() {
        // the build-time training should make letters/space far more
        // likely than control bytes after a text prompt
        let Some(m) = model() else { return };
        let d = m.artifacts.dims;
        let cache = KvCache::new(d);
        let tok = ByteTokenizer;
        let tokens = tok.encode("the satellite passes overhead every");
        let out = m.prefill(&tokens[..d.block_tokens], &cache.k, &cache.v, 0).unwrap();
        let logits = m.last_logits(&out);
        let best = argmax(logits);
        assert!(
            (32..127).contains(&best),
            "argmax byte {best} should be printable ASCII"
        );
    }
}
