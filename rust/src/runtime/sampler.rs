//! Token sampling over the model's logits: greedy, temperature, top-k —
//! deterministic via the crate's own RNG (no rand crate offline).

use crate::util::rng::XorShift64;

/// Sampling configuration.
#[derive(Debug, Clone, Copy)]
pub struct SamplerConfig {
    /// 0.0 => greedy argmax.
    pub temperature: f32,
    /// 0 => no top-k restriction.
    pub top_k: usize,
    pub seed: u64,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        Self { temperature: 0.0, top_k: 0, seed: 0x5eed }
    }
}

/// A stateful sampler (one per sequence).
pub struct Sampler {
    cfg: SamplerConfig,
    rng: XorShift64,
}

impl Sampler {
    pub fn new(cfg: SamplerConfig) -> Self {
        Self { cfg, rng: XorShift64::new(cfg.seed) }
    }

    /// Pick the next token from `logits`.
    pub fn sample(&mut self, logits: &[f32]) -> i32 {
        assert!(!logits.is_empty());
        if self.cfg.temperature <= 0.0 {
            return argmax(logits);
        }
        // softmax over (optionally top-k) logits at the given temperature
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        if self.cfg.top_k > 0 && self.cfg.top_k < logits.len() {
            idx.sort_unstable_by(|a, b| logits[*b].total_cmp(&logits[*a]));
            idx.truncate(self.cfg.top_k);
        }
        let max = idx.iter().map(|i| logits[*i]).fold(f32::NEG_INFINITY, f32::max);
        let temp = self.cfg.temperature;
        let weights: Vec<f64> =
            idx.iter().map(|i| (((logits[*i] - max) / temp) as f64).exp()).collect();
        let total: f64 = weights.iter().sum();
        let mut u = self.rng.next_f64() * total;
        for (w, i) in weights.iter().zip(&idx) {
            if u < *w {
                return *i as i32;
            }
            u -= w;
        }
        *idx.last().unwrap() as i32
    }
}

/// Argmax with deterministic tie-breaking (lowest index).
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, v) in logits.iter().enumerate() {
        if *v > logits[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let mut s = Sampler::new(SamplerConfig::default());
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        assert_eq!(s.sample(&logits), 1);
        assert_eq!(argmax(&[3.0, 3.0, 1.0]), 0, "tie -> lowest index");
    }

    #[test]
    fn temperature_sampling_is_deterministic_per_seed() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin()).collect();
        let cfg = SamplerConfig { temperature: 1.0, top_k: 0, seed: 42 };
        let a: Vec<i32> = {
            let mut s = Sampler::new(cfg);
            (0..20).map(|_| s.sample(&logits)).collect()
        };
        let b: Vec<i32> = {
            let mut s = Sampler::new(cfg);
            (0..20).map(|_| s.sample(&logits)).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn top_k_restricts_support() {
        let mut logits = vec![0.0f32; 100];
        logits[7] = 5.0;
        logits[13] = 4.0;
        let mut s = Sampler::new(SamplerConfig { temperature: 2.0, top_k: 2, seed: 1 });
        for _ in 0..200 {
            let t = s.sample(&logits);
            assert!(t == 7 || t == 13, "sampled {t} outside top-2");
        }
    }

    #[test]
    fn high_temperature_spreads_mass() {
        let logits = vec![1.0f32, 0.9, 0.8, 0.7];
        let mut s = Sampler::new(SamplerConfig { temperature: 10.0, top_k: 0, seed: 3 });
        let mut seen = std::collections::HashSet::new();
        for _ in 0..300 {
            seen.insert(s.sample(&logits));
        }
        assert!(seen.len() >= 3, "high temperature should visit most tokens");
    }

    #[test]
    fn sharp_distribution_concentrates() {
        let mut logits = vec![0.0f32; 8];
        logits[5] = 100.0;
        let mut s = Sampler::new(SamplerConfig { temperature: 0.5, top_k: 0, seed: 9 });
        for _ in 0..50 {
            assert_eq!(s.sample(&logits), 5);
        }
    }
}
