//! Byte-level tokenizer: token id == byte value (vocab 256).  The paper's
//! testbed uses TinyLlama's SentencePiece tokenizer; a byte tokenizer
//! preserves the property the protocol cares about (deterministic
//! text -> token mapping shared by hashing and the model) with zero
//! dependencies, and matches the byte-LM trained at build time.

/// Byte-level tokenizer.
#[derive(Debug, Clone, Copy, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub const VOCAB: usize = 256;

    /// Stable identifier mixed into the KVC model fingerprint.
    pub fn id(&self) -> &'static str {
        "byte-v1"
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.as_bytes().iter().map(|b| *b as i32).collect()
    }

    /// Decode tokens back to text (lossy on invalid UTF-8 boundaries).
    pub fn decode(&self, tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens.iter().map(|t| (*t & 0xFF) as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_roundtrip() {
        let t = ByteTokenizer;
        let text = "The satellite passes overhead.";
        assert_eq!(t.decode(&t.encode(text)), text);
    }

    #[test]
    fn utf8_roundtrip() {
        let t = ByteTokenizer;
        let text = "héllo ☂ satellites";
        let tokens = t.encode(text);
        assert_eq!(tokens.len(), text.len()); // bytes, not chars
        assert_eq!(t.decode(&tokens), text);
    }

    #[test]
    fn tokens_in_vocab() {
        let t = ByteTokenizer;
        for tok in t.encode("\u{0}\u{7f}émoji 🛰") {
            assert!((0..256).contains(&tok));
        }
    }

    #[test]
    fn empty() {
        let t = ByteTokenizer;
        assert!(t.encode("").is_empty());
        assert_eq!(t.decode(&[]), "");
    }
}
