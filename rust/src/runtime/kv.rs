//! Host-side KV cache: the rust coordinator owns the cache bytes (it has
//! to — they are what SkyMemory chunks and ships to the constellation),
//! and PJRT receives them as per-call input buffers.
//!
//! Layout: one f32 tensor `[L, H, S, D]` per K and V, flattened row-major.
//! A token block `b` occupies positions `[b*B, (b+1)*B)` of the `S` axis.

use super::model_config::ModelDims;

/// The engine's per-sequence KV cache.
#[derive(Clone)]
pub struct KvCache {
    pub dims: ModelDims,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// Tokens currently materialized in the cache.
    pub len: usize,
}

impl KvCache {
    pub fn new(dims: ModelDims) -> Self {
        let n = dims.cache_elems();
        Self { dims, k: vec![0.0; n], v: vec![0.0; n], len: 0 }
    }

    pub fn reset(&mut self) {
        self.k.fill(0.0);
        self.v.fill(0.0);
        self.len = 0;
    }

    /// Write a new block tensor `[L, H, B, D]` (as returned by the model)
    /// into the cache at token position `pos`.
    pub fn write_new(&mut self, pos: usize, k_new: &[f32], v_new: &[f32], block_len: usize) {
        let d = &self.dims;
        assert!(pos + block_len <= d.max_seq, "cache overflow");
        assert_eq!(k_new.len(), d.n_layers * d.n_heads * block_len * d.head_dim);
        write_block(&mut self.k, k_new, d, pos, block_len);
        write_block(&mut self.v, v_new, d, pos, block_len);
        self.len = self.len.max(pos + block_len);
    }

    /// Write a fetched KVC payload (concat of K-block then V-block values,
    /// each `[L, H, B, D]`) at block index `block_idx`.
    pub fn write_block_payload(&mut self, block_idx: usize, payload: &[f32]) {
        let d = &self.dims;
        let half = d.block_kv_elems();
        assert_eq!(payload.len(), 2 * half, "payload must be one block's K+V");
        let pos = block_idx * d.block_tokens;
        self.write_new(pos, &payload[..half], &payload[half..], d.block_tokens);
    }

    /// Extract one block's K+V as the KVC payload (inverse of
    /// `write_block_payload`).
    pub fn read_block_payload(&self, block_idx: usize) -> Vec<f32> {
        let d = &self.dims;
        let pos = block_idx * d.block_tokens;
        let mut out = Vec::with_capacity(d.block_payload_elems());
        read_block(&self.k, &mut out, d, pos, d.block_tokens);
        read_block(&self.v, &mut out, d, pos, d.block_tokens);
        out
    }
}

/// Assemble a KVC payload directly from the model's per-block outputs
/// (avoids a cache round-trip on the set path).
pub fn payload_from_new(k_new: &[f32], v_new: &[f32]) -> Vec<f32> {
    let mut out = Vec::with_capacity(k_new.len() + v_new.len());
    out.extend_from_slice(k_new);
    out.extend_from_slice(v_new);
    out
}

fn write_block(cache: &mut [f32], block: &[f32], d: &ModelDims, pos: usize, block_len: usize) {
    let row = d.head_dim;
    for l in 0..d.n_layers {
        for h in 0..d.n_heads {
            let src_base = ((l * d.n_heads + h) * block_len) * row;
            let dst_base = ((l * d.n_heads + h) * d.max_seq + pos) * row;
            let n = block_len * row;
            cache[dst_base..dst_base + n].copy_from_slice(&block[src_base..src_base + n]);
        }
    }
}

fn read_block(cache: &[f32], out: &mut Vec<f32>, d: &ModelDims, pos: usize, block_len: usize) {
    let row = d.head_dim;
    for l in 0..d.n_layers {
        for h in 0..d.n_heads {
            let src_base = ((l * d.n_heads + h) * d.max_seq + pos) * row;
            out.extend_from_slice(&cache[src_base..src_base + block_len * row]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims {
            vocab: 256,
            d_model: 128,
            n_layers: 2,
            n_heads: 3,
            head_dim: 4,
            d_ff: 512,
            max_seq: 16,
            block_tokens: 4,
            kv_block_bytes: 2 * 2 * 3 * 4 * 4 * 4,
        }
    }

    fn ramp(n: usize, base: f32) -> Vec<f32> {
        (0..n).map(|i| base + i as f32).collect()
    }

    #[test]
    fn write_then_read_block_roundtrip() {
        let d = dims();
        let mut cache = KvCache::new(d);
        let half = d.block_kv_elems();
        let payload = ramp(2 * half, 100.0);
        cache.write_block_payload(2, &payload);
        assert_eq!(cache.read_block_payload(2), payload);
        assert_eq!(cache.len, 12);
        // other blocks untouched (zero)
        assert!(cache.read_block_payload(0).iter().all(|v| *v == 0.0));
    }

    #[test]
    fn write_new_places_rows_correctly() {
        let d = dims();
        let mut cache = KvCache::new(d);
        let k_new = ramp(d.block_kv_elems(), 0.0);
        let v_new = ramp(d.block_kv_elems(), 1000.0);
        cache.write_new(4, &k_new, &v_new, d.block_tokens);
        // spot-check: layer 1, head 2, token 1 within block, dim 3
        let (l, h, t, dd) = (1usize, 2usize, 1usize, 3usize);
        let src = ((l * d.n_heads + h) * d.block_tokens + t) * d.head_dim + dd;
        let dst = ((l * d.n_heads + h) * d.max_seq + 4 + t) * d.head_dim + dd;
        assert_eq!(cache.k[dst], k_new[src]);
        assert_eq!(cache.v[dst], v_new[src]);
    }

    #[test]
    fn payload_concat_matches_cache_readback() {
        let d = dims();
        let mut cache = KvCache::new(d);
        let k_new = ramp(d.block_kv_elems(), 7.0);
        let v_new = ramp(d.block_kv_elems(), -7.0);
        cache.write_new(0, &k_new, &v_new, d.block_tokens);
        assert_eq!(payload_from_new(&k_new, &v_new), cache.read_block_payload(0));
    }

    #[test]
    fn partial_block_write() {
        let d = dims();
        let mut cache = KvCache::new(d);
        let n = d.n_layers * d.n_heads * 2 * d.head_dim; // block_len = 2
        cache.write_new(8, &ramp(n, 5.0), &ramp(n, 6.0), 2);
        assert_eq!(cache.len, 10);
    }

    #[test]
    #[should_panic(expected = "cache overflow")]
    fn overflow_panics() {
        let d = dims();
        let mut cache = KvCache::new(d);
        let n = d.block_kv_elems();
        cache.write_new(14, &ramp(n, 0.0), &ramp(n, 0.0), d.block_tokens);
    }

    #[test]
    fn reset_clears() {
        let d = dims();
        let mut cache = KvCache::new(d);
        cache.write_block_payload(0, &ramp(2 * d.block_kv_elems(), 1.0));
        cache.reset();
        assert_eq!(cache.len, 0);
        assert!(cache.k.iter().all(|v| *v == 0.0));
    }
}
