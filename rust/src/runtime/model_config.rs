//! Parse artifacts/model_config.json — the build-time contract with
//! python/compile/aot.py (model dimensions, weights manifest, artifact
//! file names, positional argument order).

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Model dimensions (mirror of python/compile/config.py::ModelConfig).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub block_tokens: usize,
    /// f32 bytes of one block's (K, V): 2*L*H*block*D*4.
    pub kv_block_bytes: usize,
}

impl ModelDims {
    /// f32 element count of one KV cache tensor [L, H, S, D].
    pub fn cache_elems(&self) -> usize {
        self.n_layers * self.n_heads * self.max_seq * self.head_dim
    }

    /// f32 element count of one block's K (or V) tensor [L, H, B, D].
    pub fn block_kv_elems(&self) -> usize {
        self.n_layers * self.n_heads * self.block_tokens * self.head_dim
    }

    /// f32 values of one block's combined (K, V) payload.
    pub fn block_payload_elems(&self) -> usize {
        2 * self.block_kv_elems()
    }

    /// How many full blocks fit the cache.
    pub fn max_blocks(&self) -> usize {
        self.max_seq / self.block_tokens
    }
}

/// One tensor of weights.bin.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WeightEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset_bytes: usize,
    pub size_bytes: usize,
}

/// The loaded artifacts directory.
#[derive(Debug, Clone)]
pub struct Artifacts {
    pub dims: ModelDims,
    pub weights: Vec<WeightEntry>,
    pub dir: PathBuf,
    pub prefill_hlo: PathBuf,
    pub decode_hlo: PathBuf,
    pub weights_bin: PathBuf,
}

impl Artifacts {
    /// Load and validate `<dir>/model_config.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let cfg_path = dir.join("model_config.json");
        let text = std::fs::read_to_string(&cfg_path)
            .with_context(|| format!("reading {cfg_path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing model_config.json")?;
        let m = j.get("model").ok_or_else(|| anyhow!("missing 'model'"))?;
        let get = |k: &str| -> Result<usize> {
            m.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("missing model.{k}"))
        };
        let dims = ModelDims {
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            head_dim: get("head_dim")?,
            d_ff: get("d_ff")?,
            max_seq: get("max_seq")?,
            block_tokens: get("block_tokens")?,
            kv_block_bytes: get("kv_block_bytes")?,
        };
        if dims.kv_block_bytes != dims.block_payload_elems() * 4 {
            bail!("kv_block_bytes inconsistent with dims");
        }
        if dims.max_seq % dims.block_tokens != 0 {
            bail!("max_seq must be a multiple of block_tokens");
        }
        let mut weights = Vec::new();
        let mut expected_offset = 0usize;
        for w in j
            .get("weights")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing 'weights'"))?
        {
            let name = w
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("weight missing name"))?
                .to_string();
            let shape: Vec<usize> = w
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{name}: missing shape"))?
                .iter()
                .map(|v| v.as_usize().ok_or_else(|| anyhow!("{name}: bad shape")))
                .collect::<Result<_>>()?;
            let offset_bytes = w
                .get("offset_bytes")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("{name}: missing offset"))?;
            let size_bytes = w
                .get("size_bytes")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("{name}: missing size"))?;
            if offset_bytes != expected_offset {
                bail!("{name}: non-contiguous manifest");
            }
            if size_bytes != 4 * shape.iter().product::<usize>() {
                bail!("{name}: size/shape mismatch");
            }
            expected_offset += size_bytes;
            weights.push(WeightEntry { name, shape, offset_bytes, size_bytes });
        }
        let arts = j.get("artifacts").ok_or_else(|| anyhow!("missing 'artifacts'"))?;
        let prefill = arts
            .get("prefill")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("missing artifacts.prefill"))?;
        let decode = arts
            .get("decode")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("missing artifacts.decode"))?;
        Ok(Self {
            dims,
            prefill_hlo: dir.join(prefill),
            decode_hlo: dir.join(decode),
            weights_bin: dir.join("weights.bin"),
            weights,
            dir,
        })
    }

    /// Total bytes weights.bin must have.
    pub fn weights_len_bytes(&self) -> usize {
        self.weights.iter().map(|w| w.size_bytes).sum()
    }

    /// Read weights.bin into per-tensor f32 vectors (manifest order).
    pub fn read_weights(&self) -> Result<Vec<(Vec<usize>, Vec<f32>)>> {
        let raw = std::fs::read(&self.weights_bin)
            .with_context(|| format!("reading {:?}", self.weights_bin))?;
        if raw.len() != self.weights_len_bytes() {
            bail!(
                "weights.bin is {} bytes, manifest says {}",
                raw.len(),
                self.weights_len_bytes()
            );
        }
        let mut out = Vec::with_capacity(self.weights.len());
        for w in &self.weights {
            let bytes = &raw[w.offset_bytes..w.offset_bytes + w.size_bytes];
            let vals: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            out.push((w.shape.clone(), vals));
        }
        Ok(out)
    }

    /// SHA-256 of weights.bin, used as the model fingerprint for the KVC
    /// chain root (§3.3: a changed parameter invalidates the cache).
    pub fn weights_digest(&self) -> Result<[u8; 32]> {
        let raw = std::fs::read(&self.weights_bin)?;
        Ok(crate::kvc::hash::sha256(&raw))
    }
}

/// Default artifacts dir: `$SKYMEMORY_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("SKYMEMORY_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        default_artifacts_dir().join("model_config.json").exists()
    }

    #[test]
    fn loads_real_artifacts_when_present() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let a = Artifacts::load(default_artifacts_dir()).unwrap();
        assert_eq!(a.dims.vocab, 256);
        assert_eq!(a.dims.max_seq % a.dims.block_tokens, 0);
        assert!(a.weights.len() > 10);
        assert_eq!(a.weights[0].name, "wte");
        let w = a.read_weights().unwrap();
        assert_eq!(w.len(), a.weights.len());
        assert_eq!(w[0].1.len(), a.dims.vocab * a.dims.d_model);
        // digest is stable across calls
        assert_eq!(a.weights_digest().unwrap(), a.weights_digest().unwrap());
    }

    #[test]
    fn rejects_inconsistent_manifest() {
        let dir = std::env::temp_dir().join(format!("skymem_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let bad = r#"{
          "model": {"vocab": 256, "d_model": 128, "n_layers": 4, "n_heads": 4,
                    "head_dim": 32, "d_ff": 512, "max_seq": 256,
                    "block_tokens": 32, "kv_block_bytes": 1},
          "weights": [], "artifacts": {"prefill": "p", "decode": "d"}
        }"#;
        std::fs::write(dir.join("model_config.json"), bad).unwrap();
        assert!(Artifacts::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dims_arithmetic() {
        let dims = ModelDims {
            vocab: 256,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            head_dim: 32,
            d_ff: 512,
            max_seq: 256,
            block_tokens: 32,
            kv_block_bytes: 2 * 4 * 4 * 32 * 32 * 4,
        };
        assert_eq!(dims.cache_elems(), 4 * 4 * 256 * 32);
        assert_eq!(dims.block_kv_elems(), 4 * 4 * 32 * 32);
        assert_eq!(dims.block_payload_elems() * 4, dims.kv_block_bytes);
        assert_eq!(dims.max_blocks(), 8);
    }
}
