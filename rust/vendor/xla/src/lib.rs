//! Stub of the `xla` (PJRT) API surface used by `runtime::pjrt`, for
//! builds where the real XLA runtime is unavailable (the offline CI
//! environment).  Every entry point type-checks against the real API
//! shape but reports [`Error::Unavailable`] at runtime; since creating
//! the [`PjRtClient`] is the first step of every PJRT path, no stubbed
//! buffer or executable is ever actually constructed.
//!
//! The serving stack degrades gracefully: `Artifacts::load` (and hence
//! `Stack::build`) is attempted before any PJRT call, and the PJRT tests
//! and benches all skip when the AOT artifacts are absent.

use std::fmt;
use std::path::Path;

/// Stub error: the backend is not present in this build.
#[derive(Debug, Clone)]
pub enum Error {
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => {
                write!(f, "{what}: XLA/PJRT backend unavailable (offline stub build)")
            }
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// Element types transferable to device buffers.
pub trait ElementType: Copy + 'static {}
impl ElementType for f32 {}
impl ElementType for f64 {}
impl ElementType for i32 {}
impl ElementType for i64 {}
impl ElementType for u8 {}
impl ElementType for u32 {}

/// Parsed HLO module (stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<Self> {
        let _ = path.as_ref();
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation (stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _private: () }
    }
}

/// Host-side literal value (stub; only the scalar constructor is real).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn scalar<T: ElementType>(_value: T) -> Literal {
        Literal { _private: () }
    }

    pub fn to_tuple3(self) -> Result<(Literal, Literal, Literal)> {
        unavailable("Literal::to_tuple3")
    }

    pub fn to_vec<T: ElementType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// PJRT client handle (stub): construction always fails, which is the
/// single choke point keeping the rest of the stub unreachable.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn buffer_from_host_buffer<T: ElementType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        let msg = err.to_string();
        assert!(msg.contains("unavailable"), "{msg}");
    }

    #[test]
    fn hlo_parse_reports_unavailable() {
        assert!(HloModuleProto::from_text_file("/tmp/nonexistent.hlo").is_err());
    }
}
