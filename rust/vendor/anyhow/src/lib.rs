//! A minimal, API-compatible subset of the `anyhow` crate, vendored for
//! the offline build environment (no crates.io access).  Covers exactly
//! what this repository uses: [`Error`], [`Result`], the [`anyhow!`],
//! [`bail!`] and [`ensure!`] macros, and the [`Context`] extension trait
//! with `context` / `with_context` on both `Result` and `Option`.
//!
//! Representation: an error is a chain of human-readable messages, newest
//! context first.  `Display` prints the newest message (matching anyhow);
//! `Debug` prints the full chain with a `Caused by:` section.

use std::fmt;

/// A dynamic error: a message plus the chain of underlying causes.
pub struct Error {
    /// `chain[0]` is the outermost (most recently attached) message.
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Wrap with an additional layer of context.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn to_message(&self) -> &str {
        &self.chain[0]
    }

    /// The full cause chain, outermost first.
    pub fn chain_messages(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain, colon-separated (like anyhow)
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// The blanket conversion every `?` site relies on.  Mirrors anyhow: legal
// only because `Error` itself does NOT implement `std::error::Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(|| ...)`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or a displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file/anywhere")
            .context("reading config")?;
        Ok(s)
    }

    #[test]
    fn blanket_from_and_context() {
        let e = io_fail().unwrap_err();
        assert_eq!(e.to_message(), "reading config");
        assert!(e.chain_messages().len() >= 2);
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x == 0 {
                bail!("zero is not allowed");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(0).unwrap_err().to_string(), "zero is not allowed");
        assert_eq!(f(-2).unwrap_err().to_string(), "negative input -2");
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.to_string(), "plain 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.with_context(|| format!("missing {}", "field")).unwrap_err();
        assert_eq!(e.to_string(), "missing field");
    }

    #[test]
    fn result_with_anyhow_error_recontexts() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
    }
}
