//! Differential test oracle for the epoch-frozen two-layer index
//! (`kvc::frozen`).
//!
//! The offline build has no proptest crate, so this is a from-scratch
//! property harness (same idiom as `proptest_invariants.rs`):
//! deterministic XorShift-driven random interleavings of insert /
//! prefix-lookup / evict (tombstone) / compact, checked op-for-op
//! against the plain structures the two-layer index replaces — the
//! radix [`BlockIndex`] and a `BTreeMap` — plus the layer-specific
//! invariants the plain structures cannot express:
//!
//! * every lookup answer is byte-identical across all three structures,
//!   before and after any number of compactions;
//! * merged iteration order ([`FrozenBlockIndex::entries`] /
//!   [`FrozenMap::entries`]) is byte-identical to the sorted oracle;
//! * blocks pinned through [`BlockRefs`] survive every compaction;
//! * a compaction never grows the modeled footprint, and evicting then
//!   compacting strictly shrinks it.
//!
//! Failure seeds are printed in every assertion for reproduction.

use skymemory::kvc::block::{block_hashes, BlockHash};
use skymemory::kvc::frozen::{FrozenBlockIndex, FrozenMap};
use skymemory::kvc::radix::{BlockIndex, BlockMeta};
use skymemory::kvc::session::BlockRefs;
use skymemory::obs::mem::MemFootprint;
use skymemory::util::rng::XorShift64;
use std::collections::BTreeMap;

const CASES: u64 = 120;
const OPS: usize = 160;

fn rand_meta(rng: &mut XorShift64) -> BlockMeta {
    BlockMeta {
        num_chunks: 1 + rng.next_range(8) as u32,
        kvc_len: 256 + rng.next_range(1 << 16) as u32,
        write_epoch: rng.next_range(64) as u64,
        quantizer_id: rng.next_range(3) as u8,
    }
}

/// Pool of block-hash chains with heavy prefix sharing: every chain
/// forks off a shared base at a random block boundary, so radix paths,
/// front-coded arena buckets and tombstone shadowing all get exercised
/// on overlapping keys.
fn chain_pool(rng: &mut XorShift64) -> Vec<Vec<BlockHash>> {
    let block = 32usize;
    let base_blocks = 4 + rng.next_range(8);
    let base: Vec<i32> = (0..(base_blocks * block) as i32).collect();
    let mut pool = vec![block_hashes(&base, block)];
    for fork in 0..5i32 {
        let keep = rng.next_range(base_blocks);
        let extra = 1 + rng.next_range(6);
        let mut tokens: Vec<i32> = base[..keep * block].to_vec();
        for t in 0..(extra * block) as i32 {
            tokens.push(10_000 + fork * 1_000 + t);
        }
        pool.push(block_hashes(&tokens, block));
    }
    pool
}

/// Pick a random prefix (chain slice of depth >= 1) from the pool.
fn rand_prefix<'a>(rng: &mut XorShift64, pool: &'a [Vec<BlockHash>]) -> &'a [BlockHash] {
    let chain = &pool[rng.next_range(pool.len())];
    &chain[..1 + rng.next_range(chain.len())]
}

fn oracle_key(hashes: &[BlockHash]) -> Vec<u8> {
    let mut key = Vec::with_capacity(32 * hashes.len());
    for h in hashes {
        key.extend_from_slice(h.as_bytes());
    }
    key
}

/// The oracle's view of what the frozen layer must iterate: every live
/// chain keyed by its *terminal* hash, sorted by that hash.
fn oracle_entries(oracle: &BTreeMap<Vec<u8>, BlockMeta>) -> Vec<([u8; 32], BlockMeta)> {
    let mut want: Vec<([u8; 32], BlockMeta)> = oracle
        .iter()
        .map(|(key, m)| {
            let mut t = [0u8; 32];
            t.copy_from_slice(&key[key.len() - 32..]);
            (t, *m)
        })
        .collect();
    want.sort_unstable_by(|a, b| a.0.cmp(&b.0));
    want
}

/// Longest cached prefix per the oracle: deepest live depth, jumping
/// holes, matching the radix tree's deepest-match semantics.
fn oracle_longest(
    oracle: &BTreeMap<Vec<u8>, BlockMeta>,
    chain: &[BlockHash],
) -> Option<(usize, BlockMeta)> {
    (1..=chain.len())
        .rev()
        .find_map(|k| oracle.get(&oracle_key(&chain[..k])).map(|m| (k, *m)))
}

#[test]
fn prop_frozen_block_index_matches_radix_and_btreemap() {
    for seed in 0..CASES {
        let mut rng = XorShift64::new(seed + 1);
        let pool = chain_pool(&mut rng);
        let mut frozen = FrozenBlockIndex::new();
        let mut radix = BlockIndex::new();
        let mut oracle: BTreeMap<Vec<u8>, BlockMeta> = BTreeMap::new();
        let refs = BlockRefs::new();
        let mut pinned: Vec<Vec<BlockHash>> = Vec::new();

        for op in 0..OPS {
            match rng.next_range(100) {
                // insert, occasionally pinning the block like a live
                // session holding a refcount on it
                0..=34 => {
                    let prefix = rand_prefix(&mut rng, &pool);
                    let meta = rand_meta(&mut rng);
                    frozen.insert(prefix, meta);
                    radix.insert(prefix, meta);
                    oracle.insert(oracle_key(prefix), meta);
                    if rng.next_range(8) == 0 {
                        refs.acquire(prefix.last().unwrap());
                        pinned.push(prefix.to_vec());
                    }
                }
                // evict — unless the block is pinned, mirroring the
                // session layer's refcount check
                35..=54 => {
                    let prefix = rand_prefix(&mut rng, &pool);
                    if refs.is_pinned(prefix.last().unwrap()) {
                        continue;
                    }
                    let got = frozen.remove(prefix);
                    assert_eq!(got, radix.remove(prefix), "seed {seed} op {op}: remove");
                    assert_eq!(got, oracle.remove(&oracle_key(prefix)), "seed {seed} op {op}");
                }
                // exact lookup
                55..=74 => {
                    let prefix = rand_prefix(&mut rng, &pool);
                    let got = frozen.get(prefix);
                    assert_eq!(got, radix.get(prefix).copied(), "seed {seed} op {op}: get");
                    assert_eq!(
                        got,
                        oracle.get(&oracle_key(prefix)).copied(),
                        "seed {seed} op {op}: get vs oracle"
                    );
                }
                // longest cached prefix over a full chain
                75..=89 => {
                    let chain = &pool[rng.next_range(pool.len())];
                    let got = frozen.longest_cached_prefix(chain);
                    assert_eq!(
                        got,
                        radix.longest_cached_prefix(chain),
                        "seed {seed} op {op}: longest vs radix"
                    );
                    assert_eq!(
                        got,
                        oracle_longest(&oracle, chain),
                        "seed {seed} op {op}: longest vs oracle"
                    );
                }
                // epoch boundary: compact and check the frozen-only
                // invariants the oracle cannot express
                _ => {
                    let pre = frozen.mem_footprint();
                    frozen.compact();
                    let post = frozen.mem_footprint();
                    assert_eq!(frozen.delta_len(), 0, "seed {seed} op {op}: delta drained");
                    assert!(
                        post.total() <= pre.total(),
                        "seed {seed} op {op}: compaction grew the footprint {} -> {}",
                        pre.total(),
                        post.total()
                    );
                    for chain in &pinned {
                        assert_eq!(
                            frozen.get(chain),
                            oracle.get(&oracle_key(chain)).copied(),
                            "seed {seed} op {op}: pinned block lost by compaction"
                        );
                        assert!(
                            frozen.get(chain).is_some(),
                            "seed {seed} op {op}: pinned block must stay cached"
                        );
                    }
                    assert_eq!(
                        frozen.entries(),
                        oracle_entries(&oracle),
                        "seed {seed} op {op}: iteration order after compaction"
                    );
                }
            }
            assert_eq!(frozen.len(), oracle.len(), "seed {seed} op {op}: len");
            assert_eq!(frozen.len(), radix.len(), "seed {seed} op {op}: len vs radix");
        }

        // final sweep: every prefix in the universe answers identically,
        // and the merged iteration is byte-identical to the oracle
        frozen.compact();
        for chain in &pool {
            for k in 1..=chain.len() {
                let prefix = &chain[..k];
                assert_eq!(
                    frozen.get(prefix),
                    oracle.get(&oracle_key(prefix)).copied(),
                    "seed {seed}: final sweep"
                );
            }
        }
        assert_eq!(frozen.entries(), oracle_entries(&oracle), "seed {seed}: final iteration");
    }
}

#[test]
fn prop_frozen_map_matches_btreemap() {
    for seed in 0..CASES {
        let mut rng = XorShift64::new(seed + 50_000);
        let universe: Vec<BlockHash> = (0..40)
            .map(|_| {
                let mut bytes = [0u8; 32];
                for b in bytes.chunks_exact_mut(8) {
                    b.copy_from_slice(&rng.next_u64().to_le_bytes());
                }
                BlockHash(bytes)
            })
            .collect();
        let mut map: FrozenMap<u64> = FrozenMap::new();
        let mut oracle: BTreeMap<BlockHash, u64> = BTreeMap::new();

        for op in 0..OPS {
            let h = universe[rng.next_range(universe.len())];
            match rng.next_range(100) {
                0..=29 => {
                    let v = rng.next_u64();
                    assert_eq!(map.insert(h, v), oracle.insert(h, v), "seed {seed} op {op}");
                }
                30..=49 => {
                    assert_eq!(map.remove(&h), oracle.remove(&h), "seed {seed} op {op}: remove");
                }
                50..=69 => {
                    assert_eq!(map.get(&h), oracle.get(&h), "seed {seed} op {op}: get");
                    assert_eq!(
                        map.contains_key(&h),
                        oracle.contains_key(&h),
                        "seed {seed} op {op}"
                    );
                }
                // copy-on-write mutation: bump through get_mut in both
                70..=84 => {
                    let got = map.get_mut(&h).map(|v| {
                        *v = v.wrapping_add(1);
                        *v
                    });
                    let want = oracle.get_mut(&h).map(|v| {
                        *v = v.wrapping_add(1);
                        *v
                    });
                    assert_eq!(got, want, "seed {seed} op {op}: get_mut");
                }
                // epoch boundary: behavior must be unchanged by freezing
                _ => {
                    map.compact();
                    assert_eq!(map.delta_len(), 0, "seed {seed} op {op}");
                }
            }
            assert_eq!(map.len(), oracle.len(), "seed {seed} op {op}: len");
        }

        let want: Vec<(BlockHash, u64)> = oracle.iter().map(|(h, v)| (*h, *v)).collect();
        assert_eq!(map.entries(), want, "seed {seed}: final iteration order");
        for h in &universe {
            assert_eq!(map.get(h), oracle.get(h), "seed {seed}: final sweep");
        }
    }
}

/// Evict-then-compact must strictly shrink the modeled footprint: the
/// monotone-shrink half of the satellite-task invariant (the random
/// interleavings above check the never-grows half at every boundary).
#[test]
fn eviction_compaction_strictly_shrinks_the_frozen_layer() {
    let tokens: Vec<i32> = (0..(64 * 32)).collect();
    let hashes = block_hashes(&tokens, 32); // one 64-block chain
    let mut idx = FrozenBlockIndex::new();
    for k in 1..=hashes.len() {
        idx.insert(&hashes[..k], rand_meta(&mut XorShift64::new(k as u64)));
    }
    assert!(idx.compact());
    let full = idx.mem_footprint();
    // tombstone three of every four prefixes, then compact them away
    for k in 1..=hashes.len() {
        if k % 4 != 0 {
            idx.remove(&hashes[..k]);
        }
    }
    assert!(idx.compact());
    let quarter = idx.mem_footprint();
    assert_eq!(idx.len(), 16);
    assert_eq!(idx.frozen_len(), 16);
    assert!(
        quarter.total() < full.total(),
        "evicting 48 of 64 prefixes must shrink the frozen layer: {} -> {}",
        full.total(),
        quarter.total()
    );
    assert_eq!(quarter.delta_bytes, 0);
    assert!(quarter.frozen_bytes > 0);
}
