//! Integration: the full serving stack — PJRT model, router, HTTP API —
//! with the constellation cache in the loop.  Skipped when artifacts/ has
//! not been built (`make artifacts`).

use skymemory::coordinator::http::{client, HttpServer};
use skymemory::coordinator::{GenRequest, Stack, StackConfig};
use skymemory::util::json::Json;

fn artifacts_present() -> bool {
    skymemory::runtime::model_config::default_artifacts_dir()
        .join("model_config.json")
        .exists()
}

fn stack() -> Stack {
    Stack::build(StackConfig::default()).expect("stack builds")
}

#[test]
fn warm_request_restores_prefix_from_orbit() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let stack = stack();
    let req = GenRequest {
        prompt: "The ground station sees ten or twenty satellites at once. The nearest \
                 one is the center of the map."
            .into(),
        max_new_tokens: 8,
        ..Default::default()
    };
    let cold = stack.router.generate(req.clone()).unwrap();
    assert_eq!(cold.cached_blocks, 0);
    assert!(cold.prefill_blocks >= 3);
    let warm = stack.router.generate(req.clone()).unwrap();
    assert_eq!(warm.cached_blocks, cold.prefill_blocks);
    assert_eq!(warm.prefill_blocks, 0);
    // identical greedy output with and without the cache (numerical
    // equivalence through quantization holds at greedy argmax)
    assert_eq!(cold.text, warm.text, "cache changed the generation");
    // cache bypass still works
    let mut nocache = req;
    nocache.use_cache = false;
    let r = stack.router.generate(nocache).unwrap();
    assert_eq!(r.cached_blocks, 0);
    assert_eq!(r.text, cold.text);
}

#[test]
fn diverging_prompts_share_prefix_blocks() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let stack = stack();
    let base = "A transformer reads a prompt as a sequence of tokens, and for every \
                token it stores a key and a value in every layer.";
    let r1 = stack
        .router
        .generate(GenRequest { prompt: format!("{base} What is stored?"), max_new_tokens: 4, ..Default::default() })
        .unwrap();
    assert!(r1.prefill_blocks >= 3);
    let r2 = stack
        .router
        .generate(GenRequest { prompt: format!("{base} Why does it help?"), max_new_tokens: 4, ..Default::default() })
        .unwrap();
    // the shared context blocks come from orbit; only the divergent tail
    // is recomputed
    assert!(r2.cached_blocks >= 3, "{:?}", r2);
    assert!(r2.prefill_blocks <= 1);
}

#[test]
fn http_api_serves_and_reports_metrics() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let stack = stack();
    let server = HttpServer::spawn("127.0.0.1:0", stack.router.clone()).unwrap();
    let body = r#"{"prompt": "the cache moves with the satellite and the ground", "max_tokens": 6}"#;
    let (status, resp) = client::post(server.addr, "/generate", body).unwrap();
    assert_eq!(status, 200, "{resp}");
    let j = Json::parse(&resp).unwrap();
    assert_eq!(j.get("generated_tokens").and_then(Json::as_usize), Some(6));
    assert!(j.get("ttft_s").and_then(Json::as_f64).unwrap() > 0.0);
    // again: now served from cache
    let (_, resp2) = client::post(server.addr, "/generate", body).unwrap();
    let j2 = Json::parse(&resp2).unwrap();
    assert!(j2.get("cached_blocks").and_then(Json::as_usize).unwrap() > 0);

    let (ms, metrics) = client::get(server.addr, "/metrics").unwrap();
    assert_eq!(ms, 200);
    assert!(metrics.contains("skymemory_requests_total 2"));
    assert!(metrics.contains("skymemory_cache_blocks_hit"));

    let (hs, health) = client::get(server.addr, "/healthz").unwrap();
    assert_eq!((hs, health.as_str()), (200, "ok\n"));
    let (nf, _) = client::get(server.addr, "/nope").unwrap();
    assert_eq!(nf, 404);
    let (bad, _) = client::post(server.addr, "/generate", "not json").unwrap();
    assert_eq!(bad, 400);
    server.shutdown();
}

#[test]
fn concurrent_requests_across_workers() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let stack = stack();
    let mut rxs = Vec::new();
    for i in 0..6 {
        rxs.push(stack.router.submit(GenRequest {
            prompt: format!("satellite number {i} holds a shard of the cache in orbit"),
            max_new_tokens: 5,
            ..Default::default()
        }));
    }
    for rx in rxs {
        let r = rx.recv().unwrap().unwrap();
        assert_eq!(r.tokens.len(), 5);
    }
    assert_eq!(
        stack
            .metrics
            .requests_total
            .load(std::sync::atomic::Ordering::Relaxed),
        6
    );
}

#[test]
fn oversized_prompt_rejected_cleanly() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let stack = stack();
    let req = GenRequest {
        prompt: "x".repeat(400), // > max_seq
        max_new_tokens: 8,
        ..Default::default()
    };
    assert!(stack.router.generate(req).is_err());
    // the engine remains usable afterwards (slot was freed)
    let ok = stack.router.generate(GenRequest {
        prompt: "short prompt".into(),
        max_new_tokens: 3,
        ..Default::default()
    });
    assert!(ok.is_ok());
}

#[test]
fn rotation_driver_keeps_cache_hot_across_epochs() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let stack = stack();
    let req = GenRequest {
        prompt: "memory is a hierarchy and the sky is one of its levels, registers, \
                 cache, host memory, flash, disk, network, orbit"
            .into(),
        max_new_tokens: 4,
        ..Default::default()
    };
    let cold = stack.router.generate(req.clone()).unwrap();
    assert!(cold.prefill_blocks >= 3);
    // drive 3 rotation epochs at 120 ms each
    let stop = stack.spawn_rotation_driver(std::time::Duration::from_millis(120));
    std::thread::sleep(std::time::Duration::from_millis(450));
    let _ = stop.send(());
    let epoch = stack.manager.transport_epoch();
    assert!(epoch >= 3, "driver advanced only to epoch {epoch}");
    // post-rotation request still hits the migrated cache
    let warm = stack.router.generate(req).unwrap();
    assert_eq!(warm.cached_blocks, cold.prefill_blocks, "{warm:?}");
}
