//! Integration: the deterministic scenario harness — golden determinism
//! of the metrics JSON, mega-constellation completion under failure
//! injection, and the spec registry.

use skymemory::sim::harness::{run_scenario, ScenarioReport};
use skymemory::sim::scenario::{FailurePlan, ScenarioSpec};

/// Golden property: the same seed must produce byte-identical metrics
/// JSON for the paper testbed shape, run-to-run in the same process.
#[test]
fn paper_19x5_fixed_seed_is_byte_identical() {
    let spec = ScenarioSpec::paper_19x5(1234);
    let a: ScenarioReport = run_scenario(&spec);
    let b: ScenarioReport = run_scenario(&spec);
    assert_eq!(a, b, "reports must be structurally identical");
    let (ja, jb) = (a.to_json_string(), b.to_json_string());
    assert_eq!(ja, jb, "metrics JSON must be byte-identical");
    // and the run really exercised the machinery
    assert!(a.requests > 0);
    assert!(a.migrated_chunks > 0, "rotation must migrate chunks: {a:?}");
    assert!(a.kvc.blocks_stored > 0);
    assert!(a.isl_hops > 0);
}

#[test]
fn paper_19x5_eviction_pressure_is_real() {
    // the paper spec's one-shot scan traffic is sized to overflow the
    // per-satellite budget: LRU eviction must actually occur on the
    // satellites, while the hot contexts keep hitting
    let r = run_scenario(&ScenarioSpec::paper_19x5(7));
    assert!(r.evicted_blocks > 0, "no eviction pressure observed: {r:?}");
    assert!(r.evicted_chunks >= r.evicted_blocks);
    assert!(r.block_hit_rate > 0.0, "{r:?}");
}

/// Acceptance: the >= 70-plane mega-constellation completes with failure
/// injection enabled and still serves a nonzero hit rate.
#[test]
fn starlink_shell_nonzero_hit_rate_under_failures() {
    let spec = ScenarioSpec::starlink_shell(99);
    assert!(spec.planes >= 70);
    assert!(!spec.failures.is_none());
    let r = run_scenario(&spec);
    assert!(r.sat_losses > 0, "losses must be injected: {r:?}");
    assert!(r.isl_outages > 0, "outages must be injected: {r:?}");
    assert!(r.handovers > 0, "a ground handover must occur: {r:?}");
    assert!(r.block_hit_rate > 0.0, "cache must survive failures: {r:?}");
    assert!(r.blocks_hit > 0);
}

#[test]
fn starlink_shell_is_deterministic_with_failures() {
    let spec = ScenarioSpec::starlink_shell(2024);
    let a = run_scenario(&spec).to_json_string();
    let b = run_scenario(&spec).to_json_string();
    assert_eq!(a, b);
}

#[test]
fn kuiper_shell_completes_and_reports() {
    let r = run_scenario(&ScenarioSpec::kuiper_shell(5));
    assert_eq!(r.planes, 34);
    assert_eq!(r.sats_per_plane, 34);
    assert!(r.requests > 0);
    assert!(r.block_hit_rate > 0.0, "{r:?}");
    assert!(r.analytic_worst_case_s > 0.0);
}

#[test]
fn failure_plan_actually_changes_the_run() {
    // same workload and seed, with and without the failure plan: the
    // failure-free run must see no injected damage, the failure run must
    let seed = 31;
    let with = run_scenario(&ScenarioSpec::paper_19x5(seed));
    let mut spec = ScenarioSpec::paper_19x5(seed);
    spec.failures = FailurePlan::NONE;
    let without = run_scenario(&spec);
    assert_eq!(with.requests, without.requests, "same workload either way");
    assert_eq!(without.sat_losses + without.isl_outages + without.handovers, 0);
    assert_eq!(without.blackholed_requests, 0);
    assert_eq!(without.failed_writes, 0);
    assert!(with.sat_losses > 0);
    assert!(without.block_hit_rate > 0.3, "clean run must hit well: {without:?}");
}

#[test]
fn seeds_change_the_numbers_but_not_the_shape() {
    let a = run_scenario(&ScenarioSpec::paper_19x5(1));
    let b = run_scenario(&ScenarioSpec::paper_19x5(2));
    assert_ne!(
        a.to_json_string(),
        b.to_json_string(),
        "different seeds must explore different runs"
    );
    assert_eq!(a.requests, b.requests);
    assert_eq!((a.planes, a.sats_per_plane), (b.planes, b.sats_per_plane));
}

#[test]
fn registry_covers_all_builtins() {
    let specs = ScenarioSpec::builtin(8);
    assert_eq!(specs.len(), 5);
    for spec in &specs {
        spec.validate();
        let found = ScenarioSpec::by_name(&spec.name, 8).expect("by_name finds builtin");
        assert_eq!(found.planes, spec.planes);
    }
    assert!(ScenarioSpec::by_name("not-a-scenario", 8).is_none());
}

#[test]
fn builtin_summaries_resolve_exactly_one_registry_each() {
    // `scenario --list` prints BUILTIN_SUMMARIES; `scenario`, `trace` and
    // `mem` resolve names through the two by_name registries.  Every
    // summarized name must resolve in exactly one of them (a double
    // registration would make the CLI dispatch ambiguous), every
    // registered builtin must be summarized, and every resolved spec must
    // validate so all CLI paths can actually run it.
    use skymemory::sim::scenario::{FederatedScenarioSpec, BUILTIN_SUMMARIES};
    let summarized: Vec<&str> = BUILTIN_SUMMARIES.iter().map(|(n, _)| *n).collect();
    for (name, _) in BUILTIN_SUMMARIES {
        let single = ScenarioSpec::by_name(name, 3);
        let fed = FederatedScenarioSpec::by_name(name, 3);
        assert!(
            single.is_some() != fed.is_some(),
            "{name} must resolve in exactly one registry"
        );
        if let Some(spec) = single {
            assert_eq!(spec.name, *name, "registry key must match the spec name");
            spec.validate();
        }
        if let Some(spec) = fed {
            assert_eq!(spec.name, *name, "registry key must match the spec name");
            spec.validate();
        }
    }
    for spec in ScenarioSpec::builtin(3) {
        assert!(summarized.contains(&spec.name.as_str()), "{} lacks a summary", spec.name);
    }
    for name in ["federated-dual-shell", "federated-tri-shell"] {
        assert!(summarized.contains(&name), "{name} lacks a summary");
    }
}

/// Acceptance for the `kvc::session` layer: the fork-heavy builtin must
/// strictly beat the independent-sessions replay of the identical token
/// traffic on hit rate, ISL bytes moved, and bytes per cached token —
/// prefix sharing has to pay for itself end to end.
#[test]
fn fork_heavy_chat_beats_its_baseline_end_to_end() {
    let spec = ScenarioSpec::fork_heavy_chat(42);
    let shared = run_scenario(&spec);
    let base = run_scenario(&spec.session_baseline());
    assert_eq!(shared.requests, base.requests, "identical traffic either way");
    let ss = shared.sessions.as_ref().expect("session run reports sessions");
    let bs = base.sessions.as_ref().expect("baseline reports sessions");
    assert!(ss.mode_shared && !bs.mode_shared);
    assert!(ss.forked > 0, "the trace must actually fork: {ss:?}");
    assert!(ss.blocks_shared > 0, "forks must share blocks: {ss:?}");
    assert_eq!(bs.blocks_shared, 0, "the baseline must not share");
    assert!(
        shared.block_hit_rate > base.block_hit_rate,
        "sharing must win hit rate: {} vs {}",
        shared.block_hit_rate,
        base.block_hit_rate
    );
    assert!(
        shared.isl_bytes < base.isl_bytes,
        "sharing must move fewer ISL bytes: {} vs {}",
        shared.isl_bytes,
        base.isl_bytes
    );
    assert!(
        shared.memory.bytes_per_cached_token < base.memory.bytes_per_cached_token,
        "sharing must cache more per byte: {} vs {}",
        shared.memory.bytes_per_cached_token,
        base.memory.bytes_per_cached_token
    );
}

/// Acceptance for the epoch-frozen two-layer index (`kvc::frozen`):
/// epoch boundaries must actually compact the delta into the frozen
/// layer, the frozen/delta split must land in the metrics JSON, and the
/// whole `memory` object — split included — must stay byte-identical
/// across same-seed runs, single-shell and federated alike.
#[test]
fn frozen_index_split_is_reported_and_deterministic() {
    use skymemory::sim::harness::run_federated_scenario;
    use skymemory::sim::scenario::FederatedScenarioSpec;

    let spec = ScenarioSpec::fork_heavy_chat(7);
    let a = run_scenario(&spec);
    let b = run_scenario(&spec);
    assert_eq!(a.to_json_string(), b.to_json_string(), "byte-identical incl. the split");
    assert!(a.memory.compactions > 0, "epoch boundaries must compact: {:?}", a.memory);
    assert!(a.memory.frozen_bytes > 0, "writes must freeze by the last epoch: {:?}", a.memory);
    let j = a.to_json_string();
    for key in ["\"frozen_bytes\"", "\"delta_bytes\"", "\"compactions\""] {
        assert!(j.contains(key), "missing {key}");
    }

    let fspec = FederatedScenarioSpec::by_name("federated-tri-shell", 7).expect("builtin");
    let fa = run_federated_scenario(&fspec);
    let fb = run_federated_scenario(&fspec);
    assert_eq!(fa.to_json_string(), fb.to_json_string(), "federated runs byte-identical");
    assert!(fa.memory.compactions > 0, "federated boundaries must compact: {:?}", fa.memory);
    assert!(fa.memory.frozen_bytes > 0, "federated index must freeze: {:?}", fa.memory);
}

/// Acceptance for the `net::sched` engine: the mega-shell scenario runs
/// byte-stably with >= 1000 chunks concurrently in flight — concurrency
/// no thread-per-chunk (or 8-thread-stripe) model could express — and
/// the scheduler's queueing/utilization counters land in the JSON.
#[test]
fn mega_shell_thousand_chunks_in_flight_and_byte_stable() {
    let spec = ScenarioSpec::mega_shell(77);
    let a = run_scenario(&spec);
    let b = run_scenario(&spec);
    assert_eq!(a, b, "virtual-time runs must be structurally identical");
    assert_eq!(a.to_json_string(), b.to_json_string(), "and byte-identical");
    assert!(a.requests > 0);
    assert!(a.block_hit_rate > 0.0, "{a:?}");
    assert!(
        a.sched.peak_in_flight >= 1000,
        "a mega-shell block must put >= 1000 chunks in flight at once: {:?}",
        a.sched
    );
    assert!(a.sched.transfers > 10_000, "{:?}", a.sched);
    assert!(a.sched.links_used > 25, "uplink + service links across the box: {:?}", a.sched);
    assert!(a.sched.queued_ns > 0, "throttled links must queue: {:?}", a.sched);
    assert!(a.sched.virtual_ns > 0);
    let j = a.to_json_string();
    for key in
        ["\"sched\"", "\"peak_in_flight\"", "\"link_queued_ns\"", "\"busiest_link_transfers\""]
    {
        assert!(j.contains(key), "missing {key}");
    }
}

#[test]
fn sched_window_shapes_the_tail_on_the_mega_shell() {
    // a wider per-link window admits more concurrent transfers: queueing
    // delay must not increase, and the pipelined virtual time must not
    // get worse (scaled-down run: the effect shows within one epoch)
    let mut narrow = ScenarioSpec::mega_shell(5);
    narrow.epochs = 1;
    narrow.requests_per_epoch = 4;
    narrow.sched_window = 1;
    let mut wide = narrow.clone();
    wide.sched_window = 64;
    let rn = run_scenario(&narrow);
    let rw = run_scenario(&wide);
    assert_eq!(rn.requests, rw.requests, "same workload either way");
    assert!(
        rw.sched.queued_ns <= rn.sched.queued_ns,
        "window 64 must not queue more than window 1: {} vs {}",
        rw.sched.queued_ns,
        rn.sched.queued_ns
    );
    assert!(
        rw.sched.virtual_ns <= rn.sched.virtual_ns,
        "wider windows cannot slow the pipeline: {} vs {}",
        rw.sched.virtual_ns,
        rn.sched.virtual_ns
    );
}
