//! Integration: the full §3.8 protocol over an in-process constellation —
//! multi-block prompts, all strategies and quantizers, rotation with
//! migration, eviction pressure, and failure injection.

use skymemory::constellation::los::LosGrid;
use skymemory::constellation::topology::{SatId, Torus};
use skymemory::kvc::block::block_hashes;
use skymemory::kvc::eviction::EvictionPolicy;
use skymemory::kvc::manager::{KvcConfig, KvcManager};
use skymemory::kvc::quantize::Quantizer;
use skymemory::mapping::Strategy;
use skymemory::net::transport::{GroundView, InProcTransport, Transport};
use skymemory::satellite::fleet::Fleet;
use skymemory::util::rng::XorShift64;
use std::sync::Arc;

fn setup(mut cfg: KvcConfig, sat_budget: usize) -> (Arc<Fleet>, KvcManager) {
    cfg.chunk_size = 600;
    let torus = Torus::new(15, 15);
    let fleet = Arc::new(Fleet::new(torus, sat_budget, cfg.eviction));
    let center = SatId::new(7, 7);
    let ground = GroundView::new(center, &LosGrid::new(center, 2, 2), torus.sats_per_plane);
    let transport = Arc::new(InProcTransport::new(fleet.clone(), ground, None));
    (fleet.clone(), KvcManager::new(cfg, torus, transport))
}

fn values(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = XorShift64::new(seed);
    (0..n).map(|_| (rng.next_f64() as f32 - 0.5) * 4.0).collect()
}

#[test]
fn every_strategy_and_quantizer_roundtrips_through_orbit() {
    for strategy in Strategy::ALL {
        for quantizer in [
            Quantizer::F32,
            Quantizer::QuantoInt8 { group: 32 },
            Quantizer::HqqInt8 { group: 32 },
        ] {
            let (_fleet, m) = setup(
                KvcConfig { strategy, quantizer, n_servers: 10, ..KvcConfig::default() },
                10 << 20,
            );
            let tokens: Vec<i32> = (0..160).map(|i| i % 251).collect();
            let hashes = block_hashes(&tokens, 32);
            for b in 0..hashes.len() {
                m.put_block(&hashes, b, &values(4096, b as u64), 0).unwrap();
            }
            let (blocks, _) = m.lookup(&hashes, 0).unwrap();
            assert_eq!(blocks, 5, "{} {}", strategy.name(), quantizer.name());
            let fetch = m.fetch_prefix(&hashes, blocks, 0).unwrap();
            assert_eq!(fetch.blocks, 5);
            for (i, kv) in fetch.kv_blocks.iter().enumerate() {
                let orig = values(4096, i as u64);
                let max_err = orig
                    .iter()
                    .zip(kv)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0f32, f32::max);
                let bound = if quantizer == Quantizer::F32 { 1e-9 } else { 0.06 };
                assert!(max_err < bound, "{} block {i}: {max_err}", quantizer.name());
            }
        }
    }
}

#[test]
fn cache_survives_many_rotation_epochs() {
    let (_fleet, m) = setup(KvcConfig { n_servers: 9, ..KvcConfig::default() }, 10 << 20);
    let tokens: Vec<i32> = (0..96).collect();
    let hashes = block_hashes(&tokens, 32);
    for b in 0..3 {
        m.put_block(&hashes, b, &values(2048, b as u64), 0).unwrap();
    }
    for epoch in 0..8u64 {
        m.advance_epoch(epoch).unwrap();
        let fetch = m.fetch_prefix(&hashes, 3, epoch + 1).unwrap();
        assert_eq!(fetch.blocks, 3, "epoch {}", epoch + 1);
    }
}

#[test]
fn blocks_written_at_different_epochs_coexist() {
    let (_fleet, m) = setup(KvcConfig { n_servers: 9, ..KvcConfig::default() }, 10 << 20);
    let tokens: Vec<i32> = (0..128).collect();
    let hashes = block_hashes(&tokens, 32);
    m.put_block(&hashes, 0, &values(2048, 0), 0).unwrap();
    m.advance_epoch(0).unwrap();
    m.put_block(&hashes, 1, &values(2048, 1), 1).unwrap();
    m.advance_epoch(1).unwrap();
    m.put_block(&hashes, 2, &values(2048, 2), 2).unwrap();
    // all three blocks fetchable at epoch 2 despite different write epochs
    let fetch = m.fetch_prefix(&hashes, 3, 2).unwrap();
    assert_eq!(fetch.blocks, 3);
}

#[test]
fn eviction_pressure_truncates_but_never_corrupts() {
    // tiny satellite budgets force LRU evictions; fetches must either
    // return correct data or honestly report a miss — never garbage
    let (_fleet, m) = setup(
        KvcConfig { n_servers: 9, eviction: EvictionPolicy::Gossip, ..KvcConfig::default() },
        3_000, // each sat holds only ~4 chunks of ~620B -> heavy LRU churn
    );
    let mut all_hashes = Vec::new();
    for p in 0i32..12 {
        let tokens: Vec<i32> = (0..64).map(|i| i * (p + 1)).collect();
        let hashes = block_hashes(&tokens, 32);
        for b in 0usize..2 {
            m.put_block(&hashes, b, &values(2048, (p as usize * 2 + b) as u64), 0).unwrap();
        }
        all_hashes.push(hashes);
    }
    let mut hits = 0;
    for (p, hashes) in all_hashes.iter().enumerate() {
        if let Some((blocks, _)) = m.lookup(hashes, 0) {
            let fetch = m.fetch_prefix(hashes, blocks, 0).unwrap();
            for (b, kv) in fetch.kv_blocks.iter().enumerate() {
                let orig = values(2048, (p * 2 + b) as u64);
                let max_err = orig
                    .iter()
                    .zip(kv)
                    .map(|(a, x)| (a - x).abs())
                    .fold(0f32, f32::max);
                assert!(max_err < 0.06, "prompt {p} block {b} corrupted: {max_err}");
                hits += 1;
            }
        }
    }
    // some content must have been evicted AND some must survive
    assert!(hits > 0, "everything evicted");
    assert!(hits < 24, "nothing evicted — budget not exercised");
}

#[test]
fn lazy_eviction_cleans_index_after_sabotage() {
    let (fleet, m) = setup(
        KvcConfig { n_servers: 9, eviction: EvictionPolicy::Lazy, ..KvcConfig::default() },
        10 << 20,
    );
    let tokens: Vec<i32> = (0..96).collect();
    let hashes = block_hashes(&tokens, 32);
    for b in 0..3 {
        m.put_block(&hashes, b, &values(2048, b as u64), 0).unwrap();
    }
    // knock out block 2 everywhere (simulate satellite memory loss)
    use skymemory::net::messages::{Envelope, Request};
    for node in fleet.nodes() {
        let env = Envelope::new(node.id, 0);
        node.handle(&fleet.torus, &env, &Request::Evict { block: hashes[2], gossip_ttl: 0 });
    }
    let fetch = m.fetch_prefix(&hashes, 3, 0).unwrap();
    assert_eq!(fetch.blocks, 2);
    // the index forgot the broken prefix: next lookup stops at 2 blocks
    assert_eq!(m.lookup(&hashes, 0).unwrap().0, 2);
    // and a re-put repairs it
    m.put_block(&hashes, 2, &values(2048, 2), 0).unwrap();
    assert_eq!(m.fetch_prefix(&hashes, 3, 0).unwrap().blocks, 3);
}

#[test]
fn session_pinned_prefix_survives_eviction_pressure() {
    // regression for the refcount-aware eviction guard: before the
    // session layer installed its BlockRefs on the fleet, LRU pressure
    // (or a gossiped eviction) would happily delete a prefix that a
    // live forked session still mapped, and the fork's next read came
    // back a miss.  Pinned blocks must deflect eviction, stay fetchable
    // and uncorrupted, and become evictable again once the sessions
    // drop.
    use skymemory::kvc::session::SessionManager;
    let (fleet, m) = setup(
        KvcConfig { n_servers: 9, eviction: EvictionPolicy::Gossip, ..KvcConfig::default() },
        3_000, // ~4 chunks per satellite -> heavy LRU churn
    );
    let sessions = SessionManager::new(32);
    fleet.set_block_refs(&sessions.refs());

    // a 2-block template prefix, stored once, then forked
    let tokens: Vec<i32> = (0..64).map(|i| i * 3 + 1).collect();
    let (root, new_blocks) = sessions.create(&tokens);
    let hashes = sessions.chain(root);
    assert_eq!(new_blocks, hashes);
    for b in 0..hashes.len() {
        m.put_block(&hashes, b, &values(2048, b as u64), 0).unwrap();
    }
    let fork = sessions.fork(root);

    // heavy unpinned scan traffic overflows the per-satellite budgets
    for p in 0i32..12 {
        let scan: Vec<i32> = (0..64).map(|i| (i + 1) * (p + 100)).collect();
        let sh = block_hashes(&scan, 32);
        for b in 0usize..2 {
            m.put_block(&sh, b, &values(2048, 90 + p as u64), 0).unwrap();
        }
    }
    // and a gossiped eviction aimed straight at the pinned block is
    // deflected on every satellite it reaches
    let center = m.transport().closest();
    m.transport().evict_block(center, hashes[0], 2).unwrap();
    assert!(sessions.refs().deflections() > 0, "the guard must actually fire");

    // the fork's prefix is still fully resident and uncorrupted
    let (blocks, _) = m.lookup(&hashes, 0).expect("pinned prefix must stay indexed");
    assert_eq!(blocks, hashes.len());
    let fetch = m.fetch_prefix(&hashes, blocks, 0).unwrap();
    assert_eq!(fetch.blocks, hashes.len());
    for (b, kv) in fetch.kv_blocks.iter().enumerate() {
        let orig = values(2048, b as u64);
        let max_err =
            orig.iter().zip(kv).map(|(a, x)| (a - x).abs()).fold(0f32, f32::max);
        assert!(max_err < 0.06, "fork block {b} corrupted: {max_err}");
    }

    // dropping both sessions releases the pin: the same eviction now
    // actually removes chunks
    sessions.drop_session(fork);
    sessions.drop_session(root);
    assert_eq!(sessions.refs().total_refs(), 0);
    let before = fleet.total_chunks();
    m.transport().evict_block(center, hashes[0], 2).unwrap();
    assert!(fleet.total_chunks() < before, "unpinned blocks must evict again");
}

#[test]
fn distributed_and_radix_lookup_agree_under_rotation() {
    let cfg = KvcConfig { n_servers: 9, ..KvcConfig::default() };
    let (_fleet, m) = setup(cfg, 10 << 20);
    let tokens: Vec<i32> = (0..128).collect();
    let hashes = block_hashes(&tokens, 32);
    for b in 0..4 {
        m.put_block(&hashes, b, &values(2048, b as u64), 0).unwrap();
    }
    let mut no_radix = cfg;
    no_radix.use_radix_index = false;
    no_radix.chunk_size = 600;
    let m2 = KvcManager::new(no_radix, Torus::new(15, 15), m.transport().clone());
    assert_eq!(m.lookup(&hashes, 0).unwrap().0, m2.lookup(&hashes, 0).unwrap().0);
    // after one migration epoch both still agree
    m.advance_epoch(0).unwrap();
    assert_eq!(m.lookup(&hashes, 1).unwrap().0, 4);
    let fetch2 = m2.fetch_prefix(&hashes, 4, 1).unwrap();
    assert_eq!(fetch2.blocks, 4, "distributed path must survive migration");
}

#[test]
fn gossip_eviction_propagates_to_siblings() {
    let (fleet, m) = setup(
        KvcConfig { n_servers: 9, eviction: EvictionPolicy::Gossip, ..KvcConfig::default() },
        10 << 20,
    );
    let tokens: Vec<i32> = (0..32).collect();
    let hashes = block_hashes(&tokens, 32);
    m.put_block(&hashes, 0, &values(4096, 7), 0).unwrap();
    let before = fleet.total_chunks();
    assert!(before > 1);
    // explicit eviction at the centre with the configured gossip radius
    let center = m.transport().closest();
    m.transport().evict_block(center, hashes[0], 2).unwrap();
    assert_eq!(fleet.total_chunks(), 0, "gossip radius 2 covers the 3x3 layout");
}

#[test]
fn prefetcher_preplaces_hot_blocks_for_future_epochs() {
    // §3.7 end to end: record traffic, pre-place for epoch+1 from the
    // local RAM tier, advance the ground view WITHOUT migrating, and the
    // hot block is already sitting on the new LOS window.
    use skymemory::coordinator::prefetch::Prefetcher;
    let cfg = KvcConfig { n_servers: 9, chunk_size: 600, ..KvcConfig::default() };
    let torus = Torus::new(15, 15);
    let fleet = Arc::new(Fleet::new(torus, 10 << 20, cfg.eviction));
    let center = SatId::new(7, 7);
    let ground = GroundView::new(center, &LosGrid::new(center, 2, 2), torus.sats_per_plane);
    let transport = Arc::new(InProcTransport::new(fleet.clone(), ground, None));
    let m = KvcManager::new(cfg, torus, transport).with_local_tier(1 << 20);

    let tokens: Vec<i32> = (0..64).collect();
    let hashes = block_hashes(&tokens, 32);
    for b in 0..2 {
        m.put_block(&hashes, b, &values(2048, b as u64), 0).unwrap();
    }
    let p = Prefetcher::new(0.5, 8);
    for _ in 0..5 {
        p.record(&hashes, 2);
    }
    assert_eq!(p.tracked(), 2);
    let placed = p.preplace(&m, 0, 1).unwrap();
    assert_eq!(placed, 2, "both hot blocks re-placed from the RAM tier");
    // jump the ground view an epoch ahead with NO satellite migration:
    // the predictive copies make the fetch work anyway
    m.transport().set_epoch(1);
    m.local_tier().unwrap().invalidate(&hashes[0]);
    m.local_tier().unwrap().invalidate(&hashes[1]);
    let fetch = m.fetch_prefix(&hashes, 2, 1).unwrap();
    assert_eq!(fetch.blocks, 2);
}
