//! Integration: geometry + topology + rotation + LOS + mappings working
//! together across rotation epochs — the constellation substrate as the
//! protocol consumes it.

use skymemory::constellation::geometry::Geometry;
use skymemory::constellation::los::LosGrid;
use skymemory::constellation::rotation::RotationModel;
use skymemory::constellation::topology::{SatId, Torus};
use skymemory::mapping::migration::{by_plane, migration_plan};
use skymemory::mapping::{box_width, Strategy};

#[test]
fn rotation_model_drives_los_and_layouts_consistently() {
    let geo = Geometry::new(550.0, 19, 5);
    let torus = Torus::new(5, 19);
    let model = RotationModel::new(geo, SatId::new(2, 9));
    let period = model.epoch_period_s();

    for epoch in 0..25u64 {
        let t = epoch as f64 * period + 1.0;
        let center = model.center_at(t);
        assert_eq!(center, model.center_at_epoch(epoch));
        let los = LosGrid::new(center, 2, 2);
        assert!(los.contains(&torus, center));
        for st in Strategy::ALL {
            let layout = st.initial_layout(&torus, center, 10);
            let uniq: std::collections::HashSet<_> = layout.iter().collect();
            assert_eq!(uniq.len(), 10);
            for sat in &layout {
                assert!(torus.contains(*sat));
                let route = torus.route(center, *sat);
                assert_eq!(route.len(), torus.hops(center, *sat));
            }
        }
    }
}

#[test]
fn migration_chain_tracks_rotation_for_a_full_orbit() {
    let torus = Torus::new(5, 19);
    let write_center = SatId::new(2, 9);
    let st = Strategy::RotationHopAware;
    let n = 10;
    let mut layout = st.layout_at(&torus, write_center, n, 0);
    for epoch in 0..19u64 {
        let plan = migration_plan(&torus, st, write_center, n, epoch);
        for m in &plan {
            layout[(m.server - 1) as usize] = m.to;
        }
        assert_eq!(layout, st.layout_at(&torus, write_center, n, epoch + 1), "epoch {epoch}");
        // §3.4: migrations are parallel per plane, one handoff pair each
        for (_, moves) in by_plane(&plan) {
            let froms: std::collections::HashSet<_> = moves.iter().map(|m| m.from).collect();
            let tos: std::collections::HashSet<_> = moves.iter().map(|m| m.to).collect();
            assert_eq!(froms.len(), 1);
            assert_eq!(tos.len(), 1);
        }
    }
}

#[test]
fn layouts_stay_within_los_reach_of_moving_center() {
    let torus = Torus::new(7, 21);
    let write_center = SatId::new(3, 10);
    for st in [Strategy::RotationAware, Strategy::RotationHopAware] {
        for n in [9usize, 10, 16, 25] {
            let half = (box_width(n) - 1) / 2;
            for epoch in 0..40u64 {
                let current_center = torus.offset(write_center, 0, -(epoch as i32));
                for sat in st.layout_at(&torus, write_center, n, epoch) {
                    let (dp, ds) = torus.signed_offset(current_center, sat);
                    assert!(
                        dp.unsigned_abs() as usize <= half && ds.unsigned_abs() as usize <= half,
                        "{:?} n={n} epoch={epoch}: {sat} outside box (dp={dp}, ds={ds})",
                        st
                    );
                }
            }
        }
    }
}

#[test]
fn hop_aware_drift_grows_monotonically() {
    let torus = Torus::new(15, 15);
    let write_center = SatId::new(7, 7);
    let layout = Strategy::HopAware.layout_at(&torus, write_center, 13, 0);
    let mut prev_max = 0;
    for epoch in 0..5u64 {
        let current = torus.offset(write_center, 0, -(epoch as i32));
        let max_hops = layout.iter().map(|s| torus.hops(current, *s)).max().unwrap();
        assert!(max_hops >= prev_max, "epoch {epoch}");
        prev_max = max_hops;
    }
    assert!(prev_max >= 2 + 4, "after 4 epochs the diamond edge is 4 east");
}

#[test]
fn visibility_window_matches_epoch_period() {
    let geo = Geometry::new(550.0, 19, 5);
    let model = RotationModel::new(geo, SatId::new(0, 0));
    let minutes = model.epoch_period_s() / 60.0;
    assert!((3.0..10.0).contains(&minutes), "{minutes} min");
}

#[test]
fn eq1_eq2_consistency_with_torus_dims() {
    let geo = Geometry::new(550.0, 19, 5);
    let torus = Torus::new(geo.planes, geo.sats_per_plane);
    assert_eq!(torus.len(), 95);
    assert!(geo.worst_hop_latency_s() >= geo.intra_plane_latency_s());
    assert!(geo.worst_hop_latency_s() >= geo.inter_plane_latency_s());
}

#[test]
fn predictive_placement_center_is_exact() {
    // §3.7: "the set of satellites in the LOS at that future time is known
    // exactly" — the centre computed for a future epoch must equal the
    // centre the rotation model reports once that time arrives.
    let geo = Geometry::new(550.0, 19, 5);
    let model = RotationModel::new(geo, SatId::new(2, 9));
    let p = model.epoch_period_s();
    for future_epoch in [1u64, 3, 10, 19, 40] {
        let predicted = model.center_at_epoch(future_epoch);
        let arrived = model.center_at(future_epoch as f64 * p + 0.5 * p);
        assert_eq!(predicted, arrived);
    }
}
