//! Integration: the multi-shell federation — golden determinism of the
//! federated metrics JSON, the inter-shell handover acceptance case
//! (killing the primary shell's layout box mid-run hands hot chunks to
//! the secondary shell and beats the no-federation baseline), and the
//! scenario registry / CLI surface.

use skymemory::sim::harness::{run_federated_scenario, FederatedScenarioReport};
use skymemory::sim::scenario::{FederatedScenarioSpec, ScenarioSpec};

/// Golden property: the same seed must produce byte-identical metrics
/// JSON for the full dual-shell federation, run-to-run in the same
/// process.
#[test]
fn federated_dual_shell_fixed_seed_is_byte_identical() {
    let spec = FederatedScenarioSpec::federated_dual_shell(1234);
    let a: FederatedScenarioReport = run_federated_scenario(&spec);
    let b: FederatedScenarioReport = run_federated_scenario(&spec);
    assert_eq!(a, b, "reports must be structurally identical");
    assert_eq!(a.to_json_string(), b.to_json_string(), "metrics JSON must be byte-identical");
    // and the run really exercised the machinery
    assert!(a.requests > 0);
    assert!(a.blocks_requested > 0);
    assert!(a.migrated_chunks > 0, "per-shell rotation must migrate chunks: {a:?}");
    assert!(a.sat_losses > 0, "random failures must hit the primary: {a:?}");
}

/// Acceptance: killing the primary shell's layout box mid-run hands the
/// hot chunks over to the secondary shell — the handover rides the
/// inter-shell links, the secondary serves hits afterwards, and the
/// federation's hit rate stays strictly above the no-federation
/// (single-shell) baseline under the identical kill schedule.
#[test]
fn primary_box_kill_hands_over_and_beats_baseline() {
    let spec = FederatedScenarioSpec::federated_dual_shell(42);
    let fed = run_federated_scenario(&spec);
    assert!(fed.box_killed_sats > 0, "the kill band must go dark: {fed:?}");
    assert!(fed.handovers > 0, "hot chunks must re-home: {fed:?}");
    assert!(fed.proactive_handover_blocks > 0, "evacuation must re-home blocks: {fed:?}");
    assert!(fed.inter_shell_bytes > 0, "the handover rides the inter-shell links: {fed:?}");
    assert!(fed.inter_shell_chunks > 0);

    let primary = fed.shells.iter().find(|s| s.name == fed.primary_shell).unwrap();
    let secondary = fed.shells.iter().find(|s| s.name != fed.primary_shell).unwrap();
    assert_eq!(fed.primary_shell, "kuiper-630", "denser planes make Kuiper the cost-primary");
    assert!(primary.blocks_stored > 0, "pre-kill traffic lands on the primary");
    assert!(secondary.blocks_hit > 0, "post-kill hits come from the secondary: {fed:?}");
    assert!(primary.failed_satellites > 0);

    let base = run_federated_scenario(&spec.baseline_single_shell());
    assert_eq!(base.shells.len(), 1);
    assert_eq!(fed.requests, base.requests, "identical workload either way");
    assert!(
        fed.block_hit_rate > base.block_hit_rate,
        "federation must out-hit the dead single shell: {} vs {}",
        fed.block_hit_rate,
        base.block_hit_rate
    );
    assert_eq!(base.handovers, 0, "a single shell has nowhere to hand over to");
    assert_eq!(base.inter_shell_bytes, 0);
    assert!(base.failed_writes > 0, "post-kill stores have nowhere to go in the baseline");
}

#[test]
fn federated_seeds_change_numbers_but_not_shape() {
    let a = run_federated_scenario(&FederatedScenarioSpec::federated_dual_shell(1));
    let b = run_federated_scenario(&FederatedScenarioSpec::federated_dual_shell(2));
    assert_ne!(a.to_json_string(), b.to_json_string());
    assert_eq!(a.requests, b.requests);
    assert_eq!(a.shells.len(), b.shells.len());
    assert_eq!(a.primary_shell, b.primary_shell);
}

#[test]
fn federated_report_carries_per_shell_metrics() {
    let r = run_federated_scenario(&FederatedScenarioSpec::federated_dual_shell(7));
    assert_eq!(r.shells.len(), 2);
    for sh in &r.shells {
        assert!(sh.analytic_worst_case_s > 0.0);
    }
    // after the kill + evacuation, the live data is homed on the secondary
    let secondary = r.shells.iter().find(|sh| sh.name != r.primary_shell).unwrap();
    assert!(secondary.placed_bytes > 0, "the secondary holds the hot set by the end: {r:?}");
    let j = r.to_json_string();
    for key in ["\"shells\"", "\"inter_shell_bytes\"", "\"handovers\"", "\"hit_rate\""] {
        assert!(j.contains(key), "missing {key} in {j}");
    }
}

#[test]
fn federated_scenario_registry_is_wired() {
    // the federated names resolve through their own registry and do not
    // collide with the single-shell one
    assert!(ScenarioSpec::by_name("federated-dual-shell", 3).is_none());
    assert!(ScenarioSpec::by_name("federated-tri-shell", 3).is_none());
    let spec = FederatedScenarioSpec::by_name("federated-dual-shell", 3).unwrap();
    spec.validate();
    assert_eq!(spec.seed, 3);
    FederatedScenarioSpec::by_name("federated-tri-shell", 3).unwrap().validate();
    assert!(FederatedScenarioSpec::by_name("paper-19x5", 3).is_none());
}

/// Golden property: the full replicated tri-shell federation under the
/// correlated-failure plan is byte-stable across two runs in the same
/// process, and the machinery really fired (replication, racing,
/// promotion, all three correlated kinds).
#[test]
fn federated_tri_shell_fixed_seed_is_byte_identical() {
    let spec = FederatedScenarioSpec::federated_tri_shell(1234);
    let a: FederatedScenarioReport = run_federated_scenario(&spec);
    let b: FederatedScenarioReport = run_federated_scenario(&spec);
    assert_eq!(a, b, "reports must be structurally identical");
    assert_eq!(a.to_json_string(), b.to_json_string(), "metrics JSON must be byte-identical");
    assert_eq!(a.shells.len(), 3);
    assert_eq!(a.plane_losses, 1, "{a:?}");
    assert_eq!(a.solar_storms, 1, "{a:?}");
    assert_eq!(a.box_kills, 1, "{a:?}");
    assert!(a.correlated_killed_sats > 100, "a storm band is a mass casualty: {a:?}");
    assert!(a.replicated_blocks > 0, "{a:?}");
    assert!(a.replica_races > 0, "{a:?}");
    assert!(a.replica_race_wins > 0, "the storm forces replica serves: {a:?}");
    assert!(a.replica_promotions > 0, "{a:?}");
}

/// Acceptance: under the identical correlated-failure plan (sudden solar
/// storm over the primary — no pre-announced evacuation — plus a plane
/// loss and a fractional box kill), the replicated tri-shell federation
/// strictly out-hits the re-homing-only baseline: racing pre-made
/// replicas saves the misses that reactive re-homing must eat, and the
/// §3.7 pre-placement keeps the hot set resolvable across handovers.
#[test]
fn replicated_tri_shell_beats_rehoming_only_baseline() {
    let spec = FederatedScenarioSpec::federated_tri_shell(42);
    let fed = run_federated_scenario(&spec);
    let base = run_federated_scenario(&spec.rehoming_baseline());
    assert_eq!(fed.requests, base.requests, "identical workload either way");
    assert_eq!(
        (base.replicated_blocks, base.replica_race_wins, base.preplaced_blocks),
        (0, 0, 0),
        "the baseline really is re-homing-only: {base:?}"
    );
    assert!(
        fed.block_hit_rate > base.block_hit_rate,
        "replication must strictly out-hit re-homing under the correlated plan: {} vs {}",
        fed.block_hit_rate,
        base.block_hit_rate
    );
    // the replica span is visible per shell: the second-cheapest shell
    // hosted replicas and served races
    let primary = fed.shells.iter().find(|s| s.name == fed.primary_shell).unwrap();
    let others: Vec<_> = fed.shells.iter().filter(|s| s.name != fed.primary_shell).collect();
    assert_eq!(fed.primary_shell, "kuiper-630");
    assert!(primary.blocks_stored > 0);
    assert!(others.iter().any(|s| s.replicas_hosted > 0), "{fed:?}");
    assert!(others.iter().any(|s| s.replica_hits > 0), "{fed:?}");
}
